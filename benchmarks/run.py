"""Benchmark harness — one benchmark per paper table/figure + system perf.

    PYTHONPATH=src python -m benchmarks.run            # all
    PYTHONPATH=src python -m benchmarks.run --only fig3_consensus
    PYTHONPATH=src python -m benchmarks.run --only kernel_micro,topology_sweep
    PYTHONPATH=src python -m benchmarks.run --smoke    # CI: tiny sizes

``--smoke`` shrinks every benchmark to seconds (fewer epochs / smaller
trees) so the CI fast job can execute the full harness on every push —
numbers are NOT meaningful in smoke mode, it exists to keep the benchmarks
from rotting; ``--only`` takes a comma-separated subset.

Benchmarks (the paper has one experiment, Fig. 3; the rest exercise the
theory quantities the paper derives and our beyond-paper claims):

  fig3_consensus        Sec. IV / Fig. 3: epochs to consensus + |w - w*|
  thm1_epsilon_sweep    Thm. 1 epsilon vs (gamma, T_S, graph) — prediction
                        vs measured final error
  consensus_strategies  faithful gossip vs collapsed vs Chebyshev: wall time
                        per epoch + rounds to target sigma (beyond-paper)
  topology_sweep        ring/line/star/complete/torus: sigma_A + spectral gap
  dynamic_federation    convergence under full vs sampled participation vs
                        faulty links vs server churn (the scenario engine)
  directed_federation   symmetric vs naive row-stochastic (biased) vs
                        push-sum (unbiased) gossip under directed /
                        asymmetrically-degraded links
  consensus_backends    einsum vs blocked vs shard_map vs shard_map_wire
                        (physical BUCKETED int8 wire; + an int4 variant)
                        consensus execution on the DYNAMIC engine (traced
                        per-epoch A_p): peak-RSS + epoch throughput per
                        backend, one clean subprocess each, cross-backend
                        agreement, and the physical-wire HLO cross-check
                        (per round: ONE all-gather of s8 codes + one of
                        f32 scales matching the bucketed byte ledger)
  compressed_consensus  the repro.comm layer: compressor x backend x wire
                        sweep recording bytes-on-wire (BytesTracker) vs
                        consensus error vs wall-clock; checks int8+EF
                        reaches the fig-3 tolerance at >= 3.5x fewer bytes
                        on BOTH the simulated and the physical wire, and
                        that the metadata byte counts match the analytic
                        forms
  overlapped_consensus  the epoch-barrier kill: per-epoch barrier engine
                        vs the K=8 fused superepoch megastep vs the
                        megastep with staleness-1 gossip on one dynamic
                        scenario — epochs/s + peak RSS per config, the
                        megastep speedup, and the CI-gated
                        staleness0_bitwise degeneration boolean (sha256
                        over final server params)
  byzantine_consensus   attack x defense grid: sign-flip / scaled-noise /
                        inlier-shift attackers vs plain gossip and the
                        robust screens (trimmed mean, median, clipped) —
                        honest-server error, honest disagreement, and the
                        per-defense wall-clock overhead
  obs_phases            the repro.obs telemetry stack on a full dynamic
                        scenario: per-phase wall breakdown (local vs
                        gossip vs surgery vs host aggregation) from the
                        span tracer, obs-on vs obs-off overhead, the
                        bitwise-inertness cross-check, and validating
                        JSONL + Chrome-trace artifacts for CI
  kernel_micro          Pallas-kernel (interpret) vs jnp-oracle parity +
                        CPU wall time (correctness harness, not TPU perf)
  lm_epoch_throughput   DFL epoch wall time on a smoke LM (CPU reference)

Each prints `name,metric,value` CSV rows and writes
experiments/bench_results.csv; the consensus benches additionally dump
experiments/BENCH_consensus.json (the machine-readable perf trajectory
tracked across PRs).
"""
import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

RESULTS = []
OUT = os.path.join(os.path.dirname(__file__), "..", "experiments")
SMOKE = False     # set by --smoke: tiny sizes, seconds per bench


def S(full, smoke):
    """Pick the full-size or the smoke-size value of a benchmark knob."""
    return smoke if SMOKE else full


def record(name, metric, value):
    RESULTS.append((name, metric, value))
    print(f"{name},{metric},{value}")


def bench_fig3_consensus():
    """Paper Fig. 3: 5x5, T_C=250, T_S=25 — epochs to consensus & error."""
    from repro.core import (DFLConfig, FLTopology, build_dfl_epoch_step,
                            init_dfl_state)
    from repro.data import RegressionSpec, make_regression_data
    from repro.optim import sgd

    topo = FLTopology(num_servers=5, clients_per_server=5,
                      t_client=S(250, 25), t_server=S(25, 5),
                      graph_kind="ring")
    data = make_regression_data(topo, RegressionSpec(), seed=0)
    x, y = jnp.asarray(data["x"]), jnp.asarray(data["y"])

    def loss_fn(w, batch, rng):
        xx, yy = batch
        return 0.5 * jnp.mean((xx @ w - yy) ** 2), {}

    gamma = 0.5 / (9.0 * topo.t_client)
    cfg = DFLConfig(topology=topo)
    opt = sgd(gamma)
    step = jax.jit(build_dfl_epoch_step(cfg, loss_fn, opt),
                   donate_argnums=(0,))
    state = init_dfl_state(cfg, jnp.zeros((2,)), opt, jax.random.key(0))
    batches = (jnp.broadcast_to(x, (topo.t_client,) + x.shape),
               jnp.broadcast_to(y, (topo.t_client,) + y.shape))
    w_star = np.linalg.lstsq(np.asarray(x).reshape(-1, 2),
                             np.asarray(y).reshape(-1), rcond=None)[0]
    consensus_epoch = None
    for epoch in range(S(200, 12)):
        state, metrics = step(state, batches)
        servers = np.asarray(state.client_params[:, 0])
        err = float(np.linalg.norm(servers - w_star, axis=-1).max())
        if consensus_epoch is None and float(
                metrics.server_disagreement) < 1e-3 and err < 0.05:
            consensus_epoch = epoch
    record("fig3_consensus", "epochs_to_consensus_near_wstar",
           consensus_epoch)
    record("fig3_consensus", "server_iters_to_consensus",
           (consensus_epoch + 1) * topo.t_server
           if consensus_epoch is not None else -1)
    record("fig3_consensus", "final_max_err", round(err, 5))
    record("fig3_consensus", "paper_claim_epochs", 160)


def bench_thm1_epsilon_sweep():
    from repro.core import (DFLConfig, FLTopology, build_dfl_epoch_step,
                            init_dfl_state)
    from repro.data import RegressionSpec, make_regression_data
    from repro.optim import sgd

    combos = [(25, 5, "ring"), (25, 25, "ring"),
              (50, 10, "line"), (25, 5, "complete")]
    for (t_c, t_s, graph) in S(combos, combos[:1]):
        topo = FLTopology(num_servers=5, clients_per_server=5, t_client=t_c,
                          t_server=t_s, graph_kind=graph)
        data = make_regression_data(topo, RegressionSpec(heterogeneity=1.0),
                                    seed=1)
        x, y = jnp.asarray(data["x"]), jnp.asarray(data["y"])

        def loss_fn(w, batch, rng):
            xx, yy = batch
            return 0.5 * jnp.mean((xx @ w - yy) ** 2), {}

        gamma = 0.4 / (9.0 * t_c)
        cfg = DFLConfig(topology=topo)
        opt = sgd(gamma)
        step = jax.jit(build_dfl_epoch_step(cfg, loss_fn, opt),
                       donate_argnums=(0,))
        state = init_dfl_state(cfg, jnp.zeros((2,)), opt, jax.random.key(0))
        batches = (jnp.broadcast_to(x, (t_c,) + x.shape),
                   jnp.broadcast_to(y, (t_c,) + y.shape))
        for _ in range(S(150, 10)):
            state, _ = step(state, batches)
        w_star = np.linalg.lstsq(np.asarray(x).reshape(-1, 2),
                                 np.asarray(y).reshape(-1), rcond=None)[0]
        servers = np.asarray(state.client_params[:, 0])
        err = float(np.linalg.norm(servers - w_star, axis=-1).max())
        eps = topo.epsilon_bound(gamma, mu=1.0, lsmooth=9.0, theta=80.0)
        tag = f"tc{t_c}_ts{t_s}_{graph}"
        record("thm1_epsilon", f"{tag}_measured_err", round(err, 5))
        record("thm1_epsilon", f"{tag}_predicted_eps", round(eps, 5))
        record("thm1_epsilon", f"{tag}_bound_holds", bool(err <= eps))


def bench_consensus_strategies():
    from repro.core import consensus as cns
    from repro.core import topology as tp

    m, t_s = 8, 25
    a_np = tp.metropolis_weights(tp.ring_graph(m))
    a = jnp.asarray(a_np, jnp.float32)
    a_eff = jnp.asarray(cns.collapse_mixing(a_np, t_s), jnp.float32)
    tree = {"w": jax.random.normal(jax.random.key(0),
                                   (m, S(1_000_000, 20_000)))}
    lam2 = float(np.sort(np.abs(np.linalg.eigvalsh(a_np)))[::-1][1])

    funcs = {
        "gossip_25rounds": jax.jit(lambda t: cns.gossip_scan(a, t, t_s)),
        "collapsed_1round": jax.jit(lambda t: cns.gossip_collapsed(a_eff, t)),
        "chebyshev_5rounds": jax.jit(
            lambda t: cns.gossip_chebyshev(a, t, 5, lam2)),
    }
    base = None
    reps = S(5, 1)
    for name, fn in funcs.items():
        out = fn(tree)
        jax.block_until_ready(out)
        t0 = time.time()
        for _ in range(reps):
            out = fn(tree)
            jax.block_until_ready(out)
        dt = (time.time() - t0) / reps
        record("consensus_strategies", f"{name}_ms", round(dt * 1000, 2))
        dis = float(jnp.linalg.norm(out["w"] - out["w"].mean(0)))
        record("consensus_strategies", f"{name}_residual_disagreement",
               round(dis, 6))
        if name.startswith("gossip"):
            base = out
        elif name.startswith("collapsed"):
            diff = float(jnp.abs(out["w"] - base["w"]).max())
            record("consensus_strategies", "collapsed_vs_gossip_maxdiff",
                   round(diff, 8))
    sig, rounds = 1.0, 0
    while sig > 0.01 and rounds < 500:
        rounds += 1
        sig = tp.sigma_a(a_np, rounds)
    record("consensus_strategies", "gossip_rounds_to_sigma_0.01", rounds)
    k = 1
    while cns.chebyshev_coefficients(a_np, k) > 0.01 and k < 500:
        k += 1
    record("consensus_strategies", "chebyshev_rounds_to_sigma_0.01", k)


def bench_topology_sweep():
    from repro.core import topology as tp
    for kind in ("ring", "line", "star", "complete"):
        for m in (5, 16):
            a = tp.metropolis_weights(tp.build_graph(kind, m))
            record("topology_sweep", f"{kind}_M{m}_sigma_T25",
                   round(tp.sigma_a(a, 25), 6))
            record("topology_sweep", f"{kind}_M{m}_spectral_gap",
                   round(tp.spectral_gap(a), 6))
    a = tp.metropolis_weights(tp.torus_2d_graph(4, 4))
    record("topology_sweep", "torus_M16_sigma_T25",
           round(tp.sigma_a(a, 25), 6))


def bench_kernel_micro():
    from repro.kernels import ops, ref

    kq, kkv, kx, kb, kc, kd = jax.random.split(jax.random.key(0), 6)
    seq = S(512, 128)
    q = jax.random.normal(kq, (2, seq, 8, 64))
    kv = jax.random.normal(kkv, (2, seq, 2, 64))

    def time_it(fn, *args):
        out = fn(*args)
        jax.block_until_ready(out)
        t0 = time.time()
        out = fn(*args)
        jax.block_until_ready(out)
        return out, (time.time() - t0) * 1000

    o_k, t_k = time_it(lambda a, b, c: ops.flash_attention(a, b, c), q, kv, kv)
    o_r, t_r = time_it(jax.jit(
        lambda a, b, c: ref.attention_ref(a, b, c)), q, kv, kv)
    record("kernel_micro", "flash_attn_err", float(jnp.abs(o_k - o_r).max()))
    record("kernel_micro", "flash_attn_interpret_ms", round(t_k, 1))
    record("kernel_micro", "flash_attn_jnp_ms", round(t_r, 1))

    xs = jax.random.normal(kx, (2, seq, 4, 64))
    bs = jax.random.normal(kb, (2, seq, 1, 128)) * 0.5
    cs = jax.random.normal(kc, (2, seq, 1, 128)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(kd, (2, seq, 4)))
    ac = -jnp.exp(jnp.linspace(-1, 1, 4))
    (y_k, _), t_k = time_it(
        lambda *a: ops.ssd_scan(*a, chunk=128), xs, bs, cs, dt, ac)
    (y_r, _), t_r = time_it(jax.jit(ref.ssd_scan_ref), xs, bs, cs, dt, ac)
    record("kernel_micro", "ssd_err", float(jnp.abs(y_k - y_r).max()))
    record("kernel_micro", "ssd_interpret_ms", round(t_k, 1))
    record("kernel_micro", "ssd_naive_ms", round(t_r, 1))


def bench_dynamic_federation():
    """Convergence under full vs sampled participation vs faulty links vs
    server churn — the scenario axis the static Algorithm 1 cannot express.
    Reports final max error to w*, epochs to reach err<0.5, and the
    time-varying product contraction sigma_prod."""
    from repro.core import (FLTopology, FaultEvent, FaultSchedule,
                            ParticipationSchedule, TopologySchedule,
                            init_dfl_state, make_engine)
    from repro.data import RegressionSpec, make_regression_task
    from repro.optim import sgd

    m, n, t_c, t_s, epochs = 5, 5, S(25, 5), S(10, 4), S(50, 6)
    topo = FLTopology(num_servers=m, clients_per_server=n, t_client=t_c,
                      t_server=t_s, graph_kind="ring")
    task = make_regression_task(topo, RegressionSpec(heterogeneity=0.5),
                                seed=0)
    loss_fn, batch_fn, w_star = (task["loss_fn"], task["batch_fn"],
                                 task["w_star"])

    gamma = 0.4 / (9.0 * t_c)
    scenarios = {
        "full": {},
        "sampled_50pct": {"participation": ParticipationSchedule(
            kind="bernoulli", rate=0.5, seed=7)},
        "sampled_25pct": {"participation": ParticipationSchedule(
            kind="bernoulli", rate=0.25, seed=7)},
        "faulty_links_p30": {"topology_schedule": TopologySchedule(
            kind="edge_drop", drop_prob=0.3, seed=11)},
        "stragglers_90pct": {"topology_schedule": TopologySchedule(
            kind="straggler", weaken=0.9, n_weak=2, seed=11)},
        "churn_drop_rejoin": {"faults": FaultSchedule((
            FaultEvent(15, "drop", 2), FaultEvent(30, "rejoin", 2)))},
    }
    for name, kw in scenarios.items():
        engine = make_engine(topo, loss_fn, sgd(gamma), **kw)
        state = init_dfl_state(engine.cfg, jnp.zeros((2,)), sgd(gamma),
                               jax.random.key(0))
        t0 = time.time()
        first_hit = None
        for epoch in range(epochs):
            state, rec = engine.run_epoch(state, epoch, batch_fn)
            servers = np.asarray(state.client_params[:, 0])
            err = float(np.linalg.norm(servers - w_star, axis=-1).max())
            if first_hit is None and err < 0.5:
                first_hit = epoch
        dt = time.time() - t0
        record("dynamic_federation", f"{name}_final_err", round(err, 5))
        record("dynamic_federation", f"{name}_epochs_to_err_0.5",
               first_hit if first_hit is not None else -1)
        record("dynamic_federation", f"{name}_sigma_prod",
               f"{rec['sigma_prod']:.3e}")
        record("dynamic_federation", f"{name}_wall_s", round(dt, 2))


def bench_directed_federation():
    """Symmetric gossip vs naive row-stochastic gossip (biased) vs push-sum
    (unbiased) under directed/asymmetrically-degraded server links.  The
    acceptance metric: push-sum's final disagreement AND distance-to-ideal
    stay within tolerance of the symmetric baseline while naive
    row-stochastic gossip stays biased (it converges to the Perron-weighted
    w_pi, not the uniform w*)."""
    from repro.core import (FLTopology, TopologySchedule, init_dfl_state,
                            make_engine, perron_weights)
    from repro.data import (RegressionSpec, make_regression_task,
                            perron_ideal)
    from repro.optim import sgd

    m, n, t_c, t_s, epochs = 5, 5, S(25, 5), S(30, 8), S(80, 6)
    ring = FLTopology(num_servers=m, clients_per_server=n, t_client=t_c,
                      t_server=t_s, graph_kind="ring")
    directed = FLTopology(num_servers=m, clients_per_server=n, t_client=t_c,
                          t_server=t_s, graph_kind="random_orientation",
                          mixing="out_degree")
    task = make_regression_task(directed, RegressionSpec(concept_shift=2.0),
                                seed=0)
    w_star = task["w_star"]
    d = np.asarray(task["x"]).shape[-1]
    pi = perron_weights(directed.mixing_matrix())
    w_pi = perron_ideal(task["x"], task["y"], pi)
    record("directed_federation", "perron_bias_norm",
           round(float(np.linalg.norm(w_pi - w_star)), 5))

    gamma = 0.4 / (9.0 * t_c)
    scenarios = {
        "symmetric": dict(topo=ring, mixing="symmetric"),
        "naive_row_stochastic": dict(topo=directed, mixing="row_stochastic"),
        "push_sum": dict(topo=directed, mixing="push_sum"),
        "push_sum_asymmetric": dict(
            topo=ring, mixing="push_sum",
            topology_schedule=TopologySchedule(kind="asymmetric",
                                               drop_prob=0.4, seed=11)),
    }
    errs = {}
    for name, sc in scenarios.items():
        kw = {k: v for k, v in sc.items() if k != "topo"}
        engine = make_engine(sc["topo"], task["loss_fn"], sgd(gamma), **kw)
        state = init_dfl_state(engine.cfg, jnp.zeros((d,)), sgd(gamma),
                               jax.random.key(0))
        t0 = time.time()
        state, hist = engine.run(state, epochs, task["batch_fn"])
        dt = time.time() - t0
        servers = np.asarray(state.client_params[:, 0])
        errs[name] = float(np.linalg.norm(servers - w_star, axis=-1).max())
        err_pi = float(np.linalg.norm(servers - w_pi, axis=-1).max())
        record("directed_federation", f"{name}_err_to_wstar",
               round(errs[name], 5))
        record("directed_federation", f"{name}_err_to_wpi", round(err_pi, 5))
        record("directed_federation", f"{name}_final_disagreement",
               f"{hist['disagreement'][-1]:.3e}")
        if "psum_min_weight" in hist:
            record("directed_federation", f"{name}_psum_min_weight",
                   round(hist["psum_min_weight"][-1], 4))
        record("directed_federation", f"{name}_wall_s", round(dt, 2))
    tol = 1.2 * errs["symmetric"] + 0.02
    record("directed_federation", "push_sum_unbiased",
           bool(errs["push_sum"] <= tol and errs["push_sum_asymmetric"] <= tol))
    record("directed_federation", "naive_row_stochastic_biased",
           bool(errs["naive_row_stochastic"] > 1.5 * errs["push_sum"]))


def bench_consensus_backends():
    """Consensus-execution backends on the dynamic engine at a gossip-bound
    model size: einsum (reference per-leaf) vs blocked streaming vs
    shard_map explicit collectives, each driven through the SAME edge_drop
    schedule with a traced per-epoch A_p.  Each backend runs in its own
    subprocess so ru_maxrss is a clean per-path peak; the parent checks the
    paths agree on the final parameters (allclose) and records peak-RSS and
    epoch throughput per backend."""
    import json
    import subprocess
    import sys

    child = r'''
import os, sys, json, time, resource
backend = sys.argv[1]
if backend.startswith("shard_map"):
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=4 "
                               + os.environ.get("XLA_FLAGS", ""))
import jax, jax.numpy as jnp, numpy as np
from repro.core import (FLTopology, TopologySchedule, init_dfl_state,
                        make_engine)
from repro.optim import sgd

m, n, t_c, t_s, epochs, d = 4, 2, 2, 10, int(sys.argv[2]), int(sys.argv[3])
topo = FLTopology(num_servers=m, clients_per_server=n, t_client=t_c,
                  t_server=t_s, graph_kind="ring")

def loss_fn(w, batch, rng):
    # gossip-bound toy objective over a wide parameter vector: the epoch
    # cost is dominated by the consensus period, which is what we meter
    return 0.5 * jnp.mean(w * w) + 0.0 * batch.sum(), {}

def batch_fn(epoch, alive):
    return jnp.zeros((t_c, len(alive), n, 1), jnp.float32)

kw = {}
if backend == "gossip_blocked":
    kw["consensus_mode"] = "gossip_blocked"
elif backend.startswith("shard_map"):
    from repro.launch import sharding as shd
    mesh = jax.sharding.Mesh(np.array(jax.devices()).reshape(m), ("server",))
    server_abs = jax.eval_shape(lambda: jnp.zeros((m, d), jnp.float32))
    ckw = {}
    if backend.startswith("shard_map_wire"):
        ckw = {"compression": ("int4" if backend.endswith("int4")
                               else "int8"),
               "error_feedback": True, "wire": "physical"}
    kw["consensus_backend"] = shd.fl_consensus_backend(
        topo, mesh, server_abs, tp_axis=None, **ckw)
engine = make_engine(topo, loss_fn, sgd(1e-3),
                     topology_schedule=TopologySchedule(
                         kind="edge_drop", drop_prob=0.3, seed=7), **kw)
params = jax.random.normal(jax.random.key(0), (d,), jnp.float32)
state = init_dfl_state(engine.cfg, params, sgd(1e-3), jax.random.key(1))
state, rec = engine.run_epoch(state, 0, batch_fn)    # compile outside timing
wire_mb = rec.get("wire_mb", 0.0)
t0 = time.time()
for epoch in range(1, epochs):
    state, rec = engine.run_epoch(state, epoch, batch_fn)
    wire_mb += rec.get("wire_mb", 0.0)
wall = time.time() - t0
out = {
    "peak_rss_mb": resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024,
    "epochs_per_s": (epochs - 1) / wall,
}
servers = np.asarray(state.client_params[:, 0], np.float64)
out["checksum"] = [float(servers.sum()), float(np.abs(servers).max())]
out["fingerprint"] = servers[:, ::100_000].tolist()
if backend.startswith("shard_map_wire"):
    # physical-wire cross-check: the compiled all-gather operands must be
    # the codec's BUCKETED byte layout (one s8 code buffer + one f32
    # scale buffer per round for the whole tree), and the per-round bytes
    # one server ships must equal what the BytesTracker ledger charges
    # per link message
    from repro.comm.accounting import (hlo_collective_bytes,
                                       tree_bucketed_wire_bytes_per_server)
    cb = kw["consensus_backend"]
    runner = cb.inner.wire_runner(cb.compressor, stochastic=True)
    tree = {"w": jnp.zeros((m, d), jnp.float32)}
    hlo = jax.jit(runner).lower(
        jnp.zeros((m, m), jnp.float32), tree, jax.random.key(0)
    ).compile().as_text()
    cols = hlo_collective_bytes(hlo)
    gathers = [c for c in cols if c["op"] == "all-gather"]
    shipped = sum(c["bytes"] // m for c in gathers)      # one round's pair
    expect = tree_bucketed_wire_bytes_per_server(cb.compressor, tree,
                                                 cb.inner.block)
    out["wire_hlo_gather_sites"] = len(gathers)
    out["wire_hlo_dtypes"] = sorted({c["dtype"] for c in gathers})
    out["wire_hlo_round_bytes"] = shipped
    out["wire_hlo_matches_ledger"] = bool(shipped == expect)
    out["wire_mb"] = wire_mb
# sentinel-prefixed result line: the parent parses by prefix, so stray
# stdout from jax/engine logging can never masquerade as the datapoint
print("BENCH_JSON " + json.dumps(out))
'''
    src = os.path.abspath(os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    results = {}
    epochs, d = S(5, 3), S(1_500_000, 100_000)
    sentinel = "BENCH_JSON "
    for backend in ("gossip", "gossip_blocked", "shard_map",
                    "shard_map_wire", "shard_map_wire_int4"):
        r = subprocess.run([sys.executable, "-c", child, backend,
                            str(epochs), str(d)],
                           capture_output=True, text=True, timeout=900,
                           env={**os.environ, "PYTHONPATH": src})
        # parse by sentinel prefix, never "the last stdout line": engine /
        # jax logging can trail the datapoint, and a dead subprocess then
        # records an error row instead of crashing the whole bench (the
        # JSON writer merges key-level, so the other backends' fresh
        # numbers still land and the dead one keeps its last datapoint)
        line = next((ln for ln in reversed(r.stdout.splitlines())
                     if ln.startswith(sentinel)), None)
        if r.returncode != 0 or line is None:
            err = (r.stderr.strip().splitlines()[-1][:120]
                   if r.stderr.strip() else "no BENCH_JSON line")
            record("consensus_backends", f"{backend}_error",
                   err.replace(",", ";"))
            continue
        results[backend] = json.loads(line[len(sentinel):])
        record("consensus_backends", f"{backend}_peak_rss_mb",
               round(results[backend]["peak_rss_mb"], 1))
        record("consensus_backends", f"{backend}_epochs_per_s",
               round(results[backend]["epochs_per_s"], 3))
    for backend in ("shard_map_wire", "shard_map_wire_int4"):
        if backend not in results:
            continue
        sw = results[backend]
        record("consensus_backends", f"{backend}_hlo_gather_sites",
               sw["wire_hlo_gather_sites"])
        record("consensus_backends", f"{backend}_hlo_dtypes",
               "+".join(sw["wire_hlo_dtypes"]))
        record("consensus_backends", f"{backend}_hlo_round_bytes",
               sw["wire_hlo_round_bytes"])
        record("consensus_backends", f"{backend}_bytes_match_hlo",
               sw["wire_hlo_matches_ledger"])
        record("consensus_backends", f"{backend}_total_wire_mb",
               round(sw["wire_mb"], 3))
    if "gossip" in results:
        ref_fp = np.asarray(results["gossip"]["fingerprint"])
        ref_ck = np.asarray(results["gossip"]["checksum"])
        for backend in ("gossip_blocked", "shard_map"):
            if backend in results:
                diff = float(np.abs(
                    np.asarray(results[backend]["fingerprint"])
                    - ref_fp).max())
                # the checksum ([sum, max|.|] over the FULL vector) catches
                # divergence outside the strided fingerprint coordinates
                ck = np.asarray(results[backend]["checksum"])
                ck_ok = bool(np.allclose(ck, ref_ck, rtol=1e-5, atol=1e-3))
                record("consensus_backends", f"{backend}_vs_einsum_maxdiff",
                       f"{diff:.3e}")
                record("consensus_backends", f"{backend}_agrees_with_einsum",
                       bool(diff < 1e-4 and ck_ok))


def bench_overlapped_consensus():
    """The epoch-barrier kill: the SAME dynamic scenario (bernoulli
    participation + edge_drop schedule on a gossip-bound model) run by the
    per-epoch barrier engine, by the K=8 fused superepoch megastep, and by
    the megastep with bounded-staleness (s=1) gossip.  Each config runs in
    its own subprocess (clean ru_maxrss, fresh compile caches); the parent
    records epochs/s + peak RSS per config, the megastep's speedup over
    the barrier, and the `staleness0_bitwise` boolean — a sha256 over the
    final server parameters proving the K=8 / staleness=0 megastep is
    BITWISE the barrier engine (the degeneration oracle, CI-gated)."""
    import json
    import subprocess
    import sys

    child = r'''
import os, sys, json, time, hashlib, resource
import jax, jax.numpy as jnp, numpy as np
from repro.core import (FLTopology, TopologySchedule, ParticipationSchedule,
                        init_dfl_state, make_engine)
from repro.optim import sgd

superepoch, staleness, epochs, d = (int(sys.argv[1]), int(sys.argv[2]),
                                    int(sys.argv[3]), int(sys.argv[4]))
m, n, t_c, t_s = 4, 2, 2, 10
topo = FLTopology(num_servers=m, clients_per_server=n, t_client=t_c,
                  t_server=t_s, graph_kind="ring")

def loss_fn(w, batch, rng):
    # toy objective sized so per-epoch device work is SMALL: the per-epoch
    # HOST barrier (dispatch + readback sync) is what the configs differ
    # in, which is exactly the regime the megastep targets
    return 0.5 * jnp.mean(w * w) + 0.0 * batch.sum(), {}

def batch_fn(epoch, alive):
    # hands over HOST numpy, like a real data loader: the device put is
    # part of the metered path (once per epoch vs once per block)
    return np.zeros((t_c, len(alive), n, 1), np.float32)

engine = make_engine(topo, loss_fn, sgd(1e-3),
                     participation=ParticipationSchedule(
                         kind="bernoulli", rate=0.8, seed=3),
                     topology_schedule=TopologySchedule(
                         kind="edge_drop", drop_prob=0.3, seed=7),
                     superepoch=superepoch, staleness=staleness)

def fresh():
    params = jax.random.normal(jax.random.key(0), (d,), jnp.float32)
    return init_dfl_state(engine.cfg, params, sgd(1e-3), jax.random.key(1))

# warm outside timing: the compiled (M, K) step donates its state operand,
# so the timed run gets a FRESH state (warm buffers are consumed)
engine.run(fresh(), max(superepoch, 1), batch_fn)
state = fresh()
t0 = time.time()
state, hist = engine.run(state, epochs, batch_fn)
wall = time.time() - t0
servers = np.asarray(state.client_params[:, 0], np.float32)
out = {
    "peak_rss_mb": resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024,
    "epochs_per_s": epochs / wall,
    # bitwise fingerprint: digest equality <=> final-params bit equality
    "params_sha256": hashlib.sha256(servers.tobytes()).hexdigest(),
    "loss_last": float(hist["loss"][-1]),
}
# sentinel-prefixed result line: the parent parses by prefix, so stray
# stdout from jax/engine logging can never masquerade as the datapoint
print("BENCH_JSON " + json.dumps(out))
'''
    src = os.path.abspath(os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    epochs, d = S(256, 32), S(10_000, 4_000)
    configs = (("barrier", 1, 0), ("superepoch8", 8, 0),
               ("superepoch8_stale1", 8, 1))
    sentinel = "BENCH_JSON "
    results = {}
    for tag, k, s in configs:
        r = subprocess.run([sys.executable, "-c", child, str(k), str(s),
                            str(epochs), str(d)],
                           capture_output=True, text=True, timeout=900,
                           env={**os.environ, "PYTHONPATH": src})
        line = next((ln for ln in reversed(r.stdout.splitlines())
                     if ln.startswith(sentinel)), None)
        if r.returncode != 0 or line is None:
            err = (r.stderr.strip().splitlines()[-1][:120]
                   if r.stderr.strip() else "no BENCH_JSON line")
            record("overlapped_consensus", f"{tag}_error",
                   err.replace(",", ";"))
            continue
        results[tag] = json.loads(line[len(sentinel):])
        record("overlapped_consensus", f"{tag}_epochs_per_s",
               round(results[tag]["epochs_per_s"], 3))
        record("overlapped_consensus", f"{tag}_peak_rss_mb",
               round(results[tag]["peak_rss_mb"], 1))
    if "barrier" in results and "superepoch8" in results:
        record("overlapped_consensus", "superepoch8_speedup_vs_barrier",
               round(results["superepoch8"]["epochs_per_s"]
                     / results["barrier"]["epochs_per_s"], 3))
        # the degeneration oracle: K=8 + staleness=0 must be the barrier
        # engine BITWISE, not merely allclose — CI asserts this boolean
        record("overlapped_consensus", "staleness0_bitwise",
               bool(results["superepoch8"]["params_sha256"]
                    == results["barrier"]["params_sha256"]))
    if "superepoch8_stale1" in results:
        record("overlapped_consensus", "stale1_loss_last",
               f"{results['superepoch8_stale1']['loss_last']:.3e}")


def bench_lm_epoch_throughput():
    from repro.launch.train import train
    epochs, t_c, seq = S(3, 1), S(3, 2), S(128, 32)
    t0 = time.time()
    res = train("smollm-360m", servers=2, clients=2, t_client=t_c,
                t_server=5, epochs=epochs, seq_len=seq, per_client_batch=2,
                gamma=0.05, log_every=100)
    dt = time.time() - t0
    tokens = epochs * t_c * 4 * 2 * seq
    record("lm_epoch_throughput", "smoke_tokens_per_s", round(tokens / dt, 1))
    record("lm_epoch_throughput", "loss_delta",
           round(res["history"]["loss"][0] - res["history"]["loss"][-1], 4))


def bench_compressed_consensus():
    """The repro.comm subsystem: compressor x backend sweep on a 32-d
    regression task (d=2 would make byte ratios meaningless), recording
    bytes-on-wire vs consensus error vs wall-clock.  Acceptance criteria
    recorded as explicit booleans: int8 + error feedback reaches the fig-3
    consensus tolerance (server disagreement < 1e-3, max server error to
    w* < 0.05) while BytesTracker reports >= 3.5x fewer on-wire bytes than
    uncompressed float32 gossip; the metadata byte counts equal the
    analytic closed forms."""
    from repro.comm.accounting import analytic_row_bytes
    from repro.comm.compressors import make_compressor
    from repro.core import FLTopology, init_dfl_state, make_engine
    from repro.data import RegressionSpec, make_regression_task
    from repro.optim import sgd

    m, n, t_c, t_s = 5, 5, S(25, 10), S(25, 10)
    epochs = S(150, 8)
    d = 32
    rng = np.random.default_rng(7)
    w_true = tuple(float(v) for v in
                   np.concatenate([rng.normal(0, 2.0, d - 1), [2.0]]))
    topo = FLTopology(num_servers=m, clients_per_server=n, t_client=t_c,
                      t_server=t_s, graph_kind="ring")
    task = make_regression_task(
        topo, RegressionSpec(w_star=w_true, heterogeneity=0.3), seed=0)
    w_star = task["w_star"]
    gamma = 0.4 / (9.0 * t_c)

    # metadata-vs-analytic cross-check rides along with the sweep
    ok = all(make_compressor(s).wire_bytes_per_row(dd)
             == analytic_row_bytes(make_compressor(s), dd)
             for s in ("int8", "int4", "top_k:0.05", "random_k:0.1")
             for dd in (2, d, 1000))
    record("compressed_consensus", "bytes_metadata_matches_analytic", ok)

    sweep = {
        "none": ("none", False, "simulated"),
        "int8": ("int8", False, "simulated"),
        "int8_ef": ("int8", True, "simulated"),
        "int4_ef": ("int4", True, "simulated"),
        "top_k10_ef": ("top_k:0.10", True, "simulated"),
        # the physical wire: codes through the collectives, re-quantized
        # at every hop — must still reach the fig-3 tolerance
        "int8_ef_phys": ("int8", True, "physical"),
        "int4_ef_phys": ("int4", True, "physical"),
    }
    from repro.core import consensus as cns

    a_np = topo.mixing_matrix()
    stats = {}
    for label, (spec, use_ef, wire) in sweep.items():
        for mode in ("gossip", "gossip_blocked"):
            if mode == "gossip_blocked":
                # inject a right-sized blocked backend: the default 4 MiB
                # block would pad this 32-d model 100k-fold per round
                backend = cns.make_backend(
                    "gossip_blocked", a_np, t_s, block=256,
                    compression=spec, error_feedback=use_ef, wire=wire)
                kw = {"consensus_backend": backend}
            else:
                kw = {"consensus_mode": mode, "compression": spec,
                      "error_feedback": use_ef, "wire": wire}
            engine = make_engine(topo, task["loss_fn"], sgd(gamma), **kw)
            state = init_dfl_state(engine.cfg, jnp.zeros((d,)), sgd(gamma),
                                   jax.random.key(0))
            t0 = time.time()
            state, hist = engine.run(state, epochs, task["batch_fn"])
            wall = time.time() - t0
            servers = np.asarray(state.client_params[:, 0])
            err = float(np.linalg.norm(servers - w_star, axis=-1).max())
            dis = hist["disagreement"][-1]
            tag = f"{label}_{mode}"
            record("compressed_consensus", f"{tag}_final_err", round(err, 5))
            record("compressed_consensus", f"{tag}_final_disagreement",
                   f"{dis:.3e}")
            record("compressed_consensus", f"{tag}_wall_s", round(wall, 2))
            if "wire_mb" in hist:
                record("compressed_consensus", f"{tag}_wire_mb",
                       round(sum(hist["wire_mb"]), 4))
                record("compressed_consensus", f"{tag}_bytes_ratio",
                       round(hist["wire_ratio"][-1], 3))
            stats[tag] = {"err": err, "dis": dis,
                          "ratio": hist.get("wire_ratio", [1.0])[-1]}
    hero = stats["int8_ef_gossip"]
    record("compressed_consensus", "int8_ef_reaches_fig3_tolerance",
           bool(hero["dis"] < 1e-3 and hero["err"] < 0.05))
    record("compressed_consensus", "int8_ef_bytes_ratio_ge_3.5",
           bool(hero["ratio"] >= 3.5))
    phys = stats["int8_ef_phys_gossip"]
    record("compressed_consensus", "physical_int8_ef_reaches_fig3_tolerance",
           bool(phys["dis"] < 1e-3 and phys["err"] < 0.05))
    record("compressed_consensus", "physical_int8_ef_bytes_ratio",
           round(phys["ratio"], 3))


def bench_byzantine_consensus():
    """Attack x defense grid on the fig-3 regression task (homogeneous
    shards so the honest optimum is unambiguous): does each attack break
    plain gossip, and does each robust screen hold under it?  Records the
    honest servers' max error to w*, their mutual disagreement, and wall
    time — the robustness datapoint tracked in BENCH_consensus.json."""
    from repro.core import (ByzantineSchedule, FLTopology, init_dfl_state,
                            make_engine)
    from repro.data import RegressionSpec, make_regression_task
    from repro.optim import sgd

    m, n, t_c, t_s, epochs = 8, 3, S(15, 6), 8, S(40, 4)
    topo = FLTopology(num_servers=m, clients_per_server=n, t_client=t_c,
                      t_server=t_s, graph_kind="complete")
    task = make_regression_task(topo, RegressionSpec(heterogeneity=0.0),
                                seed=0)
    w_star = task["w_star"]
    gamma = 1.5 / (9.0 * t_c)
    attacks = {"none": None,
               "sign_flip": "sign_flip:0.125",
               "scaled_noise": "scaled_noise:0.125:10.0",
               "inlier_shift": "inlier_shift:0.125:1.0"}
    defenses = ("gossip", "trimmed_mean:1", "median", "clipped")
    for aname, spec in attacks.items():
        byz = ByzantineSchedule.parse(spec, seed=3) if spec else None
        honest = np.ones(m, bool)
        if byz is not None:
            honest = byz.codes(0, tuple(range(m)), m) == 0
        for mode in defenses:
            engine = make_engine(topo, task["loss_fn"], sgd(gamma),
                                 consensus_mode=mode, byzantine=byz)
            state = init_dfl_state(engine.cfg, jnp.zeros((2,)), sgd(gamma),
                                   jax.random.key(0))
            t0 = time.time()
            state, _ = engine.run(state, epochs, task["batch_fn"])
            wall = time.time() - t0
            servers = np.asarray(state.client_params[:, 0])[honest]
            err = float(np.linalg.norm(servers - w_star, axis=-1).max())
            dis = float(np.linalg.norm(servers - servers.mean(0),
                                       axis=-1).max())
            tag = f"{aname}_{mode.replace(':', '')}"
            record("byzantine_consensus", f"{tag}_honest_err",
                   round(err, 5))
            record("byzantine_consensus", f"{tag}_honest_disagreement",
                   f"{dis:.3e}")
            record("byzantine_consensus", f"{tag}_wall_s", round(wall, 2))
    record("byzantine_consensus", "attacker_fraction", 0.125)
    record("byzantine_consensus", "graph", "complete8")


def bench_obs_phases():
    """The repro.obs stack on a full dynamic scenario (sampled
    participation + faulty links + drop/rejoin churn + physical int8+EF
    wire): per-phase wall breakdown from the span tracer (local vs gossip
    vs surgery vs host aggregation), obs-on vs obs-off overhead, the
    bitwise-inertness cross-check, and validating JSONL + Chrome trace
    artifacts for CI to upload."""
    from repro.core import (FLTopology, FaultEvent, FaultSchedule,
                            ParticipationSchedule, TopologySchedule,
                            init_dfl_state, make_engine)
    from repro.data import RegressionSpec, make_regression_task
    from repro.obs import (JSONLSink, MemorySink, MetricsHub, Observability,
                           Tracer, load_jsonl, validate_chrome_trace,
                           validate_jsonl)
    from repro.optim import sgd

    m, n, t_c, t_s, epochs = 4, 4, S(20, 4), S(8, 3), S(30, 8)
    topo = FLTopology(num_servers=m, clients_per_server=n, t_client=t_c,
                      t_server=t_s, graph_kind="ring")
    task = make_regression_task(topo, RegressionSpec(heterogeneity=0.5),
                                seed=0)
    gamma = 0.4 / (9.0 * t_c)
    kw = dict(consensus_mode="gossip", compression="int8",
              error_feedback=True, wire="physical",
              participation=ParticipationSchedule(kind="bernoulli",
                                                  rate=0.7, seed=7),
              topology_schedule=TopologySchedule(kind="edge_drop",
                                                 drop_prob=0.3, seed=11),
              faults=FaultSchedule((FaultEvent(epochs // 3, "drop", 2),
                                    FaultEvent(2 * epochs // 3, "rejoin",
                                               2))))

    def run(obs):
        engine = make_engine(topo, task["loss_fn"], sgd(gamma), obs=obs,
                             **kw)
        state = init_dfl_state(engine.cfg, jnp.zeros((2,)), sgd(gamma),
                               jax.random.key(0))
        hist = {}
        t0 = time.time()
        for epoch in range(epochs):
            state, rec = engine.run_epoch(state, epoch, task["batch_fn"])
            for k, v in rec.items():
                hist.setdefault(k, []).append(v)
        return hist, time.time() - t0, engine

    hist_off, wall_off, _ = run(None)

    os.makedirs(OUT, exist_ok=True)
    jsonl_path = os.path.join(OUT, "telemetry_smoke.jsonl")
    trace_path = os.path.join(OUT, "trace_smoke.json")
    tracer = Tracer()
    obs = Observability(
        hub=MetricsHub([MemorySink(),
                        JSONLSink(jsonl_path,
                                  run_info={"bench": "obs_phases",
                                            "smoke": SMOKE})]),
        tracer=tracer, monitor=True)
    hist_on, wall_on, engine = run(obs)
    obs.close()
    tracer.save_chrome(trace_path)

    inert = (set(hist_off) == set(hist_on)
             and all(hist_off[k] == hist_on[k] for k in hist_off))
    record("obs_phases", "bitwise_inert", inert)
    record("obs_phases", "epochs", epochs)
    record("obs_phases", "wall_off_s", round(wall_off, 3))
    record("obs_phases", "wall_on_s", round(wall_on, 3))
    record("obs_phases", "obs_overhead_pct",
           round(100.0 * (wall_on - wall_off) / max(wall_off, 1e-9), 1))
    phase_s = {}
    for sp in tracer.spans:
        phase_s[sp.name] = phase_s.get(sp.name, 0.0) + sp.duration_ns / 1e9
    for name in ("local-period", "gossip-period", "fault-surgery",
                 "host-aggregation"):
        record("obs_phases", f"phase_{name.replace('-', '_')}_s",
               round(phase_s.get(name, 0.0), 4))
    compiles = [ev["args"]["cause"] for ev in tracer.instants
                if ev["name"] == "compile"]
    record("obs_phases", "compiles", len(compiles))
    record("obs_phases", "compile_causes", ";".join(sorted(set(compiles))))
    n_events = len(validate_jsonl(load_jsonl(jsonl_path)))
    import json as _json
    with open(trace_path) as f:
        n_trace = len(validate_chrome_trace(_json.load(f)))
    record("obs_phases", "jsonl_events", n_events)
    record("obs_phases", "trace_events", n_trace)
    record("obs_phases", "scenario",
           "bernoulli0.7+edge_drop0.3+churn+int8_ef_physical")


BENCHES = {
    "fig3_consensus": bench_fig3_consensus,
    "thm1_epsilon_sweep": bench_thm1_epsilon_sweep,
    "consensus_strategies": bench_consensus_strategies,
    "topology_sweep": bench_topology_sweep,
    "dynamic_federation": bench_dynamic_federation,
    "directed_federation": bench_directed_federation,
    "consensus_backends": bench_consensus_backends,
    "compressed_consensus": bench_compressed_consensus,
    "byzantine_consensus": bench_byzantine_consensus,
    "overlapped_consensus": bench_overlapped_consensus,
    "obs_phases": bench_obs_phases,
    "kernel_micro": bench_kernel_micro,
    "lm_epoch_throughput": bench_lm_epoch_throughput,
}


def main() -> None:
    global SMOKE
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated benchmark subset, e.g. "
                         "'kernel_micro,topology_sweep'")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes (seconds per bench): keeps benchmarks "
                         "executable in the CI fast job; numbers are not "
                         "meaningful")
    args = ap.parse_args()
    SMOKE = args.smoke
    if args.only:
        names = [n.strip() for n in args.only.split(",") if n.strip()]
        unknown = [n for n in names if n not in BENCHES]
        if unknown:
            raise SystemExit(f"unknown benchmark(s) {unknown}; choose from "
                             f"{list(BENCHES)}")
    else:
        names = list(BENCHES)
    print("name,metric,value")
    for name in names:
        BENCHES[name]()
    os.makedirs(OUT, exist_ok=True)
    # smoke numbers are for execution coverage only: never overwrite the
    # recorded full-size results with them
    out_name = "bench_results_smoke.csv" if SMOKE else "bench_results.csv"
    path = os.path.join(OUT, out_name)
    ran = {name for name, _, _ in RESULTS}
    kept = []
    if args.only and os.path.exists(path):
        # a partial (--only) run refreshes ITS benches' rows and keeps the
        # rest of the recorded results instead of clobbering them
        with open(path) as f:
            kept = [ln.rstrip("\n") for ln in f.readlines()[1:]
                    if ln.split(",", 1)[0] not in ran]
    with open(path, "w") as f:
        f.write("name,metric,value\n")
        for ln in kept:
            f.write(ln + "\n")
        for row in RESULTS:
            f.write(",".join(str(r) for r in row) + "\n")
    write_bench_consensus_json()


def write_bench_consensus_json() -> None:
    """Machine-readable consensus-perf trajectory: whenever the
    consensus_backends / compressed_consensus benchmarks ran, dump their
    rows (per-backend wall-clock + peak RSS, simulated vs physical wire
    bytes and ratios, the HLO cross-check booleans) to
    experiments/BENCH_consensus.json so the numbers are diffable across
    PRs — the CSV is for humans, this file is the datapoint."""
    import json

    tracked = ("consensus_backends", "compressed_consensus",
               "byzantine_consensus", "overlapped_consensus", "obs_phases")
    per_bench = {name: {m: v for n, m, v in RESULTS if n == name}
                 for name in tracked}
    per_bench = {k: v for k, v in per_bench.items() if v}
    if not per_bench:
        return
    out_name = ("BENCH_consensus_smoke.json" if SMOKE
                else "BENCH_consensus.json")
    path = os.path.join(OUT, out_name)
    if os.path.exists(path):
        # KEY-level merge with the recorded datapoint: a partial (--only)
        # run refreshes its benches' metrics, and a bench whose subprocess
        # died mid-run (only an _error row landed) keeps the surviving
        # backends' fresh numbers WITHOUT dropping the dead backend's last
        # good metrics — the trajectory file must never lose a datapoint
        # to one crashed child
        try:
            with open(path) as f:
                old = json.load(f).get("benchmarks", {})
            for name in tracked:
                merged = dict(old.get(name, {}))
                merged.update(per_bench.get(name, {}))
                if merged:
                    per_bench[name] = merged
            per_bench = {k: v for k, v in per_bench.items() if v}
        except (ValueError, OSError):
            pass
    payload = {"smoke": SMOKE, "benchmarks": per_bench}
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")


if __name__ == "__main__":
    main()
