"""Render EXPERIMENTS.md §Roofline tables from experiments/dryrun/*.json.

    PYTHONPATH=src python -m benchmarks.roofline_table [--mesh sp|mp]
"""
import argparse
import glob
import json
import os

DRY = os.path.join(os.path.dirname(__file__), "..", "experiments", "dryrun")

SHAPE_ORDER = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2,
               "long_500k": 3}


def load(mesh_tag: str):
    rows = []
    for path in sorted(glob.glob(os.path.join(DRY, f"*_{mesh_tag}.json"))):
        with open(path) as f:
            rec = json.load(f)
        rows.append(rec)
    rows.sort(key=lambda r: (r["meta"]["arch"],
                             SHAPE_ORDER.get(r["meta"]["shape"], 9)))
    return rows


def fmt(x, digits=3):
    if x is None:
        return "-"
    if x == 0:
        return "0"
    return f"{x:.{digits}g}"


def render(mesh_tag: str) -> str:
    rows = load(mesh_tag)
    out = [f"| arch | shape | compute s | memory s | collective s | "
           f"dominant | peak GB/dev | coll GB/dev | model TFLOP |",
           "|---|---|---|---|---|---|---|---|---|"]
    for rec in rows:
        r = rec["roofline"]
        m = rec["meta"]
        peak = (rec["memory"]["peak_per_device"] or 0) / 1e9
        out.append(
            f"| {m['arch']} | {m['shape']} | {fmt(r['compute_s'])} | "
            f"{fmt(r['memory_s'])} | {fmt(r['collective_s'])} | "
            f"{r['dominant']} | {peak:.1f} | "
            f"{r['collective_bytes_per_device']/1e9:.1f} | "
            f"{r['model_flops']/1e12:.0f} |")
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", choices=("sp", "mp"), default="sp")
    args = ap.parse_args()
    print(render(args.mesh))


if __name__ == "__main__":
    main()
