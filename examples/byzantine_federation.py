"""Byzantine-robust federation on the paper's Sec.-IV regression task.

    PYTHONPATH=src python examples/byzantine_federation.py

Algorithm 1 trusts every server's aggregate.  This example puts 1 of 8
servers under adversarial control (its post-aggregation model is replaced
BEFORE gossip each epoch) and runs the same engine through an attack x
defense grid:

  attacks   sign_flip     broadcast the negated model (w -> -w)
            scaled_noise  broadcast w + 10 * N(0, I)
            inlier_shift  collude to the corner of the honest
                          coordinatewise envelope (unscreenable bias)

  defenses  gossip        the paper's plain weighted gossip (no defense)
            trimmed_mean  coordinatewise rank screen, drop f=1 high/low
            median        coordinatewise median (maximal screen)
            clipped       neighbor innovations norm-clipped against the
                          receiver's own model, self-annealing threshold

and prints the honest servers' max error to w* and mutual disagreement.
The punchline mirrors tests/test_robust.py: the outlier attacks send
plain gossip to err ~2 while every robust screen stays at the no-attack
floor; the inlier attack cannot explode anyone (it is bounded by the
honest envelope) — it only biases.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (ByzantineSchedule, FLTopology, init_dfl_state,
                        make_engine)
from repro.data import RegressionSpec, make_regression_task
from repro.optim import sgd

M, N, T_C, T_S, EPOCHS = 8, 3, 15, 8, 40


def main() -> None:
    topo = FLTopology(num_servers=M, clients_per_server=N, t_client=T_C,
                      t_server=T_S, graph_kind="complete")
    task = make_regression_task(topo, RegressionSpec(heterogeneity=0.0),
                                seed=0)
    loss_fn, batch_fn, w_star = (task["loss_fn"], task["batch_fn"],
                                 task["w_star"])
    gamma = 1.5 / (9.0 * T_C)

    attacks = {
        "none": None,
        "sign_flip": "sign_flip:0.125",
        "scaled_noise": "scaled_noise:0.125:10.0",
        "inlier_shift": "inlier_shift:0.125:1.0",
    }
    defenses = ("gossip", "trimmed_mean:1", "median", "clipped")

    print(f"{'attack':<14}{'defense':<16}{'honest_err':>11}"
          f"{'honest_dis':>12}")
    for aname, spec in attacks.items():
        byz = ByzantineSchedule.parse(spec, seed=3) if spec else None
        honest = np.ones(M, bool)
        if byz is not None:
            honest = byz.codes(0, tuple(range(M)), M) == 0
        for mode in defenses:
            engine = make_engine(topo, loss_fn, sgd(gamma),
                                 consensus_mode=mode, byzantine=byz)
            state = init_dfl_state(engine.cfg, jnp.zeros((2,)), sgd(gamma),
                                   jax.random.key(0))
            state, _ = engine.run(state, EPOCHS, batch_fn)
            servers = np.asarray(state.client_params[:, 0])[honest]
            err = float(np.linalg.norm(servers - w_star, axis=-1).max())
            dis = float(np.linalg.norm(servers - servers.mean(0),
                                       axis=-1).max())
            print(f"{aname:<14}{mode:<16}{err:>11.4f}{dis:>12.2e}")


if __name__ == "__main__":
    main()
