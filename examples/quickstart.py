"""Quickstart: the DFL algorithm on the paper's own problem in ~40 lines.

    PYTHONPATH=src python examples/quickstart.py

Builds the Sec.-IV setup (5 servers x 5 clients, linear regression with
w* = (5, 2)), runs the DFL epoch loop, and prints how each server's model
converges to w* while the servers agree with each other.
"""
import jax
import jax.numpy as jnp

from repro.core import DFLConfig, FLTopology, build_dfl_epoch_step, init_dfl_state
from repro.data import RegressionSpec, make_regression_data
from repro.optim import sgd


def main():
    topo = FLTopology(num_servers=5, clients_per_server=5,
                      t_client=50, t_server=25, graph_kind="ring")
    data = make_regression_data(topo, RegressionSpec(w_star=(5.0, 2.0)))
    x, y = jnp.asarray(data["x"]), jnp.asarray(data["y"])

    def loss_fn(w, batch, rng):
        xx, yy = batch
        return 0.5 * jnp.mean((xx @ w - yy) ** 2), {}

    gamma = 0.4 / (9.0 * topo.t_client)          # < 1/(L T_C)  (Thm. 1)
    optimizer = sgd(gamma)
    cfg = DFLConfig(topology=topo, consensus_mode="gossip")
    step = jax.jit(build_dfl_epoch_step(cfg, loss_fn, optimizer),
                   donate_argnums=(0,))
    state = init_dfl_state(cfg, jnp.zeros((2,)), optimizer, jax.random.key(0))

    batches = (jnp.broadcast_to(x, (topo.t_client,) + x.shape),
               jnp.broadcast_to(y, (topo.t_client,) + y.shape))
    print(f"sigma_A = {topo.sigma():.4f}   gamma = {gamma:.2e}")
    for epoch in range(101):
        state, metrics = step(state, batches)
        if epoch % 20 == 0:
            servers = state.client_params[:, 0]          # (M, 2)
            err = jnp.linalg.norm(servers - jnp.array([5.0, 2.0]), axis=-1)
            print(f"epoch {epoch:3d}  loss={float(metrics.loss[-1].mean()):.4f}  "
                  f"max|w_i - w*|={float(err.max()):.4f}  "
                  f"disagreement={float(metrics.server_disagreement):.2e}")
    print("final server models:")
    for i, w in enumerate(state.client_params[:, 0]):
        print(f"  server {i}: w = ({float(w[0]):.4f}, {float(w[1]):.4f})")


if __name__ == "__main__":
    main()
