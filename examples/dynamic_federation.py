"""Dynamic federation on the paper's Sec.-IV regression task.

    PYTHONPATH=src python examples/dynamic_federation.py

Algorithm 1 assumes every one of the M*N clients trains every epoch over a
fixed connected server graph.  This example runs the SAME compiled epoch
step through four scenarios the static paper setting cannot express:

  full          the paper baseline (all clients, static ring)
  sampled       Bernoulli(0.5) client participation per epoch
  faulty_links  every ring link fails with p=0.3 each epoch (repaired back
                to connectivity), so gossip runs over a different degraded
                graph A_p every epoch
  churn         server 2 dies at epoch 10 and rejoins at epoch 25 with the
                survivors' mean model (host-side graph surgery)

and prints each scenario's convergence trace: max server error to w*,
server disagreement (Lemma 1 LHS), participation rate, and the host-side
product contraction sigma_prod = ||prod_p A_p^{T_S} - 11'/M||_2.

Each scenario runs with the repro.obs stack attached (JSONL telemetry +
span tracer + convergence watchdogs — see docs/observability.md), so the
run leaves /tmp/dynfed_<scenario>.jsonl and a Perfetto-loadable
/tmp/dynfed_<scenario>_trace.json behind, and the summary reports any
watchdog that fired.  Observability is bitwise inert: the numbers below
are identical with or without it.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (FLTopology, FaultEvent, FaultSchedule,
                        ParticipationSchedule, TopologySchedule,
                        init_dfl_state, make_engine)
from repro.data import RegressionSpec, make_regression_task
from repro.obs import (JSONLSink, MemorySink, MetricsHub, Observability,
                       Tracer)
from repro.optim import sgd

M, N, T_C, T_S, EPOCHS = 5, 5, 25, 10, 40


def main() -> None:
    topo = FLTopology(num_servers=M, clients_per_server=N, t_client=T_C,
                      t_server=T_S, graph_kind="ring")
    task = make_regression_task(topo, RegressionSpec(heterogeneity=0.5),
                                seed=0)
    loss_fn, batch_fn, w_star = (task["loss_fn"], task["batch_fn"],
                                 task["w_star"])

    gamma = 0.4 / (9.0 * T_C)
    scenarios = {
        "full": {},
        "sampled": {"participation": ParticipationSchedule(
            kind="bernoulli", rate=0.5, seed=7)},
        "faulty_links": {"topology_schedule": TopologySchedule(
            kind="edge_drop", drop_prob=0.3, seed=11)},
        "churn": {"faults": FaultSchedule((
            FaultEvent(10, "drop", 2), FaultEvent(25, "rejoin", 2)))},
    }

    print(f"{'scenario':<14}{'err_to_w*':>10}{'disagree':>11}"
          f"{'part':>7}{'sigma_prod':>12}{'M_end':>7}  watchdogs")
    for name, kw in scenarios.items():
        tracer = Tracer()
        obs = Observability(
            hub=MetricsHub([MemorySink(),
                            JSONLSink(f"/tmp/dynfed_{name}.jsonl",
                                      run_info={"scenario": name})]),
            tracer=tracer, monitor=True)
        engine = make_engine(topo, loss_fn, sgd(gamma), obs=obs, **kw)
        state = init_dfl_state(engine.cfg, jnp.zeros((2,)), sgd(gamma),
                               jax.random.key(0))
        state, hist = engine.run(state, EPOCHS, batch_fn)
        obs.close()
        tracer.save_chrome(f"/tmp/dynfed_{name}_trace.json")
        servers = np.asarray(state.client_params[:, 0])
        err = float(np.linalg.norm(servers - w_star, axis=-1).max())
        fired = ",".join(ev.rule for ev in obs.monitor.events) or "-"
        print(f"{name:<14}{err:>10.4f}{hist['disagreement'][-1]:>11.2e}"
              f"{np.mean(hist['participation']):>7.2f}"
              f"{hist['sigma_prod'][-1]:>12.2e}"
              f"{int(hist['num_servers'][-1]):>7}  {fired}")


if __name__ == "__main__":
    main()
