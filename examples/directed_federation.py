"""Directed federation: why push-sum, on the paper's Sec.-IV regression task.

    PYTHONPATH=src python examples/directed_federation.py

The paper's Eq. 6 assumes a symmetric doubly-stochastic mixing matrix over
an undirected server graph.  When individual link DIRECTIONS fail (radio
interference, one-sided congestion), the graph becomes directed and no
doubly-stochastic matrix may exist on its support: the best a server can do
locally is split its mass over its out-neighbours — a row-stochastic A
(``repro.core.topology.out_degree_weights``).  This example runs four
consensus regimes through the SAME engine:

  symmetric       the paper baseline: undirected ring, Metropolis weights
  naive_directed  row-stochastic A applied as plain gossip W <- A W on a
                  static directed graph — converges to the BIASED
                  Perron-weighted average pi' W (watch err_to_w_pi ~ 0
                  while err_to_w* stays large)
  push_sum        ratio consensus on the same directed graph: numerator and
                  per-server weight both mixed by A', read out as num/w —
                  unbiased (err_to_w* small again)
  push_sum_asym   push-sum under per-epoch ASYMMETRIC degradation: every
                  direction of every ring link fails with p=0.4 each epoch

Per-server concept shift (``RegressionSpec.concept_shift``) makes the
per-server optima genuinely different, so the Perron bias is visible as a
persistent offset from the global least-squares w*.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (FLTopology, TopologySchedule, init_dfl_state,
                        make_engine, perron_weights)
from repro.data import RegressionSpec, make_regression_task, perron_ideal
from repro.optim import sgd

M, N, T_C, T_S, EPOCHS = 5, 5, 25, 30, 80
SPEC = RegressionSpec(concept_shift=2.0)


def main() -> None:
    ring = FLTopology(num_servers=M, clients_per_server=N, t_client=T_C,
                      t_server=T_S, graph_kind="ring")
    directed = FLTopology(num_servers=M, clients_per_server=N, t_client=T_C,
                          t_server=T_S, graph_kind="random_orientation",
                          mixing="out_degree")
    task = make_regression_task(directed, SPEC, seed=0)
    w_star = task["w_star"]
    w_pi = perron_ideal(task["x"], task["y"],
                        perron_weights(directed.mixing_matrix()))
    print(f"directed graph Perron weights: "
          f"{np.round(perron_weights(directed.mixing_matrix()), 3)}")
    print(f"|w_pi - w*| = {np.linalg.norm(w_pi - w_star):.4f}  "
          f"(the bias naive row-stochastic gossip converges to)\n")

    scenarios = {
        "symmetric": dict(topo=ring, mixing="symmetric", tsched=None),
        "naive_directed": dict(topo=directed, mixing="row_stochastic",
                               tsched=None),
        "push_sum": dict(topo=directed, mixing="push_sum", tsched=None),
        "push_sum_asym": dict(topo=ring, mixing="push_sum",
                              tsched=TopologySchedule(kind="asymmetric",
                                                      drop_prob=0.4,
                                                      seed=11)),
    }

    gamma = 0.4 / (9.0 * T_C)
    print(f"{'scenario':<16}{'err_to_w*':>10}{'err_to_w_pi':>12}"
          f"{'disagree':>11}{'min_w':>8}")
    for name, sc in scenarios.items():
        kw = {"mixing": sc["mixing"]}
        if sc["tsched"] is not None:
            kw["topology_schedule"] = sc["tsched"]
        engine = make_engine(sc["topo"], task["loss_fn"], sgd(gamma), **kw)
        state = init_dfl_state(engine.cfg, jnp.zeros((2,)), sgd(gamma),
                               jax.random.key(0))
        state, hist = engine.run(state, EPOCHS, task["batch_fn"])
        servers = np.asarray(state.client_params[:, 0])
        err = float(np.linalg.norm(servers - w_star, axis=-1).max())
        err_pi = float(np.linalg.norm(servers - w_pi, axis=-1).max())
        min_w = hist.get("psum_min_weight", [float("nan")])[-1]
        print(f"{name:<16}{err:>10.4f}{err_pi:>12.4f}"
              f"{hist['disagreement'][-1]:>11.2e}{min_w:>8.3f}")


if __name__ == "__main__":
    main()
