"""Reproduce Sec. IV / Fig. 3 of the paper exactly.

    PYTHONPATH=src python examples/paper_repro.py

5 servers x 5 clients, D = 100 points per client, w* = (5, 2),
T_C = 250 client iterations, T_S = 25 server iterations per epoch.
Fig. 3(b)'s claim: all servers reach consensus after ~160 epochs (~4000
server iterations) and the common value approaches w*.

Writes experiments/paper_repro.csv with per-epoch server trajectories
(the data behind both panels of Fig. 3).
"""
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import DFLConfig, FLTopology, build_dfl_epoch_step, init_dfl_state
from repro.data import RegressionSpec, make_regression_data
from repro.optim import sgd

OUT = os.path.join(os.path.dirname(__file__), "..", "experiments")


def main():
    topo = FLTopology(num_servers=5, clients_per_server=5,
                      t_client=250, t_server=25, graph_kind="ring")
    spec = RegressionSpec(w_star=(5.0, 2.0), points_per_client=100)
    data = make_regression_data(topo, spec, seed=0)
    x, y = jnp.asarray(data["x"]), jnp.asarray(data["y"])

    def loss_fn(w, batch, rng):
        xx, yy = batch
        return 0.5 * jnp.mean((xx @ w - yy) ** 2), {}

    # L for this data (max client Hessian eigenvalue) ~ 9; the paper's rule
    # gamma < min{1/(L T_C), 1/(mu T_C)}
    lsmooth = 9.0
    gamma = 0.5 / (lsmooth * topo.t_client)
    optimizer = sgd(gamma)
    cfg = DFLConfig(topology=topo, consensus_mode="gossip")
    step = jax.jit(build_dfl_epoch_step(cfg, loss_fn, optimizer),
                   donate_argnums=(0,))
    state = init_dfl_state(cfg, jnp.zeros((2,)), optimizer, jax.random.key(0))
    batches = (jnp.broadcast_to(x, (topo.t_client,) + x.shape),
               jnp.broadcast_to(y, (topo.t_client,) + y.shape))

    w_star = np.linalg.lstsq(np.asarray(x).reshape(-1, 2),
                             np.asarray(y).reshape(-1), rcond=None)[0]
    print(f"least-squares w* over all 2500 points: {w_star}")
    print(f"sigma_A = {topo.sigma():.6f}  gamma = {gamma:.3e}  "
          f"epsilon(Thm 1) = {topo.epsilon_bound(gamma, 1.0, lsmooth, 60.0):.4f}")

    rows = []
    consensus_epoch = None
    for epoch in range(200):
        state, metrics = step(state, batches)
        servers = np.asarray(state.client_params[:, 0])      # (M, 2)
        dis = float(metrics.server_disagreement)
        err = float(np.linalg.norm(servers - w_star, axis=-1).max())
        rows.append([epoch, dis, err] + servers.reshape(-1).tolist())
        # Fig. 3(b)'s event: servers agree on a COMMON value that is CLOSE
        # to w* (identical-init disagreement is trivially 0 at epoch 0, so
        # consensus alone is not the signal)
        if consensus_epoch is None and dis < 1e-3 and err < 0.05:
            consensus_epoch = epoch
        if epoch % 25 == 0:
            print(f"epoch {epoch:3d} ({(epoch + 1) * topo.t_server:5d} server "
                  f"iters)  disagreement={dis:.3e}  max|w_i - w*|={err:.4f}")

    os.makedirs(OUT, exist_ok=True)
    header = "epoch,disagreement,max_err," + ",".join(
        f"s{i}_{c}" for i in range(5) for c in ("slope", "intercept"))
    np.savetxt(os.path.join(OUT, "paper_repro.csv"),
               np.asarray(rows), delimiter=",", header=header, comments="")
    print(f"\nconsensus (<1e-3) reached at epoch {consensus_epoch} "
          f"(~{(consensus_epoch + 1) * topo.t_server} server iterations; "
          f"paper: ~160 epochs / ~4000)")
    print("final servers:", np.round(np.asarray(state.client_params[:, 0]), 4))


if __name__ == "__main__":
    main()
