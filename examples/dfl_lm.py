"""End-to-end driver: DFL-train a ~100M-parameter LM for a few hundred steps.

    PYTHONPATH=src python examples/dfl_lm.py                 # full run
    PYTHONPATH=src python examples/dfl_lm.py --quick         # 2-min smoke

A ~100M-param llama-style model (12 layers, d_model=512) trained with the
paper's Algorithm 1 on synthetic per-client LM shards: 2 servers x 2
clients, T_C=5 local SGD steps and T_S=5 gossip rounds per epoch, ring
graph.  Total local steps = epochs * T_C (a few hundred by default).
Logs loss + the Lemma-1/Lemma-3 diagnostics, checkpoints every 10 epochs.
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import Checkpointer
from repro.configs.base import ArchConfig
from repro.core import DFLConfig, FLTopology, build_dfl_epoch_step, init_dfl_state
from repro.data import DataConfig, FLDataPipeline
from repro.models import transformer as tf
from repro.optim import sgd


def lm_100m() -> ArchConfig:
    """~100M params: 12 layers, d=512, 8 heads, vocab 32k (llama-style)."""
    return ArchConfig(
        name="dfl-lm-100m", family="dense", source="examples/dfl_lm.py",
        num_layers=12, d_model=512, num_heads=8, num_kv_heads=4,
        head_dim=64, d_ff=1536, vocab_size=32_768, tie_embeddings=True)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="tiny config for a fast smoke run")
    ap.add_argument("--epochs", type=int, default=None)
    ap.add_argument("--ckpt-dir", default="/tmp/dfl_lm_ckpt")
    args = ap.parse_args()

    cfg = lm_100m()
    epochs, seq, batch = (args.epochs or 60), 256, 4
    if args.quick:
        cfg = dataclasses.replace(cfg, num_layers=2, d_model=128,
                                  num_heads=4, num_kv_heads=2, head_dim=32,
                                  d_ff=256, vocab_size=2048)
        epochs, seq, batch = (args.epochs or 5), 64, 2

    topo = FLTopology(num_servers=2, clients_per_server=2, t_client=5,
                      t_server=5, graph_kind="ring")
    n_params = cfg.param_count()
    print(f"model: {cfg.name}  params={n_params/1e6:.1f}M  "
          f"topology: M={topo.num_servers} N={topo.clients_per_server} "
          f"T_C={topo.t_client} T_S={topo.t_server}  "
          f"total local steps = {epochs * topo.t_client}")

    opts = tf.ApplyOptions(remat=False)
    loss_fn = tf.make_loss_fn(cfg, opts, loss_chunk=128)
    optimizer = sgd(0.1)
    dfl_cfg = DFLConfig(topology=topo)
    step = jax.jit(build_dfl_epoch_step(dfl_cfg, loss_fn, optimizer),
                   donate_argnums=(0,))
    params = tf.init_params(jax.random.key(0), cfg)
    state = init_dfl_state(dfl_cfg, params, optimizer, jax.random.key(1))
    pipe = FLDataPipeline(topo, DataConfig(seq_len=seq, per_client_batch=batch,
                                           vocab_size=cfg.vocab_size), arch=cfg)
    ckpt = Checkpointer(args.ckpt_dir, keep=2)

    t0 = time.time()
    for epoch in range(epochs):
        state, metrics = step(state, pipe.epoch_batches(epoch))
        if epoch % 5 == 0 or epoch == epochs - 1:
            print(f"epoch {epoch:4d}  loss={float(metrics.loss[-1].mean()):.4f}  "
                  f"drift={float(metrics.client_drift):.3e}  "
                  f"disagreement={float(metrics.server_disagreement):.3e}  "
                  f"({time.time() - t0:6.1f}s)")
        if epoch % 10 == 9:
            ckpt.save(epoch, state.client_params, meta={"loss": float(
                metrics.loss[-1].mean())})
    print(f"done: {epochs} epochs x {topo.t_client} local steps "
          f"in {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
