"""Compressed gossip: shipping int8 (or 5% top-k) replicas between servers.

    PYTHONPATH=src python examples/compressed_federation.py

The global-training periods of Algorithm 1 are pure inter-server
communication — every consensus round moves a full model replica across
every live edge.  This example runs the paper's regression setting (widened
to 32 features so byte counts mean something) under the `repro.comm`
compression layer and prints, per configuration:

  * bytes actually shipped per epoch (`BytesTracker`, host-side ledger),
  * the compression ratio vs float32 replicas on the same links,
  * final consensus error (server disagreement) and distance to w*.

Watch two things:

  1. int8 quantization with error feedback tracks the uncompressed run at
     ~1/4 the wire bytes — the contraction of the consensus period absorbs
     the (zero-mean) quantization noise;
  2. top-k sparsification of the WHOLE replica is visibly lossy at period
     level (every broadcast zeroes the unshipped coordinates): error
     feedback claws back a large part of the gap — the residual re-offers
     every withheld coordinate until it ships — but the quantizers remain
     the practical choice for model-replica gossip; sparsifiers shine on
     sparse updates, not dense replicas.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import FLTopology, init_dfl_state, make_engine
from repro.data import RegressionSpec, make_regression_task
from repro.optim import sgd


def main() -> None:
    m, n, t_c, t_s, epochs, d = 5, 5, 25, 25, 120, 32
    rng = np.random.default_rng(7)
    w_true = tuple(float(v) for v in
                   np.concatenate([rng.normal(0, 2.0, d - 1), [2.0]]))
    topo = FLTopology(num_servers=m, clients_per_server=n, t_client=t_c,
                      t_server=t_s, graph_kind="ring")
    task = make_regression_task(
        topo, RegressionSpec(w_star=w_true, heterogeneity=0.3), seed=0)
    gamma = 0.4 / (9.0 * t_c)

    configs = [
        ("uncompressed", "none", False, "simulated"),
        ("int8", "int8", False, "simulated"),
        ("int8 + EF", "int8", True, "simulated"),
        ("int4 + EF", "int4", True, "simulated"),
        ("top_k 25%", "top_k:0.25", False, "simulated"),
        ("top_k 25% + EF", "top_k:0.25", True, "simulated"),
        # wire="physical": the codes ARE the collective operands — the
        # period re-quantizes at every hop instead of once (see
        # docs/dynamic_federation.md §simulated vs physical wire), and the
        # ledger below counts bytes the collectives would actually move
        ("int8+EF physical", "int8", True, "physical"),
        ("int4+EF physical", "int4", True, "physical"),
    ]
    print(f"{'config':>17s} {'wire MB':>9s} {'ratio':>6s} "
          f"{'disagreement':>13s} {'err to w*':>10s}")
    for label, spec, use_ef, wire in configs:
        engine = make_engine(topo, task["loss_fn"], sgd(gamma),
                             compression=spec, error_feedback=use_ef,
                             wire=wire)
        state = init_dfl_state(engine.cfg, jnp.zeros((d,)), sgd(gamma),
                               jax.random.key(0))
        state, hist = engine.run(state, epochs, task["batch_fn"])
        servers = np.asarray(state.client_params[:, 0])
        err = float(np.linalg.norm(servers - task["w_star"], axis=-1).max())
        mb = sum(hist.get("wire_mb", [0.0]))
        ratio = hist.get("wire_ratio", [1.0])[-1]
        print(f"{label:>17s} {mb:9.3f} {ratio:6.2f} "
              f"{hist['disagreement'][-1]:13.3e} {err:10.4f}")


if __name__ == "__main__":
    main()
