"""Serve a DFL-trained model: batched prefill + decode with the KV cache.

    PYTHONPATH=src python examples/serve_demo.py --arch qwen3-1.7b

Instantiates the smoke variant of an assigned architecture, runs a batch of
requests through prefill, then generates tokens synchronously — the same
two programs (prefill / serve_step) the dry-run lowers at 32k/500k on the
production mesh.
"""
import argparse

from repro.launch.serve import serve


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.7)
    args = ap.parse_args()

    res = serve(args.arch, batch=args.batch, prompt_len=args.prompt_len,
                gen=args.gen, temperature=args.temperature)
    print(f"arch={args.arch} (smoke variant)  batch={args.batch}  "
          f"prompt={args.prompt_len}  gen={args.gen}")
    print(f"prefill: {res['prefill_s']:.2f}s   decode: {res['decode_s']:.2f}s "
          f"({res['tok_per_s']:.1f} tok/s aggregate)")
    for i, row in enumerate(res["generated"][:4]):
        print(f"request {i}: prompt[:8]={res['prompt'][i][:8].tolist()} "
              f"-> generated={row.tolist()}")


if __name__ == "__main__":
    main()
