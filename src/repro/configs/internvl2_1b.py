"""InternVL2-1B [arXiv:2404.16821] — Qwen2-0.5B language backbone; the
InternViT vision tower + MLP projector is the assignment's stub carve-out:
``input_specs`` feeds 256 precomputed patch embeddings at d_model."""
import dataclasses

from repro.configs.base import ArchConfig, FrontendStub

CONFIG = ArchConfig(
    name="internvl2-1b",
    family="vlm",
    source="arXiv:2404.16821",
    num_layers=24,
    d_model=896,
    num_heads=14,
    num_kv_heads=2,
    head_dim=64,
    d_ff=4864,
    vocab_size=151655,
    use_bias=True,                # qwen2 family uses qkv biases
    rope_theta=1_000_000.0,
    tie_embeddings=True,
    frontend=FrontendStub(kind="vision_patches", num_tokens=256,
                          embed_dim=896),
    supports_long_context=False,
    long_context_skip_reason="pure full-attention backbone, uncompressed KV",
)


def smoke_config() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, name="internvl2-smoke", num_layers=2, d_model=128,
        num_heads=4, num_kv_heads=2, head_dim=32, d_ff=256, vocab_size=512,
        frontend=FrontendStub(kind="vision_patches", num_tokens=16,
                              embed_dim=128))
