"""Qwen3-1.7B [hf:Qwen/Qwen3-8B family] — GQA with per-head q/k RMSNorm."""
import dataclasses

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-1.7b",
    family="dense",
    source="hf:Qwen/Qwen3-8B",
    num_layers=28,
    d_model=2048,
    num_heads=16,
    num_kv_heads=8,
    head_dim=128,
    d_ff=6144,
    vocab_size=151936,
    qk_norm=True,
    rope_theta=1_000_000.0,
    tie_embeddings=True,
    supports_long_context=False,
    long_context_skip_reason="pure full-attention, uncompressed KV",
)


def smoke_config() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, name="qwen3-smoke", num_layers=2, d_model=128,
        num_heads=4, num_kv_heads=2, head_dim=32, d_ff=256, vocab_size=512)
