"""Architecture / run configuration dataclasses.

Every assigned architecture gets a module ``src/repro/configs/<id>.py``
exposing ``CONFIG: ArchConfig`` (the full published size, exercised only via
the dry-run) and ``smoke_config()`` (a reduced member of the same family for
CPU smoke tests: <=2 layers, d_model<=512, <=4 experts).
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Optional, Sequence

# ---------------------------------------------------------------------------
# sub-configs
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts FFN."""

    num_experts: int
    top_k: int
    d_ff_expert: int
    num_shared_experts: int = 0
    d_ff_shared: int = 0            # per shared expert
    router_aux_weight: float = 0.01  # load-balance loss weight (kept client-local)
    # which decoder layers are MoE: "all" | "every_2" | "all_but_first"
    layer_pattern: str = "all"


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-V2 Multi-head Latent Attention."""

    kv_lora_rank: int = 512
    q_lora_rank: int = 1536
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class MambaConfig:
    """Mamba2 / SSD block."""

    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    chunk_size: int = 256

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def num_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclasses.dataclass(frozen=True)
class EncDecConfig:
    """Encoder-decoder extras (seamless-m4t)."""

    num_encoder_layers: int = 24
    # ratio of encoder input length to the nominal shape seq_len
    encoder_len_ratio: float = 1.0


@dataclasses.dataclass(frozen=True)
class FrontendStub:
    """Modality frontend carve-out: precomputed embeddings of this shape are
    fed by ``input_specs`` instead of raw pixels / waveforms."""

    kind: str                 # "vision_patches" | "audio_frames"
    num_tokens: int           # patches or frames prepended / encoded
    embed_dim: int            # must equal d_model after the (stubbed) projector


# ---------------------------------------------------------------------------
# main architecture config
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | audio | vlm
    source: str                      # citation from the assignment table
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None   # default d_model // num_heads
    # attention flavour ------------------------------------------------------
    qk_norm: bool = False
    attn_logit_softcap: Optional[float] = None
    final_logit_softcap: Optional[float] = None
    sliding_window: Optional[int] = None
    # per-layer attention pattern, cycled over layers. entries:
    #   "global" (full causal), "local" (sliding window), "mamba"
    layer_pattern: Sequence[str] = ("global",)
    rope_theta: float = 10_000.0
    use_bias: bool = False
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    act: str = "silu"                # silu | gelu
    # optional sub-systems ---------------------------------------------------
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    mamba: Optional[MambaConfig] = None
    encdec: Optional[EncDecConfig] = None
    frontend: Optional[FrontendStub] = None
    # which input shapes this arch supports for decode at 500k context
    supports_long_context: bool = False
    long_context_skip_reason: str = ""

    # -- derived -------------------------------------------------------------
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.num_heads

    @property
    def padded_vocab_size(self) -> int:
        """Embedding/unembedding tables round the vocab up to a multiple of
        128 so the vocab dim shards cleanly over a 16-wide TP axis (seamless
        256206 -> 256256, mamba2 50280 -> 50304, internvl 151655 -> 151680).
        Logits for the padding ids are masked to -inf in the head; token ids
        never reach them."""
        return ((self.vocab_size + 127) // 128) * 128

    def pattern_for_layer(self, idx: int) -> str:
        return self.layer_pattern[idx % len(self.layer_pattern)]

    def is_moe_layer(self, idx: int) -> bool:
        if self.moe is None:
            return False
        p = self.moe.layer_pattern
        if p == "all":
            return True
        if p == "every_2":
            return idx % 2 == 1
        if p == "all_but_first":
            return idx > 0
        raise ValueError(p)

    def param_count(self) -> int:
        """Total parameters (embedding + blocks + head), analytic."""
        return _param_count(self)

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: only routed top-k + shared)."""
        return _param_count(self, active_only=True)


def _attn_params(cfg: ArchConfig) -> int:
    d = cfg.d_model
    hd = cfg.resolved_head_dim()
    if cfg.mla is not None:
        m = cfg.mla
        q_head = m.qk_nope_head_dim + m.qk_rope_head_dim
        p = d * m.q_lora_rank + m.q_lora_rank * cfg.num_heads * q_head          # q down/up
        p += d * (m.kv_lora_rank + m.qk_rope_head_dim)                          # kv down (+shared rope)
        p += m.kv_lora_rank * cfg.num_heads * (m.qk_nope_head_dim + m.v_head_dim)  # kv up
        p += cfg.num_heads * m.v_head_dim * d                                    # out proj
        return p
    q = d * cfg.num_heads * hd
    kv = 2 * d * cfg.num_kv_heads * hd
    o = cfg.num_heads * hd * d
    return q + kv + o


def _mlp_params(d_model: int, d_ff: int) -> int:
    return 3 * d_model * d_ff  # gated: gate, up, down


def _mamba_params(cfg: ArchConfig) -> int:
    m = cfg.mamba
    d = cfg.d_model
    di = m.d_inner(d)
    nh = m.num_heads(d)
    # standard mamba2 in_proj size: d -> (2*di + 2*n_groups*d_state + nh)
    n_groups = 1
    p = d * (2 * di + 2 * n_groups * m.d_state + nh)
    p += m.d_conv * (di + 2 * n_groups * m.d_state)  # conv1d over x,B,C
    p += nh * 2                                       # A_log, D
    p += di                                           # norm
    p += di * d                                       # out_proj
    return p


def _block_params(cfg: ArchConfig, idx: int, active_only: bool) -> int:
    d = cfg.d_model
    pat = cfg.pattern_for_layer(idx)
    p = 2 * d  # two rmsnorms
    if pat == "mamba":
        p += _mamba_params(cfg)
    else:
        p += _attn_params(cfg)
    if cfg.is_moe_layer(idx):
        moe = cfg.moe
        n_live = (moe.top_k if active_only else moe.num_experts)
        p += n_live * _mlp_params(d, moe.d_ff_expert)
        p += moe.num_shared_experts * _mlp_params(d, moe.d_ff_shared or moe.d_ff_expert)
        p += d * moe.num_experts  # router
    elif pat != "mamba" or cfg.d_ff > 0:
        if cfg.d_ff > 0:
            p += _mlp_params(d, cfg.d_ff)
    return p


def _param_count(cfg: ArchConfig, active_only: bool = False) -> int:
    d = cfg.d_model
    p = cfg.vocab_size * d  # embedding
    if not cfg.tie_embeddings:
        p += cfg.vocab_size * d
    p += d  # final norm
    for i in range(cfg.num_layers):
        p += _block_params(cfg, i, active_only)
    if cfg.encdec is not None:
        # encoder blocks (full attention, no moe) + cross-attn in decoder
        for _ in range(cfg.encdec.num_encoder_layers):
            p += 2 * d + _attn_params(cfg) + _mlp_params(d, cfg.d_ff)
        p += cfg.num_layers * (d + _attn_params(cfg))  # cross-attn + its norm
    return p


# ---------------------------------------------------------------------------
# input shapes (assigned)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}

ARCH_IDS = (
    "mixtral_8x22b",
    "gemma2_27b",
    "seamless_m4t_large_v2",
    "internvl2_1b",
    "jamba_1_5_large_398b",
    "command_r_35b",
    "smollm_360m",
    "qwen3_1_7b",
    "mamba2_780m",
    "deepseek_v2_236b",
)


def get_arch(arch_id: str) -> ArchConfig:
    """Load ``CONFIG`` from ``repro.configs.<arch_id>`` (dashes ok)."""
    mod = importlib.import_module(
        "repro.configs." + arch_id.replace("-", "_").replace(".", "_"))
    return mod.CONFIG


def get_smoke(arch_id: str) -> ArchConfig:
    mod = importlib.import_module(
        "repro.configs." + arch_id.replace("-", "_").replace(".", "_"))
    return mod.smoke_config()
