"""Jamba-1.5-Large (398B total / 94B active) [arXiv:2403.19887] —
Mamba:attention 7:1 interleave in 8-layer blocks, MoE (16 experts top-2)
every other layer.  Attention layers use full causal attention in the
published model; Mamba layers make the arch O(1)-state for most of the
stack, so long_500k decode runs (the 9 attention layers keep a full-length
KV — 500k × 8 KV heads shards 16-way over the model axis)."""
import dataclasses

from repro.configs.base import ArchConfig, MambaConfig, MoEConfig

CONFIG = ArchConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    source="arXiv:2403.19887",
    num_layers=72,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab_size=65536,
    # 8-layer period: attention at index 4, mamba elsewhere (1:7)
    layer_pattern=("mamba", "mamba", "mamba", "mamba",
                   "global", "mamba", "mamba", "mamba"),
    moe=MoEConfig(num_experts=16, top_k=2, d_ff_expert=24576,
                  layer_pattern="every_2"),
    mamba=MambaConfig(d_state=128, d_conv=4, expand=2, head_dim=64,
                      chunk_size=256),
    supports_long_context=True,
)


def smoke_config() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, name="jamba-smoke", num_layers=2, d_model=128,
        num_heads=4, num_kv_heads=2, head_dim=32, d_ff=256, vocab_size=512,
        # keep the family (mamba + attention + MoE) at smoke scale with a
        # 2-layer period instead of the full 8-layer block
        layer_pattern=("mamba", "global"),
        moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=256,
                      layer_pattern="every_2"),
        mamba=MambaConfig(d_state=16, d_conv=4, expand=2, head_dim=32,
                          chunk_size=8))
