"""Assigned architecture configs (one module per arch) + shape registry."""
from repro.configs.base import (ARCH_IDS, INPUT_SHAPES, ArchConfig,
                                EncDecConfig, FrontendStub, InputShape,
                                MLAConfig, MambaConfig, MoEConfig, get_arch,
                                get_smoke)

__all__ = ["ARCH_IDS", "INPUT_SHAPES", "ArchConfig", "EncDecConfig",
           "FrontendStub", "InputShape", "MLAConfig", "MambaConfig",
           "MoEConfig", "get_arch", "get_smoke"]
