"""DeepSeek-V2 236B (21B active) [arXiv:2405.04434] — MLA (kv_lora 512) +
160 routed experts top-6 + 2 shared experts; dense first layer (d_ff 12288)."""
import dataclasses

from repro.configs.base import ArchConfig, MLAConfig, MoEConfig

CONFIG = ArchConfig(
    name="deepseek-v2-236b",
    family="moe",
    source="arXiv:2405.04434",
    num_layers=60,
    d_model=5120,
    num_heads=128,
    num_kv_heads=128,             # MLA: per-head K/V expanded from the latent
    d_ff=12288,                   # dense first layer
    vocab_size=102400,
    rope_theta=10_000.0,
    mla=MLAConfig(kv_lora_rank=512, q_lora_rank=1536, qk_nope_head_dim=128,
                  qk_rope_head_dim=64, v_head_dim=128),
    moe=MoEConfig(num_experts=160, top_k=6, d_ff_expert=1536,
                  num_shared_experts=2, d_ff_shared=1536,
                  layer_pattern="all_but_first"),
    supports_long_context=False,
    long_context_skip_reason=(
        "MLA latent KV is compact (~36 GB at 500k) but has no head axis to "
        "shard; blockwise latent-sharded attention is future work "
        "(DESIGN.md §4)"),
)


def smoke_config() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, name="deepseek-smoke", num_layers=3, d_model=128,
        num_heads=4, num_kv_heads=4, d_ff=256, vocab_size=512,
        mla=MLAConfig(kv_lora_rank=32, q_lora_rank=48, qk_nope_head_dim=16,
                      qk_rope_head_dim=8, v_head_dim=16),
        moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=64,
                      num_shared_experts=2, d_ff_shared=64,
                      layer_pattern="all_but_first"))
