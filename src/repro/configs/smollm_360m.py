"""SmolLM-360M [hf:HuggingFaceTB/SmolLM-135M family] — llama-style small."""
import dataclasses

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="smollm-360m",
    family="dense",
    source="hf:HuggingFaceTB/SmolLM-135M",
    num_layers=32,
    d_model=960,
    num_heads=15,
    num_kv_heads=5,
    head_dim=64,
    d_ff=2560,
    vocab_size=49152,
    tie_embeddings=True,
    supports_long_context=False,
    long_context_skip_reason="pure full-attention, uncompressed KV",
)


def smoke_config() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, name="smollm-smoke", num_layers=2, d_model=120,
        num_heads=3, num_kv_heads=1, head_dim=40, d_ff=256, vocab_size=512)
