"""Mamba2-780M [arXiv:2405.21060] — attention-free SSD (state-space duality).
O(1) decode state: the flagship long_500k architecture."""
import dataclasses

from repro.configs.base import ArchConfig, MambaConfig

CONFIG = ArchConfig(
    name="mamba2-780m",
    family="ssm",
    source="arXiv:2405.21060",
    num_layers=48,
    d_model=1536,
    num_heads=1,              # unused (attention-free)
    num_kv_heads=1,
    d_ff=0,                   # no separate MLP: mamba block is the mixer+ffn
    vocab_size=50280,
    layer_pattern=("mamba",),
    tie_embeddings=True,
    mamba=MambaConfig(d_state=128, d_conv=4, expand=2, head_dim=64,
                      chunk_size=256),
    supports_long_context=True,
)


def smoke_config() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, name="mamba2-smoke", num_layers=2, d_model=128,
        vocab_size=512,
        mamba=MambaConfig(d_state=16, d_conv=4, expand=2, head_dim=32,
                          chunk_size=8))
