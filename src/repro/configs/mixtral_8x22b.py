"""Mixtral-8x22B [arXiv:2401.04088] — 8-expert top-2 MoE with sliding-window
attention (window per the Mixtral family).  All layers MoE + SWA, so the KV
cache is window-bounded and long_500k decode is supported."""
import dataclasses

from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="mixtral-8x22b",
    family="moe",
    source="arXiv:2401.04088",
    num_layers=56,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=0,                       # all FFNs are expert FFNs
    vocab_size=32768,
    sliding_window=4096,
    layer_pattern=("local",),     # SWA on every layer
    rope_theta=1_000_000.0,
    moe=MoEConfig(num_experts=8, top_k=2, d_ff_expert=16384,
                  layer_pattern="all"),
    supports_long_context=True,
)


def smoke_config() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, name="mixtral-smoke", num_layers=2, d_model=128,
        num_heads=8, num_kv_heads=2, head_dim=16, vocab_size=512,
        sliding_window=32,
        moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=256,
                      layer_pattern="all"))
