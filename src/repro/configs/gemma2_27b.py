"""Gemma2-27B [arXiv:2408.00118] — alternating local/global attention,
attn+final logit softcaps, pre+post norms, tied embeddings."""
import dataclasses

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="gemma2-27b",
    family="dense",
    source="arXiv:2408.00118",
    num_layers=46,
    d_model=4608,
    num_heads=32,
    num_kv_heads=16,
    head_dim=128,
    d_ff=36864,
    vocab_size=256000,
    sliding_window=4096,
    layer_pattern=("local", "global"),
    attn_logit_softcap=50.0,
    final_logit_softcap=30.0,
    tie_embeddings=True,
    act="gelu",
    # local layers bound half the KV; global layers shard KV heads 16-way
    supports_long_context=True,
)


def smoke_config() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, name="gemma2-smoke", num_layers=2, d_model=128,
        num_heads=4, num_kv_heads=2, head_dim=32, d_ff=256, vocab_size=512,
        sliding_window=32)
