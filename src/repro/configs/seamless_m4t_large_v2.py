"""SeamlessM4T-large-v2 [arXiv:2308.11596] — encoder-decoder; the speech
frontend (mel + conformer feature extractor) is the assignment's stub
carve-out: ``input_specs`` feeds precomputed frame embeddings (B, T, d)."""
import dataclasses

from repro.configs.base import ArchConfig, EncDecConfig, FrontendStub

CONFIG = ArchConfig(
    name="seamless-m4t-large-v2",
    family="audio",
    source="arXiv:2308.11596",
    num_layers=24,                 # decoder layers
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    d_ff=8192,
    vocab_size=256206,
    use_bias=True,
    encdec=EncDecConfig(num_encoder_layers=24, encoder_len_ratio=1.0),
    frontend=FrontendStub(kind="audio_frames", num_tokens=0, embed_dim=1024),
    supports_long_context=False,
    long_context_skip_reason=(
        "enc-dec with full bidirectional encoder attention and full decoder "
        "KV; no sliding-window/compressed variant at 500k"),
)


def smoke_config() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, name="seamless-smoke", num_layers=2, d_model=128,
        num_heads=4, num_kv_heads=4, head_dim=32, d_ff=256, vocab_size=512,
        encdec=EncDecConfig(num_encoder_layers=2, encoder_len_ratio=1.0),
        frontend=FrontendStub(kind="audio_frames", num_tokens=0, embed_dim=128))
