"""Command-R 35B [hf:CohereForAI/c4ai-command-r-v01] — dense GQA, no biases."""
import dataclasses

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="command-r-35b",
    family="dense",
    source="hf:CohereForAI/c4ai-command-r-v01",
    num_layers=40,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=22528,
    vocab_size=256000,
    use_bias=False,
    rope_theta=8_000_000.0,
    tie_embeddings=True,
    supports_long_context=False,
    long_context_skip_reason="pure full-attention, uncompressed KV",
)


def smoke_config() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, name="command-r-smoke", num_layers=2, d_model=128,
        num_heads=8, num_kv_heads=2, head_dim=16, d_ff=256, vocab_size=512)
