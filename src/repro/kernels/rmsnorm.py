"""Fused RMSNorm Pallas-TPU kernel.

One HBM pass: load a (block_rows, d) tile, reduce mean-square along the
feature axis on the VPU, scale, write back — versus the unfused jnp path
(square -> mean -> rsqrt -> mul -> mul) which XLA usually fuses anyway; the
kernel exists because rmsnorm sits on the critical path of *every* block of
every assigned arch and pinning its tiling guarantees no accidental f32
materialisation of the squared activations at 32k sequence lengths.

Grid: (rows // block_rows,).  ``scale`` (d,) stays VMEM-resident.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, s_ref, o_ref, *, eps: float, d: int):
    x = x_ref[...].astype(jnp.float32)            # (block_rows, d)
    ms = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(ms + eps)
    o_ref[...] = (y * s_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def rmsnorm_2d(x: jax.Array, scale: jax.Array, *, eps: float = 1e-6,
               block_rows: int = 256, interpret: bool = True) -> jax.Array:
    """x: (rows, d) — callers flatten leading axes; scale: (d,)."""
    rows, d = x.shape
    block_rows = min(block_rows, rows)
    assert rows % block_rows == 0, "pad rows to block_rows"
    grid = (rows // block_rows,)
    kernel = functools.partial(_rmsnorm_kernel, eps=eps, d=d)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, d), x.dtype),
        interpret=interpret,
    )(x, scale)
