"""Mamba2 SSD (state-space duality) chunked-scan Pallas-TPU kernel.

TPU adaptation (DESIGN.md §2/§6): the CUDA selective-scan is a warp-level
prefix scan — no TPU analogue.  The SSD decomposition instead splits the
recurrence into

    intra-chunk:  y_q  = sum_{k<=q in chunk} C_q . B_k  exp(sum a)  dt_k x_k
                  — a (chunk x chunk) masked matmul pair: pure MXU work
    inter-chunk:  h_c  = exp(total_a) h_{c-1} + (chunk state)
                  — a tiny sequential recurrence

The kernel exploits the *sequential* TPU grid: the chunk index is the
innermost grid axis, and the running state (ds x hd, f32) persists in VMEM
scratch across grid steps — the inter-chunk scan costs zero extra HBM
traffic.  One (batch, head) pair per outer grid step keeps every working
tile (q x hd inputs, q x ds B/C, q x q decay matrix, ds x hd state) inside
the ~16 MB VMEM budget for q = 128..256, hd = 64, ds = 128.

Grid: (b * nh, n_chunks); chunk innermost.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, b_ref, c_ref, dt_ref, a_ref, y_ref, state_out_ref,
                h_ref, *, chunk: int, nh: int, num_chunks: int,
                seq_len: int):
    ic = pl.program_id(1)

    @pl.when(ic == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    x = x_ref[0, 0].astype(jnp.float32)          # (q, hd)
    bb = b_ref[0, 0].astype(jnp.float32)         # (q, ds)
    cc = c_ref[0, 0].astype(jnp.float32)         # (q, ds)
    dt = dt_ref[0, 0].astype(jnp.float32)        # (q, 1)
    a_h = a_ref[0].astype(jnp.float32)           # (1,) — this head's A coeff

    # ragged tail: out-of-range steps behave as dt=0 (decay 1, no input).
    # Also zero x/B/C there — padding may be NaN and 0*NaN = NaN.
    if seq_len % chunk:
        row = ic * chunk + jax.lax.broadcasted_iota(jnp.int32, (chunk, 1), 0)
        valid = row < seq_len
        dt = jnp.where(valid, dt, 0.0)
        x = jnp.where(valid, x, 0.0)
        bb = jnp.where(valid, bb, 0.0)
        cc = jnp.where(valid, cc, 0.0)

    a_step = dt * a_h                             # (q, 1) log-decay per step
    cum = jnp.cumsum(a_step, axis=0)              # (q, 1) inclusive
    total = cum[-1:, :]                           # (1, 1)

    # ---- intra-chunk quadratic term (MXU) ----
    # L[q, k] = exp(cum_q - cum_k) for k <= q  (decay from step k+1..q)
    seg = cum - jnp.transpose(cum)                # (q, q)
    qi = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    ki = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    l_mat = jnp.where(ki <= qi, jnp.exp(seg), 0.0)
    scores = jax.lax.dot_general(cc, bb, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    scores = scores * l_mat * jnp.transpose(dt)   # weight by source dt
    y = jax.lax.dot_general(scores, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)

    # ---- inter-chunk contribution from carried state ----
    # y_inter[q] = exp(cum_q) * C_q . h_prev
    ch = jax.lax.dot_general(cc, h_ref[...], (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (q, hd)
    y = y + jnp.exp(cum) * ch

    # ---- state update:  h <- exp(total) h + sum_k exp(total-cum_k) dt_k B_k x_k ----
    w = jnp.exp(total - cum) * dt                 # (q, 1)
    upd = jax.lax.dot_general(bb * w, x, (((0,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)  # (ds, hd)
    h_ref[...] = jnp.exp(total) * h_ref[...] + upd

    y_ref[0, 0] = y.astype(y_ref.dtype)

    @pl.when(ic == num_chunks - 1)
    def _emit_state():
        state_out_ref[0, 0] = h_ref[...]


def ssd_scan_bhs(xs: jax.Array, bs: jax.Array, cs: jax.Array, dt: jax.Array,
                 a_coef: jax.Array, *, chunk: int = 128,
                 interpret: bool = True) -> Tuple[jax.Array, jax.Array]:
    """Layout (b, nh, s, hd) for x, (b, nh, s, ds) for B/C (already head-
    broadcast), (b, nh, s, 1) f32 for dt, (nh,) for a_coef.

    Returns (y (b, nh, s, hd) f32, final state (b, nh, ds, hd) f32).
    """
    b, nh, s, hd = xs.shape
    ds = bs.shape[-1]
    chunk = min(chunk, s)
    nc = pl.cdiv(s, chunk)
    grid = (b * nh, nc)

    kernel = functools.partial(_ssd_kernel, chunk=chunk, nh=nh,
                               num_chunks=nc, seq_len=s)

    y, state = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, chunk, hd),
                         lambda bh, ic: (bh // nh, bh % nh, ic, 0)),
            pl.BlockSpec((1, 1, chunk, ds),
                         lambda bh, ic: (bh // nh, bh % nh, ic, 0)),
            pl.BlockSpec((1, 1, chunk, ds),
                         lambda bh, ic: (bh // nh, bh % nh, ic, 0)),
            pl.BlockSpec((1, 1, chunk, 1),
                         lambda bh, ic: (bh // nh, bh % nh, ic, 0)),
            pl.BlockSpec((1,), lambda bh, ic: (bh % nh,)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, chunk, hd),
                         lambda bh, ic: (bh // nh, bh % nh, ic, 0)),
            pl.BlockSpec((1, 1, ds, hd),
                         lambda bh, ic: (bh // nh, bh % nh, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, nh, s, hd), jnp.float32),
            jax.ShapeDtypeStruct((b, nh, ds, hd), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((ds, hd), jnp.float32)],
        interpret=interpret,
    )(xs, bs, cs, dt, a_coef)
    return y, state
