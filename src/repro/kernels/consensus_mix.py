"""Fused consensus-mixing Pallas-TPU kernel:  W <- A_eff W  in ONE HBM pass.

This is the single-chip half of the beyond-paper "collapsed consensus"
optimization (DESIGN.md §7): the faithful DFL server loop applies A for
T_S rounds, i.e. T_S full read+write passes over every server's parameter
vector.  Since A^{T_S} is an (M x M) matrix that is trivially precomputed on
the host, one streaming pass suffices — the kernel is purely memory-bound,
so collapsing T_S passes into 1 cuts consensus HBM traffic by exactly T_S x.

Layout: the parameter pytree is flattened into a (M, D) matrix (D = total
model params).  A_eff is tiny (M<=64) and stays resident in VMEM across all
grid steps; W streams through in (M, block_d) tiles.

Grid: (D // block_d,).  VMEM per step: M*block_d*4 bytes in + out + M*M.
block_d = 2048 with M = 16 -> 256 KB per buffer: far under VMEM, deep
double-buffering.

``quantized_consensus_mix_2d`` is the compressed-gossip variant: the wire
round-trip of ``comm.compressors.StochasticQuantizer`` (per-chunk scales,
stochastic rounding, dequantize) fused INTO the same single mixing pass —
what a server computes when it applies the collapsed operator to the
int8/int4 payloads it received, without ever materialising the quantized
model in HBM.

``quantized_gossip_round_2d`` is the PHYSICAL-WIRE round kernel: one
delta-coded gossip round of ``wire="physical"`` after the all-gather,
fused gather-dequant-mix-requant — input is the gathered delta code/scale
buffers + the shared f32 reference, output the updated reference, the
mixed iterates, and the re-encoded innovation codes/scales for the NEXT
round's collective; the decoded deltas and pre-encode innovations live
only in VMEM and never materialise in HBM.  Bit-identical to the jnp wire
path (``decode_block`` → accumulate → mix → ``compress``) under shared
dither, which stays the reference oracle (``tests/test_wire.py``).

``quantized_gossip_encode_2d`` covers the ENCODE side of the wire: the
innovation ``W - R`` and its absmax-scaled stochastic rounding fused into
one pass, so the pre-encode delta never round-trips HBM — what each
server computes immediately before the collective (round 0 encodes the
full state: ``R = 0``).

``bucketed_gossip_round_2d`` is the BUCKETED-wire round kernel (PR 6):
the band-carried recursion of ``core.consensus.gossip_scan_wire_bucketed``
— each server holds only its OWN reference row and a running
mixed-reference accumulator — fused encode→gather→dequant→accumulate→
mix→requant around the round's single collective pair.  Together with
``quantized_gossip_encode_2d`` it closes the loop: codes and innovations
live only in VMEM between collectives.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _mix_kernel(a_ref, w_ref, o_ref, *, total_d: int, block_d: int):
    i = pl.program_id(0)
    a = a_ref[...].astype(jnp.float32)            # (M, M) resident
    w = w_ref[...].astype(jnp.float32)            # (M, block_d)
    if total_d % block_d:
        col = i * block_d + jax.lax.broadcasted_iota(
            jnp.int32, w.shape, 1)
        w = jnp.where(col < total_d, w, 0.0)      # NaN-safe ragged tail
    o_ref[...] = jax.lax.dot_general(
        a, w, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).astype(o_ref.dtype)


def consensus_mix_2d(a_eff: jax.Array, w: jax.Array, *, block_d: int = 2048,
                     interpret: bool = True) -> jax.Array:
    """w: (M, D); a_eff: (M, M).  Returns A_eff @ w, one HBM pass."""
    m, d = w.shape
    block_d = min(block_d, d)
    grid = (pl.cdiv(d, block_d),)
    kernel = functools.partial(_mix_kernel, total_d=d, block_d=block_d)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((m, m), lambda i: (0, 0)),         # A resident
            pl.BlockSpec((m, block_d), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((m, block_d), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((m, d), w.dtype),
        interpret=interpret,
    )(a_eff, w)


# ---------------------------------------------------------------------------
# fused quantize -> mix -> dequantize (the compressed-gossip single-chip path)
# ---------------------------------------------------------------------------


def _quant_mix_kernel(a_ref, w_ref, u_ref, o_ref, *, block_d: int,
                      chunk: int, qmax: float):
    """One (M, block_d) tile: per-(row, chunk) absmax scales, stochastic-
    rounded int codes, dequantize, then the A contraction — the wire
    round-trip of ``comm.compressors.StochasticQuantizer`` fused into the
    mixing pass so the quantized values never touch HBM."""
    a = a_ref[...].astype(jnp.float32)                 # (M, M) resident
    w = w_ref[...].astype(jnp.float32)                 # (M, block_d)
    u = u_ref[...].astype(jnp.float32)                 # dither in [0, 1)
    m = w.shape[0]
    nc = block_d // chunk
    wc = w.reshape(m, nc, chunk)
    absmax = jnp.max(jnp.abs(wc), axis=-1, keepdims=True)
    # multiply by the reciprocal CONSTANT, never divide: XLA's
    # simplifier rewrites float division by a constant to a
    # reciprocal multiply in SOME programs and not others (a 1-ulp
    # scale skew between compilations of the same formula); an
    # explicit literal leaves it nothing to rewrite, and matches
    # ``comm.compressors.StochasticQuantizer._scales`` bitwise
    scale = jnp.where(absmax > 0, absmax * (1.0 / qmax), 1.0)
    q = jnp.clip(jnp.floor(wc * (1.0 / scale) + u.reshape(m, nc, chunk)),
                 -qmax, qmax)
    deq = (q * scale).reshape(m, block_d)
    o_ref[...] = jax.lax.dot_general(
        a, deq, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).astype(o_ref.dtype)


def quantized_consensus_mix_2d(a_eff: jax.Array, w: jax.Array,
                               dither: jax.Array, *, bits: int = 8,
                               chunk: int = 256, block_d: int = 2048,
                               interpret: bool = True) -> jax.Array:
    """Fused quantize -> mix -> dequantize:  A_eff @ D(C(w))  in one pass.

    ``w``: (M, D) flattened server models; ``a_eff``: the (collapsed)
    mixing operator; ``dither``: (M, D) uniform [0, 1) stochastic-rounding
    noise, generated OUTSIDE the kernel (``jax.random.uniform``) so the
    same randomness can drive the jnp wire simulation — on a real TPU the
    in-kernel ``pltpu.prng_random_bits`` path avoids the HBM read, but the
    interpret-mode CPU backend this container runs has no TPU PRNG.

    Bit-identical to ``StochasticQuantizer(bits, chunk).roundtrip`` followed
    by ``consensus_mix_2d`` when ``chunk`` divides the chosen ``block_d``
    (chunk boundaries then align across tiles), while touching W's HBM
    bytes once instead of three times (quantize pass + mix read + write).
    """
    m, d = w.shape
    if bits not in (4, 8):
        raise ValueError(f"bits must be 4 or 8, got {bits}")
    block_d = max(chunk, min(block_d, -(-d // chunk) * chunk))
    if block_d % chunk:
        raise ValueError(f"chunk={chunk} must divide block_d={block_d}")
    # pad to the tile grid up front: trailing zeros quantize to zero codes
    # and contribute nothing to the contraction, so no in-kernel masking
    nb = pl.cdiv(d, block_d)
    pad = nb * block_d - d
    if pad:
        w = jnp.pad(w, ((0, 0), (0, pad)))
        dither = jnp.pad(dither, ((0, 0), (0, pad)))
    qmax = float(2 ** (bits - 1) - 1)
    kernel = functools.partial(_quant_mix_kernel, block_d=block_d,
                               chunk=chunk, qmax=qmax)
    out = pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((m, m), lambda i: (0, 0)),        # A resident
            pl.BlockSpec((m, block_d), lambda i: (0, i)),
            pl.BlockSpec((m, block_d), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((m, block_d), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((m, nb * block_d), w.dtype),
        interpret=interpret,
    )(a_eff, w, dither)
    return out[:, :d]


# ---------------------------------------------------------------------------
# fused gather-dequant-mix-requant: one PHYSICAL-WIRE gossip round
# ---------------------------------------------------------------------------


def _wire_round_kernel(a_ref, q_ref, s_ref, r_ref, u_ref, w_ref, or_ref,
                       oq_ref, os_ref, *, block_d: int, chunk: int,
                       qmax: float):
    """One (M, block_d) tile of a delta-coded physical-wire gossip round:
    dequantize the GATHERED delta codes, accumulate them into the shared
    reference tile, mix the references, and re-encode the NEXT innovations
    (mixed - new reference) with fresh absmax scales + dither — the
    decoded-delta and mixed f32 tiles exist only in VMEM."""
    a = a_ref[...].astype(jnp.float32)                 # (M, M) resident
    q = q_ref[...].astype(jnp.float32)                 # (M, block_d) codes
    s = s_ref[...]                                     # (M, nc) scales
    ref = r_ref[...]                                   # (M, block_d) f32
    u = u_ref[...].astype(jnp.float32)                 # dither in [0, 1)
    m = q.shape[0]
    nc = block_d // chunk
    ref = ref + (q.reshape(m, nc, chunk) * s[..., None]).reshape(m, block_d)
    # unrolled left-to-right mul-adds, NOT an MXU dot: the wire paths
    # (consensus._wire_mix_rows / the shard_map round body) accumulate in
    # exactly this order, and matching it is what makes the kernel
    # bit-identical to them rather than ulp-close (M is tiny and the
    # kernel memory-bound, so the MXU buys nothing here)
    mixed = a[:, 0:1] * ref[0]
    for j in range(1, m):
        mixed = mixed + a[:, j:j + 1] * ref[j]
    wc = (mixed - ref).reshape(m, nc, chunk)           # next innovations
    absmax = jnp.max(jnp.abs(wc), axis=-1, keepdims=True)
    # multiply by the reciprocal CONSTANT, never divide: XLA's
    # simplifier rewrites float division by a constant to a
    # reciprocal multiply in SOME programs and not others (a 1-ulp
    # scale skew between compilations of the same formula); an
    # explicit literal leaves it nothing to rewrite, and matches
    # ``comm.compressors.StochasticQuantizer._scales`` bitwise
    scale = jnp.where(absmax > 0, absmax * (1.0 / qmax), 1.0)
    q2 = jnp.clip(jnp.floor(wc * (1.0 / scale) + u.reshape(m, nc, chunk)),
                  -qmax, qmax)
    w_ref[...] = mixed
    or_ref[...] = ref
    oq_ref[...] = q2.reshape(m, block_d).astype(jnp.int8)
    os_ref[...] = scale[..., 0]


def quantized_gossip_round_2d(a: jax.Array, codes: jax.Array,
                              scales: jax.Array, ref: jax.Array,
                              dither: jax.Array, *, bits: int = 8,
                              chunk: int = 256, block_d: int = 2048,
                              interpret: bool = True):
    """Fused gather-dequant-mix-requant: one delta-coded ``wire="physical"``
    gossip round after the all-gather, in one HBM pass — the single-chip
    half of ``core.consensus.make_gossip_shard_map``'s codec mode.

    Implements the innovation recursion of
    ``core.consensus.gossip_scan_wire``:

        R'      = R + D(codes, scales)        (accumulate gathered deltas)
        W'      = A · R'                      (mix the references)
        delta'  = W' - R'                     (next innovations)
        codes', scales' = C(delta'; dither)   (next round's wire)

    ``codes``: (M, D) int8 delta codes as delivered by the all-gather
    (int4 codes UNPACKED into int8 — ``comm.compressors.pack_int4`` is a
    free view change at the collective boundary); ``scales``: (M, D/chunk)
    per-chunk f32 scales; ``ref``: the (M, D) f32 shared reference state;
    ``dither``: (M, D) uniform [0, 1) rounding noise for the re-encode,
    generated outside for the same reason as ``quantized_consensus_mix_2d``.
    Returns ``(mixed, ref', codes', scales')``.  The decoded deltas and
    the pre-encode innovations never touch HBM: unfused, each round writes
    + re-reads two (M, D) f32 intermediates — 4 extra HBM passes this
    kernel keeps in VMEM (the reference itself is genuine algorithm state
    and lives in HBM either way).

    Bit-identical to the jnp oracle (``decode_block`` -> accumulate ->
    ``consensus._wire_mix_rows`` -> ``compress(dither=u)``) when ``chunk``
    divides ``block_d`` and ``D`` (chunk boundaries then align across
    tiles) and both run under jit — the wire paths always do; an EAGER
    oracle differs by one FMA-contraction ulp in the re-encode scales.
    Asserted in ``tests/test_wire.py``."""
    m, d = codes.shape
    if bits not in (4, 8):
        raise ValueError(f"bits must be 4 or 8, got {bits}")
    if d % chunk:
        raise ValueError(f"chunk={chunk} must divide D={d} (pad the wire "
                         f"buffer to the block grid first, as the gossip "
                         f"paths do)")
    block_d = max(chunk, min(block_d, d))
    if block_d % chunk:
        raise ValueError(f"chunk={chunk} must divide block_d={block_d}")
    nb = pl.cdiv(d, block_d)
    pad = nb * block_d - d
    if pad:     # ragged tile grid: zero codes / unit scales are inert
        codes = jnp.pad(codes, ((0, 0), (0, pad)))
        scales = jnp.pad(scales, ((0, 0), (0, pad // chunk)),
                         constant_values=1.0)
        ref = jnp.pad(ref, ((0, 0), (0, pad)))
        dither = jnp.pad(dither, ((0, 0), (0, pad)))
    qmax = float(2 ** (bits - 1) - 1)
    nc_blk = block_d // chunk
    kernel = functools.partial(_wire_round_kernel, block_d=block_d,
                               chunk=chunk, qmax=qmax)
    out_w, out_r, out_q, out_s = pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((m, m), lambda i: (0, 0)),        # A resident
            pl.BlockSpec((m, block_d), lambda i: (0, i)),
            pl.BlockSpec((m, nc_blk), lambda i: (0, i)),
            pl.BlockSpec((m, block_d), lambda i: (0, i)),
            pl.BlockSpec((m, block_d), lambda i: (0, i)),
        ],
        out_specs=[
            pl.BlockSpec((m, block_d), lambda i: (0, i)),
            pl.BlockSpec((m, block_d), lambda i: (0, i)),
            pl.BlockSpec((m, block_d), lambda i: (0, i)),
            pl.BlockSpec((m, nc_blk), lambda i: (0, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, nb * block_d), jnp.float32),
            jax.ShapeDtypeStruct((m, nb * block_d), jnp.float32),
            jax.ShapeDtypeStruct((m, nb * block_d), jnp.int8),
            jax.ShapeDtypeStruct((m, nb * nc_blk), jnp.float32),
        ],
        interpret=interpret,
    )(a, codes, scales, ref, dither)
    return (out_w[:, :d], out_r[:, :d], out_q[:, :d],
            out_s[:, :d // chunk])


# ---------------------------------------------------------------------------
# fused innovation + encode: the send side of the physical wire
# ---------------------------------------------------------------------------


def _wire_encode_kernel(w_ref, r_ref, u_ref, oq_ref, os_ref, *,
                        block_d: int, chunk: int, qmax: float):
    """One (M, block_d) tile of the wire's SEND side: the innovation
    ``w - r`` and its per-chunk absmax-scaled stochastic rounding in one
    pass — the pre-encode delta exists only in VMEM."""
    w = w_ref[...].astype(jnp.float32)                 # (M, block_d)
    r = r_ref[...]                                     # (M, block_d) f32
    u = u_ref[...].astype(jnp.float32)                 # dither in [0, 1)
    m = w.shape[0]
    nc = block_d // chunk
    wc = (w - r).reshape(m, nc, chunk)                 # innovations
    absmax = jnp.max(jnp.abs(wc), axis=-1, keepdims=True)
    # multiply by the reciprocal CONSTANT, never divide: XLA's
    # simplifier rewrites float division by a constant to a
    # reciprocal multiply in SOME programs and not others (a 1-ulp
    # scale skew between compilations of the same formula); an
    # explicit literal leaves it nothing to rewrite, and matches
    # ``comm.compressors.StochasticQuantizer._scales`` bitwise
    scale = jnp.where(absmax > 0, absmax * (1.0 / qmax), 1.0)
    q = jnp.clip(jnp.floor(wc * (1.0 / scale) + u.reshape(m, nc, chunk)),
                 -qmax, qmax)
    oq_ref[...] = q.reshape(m, block_d).astype(jnp.int8)
    os_ref[...] = scale[..., 0]


def quantized_gossip_encode_2d(w: jax.Array, ref: jax.Array,
                               dither: jax.Array, *, bits: int = 8,
                               chunk: int = 256, block_d: int = 2048,
                               interpret: bool = True):
    """Fused innovation + encode: ``C(w - ref; dither)`` in one HBM pass —
    the SEND side of the physical wire, what every server computes
    immediately before the round's collective (round 0, ``ref = 0``,
    encodes the full state; that transmission is what error feedback
    tracks).  Unfused, the delta is a full (M, D) f32 HBM round-trip
    before the quantize pass reads it back.

    ``w``: (M, D) iterates; ``ref``: (M, D) f32 decoded references;
    ``dither``: (M, D) uniform [0, 1) rounding noise (generated outside —
    see ``quantized_consensus_mix_2d``).  Returns ``(codes, scales)`` with
    ``codes`` (M, D) UNPACKED int8 (int4 values in int8 storage —
    ``comm.compressors.pack_int4`` is a free view change at the collective
    boundary) and ``scales`` (M, D/chunk) f32.  Bit-identical to
    ``StochasticQuantizer.encode_block`` of ``w - ref`` under jit when
    ``chunk`` divides ``D`` (asserted in ``tests/test_wire.py``)."""
    m, d = w.shape
    if bits not in (4, 8):
        raise ValueError(f"bits must be 4 or 8, got {bits}")
    if d % chunk:
        raise ValueError(f"chunk={chunk} must divide D={d} (pad the wire "
                         f"buffer to the bucket grid first, as the gossip "
                         f"paths do)")
    block_d = max(chunk, min(block_d, d))
    if block_d % chunk:
        raise ValueError(f"chunk={chunk} must divide block_d={block_d}")
    nb = pl.cdiv(d, block_d)
    pad = nb * block_d - d
    if pad:     # ragged tile grid: zero deltas quantize to zero codes
        w = jnp.pad(w, ((0, 0), (0, pad)))
        ref = jnp.pad(ref, ((0, 0), (0, pad)))
        dither = jnp.pad(dither, ((0, 0), (0, pad)))
    qmax = float(2 ** (bits - 1) - 1)
    nc_blk = block_d // chunk
    kernel = functools.partial(_wire_encode_kernel, block_d=block_d,
                               chunk=chunk, qmax=qmax)
    out_q, out_s = pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((m, block_d), lambda i: (0, i)),
            pl.BlockSpec((m, block_d), lambda i: (0, i)),
            pl.BlockSpec((m, block_d), lambda i: (0, i)),
        ],
        out_specs=[
            pl.BlockSpec((m, block_d), lambda i: (0, i)),
            pl.BlockSpec((m, nc_blk), lambda i: (0, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, nb * block_d), jnp.int8),
            jax.ShapeDtypeStruct((m, nb * nc_blk), jnp.float32),
        ],
        interpret=interpret,
    )(w, ref, dither)
    return out_q[:, :d], out_s[:, :d // chunk]


# ---------------------------------------------------------------------------
# fused bucketed round: the band-carried recursion of the PR-6 wire
# ---------------------------------------------------------------------------


def _bucketed_round_kernel(a_ref, q_ref, s_ref, r_ref, c_ref, u_ref,
                           oa_ref, or_ref, oq_ref, os_ref, *, block_d: int,
                           chunk: int, qmax: float):
    """One (M, block_d) tile of a BUCKETED delta-coded gossip round:
    dequantize the gathered codes, fold each server's own decoded delta
    into its reference row (``r`` is the band — row i is server i's OWN
    reference, so the update is elementwise, no (M, M) fan-out),
    accumulate the mixed deltas into ``acc``, and re-encode the next
    innovations ``acc - r`` with fresh scales + dither."""
    a = a_ref[...].astype(jnp.float32)                 # (M, M) resident
    q = q_ref[...].astype(jnp.float32)                 # (M, block_d) codes
    s = s_ref[...]                                     # (M, nc) scales
    r = r_ref[...]                                     # (M, block_d) band
    acc = c_ref[...]                                   # (M, block_d) f32
    u = u_ref[...].astype(jnp.float32)                 # dither in [0, 1)
    m = q.shape[0]
    nc = block_d // chunk
    dec = (q.reshape(m, nc, chunk) * s[..., None]).reshape(m, block_d)
    r = r + dec
    # unrolled left-to-right mul-adds, NOT an MXU dot — same reason and
    # same order as ``_wire_round_kernel``: this is what keeps the kernel
    # bit-identical to the bucketed wire paths
    for j in range(m):
        acc = acc + a[:, j:j + 1] * dec[j]
    wc = (acc - r).reshape(m, nc, chunk)               # next innovations
    absmax = jnp.max(jnp.abs(wc), axis=-1, keepdims=True)
    # multiply by the reciprocal CONSTANT, never divide: XLA's
    # simplifier rewrites float division by a constant to a
    # reciprocal multiply in SOME programs and not others (a 1-ulp
    # scale skew between compilations of the same formula); an
    # explicit literal leaves it nothing to rewrite, and matches
    # ``comm.compressors.StochasticQuantizer._scales`` bitwise
    scale = jnp.where(absmax > 0, absmax * (1.0 / qmax), 1.0)
    q2 = jnp.clip(jnp.floor(wc * (1.0 / scale) + u.reshape(m, nc, chunk)),
                  -qmax, qmax)
    oa_ref[...] = acc
    or_ref[...] = r
    oq_ref[...] = q2.reshape(m, block_d).astype(jnp.int8)
    os_ref[...] = scale[..., 0]


def bucketed_gossip_round_2d(a: jax.Array, codes: jax.Array,
                             scales: jax.Array, ref: jax.Array,
                             acc: jax.Array, dither: jax.Array, *,
                             bits: int = 8, chunk: int = 256,
                             block_d: int = 2048, interpret: bool = True):
    """Fused encode→gather→dequant→accumulate→mix→requant, bucketed: one
    round of ``core.consensus.gossip_scan_wire_bucketed``'s band-carried
    recursion after the all-gather, in one HBM pass.

    Implements (rows = servers, everything elementwise over D)::

        dec   = D(codes, scales)       (gathered decoded deltas)
        ref'  = ref + dec              (each row: its OWN reference band)
        acc'  = acc + A · dec          (running (A · R_t) accumulator)
        delta'= acc' - ref'            (next innovations; f32 iterate)
        codes', scales' = C(delta'; dither)

    ``codes``: (M, D) int8 delta codes as delivered by the all-gather
    (int4 UNPACKED into int8); ``scales``: (M, D/chunk) f32; ``ref`` /
    ``acc``: the (M, D) f32 band state (own-reference rows and mixed-
    reference accumulators — together ~3 vectors per server instead of the
    per-leaf form's (M+1)); ``dither``: (M, D) uniform [0, 1) noise for
    the re-encode.  Returns ``(acc', ref', codes', scales')`` — the mixed
    iterate IS ``acc'`` (cast to the model dtype by the caller).  The
    decoded deltas and pre-encode innovations never touch HBM; vs the
    unfused jnp round that is 4 (M, D) f32 HBM passes saved.

    Bit-identical to the jnp oracle (``decode_block`` → band update →
    left-to-right accumulate → ``encode_block``) under jit when ``chunk``
    divides ``block_d`` and ``D`` — asserted for both code widths in
    ``tests/test_wire.py``."""
    m, d = codes.shape
    if bits not in (4, 8):
        raise ValueError(f"bits must be 4 or 8, got {bits}")
    if d % chunk:
        raise ValueError(f"chunk={chunk} must divide D={d} (pad the wire "
                         f"buffer to the bucket grid first, as the gossip "
                         f"paths do)")
    block_d = max(chunk, min(block_d, d))
    if block_d % chunk:
        raise ValueError(f"chunk={chunk} must divide block_d={block_d}")
    nb = pl.cdiv(d, block_d)
    pad = nb * block_d - d
    if pad:     # ragged tile grid: zero codes / unit scales are inert
        codes = jnp.pad(codes, ((0, 0), (0, pad)))
        scales = jnp.pad(scales, ((0, 0), (0, pad // chunk)),
                         constant_values=1.0)
        ref = jnp.pad(ref, ((0, 0), (0, pad)))
        acc = jnp.pad(acc, ((0, 0), (0, pad)))
        dither = jnp.pad(dither, ((0, 0), (0, pad)))
    qmax = float(2 ** (bits - 1) - 1)
    nc_blk = block_d // chunk
    kernel = functools.partial(_bucketed_round_kernel, block_d=block_d,
                               chunk=chunk, qmax=qmax)
    out_a, out_r, out_q, out_s = pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((m, m), lambda i: (0, 0)),        # A resident
            pl.BlockSpec((m, block_d), lambda i: (0, i)),
            pl.BlockSpec((m, nc_blk), lambda i: (0, i)),
            pl.BlockSpec((m, block_d), lambda i: (0, i)),
            pl.BlockSpec((m, block_d), lambda i: (0, i)),
            pl.BlockSpec((m, block_d), lambda i: (0, i)),
        ],
        out_specs=[
            pl.BlockSpec((m, block_d), lambda i: (0, i)),
            pl.BlockSpec((m, block_d), lambda i: (0, i)),
            pl.BlockSpec((m, block_d), lambda i: (0, i)),
            pl.BlockSpec((m, nc_blk), lambda i: (0, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, nb * block_d), jnp.float32),
            jax.ShapeDtypeStruct((m, nb * block_d), jnp.float32),
            jax.ShapeDtypeStruct((m, nb * block_d), jnp.int8),
            jax.ShapeDtypeStruct((m, nb * nc_blk), jnp.float32),
        ],
        interpret=interpret,
    )(a, codes, scales, ref, acc, dither)
    return (out_a[:, :d], out_r[:, :d], out_q[:, :d],
            out_s[:, :d // chunk])


# ---------------------------------------------------------------------------
# software-pipelined bucketed round: the bounded-staleness wire body
# ---------------------------------------------------------------------------


def _bucketed_round_pipelined_kernel(a_ref, q_ref, s_ref, w_ref, r_ref,
                                     c_ref, u_ref, oa_ref, or_ref, oq_ref,
                                     os_ref, *, block_d: int, chunk: int,
                                     qmax: float):
    """One (M, block_d) tile of a PIPELINED bucketed round: the send side
    (encode this round's innovation, advance the own sent-reference band)
    runs first and depends only on local state, so its codes can leave on
    the wire while the consume side folds the DELAYED codes (round t-s)
    into the accumulator — the in-kernel order mirrors the data-dependence
    split that lets XLA overlap the collective with the round's FMA work
    in ``core.consensus``'s stale bodies."""
    a = a_ref[...].astype(jnp.float32)                 # (M, M) resident
    q = q_ref[...].astype(jnp.float32)                 # DELAYED codes
    s = s_ref[...]                                     # delayed scales
    w = w_ref[...].astype(jnp.float32)                 # current iterates
    r = r_ref[...]                                     # sent-reference band
    acc = c_ref[...]                                   # (M, block_d) f32
    u = u_ref[...].astype(jnp.float32)                 # dither in [0, 1)
    m = q.shape[0]
    nc = block_d // chunk
    # SEND side: encode w - r against the up-to-date sent reference
    wc = (w - r).reshape(m, nc, chunk)
    absmax = jnp.max(jnp.abs(wc), axis=-1, keepdims=True)
    # multiply by the reciprocal CONSTANT, never divide: XLA's
    # simplifier rewrites float division by a constant to a
    # reciprocal multiply in SOME programs and not others (a 1-ulp
    # scale skew between compilations of the same formula); an
    # explicit literal leaves it nothing to rewrite, and matches
    # ``comm.compressors.StochasticQuantizer._scales`` bitwise
    scale = jnp.where(absmax > 0, absmax * (1.0 / qmax), 1.0)
    q2 = jnp.clip(jnp.floor(wc * (1.0 / scale) + u.reshape(m, nc, chunk)),
                  -qmax, qmax)
    # own-decode: the sent reference advances by what just shipped, from
    # LOCAL codes — never waits on the gather of this round's codes
    r = r + (q2 * scale).reshape(m, block_d)
    # CONSUME side: fold the delayed deltas.  (a · scale) folded per chunk
    # BEFORE the code multiply, unrolled left-to-right — the exact product
    # order of ``gossip_scan_wire_bucketed``'s stale body, which is what
    # keeps the kernel bit-identical to it
    c3 = q.reshape(m, nc, chunk)
    acc3 = acc.reshape(m, nc, chunk)
    for j in range(m):
        acc3 = acc3 + (a[:, j:j + 1] * s[j])[:, :, None] * c3[j]
    oa_ref[...] = acc3.reshape(m, block_d)
    or_ref[...] = r
    oq_ref[...] = q2.reshape(m, block_d).astype(jnp.int8)
    os_ref[...] = scale[..., 0]


def bucketed_gossip_round_pipelined_2d(a: jax.Array, codes: jax.Array,
                                       scales: jax.Array, w: jax.Array,
                                       ref: jax.Array, acc: jax.Array,
                                       dither: jax.Array, *, bits: int = 8,
                                       chunk: int = 256, block_d: int = 2048,
                                       interpret: bool = True):
    """Fused SOFTWARE-PIPELINED bucketed round: one round of
    ``core.consensus.gossip_scan_wire_bucketed``'s bounded-staleness
    recursion (``staleness >= 1``) in one HBM pass.

    Implements (rows = servers; ``codes``/``scales`` are the DELAYED
    payload from round ``t - s``, pulled off the staleness ring)::

        codes', scales' = C(w - ref; dither)   (this round's innovation)
        ref'  = ref + D(codes', scales')       (own-decode, local codes)
        acc'  = acc + A · D(codes, scales)     (consume the stale deltas)

    The send side (first two lines) has no data dependence on the delayed
    payload, so round t's collective overlaps round t's accumulate — the
    double-buffering the stale wire bodies express with their code/scale
    ring carry.  The iterate gate ``w <- where(t >= s, acc', w)`` stays
    OUTSIDE the kernel: it is ring-phase control, not tile math.

    ``w``: (M, D) iterates (any float dtype; cast to f32 in-tile);
    ``codes``: (M, D) int8 delayed delta codes (int4 UNPACKED into int8);
    ``scales``: (M, D/chunk) f32 delayed scales; ``ref`` / ``acc``: the
    (M, D) f32 band state; ``dither``: (M, D) uniform [0, 1) noise.
    Returns ``(acc', ref', codes', scales')`` — ``codes'``/``scales'`` are
    what this round SHIPS (push to the staleness ring), ``acc'`` the
    consume result.  Bit-identical to the stale jnp oracle (encode →
    own-decode → folded left-to-right accumulate) under jit — asserted in
    ``tests/test_overlap.py``."""
    m, d = codes.shape
    if bits not in (4, 8):
        raise ValueError(f"bits must be 4 or 8, got {bits}")
    if d % chunk:
        raise ValueError(f"chunk={chunk} must divide D={d} (pad the wire "
                         f"buffer to the bucket grid first, as the gossip "
                         f"paths do)")
    block_d = max(chunk, min(block_d, d))
    if block_d % chunk:
        raise ValueError(f"chunk={chunk} must divide block_d={block_d}")
    nb = pl.cdiv(d, block_d)
    pad = nb * block_d - d
    if pad:     # ragged tile grid: zero codes / unit scales are inert
        codes = jnp.pad(codes, ((0, 0), (0, pad)))
        scales = jnp.pad(scales, ((0, 0), (0, pad // chunk)),
                         constant_values=1.0)
        w = jnp.pad(w, ((0, 0), (0, pad)))
        ref = jnp.pad(ref, ((0, 0), (0, pad)))
        acc = jnp.pad(acc, ((0, 0), (0, pad)))
        dither = jnp.pad(dither, ((0, 0), (0, pad)))
    qmax = float(2 ** (bits - 1) - 1)
    nc_blk = block_d // chunk
    kernel = functools.partial(_bucketed_round_pipelined_kernel,
                               block_d=block_d, chunk=chunk, qmax=qmax)
    out_a, out_r, out_q, out_s = pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((m, m), lambda i: (0, 0)),        # A resident
            pl.BlockSpec((m, block_d), lambda i: (0, i)),
            pl.BlockSpec((m, nc_blk), lambda i: (0, i)),
            pl.BlockSpec((m, block_d), lambda i: (0, i)),
            pl.BlockSpec((m, block_d), lambda i: (0, i)),
            pl.BlockSpec((m, block_d), lambda i: (0, i)),
            pl.BlockSpec((m, block_d), lambda i: (0, i)),
        ],
        out_specs=[
            pl.BlockSpec((m, block_d), lambda i: (0, i)),
            pl.BlockSpec((m, block_d), lambda i: (0, i)),
            pl.BlockSpec((m, block_d), lambda i: (0, i)),
            pl.BlockSpec((m, nc_blk), lambda i: (0, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, nb * block_d), jnp.float32),
            jax.ShapeDtypeStruct((m, nb * block_d), jnp.float32),
            jax.ShapeDtypeStruct((m, nb * block_d), jnp.int8),
            jax.ShapeDtypeStruct((m, nb * nc_blk), jnp.float32),
        ],
        interpret=interpret,
    )(a, codes, scales, w, ref, acc, dither)
    return (out_a[:, :d], out_r[:, :d], out_q[:, :d],
            out_s[:, :d // chunk])
