"""Fused consensus-mixing Pallas-TPU kernel:  W <- A_eff W  in ONE HBM pass.

This is the single-chip half of the beyond-paper "collapsed consensus"
optimization (DESIGN.md §7): the faithful DFL server loop applies A for
T_S rounds, i.e. T_S full read+write passes over every server's parameter
vector.  Since A^{T_S} is an (M x M) matrix that is trivially precomputed on
the host, one streaming pass suffices — the kernel is purely memory-bound,
so collapsing T_S passes into 1 cuts consensus HBM traffic by exactly T_S x.

Layout: the parameter pytree is flattened into a (M, D) matrix (D = total
model params).  A_eff is tiny (M<=64) and stays resident in VMEM across all
grid steps; W streams through in (M, block_d) tiles.

Grid: (D // block_d,).  VMEM per step: M*block_d*4 bytes in + out + M*M.
block_d = 2048 with M = 16 -> 256 KB per buffer: far under VMEM, deep
double-buffering.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _mix_kernel(a_ref, w_ref, o_ref, *, total_d: int, block_d: int):
    i = pl.program_id(0)
    a = a_ref[...].astype(jnp.float32)            # (M, M) resident
    w = w_ref[...].astype(jnp.float32)            # (M, block_d)
    if total_d % block_d:
        col = i * block_d + jax.lax.broadcasted_iota(
            jnp.int32, w.shape, 1)
        w = jnp.where(col < total_d, w, 0.0)      # NaN-safe ragged tail
    o_ref[...] = jax.lax.dot_general(
        a, w, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).astype(o_ref.dtype)


def consensus_mix_2d(a_eff: jax.Array, w: jax.Array, *, block_d: int = 2048,
                     interpret: bool = True) -> jax.Array:
    """w: (M, D); a_eff: (M, M).  Returns A_eff @ w, one HBM pass."""
    m, d = w.shape
    block_d = min(block_d, d)
    grid = (pl.cdiv(d, block_d),)
    kernel = functools.partial(_mix_kernel, total_d=d, block_d=block_d)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((m, m), lambda i: (0, 0)),         # A resident
            pl.BlockSpec((m, block_d), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((m, block_d), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((m, d), w.dtype),
        interpret=interpret,
    )(a_eff, w)
