"""Fused consensus-mixing Pallas-TPU kernel:  W <- A_eff W  in ONE HBM pass.

This is the single-chip half of the beyond-paper "collapsed consensus"
optimization (DESIGN.md §7): the faithful DFL server loop applies A for
T_S rounds, i.e. T_S full read+write passes over every server's parameter
vector.  Since A^{T_S} is an (M x M) matrix that is trivially precomputed on
the host, one streaming pass suffices — the kernel is purely memory-bound,
so collapsing T_S passes into 1 cuts consensus HBM traffic by exactly T_S x.

Layout: the parameter pytree is flattened into a (M, D) matrix (D = total
model params).  A_eff is tiny (M<=64) and stays resident in VMEM across all
grid steps; W streams through in (M, block_d) tiles.

Grid: (D // block_d,).  VMEM per step: M*block_d*4 bytes in + out + M*M.
block_d = 2048 with M = 16 -> 256 KB per buffer: far under VMEM, deep
double-buffering.

``quantized_consensus_mix_2d`` is the compressed-gossip variant: the wire
round-trip of ``comm.compressors.StochasticQuantizer`` (per-chunk scales,
stochastic rounding, dequantize) fused INTO the same single mixing pass —
what a server computes when it applies the collapsed operator to the
int8/int4 payloads it received, without ever materialising the quantized
model in HBM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _mix_kernel(a_ref, w_ref, o_ref, *, total_d: int, block_d: int):
    i = pl.program_id(0)
    a = a_ref[...].astype(jnp.float32)            # (M, M) resident
    w = w_ref[...].astype(jnp.float32)            # (M, block_d)
    if total_d % block_d:
        col = i * block_d + jax.lax.broadcasted_iota(
            jnp.int32, w.shape, 1)
        w = jnp.where(col < total_d, w, 0.0)      # NaN-safe ragged tail
    o_ref[...] = jax.lax.dot_general(
        a, w, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).astype(o_ref.dtype)


def consensus_mix_2d(a_eff: jax.Array, w: jax.Array, *, block_d: int = 2048,
                     interpret: bool = True) -> jax.Array:
    """w: (M, D); a_eff: (M, M).  Returns A_eff @ w, one HBM pass."""
    m, d = w.shape
    block_d = min(block_d, d)
    grid = (pl.cdiv(d, block_d),)
    kernel = functools.partial(_mix_kernel, total_d=d, block_d=block_d)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((m, m), lambda i: (0, 0)),         # A resident
            pl.BlockSpec((m, block_d), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((m, block_d), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((m, d), w.dtype),
        interpret=interpret,
    )(a_eff, w)


# ---------------------------------------------------------------------------
# fused quantize -> mix -> dequantize (the compressed-gossip single-chip path)
# ---------------------------------------------------------------------------


def _quant_mix_kernel(a_ref, w_ref, u_ref, o_ref, *, block_d: int,
                      chunk: int, qmax: float):
    """One (M, block_d) tile: per-(row, chunk) absmax scales, stochastic-
    rounded int codes, dequantize, then the A contraction — the wire
    round-trip of ``comm.compressors.StochasticQuantizer`` fused into the
    mixing pass so the quantized values never touch HBM."""
    a = a_ref[...].astype(jnp.float32)                 # (M, M) resident
    w = w_ref[...].astype(jnp.float32)                 # (M, block_d)
    u = u_ref[...].astype(jnp.float32)                 # dither in [0, 1)
    m = w.shape[0]
    nc = block_d // chunk
    wc = w.reshape(m, nc, chunk)
    absmax = jnp.max(jnp.abs(wc), axis=-1, keepdims=True)
    scale = jnp.where(absmax > 0, absmax / qmax, 1.0)
    q = jnp.clip(jnp.floor(wc / scale + u.reshape(m, nc, chunk)),
                 -qmax, qmax)
    deq = (q * scale).reshape(m, block_d)
    o_ref[...] = jax.lax.dot_general(
        a, deq, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).astype(o_ref.dtype)


def quantized_consensus_mix_2d(a_eff: jax.Array, w: jax.Array,
                               dither: jax.Array, *, bits: int = 8,
                               chunk: int = 256, block_d: int = 2048,
                               interpret: bool = True) -> jax.Array:
    """Fused quantize -> mix -> dequantize:  A_eff @ D(C(w))  in one pass.

    ``w``: (M, D) flattened server models; ``a_eff``: the (collapsed)
    mixing operator; ``dither``: (M, D) uniform [0, 1) stochastic-rounding
    noise, generated OUTSIDE the kernel (``jax.random.uniform``) so the
    same randomness can drive the jnp wire simulation — on a real TPU the
    in-kernel ``pltpu.prng_random_bits`` path avoids the HBM read, but the
    interpret-mode CPU backend this container runs has no TPU PRNG.

    Bit-identical to ``StochasticQuantizer(bits, chunk).roundtrip`` followed
    by ``consensus_mix_2d`` when ``chunk`` divides the chosen ``block_d``
    (chunk boundaries then align across tiles), while touching W's HBM
    bytes once instead of three times (quantize pass + mix read + write).
    """
    m, d = w.shape
    if bits not in (4, 8):
        raise ValueError(f"bits must be 4 or 8, got {bits}")
    block_d = max(chunk, min(block_d, -(-d // chunk) * chunk))
    if block_d % chunk:
        raise ValueError(f"chunk={chunk} must divide block_d={block_d}")
    # pad to the tile grid up front: trailing zeros quantize to zero codes
    # and contribute nothing to the contraction, so no in-kernel masking
    nb = pl.cdiv(d, block_d)
    pad = nb * block_d - d
    if pad:
        w = jnp.pad(w, ((0, 0), (0, pad)))
        dither = jnp.pad(dither, ((0, 0), (0, pad)))
    qmax = float(2 ** (bits - 1) - 1)
    kernel = functools.partial(_quant_mix_kernel, block_d=block_d,
                               chunk=chunk, qmax=qmax)
    out = pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((m, m), lambda i: (0, 0)),        # A resident
            pl.BlockSpec((m, block_d), lambda i: (0, i)),
            pl.BlockSpec((m, block_d), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((m, block_d), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((m, nb * block_d), w.dtype),
        interpret=interpret,
    )(a_eff, w, dither)
    return out[:, :d]
