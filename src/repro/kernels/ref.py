"""Pure-jnp oracles for every Pallas kernel in this package.

These are *independent* reference implementations (naive math, no blocking,
no online softmax, no chunking) so the kernel sweep tests in
``tests/test_kernels_*.py`` compare two genuinely different code paths.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True, window: Optional[int] = None,
                  softcap: Optional[float] = None,
                  scale: Optional[float] = None) -> jax.Array:
    """Naive GQA attention.  q: (b, sq, h, hd); k/v: (b, sk, kvh, hd).

    ``window``: sliding window size (key j visible to query i iff
    i-window < j <= i, positions aligned at the end: query i sits at
    absolute position i + sk - sq).
    """
    b, sq, h, hd = q.shape
    sk, kvh = k.shape[1], k.shape[2]
    group = h // kvh
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    qf = q.astype(jnp.float32) * scale
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    # expand kv heads to q heads
    kf = jnp.repeat(kf, group, axis=2)
    vf = jnp.repeat(vf, group, axis=2)
    scores = jnp.einsum("bqhd,bkhd->bhqk", qf, kf)
    if softcap is not None:
        scores = softcap * jnp.tanh(scores / softcap)
    qpos = jnp.arange(sq) + (sk - sq)
    kpos = jnp.arange(sk)
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask = mask & (kpos[None, :] <= qpos[:, None])
    if window is not None:
        mask = mask & (kpos[None, :] > qpos[:, None] - window)
    scores = jnp.where(mask[None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, vf)
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# mamba2 / SSD scan
# ---------------------------------------------------------------------------


def ssd_scan_ref(xs: jax.Array, bs: jax.Array, cs: jax.Array, dt: jax.Array,
                 a_coef: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Naive per-timestep SSM recurrence (the definition, O(s) sequential):

        h_t = exp(dt_t * A_h) * h_{t-1} + dt_t * B_t x_t'
        y_t = C_t . h_t

    xs: (b, s, nh, hd); bs/cs: (b, s, g, ds) with g==1; dt: (b, s, nh) f32;
    a_coef: (nh,) negative.  Returns (y (b,s,nh,hd) f32, state (b,nh,ds,hd)).
    """
    bsz, s, nh, hd = xs.shape
    ds = bs.shape[-1]
    bh = jnp.broadcast_to(bs[:, :, 0][:, :, None], (bsz, s, nh, ds))
    ch = jnp.broadcast_to(cs[:, :, 0][:, :, None], (bsz, s, nh, ds))

    def step(h, inp):
        x_t, b_t, c_t, dt_t = inp          # (b,nh,hd), (b,nh,ds), ..., (b,nh)
        decay = jnp.exp(dt_t * a_coef)     # (b, nh)
        upd = jnp.einsum("bhn,bhp->bhnp", b_t, x_t * dt_t[..., None])
        h = h * decay[..., None, None] + upd
        y = jnp.einsum("bhn,bhnp->bhp", c_t, h)
        return h, y

    seq = (jnp.moveaxis(xs.astype(jnp.float32), 1, 0),
           jnp.moveaxis(bh.astype(jnp.float32), 1, 0),
           jnp.moveaxis(ch.astype(jnp.float32), 1, 0),
           jnp.moveaxis(dt.astype(jnp.float32), 1, 0))
    init = jnp.zeros((bsz, nh, ds, hd), jnp.float32)
    final, ys = jax.lax.scan(step, init, seq)
    return jnp.moveaxis(ys, 0, 1), final


# ---------------------------------------------------------------------------
# consensus mixing
# ---------------------------------------------------------------------------


def consensus_mix_ref(a_eff: jax.Array, w: jax.Array) -> jax.Array:
    """W <- A_eff W.  a_eff: (M, M) f32; w: (M, D)."""
    return jnp.einsum("ij,jd->id", a_eff.astype(jnp.float32),
                      w.astype(jnp.float32)).astype(w.dtype)


# ---------------------------------------------------------------------------
# rmsnorm
# ---------------------------------------------------------------------------


def rmsnorm_ref(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) *
            scale.astype(jnp.float32)).astype(x.dtype)
