"""Jitted public wrappers around the Pallas kernels.

These adapt the model-zoo layouts to the kernel-native layouts, pad where a
block constraint requires it, and pick interpret mode automatically:
``interpret=True`` whenever the backend has no TPU (this container), the
real Mosaic path on TPU.  Models call these via ``ApplyOptions(attn_impl=
"pallas")``; the default model path stays the jnp reference so CPU dry-runs
lower without Pallas in the HLO.
"""
from __future__ import annotations

import functools
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import consensus_mix as _cm
from repro.kernels import flash_attention as _fa
from repro.kernels import rmsnorm as _rn
from repro.kernels import ssd_scan as _ssd


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


# ---------------------------------------------------------------------------
# flash attention  (model layout: (b, s, h, hd))
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("causal", "window", "softcap",
                                             "scale", "block_q", "block_k"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: Optional[int] = None,
                    softcap: Optional[float] = None,
                    scale: Optional[float] = None,
                    block_q: int = 128, block_k: int = 128) -> jax.Array:
    """q: (b, sq, h, hd); k/v: (b, sk, kvh, hd) -> (b, sq, h, hd)."""
    b, sq, h, hd = q.shape
    bq = min(block_q, sq)
    pad_q = (-sq) % bq
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    if pad_q:
        # pad queries at the FRONT so the end-aligned causal positions of the
        # real queries are unchanged; padded rows are discarded.
        qt = jnp.pad(qt, ((0, 0), (0, 0), (pad_q, 0), (0, 0)))
    out = _fa.flash_attention_bhsd(
        qt, kt, vt, causal=causal, window=window, softcap=softcap,
        scale=scale, block_q=bq, block_k=block_k,
        interpret=_interpret_default())
    if pad_q:
        out = out[:, :, pad_q:]
    return out.transpose(0, 2, 1, 3)


# ---------------------------------------------------------------------------
# SSD scan  (model layout: xs (b,s,nh,hd), bs/cs (b,s,g,ds), dt (b,s,nh))
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("chunk",))
def ssd_scan(xs: jax.Array, bs: jax.Array, cs: jax.Array, dt: jax.Array,
             a_coef: jax.Array, *, chunk: int = 128
             ) -> Tuple[jax.Array, jax.Array]:
    """Matches ``repro.models.mamba.ssd_chunked``'s contract:
    returns (y (b,s,nh,hd) f32, final state (b,nh,ds,hd) f32)."""
    b, s, nh, hd = xs.shape
    ds = bs.shape[-1]
    xk = xs.transpose(0, 2, 1, 3)
    bk = jnp.broadcast_to(bs[:, :, 0][:, :, None],
                          (b, s, nh, ds)).transpose(0, 2, 1, 3)
    ck = jnp.broadcast_to(cs[:, :, 0][:, :, None],
                          (b, s, nh, ds)).transpose(0, 2, 1, 3)
    dk = dt.astype(jnp.float32).transpose(0, 2, 1)[..., None]
    y, state = _ssd.ssd_scan_bhs(xk, bk, ck, dk, a_coef, chunk=chunk,
                                 interpret=_interpret_default())
    return y.transpose(0, 2, 1, 3), state


# ---------------------------------------------------------------------------
# consensus mixing over a parameter pytree
# ---------------------------------------------------------------------------


def consensus_mix_pytree(a_eff: jax.Array, tree: Any,
                         block_d: int = 2048) -> Any:
    """Apply W <- A_eff W to every leaf with leading server axis M, through
    ONE fused flatten -> kernel -> unflatten pass (leaves concatenated so the
    whole model is a single (M, D) stream)."""
    leaves, treedef = jax.tree.flatten(tree)
    m = leaves[0].shape[0]
    sizes = [leaf[0].size for leaf in leaves]
    flat = jnp.concatenate(
        [leaf.reshape(m, -1).astype(jnp.float32) for leaf in leaves], axis=1)
    mixed = _cm.consensus_mix_2d(a_eff, flat, block_d=block_d,
                                 interpret=_interpret_default())
    out, off = [], 0
    for leaf, size in zip(leaves, sizes):
        out.append(mixed[:, off:off + size].reshape(leaf.shape).astype(leaf.dtype))
        off += size
    return jax.tree.unflatten(treedef, out)


# ---------------------------------------------------------------------------
# rmsnorm  (model layout: (..., d))
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("eps", "block_rows"))
def rmsnorm(x: jax.Array, scale: jax.Array, *, eps: float = 1e-6,
            block_rows: int = 256) -> jax.Array:
    lead = x.shape[:-1]
    d = x.shape[-1]
    rows = 1
    for n in lead:
        rows *= n
    x2 = x.reshape(rows, d)
    br = min(block_rows, rows)
    pad = (-rows) % br
    if pad:
        x2 = jnp.pad(x2, ((0, pad), (0, 0)))
    y = _rn.rmsnorm_2d(x2, scale, eps=eps, block_rows=br,
                       interpret=_interpret_default())
    if pad:
        y = y[:rows]
    return y.reshape(*lead, d)
