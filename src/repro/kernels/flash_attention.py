"""Blockwise (flash) attention Pallas-TPU kernel.

TPU adaptation of GPU FlashAttention (DESIGN.md §6): instead of warp-level
softmax reductions, the online-softmax state (m, l, acc) lives in VMEM
scratch that persists across the *sequentially executed* innermost grid
dimension — TPU grids are sequential, so the k-block loop is a grid axis
rather than an in-kernel loop, letting Pallas double-buffer the HBM->VMEM
tile streams for k/v while the MXU works on the previous tile.

Grid: (batch*q_heads, num_q_blocks, num_k_blocks)  — k innermost.
Blocks: q tile (block_q, hd), k/v tiles (block_k, hd), out tile (block_q, hd).
VMEM scratch: acc (block_q, hd) f32, m/l (block_q, 128) f32 (lane-replicated
to keep the layout 2-D and aligned).

Features: causal masking, sliding window, logit soft-capping, GQA (kv-head
indexing folded into the BlockSpec index maps) — the union of what the
assigned architectures need (gemma2 softcap+local, mixtral SWA, command-r /
qwen3 / smollm GQA, jamba attention layers).

Masked k-blocks (fully outside the causal/window band) are skipped via
``pl.when``: the MXU work is predicated out, only the (tiny) scratch update
runs.  Entirely-masked *rows* are handled by the usual l==0 guard at the
finalization step.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
LANES = 128


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                 block_q: int, block_k: int, sq: int, sk: int,
                 causal: bool, window: Optional[int],
                 softcap: Optional[float], scale: float, num_k_blocks: int):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # absolute positions: queries sit at the END of the key sequence
    # (decode-style alignment; == standard causal when sq == sk)
    q_off = sk - sq
    q_pos = iq * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0) + q_off
    k_pos = ik * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)

    # block-level skip test: is any (q, k) pair in this tile visible?
    q_lo = iq * block_q + q_off
    q_hi = q_lo + block_q - 1
    k_lo = ik * block_k
    k_hi = k_lo + block_k - 1
    live = jnp.bool_(True)
    if causal:
        live = live & (k_lo <= q_hi)
    if window is not None:
        live = live & (k_hi > q_lo - window)

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale         # (bq, hd)
        k = k_ref[0, 0].astype(jnp.float32)                 # (bk, hd)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        mask = jnp.ones((block_q, block_k), jnp.bool_)
        if causal:
            mask = mask & (k_pos <= q_pos)
        if window is not None:
            mask = mask & (k_pos > q_pos - window)
        if sk % block_k:                                     # ragged tail
            mask = mask & (k_pos < sk)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[:, :1]                                # (bq, 1)
        m_cur = jnp.max(s, axis=-1, keepdims=True)           # (bq, 1)
        m_new = jnp.maximum(m_prev, m_cur)
        # guard fully-masked-so-far rows: exp(NEG_INF - NEG_INF) would be 1
        p = jnp.exp(s - jnp.where(m_new <= NEG_INF, 0.0, m_new))
        p = jnp.where(mask, p, 0.0)
        alpha = jnp.exp(m_prev - jnp.where(m_new <= NEG_INF, 0.0, m_new))
        alpha = jnp.where(m_prev <= NEG_INF, 0.0, alpha)     # first live block
        l_new = alpha * l_ref[:, :1] + jnp.sum(p, axis=-1, keepdims=True)
        v = v_ref[0, 0].astype(jnp.float32)                  # (bk, hd)
        if sk % block_k:
            # ragged tail: out-of-bounds v rows may be garbage/NaN padding;
            # p is 0 there but 0*NaN = NaN, so zero them explicitly.
            row = ik * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (block_k, 1), 0)
            v = jnp.where(row < sk, v, 0.0)
        pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * alpha + pv
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(ik == num_k_blocks - 1)
    def _finalize():
        l = l_ref[:, :1]
        o_ref[0, 0] = (acc_ref[...] / jnp.where(l == 0.0, 1.0, l)
                       ).astype(o_ref.dtype)


def flash_attention_bhsd(q: jax.Array, k: jax.Array, v: jax.Array, *,
                         causal: bool = True, window: Optional[int] = None,
                         softcap: Optional[float] = None,
                         scale: Optional[float] = None,
                         block_q: int = 128, block_k: int = 128,
                         interpret: bool = True) -> jax.Array:
    """Layout (b, h, s, hd) / (b, kvh, s, hd).  Returns (b, h, sq, hd).

    ``interpret=True`` runs the kernel body in Python on CPU (this container);
    on TPU pass ``interpret=False``.
    """
    b, h, sq, hd = q.shape
    kvh, sk = k.shape[1], k.shape[2]
    group = h // kvh
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    nq = pl.cdiv(sq, block_q)
    nk = pl.cdiv(sk, block_k)
    assert sq % block_q == 0, "pad queries to block_q"
    # Note: queries at negative positions (front padding when sq > sk under
    # causal) attend to nothing and finalize to 0 via the l==0 guard; the
    # ops.py wrapper slices those rows off.

    grid = (b * h, nq, nk)

    kernel = functools.partial(
        _attn_kernel, block_q=block_q, block_k=block_k, sq=sq, sk=sk,
        causal=causal, window=window, softcap=softcap, scale=scale,
        num_k_blocks=nk)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd),
                         lambda bh, iq, ik: (bh // h, bh % h, iq, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda bh, iq, ik: (bh // h, (bh % h) // group, ik, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda bh, iq, ik: (bh // h, (bh % h) // group, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, hd),
                               lambda bh, iq, ik: (bh // h, bh % h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, hd), jnp.float32),
            pltpu.VMEM((block_q, LANES), jnp.float32),
            pltpu.VMEM((block_q, LANES), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
