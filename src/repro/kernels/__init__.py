"""Pallas TPU kernels for the system's compute hot-spots (DESIGN.md §6).

Each kernel ships three layers: ``<name>.py`` (pl.pallas_call + BlockSpec),
``ops.py`` (jitted layout-adapting wrapper the models call), ``ref.py``
(pure-jnp oracle the sweep tests compare against).  On this CPU container
all kernels run with ``interpret=True``; on TPU the Mosaic path compiles.
"""
from repro.kernels import ops, ref
from repro.kernels.ops import (consensus_mix_pytree, flash_attention,
                               rmsnorm, ssd_scan)

__all__ = ["ops", "ref", "flash_attention", "ssd_scan",
           "consensus_mix_pytree", "rmsnorm"]
