from repro.data.pipeline import (DataConfig, FLDataPipeline,
                                 make_regression_data, make_regression_task,
                                 perron_ideal, RegressionSpec,
                                 synthetic_lm_batch)

__all__ = ["DataConfig", "FLDataPipeline", "make_regression_data",
           "make_regression_task", "perron_ideal", "RegressionSpec",
           "synthetic_lm_batch"]
