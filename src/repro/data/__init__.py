from repro.data.pipeline import (DataConfig, FLDataPipeline,
                                 make_regression_data, RegressionSpec,
                                 synthetic_lm_batch)

__all__ = ["DataConfig", "FLDataPipeline", "make_regression_data",
           "RegressionSpec", "synthetic_lm_batch"]
