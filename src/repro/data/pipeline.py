"""Data pipeline for DFL training.

The defining property of federated data is *per-client ownership*: client
(i, j) only ever sees its shard D^{ij} (Sec. II-B).  The pipeline therefore
indexes every batch by (server, client) and emits stacked arrays of shape
``(T_C, M, N, per_client_batch, ...)`` — one microbatch per client per local
iteration — which is exactly what ``dfl.build_dfl_epoch_step`` consumes.

Two sources:
* ``make_regression_data`` — the paper's Sec.-IV synthetic linear-regression
  task (D points per client around a ground-truth w*), with an optional
  heterogeneity knob (per-client covariate shift) to exercise non-IID FL.
* ``synthetic_lm_batch`` / ``FLDataPipeline`` — deterministic token streams
  for LM training: an infinite zipf-ish synthetic corpus, seeded per client,
  so runs are reproducible without external datasets (container is offline).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core.topology import FLTopology


# ---------------------------------------------------------------------------
# the paper's Sec.-IV regression task
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class RegressionSpec:
    w_star: Tuple[float, ...] = (5.0, 2.0)   # paper: w* = (5, 2) (slope, intercept)
    points_per_client: int = 100             # paper: D = 100
    noise_std: float = 0.5
    x_range: Tuple[float, float] = (-5.0, 5.0)
    heterogeneity: float = 0.0               # per-client covariate shift


def make_regression_data(topo: FLTopology, spec: RegressionSpec,
                         seed: int = 0) -> Dict[str, np.ndarray]:
    """Returns {'x': (M, N, D, d), 'y': (M, N, D)} with d = len(w_star);
    the last feature is the constant 1 (intercept)."""
    rng = np.random.default_rng(seed)
    m, n, d_pts = topo.num_servers, topo.clients_per_server, spec.points_per_client
    d = len(spec.w_star)
    lo, hi = spec.x_range
    xs = rng.uniform(lo, hi, size=(m, n, d_pts, d - 1))
    if spec.heterogeneity:
        shift = rng.normal(scale=spec.heterogeneity, size=(m, n, 1, d - 1))
        xs = xs + shift
    feats = np.concatenate([xs, np.ones((m, n, d_pts, 1))], axis=-1)
    w = np.asarray(spec.w_star)
    y = feats @ w + rng.normal(scale=spec.noise_std, size=(m, n, d_pts))
    return {"x": feats.astype(np.float32), "y": y.astype(np.float32)}


def make_regression_task(topo: FLTopology,
                         spec: Optional[RegressionSpec] = None,
                         seed: int = 0) -> Dict[str, object]:
    """The full Sec.-IV harness in one call (shared by tests, benchmarks and
    examples): the 0.5*MSE loss, full-batch per-iteration batches of shape
    ``(T_C, M, N, D, d)``, the global least-squares ``w_star``, and a
    ``batch_fn(epoch, alive_server_ids)`` ready for the dynamic-federation
    engine (slices rows by ORIGINAL server identity)."""
    spec = spec or RegressionSpec()
    data = make_regression_data(topo, spec, seed=seed)
    x, y = jnp.asarray(data["x"]), jnp.asarray(data["y"])

    def loss_fn(w, batch, rng):
        xx, yy = batch
        return 0.5 * jnp.mean((xx @ w - yy) ** 2), {}

    bx = jnp.broadcast_to(x, (topo.t_client,) + x.shape)
    by = jnp.broadcast_to(y, (topo.t_client,) + y.shape)
    w_star = np.linalg.lstsq(np.asarray(x).reshape(-1, x.shape[-1]),
                             np.asarray(y).reshape(-1), rcond=None)[0]

    def batch_fn(epoch, alive):
        ids = np.asarray(alive)
        # validate on the host: jax gather would silently CLAMP a bad id to
        # the last row, feeding a duplicate of another server's shard
        if ids.size and (ids.min() < 0 or ids.max() >= topo.num_servers):
            raise ValueError(f"server ids {alive} out of range for "
                             f"M={topo.num_servers}")
        return bx[:, ids], by[:, ids]

    return {"loss_fn": loss_fn, "batches": (bx, by), "batch_fn": batch_fn,
            "w_star": w_star, "x": x, "y": y}


# ---------------------------------------------------------------------------
# synthetic LM token streams
# ---------------------------------------------------------------------------


def synthetic_lm_batch(key: jax.Array, vocab: int, shape: Tuple[int, ...],
                       alpha: float = 1.1) -> jax.Array:
    """Zipf-distributed token ids (harmonic tail ~ natural-language unigram
    stats) with deterministic bigram structure so a model can actually
    reduce loss: token_t depends weakly on token_{t-1}."""
    k1, k2 = jax.random.split(key)
    ranks = jnp.arange(1, vocab + 1, dtype=jnp.float32)
    probs = ranks ** -alpha
    probs = probs / probs.sum()
    base = jax.random.choice(k1, vocab, shape=shape, p=probs)
    # inject learnable bigram structure: with p=0.5, next = (prev*7+3) % vocab
    mix = jax.random.bernoulli(k2, 0.5, shape)
    rolled = (jnp.roll(base, 1, axis=-1) * 7 + 3) % vocab
    return jnp.where(mix, rolled, base).astype(jnp.int32)


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seq_len: int
    per_client_batch: int
    vocab_size: int
    seed: int = 0


class FLDataPipeline:
    """Infinite iterator of per-epoch stacked batches for DFL.

    Each client's stream is an independently seeded generator —
    fold_in(seed, server_idx * N + client_idx) — mirroring disjoint D^{ij}.
    """

    def __init__(self, topo: FLTopology, cfg: DataConfig,
                 arch: Optional[ArchConfig] = None):
        self.topo = topo
        self.cfg = cfg
        self.arch = arch
        self._epoch = 0

    def epoch_batches(self, epoch: Optional[int] = None,
                      server_ids: Optional[Tuple[int, ...]] = None
                      ) -> Dict[str, jax.Array]:
        """Batch pytree with leaves (T_C, M, N, b, ...).

        ``server_ids``: optional tuple of ORIGINAL server indices to emit
        (dynamic federation: after fault surgery only the alive servers'
        shards are drawn, and a server that drops and later rejoins gets its
        own clients' streams back — client data ownership is tied to
        identity, not to the current row position)."""
        e = self._epoch if epoch is None else epoch
        topo, cfg = self.topo, self.cfg
        key = jax.random.fold_in(jax.random.key(cfg.seed), e)
        shape = (topo.t_client, topo.num_servers, topo.clients_per_server,
                 cfg.per_client_batch, cfg.seq_len)
        batch = {"tokens": synthetic_lm_batch(key, cfg.vocab_size, shape)}
        if self.arch is not None and self.arch.frontend is not None:
            fe = self.arch.frontend
            fkey = jax.random.fold_in(key, 1)
            emb_shape = shape[:-1] + (fe.num_tokens, fe.embed_dim)
            name = ("patch_embeds" if fe.kind == "vision_patches" else "frames")
            batch[name] = jax.random.normal(fkey, emb_shape, jnp.float32)
            if fe.kind == "vision_patches":
                # text tokens shrink so total seq stays cfg.seq_len
                batch["tokens"] = batch["tokens"][..., : cfg.seq_len - fe.num_tokens]
        if server_ids is not None:
            ids = np.asarray(server_ids)
            if ids.size and (ids.min() < 0 or ids.max() >= topo.num_servers):
                raise ValueError(f"server_ids {server_ids} out of range for "
                                 f"M={topo.num_servers}")
            batch = jax.tree.map(lambda x: x[:, ids], batch)
        self._epoch = e + 1
        return batch

    def __iter__(self) -> Iterator[Dict[str, jax.Array]]:
        while True:
            yield self.epoch_batches()
