"""Sharded checkpointing without external deps (orbax is unavailable here).

Format: one ``.npz`` per save holding every leaf (flattened key-paths as
archive names) + a JSON manifest with treedef, dtypes, shapes and FL
metadata (epoch, topology).  Arrays are gathered to host before writing —
fine at the scales this container runs; on a real pod each host would write
its addressable shards (the manifest layout already carries the pspec
strings needed to re-shard on restore, so swapping the IO layer for a
distributed one does not change the format).

Fault-tolerance path (DESIGN.md §2): ``Checkpointer.restore_dropped`` maps a
checkpoint taken with M servers onto a surviving (M-1)-server topology after
graph surgery — the failed server's clients are orphaned and its model row is
dropped; surviving rows re-index densely.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.topology import FLTopology


def _flatten_with_paths(tree: Any) -> Dict[str, Any]:
    flat = {}

    def rec(prefix, node):
        if isinstance(node, dict):
            for k in sorted(node):
                rec(f"{prefix}/{k}" if prefix else str(k), node[k])
        elif isinstance(node, (tuple, list)):
            for i, v in enumerate(node):
                rec(f"{prefix}/#{i}", v)
        else:
            flat[prefix] = node

    rec("", tree)
    return flat


def _unflatten_from_paths(flat: Dict[str, Any], template: Any) -> Any:
    def rec(prefix, node):
        if isinstance(node, dict):
            return {k: rec(f"{prefix}/{k}" if prefix else str(k), node[k])
                    for k in node}
        if isinstance(node, tuple):
            return tuple(rec(f"{prefix}/#{i}", v) for i, v in enumerate(node))
        if isinstance(node, list):
            return [rec(f"{prefix}/#{i}", v) for i, v in enumerate(node)]
        return flat[prefix]

    return rec("", template)


def save_pytree(path: str, tree: Any, meta: Optional[Dict] = None) -> None:
    flat = {k: np.asarray(v) for k, v in _flatten_with_paths(tree).items()}
    # np.savez cannot serialise ml_dtypes bfloat16 — store the u16 bit
    # pattern under a marker key and view it back on restore
    flat = {(f"__bf16__{k}" if v.dtype == jnp.bfloat16 else k):
            (v.view(np.uint16) if v.dtype == jnp.bfloat16 else v)
            for k, v in flat.items()}
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    tmp = path + ".tmp.npz"
    np.savez(tmp[:-4], **flat)   # np.savez appends .npz
    os.replace(tmp, path)
    manifest = {
        "leaves": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                   for k, v in flat.items()},
        "meta": meta or {},
        "time": time.time(),
    }
    with open(path + ".json", "w") as f:
        json.dump(manifest, f, indent=1)


def restore_pytree(path: str, template: Any) -> Any:
    with np.load(path) as z:
        flat = {}
        for k in z.files:
            if k.startswith("__bf16__"):
                flat[k[len("__bf16__"):]] = z[k].view(jnp.bfloat16)
            else:
                flat[k] = z[k]
    restored = _unflatten_from_paths(flat, template)
    return jax.tree.map(
        lambda t, r: jnp.asarray(r, getattr(t, "dtype", None)), template,
        restored)


@dataclasses.dataclass
class Checkpointer:
    directory: str
    keep: int = 3

    def _path(self, step: int) -> str:
        return os.path.join(self.directory, f"ckpt_{step:08d}.npz")

    def save(self, step: int, tree: Any, meta: Optional[Dict] = None) -> str:
        path = self._path(step)
        save_pytree(path, tree, meta={"step": step, **(meta or {})})
        self._gc()
        return path

    def latest_step(self) -> Optional[int]:
        if not os.path.isdir(self.directory):
            return None
        steps = [int(f[5:13]) for f in os.listdir(self.directory)
                 if f.startswith("ckpt_") and f.endswith(".npz")]
        return max(steps) if steps else None

    def restore(self, template: Any, step: Optional[int] = None) -> Tuple[Any, int]:
        step = self.latest_step() if step is None else step
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        return restore_pytree(self._path(step), template), step

    def _gc(self) -> None:
        if not os.path.isdir(self.directory):
            return
        files = sorted(f for f in os.listdir(self.directory)
                       if f.startswith("ckpt_") and f.endswith(".npz"))
        for f in files[: -self.keep]:
            os.remove(os.path.join(self.directory, f))
            j = os.path.join(self.directory, f + ".json")
            if os.path.exists(j):
                os.remove(j)

    # -- fault tolerance -----------------------------------------------------
    def restore_dropped(self, template: Any, dropped_server: int,
                        old_topo: FLTopology,
                        step: Optional[int] = None) -> Tuple[Any, FLTopology]:
        """Restore a checkpoint from an M-server run into an (M-1)-server
        topology: drop the failed server's row on every (M, N, ...) leaf.
        ``template`` must already have the new (M-1)-sized leading axes."""
        new_topo, keep = old_topo.drop_server(dropped_server)

        # build an M-sized template by re-inserting a dummy row
        def widen(leaf):
            if hasattr(leaf, "shape") and leaf.ndim >= 1 and \
                    leaf.shape[0] == old_topo.num_servers - 1:
                return jnp.zeros((old_topo.num_servers,) + leaf.shape[1:],
                                 leaf.dtype)
            return leaf

        wide_template = jax.tree.map(widen, template)
        restored, _ = self.restore(wide_template, step)

        def narrow(t, r):
            if hasattr(t, "shape") and r.ndim >= 1 and \
                    r.shape[0] == old_topo.num_servers and \
                    t.shape[:1] == (old_topo.num_servers - 1,):
                return r[np.asarray(keep)]
            return r

        return jax.tree.map(narrow, template, restored), new_topo
