import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) and emit
memory / cost / collective analyses (deliverable (e), EXPERIMENTS.md §Dry-run).

The two lines above MUST stay the first statements in this module — jax
locks the device count on first init, and the production meshes need 512
placeholder devices on this 1-CPU container.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-1.7b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all            # 34 sp pairs
    PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod

Each run writes experiments/dryrun/<arch>_<shape>_<sp|mp>.json with the
roofline terms; ``benchmarks/roofline_table.py`` renders the table.
"""
import argparse
import dataclasses

import jax

# Partitionable threefry lets GSPMD shard in-graph RNG with its output.
# Without it every random draw materialises REPLICATED per device — the
# compression layer's stochastic-rounding dither is a full-model-sized
# uniform draw per epoch, measured at +1.5 TB/device temp on
# mixtral-8x22b train_4k (vs +0 with the flag).  Set here, next to the
# device-count override, so every production lowering measures the
# shardable form.
jax.config.update("jax_threefry_partitionable", True)
import json
import time
import traceback
from typing import Optional

import jax

from repro.configs import INPUT_SHAPES, get_arch
from repro.launch import roofline as rl
from repro.launch.specs import build_lowering, supported_pairs

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")


def run_one(arch_id: str, shape_name: str, *, multi_pod: bool = False,
            out_dir: Optional[str] = None, verbose: bool = True,
            save: bool = True, **kw) -> dict:
    t0 = time.time()
    bundle = build_lowering(arch_id, shape_name, multi_pod=multi_pod, **kw)
    lowered = bundle.jitted.lower(*bundle.args)
    t_lower = time.time() - t0
    compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):      # jax returns [dict] per program
        cost = cost[0] if cost else {}
    hlo = compiled.as_text()
    coll = rl.collective_bytes(hlo)
    chips = bundle.mesh.devices.size
    report = rl.roofline(bundle.meta, chips, cost, coll, mem)

    rec = {
        "meta": bundle.meta,
        "mesh_axes": dict(zip(bundle.mesh.axis_names,
                              bundle.mesh.devices.shape)),
        "chips": chips,
        "timing": {"lower_s": round(t_lower, 2),
                   "compile_s": round(t_compile, 2)},
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_per_device": report.bytes_per_device_peak,
        },
        "cost": {k: cost.get(k) for k in ("flops", "bytes accessed")},
        "collectives": {
            "bytes_by_kind": coll.bytes_by_kind,
            "count_by_kind": coll.count_by_kind,
            "total_bytes": coll.total_bytes,
        },
        "roofline": report.row(),
    }
    if verbose:
        hbm = 16e9
        peak = report.bytes_per_device_peak or 0
        print(f"[dryrun] {bundle.name}: compile={t_compile:.1f}s "  # repro: ignore[print-in-library]: CLI verbose report
              f"peak/dev={peak/1e9:.2f} GB ({100*peak/hbm:.0f}% of v5e HBM) "
              f"coll_s={report.collective_s:.3g} "
              f"coll/dev={report.collective_bytes_per_device:.3g}B "
              f"dominant={report.dominant}")
    if save:
        d = out_dir or os.path.abspath(OUT_DIR)
        os.makedirs(d, exist_ok=True)
        tag = "mp" if multi_pod else "sp"
        path = os.path.join(d, f"{arch_id.replace('-', '_')}_{shape_name}_{tag}.json")
        with open(path, "w") as f:
            json.dump(rec, f, indent=1, default=str)
    return rec


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--arch", help="architecture id (e.g. qwen3-1.7b)")
    p.add_argument("--shape", choices=tuple(INPUT_SHAPES),
                   help="input shape name")
    p.add_argument("--all", action="store_true",
                   help="run every supported (arch, shape) pair")
    p.add_argument("--multi-pod", action="store_true",
                   help="2-pod (2,16,16) mesh instead of single-pod (16,16)")
    p.add_argument("--consensus-mode", default=None,
                   choices=("gossip", "gossip_blocked", "gossip_shardmap",
                            "collapsed", "chebyshev", "exact_mean"),
                   help="override the per-plan consensus backend selection "
                        "(plans.DeploymentPlan.consensus_backend)")
    p.add_argument("--out-dir", default=None)
    args = p.parse_args()

    pairs = (supported_pairs() if args.all
             else [(args.arch, args.shape)])
    failures = []
    for arch_id, shape_name in pairs:
        kw = {}
        if shape_name == "train_4k" and args.consensus_mode:
            kw["consensus_mode"] = args.consensus_mode
        try:
            run_one(arch_id, shape_name, multi_pod=args.multi_pod,
                    out_dir=args.out_dir, **kw)
        except Exception as e:  # noqa: BLE001 — report-all then fail
            failures.append((arch_id, shape_name, repr(e)))
            traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} FAILURES:")  # repro: ignore[print-in-library]: CLI entry point
        for a, s, e in failures:
            print(f"  {a} x {s}: {e}")  # repro: ignore[print-in-library]: CLI entry point
        raise SystemExit(1)
    print(f"\nall {len(pairs)} dry-runs compiled OK "  # repro: ignore[print-in-library]: CLI entry point
          f"({'multi-pod' if args.multi_pod else 'single-pod'})")


if __name__ == "__main__":
    main()
