"""Production meshes and their FL refinement (DESIGN.md §2).

``make_production_mesh`` is the assignment-mandated entry point: a 16x16
single-pod (256 chips of TPU v5e) or 2x16x16 two-pod mesh with axes
("data", "model") / ("pod", "data", "model").

``make_fl_mesh`` refines the *replica* axes (pod x data) into the paper's
("server", "client", "replica") structure while keeping "model" as the
tensor-parallel axis: M*N*R == pod*data.  Devices are assigned so a server's
clients are contiguous — in multi-pod, servers never straddle a pod
boundary, which makes ALL cross-pod traffic consensus traffic (the paper's
scarce inter-region bandwidth regime).

Everything here is a function, not a module-level constant: importing this
module never touches jax device state (the dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before first jax use).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


@dataclasses.dataclass(frozen=True)
class FLMeshSpec:
    """How the replica axes factor into the FL structure for one arch.

    M*N*R must equal the product of the production mesh's replica axes
    (pod*data); tp must equal its "model" axis.
    """

    num_servers: int        # M
    clients_per_server: int  # N
    fsdp: int               # R — intra-client weight-shard degree
    tp: int                 # tensor-parallel degree

    @property
    def devices_per_client(self) -> int:
        return self.fsdp * self.tp

    def total_devices(self) -> int:
        return self.num_servers * self.clients_per_server * self.devices_per_client


def make_fl_mesh(spec: FLMeshSpec, *, multi_pod: bool = False
                 ) -> jax.sharding.Mesh:
    """(M, N, R, TP) mesh with axes ("server","client","replica","model").

    Reuses the device order of the production mesh: the leading (pod, data)
    block reshapes to (M, N, R).  M is required to be a multiple of the pod
    count in multi-pod so each server's block lives inside one pod.
    """
    prod = make_production_mesh(multi_pod=multi_pod)
    devices = prod.devices.reshape(-1, prod.devices.shape[-1])  # (replicas, tp)
    replicas, tp = devices.shape
    if spec.tp != tp:
        raise ValueError(f"plan tp={spec.tp} != mesh model axis {tp}")
    if spec.num_servers * spec.clients_per_server * spec.fsdp != replicas:
        raise ValueError(
            f"M*N*R={spec.num_servers}*{spec.clients_per_server}*{spec.fsdp}"
            f" != replica slots {replicas}")
    if multi_pod:
        pods = prod.devices.shape[0]
        if spec.num_servers % pods:
            raise ValueError(
                f"M={spec.num_servers} must be a multiple of pods={pods} so "
                "servers do not straddle pod boundaries")
    grid = devices.reshape(spec.num_servers, spec.clients_per_server,
                           spec.fsdp, tp)
    return jax.sharding.Mesh(grid, ("server", "client", "replica", "model"))


def make_serve_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    """Serving mesh: collapse (pod, data) into one "data" axis — batched
    requests shard over it; weights shard over ("data","model") 2-D."""
    prod = make_production_mesh(multi_pod=multi_pod)
    devices = prod.devices.reshape(-1, prod.devices.shape[-1])
    return jax.sharding.Mesh(devices, ("data", "model"))


def describe(mesh: jax.sharding.Mesh) -> str:
    return (f"mesh {dict(zip(mesh.axis_names, mesh.devices.shape))} "
            f"({mesh.devices.size} devices)")
