"""Launcher: production meshes, sharding resolvers, dry-run, drivers."""
