"""Roofline terms from a compiled dry-run artifact (EXPERIMENTS.md §Roofline).

    compute term    = HLO_FLOPs / (chips * peak_FLOP/s)
    memory term     = HLO_bytes / (chips * HBM_bw)
    collective term = collective_bytes / (chips * link_bw)

``compiled.cost_analysis()`` on an SPMD module reports *per-device* FLOPs
and bytes (the module is the per-partition program), so the terms divide by
peak per-chip rates directly.  Collective bytes are not in cost_analysis:
``collective_bytes`` parses the (per-partition) HLO text and sums the
*result* shapes of every collective op — the bytes a chip receives per
executed instance — weighting all-reduce x2 (ring all-reduce moves
2(n-1)/n ~ 2 bytes per reduced byte).

Ops inside loop bodies execute once per trip: the parser multiplies by the
trip count of the enclosing while-loop when XLA kept it (scan/fori_loop);
``known_trip_counts`` lets the caller scale specific loops (e.g. report a
full T_C epoch from a T_C_dry=2 lowering).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional, Tuple

# TPU v5e, per chip (assignment-specified)
PEAK_FLOPS = 197e12         # bf16
HBM_BW = 819e9              # bytes/s
ICI_BW = 50e9               # bytes/s per link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLL_KINDS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """Sum byte sizes of every typed shape in an HLO result string."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


@dataclasses.dataclass
class CollectiveStats:
    bytes_by_kind: Dict[str, int]
    count_by_kind: Dict[str, int]

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())


def _parse_computations(hlo_text: str) -> Dict[str, list]:
    """Split HLO text into {computation_name: [lines]}."""
    comps: Dict[str, list] = {}
    current = None
    for line in hlo_text.splitlines():
        stripped = line.strip()
        if stripped.endswith("{") and ("->" in stripped or
                                       stripped.startswith("ENTRY")):
            m = re.match(r"(?:ENTRY\s+)?%?([\w.\-]+)", stripped)
            if m:
                current = m.group(1)
                comps[current] = []
            continue
        if stripped == "}":
            current = None
            continue
        if current is not None:
            comps[current].append(line)
    return comps


_CALL_RE = re.compile(
    r"(?:body|to_apply|calls)=\{?%?([\w.\-]+)")
_TRIP_RE = re.compile(r'known_trip_count"?[=:]\s*\{"?n"?[=:]"?(\d+)')


def collective_bytes(hlo_text: str) -> CollectiveStats:
    """Per-device collective traffic from compiled HLO text, loop-aware.

    Builds the computation call graph (while bodies, fusion calls) and
    multiplies each op by the product of enclosing-loop trip counts — XLA
    records counted loops as ``backend_config known_trip_count {n}`` on the
    while op.  Uncounted loops default to 1 (conservative).
    """
    comps = _parse_computations(hlo_text)
    # multiplier per computation, propagated from ENTRY
    entry = next((n for n in comps if "main" in n or n.startswith("entry")),
                 None)
    if entry is None and comps:
        entry = list(comps)[0]
    mult: Dict[str, int] = {}

    def visit(name: str, m: int) -> None:
        if name not in comps:
            return
        mult[name] = mult.get(name, 0) + m
        for line in comps[name]:
            callees = _CALL_RE.findall(line)
            if not callees:
                continue
            trip = 1
            if " while(" in line:
                tm = _TRIP_RE.search(line)
                trip = int(tm.group(1)) if tm else 1
                # the condition runs trips+1 times but holds no collectives
            for callee in set(callees):
                visit(callee, m * trip)

    if entry:
        visit(entry, 1)

    bytes_by_kind = {k: 0 for k in _COLL_KINDS}
    count_by_kind = {k: 0 for k in _COLL_KINDS}
    for name, lines in comps.items():
        m = mult.get(name, 0)
        if m == 0:
            continue
        for line in lines:
            for kind in _COLL_KINDS:
                if f" {kind}(" in line or f" {kind}-start(" in line:
                    lhs = line.split("=", 1)
                    if len(lhs) != 2:
                        continue
                    result = lhs[1].split(kind)[0]
                    nbytes = _shape_bytes(result)
                    if kind == "all-reduce":
                        nbytes *= 2
                    bytes_by_kind[kind] += nbytes * m
                    count_by_kind[kind] += m
    return CollectiveStats(bytes_by_kind, count_by_kind)


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    # analytic terms (the roofline): see ``analytic_terms`` for formulas
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops: float               # 6ND (train) / 2ND (serve), N = active
    analytic_bytes_per_device: float
    collective_bytes_per_device: float
    # HLO-reported references.  NOTE (CPU backend): cost_analysis counts
    # every loop body ONCE (scan/fori trip counts are not multiplied), so
    # these are per-iteration floors, not totals — the analytic terms above
    # are the roofline; these catch gross structural anomalies only.
    hlo_flops_per_device: float
    hlo_bytes_per_device: float
    useful_ratio: float              # model_flops / (hlo_flops x chips) — >1
    #                                  reflects the uncounted loop trips
    bytes_per_device_peak: Optional[float] = None  # memory_analysis

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    def row(self) -> Dict:
        return dataclasses.asdict(self) | {"dominant": self.dominant}


def _cache_bytes(cfg, batch: int, seq: int) -> float:
    """Decode-cache bytes (bf16) for one full forward state."""
    total = 0.0
    for i in range(cfg.num_layers):
        kind = cfg.pattern_for_layer(i)
        if kind == "mamba":
            m = cfg.mamba
            d_in = m.d_inner(cfg.d_model)
            total += batch * (m.num_heads(cfg.d_model) * m.d_state *
                              m.head_dim * 4 +           # ssm state f32
                              (m.d_conv - 1) * (d_in + 2 * m.d_state) * 2)
        elif cfg.mla is not None:
            m = cfg.mla
            total += batch * seq * (m.kv_lora_rank + m.qk_rope_head_dim) * 2
        else:
            n = seq
            if kind == "local" and cfg.sliding_window:
                n = min(seq, cfg.sliding_window)
            total += 2 * batch * n * cfg.num_kv_heads * \
                cfg.resolved_head_dim() * 2
    return total


def analytic_terms(meta: Dict, chips: int) -> Dict[str, float]:
    """Napkin-math compute/memory terms (per device, seconds).

    compute: MODEL_FLOPS / chips / peak, MODEL_FLOPS = 6*N_active*tokens for
    training (fwd+bwd) and 2*N_active*tokens for inference.

    memory (per device):
      train   T_C * (3*n_micro + 2) * P_dev   (per local step: read params +
              read/write grad per microbatch, + update read/write)
              + 2 * T_S * P_dev               (gossip read+write per round)
              + A                             (activation traffic, ~12 bytes
                                               per token-dim per layer,
                                               fwd+bwd with remat)
      prefill P_dev + A + cache write
      decode  P_dev + cache read              (the classic decode bound)
    """
    from repro.configs import get_arch                  # local import: cycle
    cfg = get_arch(meta["arch"])
    active = meta.get("active_params", meta.get("params", 0))
    shape = meta["shape"]
    dtype_b = 2 if meta.get("dtype") == "bfloat16" else 4

    if shape == "train_4k":
        m, n, r, tp = meta["M"], meta["N"], meta["R"], meta["TP"]
        tokens = meta["t_client"] * m * n * meta["per_client_batch"] * 4096
        flops = 6.0 * active * tokens
        p_dev = meta["params"] * dtype_b / (max(r, 1) * tp)
        n_micro = meta.get("grad_microbatches", 1)
        tokens_dev = tokens / (m * n * max(r, 1) * tp)
        act = tokens_dev * cfg.d_model * cfg.num_layers * 12 * dtype_b
        mem = (meta["t_client"] * (3 * n_micro + 2) * p_dev
               + 2 * meta["t_server"] * p_dev + act)
    elif shape == "prefill_32k":
        tokens = meta["batch"] * meta["seq"]
        flops = 2.0 * active * tokens
        shards = chips if meta.get("serve_fsdp") else \
            (chips // meta.get("data", 16) if False else 16)
        p_dev = meta["params"] * 2 / shards
        tokens_dev = tokens / chips
        act = tokens_dev * cfg.d_model * cfg.num_layers * 6 * 2
        cache = _cache_bytes(cfg, meta["batch"], meta["seq"]) / chips
        mem = p_dev + act + cache
    else:                                   # decode (one token)
        tokens = meta["batch"]
        flops = 2.0 * active * tokens
        shards = chips if meta.get("serve_fsdp") else 16
        p_dev = meta["params"] * 2 / shards
        cache = _cache_bytes(cfg, meta["batch"], meta["cache_len"]) / chips
        mem = p_dev + cache
    return {"model_flops": flops,
            "compute_s": flops / chips / PEAK_FLOPS,
            "mem_bytes_dev": mem,
            "memory_s": mem / HBM_BW}


def roofline(meta: Dict, chips: int, cost: Dict, coll: CollectiveStats,
             mem_stats=None) -> RooflineReport:
    hlo_flops = float(cost.get("flops", 0.0))
    hlo_bytes = float(cost.get("bytes accessed", 0.0))
    coll_dev = float(coll.total_bytes)
    terms = analytic_terms(meta, chips)
    peak = None
    if mem_stats is not None:
        peak = float(mem_stats.argument_size_in_bytes +
                     mem_stats.temp_size_in_bytes +
                     mem_stats.output_size_in_bytes -
                     mem_stats.alias_size_in_bytes)
    return RooflineReport(
        arch=meta["arch"], shape=meta["shape"],
        mesh="multi_pod" if meta.get("multi_pod") else "single_pod",
        chips=chips,
        compute_s=terms["compute_s"],
        memory_s=terms["memory_s"],
        collective_s=coll_dev / ICI_BW,
        model_flops=terms["model_flops"],
        analytic_bytes_per_device=terms["mem_bytes_dev"],
        collective_bytes_per_device=coll_dev,
        hlo_flops_per_device=hlo_flops,
        hlo_bytes_per_device=hlo_bytes,
        useful_ratio=(terms["model_flops"] / (hlo_flops * chips)
                      if hlo_flops else 0.0),
        bytes_per_device_peak=peak)
