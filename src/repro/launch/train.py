"""DFL trainer driver (runnable at CPU scale; the full-size path is the
same code lowered by dryrun.py onto the production mesh).

    PYTHONPATH=src python -m repro.launch.train --arch smollm-360m --smoke \
        --servers 2 --clients 2 --t-client 4 --t-server 5 --epochs 3

Runs the paper's Algorithm 1 end to end: T_C local SGD steps per client on
per-client synthetic LM shards, per-server aggregation, T_S gossip rounds,
broadcast — logging loss / server disagreement / client drift (the Lemma 1
and Lemma 3 quantities) every epoch, with checkpointing.
"""
from __future__ import annotations

import argparse
import time
from typing import Optional

import jax
import jax.numpy as jnp

from repro.checkpoint import Checkpointer
from repro.configs import get_arch, get_smoke
from repro.core import DFLConfig, FLTopology, build_dfl_epoch_step, init_dfl_state
from repro.data import DataConfig, FLDataPipeline
from repro.models import transformer as tf
from repro.optim import sgd


def train(arch_id: str, *, smoke: bool = True, servers: int = 2,
          clients: int = 2, t_client: int = 4, t_server: int = 5,
          epochs: int = 3, seq_len: int = 128, per_client_batch: int = 2,
          gamma: float = 0.05, graph: str = "ring",
          consensus_mode: str = "gossip",
          ckpt_dir: Optional[str] = None, seed: int = 0,
          log_every: int = 1, attn_impl: str = "reference") -> dict:
    cfg = get_smoke(arch_id) if smoke else get_arch(arch_id)
    topo = FLTopology(num_servers=servers, clients_per_server=clients,
                      t_client=t_client, t_server=t_server, graph_kind=graph)
    opts = tf.ApplyOptions(remat=False, attn_impl=attn_impl)
    loss_fn = tf.make_loss_fn(cfg, opts)
    optimizer = sgd(gamma)
    dfl_cfg = DFLConfig(topology=topo, consensus_mode=consensus_mode)
    step = jax.jit(build_dfl_epoch_step(dfl_cfg, loss_fn, optimizer),
                   donate_argnums=(0,))

    params = tf.init_params(jax.random.key(seed), cfg)
    state = init_dfl_state(dfl_cfg, params, optimizer, jax.random.key(seed + 1))
    pipe = FLDataPipeline(topo, DataConfig(seq_len=seq_len,
                                           per_client_batch=per_client_batch,
                                           vocab_size=cfg.vocab_size,
                                           seed=seed), arch=cfg)
    ckpt = Checkpointer(ckpt_dir) if ckpt_dir else None
    history = {"loss": [], "disagreement": [], "drift": []}
    t0 = time.time()
    for epoch in range(epochs):
        batches = pipe.epoch_batches(epoch)
        state, metrics = step(state, batches)
        loss = float(metrics.loss[-1].mean())
        dis = float(metrics.server_disagreement)
        drift = float(metrics.client_drift)
        history["loss"].append(loss)
        history["disagreement"].append(dis)
        history["drift"].append(drift)
        if epoch % log_every == 0:
            print(f"epoch {epoch:4d}  loss={loss:.4f}  "
                  f"server_disagreement={dis:.3e}  client_drift={drift:.3e}  "
                  f"({time.time() - t0:.1f}s)")
        if ckpt is not None:
            ckpt.save(epoch, state.client_params,
                      meta={"arch": cfg.name, "epoch": epoch})
    return {"state": state, "history": history, "topology": topo, "cfg": cfg}


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--arch", default="smollm-360m")
    p.add_argument("--smoke", action="store_true", default=True)
    p.add_argument("--full", dest="smoke", action="store_false",
                   help="full-size config (only sensible on a real pod)")
    p.add_argument("--servers", type=int, default=2)
    p.add_argument("--clients", type=int, default=2)
    p.add_argument("--t-client", type=int, default=4)
    p.add_argument("--t-server", type=int, default=5)
    p.add_argument("--epochs", type=int, default=3)
    p.add_argument("--seq-len", type=int, default=128)
    p.add_argument("--batch", type=int, default=2)
    p.add_argument("--gamma", type=float, default=0.05)
    p.add_argument("--graph", default="ring",
                   choices=("ring", "complete", "star", "line", "erdos_renyi"))
    p.add_argument("--consensus-mode", default="gossip",
                   choices=("gossip", "collapsed", "chebyshev", "exact_mean",
                            "none"))
    p.add_argument("--ckpt-dir", default=None)
    args = p.parse_args()
    train(args.arch, smoke=args.smoke, servers=args.servers,
          clients=args.clients, t_client=args.t_client,
          t_server=args.t_server, epochs=args.epochs, seq_len=args.seq_len,
          per_client_batch=args.batch, gamma=args.gamma, graph=args.graph,
          consensus_mode=args.consensus_mode, ckpt_dir=args.ckpt_dir)


if __name__ == "__main__":
    main()
