"""DFL trainer driver (runnable at CPU scale; the full-size path is the
same code lowered by dryrun.py onto the production mesh).

    PYTHONPATH=src python -m repro.launch.train --arch smollm-360m --smoke \
        --servers 2 --clients 2 --t-client 4 --t-server 5 --epochs 3

Runs the paper's Algorithm 1 end to end: T_C local SGD steps per client on
per-client synthetic LM shards, per-server aggregation, T_S gossip rounds,
broadcast — logging loss / server disagreement / client drift (the Lemma 1
and Lemma 3 quantities) every epoch, with checkpointing.
"""
from __future__ import annotations

import argparse
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import Checkpointer
from repro.configs import get_arch, get_smoke
from repro.core import (DFLConfig, FLTopology, build_dfl_epoch_step,
                        init_dfl_state, make_engine, ByzantineSchedule,
                        FaultSchedule, ParticipationSchedule, SigmaTracker,
                        TopologySchedule, load_participation_trace)
from repro.data import DataConfig, FLDataPipeline
from repro.launch import sharding as shd
from repro.models import transformer as tf
from repro.obs import (ConsoleSink, JSONLSink, MetricsHub, Observability,
                       Tracer)
from repro.optim import sgd

CONSENSUS_BACKENDS = ("auto", "einsum", "blocked", "shard_map")


def resolve_consensus_backend(backend: str, consensus_mode: str,
                              topo: FLTopology, params, *,
                              compression: str = "none",
                              error_feedback: bool = False,
                              wire: str = "simulated",
                              staleness: int = 0,
                              ) -> Tuple[str, Optional[object]]:
    """Map the ``--consensus-backend`` CLI flag to the DFLConfig pair
    ``(consensus_mode, consensus_backend)``.

    ``auto`` keeps ``consensus_mode`` as given; ``einsum`` forces the
    per-leaf reference path ('gossip'); ``blocked`` forces the streamed
    'gossip_blocked' path; ``shard_map`` builds the explicit-collective
    ``consensus.ShardMapBackend`` over a ('server',)-axis mesh — that
    needs at least M devices (on CPU set
    ``XLA_FLAGS=--xla_force_host_platform_device_count=M``).
    ``compression``/``error_feedback``/``wire`` only matter for the
    mesh-aware shard_map case (the wrap happens at construction there); the
    string paths are wrapped later by ``dfl.build_dfl_epoch_step`` from
    ``DFLConfig.compression`` / ``DFLConfig.wire``."""
    if backend not in CONSENSUS_BACKENDS:
        raise ValueError(f"unknown consensus backend {backend!r}; choose "
                         f"one of {CONSENSUS_BACKENDS}")
    if backend == "auto":
        return consensus_mode, None
    gossip_family = consensus_mode in ("gossip", "gossip_blocked")
    if not gossip_family:
        raise ValueError(
            f"--consensus-backend {backend} re-executes the T_S-round "
            f"gossip schedule and is undefined for consensus_mode="
            f"{consensus_mode!r}; use --consensus-backend auto there")
    if backend == "einsum":
        return "gossip", None
    if backend == "blocked":
        return "gossip_blocked", None
    m = topo.num_servers
    ndev = jax.device_count()
    if ndev < m:
        raise ValueError(
            f"the shard_map backend gossips over a physical 'server' mesh "
            f"axis of size M={m} but only {ndev} device(s) are visible; "
            f"set XLA_FLAGS=--xla_force_host_platform_device_count={m} "
            f"on CPU")
    mesh = jax.sharding.Mesh(np.array(jax.devices()[:m]).reshape(m),
                             ("server",))
    server_abs = jax.eval_shape(
        lambda p: jax.tree.map(
            lambda x: jnp.zeros((m,) + x.shape, x.dtype), p), params)
    return "gossip", shd.fl_consensus_backend(topo, mesh, server_abs,
                                              tp_axis=None,
                                              compression=compression,
                                              error_feedback=error_feedback,
                                              wire=wire,
                                              staleness=staleness)


def _setup_lm(arch_id, smoke, servers, clients, t_client, t_server, graph,
              gamma, seq_len, per_client_batch, seed, attn_impl,
              mixing="symmetric"):
    """Shared trainer scaffolding: arch config, topology, loss, optimizer,
    data pipeline (used by both the static and the dynamic driver).

    ``mixing`` is the DFLConfig interpretation (symmetric | row_stochastic |
    push_sum); the directed paths need row-stochastic out-degree weights on
    the topology, symmetric gossip needs Metropolis weights."""
    cfg = get_smoke(arch_id) if smoke else get_arch(arch_id)
    topo_mixing = "out_degree" if mixing != "symmetric" else "metropolis"
    topo = FLTopology(num_servers=servers, clients_per_server=clients,
                      t_client=t_client, t_server=t_server, graph_kind=graph,
                      mixing=topo_mixing)
    opts = tf.ApplyOptions(remat=False, attn_impl=attn_impl)
    loss_fn = tf.make_loss_fn(cfg, opts)
    optimizer = sgd(gamma)
    pipe = FLDataPipeline(topo, DataConfig(seq_len=seq_len,
                                           per_client_batch=per_client_batch,
                                           vocab_size=cfg.vocab_size,
                                           seed=seed), arch=cfg)
    return cfg, topo, loss_fn, optimizer, pipe


def _make_observability(*, log_every: int = 1,
                        telemetry_jsonl: Optional[str] = None,
                        chrome_trace: Optional[str] = None,
                        run_info: Optional[dict] = None) -> Observability:
    """The trainers' standard obs bundle: a ConsoleSink (the one place the
    old ``epoch ... loss=...`` prints now live), an optional JSONL
    telemetry stream, an optional span tracer for a Chrome trace export,
    and the convergence watchdogs — see docs/observability.md."""
    hub = MetricsHub([ConsoleSink(log_every=log_every)])
    if telemetry_jsonl:
        hub.add_sink(JSONLSink(telemetry_jsonl, run_info=run_info))
    return Observability(hub=hub,
                         tracer=Tracer() if chrome_trace else None,
                         monitor=True)


def _run_epochs(epochs: int, run_one: Callable[[int], dict],
                obs: Observability, *, observe: bool,
                ckpt_save: Optional[Callable[[int], None]] = None) -> dict:
    """The ONE trainer loop both drivers share (previously each hand-rolled
    its own history accumulation and print formatting): ``run_one(epoch)``
    returns the epoch's record dict, every record flows through the obs
    bundle, and the returned ``history`` keeps its historical shape —
    metric name -> per-epoch list.  ``observe=False`` when ``run_one``
    already observes internally (the dynamic engine's ``run_epoch`` does,
    with per-link / per-server labels and spans the static path lacks)."""
    history: dict = {}
    for epoch in range(epochs):
        if observe:
            with obs.span("epoch", epoch=epoch):
                rec = run_one(epoch)
            obs.observe(epoch, rec)
        else:
            rec = run_one(epoch)
        for k, v in rec.items():
            history.setdefault(k, []).append(v)
        if ckpt_save is not None:
            ckpt_save(epoch)
    return history


def train(arch_id: str, *, smoke: bool = True, servers: int = 2,
          clients: int = 2, t_client: int = 4, t_server: int = 5,
          epochs: int = 3, seq_len: int = 128, per_client_batch: int = 2,
          gamma: float = 0.05, graph: str = "ring",
          consensus_mode: str = "gossip", mixing: str = "symmetric",
          consensus_backend: str = "auto",
          compression: str = "none", error_feedback: bool = False,
          wire: str = "simulated",
          ckpt_dir: Optional[str] = None, seed: int = 0,
          log_every: int = 1, attn_impl: str = "reference",
          telemetry_jsonl: Optional[str] = None,
          chrome_trace: Optional[str] = None) -> dict:
    cfg, topo, loss_fn, optimizer, pipe = _setup_lm(
        arch_id, smoke, servers, clients, t_client, t_server, graph, gamma,
        seq_len, per_client_batch, seed, attn_impl, mixing=mixing)
    params = tf.init_params(jax.random.key(seed), cfg)
    consensus_mode, backend = resolve_consensus_backend(
        consensus_backend, consensus_mode, topo, params,
        compression=compression, error_feedback=error_feedback, wire=wire)
    dfl_cfg = DFLConfig(topology=topo, consensus_mode=consensus_mode,
                        mixing=mixing, consensus_backend=backend,
                        compression=compression,
                        error_feedback=error_feedback, wire=wire)
    step = jax.jit(build_dfl_epoch_step(dfl_cfg, loss_fn, optimizer),
                   donate_argnums=(0,))

    state = init_dfl_state(dfl_cfg, params, optimizer, jax.random.key(seed + 1))
    ckpt = Checkpointer(ckpt_dir) if ckpt_dir else None
    ledger = _make_bytes_tracker(dfl_cfg, params)
    obs = _make_observability(
        log_every=log_every, telemetry_jsonl=telemetry_jsonl,
        chrome_trace=chrome_trace,
        run_info={"arch": cfg.name, "driver": "train", "servers": servers})
    # metric-key parity with the dynamic engine's record (documented in the
    # JSONL schema, docs/observability.md): the static path is the dynamic
    # path with full participation, the fixed graph, and no surgery
    sigma = SigmaTracker(topo.num_servers,
                         mode="push_sum" if mixing == "push_sum"
                         else "average")
    a_np = (topo.mixing_matrix() if topo.num_servers > 1
            else np.ones((1, 1)))

    def run_one(epoch: int) -> dict:
        nonlocal state
        batches = pipe.epoch_batches(epoch)
        state, metrics = step(state, batches)
        record = {
            "loss": float(metrics.loss[-1].mean()),
            "disagreement": float(metrics.server_disagreement),
            "drift": float(metrics.client_drift),
            "participation": 1.0,
            "num_servers": float(topo.num_servers),
            "sigma_prod": sigma.update(a_np, topo.t_server),
        }
        if state.psum_weight is not None:
            record["psum_min_weight"] = float(jnp.min(state.psum_weight))
        if ledger is not None:
            record["wire_mb"] = ledger.update() / 1e6
            record["wire_ratio"] = ledger.tracker.ratio()
        return record

    def ckpt_save(epoch: int) -> None:
        if ckpt is not None:
            ckpt.save(epoch, state.client_params,
                      meta={"arch": cfg.name, "epoch": epoch})

    history = _run_epochs(epochs, run_one, obs, observe=True,
                          ckpt_save=ckpt_save)
    obs.close()
    if chrome_trace:
        obs.tracer.save_chrome(chrome_trace)
    return {"state": state, "history": history, "topology": topo,
            "cfg": cfg, "obs": obs}


class _StaticWireLedger:
    """Static-trainer wire ledger: a ``comm.accounting.BytesTracker`` bound
    to the fixed topology and model shapes (the dynamic engine carries its
    own per-M version)."""

    def __init__(self, dfl_cfg, params, compressor):
        from repro.comm.accounting import (
            BytesTracker, tree_physical_wire_bytes_per_server)
        from repro.comm.compressors import (tree_message_elems,
                                            tree_wire_bytes_per_server)
        from repro.core.dfl import active_wire
        topo = dfl_cfg.topology
        server_abs = jax.eval_shape(
            lambda p: jax.tree.map(
                lambda x: jnp.zeros((topo.num_servers,) + x.shape, x.dtype),
                p), params)
        wire, wire_block = active_wire(dfl_cfg)
        if wire == "physical":
            # the ledger counts the padded per-block codes + scales the
            # collectives actually gather, not the unpadded metadata form
            self._row = tree_physical_wire_bytes_per_server(
                compressor, server_abs, wire_block)
        else:
            self._row = tree_wire_bytes_per_server(compressor, server_abs)
        self._elems = tree_message_elems(server_abs)
        self._a = (topo.mixing_matrix() if topo.num_servers > 1
                   else np.ones((1, 1)))
        self._t_s = topo.t_server
        self.tracker = BytesTracker(compressor,
                                    push_sum=dfl_cfg.mixing == "push_sum")

    def update(self) -> float:
        return self.tracker.update(self._a, self._t_s, row_bytes=self._row,
                                   elems_per_row=self._elems)


def _make_bytes_tracker(dfl_cfg, params) -> Optional[_StaticWireLedger]:
    from repro.core.dfl import active_compressor
    compressor = active_compressor(dfl_cfg)
    if compressor is None:
        return None
    return _StaticWireLedger(dfl_cfg, params, compressor)


def train_dynamic(arch_id: str, *, smoke: bool = True, servers: int = 2,
                  clients: int = 2, t_client: int = 4, t_server: int = 5,
                  epochs: int = 3, seq_len: int = 128, per_client_batch: int = 2,
                  gamma: float = 0.05, graph: str = "ring",
                  consensus_mode: str = "gossip", mixing: str = "symmetric",
                  consensus_backend: str = "auto",
                  compression: str = "none", error_feedback: bool = False,
                  wire: str = "simulated",
                  superepoch: int = 1, staleness: int = 0,
                  participation_rate: float = 1.0,
                  participation_kind: str = "bernoulli",
                  edge_drop_prob: float = 0.0,
                  straggler_weaken: float = 0.0,
                  asymmetric_drop_prob: float = 0.0,
                  faults: str = "",
                  byzantine: str = "",
                  participation_trace: str = "",
                  ckpt_dir: Optional[str] = None,
                  seed: int = 0, log_every: int = 1,
                  attn_impl: str = "reference",
                  telemetry_jsonl: Optional[str] = None,
                  chrome_trace: Optional[str] = None) -> dict:
    """Dynamic-federation LM training: the same Algorithm-1 cycle driven by
    the scenario engine — partial client participation, per-epoch degraded
    server graphs, scheduled server failure/rejoin (``faults`` is the
    ``"drop:EPOCH:SERVER,rejoin:EPOCH:SERVER"`` CLI syntax), and directed
    degradation (``asymmetric_drop_prob`` fails individual link DIRECTIONS
    per epoch; pair it with ``mixing="push_sum"`` for unbiased consensus).

    ``byzantine`` is the ``"sign_flip:0.1,scaled_noise:0.1:10"`` attack-spec
    syntax (``ByzantineSchedule.parse``); pair it with a robust
    ``consensus_mode`` (``trimmed_mean[:f]`` | ``median`` | ``clipped[:mult]``)
    to keep the honest servers converging.  ``participation_trace`` replays a
    recorded JSONL availability log (``load_participation_trace``) instead of
    sampling participation stochastically.

    ``superepoch=K > 1`` fuses K epochs per compiled dispatch (history
    element-identical at any K; checkpoint cadence coarsens to block
    boundaries); ``staleness=s > 0`` lets gossip round t mix codes from
    round t-s, overlapping each round's collective with its compute
    (changes the consensus operator — see docs/dynamic_federation.md)."""
    cfg, topo, loss_fn, optimizer, pipe = _setup_lm(
        arch_id, smoke, servers, clients, t_client, t_server, graph, gamma,
        seq_len, per_client_batch, seed, attn_impl, mixing=mixing)
    params = tf.init_params(jax.random.key(seed), cfg)
    consensus_mode, backend = resolve_consensus_backend(
        consensus_backend, consensus_mode, topo, params,
        compression=compression, error_feedback=error_feedback, wire=wire,
        staleness=staleness)

    if participation_trace:
        part = ParticipationSchedule(
            kind="trace", trace=load_participation_trace(participation_trace))
    elif participation_rate >= 1.0:
        part = ParticipationSchedule()                     # full
    elif participation_kind == "bernoulli":
        part = ParticipationSchedule(kind="bernoulli",
                                     rate=participation_rate, seed=seed)
    else:  # fixed_k / round_robin: rate -> clients per server per epoch
        part = ParticipationSchedule(
            kind=participation_kind,
            k=max(1, round(participation_rate * clients)), seed=seed)
    directed_sched = (asymmetric_drop_prob > 0.0
                      or (straggler_weaken > 0.0 and mixing != "symmetric"))
    if directed_sched:
        # --straggler-weaken composes with the directed schedule: weaken
        # individual link DIRECTIONS (topology.weaken_directed_links)
        # instead of symmetric edges; with --mixing push_sum and no drop
        # prob this is the pure directed-straggler scenario.
        tsched = TopologySchedule(kind="asymmetric",
                                  drop_prob=asymmetric_drop_prob,
                                  weaken=straggler_weaken,
                                  seed=seed + 1)
    elif edge_drop_prob > 0.0:
        tsched = TopologySchedule(kind="edge_drop", drop_prob=edge_drop_prob,
                                  seed=seed + 1)
    elif straggler_weaken > 0.0:
        tsched = TopologySchedule(kind="straggler", weaken=straggler_weaken,
                                  seed=seed + 1)
    else:
        tsched = TopologySchedule()                        # static
    obs = _make_observability(
        log_every=log_every, telemetry_jsonl=telemetry_jsonl,
        chrome_trace=chrome_trace,
        run_info={"arch": cfg.name, "driver": "train_dynamic",
                  "servers": servers})
    engine = make_engine(topo, loss_fn, optimizer,
                         consensus_mode=consensus_mode, mixing=mixing,
                         consensus_backend=backend,
                         compression=compression,
                         error_feedback=error_feedback, wire=wire,
                         participation=part, topology_schedule=tsched,
                         faults=FaultSchedule.parse(faults),
                         byzantine=(ByzantineSchedule.parse(byzantine,
                                                            seed=seed)
                                    if byzantine else None),
                         obs=obs, superepoch=superepoch,
                         staleness=staleness)

    state = init_dfl_state(engine.cfg, params, optimizer,
                           jax.random.key(seed + 1))

    def batch_fn(epoch, alive):
        return pipe.epoch_batches(epoch, server_ids=alive)

    ckpt = Checkpointer(ckpt_dir) if ckpt_dir else None

    def run_one(epoch: int) -> dict:
        nonlocal state
        state, rec = engine.run_epoch(state, epoch, batch_fn)
        return rec

    def ckpt_save(epoch: int) -> None:
        if ckpt is not None:
            ckpt.save(epoch, state.client_params,
                      meta={"arch": cfg.name, "epoch": epoch,
                            "alive": list(engine.alive)})

    if superepoch > 1:
        # superepoch dispatch: the engine runs K-epoch blocks, observing
        # each epoch internally; checkpoint cadence coarsens to block
        # boundaries (the state only materializes host-side post-block —
        # per-epoch saves would all snapshot the block-final state)
        history = {}
        for epoch0, kblk in engine._plan_blocks(epochs):
            state, recs = engine.run_superepoch(state, epoch0, kblk,
                                                batch_fn)
            for rec in recs:
                for k, v in rec.items():
                    history.setdefault(k, []).append(v)
            ckpt_save(epoch0 + kblk - 1)
    else:
        # observe=False: run_epoch observes internally, with the per-link /
        # per-server labels and span structure the host loop cannot see
        history = _run_epochs(epochs, run_one, obs, observe=False,
                              ckpt_save=ckpt_save)
    obs.close()
    if chrome_trace:
        obs.tracer.save_chrome(chrome_trace)
    return {"state": state, "history": history, "engine": engine,
            "cfg": cfg, "obs": obs}


def build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--arch", default="smollm-360m")
    p.add_argument("--smoke", action="store_true", default=True)
    p.add_argument("--full", dest="smoke", action="store_false",
                   help="full-size config (only sensible on a real pod)")
    p.add_argument("--servers", type=int, default=2)
    p.add_argument("--clients", type=int, default=2)
    p.add_argument("--t-client", type=int, default=4)
    p.add_argument("--t-server", type=int, default=5)
    p.add_argument("--epochs", type=int, default=3)
    p.add_argument("--seq-len", type=int, default=128)
    p.add_argument("--batch", type=int, default=2)
    p.add_argument("--gamma", type=float, default=0.05)
    p.add_argument("--graph", default="ring",
                   choices=("ring", "complete", "star", "line", "erdos_renyi",
                            "directed_ring", "random_orientation"))
    p.add_argument("--consensus-mode", default="gossip",
                   help="inter-server mixing: gossip | gossip_blocked | "
                        "collapsed | chebyshev | exact_mean | none, or a "
                        "robust screening variant trimmed_mean[:f] | median "
                        "| clipped[:mult] (validated by "
                        "consensus.make_backend)")
    p.add_argument("--consensus-backend", default="auto",
                   choices=CONSENSUS_BACKENDS,
                   help="consensus execution backend: auto (follow "
                        "--consensus-mode), einsum (per-leaf reference "
                        "gossip), blocked (fixed-block streaming), or "
                        "shard_map (explicit collectives over a physical "
                        "'server' mesh axis; needs >= M devices)")
    p.add_argument("--mixing", default="symmetric",
                   choices=("symmetric", "row_stochastic", "push_sum"),
                   help="consensus interpretation of the mixing matrix: "
                        "symmetric doubly-stochastic gossip (the paper), "
                        "naive row-stochastic gossip (directed, biased), or "
                        "push-sum ratio consensus (directed, unbiased)")
    p.add_argument("--compression", default="none",
                   help="lossy inter-server message compression "
                        "(repro.comm): none | int8[:chunk] | int4[:chunk] "
                        "| top_k:RATIO | random_k:RATIO, e.g. top_k:0.05")
    p.add_argument("--error-feedback", action="store_true",
                   help="carry each server's compression residual and fold "
                        "it into the next period's message (removes the "
                        "bias of top-k/clipping at zero extra wire cost)")
    p.add_argument("--wire", default="simulated",
                   choices=("simulated", "physical"),
                   help="where --compression happens: 'simulated' "
                        "quantizes once per period in-graph (host byte "
                        "ledger, the collectives still move floats); "
                        "'physical' ships int8/packed-int4 codes through "
                        "the collectives themselves, re-quantizing every "
                        "gossip hop (quantizers + gossip/gossip_blocked/"
                        "shard_map backends only)")
    p.add_argument("--superepoch", type=int, default=1,
                   help="epochs fused per compiled dispatch (the megastep "
                        "K): the host loop, schedule generation, and the "
                        "metric readback run once per K epochs; history is "
                        "element-identical at any K (dynamic engine only)")
    p.add_argument("--staleness", type=int, default=0,
                   help="bounded gossip staleness s: round t mixes peer "
                        "codes from round t-s, so each round's collective "
                        "overlaps the next rounds' compute; 0 = the "
                        "synchronous path, bitwise (gossip/gossip_blocked "
                        "modes, and the delta-coded physical wire)")
    p.add_argument("--ckpt-dir", default=None)
    p.add_argument("--log-every", type=int, default=1,
                   help="console epoch-line cadence (ConsoleSink log_every)")
    p.add_argument("--telemetry-jsonl", default=None,
                   help="write the full metric-event stream (schema v1, "
                        "docs/observability.md) to this JSONL path")
    p.add_argument("--chrome-trace", default=None,
                   help="record host-side spans and write a Chrome "
                        "trace-event JSON (load in Perfetto / "
                        "chrome://tracing) to this path")
    dyn = p.add_argument_group(
        "dynamic federation (any of these switches to the scenario engine)")
    dyn.add_argument("--participation-rate", type=float, default=1.0,
                     help="fraction of clients training each epoch (<1 "
                          "enables partial participation)")
    dyn.add_argument("--participation-kind", default="bernoulli",
                     choices=("bernoulli", "fixed_k", "round_robin"))
    dyn.add_argument("--edge-drop-prob", type=float, default=0.0,
                     help="per-epoch probability that each server link fails")
    dyn.add_argument("--straggler-weaken", type=float, default=0.0,
                     help="weight fraction removed from one random link "
                          "per epoch (slow links); with --mixing "
                          "push_sum/row_stochastic or alongside "
                          "--asymmetric-drop-prob it weakens individual "
                          "link DIRECTIONS instead (directed stragglers)")
    dyn.add_argument("--asymmetric-drop-prob", type=float, default=0.0,
                     help="per-epoch probability that each link DIRECTION "
                          "fails independently (directed degradation; "
                          "combine with --mixing push_sum, and optionally "
                          "--straggler-weaken for per-direction weakening)")
    dyn.add_argument("--faults", default="",
                     help="server fault schedule, e.g. 'drop:5:1,rejoin:9:1'")
    dyn.add_argument("--byzantine", default="",
                     help="Byzantine attack schedule, e.g. "
                          "'sign_flip:0.1' or "
                          "'sign_flip:0.1,scaled_noise:0.1:10'; attacked "
                          "servers replace their aggregate before gossip "
                          "(pair with a robust --consensus-mode)")
    dyn.add_argument("--participation-trace", default="",
                     help="JSONL availability-trace path (see "
                          "schedule.save_participation_trace); replays the "
                          "recorded per-epoch client masks instead of "
                          "sampling --participation-rate")
    return p


def main() -> None:
    args = build_parser().parse_args()
    kw = dict(smoke=args.smoke, servers=args.servers, clients=args.clients,
              t_client=args.t_client, t_server=args.t_server,
              epochs=args.epochs, seq_len=args.seq_len,
              per_client_batch=args.batch, gamma=args.gamma,
              graph=args.graph, consensus_mode=args.consensus_mode,
              consensus_backend=args.consensus_backend,
              mixing=args.mixing, compression=args.compression,
              error_feedback=args.error_feedback, wire=args.wire,
              ckpt_dir=args.ckpt_dir, log_every=args.log_every,
              telemetry_jsonl=args.telemetry_jsonl,
              chrome_trace=args.chrome_trace)
    dynamic = (args.participation_rate < 1.0 or args.edge_drop_prob > 0.0
               or args.straggler_weaken > 0.0
               or args.asymmetric_drop_prob > 0.0 or bool(args.faults)
               or bool(args.byzantine) or bool(args.participation_trace)
               # superepoch fusion and bounded staleness live in the
               # dynamic engine / its consensus backends
               or args.superepoch > 1 or args.staleness > 0)
    if dynamic:
        train_dynamic(args.arch,
                      superepoch=args.superepoch, staleness=args.staleness,
                      participation_rate=args.participation_rate,
                      participation_kind=args.participation_kind,
                      edge_drop_prob=args.edge_drop_prob,
                      straggler_weaken=args.straggler_weaken,
                      asymmetric_drop_prob=args.asymmetric_drop_prob,
                      faults=args.faults, byzantine=args.byzantine,
                      participation_trace=args.participation_trace, **kw)
    else:
        train(args.arch, **kw)


if __name__ == "__main__":
    main()
