"""Per-architecture deployment plans (hardware adaptation, DESIGN.md §2).

The paper's protocol requires each client to hold a full model copy; on a
16 GB-HBM v5e that forces a per-arch trade between the number of FL clients
(M*N) and the intra-client shard degree (R*TP):

    bytes/device ~= param_bytes / (R * TP)   (+ grads of the same size
                    + remat'd activations)

Small archs use the paper-like M=4, N=4 (16 clients); the 100B+ archs scale
clients down and FSDP up (M=2, N=1, R=8..16).  dtype is bf16 for the big
archs (mixed-precision deployment; the paper's SGD is stateless so there is
no optimizer-moment memory either way) and f32 for the small ones (matches
the theory-faithful configuration).

``plan_for(arch, multi_pod)`` is the single lookup the launcher uses.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.launch.mesh import FLMeshSpec


@dataclasses.dataclass(frozen=True)
class DeploymentPlan:
    arch_id: str
    # FL refinement of the replica axes (train_4k / the DFL epoch step)
    single_pod: FLMeshSpec
    multi_pod: FLMeshSpec
    param_dtype: str = "float32"      # "float32" | "bfloat16"
    # per-client microbatch is derived: global_batch / (M*N)
    t_client_dry: int = 2             # scan body compiles once; see DESIGN §5
    t_server: int = 25                # the paper's T_S
    # Archs whose head count does not divide the 16-wide "model" axis
    # (smollm: 15 heads, internvl: 14) use the model axis as *intra-client
    # data parallelism* instead of TP: weights replicate (they are tiny),
    # the per-client batch shards 16-way, and the client-local gradient
    # all-reduce rides fast intra-group ICI.
    batch_over_model: bool = False
    # Gradient-accumulation microbatches per local step (DFLConfig pass-
    # through); sized so per-device activations fit alongside params+grads.
    grad_microbatches: int = 1
    # Serving: 2-D (data x model) weight sharding only pays for big models;
    # small ones replicate over "data" — FSDP'd weights + data-sharded
    # batches otherwise fight at every matmul (the partitioner can resolve
    # it only with per-layer gathers it does not always choose).
    serve_fsdp: bool = False
    # Megatron-SP on/off (None = auto: on unless MLA/batch_over_model).
    # §Perf hillclimbs A/B measured SP net-NEGATIVE at per-device batches
    # of 1-2 sequences under full remat (the bwd re-gathers outweigh the
    # boundary-save sharding): command-r -39%, jamba -64% collective with
    # SP off + more grad-accumulation steps.
    seq_parallel: Optional[bool] = None
    # Same knob for the serve/prefill path (mixtral measured -66%
    # collective and -33% peak with SP off at prefill_32k, while its train
    # shape prefers SP for the memory win — the knobs are independent).
    serve_seq_parallel: Optional[bool] = None
    # Consensus execution path for the train shape (the launcher's default;
    # an explicit build_train_lowering(consensus_mode=...) overrides):
    # "gossip_shardmap" = explicit blocked shard_map collectives
    # (consensus.ShardMapBackend — deterministic memory, u16 wire),
    # "gossip_blocked" = pjit blocked streaming, "gossip" = per-leaf einsum.
    consensus_backend: str = "gossip_shardmap"
    # Inter-server message compression for the train shape
    # (DFLConfig.compression, the repro.comm subsystem).  Gossip cost is
    # pure inter-server bandwidth — one full replica per live edge per
    # round — so the 140-400B archs (whose consensus periods ship hundreds
    # of GB per epoch even over a single ring edge) default to int8 with
    # error feedback: ~3.9x fewer wire bytes at a consensus-error cost the
    # compressed_consensus benchmark shows is inside the paper's fig-3
    # tolerance.  Small/mid archs keep the exact paper protocol.
    compression: str = "none"
    error_feedback: bool = False
    # Where the compression happens (DFLConfig.wire).  The 140-400B archs
    # run wire="physical": their consensus backend is gossip_shardmap, so
    # the int8 codes + per-chunk scales are the literal all-gather
    # operands — the 3.9x BytesTracker ratio becomes actual ICI traffic
    # instead of a host-side ledger over bf16 collectives.  "simulated"
    # everywhere the wire is exact anyway (compression="none").
    wire: str = "simulated"

    def serve_dtype(self):
        return jnp.bfloat16          # deployment dtype for all archs

    def fl_spec(self, multi_pod: bool) -> FLMeshSpec:
        return self.multi_pod if multi_pod else self.single_pod

    def dtype(self):
        return jnp.bfloat16 if self.param_dtype == "bfloat16" else jnp.float32


_SMALL_SP = FLMeshSpec(num_servers=4, clients_per_server=4, fsdp=1, tp=16)
_SMALL_MP = FLMeshSpec(num_servers=4, clients_per_server=8, fsdp=1, tp=16)
_MID_SP = FLMeshSpec(num_servers=2, clients_per_server=2, fsdp=4, tp=16)
_MID_MP = FLMeshSpec(num_servers=2, clients_per_server=4, fsdp=4, tp=16)
_BIG_SP = FLMeshSpec(num_servers=2, clients_per_server=1, fsdp=8, tp=16)
_BIG_MP = FLMeshSpec(num_servers=2, clients_per_server=1, fsdp=16, tp=16)

PLANS: Dict[str, DeploymentPlan] = {
    # ~0.4-2B: plenty of room -> paper-like 16 clients, f32
    "smollm_360m": DeploymentPlan("smollm_360m", _SMALL_SP, _SMALL_MP,
                                  batch_over_model=True),
    "qwen3_1_7b": DeploymentPlan("qwen3_1_7b", _SMALL_SP, _SMALL_MP),
    "mamba2_780m": DeploymentPlan("mamba2_780m", _SMALL_SP, _SMALL_MP),
    "internvl2_1b": DeploymentPlan("internvl2_1b", _SMALL_SP, _SMALL_MP,
                                   batch_over_model=True),
    "seamless_m4t_large_v2": DeploymentPlan("seamless_m4t_large_v2",
                                            _SMALL_SP, _SMALL_MP),
    # ~27-35B: bf16 + R=2 (1.7-1.9 GB params/device)
    "gemma2_27b": DeploymentPlan("gemma2_27b", _MID_SP, _MID_MP,
                                 param_dtype="bfloat16",
                                 grad_microbatches=8, serve_fsdp=True),
    "command_r_35b": DeploymentPlan("command_r_35b", _MID_SP, _MID_MP,
                                    param_dtype="bfloat16",
                                    grad_microbatches=16, serve_fsdp=True,
                                    seq_parallel=False),
    # 140-400B: bf16 + R=8/16, 2 servers x 1 client (the scalability edge
    # case: DFL still applies — consensus over M=2 is one gossip edge)
    "mixtral_8x22b": DeploymentPlan("mixtral_8x22b", _BIG_SP, _BIG_MP,
                                    param_dtype="bfloat16",
                                    grad_microbatches=16, serve_fsdp=True,
                                    serve_seq_parallel=False,
                                    compression="int8", error_feedback=True,
                                    wire="physical"),
    "deepseek_v2_236b": DeploymentPlan("deepseek_v2_236b", _BIG_SP, _BIG_MP,
                                       param_dtype="bfloat16",
                                       grad_microbatches=16, serve_fsdp=True,
                                       compression="int8",
                                       error_feedback=True,
                                       wire="physical"),
    "jamba_1_5_large_398b": DeploymentPlan("jamba_1_5_large_398b", _BIG_SP,
                                           _BIG_MP, param_dtype="bfloat16",
                                           grad_microbatches=16, serve_fsdp=True,
                                           seq_parallel=False,
                                           serve_seq_parallel=False,
                                           compression="int8",
                                           error_feedback=True,
                                           wire="physical"),
}


def plan_for(arch_id: str) -> DeploymentPlan:
    return PLANS[arch_id.replace("-", "_").replace(".", "_")]
