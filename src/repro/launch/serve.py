"""Serving driver: batched prefill + synchronous decode loop.

Runnable at CPU scale against smoke configs; the production-mesh variant of
the same two programs (prefill / serve_step) is what dryrun.py lowers for
prefill_32k / decode_32k / long_500k.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b \
        --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs import get_arch, get_smoke
from repro.models import transformer as tf


def sample_token(logits: jax.Array, rng: jax.Array, *,
                 temperature: float = 0.0) -> jax.Array:
    """Greedy (T=0) or temperature sampling. logits: (b, 1, v) -> (b, 1)."""
    if temperature <= 0.0:
        return jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    scaled = logits[:, -1].astype(jnp.float32) / temperature
    return jax.random.categorical(rng, scaled)[:, None].astype(jnp.int32)


def serve(arch_id: str, *, smoke: bool = True, batch: int = 4,
          prompt_len: int = 32, gen: int = 16, max_len: Optional[int] = None,
          temperature: float = 0.0, seed: int = 0,
          cache_dtype=jnp.float32) -> Dict:
    cfg = get_smoke(arch_id) if smoke else get_arch(arch_id)
    max_len = max_len or (prompt_len + gen)
    key = jax.random.key(seed)
    params = tf.init_params(key, cfg)
    opts = tf.ApplyOptions(remat=False, moe_no_drop=True)

    bkey, skey = jax.random.split(jax.random.fold_in(key, 1))
    prompt = {"tokens": jax.random.randint(bkey, (batch, prompt_len), 0,
                                           cfg.vocab_size, jnp.int32)}
    if cfg.frontend is not None:
        n = cfg.frontend.num_tokens or prompt_len
        name = ("patch_embeds" if cfg.frontend.kind == "vision_patches"
                else "frames")
        prompt[name] = jax.random.normal(
            jax.random.fold_in(key, 2), (batch, n, cfg.d_model)) * 0.02

    prefill = jax.jit(lambda p, b: tf.prefill(p, cfg, b, max_len=max_len,
                                              cache_dtype=cache_dtype,
                                              opts=opts))
    decode = jax.jit(lambda p, t, c: tf.decode_step(p, cfg, t, c),
                     donate_argnums=(2,))

    t0 = time.time()
    logits, cache = prefill(params, prompt)
    jax.block_until_ready(logits)
    t_prefill = time.time() - t0

    tokens = [sample_token(logits, skey, temperature=temperature)]
    t0 = time.time()
    for i in range(gen - 1):
        logits, cache = decode(params, tokens[-1], cache)
        skey = jax.random.fold_in(skey, i)
        tokens.append(sample_token(logits, skey, temperature=temperature))
    jax.block_until_ready(tokens[-1])
    t_decode = time.time() - t0
    out = jnp.concatenate(tokens, axis=1)
    return {"generated": out, "prompt": prompt["tokens"],
            "prefill_s": t_prefill, "decode_s": t_decode,
            "tok_per_s": batch * (gen - 1) / max(t_decode, 1e-9)}


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--arch", default="qwen3-1.7b")
    p.add_argument("--smoke", action="store_true", default=True)
    p.add_argument("--full", dest="smoke", action="store_false")
    p.add_argument("--batch", type=int, default=4)
    p.add_argument("--prompt-len", type=int, default=32)
    p.add_argument("--gen", type=int, default=16)
    p.add_argument("--temperature", type=float, default=0.0)
    args = p.parse_args()
    res = serve(args.arch, smoke=args.smoke, batch=args.batch,
                prompt_len=args.prompt_len, gen=args.gen,
                temperature=args.temperature)
    print(f"prefill: {res['prefill_s']:.2f}s   "  # repro: ignore[print-in-library]: CLI entry point
          f"decode: {res['decode_s']:.2f}s "
          f"({res['tok_per_s']:.1f} tok/s aggregate)")
    print("first generated row:", res["generated"][0].tolist())  # repro: ignore[print-in-library]: CLI entry point


if __name__ == "__main__":
    main()
