"""PartitionSpec resolver: parameter-leaf paths -> shardings.

Weights are sharded two ways on top of the FL (server, client) layout:

* **TP** over the "model" axis — the head / expert / feature dimension the
  leaf's table entry names, with a fallback dimension when the preferred one
  is not divisible by the axis size (e.g. kv-heads=8 on a 16-wide model
  axis: fall back to the head_dim).
* **FSDP** over the "replica" axis (train, R>1) or the "data" axis (serve) —
  a second weight dimension, ZeRO-3 style; XLA inserts the per-layer
  all-gathers.

Rules are *name-keyed and right-aligned*: a leaf path's last weight-name
component selects (tp_dims, fsdp_dims) as negative dim indices, so the same
table covers plain leaves (d, h, hd), scanned stacks (periods, d, h, hd) and
DFL client copies (M, N, periods, d, h, hd).  Any leading dims not claimed
by the table get the *lead spec* — ("server", "client") for DFL state, ()
for serve — and everything else is replicated.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# name -> (tp candidate dims, fsdp candidate dims), negative = from the right
_RULES: Dict[str, Tuple[Tuple[int, ...], Tuple[int, ...]]] = {
    "embed":   ((-2,), (-1,)),
    "head":    ((-1,), (-2,)),
    "w_q":     ((-2,), (-3,)),
    "w_k":     ((-2, -1), (-3,)),
    "w_v":     ((-2, -1), (-3,)),
    "w_o":     ((-3,), (-1,)),
    "b_q":     ((-2,), ()),
    "b_k":     ((-2,), ()),
    "b_v":     ((-2,), ()),
    "gate":    ((-1,), (-2,)),
    "up":      ((-1,), (-2,)),
    "down":    ((-2,), (-1,)),
    # MoE expert tables: expert-parallel first, feature-parallel fallback
    "w_gate":  ((-3, -1), (-2,)),
    "w_up":    ((-3, -1), (-2,)),
    "w_down":  ((-3, -2), (-1,)),
    # MLA
    "w_dq":    ((-1,), (-2,)),
    "w_uq":    ((-2,), (-3,)),
    "w_dkv":   ((), (-2,)),          # shared latent projection: TP-replicated
    "w_ukv":   ((-2,), (-3,)),
    # Mamba
    "in_proj": ((-1,), (-2,)),
    "conv_w":  ((-1,), ()),
    "conv_b":  ((-1,), ()),
    "out_proj": ((-2,), (-1,)),
}
# everything else (norm scales, router, biases, a_log, dt_bias, d_skip,
# scalar counters) is replicated beyond the lead spec.

_LAST_NAME = re.compile(r"([A-Za-z_]\w*)(?:\[|$)")


def _leaf_name(path: Tuple) -> str:
    """Last dict-key component of a tree path."""
    for entry in reversed(path):
        if isinstance(entry, jax.tree_util.DictKey):
            return str(entry.key)
    return ""


def _spec_for_leaf(name: str, ndim: int, shape: Tuple[int, ...],
                   lead: Tuple[Optional[str], ...], tp_axis: Optional[str],
                   tp_size: int, fsdp_axis: Optional[str], fsdp_size: int,
                   mesh_shape: Dict[str, int]) -> P:
    entry = [None] * ndim
    for i, ax in enumerate(lead):
        if i < ndim and ax is not None:
            entry[i] = ax
    n_lead = len(lead)
    tp_dims, fsdp_dims = _RULES.get(name, ((), ()))

    def place(axis: Optional[str], size: int, cands: Sequence[int]) -> None:
        if axis is None or size <= 1:
            return
        for c in cands:
            i = ndim + c
            if i < n_lead or i < 0:
                continue
            if entry[i] is None and shape[i] % size == 0:
                entry[i] = axis
                return

    place(tp_axis, tp_size, tp_dims)
    place(fsdp_axis, fsdp_size, fsdp_dims)
    return P(*entry)


_ATTN_LEAVES = frozenset(
    ("w_q", "w_k", "w_v", "w_o", "b_q", "b_k", "b_v"))


def _tree_specs(tree: Any, lead: Tuple[Optional[str], ...],
                mesh: Mesh, tp_axis: Optional[str],
                fsdp_axis: Optional[str],
                attn_tp: bool = True) -> Any:
    """``attn_tp=False`` replicates the attention projections instead of TP:
    for archs whose head count does not divide the model axis, the hd-dim
    fallback would leave K/V head-dim-sharded and every score contraction
    becomes a (b, h, s, chunk) all-reduce — measured 8.2 TB/device on
    smollm prefill."""
    shape = dict(zip(mesh.axis_names, mesh.devices.shape))
    tp = shape.get(tp_axis, 1) if tp_axis else 1
    fs = shape.get(fsdp_axis, 1) if fsdp_axis else 1

    def leaf_spec(path, leaf):
        if not hasattr(leaf, "ndim") or leaf.ndim == 0:
            return P()
        name = _leaf_name(path)
        use_tp = tp_axis if (attn_tp or name not in _ATTN_LEAVES) else None
        return _spec_for_leaf(name, leaf.ndim, leaf.shape,
                              lead, use_tp, tp, fsdp_axis, fs, shape)

    return jax.tree_util.tree_map_with_path(leaf_spec, tree)


# ---------------------------------------------------------------------------
# public resolvers
# ---------------------------------------------------------------------------


def fl_param_specs(params: Any, mesh: Mesh, *,
                   tp_axis: Optional[str] = "model") -> Any:
    """DFL client params: leaves (M, N, *w) on the FL mesh."""
    return _tree_specs(params, ("server", "client"), mesh,
                       tp_axis=tp_axis, fsdp_axis="replica")


def serve_param_specs(params: Any, mesh: Mesh, *,
                      fsdp: bool = True, attn_tp: bool = True) -> Any:
    """Serving params on the ("data","model") mesh: TP over "model" always;
    2-D (FSDP over "data") only when ``fsdp`` — small models replicate over
    "data" instead (weight-gather traffic isn't worth <2 GB of savings, and
    FSDP'd weights fight data-sharded batches at every matmul)."""
    return _tree_specs(params, (), mesh, tp_axis="model",
                       fsdp_axis="data" if fsdp else None, attn_tp=attn_tp)


def fl_batch_spec(mesh: Mesh, batch_div_replica: bool,
                  batch_over_model: bool = False) -> P:
    """Per-epoch batch leaves (T_C, M, N, b, ...)."""
    axes = []
    if batch_div_replica:
        axes.append("replica")
    if batch_over_model:
        axes.append("model")
    b_axis = tuple(axes) if axes else None
    return P(None, "server", "client", b_axis)


def fl_state_specs(state: Any, mesh: Mesh, *,
                   tp_axis: Optional[str] = "model") -> Any:
    """Shardings for a DFLState pytree (params + opt + scalars).

    The compression error-feedback residual (``DFLState.ef_residual``) is
    SERVER-level wire state — leaves ``(M, *w)`` with no client axis — so
    it gets the ``('server',)`` lead of the server aggregates rather than
    the client grid lead (which would scatter a weight dim over the
    'client' mesh axis)."""
    specs = _tree_specs(state, ("server", "client"), mesh,
                        tp_axis=tp_axis, fsdp_axis="replica")
    ef = getattr(state, "ef_residual", None)
    if ef is not None and hasattr(specs, "_replace"):
        specs = specs._replace(ef_residual=_tree_specs(
            ef, ("server",), mesh, tp_axis=tp_axis, fsdp_axis="replica"))
    return specs


def fl_server_specs(server_tree: Any, mesh: Mesh, *,
                    tp_axis: Optional[str] = "model") -> Any:
    """Server-aggregate tree (leaves ``(M, *w)``): leading 'server' axis
    plus the same name-keyed TP/FSDP placement as the client tree — the
    leaf specs a shard_map consensus backend gossips over."""
    return _tree_specs(server_tree, ("server",), mesh,
                       tp_axis=tp_axis, fsdp_axis="replica")


def fl_consensus_backend(topo: Any, mesh: Mesh, server_tree: Any, *,
                         tp_axis: Optional[str] = "model",
                         block: Optional[int] = None,
                         compression: str = "none",
                         error_feedback: bool = False,
                         wire: str = "simulated",
                         staleness: int = 0,
                         compression_flat_sharding=None) -> Any:
    """Mesh-aware consensus-backend construction (the production path).

    Builds a ``consensus.ShardMapBackend`` gossiping ``server_tree``-shaped
    aggregates over the mesh's 'server' axis with ``fl_server_specs``
    placement, seeded with the topology's static mixing matrix (a traced
    per-epoch ``A_p`` still overrides it in dynamic mode).  A non-"none"
    ``compression`` spec (``comm.compressors.make_compressor``) wraps the
    result in a ``consensus.CompressedBackend`` — the same wrap
    ``consensus.make_backend`` applies to the string-selected paths, done
    here because the mesh-aware backend never goes through the registry.
    ``wire="physical"`` makes the wrapped shard_map program gather the
    int8 / packed-int4 codes themselves (``ShardMapBackend.wire_runner``)
    instead of simulating the quantization in-graph — in the BUCKETED
    layout: the device's whole local tree rides as one padded code buffer,
    one s8 + one f32 all-gather per round regardless of leaf count
    (``consensus.gossip_scan_wire_bucketed`` is the bit-exact in-graph
    reference; both int8 and packed int4 ship at engine level).
    ``staleness=s > 0`` software-pipelines the wire rounds (consume codes
    from round ``t - s``, so round t's gather overlaps round t's mix) —
    it requires the delta-coded physical wire, i.e. a non-"none"
    ``compression`` AND ``wire="physical"``; the wrapped backends raise
    otherwise (``consensus.ShardMapBackend`` / ``CompressedBackend``).
    Inject the result via
    ``DFLConfig.consensus_backend``; selection between this,
    'gossip_blocked' and plain 'gossip' is per deployment plan
    (``launch.plans.DeploymentPlan.consensus_backend``)."""
    import numpy as np

    from repro.core import consensus as cns

    a_np = (topo.mixing_matrix() if topo.num_servers > 1
            else np.ones((1, 1)))
    specs = fl_server_specs(server_tree, mesh, tp_axis=tp_axis)
    kw = {} if block is None else {"block": block}
    backend = cns.ShardMapBackend(mesh, a_np, topo.t_server, specs,
                                  staleness=staleness, **kw)
    if compression != "none":
        from repro.comm.compressors import make_compressor
        backend = cns.CompressedBackend(
            backend, make_compressor(compression),
            error_feedback=error_feedback,
            flat_sharding=compression_flat_sharding,
            wire=wire)
    return backend


def named(tree_specs: Any, mesh: Mesh) -> Any:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_specs,
                        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# serving cache specs
# ---------------------------------------------------------------------------


def serve_cache_specs(cache: Any, mesh: Mesh, batch: int,
                      attn_tp: bool = True) -> Any:
    """KV / SSM cache shardings.

    batch > 1: shard batch over "data" (heads/features over "model").
    batch == 1 (long_500k): shard the *sequence* dim of length-proportional
    caches over "data" — blockwise/ring-style decode attention; state-shaped
    leaves (SSM) shard heads over "model".
    """
    data = dict(zip(mesh.axis_names, mesh.devices.shape)).get("data", 1)
    model = dict(zip(mesh.axis_names, mesh.devices.shape)).get("model", 1)
    b_axis = "data" if (batch > 1 and batch % data == 0) else None

    def leaf_spec(path, leaf):
        if not hasattr(leaf, "ndim") or leaf.ndim == 0:
            return P()
        name = _leaf_name(path)
        nd = leaf.ndim
        entry = [None] * nd
        # batch dim: caches built under scan carry (periods, b, ...) or
        # (b, ...) — find the dim whose size == batch (first match).
        b_dim = next((i for i, s in enumerate(leaf.shape) if s == batch), None)
        if b_dim is not None and b_axis is not None:
            entry[b_dim] = b_axis
        if name in ("k", "v"):                       # (.., b, n, kvh, hd)
            if leaf.shape[-2] % model == 0:
                entry[nd - 2] = "model"
            elif attn_tp and leaf.shape[-1] % model == 0:
                # hd-sharded cache only when the attention itself is TP'd;
                # otherwise it back-propagates hd-sharding into K/V and
                # every score contraction all-reduces (smollm: 8.3 TB/dev)
                entry[nd - 1] = "model"
            if b_axis is None and batch == 1 and leaf.shape[-3] % data == 0:
                entry[nd - 3] = "data"               # seq-sharded cache
        elif name in ("c_kv", "k_rope"):             # MLA latent (.., b, n, r)
            # latent has no head axis; shard the rank dim over "model"
            # (512/16=32 for deepseek) — the per-layer latent cache at
            # decode_32k is ~250 GB total and must use both mesh axes.
            if leaf.shape[-1] % model == 0:
                entry[nd - 1] = "model"
            if b_axis is None and batch == 1 and leaf.shape[-2] % data == 0:
                entry[nd - 2] = "data"
        elif name == "conv":                         # (.., b, w-1, ch)
            if leaf.shape[-1] % model == 0:
                entry[nd - 1] = "model"
        elif name == "ssm":                          # (.., b, nh, ds, hd)
            if leaf.shape[-3] % model == 0:
                entry[nd - 3] = "model"
        elif name == "pos":                          # (.., b, n)
            if b_axis is None and batch == 1 and leaf.shape[-1] % data == 0:
                entry[nd - 1] = "data"
        elif name in ("cross_k", "cross_v"):         # (.., b, enc, kvh, hd)
            if leaf.shape[-2] % model == 0:
                entry[nd - 2] = "model"
            elif leaf.shape[-1] % model == 0:
                entry[nd - 1] = "model"
        return P(*entry)

    return jax.tree_util.tree_map_with_path(leaf_spec, cache)
