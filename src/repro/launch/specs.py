"""Abstract input specs + jit lowering builders for every (arch x shape).

``input_specs`` returns ShapeDtypeStruct stand-ins (weak-type-correct,
shardable, zero allocation); the ``build_*_lowering`` functions pair them
with the right step function, mesh and shardings, ready for
``.lower(...).compile()`` in the dry-run.

Shape -> program (DESIGN.md §5):
    train_4k     dfl_epoch_step   (the paper's technique)
    prefill_32k  prefill          (full prompt -> KV cache)
    decode_32k   serve_step       (ONE token against a 32k cache)
    long_500k    serve_step       (ONE token against a 524k cache/state)

Modality carve-out: audio/vlm archs get precomputed frame/patch embeddings
(the assignment's stub) as extra batch leaves.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import INPUT_SHAPES, ArchConfig, InputShape, get_arch
from repro.core import (DFLConfig, FLTopology, build_dfl_epoch_step,
                        init_dfl_state, server_mean)
from repro.launch import sharding as shd
from repro.launch.mesh import make_fl_mesh, make_serve_mesh
from repro.launch.plans import DeploymentPlan, plan_for
from repro.models import transformer as tf
from repro.optim import sgd


@dataclasses.dataclass
class LoweringBundle:
    """Everything the dry-run needs for one (arch, shape, mesh) compile."""

    name: str
    mesh: Mesh
    jitted: Any                    # jax.jit-wrapped step
    args: Tuple[Any, ...]          # abstract pytrees for .lower(*args)
    meta: Dict[str, Any]


def _sds(shape, dtype) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(shape, dtype)


def _abstract(fn: Callable) -> Any:
    """eval_shape of a nullary builder (no allocation)."""
    return jax.eval_shape(fn)


# ---------------------------------------------------------------------------
# batch specs (shared by train / prefill)
# ---------------------------------------------------------------------------


def token_batch_specs(cfg: ArchConfig, lead: Tuple[int, ...], seq_len: int,
                      ) -> Dict[str, jax.ShapeDtypeStruct]:
    """Abstract batch leaves for one microbatch with leading dims ``lead``.

    vlm: patch embeddings are prepended, tokens shrink so the total stays
    seq_len.  audio (enc-dec): encoder frames at encoder_len_ratio * seq.
    """
    batch: Dict[str, jax.ShapeDtypeStruct] = {}
    tok_len = seq_len
    if cfg.frontend is not None and cfg.frontend.kind == "vision_patches":
        tok_len = seq_len - cfg.frontend.num_tokens
        batch["patch_embeds"] = _sds(
            lead + (cfg.frontend.num_tokens, cfg.frontend.embed_dim),
            jnp.float32)
    if cfg.encdec is not None:
        enc_len = int(seq_len * cfg.encdec.encoder_len_ratio)
        batch["frames"] = _sds(lead + (enc_len, cfg.d_model), jnp.float32)
    batch["tokens"] = _sds(lead + (tok_len,), jnp.int32)
    return batch


# ---------------------------------------------------------------------------
# train_4k: the DFL epoch step
# ---------------------------------------------------------------------------


def build_train_lowering(arch_id: str, shape: InputShape, *,
                         multi_pod: bool = False,
                         consensus_mode: Optional[str] = None,
                         remat: bool = True,
                         plan: Optional[DeploymentPlan] = None,
                         graph_kind: str = "ring",
                         seq_parallel: Optional[bool] = None) -> LoweringBundle:
    cfg = get_arch(arch_id)
    plan = plan or plan_for(arch_id)
    if plan.compression != "none":
        # the compression layer's stochastic-rounding dither is a full-
        # model-sized in-graph random draw: non-partitionable threefry
        # materialises it REPLICATED per device (measured +1.5 TB/device
        # on mixtral train_4k).  Set where the requirement is created so
        # every consumer of this lowering — not just the dryrun CLI —
        # gets the shardable form.
        jax.config.update("jax_threefry_partitionable", True)
    # consensus execution path: per-plan backend selection unless overridden
    consensus_mode = consensus_mode or plan.consensus_backend
    spec = plan.fl_spec(multi_pod)
    mesh = make_fl_mesh(spec, multi_pod=multi_pod)
    m, n, r = spec.num_servers, spec.clients_per_server, spec.fsdp
    per_client = shape.global_batch // (m * n)
    assert per_client >= 1, (arch_id, shape.name, m, n)
    topo = FLTopology(num_servers=m, clients_per_server=n,
                      t_client=plan.t_client_dry, t_server=plan.t_server,
                      graph_kind=graph_kind, intra_client_replicas=r)
    dtype = plan.dtype()
    # Megatron-style sequence parallelism at stack boundaries (unless the
    # model axis is consumed as intra-client DP for awkward-head archs).
    act_sharding = None
    moe_group_sharding = None
    ssd_head_sharding = None
    attn_head_sharding = None
    # MLA's latent split/up-project chain cannot reconcile seq-sharded
    # residuals with head-sharded attention (the partitioner replicates the
    # (b, s, h, 256) expansion) — deepseek runs batch-parallel + head-TP
    # with NO sequence parallelism; everything else gets Megatron-SP.
    seq_par = (not plan.batch_over_model and cfg.mla is None
               and shape.seq_len % spec.tp == 0)
    if plan.seq_parallel is not None:
        seq_par = plan.seq_parallel
    if seq_parallel is not None:        # perf-iteration override (§Perf)
        seq_par = seq_parallel
    if seq_par:
        act_sharding = NamedSharding(mesh, P(None, "model", None))
        moe_group_sharding = NamedSharding(
            mesh, P(("replica", "model") if r > 1 else "model", None, None))
        # SSD head pinning only composes with seq-sharded residuals; in
        # batch-parallel mode the in_proj split boundaries do not align
        # with the e-dim shards and the constraint forces full re-gathers
        # (measured: jamba 322 -> 901 s collective).
        ssd_head_sharding = NamedSharding(mesh, P(None, None, "model", None))
    elif r > 1:
        # non-SP: groups stay replica-sharded; forcing them over
        # (replica, model) as well measured 3x WORSE on jamba (B3, §Perf) —
        # the expert matmul's own e-sharding already induces the a2a.
        moe_group_sharding = NamedSharding(mesh, P("replica", None, None))
    if not plan.batch_over_model and cfg.num_heads % spec.tp == 0:
        attn_head_sharding = NamedSharding(
            mesh, P(None, None, "model", None))
    if cfg.moe is None:
        moe_groups = 1
    elif cfg.mla is not None:
        moe_groups = max(r, 1)
    else:
        moe_groups = max(r, 1) * spec.tp
    opts = tf.ApplyOptions(remat=remat, act_sharding=act_sharding,
                           moe_groups=moe_groups,
                           moe_group_sharding=moe_group_sharding,
                           ssd_chunk=64 if cfg.mamba is not None else None,
                           ssd_head_sharding=ssd_head_sharding,
                           attn_head_sharding=attn_head_sharding)
    loss_fn = tf.make_loss_fn(cfg, opts)
    optimizer = sgd(1e-3)
    micro = plan.grad_microbatches if per_client % max(
        plan.grad_microbatches, 1) == 0 else 1
    flat_axes = ("replica", "model") if r > 1 else ("model",)
    dfl_cfg = DFLConfig(topology=topo, consensus_mode=consensus_mode,
                        param_dtype=dtype, grad_microbatches=micro,
                        metrics="full" if cfg.param_count() < 5e9 else "light",
                        gossip_flat_sharding=NamedSharding(
                            mesh, P("server", flat_axes)),
                        compression=plan.compression,
                        error_feedback=plan.error_feedback,
                        wire=plan.wire)
    tp_axis = None if plan.batch_over_model else "model"
    if consensus_mode == "gossip_shardmap":
        # explicit blocked shard_map gossip (same math as "gossip"),
        # injected as a mesh-aware ConsensusBackend — wrapped in the plan's
        # compression layer at construction (the registry wrap in
        # make_backend never sees mesh-aware backends)
        params_abs0 = _abstract(
            lambda: tf.init_params(jax.random.key(0), cfg, dtype))
        client_abs = _abstract(lambda: jax.tree.map(
            lambda p: jnp.zeros((m, n) + p.shape, p.dtype), params_abs0))
        server_abs = jax.eval_shape(server_mean, client_abs)
        backend = shd.fl_consensus_backend(
            topo, mesh, server_abs, tp_axis=tp_axis,
            compression=plan.compression,
            error_feedback=plan.error_feedback,
            wire=plan.wire,
            compression_flat_sharding=NamedSharding(
                mesh, P("server", flat_axes)))
        dfl_cfg = dataclasses.replace(dfl_cfg, consensus_mode="gossip",
                                      consensus_backend=backend)
    step = build_dfl_epoch_step(dfl_cfg, loss_fn, optimizer)

    state_abs = _abstract(lambda: init_dfl_state(
        dfl_cfg, tf.init_params(jax.random.key(0), cfg, dtype), optimizer,
        jax.random.key(1)))
    lead = (topo.t_client, m, n, per_client)
    batch_abs = token_batch_specs(cfg, lead, shape.seq_len)

    state_specs = shd.fl_state_specs(state_abs, mesh, tp_axis=tp_axis)
    b_axes = []
    if r > 1 and per_client % r == 0:
        b_axes.append("replica")
    if plan.batch_over_model and per_client % (max(r, 1) * spec.tp) == 0:
        b_axes.append("model")
    bspec = P(None, "server", "client", tuple(b_axes) if b_axes else None)
    batch_specs = jax.tree.map(lambda _: bspec, batch_abs)

    jitted = jax.jit(
        step,
        in_shardings=(shd.named(state_specs, mesh),
                      shd.named(batch_specs, mesh)),
        out_shardings=(shd.named(state_specs, mesh), None),
        donate_argnums=(0,),
    )
    return LoweringBundle(
        name=f"{arch_id}:{shape.name}:{'mp' if multi_pod else 'sp'}",
        mesh=mesh, jitted=jitted, args=(state_abs, batch_abs),
        meta={"arch": arch_id, "shape": shape.name, "multi_pod": multi_pod,
              "M": m, "N": n, "R": r, "TP": spec.tp,
              "per_client_batch": per_client, "t_client": topo.t_client,
              "t_server": topo.t_server, "dtype": plan.param_dtype,
              "grad_microbatches": micro,
              "consensus_mode": consensus_mode,
              "params": cfg.param_count(),
              "active_params": cfg.active_param_count()})


# ---------------------------------------------------------------------------
# serve shapes: prefill / decode
# ---------------------------------------------------------------------------


def _serve_params_abs(cfg: ArchConfig, dtype) -> Any:
    return _abstract(lambda: tf.init_params(jax.random.key(0), cfg, dtype))


def build_prefill_lowering(arch_id: str, shape: InputShape, *,
                           multi_pod: bool = False,
                           plan: Optional[DeploymentPlan] = None,
                           remat: bool = True) -> LoweringBundle:
    cfg = get_arch(arch_id)
    plan = plan or plan_for(arch_id)
    mesh = make_serve_mesh(multi_pod=multi_pod)
    dtype = plan.serve_dtype()
    data, tp = mesh.devices.shape
    b_div = shape.global_batch % data == 0
    heads_shardable = cfg.num_heads % tp == 0 or cfg.mamba is not None
    act_sharding = None
    moe_group_sharding = None
    ssd_head_sharding = None
    seq_par = (shape.seq_len % tp == 0 and heads_shardable
               and cfg.mla is None)
    if plan.serve_seq_parallel is not None:
        seq_par = plan.serve_seq_parallel
    if seq_par:
        act_sharding = NamedSharding(
            mesh, P("data" if b_div else None, "model", None))
        moe_group_sharding = NamedSharding(
            mesh, P(("data", "model") if b_div else "model", None, None))
    elif b_div:
        # keep at least the batch axis pinned — without it the chunked-
        # attention scan state drifts to replicated and every chunk step
        # re-gathers (smollm prefill measured 8.3 TB/device of gathers)
        act_sharding = NamedSharding(mesh, P("data", None, None))
        if cfg.moe is not None:
            moe_group_sharding = NamedSharding(mesh, P("data", None, None))
    if cfg.mamba is not None:
        ssd_head_sharding = NamedSharding(
            mesh, P("data" if b_div else None, None, "model", None))
    attn_head_sharding = None
    if cfg.num_heads % tp == 0:
        attn_head_sharding = NamedSharding(
            mesh, P("data" if b_div else None, None, "model", None))
    if cfg.moe is None:
        moe_groups = 1
    elif cfg.mla is not None:
        moe_groups = data if b_div else 1
    else:
        moe_groups = data * tp if b_div else tp
    opts = tf.ApplyOptions(remat=remat, act_sharding=act_sharding,
                           moe_groups=moe_groups,
                           moe_group_sharding=moe_group_sharding,
                           ssd_chunk=128 if cfg.mamba is not None else None,
                           ssd_head_sharding=ssd_head_sharding,
                           attn_head_sharding=attn_head_sharding)
    params_abs = _serve_params_abs(cfg, dtype)
    batch_abs = token_batch_specs(cfg, (shape.global_batch,), shape.seq_len)

    param_specs = shd.serve_param_specs(params_abs, mesh,
                                        fsdp=plan.serve_fsdp,
                                        attn_tp=cfg.num_heads % tp == 0)
    b_axis = "data" if b_div else None
    batch_specs = jax.tree.map(lambda _: P(b_axis), batch_abs)

    def prefill_step(params, batch):
        return tf.prefill(params, cfg, batch, max_len=shape.seq_len,
                          cache_dtype=jnp.bfloat16, opts=opts)

    # pin the output KV cache shardings (batch over data, heads/latent over
    # model) — otherwise the 59-layer latent cache materialises unsharded
    cache_abs = jax.eval_shape(prefill_step, params_abs, batch_abs)[1]
    cache_out_specs = shd.serve_cache_specs(cache_abs, mesh,
                                            shape.global_batch,
                                            attn_tp=cfg.num_heads % tp == 0)

    jitted = jax.jit(
        prefill_step,
        in_shardings=(shd.named(param_specs, mesh),
                      shd.named(batch_specs, mesh)),
        out_shardings=(None, shd.named(cache_out_specs, mesh)),
    )
    return LoweringBundle(
        name=f"{arch_id}:{shape.name}:{'mp' if multi_pod else 'sp'}",
        mesh=mesh, jitted=jitted, args=(params_abs, batch_abs),
        meta={"arch": arch_id, "shape": shape.name, "multi_pod": multi_pod,
              "batch": shape.global_batch, "seq": shape.seq_len,
              "dtype": "bfloat16", "serve_fsdp": plan.serve_fsdp,
              "params": cfg.param_count(),
              "active_params": cfg.active_param_count()})


def build_decode_lowering(arch_id: str, shape: InputShape, *,
                          multi_pod: bool = False,
                          plan: Optional[DeploymentPlan] = None
                          ) -> LoweringBundle:
    cfg = get_arch(arch_id)
    plan = plan or plan_for(arch_id)
    mesh = make_serve_mesh(multi_pod=multi_pod)
    dtype = plan.serve_dtype()
    b = shape.global_batch
    params_abs = _serve_params_abs(cfg, dtype)
    cache_abs = _abstract(lambda: tf.init_cache(cfg, b, shape.seq_len,
                                                jnp.bfloat16))
    token_abs = _sds((b, 1), jnp.int32)

    # decode keeps the hd-sharded K/V fallback even for non-divisible head
    # counts: with a single query the per-step score all-reduce is ~16 MB
    # per layer (vs prefill's 8 TB storm), while a replicated 32k cache
    # costs ~40 GB/device (measured) — the trade flips between the shapes.
    param_specs = shd.serve_param_specs(
        params_abs, mesh, fsdp=plan.serve_fsdp, attn_tp=True)
    cache_specs = shd.serve_cache_specs(cache_abs, mesh, b, attn_tp=True)
    data = mesh.devices.shape[0]
    tok_spec = P("data" if b % data == 0 else None, None)

    def serve_step(params, token, cache):
        return tf.decode_step(params, cfg, token, cache)

    jitted = jax.jit(
        serve_step,
        in_shardings=(shd.named(param_specs, mesh),
                      NamedSharding(mesh, tok_spec),
                      shd.named(cache_specs, mesh)),
        out_shardings=(None, shd.named(cache_specs, mesh)),
        donate_argnums=(2,),
    )
    return LoweringBundle(
        name=f"{arch_id}:{shape.name}:{'mp' if multi_pod else 'sp'}",
        mesh=mesh, jitted=jitted, args=(params_abs, token_abs, cache_abs),
        meta={"arch": arch_id, "shape": shape.name, "multi_pod": multi_pod,
              "batch": b, "cache_len": shape.seq_len,
              "dtype": "bfloat16", "serve_fsdp": plan.serve_fsdp,
              "params": cfg.param_count(),
              "active_params": cfg.active_param_count()})


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------


def supported_pairs() -> Tuple[Tuple[str, str], ...]:
    """All (arch, shape) pairs this system runs (34: 10x3 + 4 long-context).

    Skips are per DESIGN.md §4: long_500k only for archs with bounded or
    shardable-at-500k decode state."""
    from repro.configs import ARCH_IDS
    pairs = []
    for arch_id in ARCH_IDS:
        cfg = get_arch(arch_id)
        for shape_name in ("train_4k", "prefill_32k", "decode_32k"):
            pairs.append((arch_id, shape_name))
        if cfg.supports_long_context:
            pairs.append((arch_id, "long_500k"))
    return tuple(pairs)


def build_lowering(arch_id: str, shape_name: str, *, multi_pod: bool = False,
                   **kw) -> LoweringBundle:
    shape = INPUT_SHAPES[shape_name]
    if shape.kind == "train":
        return build_train_lowering(arch_id, shape, multi_pod=multi_pod, **kw)
    if shape.kind == "prefill":
        return build_prefill_lowering(arch_id, shape, multi_pod=multi_pod, **kw)
    cfg = get_arch(arch_id)
    if shape.name == "long_500k" and not cfg.supports_long_context:
        raise ValueError(
            f"{arch_id} skips long_500k: {cfg.long_context_skip_reason}")
    return build_decode_lowering(arch_id, shape, multi_pod=multi_pod, **kw)
