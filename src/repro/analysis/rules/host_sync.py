"""``host-sync-in-jit`` and ``traced-branch`` — host round-trips and
Python control flow inside compiled bodies.

Both rules run only over function bodies the file DEMONSTRABLY compiles
(``rules.common.compiled_contexts``): jit-decorated defs (including the
``functools.partial(jax.jit, static_argnames=...)`` idiom, whose static
names are exempt) and functions/lambdas handed to ``jax.jit`` /
``lax.scan`` / ``lax.fori_loop`` / ``lax.while_loop`` / ``lax.cond`` at a
call site in the same file.

``host-sync-in-jit`` flags ``.item()`` / ``.tolist()`` / ``np.asarray`` /
``np.array`` anywhere in such a body (under tracing these either fail or
silently constant-fold a stale value), and ``float()`` / ``int()`` /
``bool()`` applied to a traced parameter (they force a device sync —
inside jit, a ConcretizationTypeError at best).

``traced-branch`` flags ``if``/``while`` whose test reads a traced
parameter with a value comparison or truthiness — the branch freezes at
trace time.  Structural tests are exempt: ``is``/``is not`` (pytree
structure, e.g. ``if sched.byz is None``), ``isinstance``, and ``len()``
(static under tracing)."""
from __future__ import annotations

import ast
from typing import List, Set

from repro.analysis.lint import FileContext, Finding, rule
from repro.analysis.rules.common import (compiled_contexts, dotted_name,
                                         root_name, walk_scope)

_HOST_METHODS = {"item", "tolist"}
_NUMPY_FUNCS = {"asarray", "array"}
_NUMPY_MODULES = {"np", "numpy", "onp"}
_CASTS = {"float", "int", "bool", "complex"}


def _reads_traced(node: ast.AST, traced: Set[str]) -> bool:
    for n in ast.walk(node):
        if isinstance(n, ast.Name) and isinstance(n.ctx, ast.Load) \
                and n.id in traced:
            return True
    return False


@rule("host-sync-in-jit",
      "a host-synchronizing call (.item/.tolist/np.asarray/float(traced)) "
      "inside a jit-compiled or scanned body")
def check_host_sync(ctx: FileContext):
    findings: List[Finding] = []
    for cc in compiled_contexts(ctx.tree):
        for node in walk_scope(cc.fn):
            if not isinstance(node, ast.Call):
                continue
            if isinstance(node.func, ast.Attribute) \
                    and node.func.attr in _HOST_METHODS \
                    and not node.args:
                findings.append(ctx.finding(
                    "host-sync-in-jit", node,
                    f".{node.func.attr}() inside a compiled body "
                    f"({cc.via}) forces a host sync — keep it a traced "
                    f"array, or move the read outside the compiled step"))
                continue
            fname = dotted_name(node.func)
            if fname is not None and "." in fname:
                head, tail = fname.rsplit(".", 1)
                if tail in _NUMPY_FUNCS and head in _NUMPY_MODULES:
                    findings.append(ctx.finding(
                        "host-sync-in-jit", node,
                        f"{fname}(...) inside a compiled body ({cc.via}) "
                        f"materialises on the host — use jnp inside "
                        f"compiled code"))
                    continue
            if isinstance(node.func, ast.Name) \
                    and node.func.id in _CASTS and node.args \
                    and _reads_traced(node.args[0], cc.traced_params):
                findings.append(ctx.finding(
                    "host-sync-in-jit", node,
                    f"{node.func.id}() of a traced operand inside a "
                    f"compiled body ({cc.via}) — a ConcretizationType"
                    f"Error in waiting; keep the value abstract"))
    return findings


@rule("traced-branch",
      "Python if/while branching on a traced operand inside a compiled "
      "body — the branch freezes at trace time")
def check_traced_branch(ctx: FileContext):
    findings: List[Finding] = []
    for cc in compiled_contexts(ctx.tree):
        for node in walk_scope(cc.fn):
            if not isinstance(node, (ast.If, ast.While)):
                continue
            test = node.test
            if _is_structural(test):
                continue
            if _reads_traced_value(test, cc.traced_params):
                kind = "if" if isinstance(node, ast.If) else "while"
                findings.append(ctx.finding(
                    "traced-branch", node,
                    f"`{kind}` on traced operand inside a compiled body "
                    f"({cc.via}) evaluates ONCE at trace time — use "
                    f"jnp.where / lax.cond / lax.while_loop"))
    return findings


def _is_structural(test: ast.AST) -> bool:
    """Tests that are static under tracing: identity against None,
    isinstance, len(), attribute existence."""
    if isinstance(test, ast.Compare) \
            and all(isinstance(op, (ast.Is, ast.IsNot))
                    for op in test.ops):
        return True
    if isinstance(test, ast.UnaryOp) and isinstance(test.op, ast.Not):
        return _is_structural(test.operand)
    if isinstance(test, ast.BoolOp):
        return all(_is_structural(v) for v in test.values)
    if isinstance(test, ast.Call):
        fname = dotted_name(test.func)
        if fname in ("isinstance", "len", "hasattr", "callable"):
            return True
    return False


def _reads_traced_value(test: ast.AST, traced: Set[str]) -> bool:
    """A traced parameter (or an attribute/subscript of one) appears as a
    VALUE in the test — not merely inside a structural subexpression."""
    for n in ast.walk(test):
        if isinstance(n, (ast.Attribute, ast.Subscript, ast.Name)):
            if isinstance(n, ast.Name) and not isinstance(n.ctx, ast.Load):
                continue
            if root_name(n) in traced:
                return True
    return False
