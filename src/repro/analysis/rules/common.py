"""Shared AST helpers for the lint rules: dotted-name rendering, compiled-
context discovery (jit decorators, ``functools.partial(jax.jit, ...)``,
functions handed to ``jax.jit`` / ``lax.scan`` / ``lax.fori_loop`` /
``lax.while_loop`` / ``lax.cond`` at call sites) and traced-parameter
resolution honouring ``static_argnames``."""
from __future__ import annotations

import ast
import dataclasses
from typing import Dict, Iterator, List, Optional, Set, Tuple


def dotted_name(node: ast.AST) -> Optional[str]:
    """``jax.random.normal`` for a Name/Attribute chain, else None."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def is_jit_callable(node: ast.AST) -> bool:
    """Does this expression denote ``jax.jit`` (or pjit)?  Matches the
    bare names the repo imports under and any ``*.jit`` attribute."""
    name = dotted_name(node)
    if name is None:
        return False
    return name in ("jit", "pjit") or name.endswith(".jit") \
        or name.endswith(".pjit")


def _static_argnames_from_call(call: ast.Call) -> Set[str]:
    names: Set[str] = set()
    for kw in call.keywords:
        if kw.arg == "static_argnames":
            for n in ast.walk(kw.value):
                if isinstance(n, ast.Constant) and isinstance(n.value, str):
                    names.add(n.value)
    return names


def _static_argnums_from_call(call: ast.Call) -> Set[int]:
    nums: Set[int] = set()
    for kw in call.keywords:
        if kw.arg in ("static_argnums", "static_argnum"):
            for n in ast.walk(kw.value):
                if isinstance(n, ast.Constant) and isinstance(n.value, int):
                    nums.add(n.value)
    return nums


@dataclasses.dataclass
class CompiledContext:
    """One function body that ends up inside a compiled program, with the
    parameter names that are TRACED there (static_argnames/argnums
    excluded)."""

    fn: ast.AST                      # FunctionDef | AsyncFunctionDef | Lambda
    traced_params: Set[str]
    via: str                         # what put it in a compiled program


def _params(fn: ast.AST) -> List[ast.arg]:
    a = fn.args
    return list(a.posonlyargs) + list(a.args) + list(a.kwonlyargs)


def _traced_params(fn: ast.AST, static_names: Set[str],
                   static_nums: Set[int]) -> Set[str]:
    out: Set[str] = set()
    for i, p in enumerate(_params(fn)):
        if p.arg in ("self", "cls") or p.arg in static_names:
            continue
        if i in static_nums:
            continue
        out.add(p.arg)
    return out


def _jit_decorator(dec: ast.AST) -> Optional[Tuple[Set[str], Set[int]]]:
    """(static_argnames, static_argnums) if this decorator jits, else
    None.  Handles ``@jax.jit``, ``@jit``, ``@jax.jit(...)`` via partial
    (``@partial(jax.jit, static_argnames=...)``) and direct
    ``@jax.jit(static_argnames=...)`` hmm — jax.jit is not usable that way,
    but partial is the repo idiom (``kernels/ops.py``)."""
    if is_jit_callable(dec):
        return set(), set()
    if isinstance(dec, ast.Call):
        fname = dotted_name(dec.func)
        if fname in ("partial", "functools.partial") and dec.args \
                and is_jit_callable(dec.args[0]):
            return (_static_argnames_from_call(dec),
                    _static_argnums_from_call(dec))
        if is_jit_callable(dec.func):
            return (_static_argnames_from_call(dec),
                    _static_argnums_from_call(dec))
    return None


#: call targets whose function-valued arguments execute inside a compiled
#: program (traced): the control-flow primitives plus jit itself
_COMPILING_CALLS = {
    "scan": "lax.scan", "fori_loop": "lax.fori_loop",
    "while_loop": "lax.while_loop", "cond": "lax.cond",
    "switch": "lax.switch", "checkpoint": "jax.checkpoint",
    "remat": "jax.remat", "vmap": None, "grad": None,
    "value_and_grad": None,
}


def _local_functions(tree: ast.Module) -> Dict[str, ast.AST]:
    """name -> innermost FunctionDef for every def in the file (lint
    granularity: a name collision across scopes resolves to the last def,
    which is fine for a warner)."""
    out: Dict[str, ast.AST] = {}
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            out[node.name] = node
    return out


def compiled_contexts(tree: ast.Module) -> List[CompiledContext]:
    """Every function body the file demonstrably places inside a compiled
    program, each with its traced parameter names."""
    out: List[CompiledContext] = []
    seen: Set[int] = set()
    local = _local_functions(tree)

    def add(fn: ast.AST, traced: Set[str], via: str) -> None:
        if id(fn) in seen:
            return
        seen.add(id(fn))
        out.append(CompiledContext(fn, traced, via))

    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for dec in node.decorator_list:
                got = _jit_decorator(dec)
                if got is not None:
                    add(node, _traced_params(node, *got), "decorator")
        if not isinstance(node, ast.Call):
            continue
        fname = dotted_name(node.func)
        if fname is None:
            continue
        tail = fname.rsplit(".", 1)[-1]
        if is_jit_callable(node.func):
            static_names = _static_argnames_from_call(node)
            static_nums = _static_argnums_from_call(node)
            for arg in node.args[:1]:
                fn = local.get(arg.id) if isinstance(arg, ast.Name) else \
                    (arg if isinstance(arg, ast.Lambda) else None)
                if fn is not None:
                    add(fn, _traced_params(fn, static_names, static_nums),
                        "jax.jit call")
        elif tail in ("scan", "fori_loop", "while_loop", "cond", "switch") \
                and ("lax" in fname or "jax" in fname):
            for arg in node.args:
                fn = local.get(arg.id) if isinstance(arg, ast.Name) else \
                    (arg if isinstance(arg, ast.Lambda) else None)
                if fn is not None:
                    add(fn, _traced_params(fn, set(), set()),
                        f"argument to {fname}")
    return out


def walk_scope(fn: ast.AST) -> Iterator[ast.AST]:
    """Walk a function body WITHOUT descending into nested function/class
    definitions (their params shadow; they get their own context if they
    are compiled)."""
    body = fn.body if isinstance(fn.body, list) else [fn.body]
    stack: List[ast.AST] = list(body)
    while stack:
        node = stack.pop()
        yield node
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.Lambda, ast.ClassDef)):
            continue
        stack.extend(ast.iter_child_nodes(node))


def root_name(node: ast.AST) -> Optional[str]:
    """The base Name of an attribute/subscript chain: ``sched.mask[0]``
    -> ``sched``."""
    while isinstance(node, (ast.Attribute, ast.Subscript)):
        node = node.value
    if isinstance(node, ast.Name):
        return node.id
    return None


def enclosing(node: ast.AST, kinds) -> Optional[ast.AST]:
    """Nearest ancestor of one of the given AST types (via the
    ``repro_parent`` links ``lint._link_parents`` installs)."""
    cur = getattr(node, "repro_parent", None)
    while cur is not None:
        if isinstance(cur, kinds):
            return cur
        cur = getattr(cur, "repro_parent", None)
    return None
