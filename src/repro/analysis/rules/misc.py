"""``mutable-default`` and ``dead-schedule-operand`` — general Python
hygiene with a DFL-specific twist.

``mutable-default``: a list/dict/set (display or constructor call) as a
parameter default is shared across ALL calls — the classic aliasing trap.

``dead-schedule-operand``: a function takes an ``EpochSchedule`` operand
(param named ``sched``/``schedule`` or annotated ``EpochSchedule``) and
never reads it.  A dead schedule operand means the per-epoch mask/mixing
the engine threads in is silently ignored — the dynamic run degenerates to
static while APPEARING to honour the schedule.  Underscore-prefixed params
are exempt (the explicit I-know-it-is-unused spelling)."""
from __future__ import annotations

import ast
from typing import List

from repro.analysis.lint import FileContext, Finding, rule
from repro.analysis.rules.common import dotted_name

_MUTABLE_CALLS = {"list", "dict", "set", "defaultdict", "OrderedDict"}
_SCHED_NAMES = {"sched", "schedule", "epoch_schedule"}


@rule("mutable-default",
      "mutable default argument (list/dict/set) shared across calls")
def check_mutable_default(ctx: FileContext):
    findings: List[Finding] = []
    for node in ast.walk(ctx.tree):
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.Lambda)):
            continue
        for default in list(node.args.defaults) + \
                [d for d in node.args.kw_defaults if d is not None]:
            bad = isinstance(default, (ast.List, ast.Dict, ast.Set))
            if isinstance(default, ast.Call):
                name = dotted_name(default.func) or ""
                bad = name.rsplit(".", 1)[-1] in _MUTABLE_CALLS
            if bad:
                findings.append(ctx.finding(
                    "mutable-default", default,
                    "mutable default argument is created once and shared "
                    "across every call — default to None and construct "
                    "inside the body"))
    return findings


def _is_schedule_param(arg: ast.arg) -> bool:
    if arg.arg.startswith("_"):
        return False
    if arg.arg in _SCHED_NAMES:
        return True
    if arg.annotation is not None:
        ann = dotted_name(arg.annotation) or ""
        if "EpochSchedule" in ann:
            return True
        # string annotations ('EpochSchedule') and subscripted ones
        for n in ast.walk(arg.annotation):
            if isinstance(n, ast.Constant) and isinstance(n.value, str) \
                    and "EpochSchedule" in n.value:
                return True
            if isinstance(n, ast.Name) and "EpochSchedule" in n.id:
                return True
    return False


@rule("dead-schedule-operand",
      "an EpochSchedule parameter is never read — the dynamic run "
      "silently ignores its per-epoch mask/mixing")
def check_dead_schedule(ctx: FileContext):
    findings: List[Finding] = []
    for node in ast.walk(ctx.tree):
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        a = node.args
        params = list(a.posonlyargs) + list(a.args) + list(a.kwonlyargs)
        sched_params = [p for p in params if _is_schedule_param(p)]
        if not sched_params:
            continue
        read = {n.id for n in ast.walk(node)
                if isinstance(n, ast.Name) and isinstance(n.ctx, ast.Load)}
        for p in sched_params:
            if p.arg not in read:
                findings.append(ctx.finding(
                    "dead-schedule-operand", p,
                    f"schedule operand '{p.arg}' of {node.name}() is "
                    f"never read — the per-epoch mask/mixing it carries "
                    f"is dropped; thread it or rename it '_{p.arg}'"))
    return findings
