"""Lint rule modules — importing this package registers every rule in
``analysis.lint.RULES``.  To add a rule, drop a module here that calls
``@lint.rule("name", "description")`` and import it below (walkthrough in
``docs/static_analysis.md``)."""
from repro.analysis.rules import (donation, host_sync, misc,  # noqa: F401
                                  printing, prng, quantization)
