"""``undonated-jit`` — ``jax.jit`` of a full-state epoch step without
``donate_argnums``.

The bug class that cost PR 3 its engine memory budget: jitting
``build_dfl_epoch_step(...)`` (or any ``*epoch_step*`` builder) and
threading the carried ``DFLState`` through it WITHOUT donating arg 0 makes
XLA hold TWO full copies of client params + optimizer state per call — the
old input buffer and the new output.  The rule flags any ``jax.jit(X,
...)`` call site whose first argument is (a call to) an epoch-step
builder/function and which passes neither ``donate_argnums`` nor
``donate_argnames``.

Test files (basename ``test_*``) are exempt BY DESIGN: the suite
deliberately jits undonated steps so the initial state survives for
bitwise re-runs (e.g. the static-vs-dynamic degeneration oracles), and a
suppression on each of ~20 sites would be noise.  The contract auditor
(``analysis.contracts``) covers the other side: it PROVES donation took on
the shipping paths by asserting ``input_output_alias`` in compiled HLO."""
from __future__ import annotations

import ast
import os
from typing import List

from repro.analysis.lint import FileContext, Finding, rule
from repro.analysis.rules.common import dotted_name, is_jit_callable

_DONATE_KWARGS = {"donate_argnums", "donate_argnames"}


def _is_epoch_step_expr(node: ast.AST) -> bool:
    """Does this expression denote an epoch step?  Either a direct call to
    a ``*epoch_step*`` builder (``build_dfl_epoch_step(cfg, ...)``) or a
    bare name containing ``epoch_step``."""
    if isinstance(node, ast.Call):
        name = dotted_name(node.func) or ""
        return "epoch_step" in name.rsplit(".", 1)[-1]
    name = dotted_name(node) or ""
    return "epoch_step" in name.rsplit(".", 1)[-1]


@rule("undonated-jit",
      "jax.jit of an epoch step (full DFLState threaded) without "
      "donate_argnums — holds two copies of the carried state")
def check(ctx: FileContext):
    if os.path.basename(ctx.path).startswith("test_"):
        return []
    findings: List[Finding] = []
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.Call) or not is_jit_callable(node.func):
            continue
        if not node.args or not _is_epoch_step_expr(node.args[0]):
            continue
        if any(kw.arg in _DONATE_KWARGS for kw in node.keywords):
            continue
        findings.append(ctx.finding(
            "undonated-jit", node,
            "jax.jit of an epoch step without donate_argnums: the carried "
            "DFLState is double-buffered (input + output copies) — add "
            "donate_argnums=(0,)"))
    return findings
