"""``print-in-library`` — bare ``print()`` inside the library packages.

Library code (anything under ``src/repro/``) must not write to stdout
directly: ad-hoc prints bypass the ``repro.obs`` sink fan-out (JSONL
telemetry silently loses whatever was printed), interleave with the
sanctioned ``ConsoleSink`` epoch lines, and cannot be silenced by
callers embedding the library.  Route output through a
``MetricsHub`` sink, or — for genuine CLI surfaces like ``__main__``
entry points — suppress with ``# repro: ignore[print-in-library]: why``.

Tests, examples and benchmarks are exempt (the rule only fires on paths
under ``src/repro/``); fixture files stay eligible so the rule's own
good/bad twins under ``tests/fixtures/analysis/`` exercise it."""
from __future__ import annotations

import ast
from pathlib import PurePath
from typing import List

from repro.analysis.lint import FileContext, Finding, rule


def _in_scope(ctx: FileContext) -> bool:
    parts = PurePath(ctx.path).parts
    if "fixtures" in parts:        # the rule's own test fixtures
        return True
    return "src" in parts and "repro" in parts


@rule("print-in-library",
      "bare print() in library code bypasses the repro.obs sinks — "
      "route output through a MetricsHub sink (or suppress at a real "
      "CLI entry point)")
def check_print_in_library(ctx: FileContext):
    findings: List[Finding] = []
    if not _in_scope(ctx):
        return findings
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.Call):
            continue
        fn = node.func
        if isinstance(fn, ast.Name) and fn.id == "print":
            findings.append(ctx.finding(
                "print-in-library", node,
                "bare print() in library code — emit through a "
                "repro.obs sink (ConsoleSink owns the console), or "
                "suppress with '# repro: ignore[print-in-library]: "
                "reason' at a CLI entry point"))
    return findings
