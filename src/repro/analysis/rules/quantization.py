"""``qmax-division`` — raw ``/ qmax`` at a quantization-scale site.

The PR-6 1-ulp rule: quantization scales must be computed as ``absmax *
(1.0 / qmax)``, never ``absmax / qmax``.  XLA CPU rewrites constant
division to reciprocal-multiplication INCONSISTENTLY across program
contexts (notably across the Pallas-kernel / jnp-oracle boundary), so the
two spellings differ by 1 ulp and break the bitwise kernel-vs-oracle
parity tests.  Writing the reciprocal-multiply explicitly pins one
rounding everywhere.

The rule flags any division whose denominator is a name ending in
``qmax`` — UNLESS the numerator is the literal ``1``/``1.0`` (that IS the
blessed reciprocal idiom)."""
from __future__ import annotations

import ast
from typing import List

from repro.analysis.lint import FileContext, Finding, rule
from repro.analysis.rules.common import dotted_name


def _is_qmax(node: ast.AST) -> bool:
    name = dotted_name(node) or ""
    return name.rsplit(".", 1)[-1].endswith("qmax")


@rule("qmax-division",
      "scale computed as `x / qmax` instead of `x * (1.0 / qmax)` — "
      "1-ulp divergence under XLA's inconsistent reciprocal rewrite")
def check(ctx: FileContext):
    findings: List[Finding] = []
    for node in ast.walk(ctx.tree):
        if not (isinstance(node, ast.BinOp)
                and isinstance(node.op, ast.Div)):
            continue
        if not _is_qmax(node.right):
            continue
        if isinstance(node.left, ast.Constant) \
                and node.left.value in (1, 1.0):
            continue                      # the blessed reciprocal constant
        findings.append(ctx.finding(
            "qmax-division", node,
            "dividing by qmax at a scale site: write `* (1.0 / qmax)` — "
            "XLA's division->reciprocal rewrite is context-dependent and "
            "costs 1 ulp of kernel/oracle parity"))
    return findings
