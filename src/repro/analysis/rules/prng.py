"""``key-reuse`` — a PRNG key consumed by two samplers without a
``split``/``fold_in`` between them.

JAX keys are values, not stateful generators: sampling twice with the same
key yields IDENTICAL (or worse, silently correlated) draws.  The rule
tracks, per function scope, every name bound from a key-producing call
(``jax.random.key`` / ``PRNGKey`` / ``split`` / ``fold_in``) and every
sampler call that consumes it; a second consumption without an intervening
rebind is a finding, as is any sampler consuming a loop-invariant key from
inside a loop (the per-iteration draws would all be equal)."""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Tuple

from repro.analysis.lint import FileContext, Finding, rule
from repro.analysis.rules.common import (dotted_name, enclosing, walk_scope)

#: jax.random functions that CONSUME a key to draw values.  ``split`` /
#: ``fold_in`` / ``key_data`` / ``clone`` are deliberately absent: they
#: derive or inspect, they do not draw.
SAMPLERS = frozenset({
    "normal", "uniform", "bernoulli", "randint", "permutation", "choice",
    "categorical", "gumbel", "truncated_normal", "exponential", "laplace",
    "beta", "gamma", "poisson", "dirichlet", "rademacher", "cauchy",
    "logistic", "pareto", "t", "ball", "orthogonal", "loggamma",
    "multivariate_normal", "binomial", "bits",
})

_PRODUCERS = frozenset({"key", "PRNGKey", "split", "fold_in",
                        "wrap_key_data", "clone"})


def _random_call(node: ast.Call) -> Optional[str]:
    """The jax.random function name if this call looks like one (its
    dotted path mentions ``random`` or the common ``jr``/``jrandom``
    aliases), else None."""
    name = dotted_name(node.func)
    if name is None or "." not in name:
        return None
    head, tail = name.rsplit(".", 1)
    if tail not in SAMPLERS and tail not in _PRODUCERS:
        return None
    if "random" in head or head.split(".")[-1] in ("jr", "jrandom"):
        return tail
    return None


def _consumed_key(node: ast.Call) -> Optional[str]:
    """The Name a sampler call consumes as its key (first positional or
    ``key=`` keyword), else None."""
    if node.args and isinstance(node.args[0], ast.Name):
        return node.args[0].id
    for kw in node.keywords:
        if kw.arg == "key" and isinstance(kw.value, ast.Name):
            return kw.value.id
    return None


def _scopes(tree: ast.Module):
    yield tree
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.Lambda)):
            yield node


def _bound_names(target: ast.AST) -> List[str]:
    return [n.id for n in ast.walk(target)
            if isinstance(n, ast.Name) and isinstance(n.ctx, ast.Store)]


def _loop_of(node: ast.AST, scope: ast.AST) -> Optional[ast.AST]:
    """The nearest enclosing for/while INSIDE this scope, else None."""
    loop = enclosing(node, (ast.For, ast.While, ast.FunctionDef,
                            ast.AsyncFunctionDef, ast.Lambda))
    if isinstance(loop, (ast.For, ast.While)) and loop is not scope:
        return loop
    return None


@rule("key-reuse",
      "a PRNG key is consumed by two sampler calls (or by a sampler "
      "inside a loop) without split/fold_in — identical draws")
def check(ctx: FileContext):
    findings: List[Finding] = []
    for scope in _scopes(ctx.tree):
        # (line, kind, name, node): kind 'bind' retires previous uses,
        # 'use' is a sampler consumption
        events: List[Tuple[int, int, str, ast.AST]] = []
        walker = walk_scope(scope) if not isinstance(scope, ast.Module) \
            else ast.iter_child_nodes(scope)
        nodes = []
        if isinstance(scope, ast.Module):
            # module scope: top-level statements only (functions are their
            # own scopes)
            stack = [n for n in scope.body
                     if not isinstance(n, (ast.FunctionDef,
                                           ast.AsyncFunctionDef,
                                           ast.ClassDef))]
            while stack:
                n = stack.pop()
                nodes.append(n)
                if not isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef,
                                      ast.Lambda, ast.ClassDef)):
                    stack.extend(ast.iter_child_nodes(n))
        else:
            nodes = list(walk_scope(scope))

        for node in nodes:
            if isinstance(node, ast.Assign):
                for t in node.targets:
                    for name in _bound_names(t):
                        events.append((node.lineno, 0, name, node))
            elif isinstance(node, (ast.AnnAssign, ast.AugAssign,
                                   ast.NamedExpr)):
                for name in _bound_names(node.target):
                    events.append((node.lineno, 0, name, node))
            elif isinstance(node, ast.For):
                for name in _bound_names(node.target):
                    events.append((node.lineno, 0, name, node))
            elif isinstance(node, ast.Call):
                tail = _random_call(node)
                if tail in SAMPLERS:
                    key = _consumed_key(node)
                    if key is not None:
                        events.append((node.lineno, 1, key, node))

        events.sort(key=lambda e: (e[0], e[1]))
        uses: Dict[str, int] = {}
        first_use_line: Dict[str, int] = {}
        for line, kind, name, node in events:
            if kind == 0:
                uses[name] = 0
                continue
            loop = _loop_of(node, scope)
            if loop is not None:
                # rebind inside the loop body (fold_in idiom) is fine, as
                # is the loop target itself (``for k in split(key, n)``)
                rebinds = isinstance(loop, ast.For) \
                    and name in _bound_names(loop.target)
                rebinds = rebinds or any(
                    isinstance(n, (ast.Assign, ast.AnnAssign, ast.AugAssign,
                                   ast.NamedExpr))
                    and name in sum((_bound_names(t) for t in (
                        n.targets if isinstance(n, ast.Assign)
                        else [n.target])), [])
                    for n in ast.walk(loop))
                if not rebinds:
                    findings.append(ctx.finding(
                        "key-reuse", node,
                        f"PRNG key '{name}' is sampled inside a loop "
                        f"without being rebound — every iteration draws "
                        f"the same values; fold_in the loop index or "
                        f"split before the loop"))
                    continue
            uses[name] = uses.get(name, 0) + 1
            if uses[name] == 1:
                first_use_line[name] = line
            elif uses[name] >= 2:
                findings.append(ctx.finding(
                    "key-reuse", node,
                    f"PRNG key '{name}' already consumed by a sampler at "
                    f"line {first_use_line.get(name, line)} — split or "
                    f"fold_in before reusing it"))
    return findings
