"""Shared static passes over compiled-HLO text.

This module is the ONE place that parses XLA's post-compile HLO dump; the
byte ledger (``comm.accounting.hlo_collective_bytes``), the physical-wire
regression tests (``tests/test_wire.py``), the benchmark cross-checks and
the contract auditor (``analysis.contracts``) all call through here, so a
change in XLA's text format is a one-file fix.

Three passes:

* ``collective_sites`` — every gather/permute collective with its operand
  dtype, shape and RESULT-buffer bytes (the PR-5/6 wire audit, moved here
  from ``comm.accounting`` verbatim).
* ``input_output_alias_pairs`` / ``has_donation`` — the ``{output}: (param,
  ...)`` aliasing map XLA emits in the module header when ``donate_argnums``
  donation actually took: its ABSENCE on a program that claims donation
  means the runtime silently holds two full copies of the carried state
  (the PR-3 engine bug class).
* ``host_callback_sites`` — ``custom-call`` sites whose target is a Python
  host callback (``xla_python_cpu_callback`` and friends): a compiled epoch
  step must contain none, or every step round-trips to the host.

The module deliberately imports nothing from ``repro.core`` / ``repro.comm``
(only ``re`` + numpy) so the comm layer can delegate to it without an
import cycle.
"""
from __future__ import annotations

import re
from typing import Dict, List, Tuple

import numpy as np

# one compiled-HLO collective, sync or async-start form, e.g.
#   %all-gather.3 = s8[4,256]{1,0} all-gather(s8[1,256]{1,0} %x), ...
#   %ag = (s8[1,256], s8[4,256]) all-gather-start(s8[1,256] %x), ...
# (the matching '-done' op is intentionally NOT matched — its result
# aliases the start op's output buffer and would double-count)
_HLO_COLLECTIVE = re.compile(
    r"=\s+(\(?[^=]*?)\s*(all-gather|collective-permute)(-start)?\(")
_HLO_SHAPE = re.compile(r"([a-z][a-z0-9]*)\[([\d,]*)\]")
HLO_DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
                   "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4,
                   "s64": 8, "u64": 8, "f64": 8}

# one entry of the module-header aliasing map XLA writes when donation
# took, e.g.  input_output_alias={ {0}: (0, {}, may-alias), ... } —
# matched entry-wise (the brace nesting makes a whole-map regex fragile):
#   {output tuple index}: (param number, {param tuple index}, kind)
_HLO_ALIAS_ENTRY = re.compile(
    r"\{([\d,\s]*)\}:\s*\((\d+),\s*\{[\d,\s]*\},\s*(may-alias|must-alias)\)")

# a custom-call whose target is a Python host callback (jax.debug.callback
# / io_callback / pure_callback all lower to one of these CPU/FFI targets)
_HLO_HOST_CALLBACK = re.compile(
    r'custom_call_target="([^"]*(?:python|callback)[^"]*)"', re.IGNORECASE)


def collective_sites(hlo_text: str) -> List[Dict[str, object]]:
    """Parse a compiled-HLO dump into its gather/permute collectives:
    ``[{op, dtype, shape, bytes}, ...]`` with ``bytes`` the RESULT buffer
    size (for an all-gather over M participants, each participant ships
    ``bytes / M``).  Handles both the synchronous form and the async
    ``-start`` rewrite (whose result is an (operand, result) tuple — the
    LARGEST element is the gathered buffer).  The dtypes and shapes here
    are what actually crossed the interconnect, and must match the codec's
    ``wire_block_bytes``."""
    out: List[Dict[str, object]] = []
    for m in _HLO_COLLECTIVE.finditer(hlo_text):
        result_types, op = m.group(1), m.group(2)
        best = None
        for dtype, dims in _HLO_SHAPE.findall(result_types):
            if dtype not in HLO_DTYPE_BYTES:
                continue
            shape = tuple(int(x) for x in dims.split(",") if x)
            elems = int(np.prod(shape)) if shape else 1
            nbytes = elems * HLO_DTYPE_BYTES[dtype]
            if best is None or nbytes > best["bytes"]:
                best = {"op": op, "dtype": dtype, "shape": shape,
                        "bytes": nbytes}
        if best is not None:
            out.append(best)
    return out


def input_output_alias_pairs(hlo_text: str) -> List[Tuple[Tuple[int, ...],
                                                          int, str]]:
    """The compiled module's donation map as ``[(output tuple index, param
    number, kind), ...]`` — empty when XLA established no aliasing (either
    nothing was donated, or every donation was refused, e.g. by a
    dtype/layout mismatch between the donated operand and any output)."""
    return [(tuple(int(x) for x in out_idx.split(",") if x.strip()),
             int(param), kind)
            for out_idx, param, kind in _HLO_ALIAS_ENTRY.findall(hlo_text)]


def has_donation(hlo_text: str) -> bool:
    """True iff the compiled program aliases at least one output buffer to
    an input — the observable proof that ``donate_argnums`` actually freed
    the carried state instead of silently double-buffering it."""
    return bool(input_output_alias_pairs(hlo_text))


def host_callback_sites(hlo_text: str) -> List[str]:
    """Custom-call targets that re-enter Python from inside the compiled
    program (one entry per call SITE).  A hot compiled path — an epoch
    step, a gossip round — must return an empty list here."""
    return _HLO_HOST_CALLBACK.findall(hlo_text)
