"""CLI: ``python -m repro.analysis [paths...] [--format text|json]
[--contracts] [--output FILE] [--list-rules]``.

Exit status 0 iff no findings (and, with ``--contracts``, no contract
violations) — the CI ``lint`` job gate."""
from __future__ import annotations

import argparse
import json
import sys
from typing import List

from repro.analysis import lint


def main(argv=None) -> int:
    p = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="JAX-hygiene lint + compiled-program contract audit")
    p.add_argument("paths", nargs="*",
                   help=f"files/dirs to lint (default: "
                        f"{', '.join(lint.DEFAULT_ROOTS)} under cwd)")
    p.add_argument("--format", choices=("text", "json"), default="text")
    p.add_argument("--output", default=None,
                   help="write the report here as well as stdout")
    p.add_argument("--rules", default=None,
                   help="comma-separated rule subset to run")
    p.add_argument("--contracts", action="store_true",
                   help="also lower + audit the DFLConfig contract table "
                        "and the engine retrace detector (slower: compiles "
                        "every cell)")
    p.add_argument("--list-rules", action="store_true")
    args = p.parse_args(argv)

    import repro.analysis.rules  # noqa: F401
    if args.list_rules:
        for r in sorted(lint.RULES.values(), key=lambda r: r.name):
            print(f"{r.name:24s} {r.description}")  # repro: ignore[print-in-library]: CLI report output
        return 0

    rules = args.rules.split(",") if args.rules else None
    findings = lint.lint_paths(args.paths or None, rules)

    contract_report = None
    if args.contracts:
        from repro.analysis import contracts
        results = contracts.audit_table()
        retrace = contracts.audit_engine_retrace()
        contract_report = {
            "cells": [r.to_dict() for r in results],
            "retrace": retrace.to_dict(),
        }
        for r in results:
            for v in r.violations:
                findings.append(lint.Finding(
                    "contract", f"<cell:{r.cell.name}>", 0, 0, v))
        for v in retrace.violations:
            findings.append(lint.Finding(
                "contract", "<engine-retrace>", 0, 0, v))

    if args.format == "json":
        report = {"findings": [f.to_dict() for f in findings],
                  "count": len(findings),
                  "rules": sorted(lint.RULES)}
        if contract_report is not None:
            report["contracts"] = contract_report
        text = json.dumps(report, indent=2)
    else:
        body: List[str] = [f.format() for f in findings]
        body.append(f"{len(findings)} finding(s)")
        if contract_report is not None:
            ncells = len(contract_report["cells"])
            body.append(f"contract table: {ncells} cells audited")
        text = "\n".join(body)
    print(text)  # repro: ignore[print-in-library]: CLI report output
    if args.output:
        with open(args.output, "w") as fh:
            fh.write(text + "\n")
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())
