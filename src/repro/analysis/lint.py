"""The AST lint engine: rule registry, file walking, suppressions.

Rules are project-specific JAX hygiene (``analysis.rules``): the bug
classes that cost PRs 3-6 the most runtime debugging — reused PRNG keys,
host syncs inside compiled bodies, undonated full-state jits, the
division-vs-reciprocal 1-ulp scale trap — each statically detectable from
the AST alone.  A rule is a callable ``check(ctx) -> Iterable[Finding]``
registered under a kebab-case name via the ``@rule`` decorator; the engine
parses each file once and hands every rule the same ``FileContext``.

Suppression grammar (per line, trailing comment)::

    x = a / qmax  # repro: ignore[qmax-division]: not a wire scale site
    y = f(k)      # repro: ignore[key-reuse, host-sync-in-jit]: <reason>

The reason after the second colon is MANDATORY — a reasonless ``ignore``
still suppresses the named rules (so the repo stays one-finding-per-line)
but itself surfaces as a ``bare-suppression`` finding, which cannot be
suppressed.  Suppressions bind to the physical line the finding is
reported on.

``python -m repro.analysis`` is the CLI front end (text / JSON, nonzero
exit on findings); ``lint_paths`` is the library entry the tests use.
"""
from __future__ import annotations

import ast
import dataclasses
import os
import re
import tokenize
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Set, Tuple

#: directory path components never walked: caches, VCS internals, and the
#: seeded-violation fixture files the analysis tests feed to ``lint_file``
#: directly (they contain deliberate findings and must not dirty the repo)
EXCLUDED_PARTS = {"__pycache__", ".git", ".github", "fixtures",
                  ".pytest_cache", "build", "dist"}

#: the repo surfaces ``python -m repro.analysis`` walks by default
DEFAULT_ROOTS = ("src", "tests", "benchmarks", "examples")


@dataclasses.dataclass(frozen=True)
class Finding:
    rule: str
    path: str
    line: int
    col: int
    message: str

    def format(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: [{self.rule}] " \
               f"{self.message}"

    def to_dict(self) -> Dict[str, object]:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class FileContext:
    """Everything a rule gets: one parse, shared by every rule.  The tree
    carries parent links (``node.repro_parent``) so rules can walk UP —
    e.g. 'is this sampler call inside a loop the key was defined outside
    of'."""

    path: str
    src: str
    tree: ast.Module
    lines: List[str]

    def finding(self, rule_name: str, node: ast.AST, message: str) -> Finding:
        return Finding(rule_name, self.path, getattr(node, "lineno", 1),
                       getattr(node, "col_offset", 0), message)


RuleFn = Callable[[FileContext], Iterable[Finding]]


@dataclasses.dataclass(frozen=True)
class Rule:
    name: str
    description: str
    check: RuleFn


#: the registry ``analysis.rules`` populates at import time
RULES: Dict[str, Rule] = {}

_RULE_NAME = re.compile(r"^[a-z][a-z0-9-]*$")


def rule(name: str, description: str) -> Callable[[RuleFn], RuleFn]:
    """Register a lint rule under a kebab-case name (see
    ``docs/static_analysis.md`` for the how-to-add-a-rule walkthrough)."""
    if not _RULE_NAME.match(name):
        raise ValueError(f"rule name {name!r} must be kebab-case")

    def deco(fn: RuleFn) -> RuleFn:
        if name in RULES:
            raise ValueError(f"duplicate rule name {name!r}")
        RULES[name] = Rule(name, description, fn)
        return fn
    return deco


# ---------------------------------------------------------------------------
# suppressions
# ---------------------------------------------------------------------------

_SUPPRESS = re.compile(
    r"#\s*repro:\s*ignore\[([A-Za-z0-9_\-,\s]+)\]\s*(?::\s*(\S.*))?$")


def parse_suppressions(src: str) -> Dict[int, Tuple[Set[str], bool]]:
    """``{line: (rule names, has_reason)}`` for every ``# repro: ignore``
    comment.  Comments are found with the tokenizer, not a per-line regex,
    so the marker inside a string literal is not a suppression."""
    out: Dict[int, Tuple[Set[str], bool]] = {}
    try:
        tokens = tokenize.generate_tokens(iter(src.splitlines(True)).__next__)
        for tok in tokens:
            if tok.type != tokenize.COMMENT:
                continue
            m = _SUPPRESS.search(tok.string)
            if not m:
                continue
            names = {s.strip() for s in m.group(1).split(",") if s.strip()}
            out[tok.start[0]] = (names, m.group(2) is not None)
    except tokenize.TokenizeError:
        pass
    return out


# ---------------------------------------------------------------------------
# the engine
# ---------------------------------------------------------------------------


def _link_parents(tree: ast.Module) -> None:
    for node in ast.walk(tree):
        for child in ast.iter_child_nodes(node):
            child.repro_parent = node  # type: ignore[attr-defined]
    tree.repro_parent = None  # type: ignore[attr-defined]


def lint_file(path: str,
              rules: Optional[Sequence[str]] = None) -> List[Finding]:
    """Run the (selected) rules over one file and apply its suppressions.
    Ensures rules are loaded, parses once, and returns findings sorted by
    location.  A syntactically invalid file yields a single
    ``syntax-error`` finding rather than crashing the walk."""
    import repro.analysis.rules  # noqa: F401  (registers RULES)
    with open(path, encoding="utf-8") as f:
        src = f.read()
    try:
        tree = ast.parse(src, filename=path)
    except SyntaxError as e:
        return [Finding("syntax-error", path, e.lineno or 1, e.offset or 0,
                        f"file does not parse: {e.msg}")]
    _link_parents(tree)
    ctx = FileContext(path=path, src=src, tree=tree,
                      lines=src.splitlines())
    selected = [RULES[r] for r in rules] if rules else list(RULES.values())
    findings: List[Finding] = []
    for r in selected:
        findings.extend(r.check(ctx))

    sup = parse_suppressions(src)
    kept: List[Finding] = []
    for f in findings:
        names, _ = sup.get(f.line, (set(), True))
        if f.rule not in names:
            kept.append(f)
    # a reasonless suppression is itself a finding — and NOT suppressible
    for line, (names, has_reason) in sorted(sup.items()):
        if not has_reason:
            kept.append(Finding(
                "bare-suppression", path, line, 0,
                f"suppression for [{', '.join(sorted(names))}] carries no "
                f"reason — write '# repro: ignore[rule]: why it is a "
                f"false positive'"))
        unknown = names - set(RULES) - {"bare-suppression"}
        if unknown:
            kept.append(Finding(
                "bare-suppression", path, line, 0,
                f"suppression names unknown rule(s) "
                f"{', '.join(sorted(unknown))} — it suppresses nothing"))
    return sorted(kept, key=lambda f: (f.line, f.col, f.rule))


def iter_python_files(roots: Sequence[str]) -> List[str]:
    """Every ``.py`` file under the given roots (files accepted verbatim),
    minus ``EXCLUDED_PARTS`` directories, sorted for stable output."""
    out: List[str] = []
    for root in roots:
        if os.path.isfile(root):
            out.append(root)
            continue
        for dirpath, dirnames, filenames in os.walk(root):
            dirnames[:] = sorted(d for d in dirnames
                                 if d not in EXCLUDED_PARTS)
            out.extend(os.path.join(dirpath, n) for n in sorted(filenames)
                       if n.endswith(".py"))
    return out


def lint_paths(paths: Optional[Sequence[str]] = None,
               rules: Optional[Sequence[str]] = None) -> List[Finding]:
    """Lint a set of files/directories (default: the repo surfaces in
    ``DEFAULT_ROOTS`` that exist under the current directory)."""
    if paths is None:
        paths = [r for r in DEFAULT_ROOTS if os.path.isdir(r)]
    findings: List[Finding] = []
    for path in iter_python_files(list(paths)):
        findings.extend(lint_file(path, rules))
    return findings
