"""Front 2: the declarative compiled-program contract auditor.

``CONTRACT_TABLE`` enumerates supported cells of the ``DFLConfig`` matrix
(backend x mixing x compression x wire x dynamic); ``audit_table`` lowers
each cell's epoch step at smoke size and statically asserts on the
compiled HLO — no execution, the *program text* is the evidence:

* **donation** — every cell that claims state donation must carry an
  ``input_output_alias`` map (``hlo_audit.has_donation``).  Its absence
  means XLA silently kept two full copies of client params + optimizer
  state (the PR-3 engine bug, now a static regression).
* **no host callbacks** — a compiled epoch step must never re-enter
  Python (``hlo_audit.host_callback_sites``): one stray
  ``jax.debug.callback`` turns every epoch into a device->host round-trip.
* **wire dtypes** — any collective a physical-wire cell lowers must move
  s8 codes / f32 scales, never a payload-sized float buffer.

``audit_wire_hlo`` is the reusable site-count pass generalising the PR-6
two-gather regression: fed a multi-device shard_map program's HLO (the
slow-tier subprocess tests and the ``consensus_backends`` benchmark
produce one), it asserts each gossip round is EXACTLY one s8 + one f32
all-gather — a third site is the per-leaf (unbucketed) collective
explosion coming back.

``audit_engine_retrace`` drives the dynamic engine through varied
schedules and churn and asserts, via
``DynamicFederationEngine.compile_counts``, that the epoch step compiled
AT MOST ONCE per federation size — a second trace at the same M means a
schedule operand leaked into trace structure (weak-type flip, rank change,
Python scalar) and every epoch quietly recompiles.

Unlike the rest of ``repro.analysis`` this module imports the live stack
(``repro.core``/``repro.comm``/``repro.data``); only the CLI
(``--contracts``) and the tests import it, keeping ``comm.accounting`` ->
``analysis.hlo_audit`` cycle-free.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis import hlo_audit

#: dtypes allowed to cross a collective in a physical-wire program: the
#: quantized codes and their scales (u32 shows up for packed int4 words)
WIRE_DTYPES = ("s8", "f32", "u32")


@dataclasses.dataclass(frozen=True)
class ContractCell:
    """One audited point of the DFLConfig matrix and its claims."""

    name: str
    consensus_mode: str = "gossip"
    mixing: str = "symmetric"
    compression: str = "none"
    error_feedback: bool = False
    wire: str = "simulated"
    dynamic: bool = False
    donate: bool = True            # claim: jit with donate_argnums=(0,)
    max_host_callbacks: int = 0    # claim: the step never re-enters Python
    superepoch: int = 1            # K > 1: lower the fused K-epoch megastep
    staleness: int = 0             # s > 0: bounded-staleness gossip


CONTRACT_TABLE: Tuple[ContractCell, ...] = (
    ContractCell("gossip"),
    ContractCell("gossip_blocked", consensus_mode="gossip_blocked"),
    ContractCell("collapsed", consensus_mode="collapsed"),
    ContractCell("chebyshev", consensus_mode="chebyshev"),
    ContractCell("exact_mean", consensus_mode="exact_mean"),
    ContractCell("trimmed_mean", consensus_mode="trimmed_mean:1"),
    ContractCell("median", consensus_mode="median"),
    ContractCell("clipped", consensus_mode="clipped:1.5"),
    ContractCell("push_sum", mixing="push_sum"),
    ContractCell("gossip_int8_ef", compression="int8:8",
                 error_feedback=True),
    ContractCell("gossip_int4", compression="int4:8"),
    ContractCell("gossip_topk_ef", compression="top_k:0.25",
                 error_feedback=True),
    ContractCell("gossip_int8_wire", compression="int8:8",
                 error_feedback=True, wire="physical"),
    ContractCell("blocked_int8_wire", consensus_mode="gossip_blocked",
                 compression="int8:8", wire="physical"),
    ContractCell("dynamic_gossip", dynamic=True),
    ContractCell("dynamic_int8_wire", dynamic=True, compression="int8:8",
                 error_feedback=True, wire="physical"),
    # PR-10 overlap cells: the fused K-epoch megastep must keep donation,
    # zero host callbacks, and the rolled collective structure (<= 2 T_S
    # sites per superepoch — lax.scan reuses the epoch body's sites, an
    # unrolled K-fold explosion is the regression); bounded staleness must
    # not change any of those claims
    ContractCell("superepoch_gossip", dynamic=True, superepoch=4),
    ContractCell("superepoch_int8_wire", dynamic=True, superepoch=4,
                 compression="int8:8", error_feedback=True,
                 wire="physical"),
    ContractCell("stale_gossip", dynamic=True, staleness=1),
    ContractCell("stale_int8_wire", dynamic=True, staleness=1,
                 compression="int8:8", error_feedback=True,
                 wire="physical"),
    ContractCell("superepoch_stale_int8_wire", dynamic=True, superepoch=4,
                 staleness=1, compression="int8:8", error_feedback=True,
                 wire="physical"),
)


@dataclasses.dataclass
class CellResult:
    cell: ContractCell
    violations: List[str]
    stats: Dict[str, object]

    @property
    def ok(self) -> bool:
        return not self.violations

    def to_dict(self) -> Dict[str, object]:
        return {"cell": self.cell.name, "ok": self.ok,
                "violations": list(self.violations),
                "stats": dict(self.stats)}


def lower_cell(cell: ContractCell, *, m: int = 4, n: int = 2,
               t_client: int = 2, t_server: int = 3,
               drop_donation: bool = False) -> str:
    """Build the cell's epoch step at smoke size, jit it exactly the way
    the shipping paths do (donating the carried state iff the cell claims
    it — ``drop_donation=True`` is the tests' deliberate regression), and
    return the compiled HLO text.  Cells with ``superepoch=K > 1`` lower
    the fused K-epoch megastep over stacked operands instead, exactly as
    the engine dispatches it."""
    from repro.core import (DFLConfig, EpochSchedule, EpochScheduleBatch,
                            FLTopology, build_dfl_epoch_step,
                            build_dfl_superepoch_step, init_dfl_state)
    from repro.data import RegressionSpec, make_regression_task
    from repro.optim import sgd

    topo_kw = {}
    if cell.mixing != "symmetric":
        topo_kw["mixing"] = "out_degree"
    topo = FLTopology(num_servers=m, clients_per_server=n,
                      t_client=t_client, t_server=t_server,
                      graph_kind="ring", **topo_kw)
    task = make_regression_task(topo, RegressionSpec(heterogeneity=0.3),
                                seed=0)
    cfg = DFLConfig(topology=topo, consensus_mode=cell.consensus_mode,
                    mixing=cell.mixing, compression=cell.compression,
                    error_feedback=cell.error_feedback, wire=cell.wire,
                    dynamic=cell.dynamic, staleness=cell.staleness)
    opt = sgd(1e-3)
    state = init_dfl_state(cfg, jnp.zeros((2,)), opt, jax.random.key(0))
    a = jnp.asarray(topo.mixing_matrix(), jnp.float32)
    if cell.superepoch > 1:
        k = cell.superepoch
        step = build_dfl_superepoch_step(cfg, task["loss_fn"], opt, k)
        batches = jax.tree.map(lambda x: jnp.stack([x] * k),
                               task["batches"])
        sched_b = EpochScheduleBatch(
            mask=jnp.ones((k, m, n), jnp.float32),
            mixing=jnp.stack([a] * k))
        args: Tuple = (state, batches, sched_b)
    else:
        step = build_dfl_epoch_step(cfg, task["loss_fn"], opt)
        args = (state, task["batches"])
        if cell.dynamic:
            sched = EpochSchedule(
                mask=jnp.ones((m, n), jnp.float32), mixing=a)
            args = args + (sched,)
    donate = () if (not cell.donate or drop_donation) else (0,)
    return jax.jit(step, donate_argnums=donate).lower(
        *args).compile().as_text()


def audit_cell(cell: ContractCell, hlo: Optional[str] = None,
               **size_kw) -> CellResult:
    """Check one cell's claims against its compiled HLO (lowered fresh
    unless ``hlo`` is supplied — the tests feed doctored programs)."""
    if hlo is None:
        hlo = lower_cell(cell, **size_kw)
    violations: List[str] = []
    aliased = hlo_audit.has_donation(hlo)
    callbacks = hlo_audit.host_callback_sites(hlo)
    sites = hlo_audit.collective_sites(hlo)
    if cell.donate and not aliased:
        violations.append(
            f"{cell.name}: donation claimed (donate_argnums=(0,)) but the "
            f"compiled program has NO input_output_alias — the carried "
            f"DFLState is double-buffered")
    if len(callbacks) > cell.max_host_callbacks:
        violations.append(
            f"{cell.name}: {len(callbacks)} host callback site(s) in the "
            f"compiled epoch step ({', '.join(sorted(set(callbacks)))}) — "
            f"every epoch round-trips to Python")
    if cell.wire == "physical":
        bad = sorted({c["dtype"] for c in sites
                      if c["dtype"] not in WIRE_DTYPES})
        if bad:
            violations.append(
                f"{cell.name}: physical-wire program moves "
                f"{', '.join(bad)} through a collective — only the "
                f"quantized codes (s8/u32) and f32 scales may cross")
        # per-SUPEREPOCH site bound: the gossip rounds stay rolled (fori /
        # scan), so however many epochs one program fuses, at most 2 T_S
        # collective sites may appear in its text — K x that means the
        # scan unrolled the wire (compile time and code size scale with K)
        t_server = size_kw.get("t_server", 3)
        if len(sites) > 2 * t_server:
            violations.append(
                f"{cell.name}: {len(sites)} collective sites in one "
                f"program (superepoch={cell.superepoch}) — the rolled-"
                f"round contract is <= 2*T_S = {2 * t_server} per "
                f"superepoch, regardless of K")
    return CellResult(cell, violations, {
        "aliased": aliased, "host_callbacks": len(callbacks),
        "collective_sites": len(sites)})


def audit_table(table: Sequence[ContractCell] = CONTRACT_TABLE,
                **size_kw) -> List[CellResult]:
    return [audit_cell(cell, **size_kw) for cell in table]


def audit_wire_hlo(hlo: str, *, op: str = "all-gather",
                   expect_sites: int = 2,
                   allowed_dtypes: Sequence[str] = ("s8", "f32")
                   ) -> List[str]:
    """The reusable PR-6 wire contract over an explicit-collective
    (shard_map / ring) program's compiled HLO: exactly ``expect_sites``
    collective SITES of the given op per program — the bucketed layout's
    one code + one scale gather, however many leaves the tree has — each
    moving only the allowed wire dtypes.  More sites than the contract is
    the per-leaf (unbucketed) collective explosion regressing."""
    sites = [c for c in hlo_audit.collective_sites(hlo) if c["op"] == op]
    violations: List[str] = []
    if len(sites) != expect_sites:
        kind = "per-leaf (unbucketed) collective regression" \
            if len(sites) > expect_sites else "missing collective"
        violations.append(
            f"{kind}: {len(sites)} {op} site(s), the bucketed-wire "
            f"contract is exactly {expect_sites} per program "
            f"(dtypes seen: {sorted({c['dtype'] for c in sites})})")
    bad = sorted({c["dtype"] for c in sites
                  if c["dtype"] not in allowed_dtypes})
    if bad:
        violations.append(
            f"collective operand dtype(s) {bad} outside the wire contract "
            f"{sorted(allowed_dtypes)} — a payload-sized float buffer is "
            f"crossing the interconnect")
    return violations


# ---------------------------------------------------------------------------
# jit retrace detector
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class RetraceReport:
    compile_counts: Dict[int, int]
    violations: List[str]

    @property
    def ok(self) -> bool:
        return not self.violations

    def to_dict(self) -> Dict[str, object]:
        return {"compile_counts": {str(k): v
                                   for k, v in self.compile_counts.items()},
                "ok": self.ok, "violations": list(self.violations)}


def audit_engine_retrace(epochs: int = 6, *, m: int = 5, n: int = 3,
                         t_client: int = 2, t_server: int = 3
                         ) -> RetraceReport:
    """Run the dynamic engine through per-epoch mask AND mixing variation
    plus a drop/rejoin (two federation sizes), then assert the compiled
    epoch step traced at most once per M.  A count above 1 means a
    schedule operand's trace signature varied across epochs — the
    compiles-every-epoch failure mode the EpochSchedule operand design
    exists to prevent."""
    from repro.core import (FLTopology, FaultSchedule,
                            ParticipationSchedule, TopologySchedule,
                            init_dfl_state, make_engine)
    from repro.data import RegressionSpec, make_regression_task
    from repro.optim import sgd

    topo = FLTopology(num_servers=m, clients_per_server=n,
                      t_client=t_client, t_server=t_server,
                      graph_kind="ring")
    task = make_regression_task(topo, RegressionSpec(heterogeneity=0.3),
                                seed=0)
    opt = sgd(1e-3)
    engine = make_engine(
        topo, task["loss_fn"], opt,
        participation=ParticipationSchedule(kind="bernoulli", rate=0.7,
                                            seed=1),
        topology_schedule=TopologySchedule(kind="edge_drop", drop_prob=0.3,
                                           seed=2),
        faults=FaultSchedule.parse(f"drop:2:1,rejoin:{epochs - 2}:1"))
    state = init_dfl_state(engine.cfg, jnp.zeros((2,)), opt,
                           jax.random.key(0))
    engine.run(state, epochs, task["batch_fn"])
    counts = engine.compile_counts()
    violations = [
        f"epoch step at M={mm} compiled {c} times across {epochs} "
        f"schedule-varied epochs — a traced operand's signature is "
        f"unstable (expected exactly 1 trace per federation size)"
        for mm, c in sorted(counts.items()) if c != 1]
    if len(counts) < 2:
        violations.append(
            f"retrace audit exercised only federation sizes "
            f"{sorted(counts)} — the drop/rejoin surgery did not run")
    return RetraceReport(counts, violations)
