"""repro.analysis — the static-analysis layer: an AST lint engine with
project-specific JAX-hygiene rules (``analysis.lint`` + ``analysis.rules``)
and shared compiled-HLO passes (``analysis.hlo_audit``).

Run it:  ``python -m repro.analysis [--format json] [paths...]`` — exits
nonzero on findings; per-line ``# repro: ignore[rule]: reason``
suppressions; ``--contracts`` additionally lowers the ``DFLConfig``
contract table (``analysis.contracts``).

This package root stays import-light on purpose: ``comm.accounting``
delegates its HLO parsing to ``analysis.hlo_audit``, so nothing here may
import ``repro.core``/``repro.comm`` (``analysis.contracts``, which does,
is imported only by the CLI and the tests)."""
from repro.analysis import hlo_audit  # noqa: F401
from repro.analysis.lint import (DEFAULT_ROOTS, Finding, RULES,  # noqa: F401
                                 lint_file, lint_paths, rule)
