"""The dynamic-federation engine: the host-side loop that drives the
jit-compiled dynamic epoch step through a scenario.

Split of responsibilities:

* anything that keeps array shapes fixed — partial participation, per-epoch
  mixing matrices — is a TRACED operand (``schedule.EpochSchedule``) of the
  one compiled ``dfl`` epoch step;
* anything that changes shapes — a server dying or rejoining — is host-side
  graph surgery between epochs: slice (or insert) the failed server's row
  out of every ``(M, N, *w)`` leaf, rebuild the topology via
  ``FLTopology.drop_server`` / ``rejoin_server``, and re-jit the step for
  the new M (cached per M, so a drop/rejoin cycle compiles twice, total).

A rejoining server re-enters with the mean of the survivors' models (the
natural 'state transfer from peers' bootstrap) and its clients broadcast
from it, exactly like an end-of-epoch broadcast.

The engine reports per-epoch history including the participating-client
loss, Lemma-1/3 diagnostics, and the host-side product contraction
``sigma_prod`` (``schedule.SigmaTracker``) of the time-varying gossip.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.comm.accounting import (BytesTracker,
                                   tree_bucketed_wire_bytes_per_server)
from repro.comm.compressors import tree_wire_bytes_per_server
from repro.core import dfl
from repro.core import topology as tp
from repro.core.schedule import (EpochSchedule, FaultSchedule,
                                 ParticipationSchedule, SigmaTracker,
                                 TopologySchedule)
from repro.core.topology import FLTopology
from repro.obs import OBS_OFF
from repro.optim import Optimizer

# batch_fn(epoch, alive_original_server_ids) -> batch pytree with leaves
# (T_C, M_alive, N, ...).  Data follows ORIGINAL server identity, so a
# server that drops and rejoins gets its own clients' shards back.
BatchFn = Callable[[int, Tuple[int, ...]], Any]


@dataclasses.dataclass
class DynamicFederationEngine:
    """Drives DFL training under participation/topology/fault schedules."""

    cfg: dfl.DFLConfig
    loss_fn: dfl.LossFn
    optimizer: Optimizer
    participation: ParticipationSchedule = ParticipationSchedule()
    topology_schedule: TopologySchedule = TopologySchedule()
    faults: FaultSchedule = FaultSchedule()
    # observability bundle (repro.obs.Observability) or None for the no-op
    # null bundle.  HARD CONTRACT: attaching one is bitwise inert on
    # training numerics — the instrumentation only reads already-computed
    # values, the compiled programs are identical with obs on or off
    # (asserted in tests/test_obs.py), and the tracer's block_until_ready
    # sync points exist only when a tracer is attached.
    obs: Any = None

    def __post_init__(self):
        if self.obs is None:
            self.obs = OBS_OFF
        if not self.cfg.dynamic:
            self.cfg = dataclasses.replace(self.cfg, dynamic=True)
        if (self.topology_schedule.kind == "asymmetric"
                and self.cfg.mixing == "symmetric"):
            raise ValueError(
                "TopologySchedule(kind='asymmetric') emits row-stochastic "
                "A_p: the symmetric gossip path would silently converge to "
                "a biased average — use DFLConfig(mixing='push_sum') or "
                "mixing='row_stochastic'")
        self.topo: FLTopology = self.cfg.topology
        # fail at construction, not mid-run: every fault event must name an
        # ORIGINAL server id (data shards are keyed by original identity)
        self.faults.validate(self.topo.num_servers)
        # ... and the byzantine populations must leave an honest majority
        # candidate (at least one honest server)
        if self.cfg.byzantine is not None:
            self.cfg.byzantine.validate(self.topo.num_servers)
        if (self.faults.events and self.cfg.consensus_backend is not None
                and getattr(self.cfg.consensus_backend, "mesh_bound", False)):
            raise ValueError(
                "a mesh-bound consensus backend (shard_map) cannot survive "
                "fault surgery: the server axis is a physical mesh axis and "
                "cannot change size with M — use consensus_mode="
                "'gossip_blocked' for fault scenarios")
        # original server ids still alive, in row order of the state arrays
        self.alive: List[int] = list(range(self.topo.num_servers))
        self._initial_m: int = self.topo.num_servers
        self._steps: Dict[int, Callable] = {}
        self._tracker = self._fresh_tracker()
        # compressed-gossip wire accounting (None when the wire is exact):
        # one ledger across the whole run — bytes accumulate through fault
        # surgery, unlike the contraction trackers which reset with M
        self._compressor = dfl.active_compressor(self.cfg)
        # the tracker is wire-aware: on the physical wire push-sum's (M,)
        # weight never crosses a collective (it mixes via the in-graph
        # replicated matvec), so its +4 B/message applies only simulated
        self._bytes = (BytesTracker(self._compressor,
                                    push_sum=self.cfg.mixing == "push_sum",
                                    wire=dfl.active_wire(self.cfg)[0])
                       if self._compressor is not None else None)
        self._row_bytes: Dict[int, Tuple[int, int]] = {}  # M -> (bytes, elems)
        # consensus-replay timing probes (dfl.build_consensus_replay),
        # built lazily per M and ONLY when a span tracer is attached
        self._probes: Dict[int, Optional[Callable]] = {}
        self._probe_warm: set = set()
        # spectral backends (chebyshev) consume a host-side per-epoch
        # |lambda_2(A_p)| alongside the traced matrix
        backend = self.cfg.consensus_backend
        self._needs_spectral = (self.cfg.consensus_mode == "chebyshev"
                                or getattr(backend, "needs_spectral", False))

    def _fresh_tracker(self) -> SigmaTracker:
        mode = "push_sum" if self.cfg.mixing == "push_sum" else "average"
        return SigmaTracker(self.topo.num_servers, mode=mode)

    def _reset_psum_weight(self, state: dfl.DFLState) -> dfl.DFLState:
        """Push-sum weights are per-server mass fractions of the CURRENT
        federation (positive, summing to M): after drop/rejoin surgery the
        old weights describe a federation that no longer exists, so they
        reset to 1 — consistent with every consensus period starting from
        unit weight anyway (``consensus.init_push_sum``)."""
        if self.cfg.mixing != "push_sum":
            return state
        return state._replace(
            psum_weight=jnp.ones((self.topo.num_servers,), jnp.float32))

    def _reset_ef_residual(self, state: dfl.DFLState) -> dfl.DFLState:
        """Compression error-feedback residuals are per-server WIRE state of
        the old federation (what each server still owes its peers): after
        drop/rejoin surgery they reset to zero at the new M, mirroring the
        push-sum weight reset — a rejoined server owes nothing, and a
        dropped server's debt left with it."""
        if not dfl.wants_error_feedback(self.cfg):
            return state
        ef = jax.tree.map(lambda x: jnp.zeros_like(x[:, 0]),
                          state.client_params)
        return state._replace(ef_residual=ef)

    def _wire_row_bytes(self, state: dfl.DFLState) -> Tuple[int, int]:
        """(compressed bytes, elements) of one server's message at the
        current federation size, cached per M.  Simulated wire: compressor
        metadata over the server-tree shapes (unpadded payload flooding).
        Physical wire: the BUCKETED padded codes + scales the collectives
        actually gather each round (``comm.accounting.
        tree_bucketed_wire_bytes_per_server`` — one code buffer + one
        scale buffer for the whole tree) — the ledger then reports bytes
        the interconnect really moved, cross-checked against compiled-HLO
        operand shapes in ``tests/test_wire.py``."""
        m = self.topo.num_servers
        if m not in self._row_bytes:
            server_abs = jax.eval_shape(
                lambda t: jax.tree.map(lambda x: x[:, 0], t),
                state.client_params)
            wire, wire_block = dfl.active_wire(self.cfg)
            if wire == "physical":
                row = tree_bucketed_wire_bytes_per_server(
                    self._compressor, server_abs, wire_block)
            else:
                row = tree_wire_bytes_per_server(self._compressor,
                                                 server_abs)
            self._row_bytes[m] = (
                row,
                sum(int(np.prod(l.shape[1:]))
                    for l in jax.tree.leaves(server_abs)))
        return self._row_bytes[m]

    # -- compiled-step cache -------------------------------------------------
    def _step(self) -> Callable:
        m = self.topo.num_servers
        if m not in self._steps:
            cfg = dataclasses.replace(self.cfg, topology=self.topo)
            # donate the carried state: without this every dynamic epoch
            # holds TWO full copies of client params + optimizer state (the
            # static trainer path has always donated — train.py)
            self._steps[m] = jax.jit(dfl.build_dfl_epoch_step(
                cfg, self.loss_fn, self.optimizer), donate_argnums=(0,))
        return self._steps[m]

    def compile_counts(self) -> Dict[int, int]:
        """Per federation size M, how many distinct programs the cached
        epoch step has traced.  The dynamic-mode contract is EXACTLY 1:
        the EpochSchedule operand is traced, so mask/mixing/byz variation
        must never change the trace signature.  A count above 1 means a
        schedule operand leaked into trace structure (weak-type flip,
        rank change, Python scalar) and every epoch silently recompiles —
        the regression ``analysis.contracts.audit_engine_retrace`` gates
        on this surface."""
        return {m: int(step._cache_size())
                for m, step in self._steps.items()}

    # -- fault surgery -------------------------------------------------------
    def _drop(self, state: dfl.DFLState, server: int) -> dfl.DFLState:
        """Remove ORIGINAL server id ``server`` from the federation."""
        if server not in self.alive:
            raise ValueError(f"server {server} is not alive")
        pos = self.alive.index(server)
        self.topo, keep = self.topo.drop_server(pos)
        self.alive.pop(pos)
        keep = np.asarray(keep)

        def leaf(x):
            if x.ndim >= 1 and x.shape[0] == keep.size + 1:
                return x[keep]
            return x
        state = dfl.DFLState(
            jax.tree.map(leaf, state.client_params),
            jax.tree.map(leaf, state.opt_state),
            state.epoch, state.rng)
        self._tracker = self._fresh_tracker()
        return self._reset_ef_residual(self._reset_psum_weight(state))

    def _rejoin(self, state: dfl.DFLState, server: Optional[int]) -> dfl.DFLState:
        """ORIGINAL server ``server`` re-enters with the survivor-mean
        model.  Fresh ids are rejected: client data ownership is keyed by
        original identity (``BatchFn``), so a server that never existed has
        no data shard — admitting one would crash (or silently alias
        another server's shard) at the first ``batch_fn`` call."""
        if server is None or not 0 <= server < self._initial_m:
            raise ValueError(
                f"rejoin needs an ORIGINAL server id in [0, "
                f"{self._initial_m}) — got {server!r}; a fresh server has "
                f"no data shard (data follows original identity, see "
                f"FaultSchedule.validate)")
        if server in self.alive:
            raise ValueError(f"server {server} is already alive")
        self.topo, idx = self.topo.rejoin_server()
        self.alive.append(server)

        def leaf(x):
            if x.ndim >= 1 and x.shape[0] == idx:
                new_row = x.mean(axis=0, keepdims=True).astype(x.dtype)
                return jnp.concatenate([x, new_row], axis=0)
            return x
        state = dfl.DFLState(
            jax.tree.map(leaf, state.client_params),
            jax.tree.map(leaf, state.opt_state),
            state.epoch, state.rng)
        self._tracker = self._fresh_tracker()
        return self._reset_ef_residual(self._reset_psum_weight(state))

    def apply_faults(self, state: dfl.DFLState, epoch: int) -> dfl.DFLState:
        for ev in self.faults.at(epoch):
            if ev.kind == "drop":
                state = self._drop(state, ev.server)
            else:
                state = self._rejoin(state, ev.server)
        return state

    # -- observability -------------------------------------------------------
    def _consensus_probe(self, m: int) -> Optional[Callable]:
        """The jitted consensus-replay timing probe for federation size
        ``m`` (``dfl.build_consensus_replay``), or None when there is no
        consensus period to time.  Built lazily, and only ever reached
        when a span tracer is attached."""
        if m not in self._probes:
            cfg = dataclasses.replace(self.cfg, topology=self.topo)
            fn = dfl.build_consensus_replay(cfg)
            self._probes[m] = None if fn is None else jax.jit(fn)
        return self._probes[m]

    def _trace_step(self, epoch_span, epoch: int, m: int, m_known: bool,
                    programs_before: int, t0: int, t1: int,
                    state: dfl.DFLState, a_np, lam2) -> None:
        """Tracer-only post-step work: emit the compile event if this call
        traced a new program, then split the step's [t0, t1] wall interval
        into local-period / gossip-period spans via the consensus-replay
        probe (re-run the consensus period alone on the post-epoch server
        tree, warmed once per M untimed; its wall time estimates the
        gossip share of the fused step)."""
        tracer = self.obs.tracer
        programs_after = int(self._steps[m]._cache_size())
        if programs_after > programs_before:
            if not m_known and len(self._steps) == 1:
                cause = "first_trace"
            elif not m_known:
                cause = "federation_size_change"
            else:
                # a schedule operand leaked into trace structure — the
                # compile-once contract (compile_counts) is being violated
                cause = "retrace"
            tracer.compile_event(cause, m=m, programs=programs_after,
                                 epoch=epoch)
        probe = self._consensus_probe(m)
        if probe is None:
            tracer.add_span("local-period", t0, t1, parent=epoch_span,
                            epoch=epoch)
            return
        server_tree = jax.tree.map(lambda x: x[:, 0], state.client_params)
        a_j = jnp.asarray(a_np, jnp.float32)
        if m not in self._probe_warm:
            jax.block_until_ready(probe(server_tree, a_j, lam2))
            self._probe_warm.add(m)
        p0 = tracer.now()
        jax.block_until_ready(probe(server_tree, a_j, lam2))
        gossip_ns = min(tracer.now() - p0, t1 - t0)
        split = t1 - gossip_ns
        tracer.add_span("local-period", t0, split, parent=epoch_span,
                        epoch=epoch, method="consensus-replay")
        tracer.add_span("gossip-period", split, t1, parent=epoch_span,
                        epoch=epoch, method="consensus-replay",
                        t_server=self.topo.t_server)

    # -- the loop ------------------------------------------------------------
    def run_epoch(self, state: dfl.DFLState, epoch: int,
                  batch_fn: BatchFn) -> Tuple[dfl.DFLState, Dict[str, float]]:
        obs = self.obs
        tracer = obs.tracer
        with obs.span("epoch", epoch=epoch) as epoch_span:
            with obs.span("fault-surgery", epoch=epoch):
                state = self.apply_faults(state, epoch)
            m, n = self.topo.num_servers, self.topo.clients_per_server
            mask_np = self.participation.mask(epoch, m, n)
            a_np = self.topology_schedule.mixing(self.topo, epoch)
            sigma_prod = self._tracker.update(a_np, self.topo.t_server)
            batches = batch_fn(epoch, tuple(self.alive))
            lam2 = (jnp.float32(tp.lambda_2(a_np)) if self._needs_spectral
                    else None)
            byz_np = None
            if self.cfg.byzantine is not None and self.cfg.byzantine.attacks:
                # per-row attack codes over the CURRENT federation: original
                # attacker ids (stable across surgery — drawn over the
                # ORIGINAL size) mapped through the alive row order.  The
                # array is passed every epoch, all-zero included, so the
                # compiled step's operand structure never changes.
                byz_np = self.cfg.byzantine.codes(epoch, tuple(self.alive),
                                                  self._initial_m)
            sched = EpochSchedule(jnp.asarray(mask_np, jnp.float32),
                                  jnp.asarray(a_np, jnp.float32), lam2,
                                  None if byz_np is None
                                  else jnp.asarray(byz_np, jnp.int32))
            epoch_wire_bytes = None
            if self._bytes is not None:
                row_bytes, elems = self._wire_row_bytes(state)
                epoch_wire_bytes = self._bytes.update(
                    a_np, self.topo.t_server, row_bytes=row_bytes,
                    elems_per_row=elems)
            m_known = m in self._steps
            step = self._step()
            # the tracer's sync point lives strictly OUTSIDE the compiled
            # program and exists ONLY when a tracer is attached; the
            # untraced path dispatches exactly as before
            programs_before = int(step._cache_size()) if tracer else 0
            t0 = tracer.now() if tracer else 0
            state, metrics = step(state, batches, sched)
            if tracer is not None:
                jax.block_until_ready(state)
                self._trace_step(epoch_span, epoch, m, m_known,
                                 programs_before, t0, tracer.now(), state,
                                 a_np, lam2)
            with obs.span("host-aggregation", epoch=epoch):
                # participant-weighted loss of the last local iteration
                last = np.asarray(metrics.loss[-1], np.float32)
                w = mask_np if mask_np.sum() else np.ones_like(mask_np)
                record = {
                    "loss": float((last * w).sum() / w.sum()),
                    "disagreement": float(metrics.server_disagreement),
                    "drift": float(metrics.client_drift),
                    "participation": float(mask_np.mean()),
                    "num_servers": float(m),
                    "sigma_prod": sigma_prod,
                }
                if byz_np is not None:
                    # fraction of the CURRENT federation attacking this
                    # epoch — the honest-metric masks in tests/benchmarks
                    # key off this
                    record["byzantine"] = float((byz_np > 0).mean())
                if state.psum_weight is not None:
                    # ratio-consensus conditioning: a terminal weight near
                    # 0 means that server's num/w read-out amplified
                    # rounding error
                    record["psum_min_weight"] = float(
                        jnp.min(state.psum_weight))
                if epoch_wire_bytes is not None:
                    # this epoch's on-wire consensus traffic + the
                    # cumulative compression ratio vs f32 replicas over the
                    # same links.  THIS epoch's update() return, never
                    # history[-1]: an epoch with zero gossip rounds
                    # (t_server=0, or M==1 after drop surgery) still
                    # records its true 0.0 rather than a stale entry — and
                    # never touches an empty history
                    record["wire_mb"] = epoch_wire_bytes / 1e6
                    record["wire_ratio"] = self._bytes.ratio()
                screen_per_round = None
                if metrics.screen_rejected is not None:
                    # robust-screen activity, normalised per gossip round;
                    # the per-server breakdown goes to the hub as a
                    # labelled histogram below
                    rounds = max(self.topo.t_server, 1)
                    screen_per_round = (np.asarray(metrics.screen_rejected,
                                                   np.float32) / rounds)
                    record["screen_rejected"] = float(
                        screen_per_round.sum())
            obs.observe(
                epoch, record, servers=tuple(self.alive),
                per_link=(self._bytes.per_link
                          if self._bytes is not None else None),
                screen_rejected=screen_per_round)
        return state, record

    def run(self, state: dfl.DFLState, epochs: int,
            batch_fn: BatchFn) -> Tuple[dfl.DFLState, Dict[str, List[float]]]:
        history: Dict[str, List[float]] = {}
        for epoch in range(epochs):
            state, rec = self.run_epoch(state, epoch, batch_fn)
            for k, v in rec.items():
                history.setdefault(k, []).append(v)
        return state, history


def make_engine(topology: FLTopology, loss_fn: dfl.LossFn,
                optimizer: Optimizer, *,
                consensus_mode: str = "gossip",
                participation: Optional[ParticipationSchedule] = None,
                topology_schedule: Optional[TopologySchedule] = None,
                faults: Optional[FaultSchedule] = None,
                obs: Optional[Any] = None,
                **cfg_kw) -> DynamicFederationEngine:
    """Convenience constructor mirroring ``DFLConfig`` defaults.

    Any extra keyword (``mixing``, ``metrics``, ``grad_microbatches``, ...)
    is forwarded to ``DFLConfig``; ``dynamic=True`` is always set.  Typical
    usage on the paper's Sec.-IV regression task::

        from repro.core import (FLTopology, FaultSchedule,
                                ParticipationSchedule, TopologySchedule,
                                init_dfl_state, make_engine)
        from repro.data import make_regression_task
        from repro.optim import sgd
        import jax, jax.numpy as jnp

        topo = FLTopology(num_servers=5, clients_per_server=5,
                          t_client=25, t_server=10, graph_kind="ring")
        task = make_regression_task(topo, seed=0)
        engine = make_engine(
            topo, task["loss_fn"], sgd(1e-3),
            participation=ParticipationSchedule(kind="bernoulli", rate=0.5),
            topology_schedule=TopologySchedule(kind="edge_drop",
                                              drop_prob=0.3),
            faults=FaultSchedule.parse("drop:10:2,rejoin:25:2"))
        state = init_dfl_state(engine.cfg, jnp.zeros((2,)), sgd(1e-3),
                               jax.random.key(0))
        state, history = engine.run(state, epochs=40, batch_fn=task["batch_fn"])

    ``history`` maps metric name -> per-epoch list (loss, disagreement,
    drift, participation, num_servers, sigma_prod, psum_min_weight under
    ``mixing="push_sum"``, wire_mb / wire_ratio under compressed
    consensus — ``DFLConfig.compression`` — and byzantine, the attacking
    fraction, under a ``byzantine=ByzantineSchedule(...)`` keyword, which
    forwards to ``DFLConfig.byzantine`` like any other config field).

    ``obs`` attaches a ``repro.obs.Observability`` bundle (span tracing +
    metric sinks + convergence watchdogs); omitted, the engine runs with
    the no-op null bundle — see docs/observability.md."""
    cfg = dfl.DFLConfig(topology=topology, consensus_mode=consensus_mode,
                        dynamic=True, **cfg_kw)
    return DynamicFederationEngine(
        cfg, loss_fn, optimizer,
        participation=participation or ParticipationSchedule(),
        topology_schedule=topology_schedule or TopologySchedule(),
        faults=faults or FaultSchedule(), obs=obs)
