"""The dynamic-federation engine: the host-side loop that drives the
jit-compiled dynamic epoch step through a scenario.

Split of responsibilities:

* anything that keeps array shapes fixed — partial participation, per-epoch
  mixing matrices — is a TRACED operand (``schedule.EpochSchedule``) of the
  one compiled ``dfl`` epoch step;
* anything that changes shapes — a server dying or rejoining — is host-side
  graph surgery between epochs: slice (or insert) the failed server's row
  out of every ``(M, N, *w)`` leaf, rebuild the topology via
  ``FLTopology.drop_server`` / ``rejoin_server``, and re-jit the step for
  the new M (cached per M, so a drop/rejoin cycle compiles twice, total).

A rejoining server re-enters with the mean of the survivors' models (the
natural 'state transfer from peers' bootstrap) and its clients broadcast
from it, exactly like an end-of-epoch broadcast.

The engine reports per-epoch history including the participating-client
loss, Lemma-1/3 diagnostics, and the host-side product contraction
``sigma_prod`` (``schedule.SigmaTracker``) of the time-varying gossip.

Superepoch dispatch (``superepoch=K > 1``): ``run`` becomes an event-driven
scheduler over K-epoch blocks — host-side schedules (participation masks,
mixing matrices, byzantine codes, batches) are pre-materialized per block,
stacked into one ``overlap.EpochScheduleBatch``, and dispatched through the
fused K-epoch megastep (``overlap.build_dfl_superepoch_step``, jitted with
donation and cached per (M, K)); the stacked ``DFLMetrics`` come back in
ONE ``jax.device_get``.  Blocks split at fault epochs, where graph surgery
changes shapes.  History is element-identical to the barrier loop — the
scan body is the unchanged epoch step (``tests/test_overlap.py``).  All
host metric readbacks (at any K, including the K=1 barrier path) flow
through the injectable ``_device_get`` hook, so tests can count device
syncs per dispatch.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.comm.accounting import (BytesTracker,
                                   tree_bucketed_wire_bytes_per_server)
from repro.comm.compressors import tree_wire_bytes_per_server
from repro.core import dfl
from repro.core import overlap
from repro.core import topology as tp
from repro.core.schedule import (EpochSchedule, FaultSchedule,
                                 ParticipationSchedule, SigmaTracker,
                                 TopologySchedule)
from repro.core.topology import FLTopology
from repro.obs import OBS_OFF
from repro.optim import Optimizer

# batch_fn(epoch, alive_original_server_ids) -> batch pytree with leaves
# (T_C, M_alive, N, ...).  Data follows ORIGINAL server identity, so a
# server that drops and rejoins gets its own clients' shards back.
BatchFn = Callable[[int, Tuple[int, ...]], Any]


@dataclasses.dataclass
class DynamicFederationEngine:
    """Drives DFL training under participation/topology/fault schedules."""

    cfg: dfl.DFLConfig
    loss_fn: dfl.LossFn
    optimizer: Optimizer
    participation: ParticipationSchedule = ParticipationSchedule()
    topology_schedule: TopologySchedule = TopologySchedule()
    faults: FaultSchedule = FaultSchedule()
    # observability bundle (repro.obs.Observability) or None for the no-op
    # null bundle.  HARD CONTRACT: attaching one is bitwise inert on
    # training numerics — the instrumentation only reads already-computed
    # values, the compiled programs are identical with obs on or off
    # (asserted in tests/test_obs.py), and the tracer's block_until_ready
    # sync points exist only when a tracer is attached.
    obs: Any = None
    # superepoch length K: run() dispatches K epochs per compiled program
    # (overlap.build_dfl_superepoch_step) and reads K epochs of metrics
    # back in one transfer.  1 = the per-epoch barrier loop (unchanged).
    superepoch: int = 1

    def __post_init__(self):
        if self.obs is None:
            self.obs = OBS_OFF
        if self.superepoch < 1:
            raise ValueError(
                f"superepoch must be >= 1, got {self.superepoch}")
        if not self.cfg.dynamic:
            self.cfg = dataclasses.replace(self.cfg, dynamic=True)
        if (self.topology_schedule.kind == "asymmetric"
                and self.cfg.mixing == "symmetric"):
            raise ValueError(
                "TopologySchedule(kind='asymmetric') emits row-stochastic "
                "A_p: the symmetric gossip path would silently converge to "
                "a biased average — use DFLConfig(mixing='push_sum') or "
                "mixing='row_stochastic'")
        self.topo: FLTopology = self.cfg.topology
        # fail at construction, not mid-run: every fault event must name an
        # ORIGINAL server id (data shards are keyed by original identity)
        self.faults.validate(self.topo.num_servers)
        # ... and the byzantine populations must leave an honest majority
        # candidate (at least one honest server)
        if self.cfg.byzantine is not None:
            self.cfg.byzantine.validate(self.topo.num_servers)
        if (self.faults.events and self.cfg.consensus_backend is not None
                and getattr(self.cfg.consensus_backend, "mesh_bound", False)):
            raise ValueError(
                "a mesh-bound consensus backend (shard_map) cannot survive "
                "fault surgery: the server axis is a physical mesh axis and "
                "cannot change size with M — use consensus_mode="
                "'gossip_blocked' for fault scenarios")
        # original server ids still alive, in row order of the state arrays
        self.alive: List[int] = list(range(self.topo.num_servers))
        self._initial_m: int = self.topo.num_servers
        self._steps: Dict[int, Callable] = {}
        # fused K-epoch megasteps, cached per (M, K) — K varies at block
        # boundaries (fault epochs and the run tail)
        self._super_steps: Dict[Tuple[int, int], Callable] = {}
        # ALL host metric readbacks flow through this injectable hook —
        # one call per dispatch (run_epoch or superepoch block), which the
        # device-sync regression test counts by swapping it out
        self._device_get: Callable = jax.device_get
        self._tracker = self._fresh_tracker()
        # compressed-gossip wire accounting (None when the wire is exact):
        # one ledger across the whole run — bytes accumulate through fault
        # surgery, unlike the contraction trackers which reset with M
        self._compressor = dfl.active_compressor(self.cfg)
        # the tracker is wire-aware: on the physical wire push-sum's (M,)
        # weight never crosses a collective (it mixes via the in-graph
        # replicated matvec), so its +4 B/message applies only simulated
        self._bytes = (BytesTracker(self._compressor,
                                    push_sum=self.cfg.mixing == "push_sum",
                                    wire=dfl.active_wire(self.cfg)[0])
                       if self._compressor is not None else None)
        self._row_bytes: Dict[int, Tuple[int, int]] = {}  # M -> (bytes, elems)
        # consensus-replay timing probes (dfl.build_consensus_replay),
        # built lazily per M and ONLY when a span tracer is attached
        self._probes: Dict[int, Optional[Callable]] = {}
        self._probe_warm: set = set()
        # one-time per-M gossip-period wall-time calibration (ns), measured
        # by timing the consensus-replay probe ONCE per federation size —
        # superepoch spans attribute per-epoch/per-round from this instead
        # of re-executing the probe every epoch
        self._probe_cal: Dict[int, Optional[int]] = {}
        # spectral backends (chebyshev) consume a host-side per-epoch
        # |lambda_2(A_p)| alongside the traced matrix
        backend = self.cfg.consensus_backend
        self._needs_spectral = (self.cfg.consensus_mode == "chebyshev"
                                or getattr(backend, "needs_spectral", False))

    def _fresh_tracker(self) -> SigmaTracker:
        mode = "push_sum" if self.cfg.mixing == "push_sum" else "average"
        return SigmaTracker(self.topo.num_servers, mode=mode,
                            staleness=self.cfg.staleness)

    def _reset_psum_weight(self, state: dfl.DFLState) -> dfl.DFLState:
        """Push-sum weights are per-server mass fractions of the CURRENT
        federation (positive, summing to M): after drop/rejoin surgery the
        old weights describe a federation that no longer exists, so they
        reset to 1 — consistent with every consensus period starting from
        unit weight anyway (``consensus.init_push_sum``)."""
        if self.cfg.mixing != "push_sum":
            return state
        return state._replace(
            psum_weight=jnp.ones((self.topo.num_servers,), jnp.float32))

    def _reset_ef_residual(self, state: dfl.DFLState) -> dfl.DFLState:
        """Compression error-feedback residuals are per-server WIRE state of
        the old federation (what each server still owes its peers): after
        drop/rejoin surgery they reset to zero at the new M, mirroring the
        push-sum weight reset — a rejoined server owes nothing, and a
        dropped server's debt left with it."""
        if not dfl.wants_error_feedback(self.cfg):
            return state
        ef = jax.tree.map(lambda x: jnp.zeros_like(x[:, 0]),
                          state.client_params)
        return state._replace(ef_residual=ef)

    def _wire_row_bytes(self, state: dfl.DFLState) -> Tuple[int, int]:
        """(compressed bytes, elements) of one server's message at the
        current federation size, cached per M.  Simulated wire: compressor
        metadata over the server-tree shapes (unpadded payload flooding).
        Physical wire: the BUCKETED padded codes + scales the collectives
        actually gather each round (``comm.accounting.
        tree_bucketed_wire_bytes_per_server`` — one code buffer + one
        scale buffer for the whole tree) — the ledger then reports bytes
        the interconnect really moved, cross-checked against compiled-HLO
        operand shapes in ``tests/test_wire.py``."""
        m = self.topo.num_servers
        if m not in self._row_bytes:
            server_abs = jax.eval_shape(
                lambda t: jax.tree.map(lambda x: x[:, 0], t),
                state.client_params)
            wire, wire_block = dfl.active_wire(self.cfg)
            if wire == "physical":
                row = tree_bucketed_wire_bytes_per_server(
                    self._compressor, server_abs, wire_block)
            else:
                row = tree_wire_bytes_per_server(self._compressor,
                                                 server_abs)
            self._row_bytes[m] = (
                row,
                sum(int(np.prod(l.shape[1:]))
                    for l in jax.tree.leaves(server_abs)))
        return self._row_bytes[m]

    # -- compiled-step cache -------------------------------------------------
    def _step(self) -> Callable:
        m = self.topo.num_servers
        if m not in self._steps:
            cfg = dataclasses.replace(self.cfg, topology=self.topo)
            # donate the carried state: without this every dynamic epoch
            # holds TWO full copies of client params + optimizer state (the
            # static trainer path has always donated — train.py)
            self._steps[m] = jax.jit(dfl.build_dfl_epoch_step(
                cfg, self.loss_fn, self.optimizer), donate_argnums=(0,))
        return self._steps[m]

    def _super_step(self, k: int) -> Callable:
        """The jitted fused K-epoch megastep for the current federation
        size, cached per (M, K) — same donation as ``_step`` (the carried
        state is consumed by the scan)."""
        m = self.topo.num_servers
        key = (m, k)
        if key not in self._super_steps:
            cfg = dataclasses.replace(self.cfg, topology=self.topo)
            self._super_steps[key] = jax.jit(
                overlap.build_dfl_superepoch_step(
                    cfg, self.loss_fn, self.optimizer, k),
                donate_argnums=(0,))
        return self._super_steps[key]

    def compile_counts(self) -> Dict[int, int]:
        """Per federation size M, how many distinct programs the cached
        epoch step has traced.  The dynamic-mode contract is EXACTLY 1:
        the EpochSchedule operand is traced, so mask/mixing/byz variation
        must never change the trace signature.  A count above 1 means a
        schedule operand leaked into trace structure (weak-type flip,
        rank change, Python scalar) and every epoch silently recompiles —
        the regression ``analysis.contracts.audit_engine_retrace`` gates
        on this surface."""
        return {m: int(step._cache_size())
                for m, step in self._steps.items()}

    def superepoch_compile_counts(self) -> Dict[Tuple[int, int], int]:
        """Per (M, K), how many distinct programs the cached megastep has
        traced — the superepoch twin of ``compile_counts`` with the same
        EXACTLY-1 contract: the stacked ``EpochScheduleBatch`` is traced,
        so no block's operand values may change the trace signature."""
        return {key: int(step._cache_size())
                for key, step in self._super_steps.items()}

    # -- fault surgery -------------------------------------------------------
    def _drop(self, state: dfl.DFLState, server: int) -> dfl.DFLState:
        """Remove ORIGINAL server id ``server`` from the federation."""
        if server not in self.alive:
            raise ValueError(f"server {server} is not alive")
        pos = self.alive.index(server)
        self.topo, keep = self.topo.drop_server(pos)
        self.alive.pop(pos)
        keep = np.asarray(keep)

        def leaf(x):
            if x.ndim >= 1 and x.shape[0] == keep.size + 1:
                return x[keep]
            return x
        state = dfl.DFLState(
            jax.tree.map(leaf, state.client_params),
            jax.tree.map(leaf, state.opt_state),
            state.epoch, state.rng)
        self._tracker = self._fresh_tracker()
        return self._reset_ef_residual(self._reset_psum_weight(state))

    def _rejoin(self, state: dfl.DFLState, server: Optional[int]) -> dfl.DFLState:
        """ORIGINAL server ``server`` re-enters with the survivor-mean
        model.  Fresh ids are rejected: client data ownership is keyed by
        original identity (``BatchFn``), so a server that never existed has
        no data shard — admitting one would crash (or silently alias
        another server's shard) at the first ``batch_fn`` call."""
        if server is None or not 0 <= server < self._initial_m:
            raise ValueError(
                f"rejoin needs an ORIGINAL server id in [0, "
                f"{self._initial_m}) — got {server!r}; a fresh server has "
                f"no data shard (data follows original identity, see "
                f"FaultSchedule.validate)")
        if server in self.alive:
            raise ValueError(f"server {server} is already alive")
        self.topo, idx = self.topo.rejoin_server()
        self.alive.append(server)

        def leaf(x):
            if x.ndim >= 1 and x.shape[0] == idx:
                new_row = x.mean(axis=0, keepdims=True).astype(x.dtype)
                return jnp.concatenate([x, new_row], axis=0)
            return x
        state = dfl.DFLState(
            jax.tree.map(leaf, state.client_params),
            jax.tree.map(leaf, state.opt_state),
            state.epoch, state.rng)
        self._tracker = self._fresh_tracker()
        return self._reset_ef_residual(self._reset_psum_weight(state))

    def apply_faults(self, state: dfl.DFLState, epoch: int) -> dfl.DFLState:
        for ev in self.faults.at(epoch):
            if ev.kind == "drop":
                state = self._drop(state, ev.server)
            else:
                state = self._rejoin(state, ev.server)
        return state

    # -- observability -------------------------------------------------------
    def _consensus_probe(self, m: int) -> Optional[Callable]:
        """The jitted consensus-replay timing probe for federation size
        ``m`` (``dfl.build_consensus_replay``), or None when there is no
        consensus period to time.  Built lazily, and only ever reached
        when a span tracer is attached."""
        if m not in self._probes:
            cfg = dataclasses.replace(self.cfg, topology=self.topo)
            fn = dfl.build_consensus_replay(cfg)
            self._probes[m] = None if fn is None else jax.jit(fn)
        return self._probes[m]

    def _trace_step(self, epoch_span, epoch: int, m: int, m_known: bool,
                    programs_before: int, t0: int, t1: int,
                    state: dfl.DFLState, a_np, lam2) -> None:
        """Tracer-only post-step work: emit the compile event if this call
        traced a new program, then split the step's [t0, t1] wall interval
        into local-period / gossip-period spans via the consensus-replay
        probe (re-run the consensus period alone on the post-epoch server
        tree, warmed once per M untimed; its wall time estimates the
        gossip share of the fused step)."""
        tracer = self.obs.tracer
        programs_after = int(self._steps[m]._cache_size())
        if programs_after > programs_before:
            if not m_known and len(self._steps) == 1:
                cause = "first_trace"
            elif not m_known:
                cause = "federation_size_change"
            else:
                # a schedule operand leaked into trace structure — the
                # compile-once contract (compile_counts) is being violated
                cause = "retrace"
            tracer.compile_event(cause, m=m, programs=programs_after,
                                 epoch=epoch)
        probe = self._consensus_probe(m)
        if probe is None:
            tracer.add_span("local-period", t0, t1, parent=epoch_span,
                            epoch=epoch)
            return
        server_tree = jax.tree.map(lambda x: x[:, 0], state.client_params)
        a_j = jnp.asarray(a_np, jnp.float32)
        if m not in self._probe_warm:
            jax.block_until_ready(probe(server_tree, a_j, lam2))
            self._probe_warm.add(m)
        p0 = tracer.now()
        jax.block_until_ready(probe(server_tree, a_j, lam2))
        gossip_ns = min(tracer.now() - p0, t1 - t0)
        split = t1 - gossip_ns
        tracer.add_span("local-period", t0, split, parent=epoch_span,
                        epoch=epoch, method="consensus-replay")
        tracer.add_span("gossip-period", split, t1, parent=epoch_span,
                        epoch=epoch, method="consensus-replay",
                        t_server=self.topo.t_server)

    def _gossip_cal_ns(self, m: int, state: dfl.DFLState, a_np,
                       lam2) -> Optional[int]:
        """ONE-TIME per-M calibration of the gossip-period wall share: time
        the consensus-replay probe once (after an untimed warm-up) and
        cache the result.  Superepoch span attribution reuses this number
        for every epoch of every block at this M instead of re-executing
        the probe per epoch — K probe re-executions per block would cost
        more wall time than the barrier they replace.  ``None`` when there
        is no consensus period to time."""
        if m not in self._probe_cal:
            probe = self._consensus_probe(m)
            if probe is None:
                self._probe_cal[m] = None
            else:
                tracer = self.obs.tracer
                server_tree = jax.tree.map(lambda x: x[:, 0],
                                           state.client_params)
                a_j = jnp.asarray(a_np, jnp.float32)
                jax.block_until_ready(probe(server_tree, a_j, lam2))
                p0 = tracer.now()
                jax.block_until_ready(probe(server_tree, a_j, lam2))
                self._probe_cal[m] = int(tracer.now() - p0)
        return self._probe_cal[m]

    def _trace_superepoch(self, se_span, epoch0: int, k: int, m: int,
                          m_known: bool, programs_before: int, t0: int,
                          t1: int, state: dfl.DFLState, a_np, lam2) -> None:
        """Tracer-only post-dispatch attribution of one fused K-epoch
        megastep: compile event if this dispatch traced a new program, then
        the [t0, t1] wall interval split uniformly into K per-epoch spans,
        each split into local-period / gossip-period via the cached
        ``_gossip_cal_ns`` calibration, and the gossip period further into
        T_S equal ``gossip-round`` child spans (``method=
        "calibrated-round"`` — attribution, not per-round measurement:
        rounds cannot be timed individually inside one compiled program
        without host syncs that would destroy the very overlap being
        measured)."""
        tracer = self.obs.tracer
        programs_after = int(self._super_steps[(m, k)]._cache_size())
        if programs_after > programs_before:
            if not m_known and len(self._super_steps) == 1:
                cause = "first_trace"
            elif not m_known:
                cause = "federation_size_change"
            else:
                cause = "retrace"
            tracer.compile_event(cause, m=m, programs=programs_after,
                                 epoch=epoch0, superepoch=k)
        gossip_ns = self._gossip_cal_ns(m, state, a_np, lam2)
        t_server = self.topo.t_server
        dt = max((t1 - t0) // k, 1)
        for i in range(k):
            e0 = min(t0 + i * dt, t1)
            e1 = t1 if i == k - 1 else min(t0 + (i + 1) * dt, t1)
            ep_span = tracer.add_span("epoch", e0, e1, parent=se_span,
                                      epoch=epoch0 + i,
                                      method="uniform-split")
            if gossip_ns is None:
                tracer.add_span("local-period", e0, e1, parent=ep_span,
                                epoch=epoch0 + i)
                continue
            g = min(gossip_ns, e1 - e0)
            split = e1 - g
            tracer.add_span("local-period", e0, split, parent=ep_span,
                            epoch=epoch0 + i, method="calibrated")
            gp = tracer.add_span("gossip-period", split, e1, parent=ep_span,
                                 epoch=epoch0 + i, method="calibrated",
                                 t_server=t_server)
            rdt = max(g // max(t_server, 1), 1)
            for r in range(t_server):
                r0 = min(split + r * rdt, e1)
                r1 = e1 if r == t_server - 1 else min(split + (r + 1) * rdt,
                                                      e1)
                tracer.add_span("gossip-round", r0, r1, parent=gp,
                                epoch=epoch0 + i, round=r,
                                method="calibrated-round")

    # -- the loop ------------------------------------------------------------
    def run_epoch(self, state: dfl.DFLState, epoch: int,
                  batch_fn: BatchFn) -> Tuple[dfl.DFLState, Dict[str, float]]:
        obs = self.obs
        tracer = obs.tracer
        with obs.span("epoch", epoch=epoch) as epoch_span:
            with obs.span("fault-surgery", epoch=epoch):
                state = self.apply_faults(state, epoch)
            m, n = self.topo.num_servers, self.topo.clients_per_server
            mask_np = self.participation.mask(epoch, m, n)
            a_np = self.topology_schedule.mixing(self.topo, epoch)
            sigma_prod = self._tracker.update(a_np, self.topo.t_server)
            batches = batch_fn(epoch, tuple(self.alive))
            lam2 = (jnp.float32(tp.lambda_2(a_np)) if self._needs_spectral
                    else None)
            byz_np = None
            if self.cfg.byzantine is not None and self.cfg.byzantine.attacks:
                # per-row attack codes over the CURRENT federation: original
                # attacker ids (stable across surgery — drawn over the
                # ORIGINAL size) mapped through the alive row order.  The
                # array is passed every epoch, all-zero included, so the
                # compiled step's operand structure never changes.
                byz_np = self.cfg.byzantine.codes(epoch, tuple(self.alive),
                                                  self._initial_m)
            sched = EpochSchedule(jnp.asarray(mask_np, jnp.float32),
                                  jnp.asarray(a_np, jnp.float32), lam2,
                                  None if byz_np is None
                                  else jnp.asarray(byz_np, jnp.int32))
            epoch_wire_bytes = None
            if self._bytes is not None:
                row_bytes, elems = self._wire_row_bytes(state)
                epoch_wire_bytes = self._bytes.update(
                    a_np, self.topo.t_server, row_bytes=row_bytes,
                    elems_per_row=elems)
            m_known = m in self._steps
            step = self._step()
            # the tracer's sync point lives strictly OUTSIDE the compiled
            # program and exists ONLY when a tracer is attached; the
            # untraced path dispatches exactly as before
            programs_before = int(step._cache_size()) if tracer else 0
            t0 = tracer.now() if tracer else 0
            state, metrics = step(state, batches, sched)
            if tracer is not None:
                jax.block_until_ready(state)
                self._trace_step(epoch_span, epoch, m, m_known,
                                 programs_before, t0, tracer.now(), state,
                                 a_np, lam2)
            with obs.span("host-aggregation", epoch=epoch):
                # ONE device->host transfer for the whole metrics struct:
                # the old per-field float(...)/np.asarray reads each issued
                # their own blocking transfer (5 syncs per epoch on the
                # push-sum + screen path) — everything below is numpy
                metrics_h, psw_h = self._device_get(
                    (metrics, state.psum_weight))
                # participant-weighted loss of the last local iteration
                last = np.asarray(metrics_h.loss[-1], np.float32)
                w = mask_np if mask_np.sum() else np.ones_like(mask_np)
                record = {
                    "loss": float((last * w).sum() / w.sum()),
                    "disagreement": float(metrics_h.server_disagreement),
                    "drift": float(metrics_h.client_drift),
                    "participation": float(mask_np.mean()),
                    "num_servers": float(m),
                    "sigma_prod": sigma_prod,
                }
                if byz_np is not None:
                    # fraction of the CURRENT federation attacking this
                    # epoch — the honest-metric masks in tests/benchmarks
                    # key off this
                    record["byzantine"] = float((byz_np > 0).mean())
                if psw_h is not None:
                    # ratio-consensus conditioning: a terminal weight near
                    # 0 means that server's num/w read-out amplified
                    # rounding error
                    record["psum_min_weight"] = float(np.min(psw_h))
                if epoch_wire_bytes is not None:
                    # this epoch's on-wire consensus traffic + the
                    # cumulative compression ratio vs f32 replicas over the
                    # same links.  THIS epoch's update() return, never
                    # history[-1]: an epoch with zero gossip rounds
                    # (t_server=0, or M==1 after drop surgery) still
                    # records its true 0.0 rather than a stale entry — and
                    # never touches an empty history
                    record["wire_mb"] = epoch_wire_bytes / 1e6
                    record["wire_ratio"] = self._bytes.ratio()
                screen_per_round = None
                if metrics_h.screen_rejected is not None:
                    # robust-screen activity, normalised per gossip round;
                    # the per-server breakdown goes to the hub as a
                    # labelled histogram below
                    rounds = max(self.topo.t_server, 1)
                    screen_per_round = (
                        np.asarray(metrics_h.screen_rejected, np.float32)
                        / rounds)
                    record["screen_rejected"] = float(
                        screen_per_round.sum())
            obs.observe(
                epoch, record, servers=tuple(self.alive),
                per_link=(self._bytes.per_link
                          if self._bytes is not None else None),
                screen_rejected=screen_per_round)
        return state, record

    # -- superepoch dispatch -------------------------------------------------
    def _plan_blocks(self, epochs: int) -> List[Tuple[int, int]]:
        """Cut ``[0, epochs)`` into superepoch dispatch blocks: maximal runs
        of at most ``self.superepoch`` epochs that contain no fault epoch in
        their interior.  Fault surgery changes array shapes, so a fault
        epoch must sit at a block START (where ``run_superepoch`` applies
        surgery before materializing the block's operands) — the tail block
        and the pre-fault remainder are simply shorter, hitting a smaller-K
        megastep cache entry."""
        cuts = {0, epochs}
        cuts.update(ev.epoch for ev in self.faults.events
                    if 0 < ev.epoch < epochs)
        blocks: List[Tuple[int, int]] = []
        ordered = sorted(cuts)
        for lo, hi in zip(ordered[:-1], ordered[1:]):
            e = lo
            while e < hi:
                k = min(self.superepoch, hi - e)
                blocks.append((e, k))
                e += k
        return blocks

    def run_superepoch(
            self, state: dfl.DFLState, epoch0: int, k: int,
            batch_fn: BatchFn) -> Tuple[dfl.DFLState, List[Dict[str, float]]]:
        """Dispatch epochs ``[epoch0, epoch0 + k)`` as ONE fused megastep.

        Host-side schedule generation runs up front for the whole block —
        participation masks, mixing matrices, byzantine codes, contraction
        tracking, batches — then the stacked operands cross to the device
        once, K epochs execute inside one compiled program, and the stacked
        metrics come back in one ``_device_get``.  The per-epoch records
        are built from the SAME formulas as ``run_epoch`` over the stacked
        arrays, so ``run(superepoch=K)`` history is element-identical to
        the barrier loop's (``tests/test_overlap.py``)."""
        obs = self.obs
        tracer = obs.tracer
        with obs.span("superepoch", epoch=epoch0, k=k) as se_span:
            with obs.span("fault-surgery", epoch=epoch0):
                state = self.apply_faults(state, epoch0)
            m, n = self.topo.num_servers, self.topo.clients_per_server
            # pre-materialize the block: one host-side pass per epoch, no
            # device work — the schedules are plain numpy until stacked
            scheds: List[EpochSchedule] = []
            batch_list: List[Any] = []
            sigma_list: List[float] = []
            lam2_last = None
            for i in range(k):
                e = epoch0 + i
                mask_np = self.participation.mask(e, m, n)
                a_np = self.topology_schedule.mixing(self.topo, e)
                sigma_list.append(self._tracker.update(a_np,
                                                       self.topo.t_server))
                lam2 = (np.float32(tp.lambda_2(a_np))
                        if self._needs_spectral else None)
                lam2_last = lam2
                byz_np = None
                if (self.cfg.byzantine is not None
                        and self.cfg.byzantine.attacks):
                    byz_np = self.cfg.byzantine.codes(
                        e, tuple(self.alive), self._initial_m)
                scheds.append(EpochSchedule(mask_np, a_np, lam2, byz_np))
                batch_list.append(batch_fn(e, tuple(self.alive)))
            sb = overlap.stack_epoch_schedules(scheds)
            sched = overlap.EpochScheduleBatch(
                jnp.asarray(sb.mask), jnp.asarray(sb.mixing),
                None if sb.lam2 is None else jnp.asarray(sb.lam2),
                None if sb.byz is None else jnp.asarray(sb.byz))
            batches = jax.tree.map(lambda *xs: jnp.stack(xs), *batch_list)
            wire = None
            if self._bytes is not None:
                row_bytes, elems = self._wire_row_bytes(state)
                wire = self._bytes.update_many(
                    [s.mixing for s in scheds], self.topo.t_server,
                    row_bytes=row_bytes, elems_per_row=elems)
            m_known = (m, k) in self._super_steps
            step = self._super_step(k)
            programs_before = int(step._cache_size()) if tracer else 0
            t0 = tracer.now() if tracer else 0
            state, metrics, psw = step(state, batches, sched)
            if tracer is not None:
                jax.block_until_ready(state)
                self._trace_superepoch(
                    se_span, epoch0, k, m, m_known, programs_before, t0,
                    tracer.now(), state, scheds[-1].mixing,
                    None if lam2_last is None else jnp.float32(lam2_last))
            records: List[Tuple[Dict[str, float], Optional[np.ndarray]]] = []
            with obs.span("host-aggregation", epoch=epoch0, k=k):
                # the block's ONLY device->host transfer: K epochs of
                # stacked metrics + the (K, M) push-sum weight trace
                metrics_h, psw_h = self._device_get((metrics, psw))
                rounds = max(self.topo.t_server, 1)
                for i in range(k):
                    mask_np = scheds[i].mask
                    byz_np = scheds[i].byz
                    last = np.asarray(metrics_h.loss[i][-1], np.float32)
                    w = (mask_np if mask_np.sum()
                         else np.ones_like(mask_np))
                    record = {
                        "loss": float((last * w).sum() / w.sum()),
                        "disagreement": float(
                            metrics_h.server_disagreement[i]),
                        "drift": float(metrics_h.client_drift[i]),
                        "participation": float(mask_np.mean()),
                        "num_servers": float(m),
                        "sigma_prod": sigma_list[i],
                    }
                    if byz_np is not None:
                        record["byzantine"] = float((byz_np > 0).mean())
                    if psw_h is not None:
                        record["psum_min_weight"] = float(
                            np.min(psw_h[i]))
                    if wire is not None:
                        epoch_bytes, ratio_after, _ = wire[i]
                        record["wire_mb"] = epoch_bytes / 1e6
                        record["wire_ratio"] = ratio_after
                    screen_per_round = None
                    if metrics_h.screen_rejected is not None:
                        screen_per_round = (
                            np.asarray(metrics_h.screen_rejected[i],
                                       np.float32) / rounds)
                        record["screen_rejected"] = float(
                            screen_per_round.sum())
                    records.append((record, screen_per_round))
            for i, (record, screen_per_round) in enumerate(records):
                obs.observe(
                    epoch0 + i, record, servers=tuple(self.alive),
                    per_link=(wire[i][2] if wire is not None else None),
                    screen_rejected=screen_per_round)
        return state, [r for r, _ in records]

    def run(self, state: dfl.DFLState, epochs: int,
            batch_fn: BatchFn) -> Tuple[dfl.DFLState, Dict[str, List[float]]]:
        history: Dict[str, List[float]] = {}
        if self.superepoch <= 1:
            for epoch in range(epochs):
                state, rec = self.run_epoch(state, epoch, batch_fn)
                for key, v in rec.items():
                    history.setdefault(key, []).append(v)
            return state, history
        for epoch0, k in self._plan_blocks(epochs):
            state, recs = self.run_superepoch(state, epoch0, k, batch_fn)
            for rec in recs:
                for key, v in rec.items():
                    history.setdefault(key, []).append(v)
        return state, history


def make_engine(topology: FLTopology, loss_fn: dfl.LossFn,
                optimizer: Optimizer, *,
                consensus_mode: str = "gossip",
                participation: Optional[ParticipationSchedule] = None,
                topology_schedule: Optional[TopologySchedule] = None,
                faults: Optional[FaultSchedule] = None,
                obs: Optional[Any] = None,
                superepoch: int = 1,
                **cfg_kw) -> DynamicFederationEngine:
    """Convenience constructor mirroring ``DFLConfig`` defaults.

    Any extra keyword (``mixing``, ``metrics``, ``grad_microbatches``, ...)
    is forwarded to ``DFLConfig``; ``dynamic=True`` is always set.  Typical
    usage on the paper's Sec.-IV regression task::

        from repro.core import (FLTopology, FaultSchedule,
                                ParticipationSchedule, TopologySchedule,
                                init_dfl_state, make_engine)
        from repro.data import make_regression_task
        from repro.optim import sgd
        import jax, jax.numpy as jnp

        topo = FLTopology(num_servers=5, clients_per_server=5,
                          t_client=25, t_server=10, graph_kind="ring")
        task = make_regression_task(topo, seed=0)
        engine = make_engine(
            topo, task["loss_fn"], sgd(1e-3),
            participation=ParticipationSchedule(kind="bernoulli", rate=0.5),
            topology_schedule=TopologySchedule(kind="edge_drop",
                                              drop_prob=0.3),
            faults=FaultSchedule.parse("drop:10:2,rejoin:25:2"))
        state = init_dfl_state(engine.cfg, jnp.zeros((2,)), sgd(1e-3),
                               jax.random.key(0))
        state, history = engine.run(state, epochs=40, batch_fn=task["batch_fn"])

    ``history`` maps metric name -> per-epoch list (loss, disagreement,
    drift, participation, num_servers, sigma_prod, psum_min_weight under
    ``mixing="push_sum"``, wire_mb / wire_ratio under compressed
    consensus — ``DFLConfig.compression`` — and byzantine, the attacking
    fraction, under a ``byzantine=ByzantineSchedule(...)`` keyword, which
    forwards to ``DFLConfig.byzantine`` like any other config field).

    ``obs`` attaches a ``repro.obs.Observability`` bundle (span tracing +
    metric sinks + convergence watchdogs); omitted, the engine runs with
    the no-op null bundle — see docs/observability.md.

    ``superepoch=K`` is an ENGINE knob, not a ``DFLConfig`` field: it fuses
    K epochs per compiled dispatch (``overlap.build_dfl_superepoch_step``)
    without changing the per-epoch math — history is element-identical at
    any K.  Contrast ``staleness`` (a ``DFLConfig`` field forwarded through
    ``cfg_kw``), which DOES change the consensus operator."""
    cfg = dfl.DFLConfig(topology=topology, consensus_mode=consensus_mode,
                        dynamic=True, **cfg_kw)
    return DynamicFederationEngine(
        cfg, loss_fn, optimizer,
        participation=participation or ParticipationSchedule(),
        topology_schedule=topology_schedule or TopologySchedule(),
        faults=faults or FaultSchedule(), obs=obs, superepoch=superepoch)
