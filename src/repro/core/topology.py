"""Server graphs and FL topology.

The paper (Sec. II-A) models inter-server communication as a connected
undirected graph ``G``.  This module builds the standard graph families used
in the simulations and in our benchmarks, derives doubly-stochastic mixing
matrices ``A`` satisfying Eq. (6), and computes the contraction factor

    sigma_A = || A^{T_S} - (1/M) 11' ||_2

that drives Theorem 1.  It also implements *graph surgery* — removing a
failed server and re-deriving a valid mixing matrix — which is the
fault-tolerance story of the multi-server design.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import numpy as np

# ---------------------------------------------------------------------------
# graph construction
# ---------------------------------------------------------------------------


def ring_graph(m: int) -> np.ndarray:
    """Adjacency of a ring (cycle) over ``m`` servers (no self loops)."""
    if m < 2:
        return np.zeros((m, m), dtype=bool)
    adj = np.zeros((m, m), dtype=bool)
    idx = np.arange(m)
    adj[idx, (idx + 1) % m] = True
    adj[(idx + 1) % m, idx] = True
    return adj


def complete_graph(m: int) -> np.ndarray:
    adj = np.ones((m, m), dtype=bool)
    np.fill_diagonal(adj, False)
    return adj


def star_graph(m: int) -> np.ndarray:
    """Server 0 is the hub (degenerates to hierarchical FL — used as the
    baseline topology the paper argues against)."""
    adj = np.zeros((m, m), dtype=bool)
    adj[0, 1:] = True
    adj[1:, 0] = True
    return adj


def line_graph(m: int) -> np.ndarray:
    adj = np.zeros((m, m), dtype=bool)
    i = np.arange(m - 1)
    adj[i, i + 1] = True
    adj[i + 1, i] = True
    return adj


def erdos_renyi_graph(m: int, p: float, seed: int = 0) -> np.ndarray:
    """Random connected graph: sample until connected (adds a ring as a
    fallback spanning structure after 100 tries)."""
    rng = np.random.default_rng(seed)
    for _ in range(100):
        upper = rng.random((m, m)) < p
        adj = np.triu(upper, 1)
        adj = adj | adj.T
        if is_connected(adj):
            return adj
    return ring_graph(m) | adj


def torus_2d_graph(rows: int, cols: int) -> np.ndarray:
    """2-D torus — matches the physical ICI topology of a TPU pod slice, so
    gossip edges ride single physical links."""
    m = rows * cols
    adj = np.zeros((m, m), dtype=bool)
    for r in range(rows):
        for c in range(cols):
            i = r * cols + c
            for dr, dc in ((1, 0), (0, 1)):
                j = ((r + dr) % rows) * cols + (c + dc) % cols
                if i != j:
                    adj[i, j] = adj[j, i] = True
    return adj


# ---------------------------------------------------------------------------
# directed graphs (adj[i, j] = True means a link i -> j exists)
#
# An undirected graph is the special case adj == adj.T; everything below
# also accepts that, treating each undirected edge as a bidirectional pair.
# ---------------------------------------------------------------------------


def directed_ring(m: int) -> np.ndarray:
    """Directed cycle 0 -> 1 -> ... -> m-1 -> 0 (strongly connected; its
    out-degree matrix happens to be doubly stochastic because every node has
    out-degree exactly 1 — add chords or drop directions to break that)."""
    adj = np.zeros((m, m), dtype=bool)
    if m >= 2:
        idx = np.arange(m)
        adj[idx, (idx + 1) % m] = True
    return adj


def is_directed(adj: np.ndarray) -> bool:
    """True when some link exists in only one direction."""
    return bool((adj != adj.T).any())


def is_strongly_connected(adj: np.ndarray) -> bool:
    """Directed Assumption-1 check: every server reaches every other along
    link directions.  BFS from node 0 along out-edges and along in-edges
    (reachability in the reverse graph); both covering all nodes is
    equivalent to strong connectivity.  Degenerates to ``is_connected`` on a
    symmetric adjacency."""
    if not is_directed(adj):
        return is_connected(adj)
    return _reaches_all(adj) and _reaches_all(adj.T)


def _reaches_all(adj: np.ndarray) -> bool:
    m = adj.shape[0]
    if m == 0:
        return False
    seen = np.zeros(m, dtype=bool)
    seen[0] = True
    frontier = [0]
    while frontier:
        nxt = []
        for v in frontier:
            for u in np.nonzero(adj[v])[0]:
                if not seen[u]:
                    seen[u] = True
                    nxt.append(u)
        frontier = nxt
    return bool(seen.all())


def random_orientation(adj: np.ndarray, rng: np.random.Generator,
                       ensure_strong: bool = True) -> np.ndarray:
    """Randomly orient every undirected edge (keep exactly one direction).

    Models the realistic degraded regime where each physical link works in
    only one direction.  With ``ensure_strong`` the orientation is repaired
    by re-adding reverse directions (in random order) until the digraph is
    strongly connected — push-sum's Assumption-1 analogue."""
    iu, ju = np.nonzero(np.triu(adj | adj.T, 1))
    out = np.zeros_like(adj)
    flip = rng.random(iu.size) < 0.5
    out[np.where(flip, iu, ju), np.where(flip, ju, iu)] = True
    if ensure_strong and adj.shape[0] > 1 and not is_strongly_connected(out):
        order = rng.permutation(iu.size)
        for e in order:
            out[iu[e], ju[e]] = out[ju[e], iu[e]] = True
            if is_strongly_connected(out):
                break
    return out


def random_direction_drop(adj: np.ndarray, drop_prob: float,
                          rng: np.random.Generator,
                          ensure_strong: bool = True) -> np.ndarray:
    """Asymmetric link degradation: drop each DIRECTION of each edge
    independently with probability ``drop_prob`` — the failure mode (radio
    interference, one-sided congestion) that breaks the symmetry Eq. 6
    assumes.  An edge can lose one direction (becomes directed), both
    (vanishes), or neither.  With ``ensure_strong`` dropped directions are
    re-added (random order) until the digraph is strongly connected.

    Works on directed bases too: only directions PRESENT in ``adj`` are
    candidates (a symmetric adjacency already lists both directions of
    every edge as separate nonzero entries), so degradation can never add
    a reverse link the base graph does not have."""
    di, dj = np.nonzero(adj)
    keep = rng.random(di.size) >= drop_prob
    out = np.zeros_like(adj)
    out[di[keep], dj[keep]] = True
    if ensure_strong and adj.shape[0] > 1 and not is_strongly_connected(out):
        dropped = np.nonzero(~keep)[0]
        rng.shuffle(dropped)
        for e in dropped:
            out[di[e], dj[e]] = True
            if is_strongly_connected(out):
                break
    return out


GRAPH_BUILDERS = {
    "ring": ring_graph,
    "complete": complete_graph,
    "star": star_graph,
    "line": line_graph,
    "directed_ring": directed_ring,
}


def build_graph(kind: str, m: int, **kw) -> np.ndarray:
    if kind == "erdos_renyi":
        return erdos_renyi_graph(m, kw.get("p", 0.5), kw.get("seed", 0))
    if kind == "random_orientation":
        # one-way degraded links: a random strongly-connected orientation of
        # an undirected base family (the generic non-doubly-stochasticisable
        # directed scenario; out-degrees are unequal, so naive row-stochastic
        # gossip on it is biased — see consensus.gossip_push_sum)
        base = build_graph(kw.get("base", "complete"), m)
        return random_orientation(base, np.random.default_rng(kw.get("seed", 0)))
    if kind == "torus":
        rows = kw.get("rows")
        if rows is not None:
            if m % rows:
                raise ValueError(f"torus rows={rows} does not divide M={m}")
        else:
            # largest divisor <= sqrt(M), so the node count is always M even
            # after graph surgery changes M (rows=1 degenerates to a ring —
            # the natural torus of a prime server count)
            rows = max(r for r in range(1, int(np.sqrt(m)) + 1) if m % r == 0)
        return torus_2d_graph(rows, m // rows)
    return GRAPH_BUILDERS[kind](m)


def is_connected(adj: np.ndarray) -> bool:
    """Assumption 1 check (BFS)."""
    m = adj.shape[0]
    if m == 0:
        return False
    if m == 1:
        return True
    seen = np.zeros(m, dtype=bool)
    frontier = [0]
    seen[0] = True
    while frontier:
        nxt = []
        for v in frontier:
            for u in np.nonzero(adj[v])[0]:
                if not seen[u]:
                    seen[u] = True
                    nxt.append(u)
        frontier = nxt
    return bool(seen.all())


# ---------------------------------------------------------------------------
# mixing matrices  (Eq. 6: doubly stochastic, support = G + self loops,
#                   positive entries bounded below by alpha)
# ---------------------------------------------------------------------------


def metropolis_weights(adj: np.ndarray) -> np.ndarray:
    """Metropolis–Hastings weights: symmetric, doubly stochastic, positive on
    the diagonal for any connected graph — the standard constructive choice
    satisfying Eq. (6)."""
    m = adj.shape[0]
    deg = adj.sum(1)
    a = np.zeros((m, m))
    for i in range(m):
        for j in np.nonzero(adj[i])[0]:
            a[i, j] = 1.0 / (1.0 + max(deg[i], deg[j]))
        a[i, i] = 1.0 - a[i].sum()
    return a


def uniform_weights(adj: np.ndarray) -> np.ndarray:
    """Equal-neighbour weights 1/(max_deg+1) — also doubly stochastic."""
    m = adj.shape[0]
    dmax = int(adj.sum(1).max()) if m else 0
    a = adj.astype(float) / (dmax + 1)
    np.fill_diagonal(a, 0.0)
    a += np.diag(1.0 - a.sum(1))
    return a


def check_mixing_matrix(a: np.ndarray, adj: Optional[np.ndarray] = None,
                        atol: float = 1e-10) -> None:
    """Validate Eq. (6): row/col sums 1, non-negative, support matches G."""
    m = a.shape[0]
    if not np.allclose(a.sum(0), 1.0, atol=atol):
        raise ValueError("columns must sum to 1")
    if not np.allclose(a.sum(1), 1.0, atol=atol):
        raise ValueError("rows must sum to 1")
    if (a < -atol).any():
        raise ValueError("entries must be non-negative")
    if adj is not None:
        off = ~np.eye(m, dtype=bool)
        if ((a > atol) & off & ~adj).any():
            raise ValueError("positive weight on a non-edge")


def out_degree_weights(adj: np.ndarray) -> np.ndarray:
    """Row-stochastic mixing weights for a (possibly directed) graph:

        a[i, j] = 1 / (1 + outdeg(i))   for each link i -> j,
        a[i, i] = 1 / (1 + outdeg(i)),

    the directed analogue of ``uniform_weights``: node i splits its mass
    uniformly over its out-neighbourhood plus itself, using only LOCAL
    out-degree knowledge.  Rows always sum to 1; columns sum to 1 only when
    every node has equal out-degree (e.g. a plain directed ring), so in
    general this matrix is NOT doubly stochastic: applied naively
    (``consensus.gossip_scan``) it drives all servers to the Perron-weighted
    average ``pi' W`` rather than the uniform mean — the bias push-sum
    (``consensus.gossip_push_sum``) corrects."""
    m = adj.shape[0]
    a = np.zeros((m, m))
    outdeg = adj.sum(1)
    for i in range(m):
        share = 1.0 / (1.0 + outdeg[i])
        a[i, np.nonzero(adj[i])[0]] = share
        a[i, i] = share
    return a


def check_row_stochastic(a: np.ndarray, adj: Optional[np.ndarray] = None,
                         atol: float = 1e-10) -> None:
    """Validate a directed-gossip mixing matrix: rows sum to 1, entries
    non-negative, positive diagonal (aperiodicity / self-loops), and support
    inside the directed graph when ``adj`` is given.  The column-sum clause
    of Eq. 6 is deliberately NOT required — that is the point of the
    directed regime."""
    m = a.shape[0]
    if not np.allclose(a.sum(1), 1.0, atol=atol):
        raise ValueError("rows must sum to 1")
    if (a < -atol).any():
        raise ValueError("entries must be non-negative")
    if (np.diag(a) <= atol).any():
        raise ValueError("diagonal must be positive (self-loops)")
    if adj is not None:
        off = ~np.eye(m, dtype=bool)
        if ((a > atol) & off & ~adj).any():
            raise ValueError("positive weight on a non-edge")


def perron_weights(a: np.ndarray) -> np.ndarray:
    """The left Perron vector pi of a row-stochastic A (pi' A = pi',
    pi >= 0, sum pi = 1): the stationary weighting that naive gossip
    converges to (``A^t -> 1 pi'``).  Uniform iff A is doubly stochastic."""
    ev, vec = np.linalg.eig(np.asarray(a, np.float64).T)
    k = int(np.argmin(np.abs(ev - 1.0)))
    pi = np.real(vec[:, k])
    pi = np.abs(pi)
    return pi / pi.sum()


def push_sum_deviation(p: np.ndarray) -> float:
    """Contraction of the push-sum RATIO map after mixing with a
    column-stochastic product ``P``: each server's ratio is

        z_i = (P x)_i / (P 1)_i = (row-normalised P · x)_i,

    so the effective averaging operator on the values is P with each row
    divided by its sum — row-stochastic by construction — and its distance
    to exact averaging is ``||rownorm(P) - 11'/M||_2``.  As P approaches its
    rank-one limit ``v 1'`` (column sums are preserved, so sum v = 1) the
    row-normalisation cancels v exactly and this deviation -> 0: the ratio
    is unbiased even though P itself never approaches ``11'/M``."""
    rows = p.sum(1, keepdims=True)
    if (rows <= 0).any():
        raise ValueError("push-sum product has a non-positive weight row")
    return consensus_deviation(p / rows)


def sigma_push_sum(a: np.ndarray, t_s: int) -> float:
    """Push-sum analogue of ``sigma_a``: contraction of the ratio map after
    T_S rounds of mixing with ``P = A'`` (the column-stochastic transpose of
    the row-stochastic A — see ``consensus.gossip_push_sum``)."""
    p = np.linalg.matrix_power(np.asarray(a, np.float64).T, t_s)
    return push_sum_deviation(p)


def consensus_deviation(p: np.ndarray) -> float:
    """||P - (1/M) 11'||_2: how far a (product of) mixing matrices is from
    exact averaging — the common kernel of sigma_a / sigma_product /
    schedule.SigmaTracker."""
    m = p.shape[0]
    return float(np.linalg.norm(p - np.ones((m, m)) / m, ord=2))


def sigma_a(a: np.ndarray, t_s: int) -> float:
    """sigma_A = ||A^{T_S} - (1/M) 11'||_2  (spectral norm) — the consensus
    contraction factor of Lemma 1."""
    return consensus_deviation(np.linalg.matrix_power(a, t_s))


def sigma_product(a_list: Sequence[np.ndarray], t_s: int) -> float:
    """Contraction of a time-varying consensus run: with mixing matrix A_p in
    epoch p applied for T_S rounds each, disagreement contracts by

        || prod_p A_p^{T_S} - (1/M) 11' ||_2

    (each A_p is doubly stochastic, so the product fixes the mean and the
    deviation subspace contracts multiplicatively).  The per-epoch sigma_A of
    Lemma 1 is the single-matrix special case."""
    if not len(a_list):
        raise ValueError("need at least one mixing matrix")
    prod = np.eye(a_list[0].shape[0])
    for a in a_list:
        prod = np.linalg.matrix_power(np.asarray(a, np.float64), t_s) @ prod
    return consensus_deviation(prod)


def drop_edges(adj: np.ndarray, edges: Sequence[Tuple[int, int]]) -> np.ndarray:
    """Remove undirected edges from an adjacency (no-op on non-edges)."""
    out = adj.copy()
    for i, j in edges:
        out[i, j] = out[j, i] = False
    return out


def random_edge_drop(adj: np.ndarray, drop_prob: float,
                     rng: np.random.Generator,
                     ensure_connected: bool = True) -> np.ndarray:
    """Per-epoch link failures: drop each edge independently with probability
    ``drop_prob``.  With ``ensure_connected`` the dropped graph is repaired by
    re-adding removed edges (in random order) until connected again — the
    'degraded but jointly connected' regime where Assumption 1 still holds
    per epoch; without it the graph may transiently disconnect and only the
    *product* contraction (``sigma_product``) is meaningful."""
    m = adj.shape[0]
    iu, ju = np.nonzero(np.triu(adj, 1))
    keep = rng.random(iu.size) >= drop_prob
    out = np.zeros_like(adj)
    out[iu[keep], ju[keep]] = True
    out |= out.T
    if ensure_connected and m > 1 and not is_connected(out):
        dropped = list(np.nonzero(~keep)[0])
        rng.shuffle(dropped)
        for e in dropped:
            out[iu[e], ju[e]] = out[ju[e], iu[e]] = True
            if is_connected(out):
                break
    return out


def weaken_directed_links(a: np.ndarray,
                          links: Sequence[Tuple[int, int]],
                          factor: float) -> np.ndarray:
    """Directed-straggler degradation: scale each listed link DIRECTION
    ``i -> j`` (entry ``a[i, j]`` of a row-stochastic mixing matrix) by
    ``(1 - factor)``, returning the removed mass to the SENDER's self-loop
    ``a[i, i]``.  Rows keep summing to 1, so the result is still a valid
    push-sum operator (its column-stochastic transpose preserves sums and
    the ratio read-out stays unbiased); columns change freely — that
    one-sided asymmetry is exactly what this models and what plain gossip
    cannot absorb.  The directed counterpart of ``weaken_links`` (which
    rebalances BOTH endpoints to preserve symmetry)."""
    if not 0.0 <= factor <= 1.0:
        raise ValueError("weaken factor must be in [0, 1]")
    out = np.asarray(a, np.float64).copy()
    for i, j in links:
        if i == j:
            raise ValueError("cannot weaken a self-loop")
        delta = factor * out[i, j]
        out[i, j] -= delta
        out[i, i] += delta
    return out


def weaken_links(a: np.ndarray, edges: Sequence[Tuple[int, int]],
                 factor: float) -> np.ndarray:
    """Straggler-degraded mixing: scale the weight of each listed edge by
    ``(1 - factor)``, returning the removed mass to the two endpoint
    self-loops.  Symmetry and double stochasticity (Eq. 6) are preserved, so
    the result is still a valid — just slower-contracting — consensus
    operator."""
    if not 0.0 <= factor <= 1.0:
        raise ValueError("weaken factor must be in [0, 1]")
    out = np.asarray(a, np.float64).copy()
    for i, j in edges:
        if i == j:
            raise ValueError("cannot weaken a self-loop")
        delta = factor * out[i, j]
        out[i, j] -= delta
        out[j, i] -= delta
        out[i, i] += delta
        out[j, j] += delta
    return out


def lambda_2(a: np.ndarray) -> float:
    """|lambda_2(A)| of a symmetric doubly-stochastic A — the host-side
    per-epoch spectral estimate spectral consensus backends (Chebyshev)
    consume alongside a traced mixing matrix (``schedule.EpochSchedule``)."""
    ev = np.sort(np.abs(np.linalg.eigvalsh(np.asarray(a, np.float64))))[::-1]
    return float(ev[1]) if len(ev) > 1 else 0.0


def spectral_gap(a: np.ndarray) -> float:
    """1 - |lambda_2(A)| for symmetric doubly-stochastic A."""
    return 1.0 - lambda_2(a)


# ---------------------------------------------------------------------------
# FL topology: servers x clients mapped onto mesh replica slots
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class FLTopology:
    """The paper's system model: M servers, N clients each, graph G, epoch
    split (T_C, T_S) — plus the mesh factoring used on hardware."""

    num_servers: int                 # M
    clients_per_server: int          # N
    t_client: int                    # T_C
    t_server: int                    # T_S
    graph_kind: str = "ring"
    mixing: str = "metropolis"       # metropolis | uniform | out_degree
    intra_client_replicas: int = 1   # R: FSDP degree inside one client
    # Explicit adjacency, carried through graph surgery (graph_kind
    # "explicit"): a hashable tuple-of-tuples of bool, row i = out-links of
    # server i.  None for family-built graphs.  drop_server stores the
    # INDUCED subgraph here so removing a server never invents links the
    # survivors do not have (and never resamples a random family).
    explicit_adjacency: Optional[Tuple[Tuple[bool, ...], ...]] = None

    def __post_init__(self):
        if self.num_servers < 1 or self.clients_per_server < 1:
            raise ValueError("need at least 1 server and 1 client")
        if self.t_client < 1 or self.t_server < 0:
            raise ValueError("T_C >= 1, T_S >= 0")
        if self.mixing not in ("metropolis", "uniform", "out_degree"):
            raise ValueError(f"unknown mixing weights {self.mixing!r}")
        if (self.explicit_adjacency is not None) != (
                self.graph_kind == "explicit"):
            raise ValueError("explicit_adjacency and graph_kind='explicit' "
                             "go together: set both (FLTopology."
                             "with_adjacency) or neither")
        adj = self.adjacency()
        if adj.shape[0] != self.num_servers:
            raise ValueError(f"graph family {self.graph_kind!r} built "
                             f"{adj.shape[0]} nodes for M={self.num_servers}")
        if self.num_servers > 1 and not is_strongly_connected(adj):
            raise ValueError("Assumption 1 violated: server graph must be "
                             "(strongly) connected")
        if is_directed(adj) and self.mixing != "out_degree":
            raise ValueError(
                f"graph family {self.graph_kind!r} is directed: symmetric "
                f"{self.mixing!r} weights cannot satisfy Eq. 6 on it — use "
                f"mixing='out_degree' (row-stochastic) with a push-sum "
                f"consensus path")

    # -- graph/mixing --------------------------------------------------------
    def adjacency(self) -> np.ndarray:
        if self.explicit_adjacency is not None:
            return np.asarray(self.explicit_adjacency, dtype=bool)
        return build_graph(self.graph_kind, self.num_servers)

    @staticmethod
    def freeze_adjacency(adj: np.ndarray) -> Tuple[Tuple[bool, ...], ...]:
        """Hashable form of an adjacency matrix (the frozen dataclass must
        stay hashable, so ndarrays cannot be fields)."""
        return tuple(tuple(bool(v) for v in row)
                     for row in np.asarray(adj, dtype=bool))

    def with_adjacency(self, adj: np.ndarray) -> "FLTopology":
        """This topology over an EXPLICIT server graph (the graph-surgery
        carrier): ``num_servers`` follows the matrix, all validation
        (connectivity, directedness vs mixing weights) re-runs."""
        adj = np.asarray(adj, dtype=bool)
        return dataclasses.replace(
            self, num_servers=adj.shape[0], graph_kind="explicit",
            explicit_adjacency=FLTopology.freeze_adjacency(adj))

    @property
    def directed(self) -> bool:
        """True when some server link exists in only one direction (the
        regime where the mixing matrix is row- but not doubly stochastic)."""
        return is_directed(self.adjacency())

    def mixing_matrix(self) -> np.ndarray:
        adj = self.adjacency()
        if self.mixing == "out_degree":
            a = out_degree_weights(adj)
            check_row_stochastic(a, adj)
            return a
        a = metropolis_weights(adj) if self.mixing == "metropolis" else uniform_weights(adj)
        check_mixing_matrix(a, adj)
        return a

    def sigma(self) -> float:
        if self.num_servers == 1:
            return 0.0
        a = self.mixing_matrix()
        if self.mixing == "out_degree":
            # row-stochastic A: the meaningful contraction is that of the
            # push-sum ratio map, not of A^{T_S} itself
            return sigma_push_sum(a, self.t_server)
        return sigma_a(a, self.t_server)

    # -- sizes ---------------------------------------------------------------
    @property
    def num_clients(self) -> int:
        return self.num_servers * self.clients_per_server

    @property
    def epoch_len(self) -> int:  # T_E
        return self.t_client + self.t_server

    @property
    def replica_slots(self) -> int:
        return self.num_clients * self.intra_client_replicas

    # -- Theorem 1 machinery --------------------------------------------------
    def max_step_size(self, mu: float, lsmooth: float) -> float:
        """gamma < min{1/(L T_C), 1/(mu T_C)} (Thm. 1)."""
        return 1.0 / (max(mu, lsmooth) * self.t_client)

    def epsilon_bound(self, gamma: float, mu: float, lsmooth: float,
                      theta: float, w0_disagreement: float = 0.0) -> float:
        """The Thm-1 tolerance  eps = sqrt(M) g th T_C s/(1-s) + Y0/(1-L)."""
        m = self.num_servers
        s = self.sigma()
        tc = self.t_client
        lam = np.sqrt(max(0.0, 1.0 - gamma * mu * tc))
        y0 = ((gamma * tc) ** 2 * theta * lsmooth * (1 + np.sqrt(m) * s / (1 - s))
              + gamma * tc * lsmooth * w0_disagreement)
        return float(np.sqrt(m) * gamma * theta * tc * s / (1 - s) + y0 / (1 - lam))

    # -- fault tolerance -------------------------------------------------------
    def drop_server(self, server_idx: int) -> Tuple["FLTopology", np.ndarray]:
        """Graph surgery after a server failure: remove the node and KEEP
        the induced subgraph if it is still (strongly) connected — carried
        as an explicit adjacency, so no phantom links appear between the
        failed server's neighbours and random families (``erdos_renyi``)
        are never resampled.  When the induced subgraph happens to equal
        the family rebuilt at M-1 (complete minus a node, star minus a
        leaf) the family kind is kept.  If the removal disconnects the
        survivors, fall back to a (directed) ring over them — Assumption 1
        must be restored somehow, and that repair is explicit in the
        returned ``graph_kind``.  Returns (new topology, survivor index
        map)."""
        m = self.num_servers
        if not 0 <= server_idx < m:
            raise ValueError("bad server index")
        if m == 1:
            raise ValueError("cannot drop the only server")
        keep = np.array([i for i in range(m) if i != server_idx])
        sub = self.adjacency()[np.ix_(keep, keep)]
        if not is_strongly_connected(sub):
            fallback = "directed_ring" if self.directed else "ring"
            new = dataclasses.replace(self, num_servers=m - 1,
                                      graph_kind=fallback,
                                      explicit_adjacency=None)
            return new, keep
        if self.explicit_adjacency is None:
            fam = build_graph(self.graph_kind, m - 1)
            if np.array_equal(sub, fam):
                return dataclasses.replace(self, num_servers=m - 1), keep
        return self.with_adjacency(sub), keep

    def rejoin_server(self) -> Tuple["FLTopology", int]:
        """Inverse surgery: a (recovered) server re-enters the federation,
        taking the last index.  For family graphs the family is rebuilt at
        M+1 nodes (the newcomer plugs back into the topology's pattern);
        for an explicit post-surgery graph the newcomer enters fully
        connected to every survivor — it just received the survivor-mean
        model, so links to everyone are the natural bootstrap (and keep the
        graph strongly connected with no further repair).  Returns
        (new topology, insert index)."""
        m = self.num_servers
        if self.explicit_adjacency is None:
            return dataclasses.replace(self, num_servers=m + 1), m
        grown = np.zeros((m + 1, m + 1), dtype=bool)
        grown[:m, :m] = self.adjacency()
        grown[m, :m] = True
        grown[:m, m] = True
        return self.with_adjacency(grown), m
