"""Server graphs and FL topology.

The paper (Sec. II-A) models inter-server communication as a connected
undirected graph ``G``.  This module builds the standard graph families used
in the simulations and in our benchmarks, derives doubly-stochastic mixing
matrices ``A`` satisfying Eq. (6), and computes the contraction factor

    sigma_A = || A^{T_S} - (1/M) 11' ||_2

that drives Theorem 1.  It also implements *graph surgery* — removing a
failed server and re-deriving a valid mixing matrix — which is the
fault-tolerance story of the multi-server design.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import numpy as np

# ---------------------------------------------------------------------------
# graph construction
# ---------------------------------------------------------------------------


def ring_graph(m: int) -> np.ndarray:
    """Adjacency of a ring (cycle) over ``m`` servers (no self loops)."""
    if m < 2:
        return np.zeros((m, m), dtype=bool)
    adj = np.zeros((m, m), dtype=bool)
    idx = np.arange(m)
    adj[idx, (idx + 1) % m] = True
    adj[(idx + 1) % m, idx] = True
    return adj


def complete_graph(m: int) -> np.ndarray:
    adj = np.ones((m, m), dtype=bool)
    np.fill_diagonal(adj, False)
    return adj


def star_graph(m: int) -> np.ndarray:
    """Server 0 is the hub (degenerates to hierarchical FL — used as the
    baseline topology the paper argues against)."""
    adj = np.zeros((m, m), dtype=bool)
    adj[0, 1:] = True
    adj[1:, 0] = True
    return adj


def line_graph(m: int) -> np.ndarray:
    adj = np.zeros((m, m), dtype=bool)
    i = np.arange(m - 1)
    adj[i, i + 1] = True
    adj[i + 1, i] = True
    return adj


def erdos_renyi_graph(m: int, p: float, seed: int = 0) -> np.ndarray:
    """Random connected graph: sample until connected (adds a ring as a
    fallback spanning structure after 100 tries)."""
    rng = np.random.default_rng(seed)
    for _ in range(100):
        upper = rng.random((m, m)) < p
        adj = np.triu(upper, 1)
        adj = adj | adj.T
        if is_connected(adj):
            return adj
    return ring_graph(m) | adj


def torus_2d_graph(rows: int, cols: int) -> np.ndarray:
    """2-D torus — matches the physical ICI topology of a TPU pod slice, so
    gossip edges ride single physical links."""
    m = rows * cols
    adj = np.zeros((m, m), dtype=bool)
    for r in range(rows):
        for c in range(cols):
            i = r * cols + c
            for dr, dc in ((1, 0), (0, 1)):
                j = ((r + dr) % rows) * cols + (c + dc) % cols
                if i != j:
                    adj[i, j] = adj[j, i] = True
    return adj


GRAPH_BUILDERS = {
    "ring": ring_graph,
    "complete": complete_graph,
    "star": star_graph,
    "line": line_graph,
}


def build_graph(kind: str, m: int, **kw) -> np.ndarray:
    if kind == "erdos_renyi":
        return erdos_renyi_graph(m, kw.get("p", 0.5), kw.get("seed", 0))
    if kind == "torus":
        rows = kw.get("rows") or int(np.sqrt(m))
        return torus_2d_graph(rows, m // rows)
    return GRAPH_BUILDERS[kind](m)


def is_connected(adj: np.ndarray) -> bool:
    """Assumption 1 check (BFS)."""
    m = adj.shape[0]
    if m == 0:
        return False
    if m == 1:
        return True
    seen = np.zeros(m, dtype=bool)
    frontier = [0]
    seen[0] = True
    while frontier:
        nxt = []
        for v in frontier:
            for u in np.nonzero(adj[v])[0]:
                if not seen[u]:
                    seen[u] = True
                    nxt.append(u)
        frontier = nxt
    return bool(seen.all())


# ---------------------------------------------------------------------------
# mixing matrices  (Eq. 6: doubly stochastic, support = G + self loops,
#                   positive entries bounded below by alpha)
# ---------------------------------------------------------------------------


def metropolis_weights(adj: np.ndarray) -> np.ndarray:
    """Metropolis–Hastings weights: symmetric, doubly stochastic, positive on
    the diagonal for any connected graph — the standard constructive choice
    satisfying Eq. (6)."""
    m = adj.shape[0]
    deg = adj.sum(1)
    a = np.zeros((m, m))
    for i in range(m):
        for j in np.nonzero(adj[i])[0]:
            a[i, j] = 1.0 / (1.0 + max(deg[i], deg[j]))
        a[i, i] = 1.0 - a[i].sum()
    return a


def uniform_weights(adj: np.ndarray) -> np.ndarray:
    """Equal-neighbour weights 1/(max_deg+1) — also doubly stochastic."""
    m = adj.shape[0]
    dmax = int(adj.sum(1).max()) if m else 0
    a = adj.astype(float) / (dmax + 1)
    np.fill_diagonal(a, 0.0)
    a += np.diag(1.0 - a.sum(1))
    return a


def check_mixing_matrix(a: np.ndarray, adj: Optional[np.ndarray] = None,
                        atol: float = 1e-10) -> None:
    """Validate Eq. (6): row/col sums 1, non-negative, support matches G."""
    m = a.shape[0]
    if not np.allclose(a.sum(0), 1.0, atol=atol):
        raise ValueError("columns must sum to 1")
    if not np.allclose(a.sum(1), 1.0, atol=atol):
        raise ValueError("rows must sum to 1")
    if (a < -atol).any():
        raise ValueError("entries must be non-negative")
    if adj is not None:
        off = ~np.eye(m, dtype=bool)
        if ((a > atol) & off & ~adj).any():
            raise ValueError("positive weight on a non-edge")


def sigma_a(a: np.ndarray, t_s: int) -> float:
    """sigma_A = ||A^{T_S} - (1/M) 11'||_2  (spectral norm) — the consensus
    contraction factor of Lemma 1."""
    m = a.shape[0]
    at = np.linalg.matrix_power(a, t_s)
    return float(np.linalg.norm(at - np.ones((m, m)) / m, ord=2))


def spectral_gap(a: np.ndarray) -> float:
    """1 - |lambda_2(A)| for symmetric doubly-stochastic A."""
    ev = np.sort(np.abs(np.linalg.eigvalsh(a)))[::-1]
    return float(1.0 - (ev[1] if len(ev) > 1 else 0.0))


# ---------------------------------------------------------------------------
# FL topology: servers x clients mapped onto mesh replica slots
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class FLTopology:
    """The paper's system model: M servers, N clients each, graph G, epoch
    split (T_C, T_S) — plus the mesh factoring used on hardware."""

    num_servers: int                 # M
    clients_per_server: int          # N
    t_client: int                    # T_C
    t_server: int                    # T_S
    graph_kind: str = "ring"
    mixing: str = "metropolis"       # metropolis | uniform
    intra_client_replicas: int = 1   # R: FSDP degree inside one client

    def __post_init__(self):
        if self.num_servers < 1 or self.clients_per_server < 1:
            raise ValueError("need at least 1 server and 1 client")
        if self.t_client < 1 or self.t_server < 0:
            raise ValueError("T_C >= 1, T_S >= 0")
        adj = self.adjacency()
        if self.num_servers > 1 and not is_connected(adj):
            raise ValueError("Assumption 1 violated: server graph must be connected")

    # -- graph/mixing --------------------------------------------------------
    def adjacency(self) -> np.ndarray:
        return build_graph(self.graph_kind, self.num_servers)

    def mixing_matrix(self) -> np.ndarray:
        adj = self.adjacency()
        a = metropolis_weights(adj) if self.mixing == "metropolis" else uniform_weights(adj)
        check_mixing_matrix(a, adj)
        return a

    def sigma(self) -> float:
        if self.num_servers == 1:
            return 0.0
        return sigma_a(self.mixing_matrix(), self.t_server)

    # -- sizes ---------------------------------------------------------------
    @property
    def num_clients(self) -> int:
        return self.num_servers * self.clients_per_server

    @property
    def epoch_len(self) -> int:  # T_E
        return self.t_client + self.t_server

    @property
    def replica_slots(self) -> int:
        return self.num_clients * self.intra_client_replicas

    # -- Theorem 1 machinery --------------------------------------------------
    def max_step_size(self, mu: float, lsmooth: float) -> float:
        """gamma < min{1/(L T_C), 1/(mu T_C)} (Thm. 1)."""
        return 1.0 / (max(mu, lsmooth) * self.t_client)

    def epsilon_bound(self, gamma: float, mu: float, lsmooth: float,
                      theta: float, w0_disagreement: float = 0.0) -> float:
        """The Thm-1 tolerance  eps = sqrt(M) g th T_C s/(1-s) + Y0/(1-L)."""
        m = self.num_servers
        s = self.sigma()
        tc = self.t_client
        lam = np.sqrt(max(0.0, 1.0 - gamma * mu * tc))
        y0 = ((gamma * tc) ** 2 * theta * lsmooth * (1 + np.sqrt(m) * s / (1 - s))
              + gamma * tc * lsmooth * w0_disagreement)
        return float(np.sqrt(m) * gamma * theta * tc * s / (1 - s) + y0 / (1 - lam))

    # -- fault tolerance -------------------------------------------------------
    def drop_server(self, server_idx: int) -> Tuple["FLTopology", np.ndarray]:
        """Graph surgery after a server failure: remove the node, keep the
        induced subgraph if still connected else fall back to a ring over the
        survivors.  Returns (new topology, survivor index map)."""
        m = self.num_servers
        if not 0 <= server_idx < m:
            raise ValueError("bad server index")
        if m == 1:
            raise ValueError("cannot drop the only server")
        keep = np.array([i for i in range(m) if i != server_idx])
        sub = self.adjacency()[np.ix_(keep, keep)]
        kind = self.graph_kind if is_connected(sub) else "ring"
        new = dataclasses.replace(self, num_servers=m - 1, graph_kind=kind)
        return new, keep
