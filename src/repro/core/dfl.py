"""The DFL algorithm (Algorithm 1) as a composable JAX training step.

One *epoch step* is the paper's full cycle, compiled as a single jitted
program so that XLA schedules the local compute and the two communication
phases (client->server aggregation, server<->server gossip) together:

    1. local period     — lax.scan of T_C per-client SGD steps, vmapped over
                          the (M, N) client grid          (Eq. 3)
    2. aggregation      — mean over the client axis       (Eq. 4)
    3. consensus period — T_S gossip rounds  W <- A W     (Eq. 5/7)
    4. broadcast        — server model back to its N clients

State layout: every parameter leaf carries leading axes ``(M, N, *w)``
sharded over the mesh axes ``("server", "client")`` — each device holds only
its own client's copy, so per-client models cost no per-device memory over
plain data parallelism.  Optimizer state follows the same layout and stays
client-local (the paper's SGD is stateless; for stateful optimizers this is
the natural privacy-preserving choice — moments never leave the client).

``consensus_mode``:
    "gossip"         faithful T_S-round schedule (the paper)
    "gossip_blocked" same schedule streamed over fixed-size parameter blocks
                     (the memory-deterministic production form)
    "collapsed"      beyond-paper: one round with A_eff = A^{T_S} (identical math)
    "chebyshev"      beyond-paper: accelerated polynomial gossip
    "exact_mean"     idealised sigma_A=0 limit == hierarchical FL with a root
                     aggregator (the baseline the paper argues against)
    "none"           no inter-server communication (fully local ablation)
    "trimmed_mean[:f]" / "median" / "clipped[:mult]"
                     Byzantine-robust neighbor screening in place of the
                     weighted round (consensus.py; pair with
                     DFLConfig.byzantine to actually be attacked)

Execution is delegated to a ``consensus.ConsensusBackend`` resolved from
``consensus_mode`` (or injected via ``DFLConfig.consensus_backend`` for
mesh-aware strategies like ``consensus.ShardMapBackend``); every backend
accepts the traced per-epoch ``A_p`` of dynamic mode and implements a
push-sum variant, so every execution path serves every scenario.

Directed federation (``DFLConfig.mixing``): when degraded links make the
server graph directed, Eq. 6's doubly-stochastic A may not exist on its
support.  ``mixing="push_sum"`` replaces the consensus period with ratio
consensus (``consensus.gossip_push_sum``): numerator and a per-server scalar
weight both mixed by the column-stochastic A', read out as the unbiased
ratio; the terminal weights ride along in ``DFLState.psum_weight``.
``mixing="row_stochastic"`` keeps the naive (biased) W <- A W update as the
baseline.  See docs/dynamic_federation.md for why naive row-stochastic
gossip is biased.

Dynamic federation (``DFLConfig.dynamic=True``): the compiled epoch step
additionally takes a ``schedule.EpochSchedule`` operand — a per-epoch
``(M, N)`` participation mask and a per-epoch ``(M, M)`` mixing matrix —
so partial participation and time-varying server graphs run through the
SAME compiled program as the static paper setting (all-ones mask + the
static ``A`` reproduces it exactly).  See ``masked_server_mean`` for the
masked Eq. 4 semantics; server failure/rejoin changes array shapes and is
host-side graph surgery (``engine.DynamicFederationEngine``).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import consensus as cns
from repro.core.topology import FLTopology
from repro.optim import Optimizer

LossFn = Callable[[Any, Any, jax.Array], Tuple[jax.Array, Any]]
# (params, batch, rng) -> (scalar loss, aux)


class DFLState(NamedTuple):
    """Carried across epochs. ``client_params`` leaves: (M, N, *w).

    ``psum_weight`` is only populated under ``DFLConfig(mixing="push_sum")``:
    the ``(M,)`` per-server push-sum weight at the END of the last consensus
    period (positive, sums to M).  It is a directed-gossip diagnostic — a
    weight near 0 means that server's ratio read-out num/w was
    ill-conditioned this epoch — and the state the engine must reset on
    server drop/rejoin; each consensus period itself restarts from weight 1
    (see ``consensus.init_push_sum`` for why).  ``None`` in every other
    mixing mode.

    ``ef_residual`` is only populated under compressed consensus with error
    feedback (``DFLConfig.compression`` + ``error_feedback``): the
    per-server compression residual pytree (leaves ``(M, *w)``, mirroring
    the server aggregates) of ``comm.error_feedback`` — what each server
    withheld from the wire last period and re-offers next period.  Like the
    push-sum weight it is per-server wire state, reset to zero on
    drop/rejoin surgery by the engine.  ``None`` otherwise."""

    client_params: Any
    opt_state: Any
    epoch: jax.Array          # int32 scalar
    rng: jax.Array
    psum_weight: Optional[jax.Array] = None   # (M,) or None
    ef_residual: Optional[Any] = None         # server-tree pytree or None


class DFLMetrics(NamedTuple):
    loss: jax.Array                 # (T_C, M, N) per local step per client
    server_disagreement: jax.Array  # ||W - 1 wbar'||_F after consensus (Lemma 1 LHS)
    client_drift: jax.Array         # max_ij ||w^{ij} - w^i_p|| before aggregation (Lemma 3 LHS)
    grad_norm: jax.Array            # mean per-client grad norm of last local step
    # (M,) per-SOURCE robust-screen activity: how many of server j's values
    # the receivers' trimmed_mean/median/clipped screens discarded this
    # epoch's consensus period.  Populated only under a robust backend with
    # metrics="full" (a static fact of the config, NOT of whether an
    # observer is attached — so obs on/off runs the same compiled program);
    # None everywhere else.
    screen_rejected: Optional[jax.Array] = None


@dataclasses.dataclass(frozen=True)
class DFLConfig:
    topology: FLTopology
    consensus_mode: str = "gossip"   # gossip | gossip_blocked | collapsed | chebyshev | exact_mean | none
    # How the mixing matrix is interpreted by the consensus period:
    #   "symmetric"       the paper: A doubly stochastic (Eq. 6), plain
    #                     gossip W <- A W preserves the mean.
    #   "row_stochastic"  naive directed gossip: apply a row-stochastic A
    #                     (topology.mixing="out_degree") with the SAME
    #                     W <- A W update.  Converges to the BIASED
    #                     Perron-weighted average pi' W — kept as the
    #                     baseline that shows why push-sum is needed.
    #   "push_sum"        directed gossip done right: ratio consensus with
    #                     numerator + weight mixed by A' (column
    #                     stochastic); unbiased on any strongly-connected
    #                     digraph.  The epoch step carries the per-server
    #                     weights in DFLState.psum_weight.
    mixing: str = "symmetric"
    chebyshev_rounds: Optional[int] = None  # default: ceil(sqrt(T_S * gap stuff)) picked by caller
    param_dtype: Any = jnp.float32
    # NamedSharding for the flattened (M, D) gossip matrix in
    # consensus_mode="gossip_blocked" (e.g. P("server", ("replica","model"))).
    gossip_flat_sharding: Optional[Any] = None
    # Explicit consensus execution backend (consensus.ConsensusBackend).
    # None: resolved from consensus_mode via consensus.make_backend.  Set by
    # the launcher for mesh-aware strategies (consensus.ShardMapBackend via
    # launch.sharding.fl_consensus_backend) — same math as "gossip", with
    # the per-epoch A_p still a traced operand in dynamic mode.
    consensus_backend: Optional[Any] = None
    # "full": compute the Lemma-1/Lemma-3 diagnostics (server disagreement,
    # client drift, grad norm) every epoch — the right setting for the
    # paper-scale simulations and tests.  "light": skip them (zeros) — at
    # 100B+ scale each is a full-parameter-tree reduction whose f32
    # intermediates rival the model itself in HBM.
    metrics: str = "full"
    # Gradient accumulation: each local iteration's per-client batch is
    # processed in this many sequential microbatches with the summed (mean)
    # gradient applied once — identical math to Eq. 3's full-batch gradient,
    # 1/n the activation footprint.  The per-device activation knob for the
    # 100B+ archs (DESIGN.md §2).
    grad_microbatches: int = 1
    # Dynamic federation: the epoch step takes an extra EpochSchedule operand
    # (participation mask + per-epoch mixing matrix + optional spectral
    # estimate for chebyshev) — see module docstring.
    dynamic: bool = False
    # Lossy inter-server compression (the repro.comm subsystem): a
    # comm.compressors.make_compressor spec — "none" | "int8[:chunk]" |
    # "int4[:chunk]" | "top_k:<ratio>" | "random_k:<ratio>".  Anything but
    # "none" wraps the resolved backend in consensus.CompressedBackend, so
    # the consensus period mixes the wire-decompressed messages;
    # "none" builds NO wrapper at all — that path is bitwise the
    # uncompressed program.
    compression: str = "none"
    # Error feedback for the compression above: carry each server's
    # compression residual in DFLState.ef_residual and fold it into the
    # next period's message (comm.error_feedback) — removes the persistent
    # bias of top-k/clipping at zero extra wire cost.  Ignored when
    # compression == "none".
    error_feedback: bool = False
    # Where the compression above happens (consensus.CompressedBackend):
    #   "simulated"  quantize ONCE per period in-graph (payload flooding)
    #                and let the collectives move floats — bytes are a
    #                host-side ledger (the PR-4 wire model).
    #   "physical"   the codes ARE the wire: every gossip round quantizes
    #                before the collective (int8 / packed-int4 all-gathers
    #                and ppermutes) and dequantizes after, so BytesTracker
    #                reports bytes the collectives actually move.  Needs a
    #                quantizer compressor and a per-round gossip schedule
    #                (gossip / gossip_blocked / shard_map).
    # Ignored when compression == "none".
    wire: str = "simulated"
    # Bounded-staleness consensus (consensus.gossip_scan_stale and the
    # software-pipelined wire bodies): gossip round t mixes with neighbor
    # messages from round t - staleness, so the round-t collective overlaps
    # the round-t compute instead of serializing in front of it.  In exact
    # arithmetic the period contracts as A^(T_S // (staleness+1)) — the
    # augmented operator schedule.SigmaTracker(staleness=...) monitors.
    # staleness=0 is BITWISE today's synchronous path (the build branches
    # to the literally unchanged code).  Carried by the literal T_S-round
    # schedules only (gossip / gossip_blocked / the shard_map codec wire);
    # incompatible with mixing="push_sum" and with robust/spectral modes.
    staleness: int = 0
    # Adversarial-server scenario (schedule.ByzantineSchedule or None):
    # marked servers replace their Eq.-4 aggregate with an attack
    # (apply_byzantine) BEFORE the consensus period, so the robust
    # consensus backends (trimmed_mean / median / clipped) are what stands
    # between one attacker and the whole federation.  Dynamic mode only:
    # the per-epoch attack codes ride the EpochSchedule operand.
    byzantine: Optional[Any] = None


# ---------------------------------------------------------------------------
# helpers on the (M, N, ...) layout
# ---------------------------------------------------------------------------


def replicate_to_clients(params: Any, m: int, n: int) -> Any:
    """Initial broadcast: shared w_0 across all servers and clients."""
    return jax.tree.map(
        lambda p: jnp.broadcast_to(p[None, None], (m, n) + p.shape), params)


def server_mean(client_tree: Any) -> Any:
    """Eq. 4: w^i = (1/N) sum_j w^{ij}  — mean over the client axis."""
    return jax.tree.map(lambda x: x.mean(axis=1), client_tree)


def broadcast_to_clients(server_tree: Any, n: int) -> Any:
    """End-of-epoch broadcast: every client restarts from its server model."""
    return jax.tree.map(
        lambda s: jnp.broadcast_to(s[:, None], s.shape[:1] + (n,) + s.shape[1:]),
        server_tree)


def global_mean(client_tree: Any) -> Any:
    """w̄ — mean over all servers and clients (analysis quantity)."""
    return jax.tree.map(lambda x: x.mean(axis=(0, 1)), client_tree)


def masked_server_mean(client_tree: Any, mask: jax.Array) -> Any:
    """Eq. 4 under partial participation:

        w^i = (1/|S_p^i|) sum_{j in S_p^i} w^{ij}

    where ``S_p^i = {j : mask[i, j] = 1}`` is server i's participating set
    this epoch — a masked, weight-renormalised mean over the client axis.
    Non-participants contribute nothing and carry their broadcast model
    forward unchanged (enforced by ``carry_forward`` before this is called),
    so a fully-idle server (|S_p^i| = 0) degenerates to the plain mean of N
    identical broadcast copies == its previous model: the server simply
    holds its state through the epoch.  An all-ones mask reproduces the
    paper's Eq. 4 exactly."""
    cnt = mask.sum(axis=1)                                    # (M,)
    safe = jnp.maximum(cnt, 1.0)

    def leaf(x):
        mk = mask.reshape(mask.shape + (1,) * (x.ndim - 2)).astype(x.dtype)
        s = (x * mk).sum(axis=1)
        c = safe.reshape((-1,) + (1,) * (s.ndim - 1)).astype(x.dtype)
        sel = (cnt > 0).reshape((-1,) + (1,) * (s.ndim - 1))
        return jnp.where(sel, s / c, x.mean(axis=1))

    return jax.tree.map(leaf, client_tree)


def carry_forward(mask: jax.Array, new_tree: Any, old_tree: Any) -> Any:
    """Per-client participation select: leaves with a leading ``(M, N)``
    client grid take ``new`` where ``mask`` is set and ``old`` (the epoch's
    broadcast model / pre-epoch optimizer state) where it is not; shared
    leaves (e.g. the scalar step count) always advance."""
    grid = mask.shape

    def leaf(nl, ol):
        if nl.ndim >= 2 and nl.shape[:2] == grid:
            mk = mask.reshape(grid + (1,) * (nl.ndim - 2))
            return jnp.where(mk > 0, nl, ol)
        return nl

    return jax.tree.map(leaf, new_tree, old_tree)


def apply_byzantine(server_tree: Any, codes: jax.Array, key: jax.Array,
                    attacks: Tuple[Any, ...]) -> Any:
    """Inject the scheduled attacks into the pre-gossip server tree.

    ``codes`` is the traced (M,) int32 per-row attack marking of
    ``schedule.ByzantineSchedule.codes`` (0 = honest, k+1 = attacks[k]);
    ``attacks`` is the STATIC tuple of ``schedule.ByzantineAttack`` — the
    attack kinds/scales are compiled in, only who attacks is traced, so
    one program serves every epoch's attacker set.  Pure function of
    ``(tree, codes, key)``: honest rows pass through bitwise untouched.

    Attack semantics (per ``schedule.ByzantineAttack``): ``sign_flip``
    transmits ``-scale * w``; ``scaled_noise`` transmits ``w + scale *
    N(0, I)`` (one fresh key per leaf); ``inlier_shift`` transmits the
    honest coordinatewise envelope's ``scale``-quantile corner ``h_min +
    scale * (h_max - h_min)`` — a collusion that stays inside the honest
    range (computed over ``codes == 0`` rows; if no honest row exists the
    attacker keeps its value, guarding the inf - inf NaN)."""
    honest = codes == 0

    def leaf_fn(leaf, leaf_key):
        out = leaf
        code_b = codes.reshape((-1,) + (1,) * (leaf.ndim - 1))
        for idx, atk in enumerate(attacks):
            if atk.kind == "sign_flip":
                attacked = (-atk.scale) * leaf
            elif atk.kind == "scaled_noise":
                # fold in the attack index: two scaled_noise entries in one
                # schedule must not draw the SAME noise from the leaf key
                attacked = leaf + atk.scale * jax.random.normal(
                    jax.random.fold_in(leaf_key, idx), leaf.shape,
                    leaf.dtype)
            else:  # inlier_shift
                hmask = honest.reshape((-1,) + (1,) * (leaf.ndim - 1))
                hmin = jnp.where(hmask, leaf,
                                 jnp.asarray(jnp.inf, leaf.dtype)).min(0)
                hmax = jnp.where(hmask, leaf,
                                 jnp.asarray(-jnp.inf, leaf.dtype)).max(0)
                target = jnp.broadcast_to(
                    hmin + atk.scale * (hmax - hmin), leaf.shape)
                attacked = jnp.where(honest.any(), target, leaf)
            out = jnp.where(code_b == idx + 1, attacked.astype(leaf.dtype),
                            out)
        return out

    leaves, treedef = jax.tree.flatten(server_tree)
    keys = jax.random.split(key, len(leaves))
    return treedef.unflatten(
        [leaf_fn(l, k) for l, k in zip(leaves, keys)])


def _tree_sq_norm(tree: Any) -> jax.Array:
    # reduce with an f32 accumulator WITHOUT first materialising an f32
    # copy of each (possibly multi-GB bf16) leaf
    return sum(jnp.sum(jnp.square(l), dtype=jnp.float32)
               for l in jax.tree.leaves(tree))


def disagreement_norm(server_tree: Any) -> jax.Array:
    """||W - 1 wbar'||_F over the stacked server models (Lemma 1 LHS).

    Uses sum_i ||w_i||^2 - M ||wbar||^2 (per leaf) instead of materialising
    the (M, ...) deviation tensor: under pjit the naive form all-gathers an
    f32 copy of every parameter leaf across the server axis (~2 GB/leaf at
    27B), whereas this form is shard-local squares + one tiny all-reduce."""
    total = jnp.zeros((), jnp.float32)
    for leaf in jax.tree.leaves(server_tree):
        m = leaf.shape[0]
        s_sq = jnp.sum(jnp.square(leaf), dtype=jnp.float32)
        mean = leaf.mean(axis=0, dtype=jnp.float32)
        total += s_sq - m * jnp.sum(jnp.square(mean))
    return jnp.sqrt(jnp.maximum(total, 0.0))


def max_client_drift(client_tree: Any, server_tree: Any) -> jax.Array:
    """max_{ij} ||w^{ij} - w^i|| (Lemma 3 LHS).

    ||c - s||^2 = sum c^2 - 2 sum c*s + sum s^2 per (i, j): three bf16
    elementwise products reduced with f32 accumulators — no (M, N, params)
    f32 deviation tensor (the naive form held ~8 f32 expert-table copies)."""
    sq = None
    for c, s in zip(jax.tree.leaves(client_tree),
                    jax.tree.leaves(server_tree)):
        axes = tuple(range(2, c.ndim))
        sb = s[:, None]
        term = (jnp.sum(jnp.square(c), axis=axes, dtype=jnp.float32)
                - 2.0 * jnp.sum(c * sb, axis=axes, dtype=jnp.float32)
                + jnp.sum(jnp.square(sb), axis=axes, dtype=jnp.float32))
        sq = term if sq is None else sq + term
    return jnp.sqrt(jnp.maximum(jnp.max(sq), 0.0))


# ---------------------------------------------------------------------------
# compressed-consensus config resolution (shared with engine / launcher)
# ---------------------------------------------------------------------------


def active_compressor(cfg: "DFLConfig"):
    """The compressor this config's consensus period runs through, or
    ``None`` when the wire is exact — resolved from an injected
    ``consensus.CompressedBackend`` first (the launcher's mesh-aware path),
    then from ``cfg.compression``.  Single source of truth for the engine's
    byte accounting and the EF-state plumbing."""
    backend = cfg.consensus_backend
    if backend is not None:
        if getattr(backend, "compressed", False):
            return backend.compressor
        return None
    if cfg.compression != "none" and cfg.consensus_mode != "none":
        from repro.comm.compressors import make_compressor
        return make_compressor(cfg.compression)
    return None


def wants_error_feedback(cfg: "DFLConfig") -> bool:
    """Whether this config carries an EF residual in ``DFLState`` — must
    agree between ``init_dfl_state`` and the built epoch step (the residual
    is part of the carried pytree)."""
    backend = cfg.consensus_backend
    if backend is not None:
        return bool(getattr(backend, "compressed", False)
                    and backend.error_feedback)
    return (cfg.compression != "none" and cfg.error_feedback
            and cfg.consensus_mode != "none")


def resolve_backend(cfg: "DFLConfig"):
    """The ``consensus.ConsensusBackend`` this config's consensus period
    executes through: the injected ``cfg.consensus_backend`` if any, else
    one built from ``cfg.consensus_mode`` over the static topology matrix
    (``None`` for consensus_mode='none').  Shared by the epoch-step
    builder and the engine's consensus-replay timing probe so both see
    the SAME execution strategy."""
    topo = cfg.topology
    if cfg.consensus_backend is not None:
        return cfg.consensus_backend
    if cfg.consensus_mode == "none":
        return None
    m = topo.num_servers
    a_np = topo.mixing_matrix() if m > 1 else np.ones((1, 1))
    return cns.make_backend(
        cfg.consensus_mode, a_np, topo.t_server,
        chebyshev_rounds=cfg.chebyshev_rounds,
        gossip_flat_sharding=cfg.gossip_flat_sharding,
        compression=cfg.compression,
        error_feedback=cfg.error_feedback,
        wire=cfg.wire,
        staleness=cfg.staleness)


def active_wire(cfg: "DFLConfig") -> Tuple[str, int]:
    """``(wire mode, wire block)`` of the active compression layer —
    resolved from an injected ``consensus.CompressedBackend`` first, then
    from ``cfg.wire``.  The block is the physical byte-layout partitioning
    (``consensus.DEFAULT_GOSSIP_BLOCK`` on the string paths): the engine's
    byte ledger needs it to count the BUCKETED padded codes + scales the
    collectives actually gather under ``wire='physical'`` (``comm.
    accounting.tree_bucketed_wire_bytes_per_server``), and its tracker
    needs the mode to know that push-sum's weight scalar never crosses a
    physical collective."""
    backend = cfg.consensus_backend
    if backend is not None and getattr(backend, "compressed", False):
        return backend.wire, backend.wire_block
    return cfg.wire, cns.DEFAULT_GOSSIP_BLOCK


# ---------------------------------------------------------------------------
# the epoch step builder
# ---------------------------------------------------------------------------


def build_dfl_epoch_step(
    cfg: DFLConfig,
    loss_fn: LossFn,
    optimizer: Optimizer,
) -> Callable[[DFLState, Any], Tuple[DFLState, DFLMetrics]]:
    """Return ``epoch_step(state, batches) -> (state, metrics)``.

    ``batches`` leaves are ``(T_C, M, N, *per_client_batch)`` — one
    microbatch per client per local iteration.  The returned function is NOT
    jitted; callers wrap it in jax.jit with the desired shardings (and
    donation — see ``engine.DynamicFederationEngine._step`` and
    ``launch.train.train``).
    """
    topo = cfg.topology
    m, n = topo.num_servers, topo.clients_per_server
    if cfg.mixing not in ("symmetric", "row_stochastic", "push_sum"):
        raise ValueError(f"unknown mixing interpretation {cfg.mixing!r}")
    if cfg.mixing == "symmetric" and topo.mixing == "out_degree" and m > 1:
        raise ValueError(
            "topology.mixing='out_degree' emits a row-stochastic (generally "
            "not doubly stochastic) A: running it through the symmetric "
            "gossip path would silently converge to the biased "
            "Perron-weighted average — choose DFLConfig(mixing='push_sum') "
            "(unbiased) or mixing='row_stochastic' (the explicit biased "
            "baseline)")
    if cfg.staleness < 0:
        raise ValueError(f"staleness must be >= 0, got {cfg.staleness}")
    if cfg.staleness and cfg.mixing == "push_sum":
        raise ValueError(
            "bounded staleness is undefined under mixing='push_sum': the "
            "exact (M,) weight recursion has no delayed twin, so a stale "
            "numerator over a fresh weight breaks mass conservation — use "
            "staleness=0 or a symmetric/row_stochastic mixing")
    if cfg.staleness and cfg.consensus_mode == "none" \
            and cfg.consensus_backend is None:
        raise ValueError("staleness > 0 with consensus_mode='none' is "
                         "meaningless: there are no gossip rounds to delay")
    backend = resolve_backend(cfg)
    if backend is not None and cfg.consensus_backend is not None \
            and getattr(backend, "staleness", 0) != cfg.staleness:
        raise ValueError(
            f"DFLConfig.staleness={cfg.staleness} disagrees with the "
            f"injected consensus backend's staleness="
            f"{getattr(backend, 'staleness', 0)}: the SigmaTracker "
            f"contraction and the compiled wire program must see the same "
            f"depth — build the backend with the same staleness")
    if backend is not None:
        if cfg.mixing != "symmetric" and not backend.supports_directed:
            raise ValueError(
                f"consensus backend {backend.name!r} is undefined for "
                f"mixing={cfg.mixing!r}: the directed paths need the "
                f"literal W <- A W / ratio-consensus update — use one of "
                f"('gossip', 'gossip_blocked', 'collapsed', 'shard_map', "
                f"'none')")
        if cfg.dynamic and not backend.supports_traced:
            raise ValueError(
                f"consensus backend {backend.name!r} cannot consume a "
                f"traced per-epoch A_p; use 'gossip', 'gossip_blocked', "
                f"'collapsed', 'chebyshev' or a shard_map backend")
    # byzantine injection: the attack kinds/scales are static facts of the
    # compiled program; WHO attacks is the traced EpochSchedule.byz operand
    byz_attacks = (tuple(cfg.byzantine.attacks)
                   if cfg.byzantine is not None else ())
    if byz_attacks and not cfg.dynamic:
        raise ValueError(
            "DFLConfig.byzantine needs dynamic=True: the per-epoch "
            "attacker codes ride the EpochSchedule operand (use "
            "engine.make_engine, which sets it)")
    # compression wire state: static facts of the compiled program (when
    # False, nothing below touches the rng stream or the residual — the
    # compression="none" program is bitwise the pre-compression one)
    compressed = (backend is not None
                  and getattr(backend, "compressed", False)
                  and m > 1 and topo.t_server > 0)
    # robust screen-activity readout: a STATIC fact of the config (robust
    # backend + full metrics), never of whether an observer is attached —
    # the obs-on and obs-off programs must stay byte-identical.  On the
    # plain paths mix_stats is never called, so nothing changes there
    # either.
    screen_stats = (backend is not None
                    and getattr(backend, "robust", False)
                    and cfg.metrics == "full")

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)
    # vmap over clients within a server, then over servers
    client_grad = jax.vmap(jax.vmap(grad_fn))

    n_micro = max(cfg.grad_microbatches, 1)

    def local_step(carry, batch_t):
        params, opt_state, rng = carry
        rng, sub = jax.random.split(rng)
        keys = jax.random.split(sub, (m, n))  # typed keys: pass jax.random.key()
        if n_micro == 1:
            (loss, _aux), grads = client_grad(params, batch_t, keys)
        else:
            # split the per-client batch dim (axis 2 after (M, N)) into
            # n_micro sequential microbatches; average the gradients.
            def split(leaf):
                b = leaf.shape[2]
                assert b % n_micro == 0, (leaf.shape, n_micro)
                mb = leaf.reshape(leaf.shape[:2] + (n_micro, b // n_micro)
                                  + leaf.shape[3:])
                return jnp.moveaxis(mb, 2, 0)     # (n_micro, M, N, b/n, ...)
            micro_batches = jax.tree.map(split, batch_t)

            # accumulate in the PARAM dtype: an f32 accumulator doubles to
            # 2x params f32 once the while-loop double-buffers it; scaling
            # each microgradient by 1/n first keeps bf16 accumulation well-
            # conditioned (grads are same-scale summands).
            def micro_step(g_acc, mb):
                (mloss, _maux), g = client_grad(params, mb, keys)
                g_acc = jax.tree.map(
                    lambda a, x: a + (x / n_micro).astype(a.dtype), g_acc, g)
                return g_acc, mloss

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, cfg.param_dtype),
                              params)
            grads, mlosses = jax.lax.scan(micro_step, g0, micro_batches)
            loss = mlosses.mean(axis=0)
        params, opt_state = optimizer.update(grads, opt_state, params)
        if cfg.metrics == "full":
            gnorm = jnp.sqrt(_tree_sq_norm(grads) / (m * n))
        else:
            gnorm = jnp.zeros((), jnp.float32)
        return (params, opt_state, rng), (loss, gnorm)

    def apply_consensus(server_tree, a_p=None, psum_weight=None,
                        ef_residual=None, key=None, lam2=None):
        """Run the consensus period through the resolved ConsensusBackend.
        ``a_p``: optional traced per-epoch mixing matrix (dynamic mode);
        ``None`` selects the static topology's A held by the backend.
        ``ef_residual``/``key``: the error-feedback residual tree and the
        stochastic-rounding key, threaded only under compressed consensus;
        ``lam2``: the per-epoch spectral hint for spectral backends.
        Returns ``(server_tree, psum_weight, ef_residual, screen)`` — the
        weight is the terminal push-sum weight under mixing='push_sum',
        the residual the post-transmission EF state (both pass through
        unchanged when their feature is off), and ``screen`` the per-source
        robust screen-activity counts (``(M,)`` under a robust backend
        with full metrics, ``None`` otherwise — see DFLMetrics)."""
        screen0 = (jnp.zeros((m,), jnp.float32) if screen_stats else None)
        if m == 1 or topo.t_server == 0 or backend is None:
            return server_tree, psum_weight, ef_residual, screen0
        if cfg.mixing == "push_sum":
            # each consensus period is a fresh ratio consensus: numerator =
            # this epoch's server aggregates, weight reset to 1 (the carried
            # DFLState.psum_weight is last period's terminal weight, kept as
            # a diagnostic — see init_push_sum for why it must not seed the
            # next period)
            ps0 = cns.init_push_sum(server_tree)
            if compressed:
                ps, ef_residual = backend.mix_push_sum_compressed(
                    ps0, a_p, residual=ef_residual, key=key)
            else:
                ps = backend.mix_push_sum(ps0, a_p)
            return ps.ratio(), ps.weight, ef_residual, screen0
        if compressed:
            mixed, ef_residual = backend.mix_compressed(
                server_tree, a_p, residual=ef_residual, key=key, lam2=lam2)
            return mixed, psum_weight, ef_residual, screen0
        if screen_stats:
            mixed, screen = backend.mix_stats(server_tree, a_p, lam2=lam2)
            return mixed, psum_weight, ef_residual, screen
        return backend.mix(server_tree, a_p, lam2=lam2), psum_weight, \
            ef_residual, screen0

    def epoch_step(state: DFLState, batches: Any) -> Tuple[DFLState, DFLMetrics]:
        # ---- 1. local period: T_C client SGD iterations (Eq. 3) ----
        carry = (state.client_params, state.opt_state, state.rng)
        (params, opt_state, rng), (losses, gnorms) = jax.lax.scan(
            local_step, carry, batches)

        # Lemma 3 LHS: drift of each client from its start-of-epoch server
        # model w^i_p (== the broadcast client params at epoch entry).
        if cfg.metrics == "full":
            start_server = jax.tree.map(lambda x: x[:, 0],
                                        state.client_params)
            drift = max_client_drift(params, start_server)
        else:
            drift = jnp.zeros((), jnp.float32)

        # ---- 2. aggregation at each server (Eq. 4) ----
        server = server_mean(params)

        # ---- 3. consensus period: T_S gossip rounds (Eq. 5/7) ----
        if compressed:
            rng, ckey = jax.random.split(rng)
        else:
            ckey = None
        server, psw, ef_res, screen = apply_consensus(
            server, psum_weight=state.psum_weight,
            ef_residual=state.ef_residual, key=ckey)
        disagreement = (disagreement_norm(server) if cfg.metrics == "full"
                        else jnp.zeros((), jnp.float32))

        # ---- 4. broadcast w^i_p back to C_i ----
        params = broadcast_to_clients(server, n)

        new_state = DFLState(params, opt_state, state.epoch + 1, rng, psw,
                             ef_res)
        metrics = DFLMetrics(loss=losses, server_disagreement=disagreement,
                             client_drift=drift, grad_norm=gnorms[-1],
                             screen_rejected=screen)
        return new_state, metrics

    def epoch_step_dynamic(state: DFLState, batches: Any,
                           sched: Any) -> Tuple[DFLState, DFLMetrics]:
        """Dynamic variant: ``sched`` is an ``EpochSchedule(mask, mixing[,
        lam2])`` of traced operands — one compiled program serves every
        participation mask and mixing matrix of this shape."""
        mask, a_p = sched.mask, sched.mixing
        lam2 = getattr(sched, "lam2", None)
        # ---- 1. local period (Eq. 3) — all clients traced; the mask is
        # applied afterwards, which is mathematically identical (clients are
        # independent during the local period) and keeps the scan dense.
        carry = (state.client_params, state.opt_state, state.rng)
        (params, opt_state, rng), (losses, gnorms) = jax.lax.scan(
            local_step, carry, batches)
        # non-participants carry their broadcast model (and optimizer state)
        # through the epoch untouched
        params = carry_forward(mask, params, state.client_params)
        opt_state = carry_forward(mask, opt_state, state.opt_state)

        if cfg.metrics == "full":
            start_server = jax.tree.map(lambda x: x[:, 0],
                                        state.client_params)
            drift = max_client_drift(params, start_server)
        else:
            drift = jnp.zeros((), jnp.float32)

        # ---- 2. masked aggregation (Eq. 4 over the participating set) ----
        server = masked_server_mean(params, mask)

        # ---- 2b. adversarial injection: marked servers replace their
        # aggregate BEFORE gossip — this is the message the federation
        # actually receives, and what robust consensus must screen ----
        if byz_attacks:
            rng, bkey = jax.random.split(rng)
            server = apply_byzantine(server, getattr(sched, "byz"), bkey,
                                     byz_attacks)

        # ---- 3. consensus over this epoch's graph A_p (Eq. 5/7) ----
        if compressed:
            rng, ckey = jax.random.split(rng)
        else:
            ckey = None
        server, psw, ef_res, screen = apply_consensus(
            server, a_p, psum_weight=state.psum_weight,
            ef_residual=state.ef_residual, key=ckey, lam2=lam2)
        disagreement = (disagreement_norm(server) if cfg.metrics == "full"
                        else jnp.zeros((), jnp.float32))

        # ---- 4. broadcast (every client, participant or not) ----
        params = broadcast_to_clients(server, n)

        new_state = DFLState(params, opt_state, state.epoch + 1, rng, psw,
                             ef_res)
        metrics = DFLMetrics(loss=losses, server_disagreement=disagreement,
                             client_drift=drift, grad_norm=gnorms[-1],
                             screen_rejected=screen)
        return new_state, metrics

    return epoch_step_dynamic if cfg.dynamic else epoch_step


def build_consensus_replay(cfg: DFLConfig) -> Optional[Callable]:
    """A consensus-period-only program for WALL-CLOCK ATTRIBUTION.

    ``replay(server_tree, a_p, lam2) -> mixed_tree`` re-runs just the
    T_S-round consensus period — the same ``ConsensusBackend``
    (``resolve_backend``), mixing interpretation, and compression wrapper
    as the full epoch step — on an already-computed server tree.  The
    engine's span tracer times it (results DISCARDED, nothing donated)
    to split one compiled epoch step's wall time into local-period vs
    gossip-period estimates: the two phases cannot be timed separately
    inside one compiled program without a host sync in the middle, which
    would change the very schedule being measured.

    The replay is an estimate, not the in-program truth — XLA may overlap
    phases differently in the fused step (exactly what the ROADMAP's
    overlapped-consensus work will exploit); spans carry
    ``method="consensus-replay"`` to say so.  Under compressed consensus
    the probe uses a fixed rounding key and a zero EF residual: timing
    only — its numerics never touch training state.  Returns ``None``
    when there is no consensus period to time (M == 1, T_S == 0, or
    consensus_mode='none')."""
    topo = cfg.topology
    m = topo.num_servers
    if m == 1 or topo.t_server == 0:
        return None
    backend = resolve_backend(cfg)
    if backend is None:
        return None
    compressed = getattr(backend, "compressed", False)
    ef = wants_error_feedback(cfg)

    def replay(server_tree: Any, a_p: jax.Array,
               lam2: Optional[jax.Array] = None) -> Any:
        key = jax.random.key(0) if compressed else None
        residual = (jax.tree.map(jnp.zeros_like, server_tree)
                    if compressed and ef else None)
        if cfg.mixing == "push_sum":
            ps0 = cns.init_push_sum(server_tree)
            if compressed:
                ps, _ = backend.mix_push_sum_compressed(
                    ps0, a_p, residual=residual, key=key)
            else:
                ps = backend.mix_push_sum(ps0, a_p)
            return ps.ratio()
        if compressed:
            mixed, _ = backend.mix_compressed(
                server_tree, a_p, residual=residual, key=key, lam2=lam2)
            return mixed
        return backend.mix(server_tree, a_p, lam2=lam2)

    return replay


def init_dfl_state(cfg: DFLConfig, params: Any, optimizer: Optimizer,
                   rng: jax.Array) -> DFLState:
    """Replicate shared w_0 (Alg. 1 'Initialize') and build optimizer state.
    Under ``mixing='push_sum'`` the state additionally carries a unit
    per-server push-sum weight; under compressed consensus with error
    feedback, a zero per-server compression residual (leaves ``(M, *w)``)."""
    topo = cfg.topology
    client_params = replicate_to_clients(params, topo.num_servers,
                                         topo.clients_per_server)
    opt_state = optimizer.init(client_params)
    psw = (jnp.ones((topo.num_servers,), jnp.float32)
           if cfg.mixing == "push_sum" else None)
    ef = None
    if wants_error_feedback(cfg) and topo.num_servers > 1 \
            and topo.t_server > 0:
        ef = jax.tree.map(
            lambda p: jnp.zeros((topo.num_servers,) + p.shape, p.dtype),
            params)
    return DFLState(client_params, opt_state,
                    jnp.zeros((), jnp.int32), rng, psw, ef)


# ---------------------------------------------------------------------------
# baselines the paper compares against (conceptually)
# ---------------------------------------------------------------------------


def build_fedavg_epoch_step(topology: FLTopology, loss_fn: LossFn,
                            optimizer: Optimizer) -> Callable:
    """Classic single-server FedAvg: same local period, aggregation is a
    global mean (the single central server), no gossip.  Implemented as DFL
    with consensus_mode='exact_mean' — the sigma_A=0 idealisation that
    Theorem 1's epsilon collapses to."""
    cfg = DFLConfig(topology=topology, consensus_mode="exact_mean")
    return build_dfl_epoch_step(cfg, loss_fn, optimizer)


def build_local_only_epoch_step(topology: FLTopology, loss_fn: LossFn,
                                optimizer: Optimizer) -> Callable:
    """No-communication ablation (lower bound on agreement)."""
    cfg = DFLConfig(topology=topology, consensus_mode="none")
    return build_dfl_epoch_step(cfg, loss_fn, optimizer)
