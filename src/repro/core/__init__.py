"""Core: the paper's DFL protocol (topology, consensus, epoch step) plus
the dynamic-federation layer (participation/topology/fault schedules)."""
from repro.core.topology import (FLTopology, build_graph, is_connected,
                                 metropolis_weights, uniform_weights,
                                 check_mixing_matrix, sigma_a, sigma_product,
                                 spectral_gap, drop_edges, random_edge_drop,
                                 weaken_links, directed_ring, is_directed,
                                 is_strongly_connected, random_orientation,
                                 random_direction_drop, out_degree_weights,
                                 check_row_stochastic, perron_weights,
                                 push_sum_deviation, sigma_push_sum,
                                 lambda_2, weaken_directed_links)
from repro.core.consensus import (mix_pytree, gossip_scan, gossip_scan_tv,
                                  gossip_scan_stale,
                                  gossip_scan_blocked, gossip_collapsed,
                                  gossip_chebyshev, collapse_mixing,
                                  chebyshev_coefficients, make_ring_gossip,
                                  make_gossip_shard_map, PushSumState,
                                  init_push_sum, gossip_push_sum,
                                  gossip_push_sum_tv, gossip_push_sum_blocked,
                                  ConsensusBackend, ShardMapBackend,
                                  CompressedBackend, lambda2_traced,
                                  make_backend, trimmed_mean_mix, median_mix,
                                  clip_weights, clipped_mix,
                                  gossip_scan_trimmed, gossip_scan_median,
                                  gossip_scan_clipped, TrimmedMeanBackend,
                                  MedianBackend, ClippedGossipBackend)
from repro.core.dfl import (DFLConfig, DFLState, DFLMetrics,
                            build_dfl_epoch_step, build_fedavg_epoch_step,
                            build_local_only_epoch_step, init_dfl_state,
                            replicate_to_clients, server_mean,
                            masked_server_mean, carry_forward,
                            broadcast_to_clients, global_mean,
                            disagreement_norm, max_client_drift,
                            active_compressor, wants_error_feedback,
                            apply_byzantine)
from repro.core.schedule import (EpochSchedule, ParticipationSchedule,
                                 TopologySchedule, SigmaTracker,
                                 FaultEvent, FaultSchedule,
                                 ByzantineAttack, ByzantineSchedule,
                                 diurnal_trace, save_participation_trace,
                                 load_participation_trace)
from repro.core.engine import DynamicFederationEngine, make_engine
from repro.core.overlap import (EpochScheduleBatch, stack_epoch_schedules,
                                build_dfl_superepoch_step)

__all__ = [n for n in dir() if not n.startswith("_")]
