"""Core: the paper's DFL protocol (topology, consensus, epoch step)."""
from repro.core.topology import (FLTopology, build_graph, is_connected,
                                 metropolis_weights, uniform_weights,
                                 check_mixing_matrix, sigma_a, spectral_gap)
from repro.core.consensus import (mix_pytree, gossip_scan, gossip_collapsed,
                                  gossip_chebyshev, collapse_mixing,
                                  chebyshev_coefficients, make_ring_gossip)
from repro.core.dfl import (DFLConfig, DFLState, DFLMetrics,
                            build_dfl_epoch_step, build_fedavg_epoch_step,
                            build_local_only_epoch_step, init_dfl_state,
                            replicate_to_clients, server_mean,
                            broadcast_to_clients, global_mean,
                            disagreement_norm, max_client_drift)

__all__ = [n for n in dir() if not n.startswith("_")]
