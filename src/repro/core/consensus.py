"""Server-side consensus updates (Eq. 5/7) as JAX ops.

The parameter pytree during DFL training carries a leading *server* axis of
size M (possibly preceded by a client axis — see ``dfl.py``).  A consensus
round is ``W <- A W`` applied leaf-wise:

    new_w[i] = a_ii * w[i] + sum_{j in N_i} a_ij * w[j]      (Eq. 5)

Execution strategies, all bit-identical in math:

* ``gossip_scan``    — the *faithful* schedule: T_S sequential rounds
                       (lax.fori_loop), each an einsum over the server axis.
                       Under pjit with the server axis sharded this lowers to
                       one all-gather (or neighbour exchanges) per round —
                       exactly the paper's per-iteration message pattern.
* ``gossip_scan_blocked`` — the same schedule streamed over fixed-size
                       parameter blocks (deterministic working set).
* ``gossip_collapsed`` — beyond-paper: precompute A_eff = A^{T_S} on the host
                       (M x M, trivial) and apply it in ONE round.  Output is
                       mathematically identical; collective rounds drop T_S x.
* ``gossip_chebyshev`` — beyond-paper: degree-k Chebyshev polynomial in A
                       reaching the same contraction with ~sqrt fewer rounds;
                       useful when rounds must stay iterative (fault probing
                       between rounds).
* ``make_gossip_shard_map`` — the production path: explicit blocked
                       all-gathers under shard_map, taking the mixing matrix
                       as a *traced operand* so one compiled program serves
                       every per-epoch graph.

``ring_gossip_shard_map`` additionally shows the TPU-native neighbour
exchange (lax.ppermute) for ring graphs under shard_map.

**Consensus backends.**  ``ConsensusBackend`` wraps each strategy behind one
interface consumed by ``dfl.build_dfl_epoch_step``:

    backend.mix(server_tree, a_p)            T_S rounds of W <- A W
    backend.mix_push_sum(state, a_p)         the ratio-consensus variant

``a_p`` is an optional traced per-epoch ``(M, M)`` mixing matrix (dynamic
federation); ``None`` selects the static topology matrix the backend was
built with.  ``make_backend`` maps a ``DFLConfig.consensus_mode`` string to
a backend; ``ShardMapBackend`` is mesh-aware and therefore constructed by
the launcher (``launch.sharding.fl_consensus_backend``) and injected via
``DFLConfig.consensus_backend``.

**Compressed consensus.**  ``CompressedBackend`` wraps any backend with the
``repro.comm`` wire simulation — lossy compression (quantization /
sparsification) of each server's outgoing message plus optional error
feedback — so every execution strategy composes with every compressor; the
host-side byte ledger is ``comm.accounting.BytesTracker``.

**Robust (Byzantine-screening) gossip.**  ``trimmed_mean_mix`` /
``median_mix`` / ``clipped_mix`` replace the weighted round ``W <- A W``
with neighbor-screening aggregation rules that tolerate adversarial
servers: coordinatewise trimmed mean (discard the ``f`` largest and ``f``
smallest supported values per coordinate, mean the rest — breakdown point
``2f < c`` with ``c`` the supported neighborhood size, self included),
coordinatewise median, and self-centered clipping (neighbor innovations
norm-clipped against the receiver's own model, expressed as an effective
per-round mixing matrix ``clip_weights`` so the round stays the einsum
``mix_pytree``).  All three are pure traced functions of ``(A_p, tree)``,
so they compose with the per-epoch matrices of dynamic federation;
``TrimmedMeanBackend`` / ``MedianBackend`` / ``ClippedGossipBackend``
register them through ``make_backend`` (``"trimmed_mean[:f]"`` /
``"median"`` / ``"clipped[:mult]"``).  Screening discards the Eq.-6
weights (a trimmed/median round is an unweighted mean over the surviving
values), so none has a push-sum analogue (``supports_directed=False``) and
none can run on the quantized physical wire (the screen must see every
neighbor's plaintext values) — both combinations refuse loudly.

**Physical wire.**  ``CompressedBackend(wire="physical")`` makes the
compressed format the format that actually crosses the interconnect:
every gossip round quantizes the local block to int8 / packed-int4 codes +
per-chunk scales *before* the collective, gathers the code buffer, and
dequantizes-and-mixes after — ``make_gossip_shard_map`` /
``make_ring_gossip`` with ``codec=`` are the collective programs,
``gossip_scan_wire`` the in-graph reference twin (bit-identical under the
shared dither convention ``comm.compressors.wire_dither``).  The wire
model changes with it: the simulated wire quantizes ONCE per period
(payload flooding — gossip is linear in the payloads), the physical wire
encodes at every hop.  What each hop encodes is the DELTA against the
receivers' shared decoded reference (innovation coding, the recursion in
``gossip_scan_wire``): the delta's magnitude contracts with consensus, so
per-hop quantization noise vanishes where the tolerance bites — raw-state
re-quantization instead floors the disagreement at the int8 grid (
measured ~1e-2 on the fig-3 task, 10x outside the paper's tolerance).
"""
from __future__ import annotations

import functools
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.comm import compressors as _compressors
from repro.comm import error_feedback as _ef
from repro.core.topology import lambda_2 as tp_lambda_2

try:                                   # jax >= 0.6: public jax.shard_map
    _shard_map = jax.shard_map
    _CHECK_KW = "check_vma"
except AttributeError:                 # jax 0.4.x: experimental location
    from jax.experimental.shard_map import shard_map as _shard_map
    _CHECK_KW = "check_rep"


def shard_map_compat(f, mesh, in_specs, out_specs, check=None):
    """jax.shard_map across the 0.4.x -> 0.6 API move (the keyword for
    replication checking was renamed check_rep -> check_vma)."""
    kw = {} if check is None else {_CHECK_KW: check}
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **kw)


def _mix_leaf(a: jax.Array, leaf: jax.Array) -> jax.Array:
    """new[i] = sum_j a[i, j] * leaf[j, ...] over the leading server axis.

    Contracts in the LEAF's dtype: under pjit the server axis is sharded, so
    this lowers to an all-gather of (M x shard) — doing it in bf16 moves and
    holds half the bytes of the promoted-f32 contraction (A itself is tiny
    and cast down; one bf16 rounding per round matches what real multi-host
    gossip over bf16 wires would do)."""
    return jnp.tensordot(a.astype(leaf.dtype), leaf, axes=([1], [0]))


def mix_pytree(a: jax.Array, tree: Any) -> Any:
    """One consensus round ``W <- A W`` applied to every leaf."""
    return jax.tree.map(functools.partial(_mix_leaf, a), tree)


def gossip_scan(a: jax.Array, tree: Any, t_server: int) -> Any:
    """Faithful T_S-round consensus (Alg. 1 server loop).

    One fori_loop PER LEAF (leaves gossip independently, so round-leaf
    reordering is exact): XLA schedules the per-leaf while-loops one after
    another, keeping only one leaf's (M x shard) all-gather live at a time
    instead of the whole model's."""
    if t_server == 0:
        return tree

    def leaf_loop(leaf):
        return jax.lax.fori_loop(
            0, t_server, lambda _, w: _mix_leaf(a, w), leaf)

    return jax.tree.map(leaf_loop, tree)


def gossip_scan_stale(a: jax.Array, tree: Any, t_server: int,
                      staleness: int) -> Any:
    """Bounded-staleness consensus: round ``t`` mixes the ``s``-round-old
    iterate, ``W_(t+1) = A W_(t-s)``, freezing ``W_(t+1) = W_t`` while no
    delayed iterate exists yet (``t < s``) — the overlap model where a
    server consumes neighbor state that left ``s`` rounds ago while its own
    round-``t`` send is still in flight.  In exact arithmetic the period
    composes to ``A^(T_S // (s+1))``: of every ``s+1`` rounds only one
    advances the chain (the rest re-mix the same delayed iterate), which is
    the staleness-augmented contraction ``schedule.SigmaTracker`` monitors.
    ``staleness=0`` IS ``gossip_scan`` — the call branches to the literally
    unchanged synchronous body, so the degeneration is bitwise."""
    if staleness <= 0:
        return gossip_scan(a, tree, t_server)
    if t_server == 0:
        return tree

    def leaf_loop(leaf):
        # carry the last s+1 iterates: hist[u] = W_(t-s+u) at the start of
        # round t (clamped to W_0 before round s)
        def one_round(t, hist):
            new = jax.lax.cond(t >= staleness,
                               lambda: _mix_leaf(a, hist[0]),
                               lambda: hist[-1])
            return hist[1:] + (new,)

        hist = jax.lax.fori_loop(0, t_server, one_round,
                                 (leaf,) * (staleness + 1))
        return hist[-1]

    return jax.tree.map(leaf_loop, tree)


def gossip_scan_tv(a_rounds: jax.Array, tree: Any) -> Any:
    """Time-varying consensus: round t applies ``a_rounds[t]``.

    ``a_rounds`` layout — a traced ``(T_S, M, M)`` stack with one mixing
    matrix PER ROUND, not per epoch: ``a_rounds[t]`` is the operator of
    consensus round ``t`` within a single consensus period, so the leading
    axis is the round index and its length is this period's T_S.  This is
    the fully general form of Eq. 5 where the server graph may change
    BETWEEN ROUNDS (link failures mid-consensus, straggler reweighting).
    Contrast ``schedule.TopologySchedule``, which emits ONE ``(M, M)``
    matrix per epoch ``A_p``; to feed such a per-epoch matrix here,
    broadcast it to ``(T_S, M, M)`` — a stack of T_S identical matrices is
    exactly ``gossip_scan(a, tree, T_S)`` (same per-round operator, same
    ordering).  Each round preserves the server mean when every
    ``a_rounds[t]`` is doubly stochastic, and the ordered product of the
    stack governs the contraction (``topology.sigma_product`` with t_s=1
    per entry)."""
    if a_rounds.shape[0] == 0:
        return tree

    def leaf_loop(leaf):
        return jax.lax.fori_loop(
            0, a_rounds.shape[0],
            lambda i, w: _mix_leaf(a_rounds[i], w), leaf)

    return jax.tree.map(leaf_loop, tree)


def gossip_scan_blocked(a: jax.Array, tree: Any, t_server: int,
                        block: int = 4_194_304,
                        flat_sharding=None) -> Any:
    """Faithful T_S-round gossip, streamed over fixed-size parameter blocks.

    Blocks gossip independently, so iterating (block-major, round-minor)
    instead of (round-major, leaf-minor) is *exactly* the same operator —
    but the live working set per step is one (M, block) gather instead of a
    full parameter leaf per server (which at 27B+ scales is multi-GB per
    in-flight leaf; XLA-CPU additionally upcasts bf16 contractions to f32,
    doubling it).  Used by the epoch step whenever the model is large;
    ``gossip_scan`` remains the reference for tests and small models.
    """
    if t_server == 0:
        return tree
    leaves, treedef = jax.tree.flatten(tree)
    m = leaves[0].shape[0]
    dtype = leaves[0].dtype
    sizes = [l[0].size for l in leaves]
    flat = jnp.concatenate([l.reshape(m, -1) for l in leaves], axis=1)
    d = flat.shape[1]
    nb = max(1, -(-d // block))
    pad = nb * block - d
    if pad:
        flat = jnp.pad(flat, ((0, 0), (0, pad)))
    if flat_sharding is not None:
        # keep the flattened model sharded over the intra-client axes —
        # without this the concat of heterogeneously-sharded leaves makes
        # the partitioner replicate the whole model per device.
        flat = jax.lax.with_sharding_constraint(flat, flat_sharding)
    blocks = jnp.moveaxis(flat.reshape(m, nb, block), 1, 0)   # (nb, M, blk)
    a_cast = a.astype(dtype)

    def per_block(_, blk):
        out = jax.lax.fori_loop(
            0, t_server, lambda _i, w: jnp.tensordot(a_cast, w,
                                                     axes=([1], [0])), blk)
        return None, out

    _, mixed = jax.lax.scan(per_block, None, blocks)
    flat = jnp.moveaxis(mixed, 0, 1).reshape(m, nb * block)[:, :d]
    if flat_sharding is not None:
        flat = jax.lax.with_sharding_constraint(flat, flat_sharding)
    out, off = [], 0
    new_leaves = []
    for leaf, size in zip(leaves, sizes):
        new_leaves.append(flat[:, off:off + size].reshape(leaf.shape))
        off += size
    return jax.tree.unflatten(treedef, new_leaves)


# ---------------------------------------------------------------------------
# quantized-wire gossip: the per-round physical wire model, in-graph
# ---------------------------------------------------------------------------

DEFAULT_GOSSIP_BLOCK = 4_194_304


def _wire_mix_rows(a32: jax.Array, g: jax.Array) -> jax.Array:
    """``out[i] = sum_j a32[i, j] * g[j]`` accumulated LEFT TO RIGHT in f32
    — the exact multiply-add order of the shard_map round body (one term
    per server, f32 accumulator), so the in-graph wire simulation is
    bit-identical to the physical collective path, not merely allclose."""
    m = g.shape[0]
    ones = (1,) * (g.ndim - 1)
    acc = a32[:, 0].reshape((-1,) + ones) * g[0]
    for j in range(1, m):
        acc = acc + a32[:, j].reshape((-1,) + ones) * g[j]
    return acc


def _wire_dither_rows(codec, key, m: int, nb: int, blk: int, *, leaf,
                      rnd, block_ids=None):
    """(m, nb, blk) dither for one round of one leaf under the shared
    convention, or the deterministic 0.5 when no key is given."""
    del codec
    if key is None:
        return 0.5
    blocks = jnp.arange(nb) if block_ids is None else block_ids
    return jax.vmap(lambda s: jax.vmap(
        lambda b: _compressors.wire_dither(
            key, (blk,), leaf=leaf, rnd=rnd, server=s, block=b))(
                blocks))(jnp.arange(m))


def gossip_scan_wire(a: jax.Array, tree: Any, t_server: int, codec,
                     key: Optional[jax.Array] = None, *,
                     block: int = DEFAULT_GOSSIP_BLOCK,
                     block_major: bool = False) -> Any:
    """Per-round quantized-WIRE gossip, in-graph: the reference numerics of
    the physical collective paths.  Every round, every server encodes the
    DELTA between its iterate and the receivers' shared decoded estimate of
    it (innovation coding) to wire codes (``codec.encode_block`` — int8 /
    packed int4 + per-chunk scales) with the shared dither convention
    (``comm.compressors.wire_dither``); every receiver accumulates the
    decoded deltas into its reference copy of every sender and mixes those
    references:

        delta_t = W_t - R_{t-1}          (encoded; crosses the wire)
        R_t     = R_{t-1} + D(C(delta_t))
        W_{t+1} = A · R_t                (R_0 = 0)

    Why deltas and not the raw state: re-quantizing the full iterate at
    every hop injects absmax-scaled noise 25x per period — measured on the
    fig-3 task, stochastic rounding random-walks at a ~1e-2 disagreement
    floor and round-to-nearest locks a dead-zone bias of ~3 grid steps
    (err 0.12), both far outside the paper's tolerance.  The delta's
    absmax CONTRACTS with consensus, so the per-hop quantization noise
    vanishes exactly where the tolerance bites; round 0 (R_0 = 0) still
    ships the full state, and that transmission is what period-level error
    feedback tracks (``wire_roundtrip_tree``).  Same codes + scales per
    round on the wire — the byte ledger is unchanged.

    LEGACY per-leaf layout (PR 5): every leaf is blocked and encoded
    independently, with per-(leaf, round, server, block) dither, so a
    realistic pytree pays two collectives per block per leaf per round.
    The shipping paths moved to the BUCKETED layout
    (``gossip_scan_wire_bucketed`` — one flattened code buffer for the
    whole tree, one collective pair per round); this function stays as the
    per-leaf reference oracle of ``kernels.consensus_mix.
    quantized_gossip_round_2d`` and the layout the per-leaf byte counter
    (``comm.accounting.physical_leaf_bytes``) describes.
    ``block_major=True`` streams (block-major, round-minor) like
    ``gossip_scan_blocked`` — the identical operator bit for bit, since
    blocks gossip and encode independently.

    Zero padding of the ragged tail block is harmless by construction: a
    zero element never raises its chunk's absmax and quantizes to code
    ``floor(0 + u) = 0`` for every dither ``u < 1``, so pad deltas stay
    exactly zero, references stay zero, and pads mix to zero (see
    ``StochasticQuantizer.encode_block``)."""
    if t_server == 0:
        return tree
    leaves, treedef = jax.tree.flatten(tree)
    m = leaves[0].shape[0]
    a32 = a.astype(jnp.float32)
    new_leaves = []
    for li, leaf in enumerate(leaves):
        dtype = leaf.dtype
        flat = leaf.reshape(m, -1)
        d = flat.shape[1]
        blk = min(block, d)
        nb = -(-d // blk)
        if nb * blk != d:
            flat = jnp.pad(flat, ((0, 0), (0, nb * blk - d)))
        rows = flat.reshape(m, nb, blk)

        def one_round(t, carry, li=li, blk=blk, nb=nb, dtype=dtype):
            rows, ref = carry                        # (m, nb, blk) each
            delta = rows.astype(jnp.float32) - ref
            dither = _wire_dither_rows(codec, key, m, nb, blk, leaf=li,
                                       rnd=t)
            codes, scales = codec.encode_block(delta, dither)
            ref = ref + codec.decode_block(codes, scales, blk)
            return _wire_mix_rows(a32, ref).astype(dtype), ref

        if block_major:
            def per_block(_, xs, li=li, blk=blk, dtype=dtype):
                rows_b, b = xs                       # (m, blk), block index

                def rnd_fn(t, carry):
                    w, ref = carry
                    delta = w.astype(jnp.float32) - ref
                    dither = _wire_dither_rows(
                        codec, key, m, 1, blk, leaf=li, rnd=t,
                        block_ids=b[None])
                    codes, scales = codec.encode_block(
                        delta[:, None, :], dither)
                    ref = ref + codec.decode_block(codes, scales,
                                                   blk)[:, 0]
                    return _wire_mix_rows(a32, ref).astype(dtype), ref

                out, _ = jax.lax.fori_loop(
                    0, t_server, rnd_fn,
                    (rows_b, jnp.zeros_like(rows_b, jnp.float32)))
                return None, out

            _, mixed = jax.lax.scan(
                per_block, None, (jnp.moveaxis(rows, 1, 0), jnp.arange(nb)))
            rows = jnp.moveaxis(mixed, 0, 1)
        else:
            rows, _ = jax.lax.fori_loop(
                0, t_server, one_round,
                (rows, jnp.zeros_like(rows, jnp.float32)))
        flat = rows.reshape(m, nb * blk)[:, :d]
        new_leaves.append(flat.reshape(leaf.shape))
    return jax.tree.unflatten(treedef, new_leaves)


def _bucket_flat(leaves) -> jax.Array:
    """(m, d_tot) bucket view of a server tree's leaves, every leaf cast to
    the FIRST leaf's dtype (the bucket's single wire dtype) and flattened
    row-wise in leaf order."""
    m = leaves[0].shape[0]
    dtype = leaves[0].dtype
    return jnp.concatenate(
        [leaf.astype(dtype).reshape(m, -1) for leaf in leaves], axis=1)


def _bucket_split(flat: jax.Array, leaves, treedef) -> Any:
    """Invert ``_bucket_flat``: slice the (m, >=d_tot) bucket back into the
    original leaf shapes/dtypes (any pad tail is dropped)."""
    out, off = [], 0
    for leaf in leaves:
        size = int(np.prod(leaf.shape[1:], dtype=np.int64))
        out.append(flat[:, off:off + size].reshape(leaf.shape)
                   .astype(leaf.dtype))
        off += size
    return jax.tree.unflatten(treedef, out)


def _bucket_dither_rows(codec, key, m: int, d_pad: int, *, rnd):
    """(m, d_pad) dither for one round of the BUCKETED wire — one
    ``wire_dither`` draw per server over the whole padded bucket (leaf and
    block coordinates pinned to 0: the bucket is one logical block of one
    logical leaf), or the deterministic 0.5 without a key."""
    del codec
    if key is None:
        return 0.5
    return jax.vmap(lambda s: _compressors.wire_dither(
        key, (d_pad,), leaf=0, rnd=rnd, server=s, block=0))(jnp.arange(m))


def gossip_scan_wire_bucketed(a: jax.Array, tree: Any, t_server: int,
                              codec, key: Optional[jax.Array] = None, *,
                              block: int = DEFAULT_GOSSIP_BLOCK,
                              staleness: int = 0) -> Any:
    """BUCKETED quantized-wire gossip, in-graph: the reference numerics of
    the physical collective paths since PR 6.  Same innovation recursion as
    ``gossip_scan_wire`` (delta-coded against the receivers' shared decoded
    reference — see there for why deltas and not raw state), but the whole
    pytree is flattened into ONE zero-padded code buffer per server
    (``comm.compressors.bucket_block`` layout), so every round ships
    exactly one code buffer + one scale buffer per server — what the
    shard_map program realises as one s8 all-gather + one f32 all-gather.

    The ``(M, d)`` reference matrix of the per-leaf form is factored into a
    per-server band: server ``i`` carries only its OWN reference row
    ``r_i`` and a running accumulator ``acc_i`` of the mixed references,
    using ``R_t = R_{t-1} + Δ_t`` to fold the mix incrementally::

        delta_t = W_t - r_(t-1)                (encoded; crosses the wire)
        r_t     = r_(t-1) + D(C(delta_t))_i    (own decoded innovation)
        acc_t   = acc_(t-1) + sum_j a[i,j] * D(C(delta_t))_j
        W_(t+1) = acc_t                        (acc_0-pre = 0, r_0-pre = 0)

    which telescopes to ``acc_t = (A · R_t)_i`` exactly — same fixed point,
    same contraction, but the per-device live state drops from ``(M+1)``
    rows to 3 (iterate, own reference, accumulator): the 926→~600 MB RSS
    fix of the shard_map wire.  The sum over ``j`` accumulates LEFT TO
    RIGHT in f32, one term per server, matching the shard_map round body
    term for term, so this simulation is bit-identical to the physical
    program under a shared key (asserted for int8 AND packed int4 in
    ``tests/test_wire.py``).  Mixed-dtype trees ride the wire in the FIRST
    leaf's dtype (one bucket, one wire dtype) and are cast back on exit.

    Zero padding of the bucket tail is harmless for the same reason as in
    ``gossip_scan_wire``: pad deltas quantize to zero codes and never
    perturb a real chunk's absmax scale (pads occupy whole chunks — the
    bucket block is a chunk multiple).

    **Bounded staleness** (``staleness=s > 0``): round ``t`` consumes the
    gathered code+scale buffers of round ``t - s`` while its own round-``t``
    encode is issued — the carry grows a ring of the last ``s`` in-flight
    gathered buffers (the software-pipelined / double-buffered form: the
    collective that ships round ``t`` overlaps the decode+mix work of round
    ``t - s``).  The sender encodes against its up-to-date SENT reference
    (own decodes fold in at production time), so innovations never
    double-ship; receivers need no per-neighbor reference at all — the
    accumulator telescopes over whatever decoded deltas have arrived, which
    is exactly why delta codes tolerate lateness: the sum over rounds
    commutes.  The iterate freezes until the first delayed buffer lands
    (``t < s``) and the last ``s`` rounds' codes are never consumed
    (bounded staleness discards the tail), composing to ``A^(T_S//(s+1))``
    in exact arithmetic.  ``staleness=0`` takes the literally unchanged
    synchronous body above — bitwise degeneration, the PR-5/6 oracle
    pattern."""
    if t_server == 0:
        return tree
    if staleness < 0:
        raise ValueError(f"staleness must be >= 0, got {staleness}")
    leaves, treedef = jax.tree.flatten(tree)
    m = leaves[0].shape[0]
    dtype = leaves[0].dtype
    flat = _bucket_flat(leaves)
    d_tot = flat.shape[1]
    blk, nb = _compressors.bucket_block(d_tot, block, codec.chunk)
    d_pad = nb * blk
    if d_pad != d_tot:
        flat = jnp.pad(flat, ((0, 0), (0, d_pad - d_tot)))
    a32 = a.astype(jnp.float32)
    zeros = jnp.zeros((m, d_pad), jnp.float32)

    if staleness == 0:
        def one_round(t, carry):
            w, ref, acc = carry        # (m, d_pad): wire dtype, f32, f32
            delta = w.astype(jnp.float32) - ref
            dither = _bucket_dither_rows(codec, key, m, d_pad, rnd=t)
            codes, scales = codec.encode_block(delta, dither)
            # fused dequantize-and-mix, folded exactly like the shard_map
            # round body: per-chunk scales (and the mixing weight) broadcast
            # onto raw f32 codes, one server term at a time — the same
            # scale-times-code and weight-times-scale products in the same
            # order, which is what keeps the simulation bit-identical to the
            # physical program
            c3 = codec.code_chunks(codes, d_pad)       # (m, nc, chunk)
            ref = ref + (c3 * scales[..., None]).reshape(m, d_pad)
            ws = a32[:, :, None] * scales              # (m, m, nc): ws[i, j]
            acc3 = acc.reshape(m, -1, codec.chunk)
            for j in range(m):
                acc3 = acc3 + ws[:, j, :, None] * c3[j]
            acc = acc3.reshape(m, d_pad)
            return acc.astype(dtype), ref, acc

        out, _, _ = jax.lax.fori_loop(0, t_server, one_round,
                                      (flat, zeros, zeros))
        return _bucket_split(out, leaves, treedef)

    # ring of the last `staleness` in-flight (codes, scales) buffers; zero
    # codes + unit scales decode to nothing, so the pre-fill consumed
    # before round s is inert
    code_abs = jax.eval_shape(
        lambda x: codec.encode_block(x, 0.5)[0],
        jax.ShapeDtypeStruct((m, d_pad), jnp.float32))
    ring_c = jnp.zeros((staleness,) + code_abs.shape, code_abs.dtype)
    ring_s = jnp.ones((staleness, m, d_pad // codec.chunk), jnp.float32)

    def one_round_stale(t, carry):
        w, sref, acc, rc, rs = carry
        # produce round t: encode against the SENT reference, fold the own
        # decode in immediately (the next innovation must not re-ship it)
        delta = w.astype(jnp.float32) - sref
        dither = _bucket_dither_rows(codec, key, m, d_pad, rnd=t)
        codes, scales = codec.encode_block(delta, dither)
        own3 = codec.code_chunks(codes, d_pad)     # (m, nc, chunk)
        sref = sref + (own3 * scales[..., None]).reshape(m, d_pad)
        # consume round t - s: the oldest gathered buffer in the ring
        old_c, old_s = rc[0], rs[0]
        c3 = codec.code_chunks(old_c, d_pad)
        ws = a32[:, :, None] * old_s               # (m, m, nc): ws[i, j]
        acc3 = acc.reshape(m, -1, codec.chunk)
        for j in range(m):
            acc3 = acc3 + ws[:, j, :, None] * c3[j]
        acc = acc3.reshape(m, d_pad)
        rc = jnp.concatenate([rc[1:], codes[None]], axis=0)
        rs = jnp.concatenate([rs[1:], scales[None]], axis=0)
        # the iterate advances only once a delayed buffer has landed
        w = jnp.where(t >= staleness, acc.astype(dtype), w)
        return w, sref, acc, rc, rs

    out, _, _, _, _ = jax.lax.fori_loop(
        0, t_server, one_round_stale, (flat, zeros, zeros, ring_c, ring_s))
    return _bucket_split(out, leaves, treedef)


def bucketed_roundtrip_tree(codec, tree: Any,
                            key: Optional[jax.Array] = None, *,
                            block: int = DEFAULT_GOSSIP_BLOCK,
                            rnd: int = 0) -> Any:
    """One wire round-trip of a server tree in the BUCKETED physical byte
    layout: the whole pytree flattened (first leaf's dtype), zero-padded to
    the ``comm.compressors.bucket_block`` grid, and encoded/decoded with
    the shared round-``rnd`` bucket dither — exactly what round ``rnd`` of
    the bucketed physical gossip ships of each server's OWN model.  The
    error-feedback hook of the bucketed wire (successor of the per-leaf
    ``wire_roundtrip_tree``): bucket chunk boundaries cross leaf
    boundaries, so the per-leaf round-trip no longer reproduces the
    transmission."""
    leaves, treedef = jax.tree.flatten(tree)
    m = leaves[0].shape[0]
    flat = _bucket_flat(leaves).astype(jnp.float32)
    d_tot = flat.shape[1]
    blk, nb = _compressors.bucket_block(d_tot, block, codec.chunk)
    d_pad = nb * blk
    if d_pad != d_tot:
        flat = jnp.pad(flat, ((0, 0), (0, d_pad - d_tot)))
    dither = _bucket_dither_rows(codec, key, m, d_pad, rnd=rnd)
    codes, scales = codec.encode_block(flat, dither)
    y = codec.decode_block(codes, scales, d_pad)
    return _bucket_split(y, leaves, treedef)


def wire_roundtrip_tree(codec, tree: Any, key: Optional[jax.Array] = None,
                        *, block: int = DEFAULT_GOSSIP_BLOCK,
                        rnd: int = 0) -> Any:
    """One wire round-trip of a server tree in the LEGACY per-leaf physical
    byte layout: each leaf row flattened, zero-padded to ``block``-element
    blocks, and encoded/decoded with the shared round-``rnd`` dither —
    exactly what round ``rnd`` of the per-leaf physical gossip
    (``gossip_scan_wire``) ships of each server's OWN model.  The shipping
    paths use ``bucketed_roundtrip_tree`` since PR 6; this stays the
    round-0 oracle of the per-leaf reference."""
    leaves, treedef = jax.tree.flatten(tree)
    m = leaves[0].shape[0]
    out = []
    for li, leaf in enumerate(leaves):
        flat = leaf.reshape(m, -1)
        d = flat.shape[1]
        blk = min(block, d)
        nb = -(-d // blk)
        if nb * blk != d:
            flat = jnp.pad(flat, ((0, 0), (0, nb * blk - d)))
        rows = flat.reshape(m, nb, blk).astype(jnp.float32)
        dither = _wire_dither_rows(codec, key, m, nb, blk, leaf=li, rnd=rnd)
        codes, scales = codec.encode_block(rows, dither)
        y = codec.decode_block(codes, scales, blk)
        out.append(y.reshape(m, nb * blk)[:, :d].reshape(leaf.shape)
                   .astype(leaf.dtype))
    return jax.tree.unflatten(treedef, out)


# ---------------------------------------------------------------------------
# push-sum (ratio) consensus for DIRECTED server graphs
#
# When link failures make the graph directed, no doubly-stochastic matrix
# may exist on its support (Eq. 6 is unsatisfiable): the best a node can do
# locally is split its mass over its out-neighbours — a ROW-stochastic A
# (topology.out_degree_weights).  Naive gossip with such an A converges to
# the Perron-weighted average pi' W (pi the left Perron vector of A), a
# BIASED aggregate.  Push-sum / ratio consensus (Kempe et al. 2003;
# Nedic & Olshevsky 2015) fixes this by mixing a numerator AND a scalar
# weight with the column-stochastic transpose P = A' and reading out the
# ratio:
#
#     num <- P num,   w <- P w,     z_i = num_i / w_i
#
# P column-stochastic preserves both sums (sum num = sum W_0, sum w = M),
# and P^t -> v 1' (sum v = 1), so num -> v * sum(W_0), w -> v * M and every
# ratio z_i -> the exact uniform mean — the skew v cancels.  Operationally
# each round IS the row-stochastic protocol run in push mode: node i sends
# a[i, j]-weighted shares of its (num, w) along its OUT-edges; P = A' is
# just that send pattern written as a matrix acting on the receive side.
# When A is doubly stochastic, P = A' is row-stochastic too, w stays at 1
# identically and push-sum degenerates to plain gossip.
# ---------------------------------------------------------------------------


class PushSumState(NamedTuple):
    """Numerator pytree (leaves ``(M, *w)``) + per-server scalar weight
    ``(M,)``.  Invariants under mixing: weights stay positive and sum to M;
    ``ratio()`` of a freshly-initialised state is the values themselves."""

    values: Any          # numerator pytree, leading server axis M
    weight: jax.Array    # (M,) float, > 0, sum == M

    def ratio(self) -> Any:
        """The unbiased read-out z_i = num_i / w_i, broadcast leaf-wise."""
        return jax.tree.map(
            lambda v: v / self.weight.reshape(
                (-1,) + (1,) * (v.ndim - 1)).astype(v.dtype),
            self.values)


def init_push_sum(tree: Any) -> PushSumState:
    """Start of a consensus period: numerator = the server models, weight =
    1 for every server.  Weights RESET here each period by design: with a
    persistent weight the finite-round ratio is no longer exact on
    consensus states (P^t(c*1)/P^t(1) == c for all t only when num and w
    start aligned), and re-weighting the numerator by a carried weight
    provably re-introduces the Perron bias — see docs/dynamic_federation.md."""
    m = jax.tree.leaves(tree)[0].shape[0]
    return PushSumState(tree, jnp.ones((m,), jnp.float32))


def _push_leaf(p: jax.Array, leaf: jax.Array) -> jax.Array:
    return jnp.tensordot(p.astype(leaf.dtype), leaf, axes=([1], [0]))


def gossip_push_sum(a: jax.Array, state: PushSumState,
                    t_server: int) -> PushSumState:
    """T_S rounds of push-sum over a ROW-stochastic ``a`` (shape (M, M),
    support = directed graph + self-loops, e.g. topology.out_degree_weights).

    Numerator and weight are mixed with the same column-stochastic operator
    ``P = a.T``; they interact only at read-out (``.ratio()``), so each leaf
    loops independently exactly like ``gossip_scan``.  The weight recursion
    is a tiny (M,) matvec and costs nothing next to the parameter leaves."""
    if t_server == 0:
        return state
    p = a.T

    def leaf_loop(leaf):
        return jax.lax.fori_loop(
            0, t_server, lambda _, w: _push_leaf(p, w), leaf)

    values = jax.tree.map(leaf_loop, state.values)
    weight = jax.lax.fori_loop(
        0, t_server, lambda _, w: (p @ w.astype(p.dtype)).astype(w.dtype),
        state.weight)
    return PushSumState(values, weight)


def gossip_push_sum_blocked(a: jax.Array, state: PushSumState,
                            t_server: int, block: int = 4_194_304,
                            flat_sharding=None) -> PushSumState:
    """Blocked push-sum: the ``gossip_scan_blocked`` streaming schedule run
    in ratio-consensus form.  The numerator pytree is streamed through the
    same fixed-``block`` machinery with the column-stochastic operator
    ``P = a.T`` (blocks mix independently, so block-major iteration is the
    identical operator), while the ``(M,)`` weight recursion is a trivial
    matvec outside the stream.  Accepts a traced per-epoch ``a``.

    Functional form of ``BlockedGossipBackend.mix_push_sum`` (which is
    just the generic ``ConsensusBackend.mix_push_sum`` over the blocked
    ``_mix``) — one source of truth for the streaming push-sum logic."""
    if t_server == 0:
        return state
    return BlockedGossipBackend(
        None, t_server, block=block,
        flat_sharding=flat_sharding).mix_push_sum(state, a)


def gossip_push_sum_tv(a_rounds: jax.Array,
                       state: PushSumState) -> PushSumState:
    """Time-varying push-sum: round t mixes with ``a_rounds[t].T``.

    ``a_rounds`` follows the ``gossip_scan_tv`` layout — a traced
    ``(T_S, M, M)`` stack of ROW-stochastic matrices, one per round.  Every
    round preserves sum(num) and sum(w) (each transpose is column
    stochastic), so the ratio read-out stays unbiased under arbitrary
    per-round graph changes as long as the sequence is jointly strongly
    connected."""
    if a_rounds.shape[0] == 0:
        return state

    def leaf_loop(leaf):
        return jax.lax.fori_loop(
            0, a_rounds.shape[0],
            lambda i, w: _push_leaf(a_rounds[i].T, w), leaf)

    values = jax.tree.map(leaf_loop, state.values)
    weight = jax.lax.fori_loop(
        0, a_rounds.shape[0],
        lambda i, w: (a_rounds[i].T @ w.astype(a_rounds.dtype)).astype(w.dtype),
        state.weight)
    return PushSumState(values, weight)


def collapse_mixing(a: np.ndarray, t_server: int) -> np.ndarray:
    """A_eff = A^{T_S} (host-side, float64). Doubly stochastic by closure."""
    return np.linalg.matrix_power(np.asarray(a, dtype=np.float64), t_server)


def gossip_collapsed(a_eff: jax.Array, tree: Any) -> Any:
    """Single-round application of the collapsed operator A^{T_S}."""
    return mix_pytree(a_eff, tree)


# ---------------------------------------------------------------------------
# Chebyshev-accelerated gossip (beyond-paper)
# ---------------------------------------------------------------------------


def chebyshev_coefficients(a: np.ndarray, rounds: int) -> float:
    """Return the contraction sigma achieved by ``rounds`` Chebyshev steps
    (for reporting).  Uses lambda_2 of the symmetric mixing matrix."""
    ev = np.sort(np.abs(np.linalg.eigvalsh(a)))[::-1]
    lam2 = ev[1] if len(ev) > 1 else 0.0
    if lam2 == 0.0:
        return 0.0
    # |T_k(1/lam2)|^{-1} with T_k the Chebyshev polynomial of the first kind
    x = 1.0 / lam2
    return float(1.0 / np.cosh(rounds * np.arccosh(x)))


def gossip_chebyshev(a: jax.Array, tree: Any, rounds: int, lam2) -> Any:
    """Chebyshev semi-iterative consensus:  w_k = 2 c_k/(lam2 c_{k+1}) A w_{k-1}
    - (c_{k-1}/c_{k+1}) w_{k-2}, with c_k = cosh(k acosh(1/lam2)).

    Reaches sigma ~ 2 rho^k (rho = (1-sqrt(1-lam2^2))/lam2) instead of lam2^k:
    ~sqrt(1/(1-lam2)) fewer rounds for the same contraction.  Exactly
    mean-preserving like plain gossip (each update is an affine combination
    of doubly-stochastic operators with coefficients summing to 1).

    ``lam2`` may be a host-side float (static topology) or a TRACED scalar
    — the per-epoch spectral estimate a ``TopologySchedule`` feeds through
    ``schedule.EpochSchedule.lam2`` under dynamic federation.  The
    recursion therefore carries the bounded ratio ``r_k = c_{k-1}/c_k`` in
    place of the coefficients themselves (the raw c_k overflow f32 within
    a few rounds when lam2 is small):

        alpha_k = 2x / (2x - r_k),  beta_k = r_k / (2x - r_k),
        r_{k+1} = 1 / (2x - r_k),   x = 1/lam2,  r_1 = lam2,

    with ``alpha_k - beta_k = 1`` (mean preservation) for every lam2.
    A clamped ``lam2 -> 0`` degenerates gracefully to plain repeated
    mixing (alpha -> 1, beta -> 0)."""
    if rounds == 0:
        return tree
    if isinstance(lam2, (int, float)) and lam2 <= 0.0:
        return mix_pytree(a, tree)
    x = 1.0 / jnp.maximum(jnp.asarray(lam2, jnp.float32), 1e-6)
    r = 1.0 / x          # r_1 = c_0 / c_1 = lam2

    w_prev = tree
    w_cur = mix_pytree(a, tree)  # k = 1: the first semi-iterate is just A w
    for _ in range(1, rounds):
        denom = 2.0 * x - r
        alpha, beta = 2.0 * x / denom, r / denom
        mixed = mix_pytree(a, w_cur)
        w_next = jax.tree.map(
            lambda m, p: (alpha * m - beta * p).astype(m.dtype), mixed, w_prev)
        w_prev, w_cur = w_cur, w_next
        r = 1.0 / denom
    return w_cur


def lambda2_traced(a: jax.Array) -> jax.Array:
    """|lambda_2| of a traced symmetric mixing matrix, computed in-graph
    (tiny (M, M) eigendecomposition).  Fallback for calling a spectral
    backend with a traced ``A_p`` but no host-side estimate — the engine
    normally feeds ``topology.lambda_2`` through the schedule instead."""
    if a.shape[0] < 2:
        return jnp.zeros((), jnp.float32)
    ev = jnp.sort(jnp.abs(jnp.linalg.eigvalsh(a)))
    return ev[-2].astype(jnp.float32)


# ---------------------------------------------------------------------------
# shard_map gossip: fully-manual blocked server gossip (the production path)
# ---------------------------------------------------------------------------


def make_gossip_shard_map(mesh, t_server: int, leaf_specs: Any, *,
                          axis_name: str = "server",
                          block: int = 16_777_216, codec=None,
                          stochastic: bool = True,
                          gather_codes: bool = True,
                          with_shipped: bool = False,
                          staleness: int = 0) -> Callable:
    """T_S-round gossip as an explicit shard_map program, returned as
    ``run(operator, tree)`` with the ``(M, M)`` mixing ``operator`` a
    *traced operand* — one compiled program serves every per-epoch graph
    (dynamic federation), and a compile-time-constant operator recovers the
    static case.  Pass ``A`` for plain gossip ``W <- A W``; pass ``A.T``
    (the column-stochastic transpose) to mix a push-sum numerator — the
    body applies ``operator`` row-wise either way.

    Inside the shard_map every device flattens its LOCAL weight shards into
    one vector and scans over fixed ``block``-element slices; each slice
    runs the full T_S-round loop (blocks gossip independently, so
    block-major iteration is the identical operator).  Per-round transfer
    is one bf16 all-gather of (M, block) over the server axis — memory is
    deterministic (~(M+2) x block x 2 bytes live) and dtype is under our
    control, unlike the pjit einsum form where XLA-CPU upcasts the
    contraction operand to f32 *before* the gather and overlaps per-leaf
    loops (~12 GB of f32 gathers at 27B scale).

    ``leaf_specs``: PartitionSpec pytree of the server tree (leading
    'server' axis + intra-client weight axes) — used as in_specs and
    out_specs; the operator itself rides in replicated.

    **Quantized wire mode** (``codec=`` a ``comm.compressors.
    StochasticQuantizer``): the returned ``run(operator, tree, key)``
    flattens the device's ENTIRE local tree into one zero-padded bucket
    (``comm.compressors.bucket_block`` layout) and delta-codes it against
    the receivers' shared decoded reference — see
    ``gossip_scan_wire_bucketed`` for the recursion and why innovations
    rather than raw state — to int8 / packed-int4 codes + per-chunk f32
    scales *before* the gather.  Each round is then exactly ONE all-gather
    of s8 codes plus one of f32 scales no matter how many leaves the
    pytree has (two collective sites in the compiled HLO, guarded by a
    regression test), and the collective operand is 1/4 (int8) or 1/8
    (int4) of the f32 wire, for real, asserted against compiled HLO.  The
    per-leaf form's ``(M, block)`` resident reference matrix is factored
    into a per-device band — iterate, OWN reference row, and mixed-
    reference accumulator, ~3 bucket-sized vectors live — which is the
    926→~600 MB RSS fix at benchmark scale.  Dither follows the shared
    ``comm.compressors.wire_dither`` convention with the bucket's (leaf,
    block) coordinates pinned to 0 and the server coordinate the device's
    LINEARIZED mesh position (server-major): when ``leaf_specs`` shard
    weight axes over further mesh axes (tp / fsdp), the shards of one
    server row draw DISTINCT rounding noise; on a pure ``(server,)`` mesh
    it reduces to the server index — which is what keeps the program
    bit-identical to ``gossip_scan_wire_bucketed`` (whose rows are
    unsharded) under the same key.  ``stochastic=False`` builds the
    deterministic round-to-nearest program (no key needed).
    ``gather_codes=False`` is the simulated twin for parity tests: the
    same code values cross the wire at full f32 width — 4x the bytes,
    identical ops — asserted bitwise equal to the physical program,
    proving the narrow wire changes encoding width only.  Zero-padded
    bucket tails are harmless: pad deltas quantize to zero codes and never
    perturb real chunks' scales (see ``StochasticQuantizer.encode_block``).

    ``with_shipped=True`` makes ``run`` return ``(mixed tree, shipped
    tree)`` where ``shipped`` is each device's own round-0 decoded
    transmission — the error-feedback hook: it is computed INSIDE the
    program, with the exact local-shard bucket/chunk/dither layout that
    crossed the wire (an outside ``bucketed_roundtrip_tree`` would only
    reproduce it for unsharded rows).

    **Bounded staleness** (``staleness=s > 0``, codec mode only): the round
    body becomes software-pipelined — round ``t``'s code+scale gather is
    issued at production time and pushed into an in-flight ring carried by
    the loop, while the mix consumes the gathered buffers of round
    ``t - s`` popped from the ring head.  Nothing on the FMA path depends
    on this round's collective, so the gather overlaps the decode+mix work
    (double buffering at ``s=1``).  Semantics, freeze-before-``s``, and the
    per-period contraction ``A^(T_S//(s+1))`` match
    ``gossip_scan_wire_bucketed(staleness=s)`` bitwise; ``staleness=0``
    compiles the literally unchanged synchronous body.  The plain
    (``codec=None``) path REFUSES staleness: without the delta-coded wire
    there is no innovation stream whose lateness telescopes away.
    """
    from jax.sharding import PartitionSpec as P

    if with_shipped and codec is None:
        raise ValueError("with_shipped is the wire codec's error-feedback "
                         "hook; it needs codec=")
    if staleness < 0:
        raise ValueError(f"staleness must be >= 0, got {staleness}")
    if staleness and codec is None:
        raise ValueError(
            "bounded staleness needs the delta-coded wire (codec=): the "
            "plain shard_map path gossips raw state, which has no "
            "innovation stream to consume late — build with a quantizer "
            "codec or use staleness=0")
    other_axes = [ax for ax in mesh.axis_names if ax != axis_name]
    n_other = int(np.prod([mesh.shape[ax] for ax in other_axes],
                          dtype=np.int64)) if other_axes else 1

    def body(a, kd, tree):
        m = a.shape[0]
        idx = jax.lax.axis_index(axis_name)
        row = a[idx].astype(jnp.float32)                 # (M,) my weights
        key = (jax.random.wrap_key_data(kd)
               if codec is not None and stochastic else None)
        sub = 0
        for ax in other_axes:
            sub = sub * mesh.shape[ax] + jax.lax.axis_index(ax)
        wire_server = idx * n_other + sub
        leaves, treedef = jax.tree.flatten(tree)
        dtype = leaves[0].dtype
        # Wire-format control: carry the gossip stream as u16 bit-patterns
        # of the bf16 payload.  Integer buffers are exempt from XLA-CPU's
        # float-normalization pass, which otherwise upcasts every
        # loop-carried bf16 buffer to f32 — a 2x params-sized artifact this
        # container's backend would report that a TPU (native bf16) never
        # allocates.  On TPU the bitcasts are free view changes.
        wire = jnp.uint16 if dtype == jnp.bfloat16 else None

        def to_wire(x):
            return jax.lax.bitcast_convert_type(x, wire) if wire else x

        def from_wire(x):
            return (jax.lax.bitcast_convert_type(x, jnp.bfloat16)
                    if wire else x)

        if codec is not None:
            # BUCKETED wire path: the device's whole local tree is ONE
            # zero-padded code buffer, so each round is exactly one s8
            # all-gather + one f32 all-gather no matter how many leaves
            # the pytree has — and the carry is 3 bucket-sized vectors
            # (iterate, own reference row, mixed-reference accumulator)
            # instead of the per-leaf form's (M, blk) reference matrix;
            # see ``gossip_scan_wire_bucketed`` for the telescoped
            # recursion and why acc_t == (A · R_t)_i exactly.
            flat = jnp.concatenate(
                [to_wire(leaf.astype(dtype)).reshape(-1)
                 for leaf in leaves])
            d_tot = flat.size
            blk, nb = _compressors.bucket_block(d_tot, block, codec.chunk)
            d_pad = nb * blk
            if d_pad != d_tot:
                flat = jnp.pad(flat, (0, d_pad - d_tot))

            def encode_round(t, delta):
                """Round-``t`` bucket encode under the shared dither
                convention — ONE definition used by both the loop body and
                the out-of-loop ``shipped`` pre-pass, so the pre-pass is
                elementwise-identical to what round 0 puts on the wire."""
                if key is not None:
                    dither = _compressors.wire_dither(
                        key, (d_pad,), leaf=0, rnd=t, server=wire_server,
                        block=0)
                else:
                    dither = 0.5
                return codec.encode_block(delta, dither)

            def round_fn_wire(t, carry):
                """One bucketed quantized-wire round, delta-coded: encode
                the innovation of my bucket against the receivers' shared
                decoded reference of me, gather CODES (not floats), fold
                every row's decoded delta into my own reference row and
                the mixed-reference accumulator.  The delta's absmax
                contracts with consensus, so per-hop quantization noise
                vanishes instead of flooring (see ``gossip_scan_wire``)."""
                w, ref, acc = carry            # (d_pad,) each
                delta = from_wire(w).astype(jnp.float32) - ref
                codes, scales = encode_round(t, delta)
                if gather_codes:
                    g_codes = jax.lax.all_gather(codes, axis_name)
                else:
                    # simulated twin: the same code VALUES cross the wire
                    # at full f32 width (the f32 -> int8 round-trip is
                    # exact on code integers), so the collective moves 4x
                    # the bytes but the decode still happens after the
                    # gather — keeping the multiply-add structure, and
                    # therefore the FMA contraction, identical to the
                    # physical program: the two are asserted BITWISE
                    # equal, proving the narrow wire changes encoding
                    # width only, never the numerics
                    g_codes = jax.lax.all_gather(
                        codes.astype(jnp.float32),
                        axis_name).astype(codes.dtype)
                g_scales = jax.lax.all_gather(scales, axis_name)
                # Fused dequantize-and-mix: fold the per-chunk scales and
                # the mixing-row weight into ONE broadcast factor per
                # chunk, so the round never materialises the (M, d_pad)
                # dequantized matrix or a per-element scale vector — on a
                # memory-bound host this halves the decode-side passes.
                # Term order stays one server at a time, left to right,
                # matching ``gossip_scan_wire_bucketed`` product for
                # product (the oracle folds identically).
                c3 = codec.code_chunks(g_codes, d_pad)   # (M, nc, chunk)
                ref = ref + (c3[idx] * g_scales[idx][:, None]
                             ).reshape(d_pad)
                ws = row[:, None] * g_scales             # (M, nc) folded
                acc3 = acc.reshape(-1, codec.chunk)
                for j in range(m):
                    acc3 = acc3 + ws[j][:, None] * c3[j]
                acc = acc3.reshape(d_pad)
                return to_wire(acc.astype(dtype)), ref, acc

            def round_fn_wire_stale(t, carry):
                """Software-pipelined bounded-staleness round: ISSUE round
                ``t``'s gather here (pushed onto the in-flight ring) while
                the mix consumes the ring head — round ``t - staleness``'s
                buffers.  No data path connects this round's collective to
                this round's FMA work, so the gather overlaps the
                decode+mix.  The sender's reference advances with its OWN
                codes, computed locally rather than sliced from the gather
                (same values — the gather round-trips code integers
                exactly — but keeps the reference update off the
                collective's critical path), so innovations stay
                single-shipped; the iterate freezes until the first
                delayed buffer lands (``t < staleness``)."""
                w, ref, acc, rc, rs = carry
                delta = from_wire(w).astype(jnp.float32) - ref
                codes, scales = encode_round(t, delta)
                if gather_codes:
                    g_codes = jax.lax.all_gather(codes, axis_name)
                else:
                    g_codes = jax.lax.all_gather(
                        codes.astype(jnp.float32),
                        axis_name).astype(codes.dtype)
                g_scales = jax.lax.all_gather(scales, axis_name)
                own3 = codec.code_chunks(codes, d_pad)   # (nc, chunk)
                ref = ref + (own3 * scales[:, None]).reshape(d_pad)
                old_c, old_s = rc[0], rs[0]
                c3 = codec.code_chunks(old_c, d_pad)     # (M, nc, chunk)
                ws = row[:, None] * old_s                # (M, nc) folded
                acc3 = acc.reshape(-1, codec.chunk)
                for j in range(m):
                    acc3 = acc3 + ws[j][:, None] * c3[j]
                acc = acc3.reshape(d_pad)
                rc = jnp.concatenate([rc[1:], g_codes[None]], axis=0)
                rs = jnp.concatenate([rs[1:], g_scales[None]], axis=0)
                w = jnp.where(t >= staleness,
                              to_wire(acc.astype(dtype)), w)
                return w, ref, acc, rc, rs

            zeros = jnp.zeros((d_pad,), jnp.float32)
            if with_shipped:
                # what this device shipped of its own model (the EF hook)
                # is its round-0 decoded transmission: ref_1 = dec_0[own].
                # Recompute it in a pre-pass OUTSIDE the loop — the same
                # ``encode_round(0, flat - 0)`` expression the loop body
                # evaluates, decoded locally (own row only, no gather) —
                # instead of carrying a 4th bucket vector + a per-round
                # select through the fori_loop: the loop body stays THE
                # SAME program as the plain runner (bitwise-identical
                # mixed output, single gather pair in the compiled HLO)
                # and the pre-pass costs one encode instead of t_server
                # bucket-sized selects.
                codes0, scales0 = encode_round(
                    0, from_wire(flat).astype(jnp.float32) - zeros)
                shipped = codec.decode_block(codes0, scales0, d_pad)
            else:
                shipped = zeros
            if staleness == 0:
                w, _, _ = jax.lax.fori_loop(
                    0, t_server, round_fn_wire, (flat, zeros, zeros))
            else:
                # in-flight ring pre-filled with zero codes + unit scales
                # (decode to nothing), so consumption is unconditional and
                # inert before round ``staleness``
                code_abs = jax.eval_shape(
                    lambda x: codec.encode_block(x, 0.5)[0],
                    jax.ShapeDtypeStruct((d_pad,), jnp.float32))
                ring_c = jnp.zeros((staleness, m) + code_abs.shape,
                                   code_abs.dtype)
                ring_s = jnp.ones(
                    (staleness, m, d_pad // codec.chunk), jnp.float32)
                w, _, _, _, _ = jax.lax.fori_loop(
                    0, t_server, round_fn_wire_stale,
                    (flat, zeros, zeros, ring_c, ring_s))
            out = from_wire(w)
            new_leaves, shipped_leaves, off = [], [], 0
            for leaf in leaves:
                size = leaf.size
                new_leaves.append(out[off:off + size].astype(leaf.dtype)
                                  .reshape(leaf.shape))
                shipped_leaves.append(
                    shipped[off:off + size].astype(leaf.dtype)
                    .reshape(leaf.shape))
                off += size
            mixed = jax.tree.unflatten(treedef, new_leaves)
            if not with_shipped:
                return mixed
            return mixed, jax.tree.unflatten(treedef, shipped_leaves)

        def round_fn(_i, w):
            g = from_wire(jax.lax.all_gather(w, axis_name))      # (M, blk)
            # unrolled mul-adds (M is tiny); f32 accumulate per block
            acc = row[0] * g[0].astype(jnp.float32)
            for j in range(1, m):
                acc = acc + row[j] * g[j].astype(jnp.float32)
            return to_wire(acc.astype(dtype))

        def gossip_leaf(flat):
            """Blocked in-place gossip over one flattened (wire) leaf.

            The ragged tail block is zero-padded; zeros survive the wire
            format exactly (they mix to zero), so the pad is sliced back
            off unchanged."""
            d = flat.size
            blk = min(block, d)
            nb = -(-d // blk)
            if nb * blk != d:
                flat = jnp.pad(flat, (0, nb * blk - d))
            if nb == 1:
                return jax.lax.fori_loop(0, t_server, round_fn, flat)[:d]

            def per_block(i, buf):
                w = jax.lax.dynamic_slice(buf, (i * blk,), (blk,))
                w = jax.lax.fori_loop(0, t_server, round_fn, w)
                return jax.lax.dynamic_update_slice(buf, w, (i * blk,))

            return jax.lax.fori_loop(0, nb, per_block, flat)[:d]

        # Per-leaf loops CHAINED via optimization_barrier: leaves gossip
        # independently, so XLA would otherwise schedule their while-loops
        # concurrently and hold every leaf's wire buffers at once; the
        # token dependency forces one leaf in flight at a time.
        new_leaves = []
        token = None
        for leaf in leaves:
            wl = to_wire(leaf.astype(dtype)).reshape(-1)
            if token is not None:
                wl, token = jax.lax.optimization_barrier((wl, token))
            out = gossip_leaf(wl)
            token = out[0]
            new_leaves.append(
                from_wire(out).astype(leaf.dtype).reshape(leaf.shape))
        return jax.tree.unflatten(treedef, new_leaves)

    out_specs = ((leaf_specs, leaf_specs)
                 if codec is not None and with_shipped else leaf_specs)
    sm = shard_map_compat(body, mesh, (P(None, None), P(None), leaf_specs),
                          out_specs, check=False)
    if codec is None:
        return lambda a, tree: sm(a, jnp.zeros((2,), jnp.uint32), tree)

    def run(a, tree, key=None):
        if stochastic:
            if key is None:
                raise ValueError(
                    "this wire program was built stochastic=True and needs "
                    "the rounding key; build with stochastic=False for "
                    "deterministic round-to-nearest")
            kd = jax.random.key_data(key)
        else:
            kd = jnp.zeros((2,), jnp.uint32)
        if t_server == 0:       # nothing crosses the wire (or is shipped)
            return ((tree, jax.tree.map(jnp.zeros_like, tree))
                    if with_shipped else tree)
        return sm(a, kd, tree)

    return run


# ---------------------------------------------------------------------------
# shard_map ring gossip: explicit neighbour exchange over ICI
# ---------------------------------------------------------------------------


def ring_gossip_step(w: jax.Array, *, axis_name: str, self_weight: float,
                     neighbor_weight: float) -> jax.Array:
    """One gossip round on a ring graph executed INSIDE shard_map: each server
    shard receives its two ring neighbours via collective_permute — the
    literal 'server communicates with neighbours' of Alg. 1, mapped onto the
    physical ICI ring."""
    m = jax.lax.psum(1, axis_name)
    fwd = [(i, (i + 1) % m) for i in range(m)]
    bwd = [((i + 1) % m, i) for i in range(m)]
    left = jax.lax.ppermute(w, axis_name, perm=fwd)
    right = jax.lax.ppermute(w, axis_name, perm=bwd)
    return (self_weight * w + neighbor_weight * (left + right)).astype(w.dtype)


def make_ring_gossip(mesh: jax.sharding.Mesh, axis_name: str, t_server: int,
                     self_weight: float, neighbor_weight: float, *,
                     codec=None, stochastic: bool = True,
                     gather_codes: bool = True) -> Callable:
    """Build a shard_map'd T_S-round ring gossip over ``axis_name``.

    The input pytree must have its leading (server) axis sharded over
    ``axis_name``; other axes pass through unchanged.

    **Quantized wire mode** (``codec=`` a quantizer): the returned
    ``run(tree, key)`` ppermutes int8 / packed-int4 CODES + per-chunk
    scales instead of the float payload — each round encodes the local
    shard's DELTA against the receivers' decoded reference once
    (innovation coding, see ``gossip_scan_wire``) and ships the same code
    buffer to both ring neighbours; every consumer (neighbours AND the
    own-carry self term) accumulates the decoded delta into its reference
    of the sender and mixes references — the same one-numerics-definition
    as ``make_gossip_shard_map``'s wire mode.  Dither follows
    ``comm.compressors.wire_dither`` with the local flattened shard as one
    block (block index 0); ``gather_codes=False`` builds the simulated
    twin (the same code values ppermuted at f32 width — bitwise identical)
    for the parity tests."""
    from jax.sharding import PartitionSpec as P

    def per_shard(kd, tree):
        def body(_, w):
            return jax.tree.map(
                lambda x: ring_gossip_step(
                    x, axis_name=axis_name, self_weight=self_weight,
                    neighbor_weight=neighbor_weight),
                w)
        return jax.lax.fori_loop(0, t_server, body, tree)

    def per_shard_wire(kd, tree):
        m = jax.lax.psum(1, axis_name)
        idx = jax.lax.axis_index(axis_name)
        key = jax.random.wrap_key_data(kd) if stochastic else None
        fwd = [(i, (i + 1) % m) for i in range(m)]
        bwd = [((i + 1) % m, i) for i in range(m)]
        leaves, treedef = jax.tree.flatten(tree)
        shapes = [l.shape for l in leaves]

        def step(t, carry):
            flats, refs = carry
            new_flats, new_refs = [], []
            for li, (flat, ref3) in enumerate(zip(flats, refs)):
                # delta-coded wire (see gossip_scan_wire): each node keeps
                # a decoded reference of itself and of both ring
                # neighbours; only the innovation w - ref_self is encoded,
                # so per-hop quantization noise contracts with consensus
                r_self, r_left, r_right = ref3
                length = flat.size
                delta = flat.astype(jnp.float32) - r_self
                if key is not None:
                    dither = _compressors.wire_dither(
                        key, (length,), leaf=li, rnd=t, server=idx, block=0)
                else:
                    dither = 0.5
                codes, scales = codec.encode_block(delta, dither)
                if gather_codes:
                    wire_codes = codes
                    unwire = lambda c: c          # noqa: E731
                else:
                    # simulated twin: the same code values at f32 width —
                    # decode still happens after the ppermute, keeping the
                    # FMA-contraction structure identical to the physical
                    # program (see make_gossip_shard_map), hence bitwise
                    wire_codes = codes.astype(jnp.float32)
                    unwire = lambda c: c.astype(codes.dtype)  # noqa: E731
                d_left = codec.decode_block(
                    unwire(jax.lax.ppermute(wire_codes, axis_name,
                                            perm=fwd)),
                    jax.lax.ppermute(scales, axis_name, perm=fwd), length)
                d_right = codec.decode_block(
                    unwire(jax.lax.ppermute(wire_codes, axis_name,
                                            perm=bwd)),
                    jax.lax.ppermute(scales, axis_name, perm=bwd), length)
                r_self = r_self + codec.decode_block(codes, scales, length)
                r_left = r_left + d_left
                r_right = r_right + d_right
                # Contraction-stable mixing: accumulate one weighted term
                # per add, exactly like the all-gather round body.  Every
                # add then exposes the SAME candidate multiply in both wire
                # programs, so LLVM's FMA contraction makes the same choice
                # and gather_codes=True / False stay BITWISE identical —
                # ``nw * (left + right)`` instead adds two raw dequant
                # sums in physical mode (contractible) but materialized
                # floats in simulated mode (not), and the two programs
                # drift by one rounding.
                acc = self_weight * r_self
                acc = acc + neighbor_weight * r_left
                acc = acc + neighbor_weight * r_right
                new_flats.append(acc.astype(flat.dtype))
                new_refs.append((r_self, r_left, r_right))
            return new_flats, new_refs

        flats = [l.reshape(-1) for l in leaves]
        zeros = [tuple(jnp.zeros_like(f, jnp.float32) for _ in range(3))
                 for f in flats]
        flats, _ = jax.lax.fori_loop(0, t_server, step, (flats, zeros))
        return jax.tree.unflatten(
            treedef, [f.reshape(s) for f, s in zip(flats, shapes)])

    def spec_for(tree):
        return jax.tree.map(lambda x: P(axis_name, *([None] * (x.ndim - 1))), tree)

    def run(tree, key=None):
        specs = spec_for(tree)
        body = per_shard if codec is None else per_shard_wire
        if codec is not None and stochastic:
            if key is None:
                raise ValueError(
                    "this wire program was built stochastic=True and needs "
                    "the rounding key")
            kd = jax.random.key_data(key)
        else:
            kd = jnp.zeros((2,), jnp.uint32)
        return shard_map_compat(body, mesh, (P(None), specs),
                                specs)(kd, tree)

    return run


# ---------------------------------------------------------------------------
# consensus backends: one interface over every execution strategy
# ---------------------------------------------------------------------------


class ConsensusBackend:
    """One consensus period (Eq. 5/7) behind one interface.

    ``mix(tree, a_p)`` runs T_S rounds of ``W <- A W`` on a server-leading
    pytree; ``mix_push_sum(state, a_p)`` runs the ratio-consensus variant
    (numerator and weight both mixed by the column-stochastic ``A'``, see
    ``gossip_push_sum``).  ``a_p`` is an optional *traced* per-epoch
    ``(M, M)`` mixing matrix — the dynamic engine passes a fresh one every
    epoch through the SAME compiled program; ``None`` selects the static
    matrix the backend was built with.

    Class flags gate what a backend can express:

    * ``supports_traced`` — can consume a traced ``A_p``.
    * ``supports_directed`` — applies the literal ``W <- A W`` update, so
      row-stochastic A and the push-sum correction are well-defined.
    * ``mesh_bound`` — closed over a fixed physical mesh (shard_map): the
      server axis cannot survive fault surgery that changes M.
    * ``needs_spectral`` — wants a per-epoch spectral estimate ``lam2``
      alongside a traced ``A_p`` (Chebyshev); the dynamic engine feeds it
      through ``schedule.EpochSchedule.lam2``.
    * ``compressed`` — a ``CompressedBackend`` wrapper (lossy wire
      simulation + error feedback around an inner backend).
    * ``robust`` — a Byzantine-screening backend (trimmed mean / median /
      clipped): must see every neighbor's plaintext values, so it cannot
      ride the quantized physical wire, and its update is not the literal
      ``W <- A W``, so no push-sum analogue exists.

    ``staleness`` (instance attribute, default 0) is the bounded-staleness
    depth ``s``: round ``t`` mixes with round ``t - s``'s messages
    (``gossip_scan_stale`` / the software-pipelined wire bodies), composing
    to ``A^(T_S // (s+1))`` per period in exact arithmetic — the
    staleness-augmented contraction ``schedule.SigmaTracker`` monitors.
    Only the literal T_S-round schedules carry it (gossip, gossip_blocked,
    the shard_map codec wire); every other backend refuses at build, and
    push-sum refuses at call time (the exact ``(M,)`` weight recursion has
    no delayed twin, so a stale numerator over a fresh weight would be
    inconsistent).
    """

    name = "?"
    supports_traced = True
    supports_directed = True
    mesh_bound = False
    needs_spectral = False
    compressed = False
    robust = False
    staleness = 0

    def __init__(self, a_static: Optional[np.ndarray], t_server: int):
        self.a_static = (None if a_static is None
                         else jnp.asarray(a_static, jnp.float32))
        self.t_server = t_server

    def _resolve(self, a_p: Optional[jax.Array]) -> jax.Array:
        if a_p is not None:
            return a_p
        if self.a_static is None:
            raise ValueError(f"{self.name!r} backend was built without a "
                             f"static mixing matrix; pass a per-epoch A_p")
        return self.a_static

    def mix(self, tree: Any, a_p: Optional[jax.Array] = None,
            lam2=None) -> Any:
        """T_S rounds of ``W <- A W`` over the leading server axis.
        ``lam2`` is the optional per-epoch spectral hint, consumed only by
        ``needs_spectral`` backends and ignored everywhere else."""
        del lam2
        return self._mix(tree, self._resolve(a_p))

    def mix_stats(self, tree: Any, a_p: Optional[jax.Array] = None,
                  lam2=None) -> Tuple[Any, jax.Array]:
        """``mix`` plus the period's per-source screen-activity counts —
        ``(mixed, rejected)`` with ``rejected[j]`` how many values server
        j had discarded/clipped by its receivers' screens.  Non-``robust``
        backends screen nothing: the counts are identically zero and the
        value path is EXACTLY ``mix`` (the robust backends override this
        with their shared-body stats variants)."""
        m = self._resolve(a_p).shape[0]
        return (self.mix(tree, a_p, lam2=lam2),
                jnp.zeros((m,), jnp.float32))

    def mix_push_sum(self, state: PushSumState,
                     a_p: Optional[jax.Array] = None) -> PushSumState:
        """Ratio consensus: numerator streamed through the SAME execution
        strategy with ``P = A'``, weight by the trivial ``(M,)`` matvec."""
        if not self.supports_directed:
            raise ValueError(
                f"consensus backend {self.name!r} has no ratio-consensus "
                f"analogue: its value update is not the literal W <- A W, "
                f"so a numerator/weight pair mixed by it would be "
                f"inconsistent")
        if self.staleness:
            raise ValueError(
                f"consensus backend {self.name!r} has staleness="
                f"{self.staleness}, but ratio consensus mixes a "
                f"numerator/weight PAIR and the exact (M,) weight "
                f"recursion has no delayed twin — a stale numerator over "
                f"a fresh weight breaks the mass-conservation invariant; "
                f"use staleness=0 with push-sum")
        p = jnp.swapaxes(self._resolve(a_p), 0, 1)
        return PushSumState(self._mix(state.values, p),
                            self._mix_weight(state.weight, p))

    def _mix_weight(self, weight: jax.Array, p: jax.Array) -> jax.Array:
        return jax.lax.fori_loop(
            0, self.t_server,
            lambda _, w: (p @ w.astype(p.dtype)).astype(w.dtype), weight)

    def _mix(self, tree: Any, a: jax.Array) -> Any:
        raise NotImplementedError


class GossipBackend(ConsensusBackend):
    """The reference per-leaf einsum schedule (``gossip_scan``; with
    ``staleness=s > 0``, ``gossip_scan_stale`` — whose ``s=0`` branch IS
    ``gossip_scan``, so the default construction is bitwise unchanged)."""

    name = "gossip"

    def __init__(self, a_static, t_server, *, staleness: int = 0):
        super().__init__(a_static, t_server)
        self.staleness = staleness

    def _mix(self, tree, a):
        return gossip_scan_stale(a, tree, self.t_server, self.staleness)


class BlockedGossipBackend(ConsensusBackend):
    """``gossip_scan_blocked``: fixed-block streaming — the pjit production
    path whose live working set is one (M, block) gather, not a full leaf.

    Under ``staleness=s > 0`` the plain (uncompressed) mix delegates to
    ``gossip_scan_stale``: the delayed-iterate history would multiply the
    blocked path's live set by ``s+1`` for no wire benefit — only the
    delta-coded wire (``gossip_scan_wire_bucketed``) pipelines; the
    physical-wire wrap (``CompressedBackend``) keeps the bucketed stale
    body either way."""

    name = "gossip_blocked"

    def __init__(self, a_static, t_server, *, block: int = 4_194_304,
                 flat_sharding=None, staleness: int = 0):
        super().__init__(a_static, t_server)
        self.block = block
        self.flat_sharding = flat_sharding
        self.staleness = staleness

    def _mix(self, tree, a):
        if self.staleness:
            return gossip_scan_stale(a, tree, self.t_server, self.staleness)
        return gossip_scan_blocked(a, tree, self.t_server, block=self.block,
                                   flat_sharding=self.flat_sharding)


class CollapsedBackend(ConsensusBackend):
    """One round with ``A_eff = A^{T_S}`` — host-side float64 collapse for
    the static matrix, in-program (M x M, trivial) collapse for a traced
    per-epoch ``A_p``."""

    name = "collapsed"

    def __init__(self, a_static, t_server):
        super().__init__(a_static, t_server)
        self._eff_static = (None if a_static is None else jnp.asarray(
            collapse_mixing(np.asarray(a_static), t_server), jnp.float32))

    def _eff(self, a_p: Optional[jax.Array]) -> jax.Array:
        if a_p is None:
            if self._eff_static is None:
                raise ValueError("'collapsed' backend was built without a "
                                 "static mixing matrix; pass a per-epoch A_p")
            return self._eff_static
        return jax.lax.fori_loop(
            0, self.t_server, lambda _, p: a_p @ p,
            jnp.eye(a_p.shape[0], dtype=a_p.dtype))

    def mix(self, tree, a_p=None, lam2=None):
        del lam2
        return gossip_collapsed(self._eff(a_p), tree)

    def mix_push_sum(self, state, a_p=None):
        # (A^{T_S})' == (A')^{T_S}: one collapsed round of the transpose
        effp = jnp.swapaxes(self._eff(a_p), 0, 1)
        weight = (effp @ state.weight.astype(effp.dtype)).astype(
            state.weight.dtype)
        return PushSumState(mix_pytree(effp, state.values), weight)


class ChebyshevBackend(ConsensusBackend):
    """Chebyshev semi-iterative gossip.

    Spectral data rides OUTSIDE the matrix: for the static topology,
    ``lambda_2(A)`` is computed on the host at construction; for a traced
    per-epoch ``A_p`` (dynamic federation) the matching per-epoch estimate
    arrives as the traced ``lam2`` operand — the engine computes it
    host-side per epoch (``topology.lambda_2`` via
    ``schedule.EpochSchedule.lam2``) since the ratio-parametrised recursion
    in ``gossip_chebyshev`` handles traced coefficients.  A traced ``A_p``
    with no estimate falls back to the in-graph ``lambda2_traced``.  The
    affine recursion has negative coefficients, so no ratio-consensus
    (push-sum) analogue exists."""

    name = "chebyshev"
    supports_directed = False
    needs_spectral = True

    def __init__(self, a_static, t_server, *, rounds: Optional[int] = None):
        super().__init__(a_static, t_server)
        self.lam2 = (None if a_static is None
                     else tp_lambda_2(np.asarray(a_static)))
        self.rounds = rounds or max(1, int(np.ceil(np.sqrt(max(t_server,
                                                               1)))))

    def mix(self, tree, a_p=None, lam2=None):
        a = self._resolve(a_p)
        if lam2 is None:
            lam2 = self.lam2 if a_p is None else lambda2_traced(a_p)
        if lam2 is None:
            raise ValueError("'chebyshev' was built without a static mixing "
                             "matrix; pass (a_p, lam2) per call")
        return gossip_chebyshev(a, tree, self.rounds, lam2)


class ExactMeanBackend(ConsensusBackend):
    """The idealised sigma_A = 0 limit (hierarchical FL with a root
    aggregator): ignores the mixing matrix entirely, so the directed /
    push-sum interpretations are undefined for it."""

    name = "exact_mean"
    supports_directed = False

    def _mix(self, tree, a):
        return jax.tree.map(
            lambda x: jnp.broadcast_to(x.mean(axis=0, keepdims=True),
                                       x.shape), tree)


# ---------------------------------------------------------------------------
# robust (Byzantine-screening) gossip: trimmed mean / median / clipped
# ---------------------------------------------------------------------------


def _support(a: jax.Array) -> jax.Array:
    """Boolean (M, M) gossip support of a mixing matrix: every positive
    entry plus the diagonal — a server always counts its OWN value among
    the screened candidates, even on graphs whose self-weight is 0."""
    return (a > 0) | jnp.eye(a.shape[0], dtype=bool)


def _rank_keep_mean_stats(a: jax.Array, leaf: jax.Array,
                          keep_rule) -> Tuple[jax.Array, jax.Array]:
    """Coordinatewise rank-screened neighbor mean — the shared core of the
    trimmed-mean and median rounds — plus its screen-activity readout.

    For each receiver ``i`` and each coordinate, the supported values
    (``leaf[j]`` for every ``j`` in i's support, self included) are ranked
    by a stable double-argsort (ties broken by source index, so the keep
    set is deterministic), ``keep_rule(rank, cnt)`` selects which ranks
    survive, and the output is the UNWEIGHTED mean of the survivors summed
    in ORIGINAL source order — which is why ``keep_rule = (0 <= r < cnt)``
    (the f=0 trim) is bitwise the plain masked neighbor mean.
    Non-neighbors are masked to +inf, so they occupy the ranks at and above
    ``cnt`` and no admissible rule can keep them.  A receiver whose whole
    neighborhood is screened away (past the breakdown point on a traced
    graph, unverifiable at build time) holds its own value.

    Returns ``(out, rejected)`` where ``rejected`` is the per-SOURCE
    screen-activity count: ``rejected[j]`` = how many (receiver,
    coordinate) pairs discarded server j's supported value this round.
    The rank screens discard a FIXED number of values per neighborhood
    (the informative signal is WHOSE values land in the discarded ranks —
    an attacker's coordinates are rejected far above the honest base
    rate).  Callers that only need ``out`` take element 0 and XLA
    dead-code-eliminates the counting — the plain path stays bitwise and
    cost-identical."""
    m = a.shape[0]
    sup = _support(a)
    cnt = sup.sum(axis=1)                                    # (M,) int
    supb = sup.reshape((m, m) + (1,) * (leaf.ndim - 1))
    vals = jnp.broadcast_to(leaf[None], (m,) + leaf.shape)   # (M, M, *w)
    big = jnp.where(supb, vals, jnp.asarray(jnp.inf, leaf.dtype))
    order = jnp.argsort(big, axis=1)
    rank = jnp.argsort(order, axis=1)
    cntb = cnt.reshape((m,) + (1,) * leaf.ndim)
    keep = keep_rule(rank, cntb) & supb
    kept = jnp.where(keep, vals, jnp.zeros((), leaf.dtype))
    kcnt = keep.sum(axis=1)
    out = kept.sum(axis=1) / jnp.maximum(kcnt, 1).astype(leaf.dtype)
    rejected = (supb & ~keep).sum(
        axis=tuple(i for i in range(keep.ndim) if i != 1),
        dtype=jnp.float32)                                   # (M,) per source
    return jnp.where(kcnt > 0, out, leaf), rejected


def _rank_keep_mean(a: jax.Array, leaf: jax.Array, keep_rule) -> jax.Array:
    """``_rank_keep_mean_stats`` without the screen-activity readout."""
    return _rank_keep_mean_stats(a, leaf, keep_rule)[0]


def trimmed_mean_mix(a: jax.Array, tree: Any, f: int) -> Any:
    """One coordinatewise-trimmed-mean screening round: per receiver and
    coordinate, discard the ``f`` largest and ``f`` smallest supported
    values and average the rest (unweighted).  Tolerates up to ``f``
    arbitrary values per neighborhood as long as ``2f < c``; with ``f=0``
    it IS the plain masked neighbor mean, bitwise."""
    if f < 0:
        raise ValueError(f"trimmed mean needs f >= 0, got {f}")
    return jax.tree.map(
        lambda leaf: _rank_keep_mean(
            a, leaf, lambda r, c: (r >= f) & (r < c - f)), tree)


def median_mix(a: jax.Array, tree: Any) -> Any:
    """One coordinatewise-median screening round: per receiver and
    coordinate, the median of the supported values (mean of the two middle
    ranks when the neighborhood is even) — trimmed mean pushed to its
    breakdown point ``f < c/2`` without choosing f."""
    return jax.tree.map(
        lambda leaf: _rank_keep_mean(
            a, leaf, lambda r, c: (r >= (c - 1) // 2) & (r <= c // 2)),
        tree)


def clip_weights(a: jax.Array, tree: Any,
                 clip_mult: float = 1.0) -> jax.Array:
    """Self-centered clipping as an EFFECTIVE per-round mixing matrix.

    Each receiver ``i`` clips every neighbor's innovation against its own
    model: the off-diagonal weight becomes ``a[i,j] * min(1, tau_i /
    ||x_j - x_i||)`` and the clipped-away mass returns to the self-loop,
    so a round is the ordinary einsum ``mix_pytree(C, tree)`` and composes
    with everything that consumes a mixing matrix.  The threshold ``tau_i``
    is ``clip_mult x`` the MEDIAN tree-wide distance from ``i`` to its
    supported neighbors — self-annealing: as the honest servers contract,
    tau shrinks with them and the clip bites harder on anything still far
    away (the attacker), while at ``tau -> inf`` the round degenerates to
    the exact weighted gossip.  Distances are tree-wide l2 norms via the
    Gram identity (one (M, M) accumulation, no (M, M, *w) tensor)."""
    return clip_weights_stats(a, tree, clip_mult)[0]


def clip_weights_stats(a: jax.Array, tree: Any, clip_mult: float = 1.0
                       ) -> Tuple[jax.Array, jax.Array]:
    """``clip_weights`` plus its screen-activity readout: ``clipped[j]`` =
    how many receivers clipped sender j's innovation this round (links
    where the clip factor actually bit, ``fac < 1``).  One shared body, so
    the effective matrix is bitwise identical whether or not the count is
    consumed (XLA dead-code-eliminates it on the plain path)."""
    m = a.shape[0]
    off = _support(a) & ~jnp.eye(m, dtype=bool)
    d2 = jnp.zeros((m, m), jnp.float32)
    for leaf in jax.tree.leaves(tree):
        x = leaf.reshape(m, -1).astype(jnp.float32)
        g = x @ x.T
        sq = jnp.diagonal(g)
        d2 = d2 + (sq[:, None] + sq[None, :] - 2.0 * g)
    dist = jnp.sqrt(jnp.maximum(d2, 0.0))
    masked = jnp.where(off, dist, jnp.inf)
    srt = jnp.sort(masked, axis=1)
    k = off.sum(axis=1)
    med = jnp.take_along_axis(
        srt, jnp.maximum((k - 1) // 2, 0)[:, None], axis=1)[:, 0]
    tau = clip_mult * med                    # inf for an isolated receiver
    fac = jnp.where(dist > 0.0,
                    jnp.minimum(1.0, tau[:, None] / jnp.maximum(dist, 1e-30)),
                    1.0)
    c_off = jnp.where(off, a.astype(jnp.float32) * fac, 0.0)
    clipped = (off & (fac < 1.0)).sum(axis=0, dtype=jnp.float32)  # per source
    return c_off + jnp.diag(1.0 - c_off.sum(axis=1)), clipped


def clipped_mix(a: jax.Array, tree: Any, clip_mult: float = 1.0) -> Any:
    """One clipped-gossip round: build the state-dependent effective matrix
    and apply the ordinary weighted round with it."""
    return mix_pytree(clip_weights(a, tree, clip_mult), tree)


def gossip_scan_trimmed(a: jax.Array, tree: Any, t_server: int,
                        f: int) -> Any:
    """T_S rounds of trimmed-mean screening (per-leaf fori_loop, mirroring
    ``gossip_scan``'s schedule — leaves screen independently)."""
    if f < 0:
        raise ValueError(f"trimmed mean needs f >= 0, got {f}")
    if t_server == 0:
        return tree

    def leaf_loop(leaf):
        return jax.lax.fori_loop(
            0, t_server,
            lambda _, w: _rank_keep_mean(
                a, w, lambda r, c: (r >= f) & (r < c - f)), leaf)

    return jax.tree.map(leaf_loop, tree)


def gossip_scan_median(a: jax.Array, tree: Any, t_server: int) -> Any:
    """T_S rounds of coordinatewise-median screening."""
    if t_server == 0:
        return tree

    def leaf_loop(leaf):
        return jax.lax.fori_loop(
            0, t_server,
            lambda _, w: _rank_keep_mean(
                a, w, lambda r, c: (r >= (c - 1) // 2) & (r <= c // 2)),
            leaf)

    return jax.tree.map(leaf_loop, tree)


def gossip_scan_clipped(a: jax.Array, tree: Any, t_server: int,
                        clip_mult: float = 1.0) -> Any:
    """T_S rounds of clipped gossip.  The effective matrix depends on the
    WHOLE tree's current state (tree-wide distances), so rounds cannot run
    per leaf: a plain unrolled loop over the (static) round count."""
    for _ in range(t_server):
        tree = clipped_mix(a, tree, clip_mult)
    return tree


# -- screen-activity variants: same rounds, plus the per-source counts -----


def _rank_scan_stats(a: jax.Array, tree: Any, t_server: int,
                     keep_rule) -> Tuple[Any, jax.Array]:
    """T_S rank-screened rounds returning ``(tree, rejected)`` with
    ``rejected[j]`` the total (receiver, coordinate, round, leaf) count of
    server j's screened-away values this period.  The value path is the
    exact ``_rank_keep_mean`` round sequence — only the f32 count rides
    alongside the ``fori_loop`` carry."""
    m = a.shape[0]
    if t_server == 0:
        return tree, jnp.zeros((m,), jnp.float32)

    def leaf_loop(leaf):
        def body(_, carry):
            w, rej = carry
            out, r = _rank_keep_mean_stats(a, w, keep_rule)
            return out, rej + r
        return jax.lax.fori_loop(0, t_server, body,
                                 (leaf, jnp.zeros((m,), jnp.float32)))

    leaves, treedef = jax.tree.flatten(tree)
    results = [leaf_loop(l) for l in leaves]
    out = treedef.unflatten([r[0] for r in results])
    rejected = sum(r[1] for r in results)
    return out, rejected


def gossip_scan_trimmed_stats(a: jax.Array, tree: Any, t_server: int,
                              f: int) -> Tuple[Any, jax.Array]:
    """``gossip_scan_trimmed`` + per-source screen-activity counts."""
    if f < 0:
        raise ValueError(f"trimmed mean needs f >= 0, got {f}")
    return _rank_scan_stats(
        a, tree, t_server, lambda r, c: (r >= f) & (r < c - f))


def gossip_scan_median_stats(a: jax.Array, tree: Any,
                             t_server: int) -> Tuple[Any, jax.Array]:
    """``gossip_scan_median`` + per-source screen-activity counts."""
    return _rank_scan_stats(
        a, tree, t_server,
        lambda r, c: (r >= (c - 1) // 2) & (r <= c // 2))


def gossip_scan_clipped_stats(a: jax.Array, tree: Any, t_server: int,
                              clip_mult: float = 1.0
                              ) -> Tuple[Any, jax.Array]:
    """``gossip_scan_clipped`` + per-source counts of links whose clip
    factor bit (``fac < 1``), summed over rounds and receivers."""
    clipped = jnp.zeros((a.shape[0],), jnp.float32)
    for _ in range(t_server):
        c, hit = clip_weights_stats(a, tree, clip_mult)
        tree = mix_pytree(c, tree)
        clipped = clipped + hit
    return tree, clipped


class TrimmedMeanBackend(ConsensusBackend):
    """Coordinatewise trimmed-mean gossip (``gossip_scan_trimmed``).

    Screens up to ``f`` arbitrary (Byzantine) values per neighborhood per
    coordinate; construction fails fast when the STATIC graph is already
    past the breakdown point (some supported neighborhood, self included,
    has ``c <= 2f`` values — the screen would discard everything).  A
    traced per-epoch ``A_p`` cannot be checked at build time; a fully
    screened receiver then holds its own value (see ``_rank_keep_mean``).

    ``f == 0`` requests no screening at all, so the backend degenerates to
    the EXACT weighted schedule (``gossip_scan``) — bitwise identical to
    the unprotected ``'gossip'`` backend, the identity the adversarial
    suite (``tests/test_robust.py``) pins."""

    name = "trimmed_mean"
    supports_directed = False
    robust = True

    def __init__(self, a_static, t_server, *, f: int = 1):
        super().__init__(a_static, t_server)
        if f < 0:
            raise ValueError(f"trimmed mean needs f >= 0, got {f}")
        self.f = f
        if a_static is not None and f > 0:
            a = np.asarray(a_static)
            cnt = int(((a > 0) | np.eye(a.shape[0], dtype=bool))
                      .sum(axis=1).min())
            if cnt <= 2 * f:
                raise ValueError(
                    f"trimmed_mean with f={f} is past its breakdown point "
                    f"on this graph: a server has only {cnt} supported "
                    f"values (self included) but the screen discards "
                    f"2f={2 * f} per coordinate and needs > 2f survivors' "
                    f"worth of margin; lower f or densify the graph")

    def _mix(self, tree, a):
        if self.f == 0:
            return gossip_scan(a, tree, self.t_server)
        return gossip_scan_trimmed(a, tree, self.t_server, self.f)

    def mix_stats(self, tree, a_p=None, lam2=None):
        del lam2
        a = self._resolve(a_p)
        if self.f == 0:
            # no screening requested: the exact weighted schedule, with
            # identically-zero counts (the f=0 bitwise identity holds)
            return (gossip_scan(a, tree, self.t_server),
                    jnp.zeros((a.shape[0],), jnp.float32))
        return gossip_scan_trimmed_stats(a, tree, self.t_server, self.f)


class MedianBackend(ConsensusBackend):
    """Coordinatewise-median gossip (``gossip_scan_median``): the maximal
    screen — tolerates any minority of attackers per neighborhood
    (breakdown point f < c/2) at the cost of discarding the most
    information per round."""

    name = "median"
    supports_directed = False
    robust = True

    def _mix(self, tree, a):
        return gossip_scan_median(a, tree, self.t_server)

    def mix_stats(self, tree, a_p=None, lam2=None):
        del lam2
        return gossip_scan_median_stats(self._resolve(a_p), tree,
                                        self.t_server)


class ClippedGossipBackend(ConsensusBackend):
    """Clipped gossip (``gossip_scan_clipped``): neighbor innovations
    norm-clipped against the receiver's own model via the effective matrix
    ``clip_weights``, so each round remains the weighted einsum and the
    honest-and-agreed fixed point is EXACTLY preserved (an all-equal tree
    has zero innovations and C == A).  Unlike the rank screens it keeps
    the Eq.-6 weights for everything inside the clip radius."""

    name = "clipped"
    supports_directed = False
    robust = True

    def __init__(self, a_static, t_server, *, clip_mult: float = 1.0):
        super().__init__(a_static, t_server)
        if not clip_mult > 0.0:
            raise ValueError(f"clipped needs clip_mult > 0, got {clip_mult}")
        self.clip_mult = clip_mult

    def _mix(self, tree, a):
        return gossip_scan_clipped(a, tree, self.t_server,
                                   clip_mult=self.clip_mult)

    def mix_stats(self, tree, a_p=None, lam2=None):
        del lam2
        return gossip_scan_clipped_stats(self._resolve(a_p), tree,
                                         self.t_server,
                                         clip_mult=self.clip_mult)


class ShardMapBackend(ConsensusBackend):
    """The production explicit-collective path (``make_gossip_shard_map``):
    blocked u16-wire all-gathers over the mesh's server axis, with the
    mixing matrix a traced operand.  Mesh-aware, so it is built by the
    launcher (``launch.sharding.fl_consensus_backend``) and injected via
    ``DFLConfig.consensus_backend``; being bound to a physical mesh axis it
    cannot survive fault surgery that changes M (``mesh_bound``)."""

    name = "shard_map"
    mesh_bound = True

    def __init__(self, mesh, a_static, t_server, leaf_specs, *,
                 axis_name: str = "server", block: int = 16_777_216,
                 staleness: int = 0):
        super().__init__(a_static, t_server)
        self.mesh = mesh
        self.leaf_specs = leaf_specs
        self.axis_name = axis_name
        self.block = block
        self.staleness = staleness
        self._run = make_gossip_shard_map(mesh, t_server, leaf_specs,
                                          axis_name=axis_name, block=block)
        self._wire_runners = {}

    def _mix(self, tree, a):
        if self.staleness:
            raise ValueError(
                "shard_map bounded staleness rides the delta-coded wire "
                "only (make_gossip_shard_map refuses codec=None): wrap "
                "with a physical-wire CompressedBackend or use staleness=0")
        return self._run(a, tree)

    def wire_runner(self, codec, *, stochastic: bool = True,
                    gather_codes: bool = True,
                    with_shipped: bool = False) -> Callable:
        """The physical-wire twin of this backend's program — same mesh,
        specs and block, but the all-gather moves the codec's int8 /
        packed-int4 codes instead of the float payload.
        ``with_shipped=True`` additionally returns each device's round-0
        decoded transmission (the error-feedback hook, computed inside the
        program with the exact local-shard wire layout).  Built on demand
        and cached per (codec, mode); ``CompressedBackend(wire='physical')``
        is the caller.  The backend's ``staleness`` threads through to the
        software-pipelined wire body."""
        k = (codec, bool(stochastic), bool(gather_codes),
             bool(with_shipped), self.staleness)
        if k not in self._wire_runners:
            self._wire_runners[k] = make_gossip_shard_map(
                self.mesh, self.t_server, self.leaf_specs,
                axis_name=self.axis_name, block=self.block, codec=codec,
                stochastic=stochastic, gather_codes=gather_codes,
                with_shipped=with_shipped, staleness=self.staleness)
        return self._wire_runners[k]


# ---------------------------------------------------------------------------
# compressed consensus: the comm subsystem's wrapper over any backend
# ---------------------------------------------------------------------------


class CompressedBackend(ConsensusBackend):
    """Lossy-compression wrapper around any ``ConsensusBackend`` — the
    ``repro.comm`` subsystem's hook into the consensus period.

    The wrapped period mixes the DECOMPRESSED server messages: ``mix``
    becomes ``inner.mix(D(C(W)))`` — mathematically what every receiver
    reconstructs from the on-wire payload — optionally with error feedback
    (``comm.error_feedback.ef_roundtrip``) whose per-server residual rides
    in ``dfl.DFLState.ef_residual``.  Because the T_S rounds are linear in
    the payloads, shipping each server's ONE compressed payload and letting
    it propagate T_S hops realises the whole period, so the on-wire cost is
    live-links x T_S x compressed-row bytes (``comm.accounting.
    BytesTracker``).  With the identity compressor (and a zero residual)
    every output is bitwise the inner backend's.

    The push-sum variant compresses the NUMERATOR only; the tiny ``(M,)``
    weight rides uncompressed (one f32 scalar per message, counted by the
    tracker).  Capability flags delegate to the inner backend, so the
    wrapper composes with einsum / blocked / collapsed / chebyshev /
    shard_map and both mixing modes.

    ``wire`` selects where compression happens:

    * ``"simulated"`` (default, the PR-4 wire model) — quantize ONCE per
      period in-graph (payload flooding: gossip is linear in the payloads,
      so one compressed payload per server forwarded T_S hops realises the
      period) and let the inner backend's collectives move floats; bytes
      are a host-side ledger.
    * ``"physical"`` — the codes ARE what crosses the interconnect: every
      round quantizes before the collective and dequantizes after
      (``gossip_scan_wire_bucketed`` for the pjit paths,
      ``ShardMapBackend.wire_runner`` for explicit collectives), so each
      hop re-quantizes like a real store-and-forward relay and every
      collective operand is int8 / packed int4 — in the BUCKETED layout:
      the whole tree as one padded code buffer, one collective pair per
      round.  Only the quantizers define a wire byte format, and only the
      literal T_S-round schedules (gossip / gossip_blocked / shard_map)
      have a per-round wire.  Error feedback tracks the round-0
      transmission of each server's OWN model
      (``bucketed_roundtrip_tree``) — later hops' stochastic-rounding
      error is zero-mean and untracked."""

    compressed = True

    def __init__(self, inner: ConsensusBackend,
                 compressor: "_compressors.Compressor", *,
                 error_feedback: bool = True, flat_sharding=None,
                 wire: str = "simulated",
                 wire_block: Optional[int] = None):
        if getattr(inner, "compressed", False):
            raise ValueError("refusing to wrap an already-compressed "
                             "backend: double compression double-counts "
                             "wire bytes and compounds loss")
        if wire not in ("simulated", "physical"):
            raise ValueError(f"wire must be 'simulated' or 'physical', "
                             f"got {wire!r}")
        if wire == "physical":
            if getattr(inner, "robust", False):
                raise ValueError(
                    f"wire='physical' ships quantized codes through the "
                    f"collectives, but the robust screening backend "
                    f"{inner.name!r} must rank/clip every neighbor's "
                    f"plaintext values before mixing — robust gossip "
                    f"composes with wire='simulated' compression only")
            if not isinstance(compressor, _compressors.StochasticQuantizer):
                raise ValueError(
                    "wire='physical' ships quantized codes through the "
                    "collectives; only the int8/int4 quantizers define a "
                    "wire byte format — top_k/random_k/identity run "
                    "wire='simulated'")
            if inner.name not in ("gossip", "gossip_blocked", "shard_map"):
                raise ValueError(
                    f"wire='physical' re-quantizes at every gossip hop, so "
                    f"it needs the literal T_S-round W <- A W schedule; "
                    f"backend {inner.name!r} has no per-round wire — use "
                    f"'gossip', 'gossip_blocked' or the shard_map backend")
        if getattr(inner, "staleness", 0) and wire != "physical":
            raise ValueError(
                "bounded staleness + wire='simulated' is incoherent: the "
                "simulated wire quantizes ONCE per period (no per-round "
                "in-flight buffers exist to be late), so the delayed-"
                "consumption model has nothing physical to model — use "
                "wire='physical' or staleness=0")
        self.inner = inner
        self.compressor = compressor
        self.error_feedback = error_feedback
        self.wire = wire
        # the block partitioning of the physical byte layout: follow the
        # inner backend's streaming block when it has one, so the EF
        # residual and the byte ledger see the exact on-wire layout
        self.wire_block = (getattr(inner, "block", None) or wire_block
                           or DEFAULT_GOSSIP_BLOCK)
        # NamedSharding of the flattened (M, d) leaf views under pjit —
        # same constraint (and same reason) as gossip_scan_blocked's
        self.flat_sharding = flat_sharding
        self.a_static = inner.a_static
        self.t_server = inner.t_server
        self.staleness = getattr(inner, "staleness", 0)
        self.name = f"compressed[{inner.name}+{compressor.name}" + (
            "+wire" if wire == "physical" else "") + "]"
        self.supports_traced = inner.supports_traced
        self.supports_directed = inner.supports_directed
        self.mesh_bound = inner.mesh_bound
        self.needs_spectral = inner.needs_spectral

    def _wire(self, tree: Any, residual: Optional[Any],
              key: Optional[jax.Array]):
        """Simulate the wire: (decompressed message tree, new residual)."""
        if residual is not None and self.error_feedback:
            return _ef.ef_roundtrip(self.compressor, tree, residual, key,
                                    flat_sharding=self.flat_sharding)
        return _compressors.roundtrip_tree(
            self.compressor, tree, key,
            flat_sharding=self.flat_sharding), residual

    def _mix_physical(self, tree: Any, a: jax.Array, *, residual, key):
        """Run one physical-wire consensus period on a (possibly
        transposed) operator: EF correction + round-0 residual update, then
        the per-round quantized collectives in the BUCKETED layout (one
        code + one scale buffer per server per round).  Returns ``(mixed
        tree, new residual)``.  The residual is ``corrected - (round-0
        decoded transmission)``: for the shard_map backend that
        transmission comes back from INSIDE the collective program
        (``with_shipped`` — the only layout-exact source when leaf specs
        shard weight axes); the pjit paths recompute it with
        ``bucketed_roundtrip_tree``, whose global-row layout is exactly
        what ``gossip_scan_wire_bucketed`` encodes.  The pjit gossip and
        gossip_blocked backends share one bucketed program — bucket blocks
        encode and gossip independently, so there is no block-major /
        round-major distinction left to preserve."""
        codec = self.compressor
        ef = residual is not None and self.error_feedback
        if ef:
            tree = jax.tree.map(lambda x, e: x + e.astype(x.dtype),
                                tree, residual)
        if isinstance(self.inner, ShardMapBackend):
            run = self.inner.wire_runner(codec, stochastic=key is not None,
                                         with_shipped=ef)
            if ef:
                out, shipped = run(a, tree, key)
                residual = jax.tree.map(lambda c, q: c - q, tree, shipped)
            else:
                out = run(a, tree, key)
            return out, residual
        if ef:
            shipped = bucketed_roundtrip_tree(codec, tree, key,
                                              block=self.wire_block)
            residual = jax.tree.map(lambda c, q: c - q, tree, shipped)
        return gossip_scan_wire_bucketed(
            a, tree, self.inner.t_server, codec, key,
            block=self.wire_block, staleness=self.staleness), residual

    # -- the EF-threading entry points the epoch step calls ------------------
    def mix_compressed(self, tree: Any, a_p: Optional[jax.Array] = None, *,
                       residual: Optional[Any] = None,
                       key: Optional[jax.Array] = None, lam2=None):
        """``(inner.mix of the wire-simulated tree, new EF residual)`` —
        or, under ``wire='physical'``, the per-round quantized-collective
        period."""
        if self.wire == "physical":
            del lam2
            return self._mix_physical(tree, self._resolve(a_p),
                                      residual=residual, key=key)
        msg, new_res = self._wire(tree, residual, key)
        return self.inner.mix(msg, a_p, lam2=lam2), new_res

    def mix_push_sum_compressed(self, state: PushSumState,
                                a_p: Optional[jax.Array] = None, *,
                                residual: Optional[Any] = None,
                                key: Optional[jax.Array] = None):
        if self.wire == "physical":
            if not self.supports_directed:
                raise ValueError(
                    f"consensus backend {self.name!r} has no "
                    f"ratio-consensus analogue")
            # the numerator rides the quantized wire (operator = the
            # column-stochastic transpose); the tiny (M,) weight recursion
            # stays exact, one f32 scalar per message on the ledger
            p = jnp.swapaxes(self._resolve(a_p), 0, 1)
            values, new_res = self._mix_physical(state.values, p,
                                                 residual=residual, key=key)
            weight = self.inner._mix_weight(state.weight, p)
            return PushSumState(values, weight), new_res
        msg, new_res = self._wire(state.values, residual, key)
        return self.inner.mix_push_sum(PushSumState(msg, state.weight),
                                       a_p), new_res

    # -- plain ConsensusBackend interface (no EF state threaded) -------------
    def mix(self, tree, a_p=None, lam2=None):
        return self.mix_compressed(tree, a_p, lam2=lam2)[0]

    def mix_push_sum(self, state, a_p=None):
        return self.mix_push_sum_compressed(state, a_p)[0]


BACKEND_MODES = ("gossip", "gossip_blocked", "collapsed", "chebyshev",
                 "exact_mean", "trimmed_mean", "median", "clipped")


def make_backend(mode: str, a_static: Optional[np.ndarray], t_server: int, *,
                 chebyshev_rounds: Optional[int] = None,
                 gossip_flat_sharding=None,
                 block: int = DEFAULT_GOSSIP_BLOCK,
                 compression: str = "none",
                 error_feedback: bool = False,
                 wire: str = "simulated",
                 staleness: int = 0) -> ConsensusBackend:
    """Map a ``DFLConfig.consensus_mode`` string to a ``ConsensusBackend``.

    The robust screens take an optional spec argument after a colon:
    ``"trimmed_mean[:f]"`` (default f=1) and ``"clipped[:mult]"`` (default
    clip_mult=1.0); ``"median"`` is parameter-free.

    ``compression`` other than ``"none"`` (a ``comm.compressors.
    make_compressor`` spec, e.g. ``"int8"`` / ``"top_k:0.05"``) wraps the
    resolved backend in a ``CompressedBackend``, optionally with error
    feedback; ``wire`` selects the simulated (once-per-period, host byte
    ledger) vs physical (codes through the collectives, per-round) wire —
    see ``CompressedBackend``.  ``shard_map`` is absent on purpose: it
    needs a mesh and per-leaf PartitionSpecs, so the launcher builds it
    directly (``launch.sharding.fl_consensus_backend``, which applies the
    same compression wrap).

    ``staleness`` (bounded-staleness depth, see ``gossip_scan_stale``)
    threads into the literal T_S-round schedules only — every other mode
    has no per-round message stream to delay and refuses loudly."""
    base, _, arg = mode.partition(":")
    if staleness < 0:
        raise ValueError(f"staleness must be >= 0, got {staleness}")
    if staleness and base not in ("gossip", "gossip_blocked"):
        raise ValueError(
            f"bounded staleness needs the literal T_S-round W <- A W "
            f"schedule (round t consumes round t-s's messages); mode "
            f"{mode!r} has no per-round message stream to delay — use "
            f"'gossip'/'gossip_blocked' (or the launcher's shard_map "
            f"backend) or staleness=0")
    if mode == "gossip":
        backend = GossipBackend(a_static, t_server, staleness=staleness)
    elif mode == "gossip_blocked":
        backend = BlockedGossipBackend(a_static, t_server, block=block,
                                       flat_sharding=gossip_flat_sharding,
                                       staleness=staleness)
    elif mode == "collapsed":
        backend = CollapsedBackend(a_static, t_server)
    elif mode == "chebyshev":
        backend = ChebyshevBackend(a_static, t_server,
                                   rounds=chebyshev_rounds)
    elif mode == "exact_mean":
        backend = ExactMeanBackend(a_static, t_server)
    elif base == "trimmed_mean":
        if arg and not arg.isdigit():
            raise ValueError(f"bad trimmed_mean spec {mode!r}: expected "
                             f"'trimmed_mean[:f]' with integer f >= 0")
        backend = TrimmedMeanBackend(a_static, t_server,
                                     f=int(arg) if arg else 1)
    elif base == "median":
        if arg:
            raise ValueError(f"bad median spec {mode!r}: the coordinatewise "
                             f"median takes no parameter")
        backend = MedianBackend(a_static, t_server)
    elif base == "clipped":
        try:
            clip_mult = float(arg) if arg else 1.0
        except ValueError:
            raise ValueError(f"bad clipped spec {mode!r}: expected "
                             f"'clipped[:mult]' with float mult > 0")
        backend = ClippedGossipBackend(a_static, t_server,
                                       clip_mult=clip_mult)
    else:
        raise ValueError(f"unknown consensus mode {mode!r}")
    if compression != "none":
        backend = CompressedBackend(
            backend, _compressors.make_compressor(compression),
            error_feedback=error_feedback,
            flat_sharding=gossip_flat_sharding,
            wire=wire, wire_block=block)
    return backend
