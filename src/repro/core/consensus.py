"""Server-side consensus updates (Eq. 5/7) as JAX ops.

The parameter pytree during DFL training carries a leading *server* axis of
size M (possibly preceded by a client axis — see ``dfl.py``).  A consensus
round is ``W <- A W`` applied leaf-wise:

    new_w[i] = a_ii * w[i] + sum_{j in N_i} a_ij * w[j]      (Eq. 5)

Execution strategies, all bit-identical in math:

* ``gossip_scan``    — the *faithful* schedule: T_S sequential rounds
                       (lax.fori_loop), each an einsum over the server axis.
                       Under pjit with the server axis sharded this lowers to
                       one all-gather (or neighbour exchanges) per round —
                       exactly the paper's per-iteration message pattern.
* ``gossip_scan_blocked`` — the same schedule streamed over fixed-size
                       parameter blocks (deterministic working set).
* ``gossip_collapsed`` — beyond-paper: precompute A_eff = A^{T_S} on the host
                       (M x M, trivial) and apply it in ONE round.  Output is
                       mathematically identical; collective rounds drop T_S x.
* ``gossip_chebyshev`` — beyond-paper: degree-k Chebyshev polynomial in A
                       reaching the same contraction with ~sqrt fewer rounds;
                       useful when rounds must stay iterative (fault probing
                       between rounds).
* ``make_gossip_shard_map`` — the production path: explicit blocked
                       all-gathers under shard_map, taking the mixing matrix
                       as a *traced operand* so one compiled program serves
                       every per-epoch graph.

``ring_gossip_shard_map`` additionally shows the TPU-native neighbour
exchange (lax.ppermute) for ring graphs under shard_map.

**Consensus backends.**  ``ConsensusBackend`` wraps each strategy behind one
interface consumed by ``dfl.build_dfl_epoch_step``:

    backend.mix(server_tree, a_p)            T_S rounds of W <- A W
    backend.mix_push_sum(state, a_p)         the ratio-consensus variant

``a_p`` is an optional traced per-epoch ``(M, M)`` mixing matrix (dynamic
federation); ``None`` selects the static topology matrix the backend was
built with.  ``make_backend`` maps a ``DFLConfig.consensus_mode`` string to
a backend; ``ShardMapBackend`` is mesh-aware and therefore constructed by
the launcher (``launch.sharding.fl_consensus_backend``) and injected via
``DFLConfig.consensus_backend``.

**Compressed consensus.**  ``CompressedBackend`` wraps any backend with the
``repro.comm`` wire simulation — lossy compression (quantization /
sparsification) of each server's outgoing message plus optional error
feedback — so every execution strategy composes with every compressor; the
host-side byte ledger is ``comm.accounting.BytesTracker``.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.comm import compressors as _compressors
from repro.comm import error_feedback as _ef
from repro.core.topology import lambda_2 as tp_lambda_2

try:                                   # jax >= 0.6: public jax.shard_map
    _shard_map = jax.shard_map
    _CHECK_KW = "check_vma"
except AttributeError:                 # jax 0.4.x: experimental location
    from jax.experimental.shard_map import shard_map as _shard_map
    _CHECK_KW = "check_rep"


def shard_map_compat(f, mesh, in_specs, out_specs, check=None):
    """jax.shard_map across the 0.4.x -> 0.6 API move (the keyword for
    replication checking was renamed check_rep -> check_vma)."""
    kw = {} if check is None else {_CHECK_KW: check}
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **kw)


def _mix_leaf(a: jax.Array, leaf: jax.Array) -> jax.Array:
    """new[i] = sum_j a[i, j] * leaf[j, ...] over the leading server axis.

    Contracts in the LEAF's dtype: under pjit the server axis is sharded, so
    this lowers to an all-gather of (M x shard) — doing it in bf16 moves and
    holds half the bytes of the promoted-f32 contraction (A itself is tiny
    and cast down; one bf16 rounding per round matches what real multi-host
    gossip over bf16 wires would do)."""
    return jnp.tensordot(a.astype(leaf.dtype), leaf, axes=([1], [0]))


def mix_pytree(a: jax.Array, tree: Any) -> Any:
    """One consensus round ``W <- A W`` applied to every leaf."""
    return jax.tree.map(functools.partial(_mix_leaf, a), tree)


def gossip_scan(a: jax.Array, tree: Any, t_server: int) -> Any:
    """Faithful T_S-round consensus (Alg. 1 server loop).

    One fori_loop PER LEAF (leaves gossip independently, so round-leaf
    reordering is exact): XLA schedules the per-leaf while-loops one after
    another, keeping only one leaf's (M x shard) all-gather live at a time
    instead of the whole model's."""
    if t_server == 0:
        return tree

    def leaf_loop(leaf):
        return jax.lax.fori_loop(
            0, t_server, lambda _, w: _mix_leaf(a, w), leaf)

    return jax.tree.map(leaf_loop, tree)


def gossip_scan_tv(a_rounds: jax.Array, tree: Any) -> Any:
    """Time-varying consensus: round t applies ``a_rounds[t]``.

    ``a_rounds`` layout — a traced ``(T_S, M, M)`` stack with one mixing
    matrix PER ROUND, not per epoch: ``a_rounds[t]`` is the operator of
    consensus round ``t`` within a single consensus period, so the leading
    axis is the round index and its length is this period's T_S.  This is
    the fully general form of Eq. 5 where the server graph may change
    BETWEEN ROUNDS (link failures mid-consensus, straggler reweighting).
    Contrast ``schedule.TopologySchedule``, which emits ONE ``(M, M)``
    matrix per epoch ``A_p``; to feed such a per-epoch matrix here,
    broadcast it to ``(T_S, M, M)`` — a stack of T_S identical matrices is
    exactly ``gossip_scan(a, tree, T_S)`` (same per-round operator, same
    ordering).  Each round preserves the server mean when every
    ``a_rounds[t]`` is doubly stochastic, and the ordered product of the
    stack governs the contraction (``topology.sigma_product`` with t_s=1
    per entry)."""
    if a_rounds.shape[0] == 0:
        return tree

    def leaf_loop(leaf):
        return jax.lax.fori_loop(
            0, a_rounds.shape[0],
            lambda i, w: _mix_leaf(a_rounds[i], w), leaf)

    return jax.tree.map(leaf_loop, tree)


def gossip_scan_blocked(a: jax.Array, tree: Any, t_server: int,
                        block: int = 4_194_304,
                        flat_sharding=None) -> Any:
    """Faithful T_S-round gossip, streamed over fixed-size parameter blocks.

    Blocks gossip independently, so iterating (block-major, round-minor)
    instead of (round-major, leaf-minor) is *exactly* the same operator —
    but the live working set per step is one (M, block) gather instead of a
    full parameter leaf per server (which at 27B+ scales is multi-GB per
    in-flight leaf; XLA-CPU additionally upcasts bf16 contractions to f32,
    doubling it).  Used by the epoch step whenever the model is large;
    ``gossip_scan`` remains the reference for tests and small models.
    """
    if t_server == 0:
        return tree
    leaves, treedef = jax.tree.flatten(tree)
    m = leaves[0].shape[0]
    dtype = leaves[0].dtype
    sizes = [l[0].size for l in leaves]
    flat = jnp.concatenate([l.reshape(m, -1) for l in leaves], axis=1)
    d = flat.shape[1]
    nb = max(1, -(-d // block))
    pad = nb * block - d
    if pad:
        flat = jnp.pad(flat, ((0, 0), (0, pad)))
    if flat_sharding is not None:
        # keep the flattened model sharded over the intra-client axes —
        # without this the concat of heterogeneously-sharded leaves makes
        # the partitioner replicate the whole model per device.
        flat = jax.lax.with_sharding_constraint(flat, flat_sharding)
    blocks = jnp.moveaxis(flat.reshape(m, nb, block), 1, 0)   # (nb, M, blk)
    a_cast = a.astype(dtype)

    def per_block(_, blk):
        out = jax.lax.fori_loop(
            0, t_server, lambda _i, w: jnp.tensordot(a_cast, w,
                                                     axes=([1], [0])), blk)
        return None, out

    _, mixed = jax.lax.scan(per_block, None, blocks)
    flat = jnp.moveaxis(mixed, 0, 1).reshape(m, nb * block)[:, :d]
    if flat_sharding is not None:
        flat = jax.lax.with_sharding_constraint(flat, flat_sharding)
    out, off = [], 0
    new_leaves = []
    for leaf, size in zip(leaves, sizes):
        new_leaves.append(flat[:, off:off + size].reshape(leaf.shape))
        off += size
    return jax.tree.unflatten(treedef, new_leaves)


# ---------------------------------------------------------------------------
# push-sum (ratio) consensus for DIRECTED server graphs
#
# When link failures make the graph directed, no doubly-stochastic matrix
# may exist on its support (Eq. 6 is unsatisfiable): the best a node can do
# locally is split its mass over its out-neighbours — a ROW-stochastic A
# (topology.out_degree_weights).  Naive gossip with such an A converges to
# the Perron-weighted average pi' W (pi the left Perron vector of A), a
# BIASED aggregate.  Push-sum / ratio consensus (Kempe et al. 2003;
# Nedic & Olshevsky 2015) fixes this by mixing a numerator AND a scalar
# weight with the column-stochastic transpose P = A' and reading out the
# ratio:
#
#     num <- P num,   w <- P w,     z_i = num_i / w_i
#
# P column-stochastic preserves both sums (sum num = sum W_0, sum w = M),
# and P^t -> v 1' (sum v = 1), so num -> v * sum(W_0), w -> v * M and every
# ratio z_i -> the exact uniform mean — the skew v cancels.  Operationally
# each round IS the row-stochastic protocol run in push mode: node i sends
# a[i, j]-weighted shares of its (num, w) along its OUT-edges; P = A' is
# just that send pattern written as a matrix acting on the receive side.
# When A is doubly stochastic, P = A' is row-stochastic too, w stays at 1
# identically and push-sum degenerates to plain gossip.
# ---------------------------------------------------------------------------


class PushSumState(NamedTuple):
    """Numerator pytree (leaves ``(M, *w)``) + per-server scalar weight
    ``(M,)``.  Invariants under mixing: weights stay positive and sum to M;
    ``ratio()`` of a freshly-initialised state is the values themselves."""

    values: Any          # numerator pytree, leading server axis M
    weight: jax.Array    # (M,) float, > 0, sum == M

    def ratio(self) -> Any:
        """The unbiased read-out z_i = num_i / w_i, broadcast leaf-wise."""
        return jax.tree.map(
            lambda v: v / self.weight.reshape(
                (-1,) + (1,) * (v.ndim - 1)).astype(v.dtype),
            self.values)


def init_push_sum(tree: Any) -> PushSumState:
    """Start of a consensus period: numerator = the server models, weight =
    1 for every server.  Weights RESET here each period by design: with a
    persistent weight the finite-round ratio is no longer exact on
    consensus states (P^t(c*1)/P^t(1) == c for all t only when num and w
    start aligned), and re-weighting the numerator by a carried weight
    provably re-introduces the Perron bias — see docs/dynamic_federation.md."""
    m = jax.tree.leaves(tree)[0].shape[0]
    return PushSumState(tree, jnp.ones((m,), jnp.float32))


def _push_leaf(p: jax.Array, leaf: jax.Array) -> jax.Array:
    return jnp.tensordot(p.astype(leaf.dtype), leaf, axes=([1], [0]))


def gossip_push_sum(a: jax.Array, state: PushSumState,
                    t_server: int) -> PushSumState:
    """T_S rounds of push-sum over a ROW-stochastic ``a`` (shape (M, M),
    support = directed graph + self-loops, e.g. topology.out_degree_weights).

    Numerator and weight are mixed with the same column-stochastic operator
    ``P = a.T``; they interact only at read-out (``.ratio()``), so each leaf
    loops independently exactly like ``gossip_scan``.  The weight recursion
    is a tiny (M,) matvec and costs nothing next to the parameter leaves."""
    if t_server == 0:
        return state
    p = a.T

    def leaf_loop(leaf):
        return jax.lax.fori_loop(
            0, t_server, lambda _, w: _push_leaf(p, w), leaf)

    values = jax.tree.map(leaf_loop, state.values)
    weight = jax.lax.fori_loop(
        0, t_server, lambda _, w: (p @ w.astype(p.dtype)).astype(w.dtype),
        state.weight)
    return PushSumState(values, weight)


def gossip_push_sum_blocked(a: jax.Array, state: PushSumState,
                            t_server: int, block: int = 4_194_304,
                            flat_sharding=None) -> PushSumState:
    """Blocked push-sum: the ``gossip_scan_blocked`` streaming schedule run
    in ratio-consensus form.  The numerator pytree is streamed through the
    same fixed-``block`` machinery with the column-stochastic operator
    ``P = a.T`` (blocks mix independently, so block-major iteration is the
    identical operator), while the ``(M,)`` weight recursion is a trivial
    matvec outside the stream.  Accepts a traced per-epoch ``a``.

    Functional form of ``BlockedGossipBackend.mix_push_sum`` (which is
    just the generic ``ConsensusBackend.mix_push_sum`` over the blocked
    ``_mix``) — one source of truth for the streaming push-sum logic."""
    if t_server == 0:
        return state
    return BlockedGossipBackend(
        None, t_server, block=block,
        flat_sharding=flat_sharding).mix_push_sum(state, a)


def gossip_push_sum_tv(a_rounds: jax.Array,
                       state: PushSumState) -> PushSumState:
    """Time-varying push-sum: round t mixes with ``a_rounds[t].T``.

    ``a_rounds`` follows the ``gossip_scan_tv`` layout — a traced
    ``(T_S, M, M)`` stack of ROW-stochastic matrices, one per round.  Every
    round preserves sum(num) and sum(w) (each transpose is column
    stochastic), so the ratio read-out stays unbiased under arbitrary
    per-round graph changes as long as the sequence is jointly strongly
    connected."""
    if a_rounds.shape[0] == 0:
        return state

    def leaf_loop(leaf):
        return jax.lax.fori_loop(
            0, a_rounds.shape[0],
            lambda i, w: _push_leaf(a_rounds[i].T, w), leaf)

    values = jax.tree.map(leaf_loop, state.values)
    weight = jax.lax.fori_loop(
        0, a_rounds.shape[0],
        lambda i, w: (a_rounds[i].T @ w.astype(a_rounds.dtype)).astype(w.dtype),
        state.weight)
    return PushSumState(values, weight)


def collapse_mixing(a: np.ndarray, t_server: int) -> np.ndarray:
    """A_eff = A^{T_S} (host-side, float64). Doubly stochastic by closure."""
    return np.linalg.matrix_power(np.asarray(a, dtype=np.float64), t_server)


def gossip_collapsed(a_eff: jax.Array, tree: Any) -> Any:
    """Single-round application of the collapsed operator A^{T_S}."""
    return mix_pytree(a_eff, tree)


# ---------------------------------------------------------------------------
# Chebyshev-accelerated gossip (beyond-paper)
# ---------------------------------------------------------------------------


def chebyshev_coefficients(a: np.ndarray, rounds: int) -> float:
    """Return the contraction sigma achieved by ``rounds`` Chebyshev steps
    (for reporting).  Uses lambda_2 of the symmetric mixing matrix."""
    ev = np.sort(np.abs(np.linalg.eigvalsh(a)))[::-1]
    lam2 = ev[1] if len(ev) > 1 else 0.0
    if lam2 == 0.0:
        return 0.0
    # |T_k(1/lam2)|^{-1} with T_k the Chebyshev polynomial of the first kind
    x = 1.0 / lam2
    return float(1.0 / np.cosh(rounds * np.arccosh(x)))


def gossip_chebyshev(a: jax.Array, tree: Any, rounds: int, lam2) -> Any:
    """Chebyshev semi-iterative consensus:  w_k = 2 c_k/(lam2 c_{k+1}) A w_{k-1}
    - (c_{k-1}/c_{k+1}) w_{k-2}, with c_k = cosh(k acosh(1/lam2)).

    Reaches sigma ~ 2 rho^k (rho = (1-sqrt(1-lam2^2))/lam2) instead of lam2^k:
    ~sqrt(1/(1-lam2)) fewer rounds for the same contraction.  Exactly
    mean-preserving like plain gossip (each update is an affine combination
    of doubly-stochastic operators with coefficients summing to 1).

    ``lam2`` may be a host-side float (static topology) or a TRACED scalar
    — the per-epoch spectral estimate a ``TopologySchedule`` feeds through
    ``schedule.EpochSchedule.lam2`` under dynamic federation.  The
    recursion therefore carries the bounded ratio ``r_k = c_{k-1}/c_k`` in
    place of the coefficients themselves (the raw c_k overflow f32 within
    a few rounds when lam2 is small):

        alpha_k = 2x / (2x - r_k),  beta_k = r_k / (2x - r_k),
        r_{k+1} = 1 / (2x - r_k),   x = 1/lam2,  r_1 = lam2,

    with ``alpha_k - beta_k = 1`` (mean preservation) for every lam2.
    A clamped ``lam2 -> 0`` degenerates gracefully to plain repeated
    mixing (alpha -> 1, beta -> 0)."""
    if rounds == 0:
        return tree
    if isinstance(lam2, (int, float)) and lam2 <= 0.0:
        return mix_pytree(a, tree)
    x = 1.0 / jnp.maximum(jnp.asarray(lam2, jnp.float32), 1e-6)
    r = 1.0 / x          # r_1 = c_0 / c_1 = lam2

    w_prev = tree
    w_cur = mix_pytree(a, tree)  # k = 1: the first semi-iterate is just A w
    for _ in range(1, rounds):
        denom = 2.0 * x - r
        alpha, beta = 2.0 * x / denom, r / denom
        mixed = mix_pytree(a, w_cur)
        w_next = jax.tree.map(
            lambda m, p: (alpha * m - beta * p).astype(m.dtype), mixed, w_prev)
        w_prev, w_cur = w_cur, w_next
        r = 1.0 / denom
    return w_cur


def lambda2_traced(a: jax.Array) -> jax.Array:
    """|lambda_2| of a traced symmetric mixing matrix, computed in-graph
    (tiny (M, M) eigendecomposition).  Fallback for calling a spectral
    backend with a traced ``A_p`` but no host-side estimate — the engine
    normally feeds ``topology.lambda_2`` through the schedule instead."""
    if a.shape[0] < 2:
        return jnp.zeros((), jnp.float32)
    ev = jnp.sort(jnp.abs(jnp.linalg.eigvalsh(a)))
    return ev[-2].astype(jnp.float32)


# ---------------------------------------------------------------------------
# shard_map gossip: fully-manual blocked server gossip (the production path)
# ---------------------------------------------------------------------------


def make_gossip_shard_map(mesh, t_server: int, leaf_specs: Any, *,
                          axis_name: str = "server",
                          block: int = 16_777_216) -> Callable:
    """T_S-round gossip as an explicit shard_map program, returned as
    ``run(operator, tree)`` with the ``(M, M)`` mixing ``operator`` a
    *traced operand* — one compiled program serves every per-epoch graph
    (dynamic federation), and a compile-time-constant operator recovers the
    static case.  Pass ``A`` for plain gossip ``W <- A W``; pass ``A.T``
    (the column-stochastic transpose) to mix a push-sum numerator — the
    body applies ``operator`` row-wise either way.

    Inside the shard_map every device flattens its LOCAL weight shards into
    one vector and scans over fixed ``block``-element slices; each slice
    runs the full T_S-round loop (blocks gossip independently, so
    block-major iteration is the identical operator).  Per-round transfer
    is one bf16 all-gather of (M, block) over the server axis — memory is
    deterministic (~(M+2) x block x 2 bytes live) and dtype is under our
    control, unlike the pjit einsum form where XLA-CPU upcasts the
    contraction operand to f32 *before* the gather and overlaps per-leaf
    loops (~12 GB of f32 gathers at 27B scale).

    ``leaf_specs``: PartitionSpec pytree of the server tree (leading
    'server' axis + intra-client weight axes) — used as in_specs and
    out_specs; the operator itself rides in replicated.
    """
    from jax.sharding import PartitionSpec as P

    def body(a, tree):
        m = a.shape[0]
        idx = jax.lax.axis_index(axis_name)
        row = a[idx].astype(jnp.float32)                 # (M,) my weights
        leaves, treedef = jax.tree.flatten(tree)
        dtype = leaves[0].dtype
        # Wire-format control: carry the gossip stream as u16 bit-patterns
        # of the bf16 payload.  Integer buffers are exempt from XLA-CPU's
        # float-normalization pass, which otherwise upcasts every
        # loop-carried bf16 buffer to f32 — a 2x params-sized artifact this
        # container's backend would report that a TPU (native bf16) never
        # allocates.  On TPU the bitcasts are free view changes.
        wire = jnp.uint16 if dtype == jnp.bfloat16 else None

        def to_wire(x):
            return jax.lax.bitcast_convert_type(x, wire) if wire else x

        def from_wire(x):
            return (jax.lax.bitcast_convert_type(x, jnp.bfloat16)
                    if wire else x)

        def round_fn(_i, w):
            g = from_wire(jax.lax.all_gather(w, axis_name))      # (M, blk)
            # unrolled mul-adds (M is tiny); f32 accumulate per block
            acc = row[0] * g[0].astype(jnp.float32)
            for j in range(1, m):
                acc = acc + row[j] * g[j].astype(jnp.float32)
            return to_wire(acc.astype(dtype))

        def gossip_leaf(flat):
            """Blocked in-place gossip over one flattened (wire) leaf."""
            d = flat.size
            blk = min(block, d)
            nb = -(-d // blk)
            if nb * blk != d:
                flat = jnp.pad(flat, (0, nb * blk - d))
            if nb == 1:
                return jax.lax.fori_loop(0, t_server, round_fn, flat)[:d]

            def per_block(i, buf):
                w = jax.lax.dynamic_slice(buf, (i * blk,), (blk,))
                w = jax.lax.fori_loop(0, t_server, round_fn, w)
                return jax.lax.dynamic_update_slice(buf, w, (i * blk,))

            return jax.lax.fori_loop(0, nb, per_block, flat)[:d]

        # Per-leaf loops CHAINED via optimization_barrier: leaves gossip
        # independently, so XLA would otherwise schedule their while-loops
        # concurrently and hold every leaf's wire buffers at once; the
        # token dependency forces one leaf in flight at a time.
        new_leaves = []
        token = None
        for leaf in leaves:
            wl = to_wire(leaf.astype(dtype)).reshape(-1)
            if token is not None:
                wl, token = jax.lax.optimization_barrier((wl, token))
            out = gossip_leaf(wl)
            token = out[0]
            new_leaves.append(
                from_wire(out).astype(leaf.dtype).reshape(leaf.shape))
        return jax.tree.unflatten(treedef, new_leaves)

    return shard_map_compat(body, mesh, (P(None, None), leaf_specs),
                            leaf_specs, check=False)


# ---------------------------------------------------------------------------
# shard_map ring gossip: explicit neighbour exchange over ICI
# ---------------------------------------------------------------------------


def ring_gossip_step(w: jax.Array, *, axis_name: str, self_weight: float,
                     neighbor_weight: float) -> jax.Array:
    """One gossip round on a ring graph executed INSIDE shard_map: each server
    shard receives its two ring neighbours via collective_permute — the
    literal 'server communicates with neighbours' of Alg. 1, mapped onto the
    physical ICI ring."""
    m = jax.lax.psum(1, axis_name)
    fwd = [(i, (i + 1) % m) for i in range(m)]
    bwd = [((i + 1) % m, i) for i in range(m)]
    left = jax.lax.ppermute(w, axis_name, perm=fwd)
    right = jax.lax.ppermute(w, axis_name, perm=bwd)
    return (self_weight * w + neighbor_weight * (left + right)).astype(w.dtype)


def make_ring_gossip(mesh: jax.sharding.Mesh, axis_name: str, t_server: int,
                     self_weight: float, neighbor_weight: float) -> Callable:
    """Build a shard_map'd T_S-round ring gossip over ``axis_name``.

    The input pytree must have its leading (server) axis sharded over
    ``axis_name``; other axes pass through unchanged.
    """
    from jax.sharding import PartitionSpec as P

    def per_shard(tree):
        def body(_, w):
            return jax.tree.map(
                lambda x: ring_gossip_step(
                    x, axis_name=axis_name, self_weight=self_weight,
                    neighbor_weight=neighbor_weight),
                w)
        return jax.lax.fori_loop(0, t_server, body, tree)

    def spec_for(tree):
        return jax.tree.map(lambda x: P(axis_name, *([None] * (x.ndim - 1))), tree)

    def run(tree):
        specs = spec_for(tree)
        return shard_map_compat(per_shard, mesh, (specs,), specs)(tree)

    return run


# ---------------------------------------------------------------------------
# consensus backends: one interface over every execution strategy
# ---------------------------------------------------------------------------


class ConsensusBackend:
    """One consensus period (Eq. 5/7) behind one interface.

    ``mix(tree, a_p)`` runs T_S rounds of ``W <- A W`` on a server-leading
    pytree; ``mix_push_sum(state, a_p)`` runs the ratio-consensus variant
    (numerator and weight both mixed by the column-stochastic ``A'``, see
    ``gossip_push_sum``).  ``a_p`` is an optional *traced* per-epoch
    ``(M, M)`` mixing matrix — the dynamic engine passes a fresh one every
    epoch through the SAME compiled program; ``None`` selects the static
    matrix the backend was built with.

    Class flags gate what a backend can express:

    * ``supports_traced`` — can consume a traced ``A_p``.
    * ``supports_directed`` — applies the literal ``W <- A W`` update, so
      row-stochastic A and the push-sum correction are well-defined.
    * ``mesh_bound`` — closed over a fixed physical mesh (shard_map): the
      server axis cannot survive fault surgery that changes M.
    * ``needs_spectral`` — wants a per-epoch spectral estimate ``lam2``
      alongside a traced ``A_p`` (Chebyshev); the dynamic engine feeds it
      through ``schedule.EpochSchedule.lam2``.
    * ``compressed`` — a ``CompressedBackend`` wrapper (lossy wire
      simulation + error feedback around an inner backend).
    """

    name = "?"
    supports_traced = True
    supports_directed = True
    mesh_bound = False
    needs_spectral = False
    compressed = False

    def __init__(self, a_static: Optional[np.ndarray], t_server: int):
        self.a_static = (None if a_static is None
                         else jnp.asarray(a_static, jnp.float32))
        self.t_server = t_server

    def _resolve(self, a_p: Optional[jax.Array]) -> jax.Array:
        if a_p is not None:
            return a_p
        if self.a_static is None:
            raise ValueError(f"{self.name!r} backend was built without a "
                             f"static mixing matrix; pass a per-epoch A_p")
        return self.a_static

    def mix(self, tree: Any, a_p: Optional[jax.Array] = None,
            lam2=None) -> Any:
        """T_S rounds of ``W <- A W`` over the leading server axis.
        ``lam2`` is the optional per-epoch spectral hint, consumed only by
        ``needs_spectral`` backends and ignored everywhere else."""
        del lam2
        return self._mix(tree, self._resolve(a_p))

    def mix_push_sum(self, state: PushSumState,
                     a_p: Optional[jax.Array] = None) -> PushSumState:
        """Ratio consensus: numerator streamed through the SAME execution
        strategy with ``P = A'``, weight by the trivial ``(M,)`` matvec."""
        if not self.supports_directed:
            raise ValueError(
                f"consensus backend {self.name!r} has no ratio-consensus "
                f"analogue: its value update is not the literal W <- A W, "
                f"so a numerator/weight pair mixed by it would be "
                f"inconsistent")
        p = jnp.swapaxes(self._resolve(a_p), 0, 1)
        return PushSumState(self._mix(state.values, p),
                            self._mix_weight(state.weight, p))

    def _mix_weight(self, weight: jax.Array, p: jax.Array) -> jax.Array:
        return jax.lax.fori_loop(
            0, self.t_server,
            lambda _, w: (p @ w.astype(p.dtype)).astype(w.dtype), weight)

    def _mix(self, tree: Any, a: jax.Array) -> Any:
        raise NotImplementedError


class GossipBackend(ConsensusBackend):
    """The reference per-leaf einsum schedule (``gossip_scan``)."""

    name = "gossip"

    def _mix(self, tree, a):
        return gossip_scan(a, tree, self.t_server)


class BlockedGossipBackend(ConsensusBackend):
    """``gossip_scan_blocked``: fixed-block streaming — the pjit production
    path whose live working set is one (M, block) gather, not a full leaf."""

    name = "gossip_blocked"

    def __init__(self, a_static, t_server, *, block: int = 4_194_304,
                 flat_sharding=None):
        super().__init__(a_static, t_server)
        self.block = block
        self.flat_sharding = flat_sharding

    def _mix(self, tree, a):
        return gossip_scan_blocked(a, tree, self.t_server, block=self.block,
                                   flat_sharding=self.flat_sharding)


class CollapsedBackend(ConsensusBackend):
    """One round with ``A_eff = A^{T_S}`` — host-side float64 collapse for
    the static matrix, in-program (M x M, trivial) collapse for a traced
    per-epoch ``A_p``."""

    name = "collapsed"

    def __init__(self, a_static, t_server):
        super().__init__(a_static, t_server)
        self._eff_static = (None if a_static is None else jnp.asarray(
            collapse_mixing(np.asarray(a_static), t_server), jnp.float32))

    def _eff(self, a_p: Optional[jax.Array]) -> jax.Array:
        if a_p is None:
            if self._eff_static is None:
                raise ValueError("'collapsed' backend was built without a "
                                 "static mixing matrix; pass a per-epoch A_p")
            return self._eff_static
        return jax.lax.fori_loop(
            0, self.t_server, lambda _, p: a_p @ p,
            jnp.eye(a_p.shape[0], dtype=a_p.dtype))

    def mix(self, tree, a_p=None, lam2=None):
        del lam2
        return gossip_collapsed(self._eff(a_p), tree)

    def mix_push_sum(self, state, a_p=None):
        # (A^{T_S})' == (A')^{T_S}: one collapsed round of the transpose
        effp = jnp.swapaxes(self._eff(a_p), 0, 1)
        weight = (effp @ state.weight.astype(effp.dtype)).astype(
            state.weight.dtype)
        return PushSumState(mix_pytree(effp, state.values), weight)


class ChebyshevBackend(ConsensusBackend):
    """Chebyshev semi-iterative gossip.

    Spectral data rides OUTSIDE the matrix: for the static topology,
    ``lambda_2(A)`` is computed on the host at construction; for a traced
    per-epoch ``A_p`` (dynamic federation) the matching per-epoch estimate
    arrives as the traced ``lam2`` operand — the engine computes it
    host-side per epoch (``topology.lambda_2`` via
    ``schedule.EpochSchedule.lam2``) since the ratio-parametrised recursion
    in ``gossip_chebyshev`` handles traced coefficients.  A traced ``A_p``
    with no estimate falls back to the in-graph ``lambda2_traced``.  The
    affine recursion has negative coefficients, so no ratio-consensus
    (push-sum) analogue exists."""

    name = "chebyshev"
    supports_directed = False
    needs_spectral = True

    def __init__(self, a_static, t_server, *, rounds: Optional[int] = None):
        super().__init__(a_static, t_server)
        self.lam2 = (None if a_static is None
                     else tp_lambda_2(np.asarray(a_static)))
        self.rounds = rounds or max(1, int(np.ceil(np.sqrt(max(t_server,
                                                               1)))))

    def mix(self, tree, a_p=None, lam2=None):
        a = self._resolve(a_p)
        if lam2 is None:
            lam2 = self.lam2 if a_p is None else lambda2_traced(a_p)
        if lam2 is None:
            raise ValueError("'chebyshev' was built without a static mixing "
                             "matrix; pass (a_p, lam2) per call")
        return gossip_chebyshev(a, tree, self.rounds, lam2)


class ExactMeanBackend(ConsensusBackend):
    """The idealised sigma_A = 0 limit (hierarchical FL with a root
    aggregator): ignores the mixing matrix entirely, so the directed /
    push-sum interpretations are undefined for it."""

    name = "exact_mean"
    supports_directed = False

    def _mix(self, tree, a):
        return jax.tree.map(
            lambda x: jnp.broadcast_to(x.mean(axis=0, keepdims=True),
                                       x.shape), tree)


class ShardMapBackend(ConsensusBackend):
    """The production explicit-collective path (``make_gossip_shard_map``):
    blocked u16-wire all-gathers over the mesh's server axis, with the
    mixing matrix a traced operand.  Mesh-aware, so it is built by the
    launcher (``launch.sharding.fl_consensus_backend``) and injected via
    ``DFLConfig.consensus_backend``; being bound to a physical mesh axis it
    cannot survive fault surgery that changes M (``mesh_bound``)."""

    name = "shard_map"
    mesh_bound = True

    def __init__(self, mesh, a_static, t_server, leaf_specs, *,
                 axis_name: str = "server", block: int = 16_777_216):
        super().__init__(a_static, t_server)
        self._run = make_gossip_shard_map(mesh, t_server, leaf_specs,
                                          axis_name=axis_name, block=block)

    def _mix(self, tree, a):
        return self._run(a, tree)


# ---------------------------------------------------------------------------
# compressed consensus: the comm subsystem's wrapper over any backend
# ---------------------------------------------------------------------------


class CompressedBackend(ConsensusBackend):
    """Lossy-compression wrapper around any ``ConsensusBackend`` — the
    ``repro.comm`` subsystem's hook into the consensus period.

    The wrapped period mixes the DECOMPRESSED server messages: ``mix``
    becomes ``inner.mix(D(C(W)))`` — mathematically what every receiver
    reconstructs from the on-wire payload — optionally with error feedback
    (``comm.error_feedback.ef_roundtrip``) whose per-server residual rides
    in ``dfl.DFLState.ef_residual``.  Because the T_S rounds are linear in
    the payloads, shipping each server's ONE compressed payload and letting
    it propagate T_S hops realises the whole period, so the on-wire cost is
    live-links x T_S x compressed-row bytes (``comm.accounting.
    BytesTracker``).  With the identity compressor (and a zero residual)
    every output is bitwise the inner backend's.

    The push-sum variant compresses the NUMERATOR only; the tiny ``(M,)``
    weight rides uncompressed (one f32 scalar per message, counted by the
    tracker).  Capability flags delegate to the inner backend, so the
    wrapper composes with einsum / blocked / collapsed / chebyshev /
    shard_map and both mixing modes."""

    compressed = True

    def __init__(self, inner: ConsensusBackend,
                 compressor: "_compressors.Compressor", *,
                 error_feedback: bool = True, flat_sharding=None):
        if getattr(inner, "compressed", False):
            raise ValueError("refusing to wrap an already-compressed "
                             "backend: double compression double-counts "
                             "wire bytes and compounds loss")
        self.inner = inner
        self.compressor = compressor
        self.error_feedback = error_feedback
        # NamedSharding of the flattened (M, d) leaf views under pjit —
        # same constraint (and same reason) as gossip_scan_blocked's
        self.flat_sharding = flat_sharding
        self.a_static = inner.a_static
        self.t_server = inner.t_server
        self.name = f"compressed[{inner.name}+{compressor.name}]"
        self.supports_traced = inner.supports_traced
        self.supports_directed = inner.supports_directed
        self.mesh_bound = inner.mesh_bound
        self.needs_spectral = inner.needs_spectral

    def _wire(self, tree: Any, residual: Optional[Any],
              key: Optional[jax.Array]):
        """Simulate the wire: (decompressed message tree, new residual)."""
        if residual is not None and self.error_feedback:
            return _ef.ef_roundtrip(self.compressor, tree, residual, key,
                                    flat_sharding=self.flat_sharding)
        return _compressors.roundtrip_tree(
            self.compressor, tree, key,
            flat_sharding=self.flat_sharding), residual

    # -- the EF-threading entry points the epoch step calls ------------------
    def mix_compressed(self, tree: Any, a_p: Optional[jax.Array] = None, *,
                       residual: Optional[Any] = None,
                       key: Optional[jax.Array] = None, lam2=None):
        """``(inner.mix of the wire-simulated tree, new EF residual)``."""
        msg, new_res = self._wire(tree, residual, key)
        return self.inner.mix(msg, a_p, lam2=lam2), new_res

    def mix_push_sum_compressed(self, state: PushSumState,
                                a_p: Optional[jax.Array] = None, *,
                                residual: Optional[Any] = None,
                                key: Optional[jax.Array] = None):
        msg, new_res = self._wire(state.values, residual, key)
        return self.inner.mix_push_sum(PushSumState(msg, state.weight),
                                       a_p), new_res

    # -- plain ConsensusBackend interface (no EF state threaded) -------------
    def mix(self, tree, a_p=None, lam2=None):
        return self.mix_compressed(tree, a_p, lam2=lam2)[0]

    def mix_push_sum(self, state, a_p=None):
        return self.mix_push_sum_compressed(state, a_p)[0]


BACKEND_MODES = ("gossip", "gossip_blocked", "collapsed", "chebyshev",
                 "exact_mean")


def make_backend(mode: str, a_static: Optional[np.ndarray], t_server: int, *,
                 chebyshev_rounds: Optional[int] = None,
                 gossip_flat_sharding=None,
                 block: int = 4_194_304,
                 compression: str = "none",
                 error_feedback: bool = False) -> ConsensusBackend:
    """Map a ``DFLConfig.consensus_mode`` string to a ``ConsensusBackend``.

    ``compression`` other than ``"none"`` (a ``comm.compressors.
    make_compressor`` spec, e.g. ``"int8"`` / ``"top_k:0.05"``) wraps the
    resolved backend in a ``CompressedBackend``, optionally with error
    feedback.  ``shard_map`` is absent on purpose: it needs a mesh and
    per-leaf PartitionSpecs, so the launcher builds it directly
    (``launch.sharding.fl_consensus_backend``, which applies the same
    compression wrap)."""
    if mode == "gossip":
        backend = GossipBackend(a_static, t_server)
    elif mode == "gossip_blocked":
        backend = BlockedGossipBackend(a_static, t_server, block=block,
                                       flat_sharding=gossip_flat_sharding)
    elif mode == "collapsed":
        backend = CollapsedBackend(a_static, t_server)
    elif mode == "chebyshev":
        backend = ChebyshevBackend(a_static, t_server,
                                   rounds=chebyshev_rounds)
    elif mode == "exact_mean":
        backend = ExactMeanBackend(a_static, t_server)
    else:
        raise ValueError(f"unknown consensus mode {mode!r}")
    if compression != "none":
        backend = CompressedBackend(
            backend, _compressors.make_compressor(compression),
            error_feedback=error_feedback,
            flat_sharding=gossip_flat_sharding)
    return backend
