"""Server-side consensus updates (Eq. 5/7) as JAX ops.

The parameter pytree during DFL training carries a leading *server* axis of
size M (possibly preceded by a client axis — see ``dfl.py``).  A consensus
round is ``W <- A W`` applied leaf-wise:

    new_w[i] = a_ii * w[i] + sum_{j in N_i} a_ij * w[j]      (Eq. 5)

Three execution strategies, all bit-identical in math:

* ``gossip_scan``    — the *faithful* schedule: T_S sequential rounds
                       (lax.fori_loop), each an einsum over the server axis.
                       Under pjit with the server axis sharded this lowers to
                       one all-gather (or neighbour exchanges) per round —
                       exactly the paper's per-iteration message pattern.
* ``gossip_collapsed`` — beyond-paper: precompute A_eff = A^{T_S} on the host
                       (M x M, trivial) and apply it in ONE round.  Output is
                       mathematically identical; collective rounds drop T_S x.
* ``gossip_chebyshev`` — beyond-paper: degree-k Chebyshev polynomial in A
                       reaching the same contraction with ~sqrt fewer rounds;
                       useful when rounds must stay iterative (fault probing
                       between rounds).

``ring_gossip_shard_map`` additionally shows the TPU-native neighbour
exchange (lax.ppermute) for ring graphs under shard_map.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

try:                                   # jax >= 0.6: public jax.shard_map
    _shard_map = jax.shard_map
    _CHECK_KW = "check_vma"
except AttributeError:                 # jax 0.4.x: experimental location
    from jax.experimental.shard_map import shard_map as _shard_map
    _CHECK_KW = "check_rep"


def shard_map_compat(f, mesh, in_specs, out_specs, check=None):
    """jax.shard_map across the 0.4.x -> 0.6 API move (the keyword for
    replication checking was renamed check_rep -> check_vma)."""
    kw = {} if check is None else {_CHECK_KW: check}
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **kw)


def _mix_leaf(a: jax.Array, leaf: jax.Array) -> jax.Array:
    """new[i] = sum_j a[i, j] * leaf[j, ...] over the leading server axis.

    Contracts in the LEAF's dtype: under pjit the server axis is sharded, so
    this lowers to an all-gather of (M x shard) — doing it in bf16 moves and
    holds half the bytes of the promoted-f32 contraction (A itself is tiny
    and cast down; one bf16 rounding per round matches what real multi-host
    gossip over bf16 wires would do)."""
    return jnp.tensordot(a.astype(leaf.dtype), leaf, axes=([1], [0]))


def mix_pytree(a: jax.Array, tree: Any) -> Any:
    """One consensus round ``W <- A W`` applied to every leaf."""
    return jax.tree.map(functools.partial(_mix_leaf, a), tree)


def gossip_scan(a: jax.Array, tree: Any, t_server: int) -> Any:
    """Faithful T_S-round consensus (Alg. 1 server loop).

    One fori_loop PER LEAF (leaves gossip independently, so round-leaf
    reordering is exact): XLA schedules the per-leaf while-loops one after
    another, keeping only one leaf's (M x shard) all-gather live at a time
    instead of the whole model's."""
    if t_server == 0:
        return tree

    def leaf_loop(leaf):
        return jax.lax.fori_loop(
            0, t_server, lambda _, w: _mix_leaf(a, w), leaf)

    return jax.tree.map(leaf_loop, tree)


def gossip_scan_tv(a_rounds: jax.Array, tree: Any) -> Any:
    """Time-varying consensus: round t applies ``a_rounds[t]``.

    ``a_rounds`` layout — a traced ``(T_S, M, M)`` stack with one mixing
    matrix PER ROUND, not per epoch: ``a_rounds[t]`` is the operator of
    consensus round ``t`` within a single consensus period, so the leading
    axis is the round index and its length is this period's T_S.  This is
    the fully general form of Eq. 5 where the server graph may change
    BETWEEN ROUNDS (link failures mid-consensus, straggler reweighting).
    Contrast ``schedule.TopologySchedule``, which emits ONE ``(M, M)``
    matrix per epoch ``A_p``; to feed such a per-epoch matrix here,
    broadcast it to ``(T_S, M, M)`` — a stack of T_S identical matrices is
    exactly ``gossip_scan(a, tree, T_S)`` (same per-round operator, same
    ordering).  Each round preserves the server mean when every
    ``a_rounds[t]`` is doubly stochastic, and the ordered product of the
    stack governs the contraction (``topology.sigma_product`` with t_s=1
    per entry)."""
    if a_rounds.shape[0] == 0:
        return tree

    def leaf_loop(leaf):
        return jax.lax.fori_loop(
            0, a_rounds.shape[0],
            lambda i, w: _mix_leaf(a_rounds[i], w), leaf)

    return jax.tree.map(leaf_loop, tree)


def gossip_scan_blocked(a: jax.Array, tree: Any, t_server: int,
                        block: int = 4_194_304,
                        flat_sharding=None) -> Any:
    """Faithful T_S-round gossip, streamed over fixed-size parameter blocks.

    Blocks gossip independently, so iterating (block-major, round-minor)
    instead of (round-major, leaf-minor) is *exactly* the same operator —
    but the live working set per step is one (M, block) gather instead of a
    full parameter leaf per server (which at 27B+ scales is multi-GB per
    in-flight leaf; XLA-CPU additionally upcasts bf16 contractions to f32,
    doubling it).  Used by the epoch step whenever the model is large;
    ``gossip_scan`` remains the reference for tests and small models.
    """
    if t_server == 0:
        return tree
    leaves, treedef = jax.tree.flatten(tree)
    m = leaves[0].shape[0]
    dtype = leaves[0].dtype
    sizes = [l[0].size for l in leaves]
    flat = jnp.concatenate([l.reshape(m, -1) for l in leaves], axis=1)
    d = flat.shape[1]
    nb = max(1, -(-d // block))
    pad = nb * block - d
    if pad:
        flat = jnp.pad(flat, ((0, 0), (0, pad)))
    if flat_sharding is not None:
        # keep the flattened model sharded over the intra-client axes —
        # without this the concat of heterogeneously-sharded leaves makes
        # the partitioner replicate the whole model per device.
        flat = jax.lax.with_sharding_constraint(flat, flat_sharding)
    blocks = jnp.moveaxis(flat.reshape(m, nb, block), 1, 0)   # (nb, M, blk)
    a_cast = a.astype(dtype)

    def per_block(_, blk):
        out = jax.lax.fori_loop(
            0, t_server, lambda _i, w: jnp.tensordot(a_cast, w,
                                                     axes=([1], [0])), blk)
        return None, out

    _, mixed = jax.lax.scan(per_block, None, blocks)
    flat = jnp.moveaxis(mixed, 0, 1).reshape(m, nb * block)[:, :d]
    if flat_sharding is not None:
        flat = jax.lax.with_sharding_constraint(flat, flat_sharding)
    out, off = [], 0
    new_leaves = []
    for leaf, size in zip(leaves, sizes):
        new_leaves.append(flat[:, off:off + size].reshape(leaf.shape))
        off += size
    return jax.tree.unflatten(treedef, new_leaves)


# ---------------------------------------------------------------------------
# push-sum (ratio) consensus for DIRECTED server graphs
#
# When link failures make the graph directed, no doubly-stochastic matrix
# may exist on its support (Eq. 6 is unsatisfiable): the best a node can do
# locally is split its mass over its out-neighbours — a ROW-stochastic A
# (topology.out_degree_weights).  Naive gossip with such an A converges to
# the Perron-weighted average pi' W (pi the left Perron vector of A), a
# BIASED aggregate.  Push-sum / ratio consensus (Kempe et al. 2003;
# Nedic & Olshevsky 2015) fixes this by mixing a numerator AND a scalar
# weight with the column-stochastic transpose P = A' and reading out the
# ratio:
#
#     num <- P num,   w <- P w,     z_i = num_i / w_i
#
# P column-stochastic preserves both sums (sum num = sum W_0, sum w = M),
# and P^t -> v 1' (sum v = 1), so num -> v * sum(W_0), w -> v * M and every
# ratio z_i -> the exact uniform mean — the skew v cancels.  Operationally
# each round IS the row-stochastic protocol run in push mode: node i sends
# a[i, j]-weighted shares of its (num, w) along its OUT-edges; P = A' is
# just that send pattern written as a matrix acting on the receive side.
# When A is doubly stochastic, P = A' is row-stochastic too, w stays at 1
# identically and push-sum degenerates to plain gossip.
# ---------------------------------------------------------------------------


class PushSumState(NamedTuple):
    """Numerator pytree (leaves ``(M, *w)``) + per-server scalar weight
    ``(M,)``.  Invariants under mixing: weights stay positive and sum to M;
    ``ratio()`` of a freshly-initialised state is the values themselves."""

    values: Any          # numerator pytree, leading server axis M
    weight: jax.Array    # (M,) float, > 0, sum == M

    def ratio(self) -> Any:
        """The unbiased read-out z_i = num_i / w_i, broadcast leaf-wise."""
        return jax.tree.map(
            lambda v: v / self.weight.reshape(
                (-1,) + (1,) * (v.ndim - 1)).astype(v.dtype),
            self.values)


def init_push_sum(tree: Any) -> PushSumState:
    """Start of a consensus period: numerator = the server models, weight =
    1 for every server.  Weights RESET here each period by design: with a
    persistent weight the finite-round ratio is no longer exact on
    consensus states (P^t(c*1)/P^t(1) == c for all t only when num and w
    start aligned), and re-weighting the numerator by a carried weight
    provably re-introduces the Perron bias — see docs/dynamic_federation.md."""
    m = jax.tree.leaves(tree)[0].shape[0]
    return PushSumState(tree, jnp.ones((m,), jnp.float32))


def _push_leaf(p: jax.Array, leaf: jax.Array) -> jax.Array:
    return jnp.tensordot(p.astype(leaf.dtype), leaf, axes=([1], [0]))


def gossip_push_sum(a: jax.Array, state: PushSumState,
                    t_server: int) -> PushSumState:
    """T_S rounds of push-sum over a ROW-stochastic ``a`` (shape (M, M),
    support = directed graph + self-loops, e.g. topology.out_degree_weights).

    Numerator and weight are mixed with the same column-stochastic operator
    ``P = a.T``; they interact only at read-out (``.ratio()``), so each leaf
    loops independently exactly like ``gossip_scan``.  The weight recursion
    is a tiny (M,) matvec and costs nothing next to the parameter leaves."""
    if t_server == 0:
        return state
    p = a.T

    def leaf_loop(leaf):
        return jax.lax.fori_loop(
            0, t_server, lambda _, w: _push_leaf(p, w), leaf)

    values = jax.tree.map(leaf_loop, state.values)
    weight = jax.lax.fori_loop(
        0, t_server, lambda _, w: (p @ w.astype(p.dtype)).astype(w.dtype),
        state.weight)
    return PushSumState(values, weight)


def gossip_push_sum_tv(a_rounds: jax.Array,
                       state: PushSumState) -> PushSumState:
    """Time-varying push-sum: round t mixes with ``a_rounds[t].T``.

    ``a_rounds`` follows the ``gossip_scan_tv`` layout — a traced
    ``(T_S, M, M)`` stack of ROW-stochastic matrices, one per round.  Every
    round preserves sum(num) and sum(w) (each transpose is column
    stochastic), so the ratio read-out stays unbiased under arbitrary
    per-round graph changes as long as the sequence is jointly strongly
    connected."""
    if a_rounds.shape[0] == 0:
        return state

    def leaf_loop(leaf):
        return jax.lax.fori_loop(
            0, a_rounds.shape[0],
            lambda i, w: _push_leaf(a_rounds[i].T, w), leaf)

    values = jax.tree.map(leaf_loop, state.values)
    weight = jax.lax.fori_loop(
        0, a_rounds.shape[0],
        lambda i, w: (a_rounds[i].T @ w.astype(a_rounds.dtype)).astype(w.dtype),
        state.weight)
    return PushSumState(values, weight)


def collapse_mixing(a: np.ndarray, t_server: int) -> np.ndarray:
    """A_eff = A^{T_S} (host-side, float64). Doubly stochastic by closure."""
    return np.linalg.matrix_power(np.asarray(a, dtype=np.float64), t_server)


def gossip_collapsed(a_eff: jax.Array, tree: Any) -> Any:
    """Single-round application of the collapsed operator A^{T_S}."""
    return mix_pytree(a_eff, tree)


# ---------------------------------------------------------------------------
# Chebyshev-accelerated gossip (beyond-paper)
# ---------------------------------------------------------------------------


def chebyshev_coefficients(a: np.ndarray, rounds: int) -> float:
    """Return the contraction sigma achieved by ``rounds`` Chebyshev steps
    (for reporting).  Uses lambda_2 of the symmetric mixing matrix."""
    ev = np.sort(np.abs(np.linalg.eigvalsh(a)))[::-1]
    lam2 = ev[1] if len(ev) > 1 else 0.0
    if lam2 == 0.0:
        return 0.0
    # |T_k(1/lam2)|^{-1} with T_k the Chebyshev polynomial of the first kind
    x = 1.0 / lam2
    return float(1.0 / np.cosh(rounds * np.arccosh(x)))


def gossip_chebyshev(a: jax.Array, tree: Any, rounds: int, lam2: float) -> Any:
    """Chebyshev semi-iterative consensus:  w_k = 2 c_k/(lam2 c_{k+1}) A w_{k-1}
    - (c_{k-1}/c_{k+1}) w_{k-2}, with c_k = cosh(k acosh(1/lam2)).

    Reaches sigma ~ 2 rho^k (rho = (1-sqrt(1-lam2^2))/lam2) instead of lam2^k:
    ~sqrt(1/(1-lam2)) fewer rounds for the same contraction.  Exactly
    mean-preserving like plain gossip (each update is an affine combination
    of doubly-stochastic operators with coefficients summing to 1).
    """
    if rounds == 0:
        return tree
    if lam2 <= 0.0:
        return mix_pytree(a, tree)
    x = 1.0 / lam2
    c_prev, c_cur = 1.0, x  # c_0, c_1

    w_prev = tree
    w_cur = mix_pytree(a, tree)  # k = 1 step: T_1(x A / 1) -> A w  scaled below
    # first step of the semi-iteration is just A w (coefficients work out)
    for _ in range(1, rounds):
        c_next = 2.0 * x * c_cur - c_prev
        alpha = 2.0 * x * c_cur / c_next
        beta = c_prev / c_next
        mixed = mix_pytree(a, w_cur)
        w_next = jax.tree.map(
            lambda m, p: (alpha * m - beta * p).astype(m.dtype), mixed, w_prev)
        w_prev, w_cur = w_cur, w_next
        c_prev, c_cur = c_cur, c_next
    return w_cur


# ---------------------------------------------------------------------------
# shard_map gossip: fully-manual blocked server gossip (the production path)
# ---------------------------------------------------------------------------


def make_gossip_shard_map(mesh, a_np: np.ndarray, t_server: int,
                          leaf_specs: Any, *, axis_name: str = "server",
                          block: int = 16_777_216) -> Callable:
    """T_S-round gossip as an explicit shard_map program.

    Inside the shard_map every device flattens its LOCAL weight shards into
    one vector and scans over fixed ``block``-element slices; each slice
    runs the full T_S-round loop (blocks gossip independently, so
    block-major iteration is the identical operator).  Per-round transfer
    is one bf16 all-gather of (M, block) over the server axis — memory is
    deterministic (~(M+2) x block x 2 bytes live) and dtype is under our
    control, unlike the pjit einsum form where XLA-CPU upcasts the
    contraction operand to f32 *before* the gather and overlaps per-leaf
    loops (~12 GB of f32 gathers at 27B scale).

    ``leaf_specs``: PartitionSpec pytree of the server tree (leading
    'server' axis + intra-client weight axes) — used as in_specs and
    out_specs.
    """
    m = a_np.shape[0]
    a = jnp.asarray(a_np, jnp.float32)

    def body(tree):
        idx = jax.lax.axis_index(axis_name)
        row = a[idx]                                     # (M,) my weights
        leaves, treedef = jax.tree.flatten(tree)
        dtype = leaves[0].dtype
        # Wire-format control: carry the gossip stream as u16 bit-patterns
        # of the bf16 payload.  Integer buffers are exempt from XLA-CPU's
        # float-normalization pass, which otherwise upcasts every
        # loop-carried bf16 buffer to f32 — a 2x params-sized artifact this
        # container's backend would report that a TPU (native bf16) never
        # allocates.  On TPU the bitcasts are free view changes.
        wire = jnp.uint16 if dtype == jnp.bfloat16 else None

        def to_wire(x):
            return jax.lax.bitcast_convert_type(x, wire) if wire else x

        def from_wire(x):
            return (jax.lax.bitcast_convert_type(x, jnp.bfloat16)
                    if wire else x)

        def round_fn(_i, w):
            g = from_wire(jax.lax.all_gather(w, axis_name))      # (M, blk)
            # unrolled mul-adds (M is tiny); f32 accumulate per block
            acc = row[0] * g[0].astype(jnp.float32)
            for j in range(1, m):
                acc = acc + row[j] * g[j].astype(jnp.float32)
            return to_wire(acc.astype(dtype))

        def gossip_leaf(flat):
            """Blocked in-place gossip over one flattened (wire) leaf."""
            d = flat.size
            blk = min(block, d)
            nb = -(-d // blk)
            if nb * blk != d:
                flat = jnp.pad(flat, (0, nb * blk - d))
            if nb == 1:
                return jax.lax.fori_loop(0, t_server, round_fn, flat)[:d]

            def per_block(i, buf):
                w = jax.lax.dynamic_slice(buf, (i * blk,), (blk,))
                w = jax.lax.fori_loop(0, t_server, round_fn, w)
                return jax.lax.dynamic_update_slice(buf, w, (i * blk,))

            return jax.lax.fori_loop(0, nb, per_block, flat)[:d]

        # Per-leaf loops CHAINED via optimization_barrier: leaves gossip
        # independently, so XLA would otherwise schedule their while-loops
        # concurrently and hold every leaf's wire buffers at once; the
        # token dependency forces one leaf in flight at a time.
        new_leaves = []
        token = None
        for leaf in leaves:
            wl = to_wire(leaf.astype(dtype)).reshape(-1)
            if token is not None:
                wl, token = jax.lax.optimization_barrier((wl, token))
            out = gossip_leaf(wl)
            token = out[0]
            new_leaves.append(
                from_wire(out).astype(leaf.dtype).reshape(leaf.shape))
        return jax.tree.unflatten(treedef, new_leaves)

    return shard_map_compat(body, mesh, (leaf_specs,), leaf_specs,
                            check=False)


# ---------------------------------------------------------------------------
# shard_map ring gossip: explicit neighbour exchange over ICI
# ---------------------------------------------------------------------------


def ring_gossip_step(w: jax.Array, *, axis_name: str, self_weight: float,
                     neighbor_weight: float) -> jax.Array:
    """One gossip round on a ring graph executed INSIDE shard_map: each server
    shard receives its two ring neighbours via collective_permute — the
    literal 'server communicates with neighbours' of Alg. 1, mapped onto the
    physical ICI ring."""
    m = jax.lax.psum(1, axis_name)
    fwd = [(i, (i + 1) % m) for i in range(m)]
    bwd = [((i + 1) % m, i) for i in range(m)]
    left = jax.lax.ppermute(w, axis_name, perm=fwd)
    right = jax.lax.ppermute(w, axis_name, perm=bwd)
    return (self_weight * w + neighbor_weight * (left + right)).astype(w.dtype)


def make_ring_gossip(mesh: jax.sharding.Mesh, axis_name: str, t_server: int,
                     self_weight: float, neighbor_weight: float) -> Callable:
    """Build a shard_map'd T_S-round ring gossip over ``axis_name``.

    The input pytree must have its leading (server) axis sharded over
    ``axis_name``; other axes pass through unchanged.
    """
    from jax.sharding import PartitionSpec as P

    def per_shard(tree):
        def body(_, w):
            return jax.tree.map(
                lambda x: ring_gossip_step(
                    x, axis_name=axis_name, self_weight=self_weight,
                    neighbor_weight=neighbor_weight),
                w)
        return jax.lax.fori_loop(0, t_server, body, tree)

    def spec_for(tree):
        return jax.tree.map(lambda x: P(axis_name, *([None] * (x.ndim - 1))), tree)

    def run(tree):
        specs = spec_for(tree)
        return shard_map_compat(per_shard, mesh, (specs,), specs)(tree)

    return run
