"""Overlapped execution of Algorithm 1: the superepoch megastep.

The barrier engine (``engine.DynamicFederationEngine.run_epoch``) dispatches
ONE compiled epoch step per epoch and immediately blocks on host-side metric
readbacks, so every epoch costs a full host round trip: dispatch latency,
a device->host transfer, and Python schedule generation all serialize in
front of the next epoch's compute.  At the paper's scales the per-epoch
device work is small enough that this host loop — not FLOPs — dominates
wall clock.

This module removes the barrier without changing a single bit of the math:

* ``build_dfl_superepoch_step`` wraps the UNCHANGED dynamic epoch step
  (``dfl.build_dfl_epoch_step``) in a ``jax.lax.scan`` over ``K`` epochs,
  so one compiled program runs K full cycles of Algorithm 1 and the host
  loop runs once per K epochs.  The scan body IS the per-epoch program —
  same operands, same order — so the K-epoch history is exactly the
  barrier engine's (asserted element-bitwise in ``tests/test_overlap.py``).
* ``EpochScheduleBatch`` is the stacked traced operand: the K per-epoch
  ``schedule.EpochSchedule`` tuples pre-materialized host-side and stacked
  along a leading K axis (``(K, M, N)`` masks, ``(K, M, M)`` mixing
  matrices, ``(K,)`` lam2, ``(K, M)`` byzantine codes), which the scan
  slices one epoch at a time.  ``stack_epoch_schedules`` builds it.
* ``DFLMetrics`` comes back STACKED (leading K axis on every leaf) plus a
  per-epoch ``(K, M)`` push-sum weight trace, so the engine reads the
  whole block back in ONE ``jax.device_get`` instead of 2K+ blocking
  scalar transfers.

Staleness (``dfl.DFLConfig.staleness``) composes orthogonally: it lives
INSIDE the consensus period (``consensus.gossip_scan_stale`` / the
software-pipelined wire bodies), so the scan body picks it up through the
ordinary epoch step — the superepoch overlaps epochs against the host,
bounded staleness overlaps gossip rounds against each other.

Host-side schedule generation (participation masks, per-epoch mixing
matrices, fault surgery, byzantine codes) stays on the host: the engine
pre-materializes one K-block of operands per dispatch and splits blocks at
fault epochs, where array shapes change (``engine.DynamicFederationEngine
.run`` with ``superepoch > 1``).
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.core import dfl
from repro.core.schedule import EpochSchedule
from repro.optim import Optimizer


class EpochScheduleBatch(NamedTuple):
    """K stacked ``schedule.EpochSchedule`` operands — the traced input of
    one superepoch dispatch.  Field-for-field the per-epoch tuple with a
    leading K axis; ``lam2``/``byz`` are ``None`` exactly when the
    per-epoch schedules carry ``None`` (the scan then passes the empty
    pytree node through and the compiled step contains no code for it,
    matching the barrier engine's operand structure).

    ``mask``:   (K, M, N) float32 participation masks.
    ``mixing``: (K, M, M) float32 mixing matrices A_p.
    ``lam2``:   optional (K,) float32 per-epoch spectral estimates.
    ``byz``:    optional (K, M) int32 per-epoch attack codes.
    """

    mask: Any
    mixing: Any
    lam2: Optional[Any] = None
    byz: Optional[Any] = None

    @property
    def k(self) -> int:
        return int(self.mask.shape[0])


def stack_epoch_schedules(
        scheds: Sequence[EpochSchedule]) -> EpochScheduleBatch:
    """Stack K per-epoch ``EpochSchedule`` tuples (host-side numpy) into
    one ``EpochScheduleBatch``.  Optional fields must be all-``None`` or
    all-present across the block — a mixed block would change the compiled
    step's operand structure mid-scan."""
    if not scheds:
        raise ValueError("cannot stack an empty schedule block")
    for field in ("lam2", "byz"):
        vals = [getattr(s, field) for s in scheds]
        if any(v is None for v in vals) and not all(v is None for v in vals):
            raise ValueError(
                f"EpochSchedule.{field} is set for some epochs of the block "
                f"but not others — one compiled superepoch program needs a "
                f"uniform operand structure")
    return EpochScheduleBatch(
        mask=np.stack([np.asarray(s.mask, np.float32) for s in scheds]),
        mixing=np.stack([np.asarray(s.mixing, np.float32) for s in scheds]),
        lam2=(None if scheds[0].lam2 is None else
              np.stack([np.asarray(s.lam2, np.float32) for s in scheds])),
        byz=(None if scheds[0].byz is None else
             np.stack([np.asarray(s.byz, np.int32) for s in scheds])))


def build_dfl_superepoch_step(
    cfg: dfl.DFLConfig,
    loss_fn: dfl.LossFn,
    optimizer: Optimizer,
    k: int,
) -> Callable[[dfl.DFLState, Any, EpochScheduleBatch],
              Tuple[dfl.DFLState, dfl.DFLMetrics, Optional[jax.Array]]]:
    """Return ``superepoch_step(state, batches, sched_batch) -> (state,
    stacked_metrics, psum_weights)``: K epochs of Algorithm 1 fused into
    one compiled program via ``jax.lax.scan`` over the UNCHANGED dynamic
    epoch step.

    ``batches`` leaves are ``(K, T_C, M, N, *per_client_batch)`` — the
    per-epoch batch pytrees stacked along a leading K axis; ``sched_batch``
    is the matching ``EpochScheduleBatch``.  ``stacked_metrics`` is
    ``dfl.DFLMetrics`` with a leading K axis on every leaf;
    ``psum_weights`` is the ``(K, M)`` per-epoch terminal push-sum weight
    trace under ``mixing='push_sum'`` (the end-state only keeps the LAST
    epoch's weight — the engine needs every epoch's for its
    ``psum_min_weight`` history column), ``None`` otherwise.

    K=1 is the degenerate superepoch: a scan of length 1 around the very
    program the barrier engine jits, bitwise-identical history (the
    K∈{1,2,4} parity tests in ``tests/test_overlap.py``).  Like the epoch
    step, the returned function is NOT jitted — the engine wraps it with
    donation (``donate_argnums=(0,)``), cached per (M, K)."""
    if k < 1:
        raise ValueError(f"superepoch length must be >= 1, got {k}")
    if not cfg.dynamic:
        # the superepoch exists to amortize the dynamic engine's host loop;
        # its scan body consumes the EpochSchedule operand, so the static
        # step (no schedule argument) has nothing to batch
        raise ValueError("build_dfl_superepoch_step needs "
                         "DFLConfig(dynamic=True) — the scan body consumes "
                         "per-epoch EpochSchedule operands")
    epoch_step = dfl.build_dfl_epoch_step(cfg, loss_fn, optimizer)

    def superepoch_step(state: dfl.DFLState, batches: Any,
                        sched_batch: EpochScheduleBatch):
        def body(st, operands):
            bt, sb = operands
            st, metrics = epoch_step(
                st, bt, EpochSchedule(sb.mask, sb.mixing, sb.lam2, sb.byz))
            # ys carry the per-epoch terminal push-sum weight alongside the
            # metrics: the carried state only retains epoch K-1's weight,
            # but the engine's psum_min_weight history column is per-epoch
            return st, (metrics, st.psum_weight)

        state, (metrics, psw) = jax.lax.scan(
            body, state, (batches, sched_batch), length=k)
        return state, metrics, psw

    return superepoch_step
