"""Dynamic-federation schedules: who participates, over which graph, and
which servers fail when.

The paper's Algorithm 1 is *static*: all M·N clients train every epoch over
one fixed connected server graph.  Its headline claims — scalability and
fault-tolerance — only become testable scenarios once participation and
topology can change mid-run.  This module provides the host-side scenario
generators; `dfl.build_dfl_epoch_step(dynamic=True)` consumes their output
as traced operands so ONE compiled epoch step covers every scenario of a
given shape:

* ``ParticipationSchedule`` — a per-epoch ``(M, N)`` 0/1 mask.  Eq. 4
  becomes a masked, weight-renormalised mean (see ``dfl.masked_server_mean``)
  and non-participants carry their broadcast model forward unchanged.
* ``TopologySchedule``    — a per-epoch mixing matrix ``A_p`` (edge
  drop/add, straggler-weakened links), always doubly stochastic, fed as a
  traced operand to gossip.  ``SigmaTracker`` accumulates the host-side
  product contraction ``||prod_p A_p^{T_S} - 11'/M||_2`` (Lemma 1's sigma_A
  generalised to time-varying graphs).
* ``FaultSchedule``       — scheduled server failure/rejoin events, executed
  between epochs via ``FLTopology.drop_server`` / ``rejoin_server`` graph
  surgery (shapes change, so these live on the host; see ``engine.py``).
* ``ByzantineSchedule``   — per-epoch ADVERSARIAL server sets: which servers
  replace their Eq.-4 aggregate with an attack (sign flip, scaled noise,
  inlier-shift collusion) before gossip.  The schedule marks attackers on
  the host (``codes``); the attack itself is the pure traced function
  ``dfl.apply_byzantine`` on the pre-gossip server tree, defended by the
  robust consensus backends (``consensus.TrimmedMeanBackend`` & co).
* trace-driven participation — ``ParticipationSchedule(kind="trace")``
  replays an explicit ``(E, M, N)`` 0/1 availability trace verbatim
  (diurnal cycles, correlated churn — everything i.i.d. Bernoulli masks
  cannot express), or interprets a float trace as per-epoch per-client
  Bernoulli RATES (fleet telemetry exports probabilities, not outcomes).
  ``diurnal_trace`` synthesises one; ``save_participation_trace`` /
  ``load_participation_trace`` round-trip either through a JSONL
  availability log bitwise.

All sampling is deterministic in ``(seed, epoch)`` so runs are reproducible
and a schedule can be replayed or sliced without storing mask traces.
"""
from __future__ import annotations

import dataclasses
import json
from typing import NamedTuple, Optional, Tuple

import numpy as np

from repro.core import topology as tp
from repro.core.topology import FLTopology


class EpochSchedule(NamedTuple):
    """The traced per-epoch operands of a dynamic epoch step.

    ``mask``:   (M, N) float32 0/1 participation mask.
    ``mixing``: (M, M) float32 doubly-stochastic mixing matrix A_p.
    ``lam2``:   optional scalar |lambda_2(A_p)| — the host-side per-epoch
                spectral estimate (``topology.lambda_2``) that spectral
                consensus backends (``consensus.ChebyshevBackend``) consume
                alongside the traced matrix; ``None`` for every other
                backend (the engine only computes it when asked for).
    ``byz``:    optional (M,) int32 per-server attack codes for this epoch
                (0 = honest, k+1 = ``ByzantineSchedule.attacks[k]``), in
                CURRENT row order (original attacker ids mapped through the
                engine's alive list, so surgery and attacks compose).
                ``None`` whenever no ``ByzantineSchedule`` is configured —
                the compiled step then contains no injection code at all.
    """

    mask: np.ndarray
    mixing: np.ndarray
    lam2: Optional[np.ndarray] = None
    byz: Optional[np.ndarray] = None


# ---------------------------------------------------------------------------
# participation
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ParticipationSchedule:
    """Per-epoch client participation masks.

    kinds:
      ``full``        every client every epoch (the paper's setting).
      ``bernoulli``   each client participates independently w.p. ``rate``.
      ``fixed_k``     exactly ``k`` uniformly-sampled clients per server.
      ``round_robin`` deterministic rotation of ``k`` clients per server —
                      the scheduling-policy baseline of Abdelghany et al.
      ``trace``       replay an explicit ``(E, M, N)`` availability trace
                      (epoch ``p`` uses row ``p mod E``).  A 0/1 trace is
                      replayed VERBATIM — diurnal cycles and correlated
                      churn instead of i.i.d. masks.  A trace with ANY
                      fractional entry in [0, 1] is instead a per-epoch
                      per-client sampling-RATE schedule: epoch ``p`` draws
                      ``mask[i, j] ~ Bernoulli(trace[p mod E, i, j])``,
                      deterministic in ``(seed, epoch)`` — logged
                      availability PROBABILITIES (fleet telemetry exports
                      rates, not outcomes) drive participation directly.
                      Either way the trace is authoritative: no
                      min_per_server top-up is applied (a replayed 0/1 log
                      must reproduce bitwise —
                      ``load_participation_trace`` round-trip; a rate row
                      must realise its exact Bernoulli law), so a
                      fully-idle server simply carries its model.

    ``min_per_server`` forces at least that many participants per server
    (sampled uniformly from the idle ones) so the masked Eq. 4 mean stays
    well-defined; set it to 0 to allow fully-idle servers, which then simply
    carry their model through the epoch.
    """

    kind: str = "full"
    rate: float = 1.0
    k: Optional[int] = None
    min_per_server: int = 1
    seed: int = 0
    # the (E, M, N) availability trace of kind="trace" — excluded from
    # eq/hash (ndarray __eq__ is elementwise and would break the frozen
    # dataclass contract) and from repr (it can be thousands of epochs)
    trace: Optional[np.ndarray] = dataclasses.field(
        default=None, repr=False, compare=False)

    def __post_init__(self):
        if self.kind not in ("full", "bernoulli", "fixed_k", "round_robin",
                             "trace"):
            raise ValueError(f"unknown participation kind {self.kind!r}")
        if self.kind == "bernoulli" and not 0.0 <= self.rate <= 1.0:
            raise ValueError("rate must be in [0, 1]")
        if self.kind in ("fixed_k", "round_robin") and not self.k:
            raise ValueError(f"kind={self.kind!r} needs k >= 1")
        if self.kind == "trace":
            if self.trace is None:
                raise ValueError("kind='trace' needs a trace array — "
                                 "generate one with diurnal_trace or load "
                                 "a log with load_participation_trace")
            t = np.asarray(self.trace)
            if t.ndim != 3 or t.shape[0] < 1:
                raise ValueError(f"trace must be (E, M, N) with E >= 1, "
                                 f"got shape {t.shape}")
            if t.min() < 0.0 or t.max() > 1.0:
                raise ValueError(
                    "trace entries must be 0/1 availability or Bernoulli "
                    "rates in [0, 1]")
        elif self.trace is not None:
            raise ValueError(f"kind={self.kind!r} does not take a trace")

    def mask(self, epoch: int, m: int, n: int) -> np.ndarray:
        """(M, N) float32 0/1 mask for ``epoch`` — deterministic in
        (seed, epoch), independent of call order."""
        if self.kind == "full":
            return np.ones((m, n), np.float32)
        if self.kind == "trace":
            t = np.asarray(self.trace)
            if t.shape[1:] != (m, n):
                raise ValueError(
                    f"participation trace is shaped for a "
                    f"({t.shape[1]}, {t.shape[2]}) federation but this run "
                    f"has (M, N) = ({m}, {n}) — traces replay availability "
                    f"of SPECIFIC clients and cannot be resized")
            row = t[epoch % t.shape[0]]
            if np.isin(t, (0, 1)).all():
                # binary availability log: replayed verbatim (bitwise)
                return row.astype(np.float32)
            # sampling-RATE trace: per-client Bernoulli draw against this
            # epoch's rate row, deterministic in (seed, epoch) like every
            # other sampled kind
            rng = np.random.default_rng((self.seed, epoch))
            return (rng.random((m, n)) < np.asarray(row, np.float64)
                    ).astype(np.float32)
        rng = np.random.default_rng((self.seed, epoch))
        if self.kind == "bernoulli":
            mask = (rng.random((m, n)) < self.rate)
        elif self.kind == "fixed_k":
            k = min(self.k, n)
            mask = np.zeros((m, n), bool)
            for i in range(m):
                mask[i, rng.choice(n, size=k, replace=False)] = True
        else:  # round_robin
            k = min(self.k, n)
            cols = (epoch * k + np.arange(k)) % n
            mask = np.zeros((m, n), bool)
            mask[:, cols] = True
        need = min(self.min_per_server, n)
        for i in range(m):
            short = need - int(mask[i].sum())
            if short > 0:
                idle = np.nonzero(~mask[i])[0]
                mask[i, rng.choice(idle, size=short, replace=False)] = True
        return mask.astype(np.float32)

    def expected_rate(self, n: int) -> float:
        """Mean fraction of participating clients (for reporting).  For
        kind='trace' this is EXACT — the empirical mean of a replayed 0/1
        trace, and the exact Bernoulli expectation (mean of the rates) of
        a sampling-rate trace — since the trace is authoritative (no
        top-up)."""
        if self.kind == "full":
            return 1.0
        if self.kind == "trace":
            return float(np.asarray(self.trace, np.float64).mean())
        if self.kind == "bernoulli":
            return max(self.rate, self.min_per_server / n)
        return min(self.k, n) / n


def diurnal_trace(epochs: int, m: int, n: int, *, period: int = 24,
                  base: float = 0.6, amplitude: float = 0.4,
                  min_per_server: int = 1, seed: int = 0) -> np.ndarray:
    """Synthesise an ``(epochs, M, N)`` uint8 availability trace with a
    diurnal cycle: server ``i``'s clients are available w.p.
    ``clip(base + amplitude * sin(2 pi (p + phase_i) / period), 0, 1)`` at
    epoch ``p``, with a uniformly-random per-server phase — correlated
    within a server (its whole fleet sees the same local time-of-day) and
    staggered across servers (time zones), the two structures i.i.d.
    Bernoulli masks cannot express.  ``min_per_server`` participants are
    topped up deterministically HERE, at generation time, so the emitted
    trace is replayable verbatim (``ParticipationSchedule(kind='trace')``
    applies no further top-up)."""
    if epochs < 1 or m < 1 or n < 1:
        raise ValueError("diurnal_trace needs epochs, m, n >= 1")
    rng = np.random.default_rng((seed, 0))
    phase = rng.uniform(0.0, period, size=m)
    trace = np.zeros((epochs, m, n), np.uint8)
    need = min(min_per_server, n)
    for p in range(epochs):
        rate = np.clip(base + amplitude
                       * np.sin(2.0 * np.pi * (p + phase) / period),
                       0.0, 1.0)                              # (M,)
        row = rng.random((m, n)) < rate[:, None]
        for i in range(m):
            short = need - int(row[i].sum())
            if short > 0:
                idle = np.nonzero(~row[i])[0]
                row[i, rng.choice(idle, size=short, replace=False)] = True
        trace[p] = row
    return trace


def save_participation_trace(path: str, trace: np.ndarray) -> None:
    """Write an availability trace as a JSONL log: one line per epoch,
    ``{"epoch": p, "mask": [[0/1 x N] x M]}`` — the interchange format for
    replaying real fleet availability logs through
    ``ParticipationSchedule(kind="trace")``.  A 0/1 trace serialises as
    integer lists (the original format, byte-stable); a sampling-RATE
    trace (any fractional entry) serialises its rates as f32-exact floats,
    so the round trip through ``load_participation_trace`` reproduces the
    float32 rates bitwise."""
    t = np.asarray(trace)
    if t.ndim != 3:
        raise ValueError(f"trace must be (E, M, N), got shape {t.shape}")
    binary = np.isin(t, (0, 1)).all()
    with open(path, "w") as f:
        for p in range(t.shape[0]):
            row = (t[p].astype(int) if binary
                   else t[p].astype(np.float32)).tolist()
            f.write(json.dumps({"epoch": p, "mask": row}) + "\n")


def load_participation_trace(path: str) -> np.ndarray:
    """Read a JSONL availability log back into an ``(E, M, N)`` trace —
    uint8 for a 0/1 availability log, float32 for a sampling-rate log
    (any fractional entry; see ``ParticipationSchedule`` kind='trace').
    Lines must cover epochs 0..E-1 contiguously and in order (a replayed
    log with a hole would silently shift every later epoch), and every
    mask must share one (M, N) shape."""
    rows = []
    with open(path) as f:
        for lineno, line in enumerate(filter(str.strip, f)):
            rec = json.loads(line)
            if rec.get("epoch") != lineno:
                raise ValueError(
                    f"availability log {path!r} is not contiguous: line "
                    f"{lineno} carries epoch {rec.get('epoch')!r} (expected "
                    f"{lineno}) — a hole would shift every later epoch")
            rows.append(np.asarray(rec["mask"], np.float64))
    if not rows:
        raise ValueError(f"availability log {path!r} is empty")
    if any(r.shape != rows[0].shape or r.ndim != 2 for r in rows):
        raise ValueError(f"availability log {path!r} mixes mask shapes")
    stack = np.stack(rows)
    if np.isin(stack, (0, 1)).all():
        return stack.astype(np.uint8)
    return stack.astype(np.float32)


# ---------------------------------------------------------------------------
# time-varying graphs
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TopologySchedule:
    """Per-epoch mixing matrices A_p over a degrading server network.

    kinds:
      ``static``    A_p = A for all p (the paper; bitwise-reproduces the
                    fixed-graph run).
      ``edge_drop`` each epoch, every edge of the base graph fails
                    independently w.p. ``drop_prob`` (repaired back to
                    connectivity when ``ensure_connected``).
      ``straggler`` each epoch, ``n_weak`` uniformly-chosen links carry only
                    ``(1 - weaken)`` of their weight (the rest returns to the
                    endpoint self-loops) — slow links, not dead ones.
      ``asymmetric`` each epoch, every DIRECTION of every base-graph edge
                    fails independently w.p. ``drop_prob`` (repaired back to
                    strong connectivity when ``ensure_connected``), and the
                    emitted A_p is the ROW-stochastic
                    ``topology.out_degree_weights`` of the surviving
                    digraph.  With ``weaken > 0``, additionally the
                    directed counterpart of ``straggler``: ``n_weak``
                    uniformly-chosen surviving link DIRECTIONS keep only
                    ``(1 - weaken)`` of their weight, the rest returning to
                    the SENDER's self-loop
                    (``topology.weaken_directed_links``) — one-sided slow
                    links, not dead ones.  Only meaningful with a push-sum
                    (or explicit row-stochastic-baseline) consensus path —
                    see ``dfl.DFLConfig.mixing``.

    Under the first three kinds every emitted A_p is symmetric doubly
    stochastic (Eq. 6 without the fixed-support clause), so each epoch's
    gossip preserves the server mean; under ``asymmetric`` the A_p are only
    row stochastic and plain gossip is biased — push-sum's ratio read-out
    restores the mean (rows still sum to 1 after per-direction weakening,
    so the column-stochastic transpose keeps preserving sums and the ratio
    stays unbiased).  Contraction over a run is tracked by ``SigmaTracker``
    (mode="push_sum" for the asymmetric case).
    """

    kind: str = "static"
    drop_prob: float = 0.0
    weaken: float = 0.0
    n_weak: int = 1
    ensure_connected: bool = True
    seed: int = 0

    def __post_init__(self):
        if self.kind not in ("static", "edge_drop", "straggler", "asymmetric"):
            raise ValueError(f"unknown topology schedule kind {self.kind!r}")

    def mixing(self, topo: FLTopology, epoch: int) -> np.ndarray:
        """float64 (M, M) mixing matrix for ``epoch`` (full precision so
        ``SigmaTracker`` products stay meaningful; the engine casts to f32
        only at the jit boundary)."""
        if topo.num_servers == 1:
            return np.ones((1, 1))
        if self.kind == "static":
            return topo.mixing_matrix()
        rng = np.random.default_rng((self.seed, epoch))
        if self.kind == "asymmetric":
            adj = tp.random_direction_drop(
                topo.adjacency(), self.drop_prob, rng,
                ensure_strong=self.ensure_connected)
            a = tp.out_degree_weights(adj)
            if self.weaken > 0.0 and self.n_weak:
                # directed straggler: weaken individual link DIRECTIONS
                di, dj = np.nonzero(adj)
                off = di != dj
                di, dj = di[off], dj[off]
                if di.size:
                    pick = rng.choice(di.size,
                                      size=min(self.n_weak, di.size),
                                      replace=False)
                    a = tp.weaken_directed_links(
                        a, list(zip(di[pick], dj[pick])), self.weaken)
            tp.check_row_stochastic(a, adj)
            return a
        if self.kind == "edge_drop":
            adj = tp.random_edge_drop(topo.adjacency(), self.drop_prob, rng,
                                      ensure_connected=self.ensure_connected)
            a = (tp.metropolis_weights(adj) if topo.mixing == "metropolis"
                 else tp.uniform_weights(adj))
            tp.check_mixing_matrix(a, adj)
            return a
        # straggler: weaken n_weak random links of the base matrix
        a = topo.mixing_matrix()
        iu, ju = np.nonzero(np.triu(topo.adjacency(), 1))
        if iu.size:
            pick = rng.choice(iu.size, size=min(self.n_weak, iu.size),
                              replace=False)
            a = tp.weaken_links(a, list(zip(iu[pick], ju[pick])), self.weaken)
        return a


class SigmaTracker:
    """Host-side product-contraction tracking for time-varying gossip.

    mode="average" (symmetric/doubly-stochastic gossip): accumulates
    P <- A_p^{T_S} P across epochs; ``sigma()`` is ``||P - 11'/M||_2`` — the
    factor by which initial server disagreement has provably contracted so
    far (Lemma 1 with a matrix product in place of a power).

    mode="push_sum" (directed, row-stochastic A_p): accumulates the
    column-stochastic product P <- (A_p')^{T_S} P and ``sigma()`` is
    ``topology.push_sum_deviation(P)`` — the contraction of the ratio
    read-out, which -> 0 under joint strong connectivity even though P
    itself converges to a skewed rank-one ``v 1'``.

    ``staleness`` is the bounded-staleness depth of the consensus period
    (``dfl.DFLConfig.staleness``): with round ``t`` mixing round
    ``t - s``'s messages, only one round in every ``s+1`` advances the
    chain (the rest re-mix the same delayed iterate), so the EXACT
    per-epoch operator is ``A_p^(T_S // (s+1))`` — the tracker raises the
    per-epoch power accordingly, keeping Theorem-1 monitoring
    (``obs.monitor.ConvergenceMonitor``'s ``contraction_bound``) honest
    rather than optimistically assuming all T_S synchronous rounds.

    Reset on topology surgery (M changes)."""

    def __init__(self, m: int, mode: str = "average", *, staleness: int = 0):
        if mode not in ("average", "push_sum"):
            raise ValueError(f"unknown SigmaTracker mode {mode!r}")
        if staleness < 0:
            raise ValueError(f"staleness must be >= 0, got {staleness}")
        self.m = m
        self.mode = mode
        self.staleness = staleness
        self.prod = np.eye(m)

    def update(self, a: np.ndarray, t_server: int) -> float:
        op = np.asarray(a, np.float64)
        if self.mode == "push_sum":
            op = op.T
        rounds = t_server // (self.staleness + 1)
        self.prod = np.linalg.matrix_power(op, rounds) @ self.prod
        return self.sigma()

    def sigma(self) -> float:
        if self.mode == "push_sum":
            return tp.push_sum_deviation(self.prod)
        return tp.consensus_deviation(self.prod)


# ---------------------------------------------------------------------------
# fault schedules
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One scheduled fault: at the START of ``epoch``, ``server`` (an
    ORIGINAL server index, stable across surgeries) drops out or rejoins."""

    epoch: int
    kind: str          # "drop" | "rejoin"
    server: int

    def __post_init__(self):
        if self.kind not in ("drop", "rejoin"):
            raise ValueError(f"unknown fault kind {self.kind!r}")
        if self.epoch < 0 or self.server < 0:
            raise ValueError("epoch and server must be non-negative")


@dataclasses.dataclass(frozen=True)
class FaultSchedule:
    events: Tuple[FaultEvent, ...] = ()

    @staticmethod
    def parse(spec: str) -> "FaultSchedule":
        """Parse the CLI fault grammar of ``launch/train.py --faults``.

        Grammar (comma-separated events, whitespace around events ignored)::

            spec   ::= "" | event ("," event)*
            event  ::= kind ":" EPOCH ":" SERVER
            kind   ::= "drop" | "rejoin"

        where ``EPOCH`` and ``SERVER`` are non-negative decimal integers:
        the event fires at the START of epoch ``EPOCH`` (before that
        epoch's local period), and ``SERVER`` is an ORIGINAL server index —
        stable across surgeries, so ``"drop:5:2,rejoin:9:2"`` drops server
        2 at epoch 5 and re-admits the SAME server (with its own clients'
        data shards) at epoch 9.  A rejoined server re-enters at the last
        row position with the survivors' mean model.  Events need not be
        sorted; several events may share an epoch and are applied in spec
        order.  The empty string parses to an empty schedule.  Malformed
        events (wrong field count, non-numeric epoch/server, unknown kind)
        raise ``ValueError``; ids outside the ORIGINAL federation (>= M)
        are rejected by ``FaultSchedule.validate`` when the engine is
        constructed."""
        events = []
        for part in filter(None, (s.strip() for s in spec.split(","))):
            fields = part.split(":")
            if len(fields) != 3 or not fields[1].isdigit() \
                    or not fields[2].isdigit():
                raise ValueError(
                    f"bad fault spec {part!r}: expected "
                    f"'drop:EPOCH:SERVER' or 'rejoin:EPOCH:SERVER'")
            kind, epoch, server = fields
            events.append(FaultEvent(int(epoch), kind, int(server)))
        return FaultSchedule(tuple(events))

    def validate(self, num_servers: int) -> None:
        """Reject events naming servers the federation never had.

        ``SERVER`` ids are ORIGINAL indices: client data ownership is keyed
        by original identity (``engine.BatchFn`` / the data pipelines), so
        an id >= the initial federation size has no data shard — a
        ``rejoin`` for it would crash (or silently alias another server's
        shard) mid-run at the first batch fetch.  The engine calls this at
        construction so a bad schedule fails before any training."""
        for ev in self.events:
            if ev.server >= num_servers:
                raise ValueError(
                    f"fault event {ev.kind}:{ev.epoch}:{ev.server} names "
                    f"server {ev.server}, but the federation has only "
                    f"{num_servers} ORIGINAL servers (ids 0.."
                    f"{num_servers - 1}); fresh-id rejoin is undefined — "
                    f"data shards are keyed by original identity")

    def at(self, epoch: int) -> Tuple[FaultEvent, ...]:
        return tuple(e for e in self.events if e.epoch == epoch)

    @property
    def last_epoch(self) -> int:
        return max((e.epoch for e in self.events), default=-1)

    @staticmethod
    def from_trace(trace: np.ndarray, *,
                   min_down_epochs: int = 1) -> "FaultSchedule":
        """Derive correlated drop/rejoin churn from an ``(E, M, N)``
        availability trace — the SAME JSONL logs
        ``ParticipationSchedule(kind="trace")`` replays
        (``load_participation_trace`` / ``diurnal_trace``), so one fleet
        log drives both participation masks and server-level surgery.

        Server ``i`` is DOWN at epoch ``p`` iff its whole client row is
        zero (no client of that server reported in).  Each maximal outage
        ``[p0, p1)`` becomes ``drop`` at epoch ``p0`` and ``rejoin`` at
        epoch ``p1`` (events fire at the START of an epoch, matching the
        engine's surgery point); an outage still running at the end of
        the trace gets no rejoin.  Outages shorter than
        ``min_down_epochs`` are ignored as logging blips — raise it to
        keep transient gaps from thrashing the jit cache with drop/rejoin
        resizes.  Rejects a trace with an epoch where EVERY server is
        down (the surgery would leave an empty federation); round-trip:
        replaying the events reproduces the trace's (blip-filtered)
        down-timeline exactly (``tests/test_dynamic_federation.py``)."""
        t = np.asarray(trace)
        if t.ndim != 3 or t.shape[0] < 1:
            raise ValueError(f"trace must be (E, M, N) with E >= 1, got "
                             f"shape {t.shape}")
        if not np.isin(t, (0, 1)).all():
            raise ValueError("trace entries must be 0/1 availability")
        if min_down_epochs < 1:
            raise ValueError("min_down_epochs must be >= 1")
        epochs, m, _ = t.shape
        down = t.sum(axis=2) == 0                          # (E, M)
        # blip filter BEFORE the all-down check: a one-epoch global gap
        # below the threshold never becomes surgery, so it is survivable
        kept = np.zeros_like(down)
        events = []
        for i in range(m):
            p = 0
            while p < epochs:
                if not down[p, i]:
                    p += 1
                    continue
                q = p
                while q < epochs and down[q, i]:
                    q += 1
                if q - p >= min_down_epochs:
                    kept[p:q, i] = True
                    events.append(FaultEvent(p, "drop", i))
                    if q < epochs:
                        events.append(FaultEvent(q, "rejoin", i))
                p = q
        all_down = np.nonzero(kept.all(axis=1))[0]
        if all_down.size:
            raise ValueError(
                f"availability trace has every server down at epoch(s) "
                f"{all_down.tolist()[:5]} — the derived surgery would "
                f"leave an empty federation; raise min_down_epochs or "
                f"clean the log")
        events.sort(key=lambda e: (e.epoch, e.kind == "drop", e.server))
        return FaultSchedule(tuple(events))


# ---------------------------------------------------------------------------
# Byzantine (adversarial-server) schedules
# ---------------------------------------------------------------------------

ATTACK_KINDS = ("sign_flip", "scaled_noise", "inlier_shift")


@dataclasses.dataclass(frozen=True)
class ByzantineAttack:
    """One attack population: a ``frac`` fraction of the ORIGINAL servers
    runs attack ``kind`` with strength ``scale``.

    kinds (the traced injection functions live in ``dfl.apply_byzantine``):
      ``sign_flip``    transmit ``-scale * w`` — the classic
                       gradient/model reversal; drags plain gossip's
                       average toward the mirrored model.
      ``scaled_noise`` transmit ``w + scale * N(0, I)`` — a noise flooder;
                       keeps every honest neighbor's post-mix state jittery
                       so disagreement never reaches tolerance.
      ``inlier_shift`` COLLUSION that stays inside the honest coordinate
                       range: transmit ``h_min + scale * (h_max - h_min)``
                       per coordinate (the honest envelope's ``scale``
                       quantile corner, computed over the true honest
                       servers) — undetectable by range checks, biases
                       plain averaging toward the envelope edge.
    """

    kind: str
    frac: float
    scale: float = 1.0

    def __post_init__(self):
        if self.kind not in ATTACK_KINDS:
            raise ValueError(f"unknown byzantine attack kind {self.kind!r}; "
                             f"choose from {ATTACK_KINDS}")
        if not 0.0 <= self.frac <= 1.0:
            raise ValueError("attack frac must be in [0, 1]")
        if self.kind == "inlier_shift" and not 0.0 <= self.scale <= 1.0:
            raise ValueError("inlier_shift scale is an envelope quantile "
                             "and must be in [0, 1]")


@dataclasses.dataclass(frozen=True)
class ByzantineSchedule:
    """Which servers attack, when — the adversarial sibling of
    ``FaultSchedule``.

    Attacker identities are drawn over ORIGINAL server ids (one seeded
    permutation of ``range(M)``, carved into disjoint per-attack sets), so
    they are stable across drop/rejoin surgery: a server that is both
    scheduled to attack and currently dropped simply isn't there to
    attack, and resumes attacking when it rejoins.  With ``resample=True``
    a fresh permutation is drawn every epoch (a roaming adversary);
    default is the fixed-adversary model every breakdown-point statement
    assumes.

    The schedule only MARKS attackers (host-side, ``codes``); the attacks
    themselves are pure traced functions applied to the pre-gossip server
    tree by ``dfl.apply_byzantine``, so the compiled epoch step stays one
    program per federation size."""

    attacks: Tuple[ByzantineAttack, ...] = ()
    seed: int = 0
    resample: bool = False

    @staticmethod
    def parse(spec: str, *, seed: int = 0,
              resample: bool = False) -> "ByzantineSchedule":
        """Parse the CLI grammar of ``launch/train.py --byzantine``.

        Grammar (comma-separated attacks, whitespace ignored)::

            spec   ::= "" | attack ("," attack)*
            attack ::= kind ":" FRAC [":" SCALE]
            kind   ::= "sign_flip" | "scaled_noise" | "inlier_shift"

        e.g. ``"sign_flip:0.125"`` (1 of 8 servers flips its sign at the
        default scale 1.0) or ``"sign_flip:0.1,scaled_noise:0.1:10"``.
        The empty string parses to an empty (all-honest) schedule."""
        attacks = []
        for part in filter(None, (s.strip() for s in spec.split(","))):
            fields = part.split(":")
            if len(fields) not in (2, 3):
                raise ValueError(f"bad byzantine spec {part!r}: expected "
                                 f"'kind:FRAC[:SCALE]'")
            try:
                frac = float(fields[1])
                scale = float(fields[2]) if len(fields) == 3 else 1.0
            except ValueError:
                raise ValueError(f"bad byzantine spec {part!r}: FRAC and "
                                 f"SCALE must be numbers")
            attacks.append(ByzantineAttack(fields[0], frac, scale))
        return ByzantineSchedule(tuple(attacks), seed=seed,
                                 resample=resample)

    def counts(self, m: int) -> Tuple[int, ...]:
        """Attackers per attack at federation size ``m`` (rounded)."""
        return tuple(int(round(a.frac * m)) for a in self.attacks)

    def validate(self, num_servers: int) -> None:
        """Fail at engine construction when the attack populations don't
        fit: the per-attack sets are disjoint, so their total size must
        leave at least one honest server (an all-attacker federation has
        no honest envelope, no honest metric, and nothing to defend)."""
        total = sum(self.counts(num_servers))
        if total >= num_servers and total > 0:
            raise ValueError(
                f"byzantine schedule marks {total} attackers but the "
                f"federation has only {num_servers} servers — at least one "
                f"honest server must remain")

    def attacker_sets(self, epoch: int, m: int) -> Tuple[frozenset, ...]:
        """Disjoint per-attack sets of ORIGINAL server ids for ``epoch``:
        one seeded permutation of ``range(m)`` carved sequentially (a
        fixed permutation unless ``resample``)."""
        if not self.attacks:
            return ()
        key = (self.seed, epoch) if self.resample else (self.seed,)
        perm = np.random.default_rng(key).permutation(m)
        sets, lo = [], 0
        for cnt in self.counts(m):
            sets.append(frozenset(int(s) for s in perm[lo:lo + cnt]))
            lo += cnt
        return tuple(sets)

    def codes(self, epoch: int, alive: Tuple[int, ...],
              num_servers: int) -> np.ndarray:
        """Per-CURRENT-ROW attack codes for ``epoch``: 0 = honest, k+1 =
        ``attacks[k]``.  ``alive`` is the engine's original-id row order,
        so the codes line up with the state arrays after any surgery;
        ``num_servers`` is the ORIGINAL federation size — the permutation
        is always drawn over it, so attacker identities don't shift when
        a server drops."""
        sets = self.attacker_sets(epoch, num_servers)
        out = np.zeros(len(alive), np.int32)
        for row, orig in enumerate(alive):
            for k, ids in enumerate(sets):
                if orig in ids:
                    out[row] = k + 1
                    break
        return out
