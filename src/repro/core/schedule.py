"""Dynamic-federation schedules: who participates, over which graph, and
which servers fail when.

The paper's Algorithm 1 is *static*: all M·N clients train every epoch over
one fixed connected server graph.  Its headline claims — scalability and
fault-tolerance — only become testable scenarios once participation and
topology can change mid-run.  This module provides the host-side scenario
generators; `dfl.build_dfl_epoch_step(dynamic=True)` consumes their output
as traced operands so ONE compiled epoch step covers every scenario of a
given shape:

* ``ParticipationSchedule`` — a per-epoch ``(M, N)`` 0/1 mask.  Eq. 4
  becomes a masked, weight-renormalised mean (see ``dfl.masked_server_mean``)
  and non-participants carry their broadcast model forward unchanged.
* ``TopologySchedule``    — a per-epoch mixing matrix ``A_p`` (edge
  drop/add, straggler-weakened links), always doubly stochastic, fed as a
  traced operand to gossip.  ``SigmaTracker`` accumulates the host-side
  product contraction ``||prod_p A_p^{T_S} - 11'/M||_2`` (Lemma 1's sigma_A
  generalised to time-varying graphs).
* ``FaultSchedule``       — scheduled server failure/rejoin events, executed
  between epochs via ``FLTopology.drop_server`` / ``rejoin_server`` graph
  surgery (shapes change, so these live on the host; see ``engine.py``).

All sampling is deterministic in ``(seed, epoch)`` so runs are reproducible
and a schedule can be replayed or sliced without storing mask traces.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional, Tuple

import numpy as np

from repro.core import topology as tp
from repro.core.topology import FLTopology


class EpochSchedule(NamedTuple):
    """The traced per-epoch operands of a dynamic epoch step.

    ``mask``:   (M, N) float32 0/1 participation mask.
    ``mixing``: (M, M) float32 doubly-stochastic mixing matrix A_p.
    ``lam2``:   optional scalar |lambda_2(A_p)| — the host-side per-epoch
                spectral estimate (``topology.lambda_2``) that spectral
                consensus backends (``consensus.ChebyshevBackend``) consume
                alongside the traced matrix; ``None`` for every other
                backend (the engine only computes it when asked for).
    """

    mask: np.ndarray
    mixing: np.ndarray
    lam2: Optional[np.ndarray] = None


# ---------------------------------------------------------------------------
# participation
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ParticipationSchedule:
    """Per-epoch client participation masks.

    kinds:
      ``full``        every client every epoch (the paper's setting).
      ``bernoulli``   each client participates independently w.p. ``rate``.
      ``fixed_k``     exactly ``k`` uniformly-sampled clients per server.
      ``round_robin`` deterministic rotation of ``k`` clients per server —
                      the scheduling-policy baseline of Abdelghany et al.

    ``min_per_server`` forces at least that many participants per server
    (sampled uniformly from the idle ones) so the masked Eq. 4 mean stays
    well-defined; set it to 0 to allow fully-idle servers, which then simply
    carry their model through the epoch.
    """

    kind: str = "full"
    rate: float = 1.0
    k: Optional[int] = None
    min_per_server: int = 1
    seed: int = 0

    def __post_init__(self):
        if self.kind not in ("full", "bernoulli", "fixed_k", "round_robin"):
            raise ValueError(f"unknown participation kind {self.kind!r}")
        if self.kind == "bernoulli" and not 0.0 <= self.rate <= 1.0:
            raise ValueError("rate must be in [0, 1]")
        if self.kind in ("fixed_k", "round_robin") and not self.k:
            raise ValueError(f"kind={self.kind!r} needs k >= 1")

    def mask(self, epoch: int, m: int, n: int) -> np.ndarray:
        """(M, N) float32 0/1 mask for ``epoch`` — deterministic in
        (seed, epoch), independent of call order."""
        if self.kind == "full":
            return np.ones((m, n), np.float32)
        rng = np.random.default_rng((self.seed, epoch))
        if self.kind == "bernoulli":
            mask = (rng.random((m, n)) < self.rate)
        elif self.kind == "fixed_k":
            k = min(self.k, n)
            mask = np.zeros((m, n), bool)
            for i in range(m):
                mask[i, rng.choice(n, size=k, replace=False)] = True
        else:  # round_robin
            k = min(self.k, n)
            cols = (epoch * k + np.arange(k)) % n
            mask = np.zeros((m, n), bool)
            mask[:, cols] = True
        need = min(self.min_per_server, n)
        for i in range(m):
            short = need - int(mask[i].sum())
            if short > 0:
                idle = np.nonzero(~mask[i])[0]
                mask[i, rng.choice(idle, size=short, replace=False)] = True
        return mask.astype(np.float32)

    def expected_rate(self, n: int) -> float:
        """Mean fraction of participating clients (for reporting)."""
        if self.kind == "full":
            return 1.0
        if self.kind == "bernoulli":
            return max(self.rate, self.min_per_server / n)
        return min(self.k, n) / n


# ---------------------------------------------------------------------------
# time-varying graphs
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TopologySchedule:
    """Per-epoch mixing matrices A_p over a degrading server network.

    kinds:
      ``static``    A_p = A for all p (the paper; bitwise-reproduces the
                    fixed-graph run).
      ``edge_drop`` each epoch, every edge of the base graph fails
                    independently w.p. ``drop_prob`` (repaired back to
                    connectivity when ``ensure_connected``).
      ``straggler`` each epoch, ``n_weak`` uniformly-chosen links carry only
                    ``(1 - weaken)`` of their weight (the rest returns to the
                    endpoint self-loops) — slow links, not dead ones.
      ``asymmetric`` each epoch, every DIRECTION of every base-graph edge
                    fails independently w.p. ``drop_prob`` (repaired back to
                    strong connectivity when ``ensure_connected``), and the
                    emitted A_p is the ROW-stochastic
                    ``topology.out_degree_weights`` of the surviving
                    digraph.  With ``weaken > 0``, additionally the
                    directed counterpart of ``straggler``: ``n_weak``
                    uniformly-chosen surviving link DIRECTIONS keep only
                    ``(1 - weaken)`` of their weight, the rest returning to
                    the SENDER's self-loop
                    (``topology.weaken_directed_links``) — one-sided slow
                    links, not dead ones.  Only meaningful with a push-sum
                    (or explicit row-stochastic-baseline) consensus path —
                    see ``dfl.DFLConfig.mixing``.

    Under the first three kinds every emitted A_p is symmetric doubly
    stochastic (Eq. 6 without the fixed-support clause), so each epoch's
    gossip preserves the server mean; under ``asymmetric`` the A_p are only
    row stochastic and plain gossip is biased — push-sum's ratio read-out
    restores the mean (rows still sum to 1 after per-direction weakening,
    so the column-stochastic transpose keeps preserving sums and the ratio
    stays unbiased).  Contraction over a run is tracked by ``SigmaTracker``
    (mode="push_sum" for the asymmetric case).
    """

    kind: str = "static"
    drop_prob: float = 0.0
    weaken: float = 0.0
    n_weak: int = 1
    ensure_connected: bool = True
    seed: int = 0

    def __post_init__(self):
        if self.kind not in ("static", "edge_drop", "straggler", "asymmetric"):
            raise ValueError(f"unknown topology schedule kind {self.kind!r}")

    def mixing(self, topo: FLTopology, epoch: int) -> np.ndarray:
        """float64 (M, M) mixing matrix for ``epoch`` (full precision so
        ``SigmaTracker`` products stay meaningful; the engine casts to f32
        only at the jit boundary)."""
        if topo.num_servers == 1:
            return np.ones((1, 1))
        if self.kind == "static":
            return topo.mixing_matrix()
        rng = np.random.default_rng((self.seed, epoch))
        if self.kind == "asymmetric":
            adj = tp.random_direction_drop(
                topo.adjacency(), self.drop_prob, rng,
                ensure_strong=self.ensure_connected)
            a = tp.out_degree_weights(adj)
            if self.weaken > 0.0 and self.n_weak:
                # directed straggler: weaken individual link DIRECTIONS
                di, dj = np.nonzero(adj)
                off = di != dj
                di, dj = di[off], dj[off]
                if di.size:
                    pick = rng.choice(di.size,
                                      size=min(self.n_weak, di.size),
                                      replace=False)
                    a = tp.weaken_directed_links(
                        a, list(zip(di[pick], dj[pick])), self.weaken)
            tp.check_row_stochastic(a, adj)
            return a
        if self.kind == "edge_drop":
            adj = tp.random_edge_drop(topo.adjacency(), self.drop_prob, rng,
                                      ensure_connected=self.ensure_connected)
            a = (tp.metropolis_weights(adj) if topo.mixing == "metropolis"
                 else tp.uniform_weights(adj))
            tp.check_mixing_matrix(a, adj)
            return a
        # straggler: weaken n_weak random links of the base matrix
        a = topo.mixing_matrix()
        iu, ju = np.nonzero(np.triu(topo.adjacency(), 1))
        if iu.size:
            pick = rng.choice(iu.size, size=min(self.n_weak, iu.size),
                              replace=False)
            a = tp.weaken_links(a, list(zip(iu[pick], ju[pick])), self.weaken)
        return a


class SigmaTracker:
    """Host-side product-contraction tracking for time-varying gossip.

    mode="average" (symmetric/doubly-stochastic gossip): accumulates
    P <- A_p^{T_S} P across epochs; ``sigma()`` is ``||P - 11'/M||_2`` — the
    factor by which initial server disagreement has provably contracted so
    far (Lemma 1 with a matrix product in place of a power).

    mode="push_sum" (directed, row-stochastic A_p): accumulates the
    column-stochastic product P <- (A_p')^{T_S} P and ``sigma()`` is
    ``topology.push_sum_deviation(P)`` — the contraction of the ratio
    read-out, which -> 0 under joint strong connectivity even though P
    itself converges to a skewed rank-one ``v 1'``.

    Reset on topology surgery (M changes)."""

    def __init__(self, m: int, mode: str = "average"):
        if mode not in ("average", "push_sum"):
            raise ValueError(f"unknown SigmaTracker mode {mode!r}")
        self.m = m
        self.mode = mode
        self.prod = np.eye(m)

    def update(self, a: np.ndarray, t_server: int) -> float:
        op = np.asarray(a, np.float64)
        if self.mode == "push_sum":
            op = op.T
        self.prod = np.linalg.matrix_power(op, t_server) @ self.prod
        return self.sigma()

    def sigma(self) -> float:
        if self.mode == "push_sum":
            return tp.push_sum_deviation(self.prod)
        return tp.consensus_deviation(self.prod)


# ---------------------------------------------------------------------------
# fault schedules
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One scheduled fault: at the START of ``epoch``, ``server`` (an
    ORIGINAL server index, stable across surgeries) drops out or rejoins."""

    epoch: int
    kind: str          # "drop" | "rejoin"
    server: int

    def __post_init__(self):
        if self.kind not in ("drop", "rejoin"):
            raise ValueError(f"unknown fault kind {self.kind!r}")
        if self.epoch < 0 or self.server < 0:
            raise ValueError("epoch and server must be non-negative")


@dataclasses.dataclass(frozen=True)
class FaultSchedule:
    events: Tuple[FaultEvent, ...] = ()

    @staticmethod
    def parse(spec: str) -> "FaultSchedule":
        """Parse the CLI fault grammar of ``launch/train.py --faults``.

        Grammar (comma-separated events, whitespace around events ignored)::

            spec   ::= "" | event ("," event)*
            event  ::= kind ":" EPOCH ":" SERVER
            kind   ::= "drop" | "rejoin"

        where ``EPOCH`` and ``SERVER`` are non-negative decimal integers:
        the event fires at the START of epoch ``EPOCH`` (before that
        epoch's local period), and ``SERVER`` is an ORIGINAL server index —
        stable across surgeries, so ``"drop:5:2,rejoin:9:2"`` drops server
        2 at epoch 5 and re-admits the SAME server (with its own clients'
        data shards) at epoch 9.  A rejoined server re-enters at the last
        row position with the survivors' mean model.  Events need not be
        sorted; several events may share an epoch and are applied in spec
        order.  The empty string parses to an empty schedule.  Malformed
        events (wrong field count, non-numeric epoch/server, unknown kind)
        raise ``ValueError``; ids outside the ORIGINAL federation (>= M)
        are rejected by ``FaultSchedule.validate`` when the engine is
        constructed."""
        events = []
        for part in filter(None, (s.strip() for s in spec.split(","))):
            fields = part.split(":")
            if len(fields) != 3 or not fields[1].isdigit() \
                    or not fields[2].isdigit():
                raise ValueError(
                    f"bad fault spec {part!r}: expected "
                    f"'drop:EPOCH:SERVER' or 'rejoin:EPOCH:SERVER'")
            kind, epoch, server = fields
            events.append(FaultEvent(int(epoch), kind, int(server)))
        return FaultSchedule(tuple(events))

    def validate(self, num_servers: int) -> None:
        """Reject events naming servers the federation never had.

        ``SERVER`` ids are ORIGINAL indices: client data ownership is keyed
        by original identity (``engine.BatchFn`` / the data pipelines), so
        an id >= the initial federation size has no data shard — a
        ``rejoin`` for it would crash (or silently alias another server's
        shard) mid-run at the first batch fetch.  The engine calls this at
        construction so a bad schedule fails before any training."""
        for ev in self.events:
            if ev.server >= num_servers:
                raise ValueError(
                    f"fault event {ev.kind}:{ev.epoch}:{ev.server} names "
                    f"server {ev.server}, but the federation has only "
                    f"{num_servers} ORIGINAL servers (ids 0.."
                    f"{num_servers - 1}); fresh-id rejoin is undefined — "
                    f"data shards are keyed by original identity")

    def at(self, epoch: int) -> Tuple[FaultEvent, ...]:
        return tuple(e for e in self.events if e.epoch == epoch)

    @property
    def last_epoch(self) -> int:
        return max((e.epoch for e in self.events), default=-1)
