"""repro.obs — structured telemetry for the DFL engine.

Three layers, combinable through one ``Observability`` bundle handed to
``DynamicFederationEngine`` / the trainers:

- ``trace.Tracer``            — host-side span tracing -> Chrome trace
                                JSON (Perfetto-loadable).
- ``metrics.MetricsHub``      — typed counter/gauge/histogram events
                                fanned out to Memory/JSONL/Console sinks.
- ``monitor.ConvergenceMonitor`` — Theorem-1 / fig-3 derived gauges +
                                watchdog warnings.

The bundle is BITWISE INERT on training numerics: it only reads floats
the engine already computed, and the engine's compiled programs are
byte-identical with ``OBS_OFF`` (the no-op null bundle, the default) or
a full bundle attached — asserted in ``tests/test_obs.py``.
"""
from __future__ import annotations

from typing import Any, Dict, Iterable, Optional, Sequence

from .metrics import (SCHEMA_VERSION, ConsoleSink, JSONLSink, MemorySink,
                      MetricEvent, MetricsHub, Sink, load_jsonl,
                      validate_jsonl)
from .monitor import FIG3_TOLERANCE, ConvergenceMonitor, WatchdogEvent
from .trace import Span, Tracer, validate_chrome_trace

__all__ = [
    "SCHEMA_VERSION", "FIG3_TOLERANCE", "MetricEvent", "MetricsHub",
    "Sink", "MemorySink", "JSONLSink", "ConsoleSink", "ConvergenceMonitor",
    "WatchdogEvent", "Span", "Tracer", "Observability", "OBS_OFF",
    "load_jsonl", "validate_jsonl", "validate_chrome_trace",
]


class _NullSpan:
    """Context manager that does nothing — what ``OBS_OFF.span`` returns,
    so instrumented code has ONE code path whether obs is on or off."""

    __slots__ = ()

    def __enter__(self):
        return None

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


class Observability:
    """One handle bundling hub + tracer + monitor.

    Everything is optional: ``Observability()`` gives a bare hub with no
    sinks (still inert, still cheap); pass ``tracer=Tracer()`` to record
    spans, ``monitor=True`` to attach a ``ConvergenceMonitor`` over the
    hub.  The engine/trainers call only ``span`` / ``compile_event`` /
    ``observe`` / ``close``."""

    enabled = True

    def __init__(self, hub: Optional[MetricsHub] = None,
                 tracer: Optional[Tracer] = None,
                 monitor: Any = None):
        self.hub = hub if hub is not None else MetricsHub()
        self.tracer = tracer
        if monitor is True:
            monitor = ConvergenceMonitor(self.hub)
        self.monitor: Optional[ConvergenceMonitor] = monitor

    def span(self, name: str, **args: Any):
        if self.tracer is None:
            return _NULL_SPAN
        return self.tracer.span(name, **args)

    def compile_event(self, cause: str, **args: Any) -> None:
        if self.tracer is not None:
            self.tracer.compile_event(cause, **args)

    def observe(self, epoch: int, record: Dict[str, float], *,
                servers: Optional[Sequence[int]] = None,
                per_link: Any = None,
                screen_rejected: Optional[Iterable[float]] = None) -> None:
        """Fan one epoch's telemetry out: the full record as an ``epoch``
        event, the ``BytesTracker`` per-link byte matrix as labelled
        counters, robust-screen per-server rejection counts as a
        labelled histogram, then the convergence monitor's checks."""
        self.hub.observe_epoch(epoch, record)
        if per_link is not None:
            ids = list(servers) if servers is not None else None
            m = len(per_link)
            for i in range(m):
                for j in range(m):
                    b = float(per_link[i][j])
                    if b > 0:
                        self.hub.counter(
                            "wire_bytes", b, epoch=epoch,
                            dst=ids[i] if ids else i,
                            src=ids[j] if ids else j)
        if screen_rejected is not None:
            vals = [float(v) for v in screen_rejected]
            self.hub.histogram(
                "screen_rejected", vals, epoch=epoch,
                servers=list(servers) if servers is not None
                else list(range(len(vals))))
        if self.monitor is not None:
            self.monitor.observe(epoch, record)

    def close(self) -> None:
        self.hub.close()


class _ObsOff:
    """The null bundle: every hook is a no-op.  The engine's default, so
    un-instrumented runs pay one attribute read and one ``if`` per hook."""

    enabled = False
    hub = None
    tracer = None
    monitor = None

    __slots__ = ()

    def span(self, name: str, **args: Any):
        return _NULL_SPAN

    def compile_event(self, cause: str, **args: Any) -> None:
        pass

    def observe(self, epoch: int, record: Dict[str, float],
                **kw: Any) -> None:
        pass

    def close(self) -> None:
        pass


OBS_OFF = _ObsOff()
