"""Host-side span tracing for the DFL engine.

``Tracer`` is a monotonic-clock (``time.perf_counter_ns``) span recorder
for the HOST loop: compiled regions are timed as one opaque span bounded
by an explicit ``jax.block_until_ready`` sync placed by the caller
strictly OUTSIDE the jitted program (the engine only syncs when a tracer
is attached, so tracing never changes dispatch behaviour of an untraced
run — and never changes numerics of any run).  Spans nest through the
``span()`` context manager; phases measured indirectly (the engine's
consensus-replay attribution of local vs gossip time inside one compiled
epoch step) are inserted with explicit timestamps via ``add_span``.

Besides spans the tracer records INSTANT events — most importantly
``compile`` events emitted by the engine whenever its per-M jit cache
traces a new program, tagged with the cause (``first_trace``,
``federation_size_change``, ``retrace``).

``to_chrome()`` exports everything in the Chrome trace-event JSON format
(``{"traceEvents": [...]}``, complete ``"ph": "X"`` events with
microsecond ``ts``/``dur``), loadable directly in Perfetto / chrome
about:tracing; ``save_chrome(path)`` writes it to disk.  See
``docs/observability.md`` for the span taxonomy.
"""
from __future__ import annotations

import contextlib
import dataclasses
import json
import time
from typing import Any, Callable, Dict, List, Optional

__all__ = ["Span", "Tracer", "validate_chrome_trace"]


@dataclasses.dataclass
class Span:
    """One closed wall-clock interval.  ``depth``/``parent`` encode the
    nesting at record time; Chrome viewers re-derive nesting from time
    containment on the single host track."""

    name: str
    t0_ns: int
    t1_ns: Optional[int] = None
    depth: int = 0
    parent: Optional["Span"] = None
    args: Dict[str, Any] = dataclasses.field(default_factory=dict)

    @property
    def duration_ns(self) -> int:
        if self.t1_ns is None:
            raise ValueError(f"span {self.name!r} is still open")
        return self.t1_ns - self.t0_ns

    def encloses(self, other: "Span") -> bool:
        """Whether ``other`` lies fully inside this span's interval."""
        return (self.t0_ns <= other.t0_ns
                and other.t1_ns is not None and self.t1_ns is not None
                and other.t1_ns <= self.t1_ns)


def _jsonable(v: Any) -> Any:
    if isinstance(v, (bool, int, float, str)) or v is None:
        return v
    return str(v)


class Tracer:
    """Span + instant-event recorder over one monotonic clock.

    Near-zero cost when unused; the engine holds NO tracer by default, so
    the untraced path never even reaches this module.  ``clock`` is
    injectable for deterministic tests (must return integer nanoseconds
    and be monotonic)."""

    def __init__(self, clock: Callable[[], int] = time.perf_counter_ns):
        self._clock = clock
        self.spans: List[Span] = []       # appended at span EXIT
        self.instants: List[Dict[str, Any]] = []
        self._stack: List[Span] = []

    def now(self) -> int:
        """The tracer's clock, for callers timing external work (e.g. the
        engine's consensus-replay probe) that lands via ``add_span``."""
        return self._clock()

    @contextlib.contextmanager
    def span(self, name: str, **args: Any):
        sp = Span(name=name, t0_ns=self._clock(), depth=len(self._stack),
                  parent=self._stack[-1] if self._stack else None, args=args)
        self._stack.append(sp)
        try:
            yield sp
        finally:
            self._stack.pop()
            sp.t1_ns = self._clock()
            self.spans.append(sp)

    def add_span(self, name: str, t0_ns: int, t1_ns: int,
                 parent: Optional[Span] = None, **args: Any) -> Span:
        """Record a span with EXPLICIT timestamps — for phases whose wall
        time was measured out-of-band (the engine's local/gossip split of
        one compiled step) and must be placed inside an already-closed
        parent's interval."""
        if t1_ns < t0_ns:
            raise ValueError(f"span {name!r} ends before it starts")
        depth = parent.depth + 1 if parent is not None else len(self._stack)
        sp = Span(name=name, t0_ns=t0_ns, t1_ns=t1_ns, depth=depth,
                  parent=parent, args=args)
        self.spans.append(sp)
        return sp

    def instant(self, name: str, **args: Any) -> None:
        self.instants.append({"name": name, "ts_ns": self._clock(),
                              "args": args})

    def compile_event(self, cause: str, **args: Any) -> None:
        """An XLA trace/compile happened on the caller's jit cache —
        ``cause`` is ``first_trace`` (cold cache), ``federation_size_change``
        (fault surgery re-jit at a new M), or ``retrace`` (a schedule
        operand leaked into trace structure: the compile-once contract is
        being violated — see ``engine.DynamicFederationEngine.
        compile_counts``)."""
        self.instant("compile", cause=cause, **args)

    # -- export ------------------------------------------------------------
    def to_chrome(self) -> Dict[str, Any]:
        """Chrome trace-event JSON: complete ``X`` events (ts/dur in
        microseconds) on one pid/tid track plus instant ``i`` events —
        load the saved file straight into Perfetto (ui.perfetto.dev)."""
        events: List[Dict[str, Any]] = []
        for sp in sorted(self.spans, key=lambda s: (s.t0_ns, s.depth)):
            if sp.t1_ns is None:
                continue
            events.append({
                "name": sp.name, "ph": "X", "cat": "repro", "pid": 1,
                "tid": 1, "ts": sp.t0_ns / 1e3,
                "dur": (sp.t1_ns - sp.t0_ns) / 1e3,
                "args": {k: _jsonable(v) for k, v in sp.args.items()},
            })
        for ev in self.instants:
            events.append({
                "name": ev["name"], "ph": "i", "s": "t", "cat": "repro",
                "pid": 1, "tid": 1, "ts": ev["ts_ns"] / 1e3,
                "args": {k: _jsonable(v) for k, v in ev["args"].items()},
            })
        return {"traceEvents": events, "displayTimeUnit": "ms"}

    def save_chrome(self, path: str) -> None:
        with open(path, "w", encoding="utf-8") as f:
            json.dump(self.to_chrome(), f)


def validate_chrome_trace(doc: Any) -> List[Dict[str, Any]]:
    """Validate a Chrome trace-event document (the JSON-object form this
    module emits) and return its event list.  Raises ``ValueError`` on any
    event a trace viewer would reject."""
    if not isinstance(doc, dict) or not isinstance(
            doc.get("traceEvents"), list):
        raise ValueError("not a Chrome trace: expected "
                         "{'traceEvents': [...]}")
    for ev in doc["traceEvents"]:
        if not isinstance(ev, dict):
            raise ValueError(f"trace event is not an object: {ev!r}")
        if not isinstance(ev.get("name"), str):
            raise ValueError(f"trace event without a name: {ev!r}")
        if ev.get("ph") not in ("X", "i", "B", "E", "M"):
            raise ValueError(f"unsupported phase {ev.get('ph')!r}")
        if not isinstance(ev.get("ts"), (int, float)):
            raise ValueError(f"trace event without numeric ts: {ev!r}")
        if ev["ph"] == "X" and not (isinstance(ev.get("dur"), (int, float))
                                    and ev["dur"] >= 0):
            raise ValueError(f"complete event needs dur >= 0: {ev!r}")
    return doc["traceEvents"]
