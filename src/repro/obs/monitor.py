"""Live convergence monitoring against the paper's quantities.

Turns each epoch record into derived gauges and watchdog checks:

- ``contraction_bound`` — Theorem 1 bounds the consensus error by the
  running product of per-epoch gossip contraction factors,
  ``sigma_prod * d0`` with ``d0`` the first observed disagreement: if
  the measured disagreement sits far ABOVE this curve, gossip is not
  delivering the contraction the mixing matrices promise.
- ``tolerance_gap`` — measured server disagreement relative to the fig-3
  consensus tolerance (1e-3): ``disagreement / tol``; < 1 means the run
  is inside the paper's reproduction band.

Watchdog rules (each fires a structured ``warning`` event through the
hub, at most once per rule per run unless the condition clears):

- ``nan-loss``                — loss or disagreement went NaN/inf.
- ``disagreement-divergence`` — disagreement grew by more than
  ``divergence_factor``× over the last ``divergence_window`` epochs
  (consensus is losing to drift — wrong sigma, partition, attack).
- ``wire-ratio-regression``   — compressed-wire savings collapsed:
  ``wire_ratio`` fell below ``wire_ratio_drop`` × its best observed
  value (e.g. the physical wire silently fell back to float payloads).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional

from .metrics import MetricsHub

FIG3_TOLERANCE = 1e-3

__all__ = ["FIG3_TOLERANCE", "WatchdogEvent", "ConvergenceMonitor"]


@dataclasses.dataclass
class WatchdogEvent:
    rule: str
    epoch: int
    message: str
    value: float


class ConvergenceMonitor:
    """Stateful per-run monitor; feed it every epoch record via
    ``observe`` and it emits derived gauges + watchdog warnings through
    the hub.  Pure host-side consumer of already-computed floats — it can
    never perturb training numerics."""

    def __init__(self, hub: MetricsHub, *,
                 disagreement_tol: float = FIG3_TOLERANCE,
                 divergence_factor: float = 10.0,
                 divergence_window: int = 5,
                 wire_ratio_drop: float = 0.5):
        self.hub = hub
        self.disagreement_tol = disagreement_tol
        self.divergence_factor = divergence_factor
        self.divergence_window = divergence_window
        self.wire_ratio_drop = wire_ratio_drop
        self.events: List[WatchdogEvent] = []
        self._d0: Optional[float] = None
        self._dis: List[float] = []
        self._best_ratio: float = 0.0
        self._fired: Dict[str, bool] = {}

    def _fire(self, rule: str, epoch: int, message: str,
              value: float) -> None:
        if self._fired.get(rule):
            return
        self._fired[rule] = True
        self.events.append(WatchdogEvent(rule, epoch, message, value))
        self.hub.warning(rule, message, epoch=epoch, value=value)

    def observe(self, epoch: int, record: Dict[str, float]) -> None:
        loss = record.get("loss")
        dis = record.get("disagreement")
        sigma = record.get("sigma_prod")
        ratio = record.get("wire_ratio")

        # derived gauges: paper quantities as live signals
        if dis is not None and math.isfinite(dis):
            if self._d0 is None:
                self._d0 = max(dis, self.disagreement_tol)
            self._dis.append(dis)
            self.hub.gauge("tolerance_gap", dis / self.disagreement_tol,
                           epoch=epoch)
            if sigma is not None and math.isfinite(sigma):
                self.hub.gauge("contraction_bound", sigma * self._d0,
                               epoch=epoch)

        # watchdog: nan-loss
        for key, val in (("loss", loss), ("disagreement", dis)):
            if val is not None and not math.isfinite(val):
                self._fire("nan-loss", epoch,
                           f"{key} is non-finite ({val}) — training has "
                           f"diverged or a kernel produced NaN", float("nan"))

        # watchdog: disagreement-divergence over a trailing window
        w = self.divergence_window
        if len(self._dis) > w:
            past = self._dis[-w - 1]
            now = self._dis[-1]
            if (math.isfinite(past) and math.isfinite(now) and past > 0
                    and now > self.divergence_factor * past
                    and now > self.disagreement_tol):
                self._fire(
                    "disagreement-divergence", epoch,
                    f"server disagreement grew {now / past:.1f}x over "
                    f"{w} epochs ({past:.3e} -> {now:.3e}) — consensus is "
                    f"losing to drift", now)

        # watchdog: wire-ratio regression (compressed runs only)
        if ratio is not None and math.isfinite(ratio) and ratio > 0:
            if ratio >= self._best_ratio:
                self._best_ratio = ratio
            elif ratio < self.wire_ratio_drop * self._best_ratio:
                self._fire(
                    "wire-ratio-regression", epoch,
                    f"wire compression ratio fell to {ratio:.2f}x from a "
                    f"best of {self._best_ratio:.2f}x — the wire may have "
                    f"fallen back to uncompressed payloads", ratio)
