"""MetricsHub: typed metric emission behind pluggable sinks.

One hub subsumes the repo's previously disjoint ledgers — the engine /
trainer ad-hoc ``record``/``history`` dicts, ``SigmaTracker`` sigma
products, and the ``BytesTracker`` per-link wire ledger — as a stream of
typed ``MetricEvent``s fanned out to every attached sink:

- ``MemorySink``     — accumulates the backward-compatible ``history``
                       dict (list per scalar metric, epoch-ordered).
- ``JSONLSink``      — newline-delimited JSON with a versioned schema
                       (``SCHEMA_VERSION``); first line is a ``meta``
                       record, every later line one event.
- ``ConsoleSink``    — the single place library code prints progress
                       (the trainers' old hand-rolled ``epoch ...``
                       lines route here).

Event kinds: ``counter`` (monotonic totals, e.g. wire bytes),
``gauge`` (point-in-time scalars, e.g. sigma product, disagreement),
``histogram`` (small per-epoch vectors with per-server / per-link
labels, e.g. screen-rejection counts), ``epoch`` (the engine's full
record dict in one event), ``warning`` (watchdog emissions).  The JSONL
schema is documented in ``docs/observability.md``.
"""
from __future__ import annotations

import dataclasses
import json
import time
from typing import Any, Dict, IO, Iterable, List, Optional, Sequence, Union

SCHEMA_VERSION = 1

_KINDS = ("epoch", "counter", "gauge", "histogram", "warning")

__all__ = [
    "SCHEMA_VERSION", "MetricEvent", "Sink", "MemorySink", "JSONLSink",
    "ConsoleSink", "MetricsHub", "load_jsonl", "validate_jsonl",
]


@dataclasses.dataclass
class MetricEvent:
    """One typed telemetry record.  ``value`` is a float for
    counter/gauge, a list of floats for histogram, a flat str->scalar
    dict for epoch, and a message dict for warning.  ``labels`` carry
    the per-server (``server=i``) / per-link (``src=j,dst=i``) axes."""

    kind: str
    name: str
    value: Union[float, List[float], Dict[str, Any]]
    epoch: Optional[int] = None
    labels: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def to_json(self) -> Dict[str, Any]:
        out: Dict[str, Any] = {"kind": self.kind, "name": self.name,
                               "value": self.value}
        if self.epoch is not None:
            out["epoch"] = self.epoch
        if self.labels:
            out["labels"] = self.labels
        return out


class Sink:
    """Sink interface: ``emit`` receives every event; ``close`` flushes."""

    def emit(self, ev: MetricEvent) -> None:  # pragma: no cover - interface
        raise NotImplementedError

    def close(self) -> None:
        pass


class MemorySink(Sink):
    """Accumulates the legacy ``history`` dict: one list per scalar key of
    each ``epoch`` event, in arrival order — exactly the shape
    ``DynamicFederationEngine.run`` / ``launch.train`` always returned."""

    def __init__(self):
        self._history: Dict[str, List[float]] = {}
        self._totals: Dict[str, float] = {}
        self._warnings: List[MetricEvent] = []

    def emit(self, ev: MetricEvent) -> None:
        if ev.kind == "epoch":
            for k, v in ev.value.items():
                self._history.setdefault(k, []).append(v)
        elif ev.kind == "counter":
            self._totals[ev.name] = self._totals.get(ev.name, 0.0) + ev.value
        elif ev.kind == "warning":
            self._warnings.append(ev)

    def history(self) -> Dict[str, List[float]]:
        return self._history

    def totals(self) -> Dict[str, float]:
        return self._totals

    def warnings(self) -> List[MetricEvent]:
        return self._warnings


class JSONLSink(Sink):
    """Newline-delimited JSON stream.  Line 1 is the meta record
    ``{"kind": "meta", "schema": SCHEMA_VERSION, ...}``; every subsequent
    line is one ``MetricEvent``.  ``validate_jsonl`` round-trips it."""

    def __init__(self, path_or_file: Union[str, IO[str]],
                 run_info: Optional[Dict[str, Any]] = None):
        if isinstance(path_or_file, str):
            self._f: IO[str] = open(path_or_file, "w", encoding="utf-8")
            self._owns = True
        else:
            self._f = path_or_file
            self._owns = False
        meta = {"kind": "meta", "schema": SCHEMA_VERSION,
                "unix_time": time.time()}
        if run_info:
            meta["run"] = run_info
        self._f.write(json.dumps(meta) + "\n")

    def emit(self, ev: MetricEvent) -> None:
        self._f.write(json.dumps(ev.to_json()) + "\n")

    def close(self) -> None:
        self._f.flush()
        if self._owns:
            self._f.close()


class ConsoleSink(Sink):
    """Human progress lines — the ONE sanctioned print site in library
    code (the trainers' duplicated ``epoch ... loss=...`` scaffolding
    collapsed here).  Prints every ``log_every``-th epoch event plus all
    warnings."""

    _ORDER = ("loss", "disagreement", "drift", "wire_mb", "sigma_prod",
              "num_servers")
    _FMT = {"loss": ".4f", "disagreement": ".3e", "drift": ".3e",
            "wire_mb": ".2f", "sigma_prod": ".3f", "num_servers": ".0f"}

    def __init__(self, log_every: int = 1, prefix: str = "epoch"):
        self.log_every = max(1, int(log_every))
        self.prefix = prefix
        self._t0 = time.perf_counter()

    def emit(self, ev: MetricEvent) -> None:
        if ev.kind == "warning":
            msg = f"[obs:warn] {ev.name}: {ev.value.get('message', ev.value)}"
            print(msg)  # repro: ignore[print-in-library]: the sanctioned console sink
            return
        if ev.kind != "epoch" or ev.epoch is None:
            return
        if ev.epoch % self.log_every and ev.epoch != 0:
            return
        parts = [f"{self.prefix} {ev.epoch:4d}"]
        for k in self._ORDER:
            if k in ev.value:
                parts.append(f"{k}={ev.value[k]:{self._FMT[k]}}")
        parts.append(f"({time.perf_counter() - self._t0:.1f}s)")
        print("  ".join(parts))  # repro: ignore[print-in-library]: the sanctioned console sink


class MetricsHub:
    """Fan-out of typed metric events to every attached sink."""

    def __init__(self, sinks: Sequence[Sink] = ()):
        self.sinks: List[Sink] = list(sinks)

    def add_sink(self, sink: Sink) -> Sink:
        self.sinks.append(sink)
        return sink

    def _emit(self, ev: MetricEvent) -> None:
        for s in self.sinks:
            s.emit(ev)

    def counter(self, name: str, value: float, *, epoch: Optional[int] = None,
                **labels: Any) -> None:
        self._emit(MetricEvent("counter", name, float(value), epoch, labels))

    def gauge(self, name: str, value: float, *, epoch: Optional[int] = None,
              **labels: Any) -> None:
        self._emit(MetricEvent("gauge", name, float(value), epoch, labels))

    def histogram(self, name: str, values: Iterable[float], *,
                  epoch: Optional[int] = None, **labels: Any) -> None:
        self._emit(MetricEvent("histogram", name,
                               [float(v) for v in values], epoch, labels))

    def warning(self, name: str, message: str, *,
                epoch: Optional[int] = None, **fields: Any) -> None:
        payload = {"message": message, **fields}
        self._emit(MetricEvent("warning", name, payload, epoch))

    def observe_epoch(self, epoch: int, record: Dict[str, float],
                      **labels: Any) -> None:
        """The engine's full per-epoch record in one event (MemorySink
        turns it back into the legacy ``history`` dict)."""
        self._emit(MetricEvent("epoch", "epoch",
                               {k: float(v) for k, v in record.items()},
                               epoch, labels))

    def close(self) -> None:
        for s in self.sinks:
            s.close()


def load_jsonl(path: str) -> List[Dict[str, Any]]:
    with open(path, "r", encoding="utf-8") as f:
        return [json.loads(line) for line in f if line.strip()]


def validate_jsonl(records: List[Dict[str, Any]]) -> List[Dict[str, Any]]:
    """Validate a decoded JSONL telemetry stream against the versioned
    schema; returns the event records (meta stripped).  Raises
    ``ValueError`` with the offending record on any violation."""
    if not records:
        raise ValueError("empty telemetry stream: missing meta record")
    meta = records[0]
    if meta.get("kind") != "meta":
        raise ValueError(f"first record must be meta, got {meta!r}")
    if meta.get("schema") != SCHEMA_VERSION:
        raise ValueError(f"schema version {meta.get('schema')!r} != "
                         f"{SCHEMA_VERSION}")
    events = records[1:]
    for rec in events:
        kind = rec.get("kind")
        if kind not in _KINDS:
            raise ValueError(f"unknown event kind {kind!r}: {rec!r}")
        if not isinstance(rec.get("name"), str):
            raise ValueError(f"event without name: {rec!r}")
        val = rec.get("value")
        if kind in ("counter", "gauge"):
            ok = isinstance(val, (int, float)) and not isinstance(val, bool)
        elif kind == "histogram":
            ok = (isinstance(val, list)
                  and all(isinstance(v, (int, float)) for v in val))
        else:  # epoch / warning
            ok = isinstance(val, dict)
        if not ok:
            raise ValueError(f"bad value for {kind} event: {rec!r}")
        if "epoch" in rec and not isinstance(rec["epoch"], int):
            raise ValueError(f"non-integer epoch: {rec!r}")
        if "labels" in rec and not isinstance(rec["labels"], dict):
            raise ValueError(f"non-object labels: {rec!r}")
    return events
