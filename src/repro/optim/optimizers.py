"""Minimal pure-JAX optimizer library (no optax dependency).

All update rules are *elementwise* over pytree leaves, so they compose
transparently with the DFL client axes: a parameter leaf of shape
``(M, N, *w)`` with matching optimizer state behaves as M*N independent
optimizers — exactly the per-client local training of Alg. 1.

The paper's local update (Eq. 3) is ``sgd(gamma)`` with a constant step
size; the others are beyond-paper options (``faithful=False`` in the
trainer config).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Union

import jax
import jax.numpy as jnp

Schedule = Callable[[jax.Array], jax.Array]
ScalarOrSchedule = Union[float, Schedule]


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]                    # params -> state
    update: Callable[[Any, Any, Any], tuple]      # (grads, state, params) -> (new_params, new_state)


def _lr_at(lr: ScalarOrSchedule, count: jax.Array) -> jax.Array:
    return lr(count) if callable(lr) else jnp.asarray(lr, jnp.float32)


class SGDState(NamedTuple):
    count: jax.Array


def sgd(lr: ScalarOrSchedule) -> Optimizer:
    """Eq. (3): w <- w - gamma * grad."""

    def init(params):
        del params
        return SGDState(jnp.zeros((), jnp.int32))

    def update(grads, state, params):
        g = _lr_at(lr, state.count)

        def leaf(p, dg):
            # compute in the PARAM dtype: promoting to f32 would materialise
            # two f32 copies of every leaf (convert + result) — bf16-pure
            # SGD is the deployment contract for bf16 plans, f32 for f32.
            return p - g.astype(p.dtype) * dg.astype(p.dtype)

        new = jax.tree.map(leaf, params, grads)
        return new, SGDState(state.count + 1)

    return Optimizer(init, update)


class MomentumState(NamedTuple):
    count: jax.Array
    velocity: Any


def momentum(lr: ScalarOrSchedule, beta: float = 0.9, nesterov: bool = False) -> Optimizer:
    def init(params):
        return MomentumState(jnp.zeros((), jnp.int32),
                             jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params))

    def update(grads, state, params):
        g = _lr_at(lr, state.count)
        vel = jax.tree.map(lambda v, dg: beta * v + dg.astype(jnp.float32),
                           state.velocity, grads)
        if nesterov:
            step = jax.tree.map(lambda v, dg: beta * v + dg.astype(jnp.float32), vel, grads)
        else:
            step = vel
        new = jax.tree.map(lambda p, s: (p - g * s).astype(p.dtype), params, step)
        return new, MomentumState(state.count + 1, vel)

    return Optimizer(init, update)


class AdamState(NamedTuple):
    count: jax.Array
    mu: Any
    nu: Any


def adam(lr: ScalarOrSchedule, b1: float = 0.9, b2: float = 0.999,
         eps: float = 1e-8, weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        z = lambda p: jnp.zeros_like(p, jnp.float32)
        return AdamState(jnp.zeros((), jnp.int32),
                         jax.tree.map(z, params), jax.tree.map(z, params))

    def update(grads, state, params):
        count = state.count + 1
        g = _lr_at(lr, state.count)
        mu = jax.tree.map(lambda m, dg: b1 * m + (1 - b1) * dg.astype(jnp.float32),
                          state.mu, grads)
        nu = jax.tree.map(lambda v, dg: b2 * v + (1 - b2) * jnp.square(dg.astype(jnp.float32)),
                          state.nu, grads)
        c1 = 1 - b1 ** count.astype(jnp.float32)
        c2 = 1 - b2 ** count.astype(jnp.float32)

        def leaf(p, m, v):
            step = (m / c1) / (jnp.sqrt(v / c2) + eps)
            if weight_decay:
                step = step + weight_decay * p.astype(jnp.float32)
            return (p - g * step).astype(p.dtype)

        new = jax.tree.map(leaf, params, mu, nu)
        return new, AdamState(count, mu, nu)

    return Optimizer(init, update)


def clip_by_global_norm(optimizer: Optimizer, max_norm: float) -> Optimizer:
    """Wrap an optimizer with global-norm gradient clipping."""

    def update(grads, state, params):
        sq = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                 for g in jax.tree.leaves(grads))
        norm = jnp.sqrt(sq)
        scale = jnp.minimum(1.0, max_norm / (norm + 1e-12))
        grads = jax.tree.map(lambda dg: dg * scale, grads)
        return optimizer.update(grads, state, params)

    return Optimizer(optimizer.init, update)
