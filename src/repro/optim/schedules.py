"""Learning-rate schedules (pure functions of the step count)."""
from __future__ import annotations

import jax.numpy as jnp


def constant(value: float):
    return lambda count: jnp.asarray(value, jnp.float32)


def linear_warmup(peak: float, warmup_steps: int):
    def fn(count):
        frac = jnp.minimum(count.astype(jnp.float32) / max(warmup_steps, 1), 1.0)
        return peak * frac
    return fn


def cosine_decay(init: float, decay_steps: int, alpha: float = 0.0):
    def fn(count):
        frac = jnp.clip(count.astype(jnp.float32) / max(decay_steps, 1), 0.0, 1.0)
        cos = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
        return init * ((1 - alpha) * cos + alpha)
    return fn


def warmup_cosine(peak: float, warmup_steps: int, total_steps: int,
                  final_frac: float = 0.1):
    def fn(count):
        c = count.astype(jnp.float32)
        warm = peak * c / max(warmup_steps, 1)
        frac = jnp.clip((c - warmup_steps) / max(total_steps - warmup_steps, 1), 0.0, 1.0)
        cos = peak * (final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * frac)))
        return jnp.where(c < warmup_steps, warm, cos)
    return fn
