from repro.optim.optimizers import (Optimizer, adam, momentum, sgd,
                                    clip_by_global_norm)
from repro.optim.schedules import (constant, cosine_decay, linear_warmup,
                                   warmup_cosine)

__all__ = [
    "Optimizer", "sgd", "momentum", "adam", "clip_by_global_norm",
    "constant", "cosine_decay", "linear_warmup", "warmup_cosine",
]
