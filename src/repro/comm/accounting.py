"""On-wire byte accounting for the compressed-gossip layer.

``BytesTracker`` mirrors ``core.schedule.SigmaTracker``: a host-side
per-epoch accumulator the dynamic engine (and the static trainer) updates
once per epoch.  The count follows the payload-flooding wire model of
``comm.compressors``: during one consensus period every live DIRECTED link
carries one compressed row message per round, so

    epoch bytes = sum over links (i <- j)  of  T_S * row_bytes
    link (i <- j) is live iff  A_p[i, j] != 0, i != j

with ``row_bytes`` the compressor-metadata bytes of one server's message
(``compressors.tree_wire_bytes_per_server``), plus 4 bytes per message for
the push-sum weight scalar when ratio consensus is on.  The tracker also
carries the float32-uncompressed baseline of the SAME traffic so the
headline compression ratio needs no second run.

``analytic_row_bytes`` is the INDEPENDENT closed-form count per compressor
family; tests and the ``compressed_consensus`` benchmark cross-check it
against the metadata-derived ``Compressor.wire_bytes_per_row``.

Physical wire.  Under ``wire="physical"`` the collectives themselves move
the quantized codes: since PR 6 the whole pytree is flattened into ONE
padded code buffer + one scale buffer per server (``comm.compressors.
bucket_block`` layout), so each gossip round is exactly one all-gather of
s8 codes and one of f32 scales regardless of leaf count.
``tree_bucketed_wire_bytes_per_server`` counts exactly that layout, so the
``BytesTracker`` ledger reports the bytes the collectives actually ship
(cross-checked against compiled-HLO operand shapes by
``tests/test_wire.py`` via ``hlo_collective_bytes``).  The padded tail
costs at most ``lcm(chunk, 2) - 1`` elements over the metadata count of
the simulated wire.  ``physical_leaf_bytes`` /
``tree_physical_wire_bytes_per_server`` keep the PR-5 per-leaf blocked
layout for the legacy in-graph reference (``core.consensus.
gossip_scan_wire``).

One physical-wire accounting subtlety: push-sum's ``(M,)`` weight never
crosses a collective there — it mixes via the in-graph replicated matvec
(``core.consensus.ConsensusBackend._mix_weight``) — so ``BytesTracker``
adds its +4 B/message only on the simulated wire (``wire=`` ctor arg).
"""
from __future__ import annotations

from typing import Dict, List, Optional

import numpy as np

from repro.comm import compressors as cp


def uncompressed_row_bytes(d: int, bytes_per_elem: int = 4) -> int:
    """Baseline: one float32 (by default) replica row on the wire."""
    return d * bytes_per_elem


def analytic_row_bytes(compressor: cp.Compressor, d: int) -> int:
    """Closed-form on-wire bytes of one compressed d-element row — written
    independently of ``Compressor.wire_bytes_per_row`` (which derives the
    count from the actual payload shapes) so the two can cross-check."""
    if isinstance(compressor, cp.IdentityCompressor):
        return 4 * d
    if isinstance(compressor, cp.StochasticQuantizer):
        nc = -(-d // compressor.chunk)                    # ceil(d / chunk)
        code_bytes = int(np.ceil(d * compressor.bits / 8))  # codes unpadded
        return code_bytes + 4 * nc                        # + f32 scales
    if isinstance(compressor, cp.TopKCompressor):
        return compressor.k_for(d) * (4 + 4)              # values + indices
    if isinstance(compressor, cp.RandomKCompressor):
        return compressor.k_for(d) * 4                    # seed-shared idx
    raise ValueError(f"no analytic byte count for {compressor!r}")


def analytic_leaf_bytes(compressor: cp.Compressor, shape) -> int:
    """Closed form of ``Compressor.wire_bytes_per_leaf`` for a server-tree
    leaf shape (leading axis = server).  Shape-preserving quantizers chunk
    the leaf's LAST axis per row, so the scale count follows the leaf's
    row structure; flatten-based compressors reduce to the flat-row form."""
    shape = tuple(shape)
    d = int(np.prod(shape[1:]))
    if isinstance(compressor, cp.StochasticQuantizer):
        rows = int(np.prod(shape[1:-1])) if len(shape) > 2 else 1
        length = shape[-1] if len(shape) > 1 else 1
        nc = rows * -(-length // compressor.chunk)
        return int(np.ceil(d * compressor.bits / 8)) + 4 * nc
    return analytic_row_bytes(compressor, d)


def physical_leaf_bytes(quantizer: cp.StochasticQuantizer, shape,
                        block: int) -> int:
    """On-wire bytes of one server's PHYSICAL-wire message for one leaf per
    round: the leaf's row is flattened and padded to ``nb`` blocks of
    ``min(block, d)`` elements, and every round each block's codes + scales
    cross the collective.  This is the padded layout the shard_map program
    gathers, not the unpadded metadata count of the simulated wire.

    Assumes UNSHARDED rows — true for every ledger-carrying path today
    (the trainer's shard_map mesh is ``(server,)``-only, and the engine's
    string backends flatten whole rows).  A tp/fsdp-sharded shard_map
    program flattens each device's LOCAL shard instead, so its per-shard
    chunk/pad boundaries give a slightly larger scale count than this
    closed form; if the ledger ever meets such a mesh, derive the count
    from the local shard shapes."""
    if not isinstance(quantizer, cp.StochasticQuantizer):
        raise ValueError(
            f"the physical wire has a byte layout only for the int8/int4 "
            f"quantizers, got {quantizer!r}")
    d = int(np.prod(tuple(shape)[1:]))
    blk = min(block, d)
    nb = -(-d // blk)
    code_bytes, scale_bytes = quantizer.wire_block_bytes(blk)
    return nb * (code_bytes + scale_bytes)


def tree_physical_wire_bytes_per_server(quantizer: cp.StochasticQuantizer,
                                        tree, block: int) -> int:
    """Physical-wire bytes of one server's full message per round in the
    PR-5 per-leaf layout: each leaf flattened and blocked independently
    (mirroring ``core.consensus.gossip_scan_wire``, the legacy in-graph
    reference).  The shipping paths use the bucketed layout —
    ``tree_bucketed_wire_bytes_per_server``."""
    import jax
    return sum(physical_leaf_bytes(quantizer, l.shape, block)
               for l in jax.tree.leaves(tree))


def tree_bucketed_wire_bytes_per_server(quantizer: cp.StochasticQuantizer,
                                        tree, block: int) -> int:
    """On-wire bytes of one server's full message per round in the BUCKETED
    physical layout (``comm.compressors.bucket_block``): the whole pytree
    flattened into one zero-padded code buffer plus one scale buffer, so
    each round's collective cost is ``nb`` blocks of ``blk`` codes (int4
    packed two per byte) + one f32 scale per chunk — what ONE all-gather
    of codes and one of scales actually move.  Successor of
    ``tree_physical_wire_bytes_per_server``; same unsharded-rows assumption
    as ``physical_leaf_bytes``.  Cross-checked against compiled-HLO operand
    shapes (``hlo_collective_bytes``) in ``tests/test_wire.py`` and the
    ``consensus_backends`` benchmark."""
    if not isinstance(quantizer, cp.StochasticQuantizer):
        raise ValueError(
            f"the physical wire has a byte layout only for the int8/int4 "
            f"quantizers, got {quantizer!r}")
    import jax
    d_tot = sum(int(np.prod(l.shape[1:]))
                for l in jax.tree.leaves(tree))
    blk, nb = cp.bucket_block(d_tot, block, quantizer.chunk)
    code_bytes, scale_bytes = quantizer.wire_block_bytes(blk)
    return nb * (code_bytes + scale_bytes)


def hlo_collective_bytes(hlo_text: str) -> List[Dict[str, object]]:
    """Parse a compiled-HLO dump into its gather/permute collectives:
    ``[{op, dtype, shape, bytes}, ...]`` with ``bytes`` the RESULT buffer
    size (for an all-gather over M participants, each participant ships
    ``bytes / M``).  Handles both the synchronous form and the async
    ``-start`` rewrite (whose result is an (operand, result) tuple — the
    LARGEST element is the gathered buffer).  Test/benchmark
    instrumentation for the physical-wire claim: the dtypes and shapes
    here are what actually crossed the interconnect, and must match the
    codec's ``wire_block_bytes``.

    Kept as the comm-facing name; since PR 8 the parser itself lives in
    ``repro.analysis.hlo_audit.collective_sites`` so the byte ledger, the
    wire regression tests and the contract auditor
    (``analysis.contracts``) share ONE HLO pass."""
    from repro.analysis.hlo_audit import collective_sites
    return collective_sites(hlo_text)


class BytesTracker:
    """Host-side on-wire byte accumulator for compressed consensus.

    Per epoch, ``update`` takes the epoch's mixing matrix (its off-diagonal
    support = the live directed links), the round count, the per-row
    compressed bytes and the per-row element count, and returns this
    epoch's total; ``per_link`` holds the per-link (M, M) byte matrix of
    the LAST epoch (entry [i, j] = bytes shipped j -> i this epoch).
    Cumulative totals drive ``ratio()`` — uncompressed-f32 bytes over
    compressed bytes for identical traffic."""

    def __init__(self, compressor: cp.Compressor, *, push_sum: bool = False,
                 wire: str = "simulated",
                 baseline_bytes_per_elem: int = 4):
        self.compressor = compressor
        self.push_sum = push_sum
        self.wire = wire
        self.baseline_bytes_per_elem = baseline_bytes_per_elem
        self.total_bytes = 0
        self.baseline_bytes = 0
        self.per_link: Optional[np.ndarray] = None
        self.history: List[Dict[str, float]] = []

    def _msg_bytes(self, row_bytes: int) -> int:
        # push-sum ships the (num, w) pair: + one f32 weight scalar per
        # msg — on the SIMULATED wire only.  Under wire="physical" the
        # (M,) weight recursion is an in-graph replicated matvec
        # (``core.consensus.ConsensusBackend._mix_weight``): no collective
        # ever carries it, the padded code+scale layout is the whole
        # message, and the HLO byte audit would catch a phantom +4
        # (asserted in ``tests/test_wire.py``).
        if self.push_sum and self.wire != "physical":
            return row_bytes + 4
        return row_bytes

    def epoch_link_bytes(self, a_np: np.ndarray, t_server: int,
                         row_bytes: int) -> np.ndarray:
        """(M, M) int64 matrix of this epoch's per-link bytes: entry [i, j]
        counts the j -> i messages (one per round on every live link)."""
        a = np.asarray(a_np)
        live = (a != 0) & ~np.eye(a.shape[0], dtype=bool)
        return live.astype(np.int64) * (t_server * self._msg_bytes(row_bytes))

    def update(self, a_np: np.ndarray, t_server: int, *, row_bytes: int,
               elems_per_row: int) -> float:
        """Account one epoch; returns its total on-wire bytes."""
        self.per_link = self.epoch_link_bytes(a_np, t_server, row_bytes)
        epoch_bytes = int(self.per_link.sum())
        n_msgs = int((self.per_link > 0).sum()) * t_server
        base_row = self._msg_bytes(uncompressed_row_bytes(
            elems_per_row, self.baseline_bytes_per_elem))
        epoch_baseline = n_msgs * base_row
        self.total_bytes += epoch_bytes
        self.baseline_bytes += epoch_baseline
        self.history.append({"bytes": float(epoch_bytes),
                             "baseline": float(epoch_baseline)})
        return float(epoch_bytes)

    def update_many(self, a_stack, t_server: int, *, row_bytes: int,
                    elems_per_row: int) -> List[tuple]:
        """Account one SUPEREPOCH: K sequential per-epoch updates in one
        call (the engine dispatches K epochs per compiled megastep, but the
        ledger's history stays per-epoch).  ``a_stack`` is an iterable of K
        per-epoch mixing matrices; returns ``[(epoch_bytes, cumulative
        ratio after that epoch, that epoch's per-link matrix), ...]`` — the
        same values K individual ``update``/``ratio``/``per_link`` reads
        would have produced, so the superepoch engine's history columns
        match the barrier engine's exactly."""
        out = []
        for a_np in a_stack:
            b = self.update(a_np, t_server, row_bytes=row_bytes,
                            elems_per_row=elems_per_row)
            out.append((b, self.ratio(), self.per_link))
        return out

    def ratio(self) -> float:
        """Cumulative compression ratio: uncompressed-f32 bytes of the same
        traffic over actually-shipped bytes (>= 1 for real compressors)."""
        if self.total_bytes == 0:
            return float("inf") if self.baseline_bytes else 1.0
        return self.baseline_bytes / self.total_bytes
