"""Lossy compressors for inter-server gossip messages.

Every compressor is a pure ``compress``/``decompress`` pair over 2-D
``(M, d)`` arrays — row i is server i's flattened outgoing message — with
static output shapes, so both directions trace cleanly inside jit.  The
consensus period then mixes the DECOMPRESSED values
(``core.consensus.CompressedBackend``): mathematically that is exactly what
every receiver reconstructs from the on-wire payload.

Wire model.  Gossip is linear in the payloads, so one compressed message
per server per consensus period, forwarded T_S hops ("payload flooding"),
realises the whole T_S-round period on decompressed values.  The on-wire
cost accounted by ``comm.accounting.BytesTracker`` is therefore

    live directed links  x  T_S rounds  x  wire_bytes_per_row.

Compressors:

* ``IdentityCompressor``            exact passthrough (accounting baseline).
* ``StochasticQuantizer(bits, chunk)``  int8/int4 with per-chunk absmax
      scales and UNBIASED stochastic rounding ``q = floor(x * (1/s) + u)``,
      ``u ~ U[0, 1)``: ``E[decompress] = x``, so quantization noise is
      zero-mean and error feedback only has to absorb its variance.  With
      no rng key the rounding degrades to deterministic round-to-nearest.
* ``TopKCompressor(ratio)``         per-row magnitude top-k: values plus
      explicit int32 indices cross the wire.
* ``RandomKCompressor(ratio)``      k coordinates sampled per call from the
      SHARED rng key: every server transmits the same coordinate set, so
      the indices never cross the wire (receivers regenerate them from the
      shared seed) and the gossip operator acts identically per coordinate.

``make_compressor`` parses the ``DFLConfig.compression`` /
``--compression`` spec grammar::

    none | int8[:CHUNK] | int4[:CHUNK] | top_k:RATIO | random_k:RATIO

Wire codecs.  The quantizers double as SHARD-SHAPED wire codecs for the
physical-wire gossip paths (``core.consensus.make_gossip_shard_map`` /
``make_ring_gossip`` with ``codec=``): ``StochasticQuantizer.encode_block``
turns one flattened block into the exact byte layout that crosses the
collective — int8 codes (two int4 codes packed per byte via ``pack_int4``)
plus per-chunk f32 scales — and ``decode_block`` inverts it.  Both are thin
wrappers over the same ``compress``/``decompress`` math, so the in-graph
wire simulation and the physical collective path share ONE numerics
definition; under the shared dither convention (``wire_dither``) the two
are bit-identical (asserted in ``tests/test_wire.py``).
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# int4 byte packing + the shared wire-dither convention
# ---------------------------------------------------------------------------


def pack_int4(codes: jax.Array) -> jax.Array:
    """Pack int4 codes (int8 array, values in [-8, 7]) two per byte along
    the last axis: element ``2i`` in the low nibble, ``2i+1`` in the high
    nibble.  An odd-length axis is padded with one zero code (the receiver
    slices it off in ``unpack_int4``).  Exactly invertible, so routing
    codes through ``pack_int4``/``unpack_int4`` never changes numerics —
    it only halves the bytes the collective moves."""
    if codes.shape[-1] % 2:
        codes = jnp.pad(codes, [(0, 0)] * (codes.ndim - 1) + [(0, 1)])
    u = jax.lax.bitcast_convert_type(codes, jnp.uint8)
    lo = u[..., 0::2] & 0x0F
    hi = (u[..., 1::2] & 0x0F) << 4
    return jax.lax.bitcast_convert_type(lo | hi, jnp.int8)


def unpack_int4(packed: jax.Array, length: int) -> jax.Array:
    """Inverse of ``pack_int4``: (..., ceil(length/2)) bytes -> (..., length)
    sign-extended int8 codes."""
    u = jax.lax.bitcast_convert_type(packed, jnp.uint8)
    lo = (u & 0x0F).astype(jnp.int8)
    hi = ((u >> 4) & 0x0F).astype(jnp.int8)
    # sign-extend the 4-bit values: v in [0, 15] -> (v ^ 8) - 8 in [-8, 7]
    both = jnp.stack([lo, hi], axis=-1)
    both = ((both ^ 8) - 8).astype(jnp.int8)
    flat = both.reshape(both.shape[:-2] + (-1,))
    return flat[..., :length]


def bucket_block(d_tot: int, block: int, chunk: int) -> Tuple[int, int]:
    """``(blk, nb)`` of the BUCKETED physical-wire layout: the whole server
    pytree flattened to ``d_tot`` elements and cut into ``nb`` equal blocks
    of ``blk`` elements (zero-padded tail).  ``blk`` is ``min(block,
    d_tot)`` rounded UP to a multiple of ``lcm(chunk, 2)``, so (a) chunk
    boundaries never cross a block — every block encodes independently —
    and (b) a block's packed-int4 codes are a whole number of bytes, making
    per-block views of the packed code buffer free slices.  Shared by the
    bucketed gossip programs (``core.consensus.gossip_scan_wire_bucketed``
    / ``make_gossip_shard_map``'s codec mode) and the byte ledger
    (``comm.accounting.tree_bucketed_wire_bytes_per_server``), which must
    agree on the padded layout for the HLO byte audit to close."""
    d_tot = max(int(d_tot), 1)
    unit = chunk if chunk % 2 == 0 else 2 * chunk
    blk = -(-min(block, d_tot) // unit) * unit
    return blk, -(-d_tot // blk)


def wire_dither(key: jax.Array, shape: Tuple[int, ...], *, leaf, rnd,
                server, block) -> jax.Array:
    """THE stochastic-rounding dither of the wire paths: uniform [0, 1)
    noise keyed by ``(leaf index, gossip round, server row, block index)``.

    Every wire execution — the in-graph simulation
    (``core.consensus.gossip_scan_wire_bucketed`` and the legacy per-leaf
    form), the physical shard_map / ring collectives, and the
    error-feedback residual update — derives its dither from this one
    convention, which is what makes them bit-identical under a shared
    key: the same (leaf, round, server, block) cell always rounds with
    the same noise, no matter which execution produced it.  All four
    coordinates may be traced (the shard_map paths fold in
    ``lax.axis_index`` and loop counters).

    The per-element noise is a keyed counter hash (``_mix32`` murmur
    avalanche over the element counters, same idiom as
    ``keyed_index_sample``), NOT a threefry ``jax.random.uniform``: the
    dither is regenerated every gossip round on every device over the
    whole bucket, and at benchmark scale the ~20-round threefry was the
    single largest per-round compute on the wire path (~35% of the
    consensus period) — the 24-bit-resolution hash has the avalanche
    quality stochastic rounding needs at a fraction of the ALU work.
    The four scalar ``fold_in``s stay threefry: they are O(1) and define
    the coordinate keying."""
    k = jax.random.fold_in(key, leaf)
    k = jax.random.fold_in(k, rnd)
    k = jax.random.fold_in(k, server)
    k = jax.random.fold_in(k, block)
    kd = jax.random.key_data(k).astype(jnp.uint32)
    n = int(np.prod(shape, dtype=np.int64))
    ctr = jax.lax.iota(jnp.uint32, n)
    x = _mix32((ctr ^ kd[-1]) * jnp.uint32(0x9E3779B9) ^ kd[0])
    return ((x >> jnp.uint32(8)).astype(jnp.float32)
            * jnp.float32(2.0 ** -24)).reshape(shape)


# ---------------------------------------------------------------------------
# counter-based O(k) index sampling (random-k at LM scale)
# ---------------------------------------------------------------------------


def _mix32(x: jax.Array) -> jax.Array:
    """murmur3-style avalanche on uint32 (the Feistel round function)."""
    x = (x ^ (x >> 16)) * jnp.uint32(0x85EBCA6B)
    x = (x ^ (x >> 13)) * jnp.uint32(0xC2B2AE35)
    return x ^ (x >> 16)


def keyed_index_sample(key: jax.Array, d: int, k: int) -> jax.Array:
    """``k`` DISTINCT uniform indices in ``[0, d)`` in O(k) work: encrypt
    the counters ``0..k-1`` with a keyed 4-round Feistel bijection over the
    smallest even-bit power-of-two domain ``>= d`` and cycle-walk any value
    that lands outside ``[0, d)`` back through the cipher.

    This replaces the ``jax.random.permutation`` sampler, whose O(D log D)
    sort (and O(D) memory) is fine at benchmark scale but prohibitive at LM
    scale — the bijection gives the same guarantees random-k needs (distinct
    indices, per-coordinate uniformity over keys, identical on every server
    given the shared key) at O(k).  Cycle-walking terminates because the
    cipher is a bijection: the walk traverses a cycle that must re-enter
    ``[0, d)`` (expected < 4 steps; the domain is < 4d).

    ``d`` is capped at ``2^31 - 1``: the indices gather with int32 (the
    width jnp indexing uses without x64), and past that the wrap would
    silently alias coordinates — and past ``2^32`` the uint32 cipher stops
    being a bijection.  That is also the per-axis size ceiling of the
    arrays these coordinates index, so the cap costs nothing in practice;
    lifting it means moving the Feistel (and the gather) to 64-bit."""
    if not 0 < k <= d:
        raise ValueError(f"need 0 < k <= d, got k={k}, d={d}")
    if d > np.iinfo(np.int32).max:
        raise ValueError(
            f"keyed_index_sample is 32-bit (uint32 cipher, int32 gather "
            f"indices): d={d} exceeds 2^31 - 1 and would silently alias "
            f"coordinates")
    half = max(1, -(-max(d - 1, 1).bit_length() // 2))    # ceil(bits/2)
    mask = jnp.uint32((1 << half) - 1)
    round_keys = jax.random.bits(key, (4,), dtype=jnp.uint32)

    def feistel(x):
        left, right = x >> half, x & mask
        for rk in round_keys:
            left, right = right, left ^ (_mix32(right ^ rk) & mask)
        return (left << half) | right

    def walk(x):
        return jax.lax.while_loop(lambda v: v >= d, lambda v: feistel(v), x)

    idx = jax.vmap(walk)(feistel(jnp.arange(k, dtype=jnp.uint32)))
    return idx.astype(jnp.int32)


class Compressed(NamedTuple):
    """On-wire representation of one compressed ``(M, d)`` message batch.

    ``data`` is the payload (quantized codes or kept values); ``scale`` the
    per-chunk dequantization scales (quantizers only); ``idx`` the kept
    coordinates (sparsifiers only — shape ``(M, k)`` for top-k, shared
    ``(k,)`` for seed-coordinated random-k).  Unused fields are ``None``."""

    data: jax.Array
    scale: Optional[jax.Array] = None
    idx: Optional[jax.Array] = None


@dataclasses.dataclass(frozen=True)
class Compressor:
    """Base: a pure compress/decompress pair + metadata-derived wire bytes.

    ``wire_bits_data`` is the TRUE on-wire width of one ``data`` element —
    it may be narrower than the array dtype carrying it in memory (int4
    codes ride in int8 arrays).  ``idx_on_wire`` is False when receivers
    can reconstruct the indices without transmission (shared-seed
    random-k).  ``shape_preserving`` marks compressors whose round-trip is
    purely elementwise over the input's natural shape (chunking along the
    LAST axis only): ``roundtrip_tree`` then skips the ``(M, d)`` flatten
    entirely, which under pjit is the difference between per-shard local
    compute and replicating every leaf (the flatten merges sharded weight
    axes)."""

    wire_bits_data = 32
    idx_on_wire = True
    shape_preserving = False

    name = "?"

    def compress(self, x: jax.Array,
                 key: Optional[jax.Array] = None) -> Compressed:
        raise NotImplementedError

    def decompress(self, comp: Compressed, d: int) -> jax.Array:
        raise NotImplementedError

    def roundtrip(self, x: jax.Array,
                  key: Optional[jax.Array] = None) -> jax.Array:
        """What the receivers reconstruct: D(C(x)), in ``x``'s dtype."""
        return self.decompress(self.compress(x, key),
                               x.shape[-1]).astype(x.dtype)

    def wire_bytes_per_row(self, d: int) -> int:
        """On-wire bytes of ONE server's compressed d-element message,
        derived from the ACTUAL compressed representation (``jax.eval_shape``
        over ``compress`` — payload metadata, not a closed form; the
        independent closed forms live in ``comm.accounting.
        analytic_row_bytes`` and the two are cross-checked by tests and the
        ``compressed_consensus`` benchmark)."""
        return self.wire_bytes_per_leaf((1, d))

    def wire_bytes_per_leaf(self, shape) -> int:
        """Bytes of one server's compressed message for a server-tree leaf
        of the given shape (leading axis = server): what actually crosses
        the wire, derived from the payload metadata of compressing exactly
        what ``roundtrip_tree`` compresses — the flat ``(1, d)`` row for
        flatten-based compressors, the natural ``(1, *w)`` shape for
        shape-preserving ones (their chunk count follows the leaf's last
        axis)."""
        shape = tuple(shape)
        if not self.shape_preserving:
            shape = (1, int(np.prod(shape[1:])))
        else:
            shape = (1,) + shape[1:]
        comp = jax.eval_shape(
            lambda x: self.compress(x, key=jax.random.key(0)),
            jax.ShapeDtypeStruct(shape, jnp.float32))
        total = int(np.ceil(comp.data.size * self.wire_bits_data / 8))
        if comp.scale is not None:
            total += comp.scale.size * comp.scale.dtype.itemsize
        if comp.idx is not None and self.idx_on_wire:
            total += comp.idx.size * comp.idx.dtype.itemsize
        return total


@dataclasses.dataclass(frozen=True)
class IdentityCompressor(Compressor):
    """Exact passthrough — the float32-wire baseline of the accounting, and
    the compressor under which the whole layer degenerates exactly."""

    name = "identity"
    shape_preserving = True

    def compress(self, x, key=None):
        del key
        return Compressed(data=x)

    def decompress(self, comp, d):
        return comp.data[..., :d]


@dataclasses.dataclass(frozen=True)
class StochasticQuantizer(Compressor):
    """int8/int4 quantization with per-chunk absmax scales.

    The LAST axis of the input is split into ``chunk``-element chunks (the
    last may be partial); chunk c gets scale ``s_c = absmax_c / qmax``
    (``qmax = 2^{bits-1}-1``) and codes ``q = clip(floor(x * (1/s_c) + u),
    -qmax, qmax)`` with dither ``u ~ U[0, 1)`` — unbiased stochastic
    rounding (round-to-nearest when no key is given).  The grid step is
    applied as a multiply by the per-chunk reciprocal ``1/s_c`` (division
    was the hottest per-element op of the physical-wire round); ``s_c``
    itself stays the on-wire scale, and every encoder — in-graph,
    shard_map, Pallas — derives the same reciprocal bitwise.  On the wire: UNPADDED codes
    + one f32 scale per chunk; int4 codes are carried in int8 arrays in
    memory but counted at 4 bits.

    Shape preserving: every op is elementwise except a last-axis-only
    reshape, so ``(M, *w)`` leaves compress in their natural layout — under
    pjit each device quantizes its local shard (chunk boundaries follow the
    leaf's rows, which is also what a real per-tensor wire format does),
    no gather, no flatten.  Pass ``dither`` explicitly to share the
    randomness with a fused kernel (``kernels.consensus_mix.
    quantized_consensus_mix_2d`` parity)."""

    bits: int = 8
    chunk: int = 256

    def __post_init__(self):
        if self.bits not in (4, 8):
            raise ValueError(f"bits must be 4 or 8, got {self.bits}")
        if self.chunk < 1:
            raise ValueError("chunk must be >= 1")

    @property
    def name(self):
        return f"int{self.bits}"

    @property
    def wire_bits_data(self):
        return self.bits

    shape_preserving = True

    @property
    def qmax(self) -> float:
        return float(2 ** (self.bits - 1) - 1)

    def _scales(self, x32: jax.Array) -> jax.Array:
        """(..., nc) per-chunk scales over the last axis of a float32 array
        (zero-padded virtually: a trailing partial chunk uses only its real
        elements)."""
        length = x32.shape[-1]
        nc = -(-length // self.chunk)
        pad = nc * self.chunk - length
        if pad:
            x32 = jnp.pad(x32, [(0, 0)] * (x32.ndim - 1) + [(0, pad)])
        chunked = x32.reshape(x32.shape[:-1] + (nc, self.chunk))
        absmax = jnp.max(jnp.abs(chunked), axis=-1)
        # multiply by the reciprocal CONSTANT, never divide: XLA's
        # simplifier rewrites float division by a constant into a
        # reciprocal multiply in SOME programs and not in others, which
        # skews the scale by 1 ulp between two compilations of this same
        # formula (observed between a shard_map wire program and the
        # in-graph oracle it must match bitwise).  An explicit literal
        # leaves the compiler nothing to rewrite; the Pallas consensus
        # kernels use the same form.
        return jnp.where(absmax > 0, absmax * (1.0 / self.qmax), 1.0)

    def _per_elem(self, scale: jax.Array, d: int) -> jax.Array:
        """Broadcast (..., nc) chunk scales back onto the d real last-axis
        elements — codes ship UNPADDED, only the scales carry the chunk
        structure."""
        return jnp.repeat(scale, self.chunk, axis=-1)[..., :d]

    def compress(self, x, key=None, *, dither=None):
        d = x.shape[-1]
        x32 = x.astype(jnp.float32)
        scale = self._scales(x32)
        if dither is None:
            dither = (jax.random.uniform(key, x32.shape)
                      if key is not None else 0.5)
        # Quantize by MULTIPLYING with the reciprocal of the on-wire scale
        # (``inv`` is per-chunk, so the two tiny divisions are amortised
        # over ``chunk`` elements): per-element division was the single
        # hottest op of the physical-wire round on a host backend.  The
        # reciprocal is computed from the canonical wire scale — every
        # encoder (in-graph, shard_map, Pallas kernels) derives the same
        # ``1/s_c`` bitwise, which is what keeps their codes identical.
        inv = 1.0 / scale
        if d % self.chunk == 0:
            # chunk-multiple fast path (every bucketed-wire block, by
            # ``bucket_block`` construction): scale in the (..., nc,
            # chunk) layout so the chunk reciprocal broadcasts, instead
            # of materialising a full-width per-element scale vector.
            # Same multiply/add/floor operands element for element, so
            # the codes are bitwise identical to the general path.
            x3 = x32.reshape(x32.shape[:-1] + (-1, self.chunk))
            u3 = (dither if jnp.ndim(dither) == 0
                  else jnp.reshape(dither, x3.shape))
            q = (jnp.clip(jnp.floor(x3 * inv[..., None] + u3),
                          -self.qmax, self.qmax)
                 .astype(jnp.int8).reshape(x32.shape))
        else:
            q = jnp.clip(jnp.floor(x32 * self._per_elem(inv, d) + dither),
                         -self.qmax, self.qmax).astype(jnp.int8)
        return Compressed(data=q, scale=scale)

    def decompress(self, comp, d):
        scale = self._per_elem(comp.scale, d)
        return comp.data[..., :d].astype(jnp.float32) * scale

    # -- shard-shaped wire codec (the physical-wire gossip byte layout) ------
    def encode_block(self, x: jax.Array, dither) -> Tuple[jax.Array,
                                                          jax.Array]:
        """Encode a block (last axis = the flattened slice a device ships)
        into its ON-WIRE representation: ``(codes, scales)`` where ``codes``
        is int8 — for ``bits=4``, two codes packed per byte
        (``pack_int4``) — and ``scales`` one f32 per chunk.  A thin wrapper
        over ``compress``, so the wire format and the in-graph simulation
        are ONE numerics definition: under the same dither,
        ``decode_block(*encode_block(x, u))`` is bitwise
        ``decompress(compress(x, dither=u))``.

        Zero padding is scale-neutral by construction: ``|0|`` never raises
        a chunk's absmax, an all-pad chunk gets scale 1, and a pad element
        quantizes to code ``floor(0 + u) = 0`` for every dither ``u < 1`` —
        so zero-padded tails decode to exact zeros and cannot perturb the
        real data's quantization grid (asserted in ``tests/test_wire.py``).
        """
        comp = self.compress(x, dither=dither)
        codes = pack_int4(comp.data) if self.bits == 4 else comp.data
        return codes, comp.scale

    def decode_block(self, codes: jax.Array, scales: jax.Array,
                     length: int) -> jax.Array:
        """Invert ``encode_block``: unpack (int4) and dequantize to f32."""
        q = unpack_int4(codes, length) if self.bits == 4 else codes
        return self.decompress(Compressed(data=q, scale=scales), length)

    def code_chunks(self, codes: jax.Array, length: int) -> jax.Array:
        """Unpacked integer codes as f32 in per-chunk layout ``(..., nc,
        chunk)`` — the fused-decode surface of the bucketed wire.  Gossip
        consumers fold the per-chunk scales (and the mixing-row weight)
        into one broadcast factor per chunk, so dequantize never
        materialises a full-width per-element scale vector:
        ``(code_chunks(c, d) * scales[..., None]).reshape(..., d)`` is
        bitwise ``decode_block(c, scales, d)`` — the same scale-times-code
        products in the same order, only the broadcast shape differs.
        Requires ``length`` to be a chunk multiple (bucket blocks are, by
        ``bucket_block`` construction)."""
        if length % self.chunk:
            raise ValueError(
                f"code_chunks needs a chunk-multiple length, got {length} "
                f"with chunk={self.chunk}")
        q = unpack_int4(codes, length) if self.bits == 4 else codes
        return q.astype(jnp.float32).reshape(
            q.shape[:-1] + (length // self.chunk, self.chunk))

    def wire_block_bytes(self, length: int) -> Tuple[int, int]:
        """(code bytes, scale bytes) of one encoded ``length``-element
        block — the exact operand sizes of the physical-wire collective,
        cross-checked against compiled-HLO shapes in ``tests/test_wire.py``.
        """
        nc = -(-length // self.chunk)
        code_bytes = -(-length // 2) if self.bits == 4 else length
        return code_bytes, 4 * nc


@dataclasses.dataclass(frozen=True)
class TopKCompressor(Compressor):
    """Per-row magnitude top-k sparsification: each server keeps its
    ``k = max(1, round(ratio * d))`` largest-|.| coordinates.  Biased (EF
    recommended); both values AND int32 indices cross the wire — contrast
    ``RandomKCompressor``, whose shared coordinates cost zero index bytes."""

    ratio: float = 0.05

    name = "top_k"

    def __post_init__(self):
        if not 0.0 < self.ratio <= 1.0:
            raise ValueError(f"top_k ratio must be in (0, 1], got {self.ratio}")

    def k_for(self, d: int) -> int:
        return max(1, min(d, int(round(self.ratio * d))))

    def compress(self, x, key=None):
        del key
        k = self.k_for(x.shape[1])
        _, idx = jax.lax.top_k(jnp.abs(x), k)
        vals = jnp.take_along_axis(x, idx, axis=1)
        return Compressed(data=vals, idx=idx.astype(jnp.int32))

    def decompress(self, comp, d):
        m = comp.data.shape[0]
        out = jnp.zeros((m, d), jnp.float32)
        rows = jnp.arange(m)[:, None]
        return out.at[rows, comp.idx].set(comp.data.astype(jnp.float32))


@dataclasses.dataclass(frozen=True)
class RandomKCompressor(Compressor):
    """Seed-coordinated random-k sparsification: ONE random coordinate set
    per call (from the shared rng key) used by every server, so receivers
    regenerate the indices from the seed and only the values cross the wire.
    Biased per call (no d/k rescale — error feedback absorbs it, and the
    unscaled form keeps values bounded, which quantizer-style downstream
    stages prefer).

    Coordinates come from the counter-based ``keyed_index_sample`` —
    O(k) work and memory (a keyed Feistel bijection over the counters)
    instead of the O(D log D) full ``jax.random.permutation`` sort, which
    is what makes seed-regeneration viable at LM scale on the receivers."""

    ratio: float = 0.05

    name = "random_k"
    idx_on_wire = False

    def __post_init__(self):
        if not 0.0 < self.ratio <= 1.0:
            raise ValueError(
                f"random_k ratio must be in (0, 1], got {self.ratio}")

    def k_for(self, d: int) -> int:
        return max(1, min(d, int(round(self.ratio * d))))

    def compress(self, x, key=None):
        if key is None:
            raise ValueError("random_k needs the shared rng key (the "
                             "coordinate set IS the seed)")
        d = x.shape[1]
        idx = keyed_index_sample(key, d, self.k_for(d))
        return Compressed(data=x[:, idx], idx=idx)

    def decompress(self, comp, d):
        m = comp.data.shape[0]
        out = jnp.zeros((m, d), jnp.float32)
        return out.at[:, comp.idx].set(comp.data.astype(jnp.float32))


def make_compressor(spec: str) -> Compressor:
    """Parse a compression spec string (see module docstring grammar).

    ``"none"`` deliberately raises: it means the compression layer is OFF
    (no wrapper is built at all), not that an identity compressor runs —
    callers guard on it before resolving a compressor."""
    s = spec.strip()
    if s in ("none", ""):
        raise ValueError("compression='none' disables the layer; there is "
                         "no compressor to build")
    head, _, arg = s.partition(":")
    if head in ("int8", "int4"):
        chunk = int(arg) if arg else 256
        return StochasticQuantizer(bits=int(head[3:]), chunk=chunk)
    if head in ("top_k", "random_k"):
        if not arg:
            raise ValueError(f"{head} needs a keep ratio, e.g. '{head}:0.05'")
        cls = TopKCompressor if head == "top_k" else RandomKCompressor
        return cls(ratio=float(arg))
    if head == "identity":
        return IdentityCompressor()
    raise ValueError(f"unknown compression spec {spec!r}; expected none | "
                     f"int8[:chunk] | int4[:chunk] | top_k:ratio | "
                     f"random_k:ratio")


# ---------------------------------------------------------------------------
# pytree wrappers over the (M, d) row layout
# ---------------------------------------------------------------------------


def roundtrip_tree(compressor: Compressor, tree: Any,
                   key: Optional[jax.Array] = None,
                   flat_sharding=None) -> Any:
    """Wire-simulate a server tree (leaves ``(M, *w)``): each leaf is
    flattened to ``(M, d)`` rows, compressed and decompressed per leaf (the
    rng key folded per leaf index so dither/coordinates differ across
    leaves), and reshaped back in the leaf's dtype.

    Shape-preserving compressors (identity, the quantizers) skip the
    flatten and round-trip each leaf in its natural ``(M, *w)`` layout —
    elementwise per-shard work under pjit.  Flatten-based compressors
    (top-k / random-k need the whole row to rank coordinates) reshape to
    ``(M, d)``; ``flat_sharding`` is an optional NamedSharding for that
    view (e.g. ``P("server", ("replica", "model"))`` — the same constraint
    ``consensus.gossip_scan_blocked`` uses): without it the partitioner
    replicates the merged weight axes, which at LM scale is an OOM."""
    leaves, treedef = jax.tree.flatten(tree)
    out = []
    for i, leaf in enumerate(leaves):
        k = jax.random.fold_in(key, i) if key is not None else None
        if compressor.shape_preserving:
            out.append(compressor.roundtrip(leaf, k))
            continue
        x = leaf.reshape(leaf.shape[0], -1)
        if flat_sharding is not None:
            x = jax.lax.with_sharding_constraint(x, flat_sharding)
        y = compressor.roundtrip(x, k)
        if flat_sharding is not None:
            y = jax.lax.with_sharding_constraint(y, flat_sharding)
        out.append(y.reshape(leaf.shape))
    return jax.tree.unflatten(treedef, out)


def tree_message_elems(tree: Any) -> int:
    """Elements of ONE server's message (the per-row model size): the sum
    over leaves of everything behind the leading server axis."""
    return sum(int(np.prod(l.shape[1:])) for l in jax.tree.leaves(tree))


def tree_wire_bytes_per_server(compressor: Compressor, tree: Any) -> int:
    """On-wire bytes of one server's full compressed message: the per-leaf
    ``wire_bytes_per_leaf`` summed over leaves (chunking/top-k rounding
    apply per leaf — and per leaf ROW for shape-preserving compressors —
    exactly as the in-graph wire simulation does)."""
    return sum(compressor.wire_bytes_per_leaf(l.shape)
               for l in jax.tree.leaves(tree))
