"""Lossy compressors for inter-server gossip messages.

Every compressor is a pure ``compress``/``decompress`` pair over 2-D
``(M, d)`` arrays — row i is server i's flattened outgoing message — with
static output shapes, so both directions trace cleanly inside jit.  The
consensus period then mixes the DECOMPRESSED values
(``core.consensus.CompressedBackend``): mathematically that is exactly what
every receiver reconstructs from the on-wire payload.

Wire model.  Gossip is linear in the payloads, so one compressed message
per server per consensus period, forwarded T_S hops ("payload flooding"),
realises the whole T_S-round period on decompressed values.  The on-wire
cost accounted by ``comm.accounting.BytesTracker`` is therefore

    live directed links  x  T_S rounds  x  wire_bytes_per_row.

Compressors:

* ``IdentityCompressor``            exact passthrough (accounting baseline).
* ``StochasticQuantizer(bits, chunk)``  int8/int4 with per-chunk absmax
      scales and UNBIASED stochastic rounding ``q = floor(x/s + u)``,
      ``u ~ U[0, 1)``: ``E[decompress] = x``, so quantization noise is
      zero-mean and error feedback only has to absorb its variance.  With
      no rng key the rounding degrades to deterministic round-to-nearest.
* ``TopKCompressor(ratio)``         per-row magnitude top-k: values plus
      explicit int32 indices cross the wire.
* ``RandomKCompressor(ratio)``      k coordinates sampled per call from the
      SHARED rng key: every server transmits the same coordinate set, so
      the indices never cross the wire (receivers regenerate them from the
      shared seed) and the gossip operator acts identically per coordinate.

``make_compressor`` parses the ``DFLConfig.compression`` /
``--compression`` spec grammar::

    none | int8[:CHUNK] | int4[:CHUNK] | top_k:RATIO | random_k:RATIO
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np


class Compressed(NamedTuple):
    """On-wire representation of one compressed ``(M, d)`` message batch.

    ``data`` is the payload (quantized codes or kept values); ``scale`` the
    per-chunk dequantization scales (quantizers only); ``idx`` the kept
    coordinates (sparsifiers only — shape ``(M, k)`` for top-k, shared
    ``(k,)`` for seed-coordinated random-k).  Unused fields are ``None``."""

    data: jax.Array
    scale: Optional[jax.Array] = None
    idx: Optional[jax.Array] = None


@dataclasses.dataclass(frozen=True)
class Compressor:
    """Base: a pure compress/decompress pair + metadata-derived wire bytes.

    ``wire_bits_data`` is the TRUE on-wire width of one ``data`` element —
    it may be narrower than the array dtype carrying it in memory (int4
    codes ride in int8 arrays).  ``idx_on_wire`` is False when receivers
    can reconstruct the indices without transmission (shared-seed
    random-k).  ``shape_preserving`` marks compressors whose round-trip is
    purely elementwise over the input's natural shape (chunking along the
    LAST axis only): ``roundtrip_tree`` then skips the ``(M, d)`` flatten
    entirely, which under pjit is the difference between per-shard local
    compute and replicating every leaf (the flatten merges sharded weight
    axes)."""

    wire_bits_data = 32
    idx_on_wire = True
    shape_preserving = False

    name = "?"

    def compress(self, x: jax.Array,
                 key: Optional[jax.Array] = None) -> Compressed:
        raise NotImplementedError

    def decompress(self, comp: Compressed, d: int) -> jax.Array:
        raise NotImplementedError

    def roundtrip(self, x: jax.Array,
                  key: Optional[jax.Array] = None) -> jax.Array:
        """What the receivers reconstruct: D(C(x)), in ``x``'s dtype."""
        return self.decompress(self.compress(x, key),
                               x.shape[-1]).astype(x.dtype)

    def wire_bytes_per_row(self, d: int) -> int:
        """On-wire bytes of ONE server's compressed d-element message,
        derived from the ACTUAL compressed representation (``jax.eval_shape``
        over ``compress`` — payload metadata, not a closed form; the
        independent closed forms live in ``comm.accounting.
        analytic_row_bytes`` and the two are cross-checked by tests and the
        ``compressed_consensus`` benchmark)."""
        return self.wire_bytes_per_leaf((1, d))

    def wire_bytes_per_leaf(self, shape) -> int:
        """Bytes of one server's compressed message for a server-tree leaf
        of the given shape (leading axis = server): what actually crosses
        the wire, derived from the payload metadata of compressing exactly
        what ``roundtrip_tree`` compresses — the flat ``(1, d)`` row for
        flatten-based compressors, the natural ``(1, *w)`` shape for
        shape-preserving ones (their chunk count follows the leaf's last
        axis)."""
        shape = tuple(shape)
        if not self.shape_preserving:
            shape = (1, int(np.prod(shape[1:])))
        else:
            shape = (1,) + shape[1:]
        comp = jax.eval_shape(
            lambda x: self.compress(x, key=jax.random.key(0)),
            jax.ShapeDtypeStruct(shape, jnp.float32))
        total = int(np.ceil(comp.data.size * self.wire_bits_data / 8))
        if comp.scale is not None:
            total += comp.scale.size * comp.scale.dtype.itemsize
        if comp.idx is not None and self.idx_on_wire:
            total += comp.idx.size * comp.idx.dtype.itemsize
        return total


@dataclasses.dataclass(frozen=True)
class IdentityCompressor(Compressor):
    """Exact passthrough — the float32-wire baseline of the accounting, and
    the compressor under which the whole layer degenerates exactly."""

    name = "identity"
    shape_preserving = True

    def compress(self, x, key=None):
        del key
        return Compressed(data=x)

    def decompress(self, comp, d):
        return comp.data[..., :d]


@dataclasses.dataclass(frozen=True)
class StochasticQuantizer(Compressor):
    """int8/int4 quantization with per-chunk absmax scales.

    The LAST axis of the input is split into ``chunk``-element chunks (the
    last may be partial); chunk c gets scale ``s_c = absmax_c / qmax``
    (``qmax = 2^{bits-1}-1``) and codes ``q = clip(floor(x/s_c + u), -qmax,
    qmax)`` with dither ``u ~ U[0, 1)`` — unbiased stochastic rounding
    (round-to-nearest when no key is given).  On the wire: UNPADDED codes
    + one f32 scale per chunk; int4 codes are carried in int8 arrays in
    memory but counted at 4 bits.

    Shape preserving: every op is elementwise except a last-axis-only
    reshape, so ``(M, *w)`` leaves compress in their natural layout — under
    pjit each device quantizes its local shard (chunk boundaries follow the
    leaf's rows, which is also what a real per-tensor wire format does),
    no gather, no flatten.  Pass ``dither`` explicitly to share the
    randomness with a fused kernel (``kernels.consensus_mix.
    quantized_consensus_mix_2d`` parity)."""

    bits: int = 8
    chunk: int = 256

    def __post_init__(self):
        if self.bits not in (4, 8):
            raise ValueError(f"bits must be 4 or 8, got {self.bits}")
        if self.chunk < 1:
            raise ValueError("chunk must be >= 1")

    @property
    def name(self):
        return f"int{self.bits}"

    @property
    def wire_bits_data(self):
        return self.bits

    shape_preserving = True

    @property
    def qmax(self) -> float:
        return float(2 ** (self.bits - 1) - 1)

    def _scales(self, x32: jax.Array) -> jax.Array:
        """(..., nc) per-chunk scales over the last axis of a float32 array
        (zero-padded virtually: a trailing partial chunk uses only its real
        elements)."""
        length = x32.shape[-1]
        nc = -(-length // self.chunk)
        pad = nc * self.chunk - length
        if pad:
            x32 = jnp.pad(x32, [(0, 0)] * (x32.ndim - 1) + [(0, pad)])
        chunked = x32.reshape(x32.shape[:-1] + (nc, self.chunk))
        absmax = jnp.max(jnp.abs(chunked), axis=-1)
        return jnp.where(absmax > 0, absmax / self.qmax, 1.0)

    def _per_elem(self, scale: jax.Array, d: int) -> jax.Array:
        """Broadcast (..., nc) chunk scales back onto the d real last-axis
        elements — codes ship UNPADDED, only the scales carry the chunk
        structure."""
        return jnp.repeat(scale, self.chunk, axis=-1)[..., :d]

    def compress(self, x, key=None, *, dither=None):
        d = x.shape[-1]
        x32 = x.astype(jnp.float32)
        scale = self._scales(x32)
        if dither is None:
            dither = (jax.random.uniform(key, x32.shape)
                      if key is not None else 0.5)
        q = jnp.clip(jnp.floor(x32 / self._per_elem(scale, d) + dither),
                     -self.qmax, self.qmax).astype(jnp.int8)
        return Compressed(data=q, scale=scale)

    def decompress(self, comp, d):
        scale = self._per_elem(comp.scale, d)
        return comp.data[..., :d].astype(jnp.float32) * scale


@dataclasses.dataclass(frozen=True)
class TopKCompressor(Compressor):
    """Per-row magnitude top-k sparsification: each server keeps its
    ``k = max(1, round(ratio * d))`` largest-|.| coordinates.  Biased (EF
    recommended); both values AND int32 indices cross the wire — contrast
    ``RandomKCompressor``, whose shared coordinates cost zero index bytes."""

    ratio: float = 0.05

    name = "top_k"

    def __post_init__(self):
        if not 0.0 < self.ratio <= 1.0:
            raise ValueError(f"top_k ratio must be in (0, 1], got {self.ratio}")

    def k_for(self, d: int) -> int:
        return max(1, min(d, int(round(self.ratio * d))))

    def compress(self, x, key=None):
        del key
        k = self.k_for(x.shape[1])
        _, idx = jax.lax.top_k(jnp.abs(x), k)
        vals = jnp.take_along_axis(x, idx, axis=1)
        return Compressed(data=vals, idx=idx.astype(jnp.int32))

    def decompress(self, comp, d):
        m = comp.data.shape[0]
        out = jnp.zeros((m, d), jnp.float32)
        rows = jnp.arange(m)[:, None]
        return out.at[rows, comp.idx].set(comp.data.astype(jnp.float32))


@dataclasses.dataclass(frozen=True)
class RandomKCompressor(Compressor):
    """Seed-coordinated random-k sparsification: ONE random coordinate set
    per call (from the shared rng key) used by every server, so receivers
    regenerate the indices from the seed and only the values cross the wire.
    Biased per call (no d/k rescale — error feedback absorbs it, and the
    unscaled form keeps values bounded, which quantizer-style downstream
    stages prefer)."""

    ratio: float = 0.05

    name = "random_k"
    idx_on_wire = False

    def __post_init__(self):
        if not 0.0 < self.ratio <= 1.0:
            raise ValueError(
                f"random_k ratio must be in (0, 1], got {self.ratio}")

    def k_for(self, d: int) -> int:
        return max(1, min(d, int(round(self.ratio * d))))

    def compress(self, x, key=None):
        if key is None:
            raise ValueError("random_k needs the shared rng key (the "
                             "coordinate set IS the seed)")
        d = x.shape[1]
        idx = jax.random.permutation(key, d)[: self.k_for(d)]
        return Compressed(data=x[:, idx], idx=idx.astype(jnp.int32))

    def decompress(self, comp, d):
        m = comp.data.shape[0]
        out = jnp.zeros((m, d), jnp.float32)
        return out.at[:, comp.idx].set(comp.data.astype(jnp.float32))


def make_compressor(spec: str) -> Compressor:
    """Parse a compression spec string (see module docstring grammar).

    ``"none"`` deliberately raises: it means the compression layer is OFF
    (no wrapper is built at all), not that an identity compressor runs —
    callers guard on it before resolving a compressor."""
    s = spec.strip()
    if s in ("none", ""):
        raise ValueError("compression='none' disables the layer; there is "
                         "no compressor to build")
    head, _, arg = s.partition(":")
    if head in ("int8", "int4"):
        chunk = int(arg) if arg else 256
        return StochasticQuantizer(bits=int(head[3:]), chunk=chunk)
    if head in ("top_k", "random_k"):
        if not arg:
            raise ValueError(f"{head} needs a keep ratio, e.g. '{head}:0.05'")
        cls = TopKCompressor if head == "top_k" else RandomKCompressor
        return cls(ratio=float(arg))
    if head == "identity":
        return IdentityCompressor()
    raise ValueError(f"unknown compression spec {spec!r}; expected none | "
                     f"int8[:chunk] | int4[:chunk] | top_k:ratio | "
                     f"random_k:ratio")


# ---------------------------------------------------------------------------
# pytree wrappers over the (M, d) row layout
# ---------------------------------------------------------------------------


def roundtrip_tree(compressor: Compressor, tree: Any,
                   key: Optional[jax.Array] = None,
                   flat_sharding=None) -> Any:
    """Wire-simulate a server tree (leaves ``(M, *w)``): each leaf is
    flattened to ``(M, d)`` rows, compressed and decompressed per leaf (the
    rng key folded per leaf index so dither/coordinates differ across
    leaves), and reshaped back in the leaf's dtype.

    Shape-preserving compressors (identity, the quantizers) skip the
    flatten and round-trip each leaf in its natural ``(M, *w)`` layout —
    elementwise per-shard work under pjit.  Flatten-based compressors
    (top-k / random-k need the whole row to rank coordinates) reshape to
    ``(M, d)``; ``flat_sharding`` is an optional NamedSharding for that
    view (e.g. ``P("server", ("replica", "model"))`` — the same constraint
    ``consensus.gossip_scan_blocked`` uses): without it the partitioner
    replicates the merged weight axes, which at LM scale is an OOM."""
    leaves, treedef = jax.tree.flatten(tree)
    out = []
    for i, leaf in enumerate(leaves):
        k = jax.random.fold_in(key, i) if key is not None else None
        if compressor.shape_preserving:
            out.append(compressor.roundtrip(leaf, k))
            continue
        x = leaf.reshape(leaf.shape[0], -1)
        if flat_sharding is not None:
            x = jax.lax.with_sharding_constraint(x, flat_sharding)
        y = compressor.roundtrip(x, k)
        if flat_sharding is not None:
            y = jax.lax.with_sharding_constraint(y, flat_sharding)
        out.append(y.reshape(leaf.shape))
    return jax.tree.unflatten(treedef, out)


def tree_message_elems(tree: Any) -> int:
    """Elements of ONE server's message (the per-row model size): the sum
    over leaves of everything behind the leading server axis."""
    return sum(int(np.prod(l.shape[1:])) for l in jax.tree.leaves(tree))


def tree_wire_bytes_per_server(compressor: Compressor, tree: Any) -> int:
    """On-wire bytes of one server's full compressed message: the per-leaf
    ``wire_bytes_per_leaf`` summed over leaves (chunking/top-k rounding
    apply per leaf — and per leaf ROW for shape-preserving compressors —
    exactly as the in-graph wire simulation does)."""
    return sum(compressor.wire_bytes_per_leaf(l.shape)
               for l in jax.tree.leaves(tree))
