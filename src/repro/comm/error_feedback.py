"""Error feedback (EF) for compressed gossip.

Lossy compression of the consensus messages perturbs what the mixing
operator averages; for BIASED compressors (top-k keeps only the largest
coordinates, quantizers clip) the perturbation need not vanish and the
federation can converge to the wrong point.  Error feedback (Seide et al.
2014; Stich et al. 2018; Karimireddy et al. 2019) keeps each server's
compression residual locally and folds it into the NEXT period's message:

    msg_i = C(x_i + e_i)                    (crosses the wire)
    e_i'  = (x_i + e_i) - D(msg_i)          (stays local)

Nothing extra is transmitted; whatever information compression withheld in
period p is re-offered in period p+1, so the running sum of what receivers
decode tracks the running sum of the true messages and compression error
stops accumulating in the consensus direction.  With the identity
compressor ``D(C(x)) = x`` exactly, the residual is identically zero, and
the layer degenerates to the uncompressed path.

State: the residual pytree (leaves ``(M, *w)``, mirroring the server
aggregates) rides across epochs in ``core.dfl.DFLState.ef_residual`` and
is reset to zero on fault surgery (``core.engine.DynamicFederationEngine``):
the old residuals are wire state of a federation that no longer exists,
exactly like the push-sum weights.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.comm.compressors import Compressor, roundtrip_tree


def init_ef_residual(server_tree: Any) -> Any:
    """Zero residual, shaped like the server aggregates (leaves (M, *w))."""
    return jax.tree.map(jnp.zeros_like, server_tree)


def ef_roundtrip(compressor: Compressor, tree: Any, residual: Any,
                 key: Optional[jax.Array] = None,
                 flat_sharding=None) -> Tuple[Any, Any]:
    """One error-compensated transmission of a server tree.

    Returns ``(decompressed message tree, new residual)``; the message tree
    is what every receiver reconstructs and what the consensus operator
    mixes.  Residuals accumulate in the leaf dtype (they are bounded by one
    compression step, so bf16 residuals stay well-conditioned).
    ``flat_sharding`` is forwarded to the wire simulation (see
    ``compressors.roundtrip_tree``)."""
    corrected = jax.tree.map(lambda x, e: x + e, tree, residual)
    msg = roundtrip_tree(compressor, corrected, key,
                         flat_sharding=flat_sharding)
    new_residual = jax.tree.map(lambda c, q: c - q, corrected, msg)
    return msg, new_residual
