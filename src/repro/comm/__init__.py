"""Compressed-gossip communication subsystem.

The paper's global-training periods are pure inter-server communication:
every consensus round ships a full model replica across every live edge
(Eq. 5), which dominates the epoch cost once the federation or the model
grows.  This package puts a lossy-compression layer under the consensus
execution backends (``core.consensus.ConsensusBackend``):

* ``comm.compressors``     — pure compress/decompress pairs (identity,
                             int8/int4 stochastic-rounding quantization
                             with per-chunk scales, top-k and random-k
                             sparsification), all usable inside jit;
* ``comm.error_feedback``  — the EF residual recursion that keeps lossy
                             compression from biasing consensus;
* ``comm.accounting``      — host-side on-wire byte accounting
                             (``BytesTracker``, mirroring
                             ``core.schedule.SigmaTracker``), cross-checked
                             against closed-form analytic counts.

Integration points: ``core.consensus.CompressedBackend`` wraps any backend,
``core.dfl.DFLConfig.compression`` / ``error_feedback`` select it, the EF
residual rides in ``core.dfl.DFLState.ef_residual``, and the dynamic engine
reports per-epoch wire bytes.  See docs/dynamic_federation.md §compression.
"""
from repro.comm.compressors import (Compressed, Compressor,
                                    IdentityCompressor, RandomKCompressor,
                                    StochasticQuantizer, TopKCompressor,
                                    keyed_index_sample, make_compressor,
                                    pack_int4, roundtrip_tree,
                                    tree_message_elems,
                                    tree_wire_bytes_per_server, unpack_int4,
                                    wire_dither)
from repro.comm.error_feedback import ef_roundtrip, init_ef_residual
from repro.comm.accounting import (BytesTracker, analytic_leaf_bytes,
                                   analytic_row_bytes, hlo_collective_bytes,
                                   physical_leaf_bytes,
                                   tree_physical_wire_bytes_per_server,
                                   uncompressed_row_bytes)

__all__ = [n for n in dir() if not n.startswith("_")]
