"""Mamba2 (state-space duality) block — pure-JAX chunked reference.

TPU adaptation (DESIGN.md §2/§6): the CUDA selective-scan relies on
warp-level shuffles; the SSD formulation instead decomposes the recurrence
into *chunk-local quadratic attention-like matmuls* (MXU-friendly) plus a
tiny inter-chunk state recurrence (lax.scan over chunks).  The Pallas kernel
in ``repro/kernels/ssd_scan.py`` tiles exactly this structure; this module
is the jnp oracle and the path used for CPU lowering.

Recurrence implemented (per head h, state dim n, head dim p):
    h_t = exp(dt_t * A) * h_{t-1} + dt_t * B_t x_t'
    y_t = C_t h_t + D * x_t
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.modules import _dense_init, rmsnorm_apply, rmsnorm_init

N_GROUPS = 1  # B/C groups (mamba2 default n_groups=1 at these scales)


def mamba_init(key, cfg: ArchConfig, dtype=jnp.float32) -> Dict:
    m = cfg.mamba
    d = cfg.d_model
    di = m.d_inner(d)
    nh = m.num_heads(d)
    conv_ch = di + 2 * N_GROUPS * m.d_state
    ks = jax.random.split(key, 5)
    return {
        # order: [z (di), xBC (conv_ch), dt (nh)]
        "in_proj": _dense_init(ks[0], (d, 2 * di + 2 * N_GROUPS * m.d_state + nh), dtype),
        "conv_w": _dense_init(ks[1], (m.d_conv, conv_ch), dtype,
                              scale=1.0 / math.sqrt(m.d_conv)),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "dt_bias": jnp.zeros((nh,), dtype),
        "a_log": jnp.log(jnp.arange(1, nh + 1, dtype=jnp.float32)).astype(dtype),
        "d_skip": jnp.ones((nh,), dtype),
        "norm": rmsnorm_init(di, dtype),
        "out_proj": _dense_init(ks[2], (di, d), dtype),
    }


def _split_proj(params, x, cfg: ArchConfig):
    m = cfg.mamba
    di = m.d_inner(cfg.d_model)
    nh = m.num_heads(cfg.d_model)
    zxbcdt = jnp.einsum("bsd,de->bse", x, params["in_proj"])
    z, xbc, dt = jnp.split(zxbcdt, [di, 2 * di + 2 * N_GROUPS * m.d_state], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"].astype(jnp.float32))
    return z, xbc, dt  # (b,s,di), (b,s,conv_ch), (b,s,nh) f32


def _causal_conv(xbc: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv along seq. xbc: (b, s, ch); w: (width, ch)."""
    width = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (width - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + xbc.shape[1]] * w[i] for i in range(width))
    return jax.nn.silu(out + b)


def _split_xbc(xbc, cfg: ArchConfig):
    m = cfg.mamba
    di = m.d_inner(cfg.d_model)
    nh = m.num_heads(cfg.d_model)
    xs, bs, cs = jnp.split(xbc, [di, di + N_GROUPS * m.d_state], axis=-1)
    b, s = xs.shape[:2]
    xs = xs.reshape(b, s, nh, m.head_dim)
    bs = bs.reshape(b, s, N_GROUPS, m.d_state)
    cs = cs.reshape(b, s, N_GROUPS, m.d_state)
    return xs, bs, cs


def segsum(a: jax.Array) -> jax.Array:
    """Stable segment-sum: out[..., i, j] = sum_{j < k <= i} a[..., k]
    (=-inf for j > i).  a: (..., q)."""
    q = a.shape[-1]
    cum = jnp.cumsum(a, axis=-1)
    diff = cum[..., :, None] - cum[..., None, :]
    mask = jnp.tril(jnp.ones((q, q), bool), k=0)
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(xs, bs, cs, dt, a_coef, chunk: int):
    """Chunked SSD scan (jnp reference).

    xs: (b,s,nh,hd) — inputs (pre-multiplied by nothing; dt applied here)
    bs/cs: (b,s,g,ds); dt: (b,s,nh) f32; a_coef: (nh,) negative.
    Returns y: (b,s,nh,hd), final state (b,nh,hd,ds).
    """
    bsz, s, nh, hd = xs.shape
    ds = bs.shape[-1]
    orig_s = s
    if s % chunk:
        # right-pad with dt=0 steps: decay=exp(0)=1 and dt*B*x=0, so padding
        # is exact for both outputs (sliced off) and the final state.
        pad = chunk - s % chunk
        z = lambda t: jnp.pad(t, [(0, 0), (0, pad)] + [(0, 0)] * (t.ndim - 2))
        xs, bs, cs, dt = z(xs), z(bs), z(cs), z(dt)
        s = s + pad
    nc = s // chunk
    # group-broadcast B/C to heads (g=1)
    bh = jnp.broadcast_to(bs[:, :, 0][:, :, None], (bsz, s, nh, ds))
    ch = jnp.broadcast_to(cs[:, :, 0][:, :, None], (bsz, s, nh, ds))

    def r(t, last):  # reshape to chunks
        return t.reshape((bsz, nc, chunk) + last)

    xc = r(xs, (nh, hd)).astype(jnp.float32)
    bc = r(bh, (nh, ds)).astype(jnp.float32)
    cc = r(ch, (nh, ds)).astype(jnp.float32)
    dtc = r(dt, (nh,))
    a = dtc * a_coef.astype(jnp.float32)            # (b,nc,q,nh) log-decay
    a_t = jnp.moveaxis(a, -1, -2)                    # (b,nc,nh,q)
    cum = jnp.cumsum(a_t, axis=-1)                   # (b,nc,nh,q)
    total = cum[..., -1]                             # (b,nc,nh)

    # ---- intra-chunk (quadratic, MXU) ----
    l_mat = jnp.exp(segsum(a_t))                     # (b,nc,nh,q,q)
    scores = jnp.einsum("bcqhn,bckhn->bchqk", cc, bc) * l_mat
    # weight by dt of the source step
    scores = scores * jnp.moveaxis(dtc, -1, -2)[:, :, :, None, :]
    y_intra = jnp.einsum("bchqk,bckhp->bcqhp", scores, xc)

    # ---- chunk states ----
    decay_to_end = jnp.exp(total[..., None] - cum)   # (b,nc,nh,q)
    sts = jnp.einsum("bcqhn,bchq,bcqh,bcqhp->bchnp",
                     bc, decay_to_end, dtc, xc)

    # ---- inter-chunk recurrence over nc (sequential, tiny) ----
    def step(h, inp):
        st, tot = inp                                # (b,nh,ds,hd), (b,nh)
        h_new = h * jnp.exp(tot)[..., None, None] + st
        return h_new, h                              # emit state BEFORE chunk

    init = jnp.zeros((bsz, nh, ds, hd), jnp.float32)
    sts_t = jnp.moveaxis(sts, 1, 0)                  # (nc,b,nh,ds,hd)
    tot_t = jnp.moveaxis(total, 1, 0)                # (nc,b,nh)
    final, prev_states = jax.lax.scan(step, init, (sts_t, tot_t))
    prev_states = jnp.moveaxis(prev_states, 0, 1)    # (b,nc,nh,ds,hd)

    # ---- inter-chunk contribution ----
    in_decay = jnp.exp(cum)                          # (b,nc,nh,q)
    y_inter = jnp.einsum("bcqhn,bchq,bchnp->bcqhp", cc, in_decay, prev_states)

    y = (y_intra + y_inter).reshape(bsz, s, nh, hd)
    return y[:, :orig_s], final


def mamba_apply(params: Dict, x: jax.Array, cfg: ArchConfig,
                impl: str = "reference",
                chunk_override: Optional[int] = None,
                head_sharding=None) -> jax.Array:
    """Full-sequence forward (training / prefill).

    ``chunk_override`` shrinks the intra-chunk quadratic block (the L matrix
    is O(b*nh*s*chunk) — training lowerings pass 64); ``head_sharding``
    constrains the per-head streams (b, s, nh, hd) so XLA shards the SSD
    over heads (nh is a multiple of 16 for every assigned SSM arch)."""
    m = cfg.mamba
    chunk = chunk_override or m.chunk_size
    z, xbc_raw, dt = _split_proj(params, x, cfg)
    xbc = _causal_conv(xbc_raw, params["conv_w"], params["conv_b"])
    xs, bs, cs = _split_xbc(xbc, cfg)
    if head_sharding is not None:
        xs = jax.lax.with_sharding_constraint(xs, head_sharding)
    a_coef = -jnp.exp(params["a_log"].astype(jnp.float32))
    if impl == "pallas":
        from repro.kernels import ops as kops
        y, _ = kops.ssd_scan(xs, bs, cs, dt, a_coef, chunk=chunk)
    else:
        y, _ = ssd_chunked(xs, bs, cs, dt, a_coef, chunk)
    y = y + xs.astype(jnp.float32) * params["d_skip"].astype(jnp.float32)[:, None]
    y = y.reshape(x.shape[0], x.shape[1], -1)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    y = rmsnorm_apply(params["norm"], y.astype(x.dtype), cfg.norm_eps)
    return jnp.einsum("bsd,de->bse", y, params["out_proj"])


def mamba_prefill(params: Dict, x: jax.Array, cfg: ArchConfig,
                  conv_cache_dtype=jnp.bfloat16,
                  chunk_override: Optional[int] = None,
                  head_sharding=None) -> Tuple[jax.Array, Dict]:
    """Full-sequence forward that ALSO returns the decode cache — one SSD
    scan for both (the naive prefill ran the scan twice: once for outputs,
    once for the final state)."""
    m = cfg.mamba
    chunk = chunk_override or m.chunk_size
    z, xbc_raw, dt = _split_proj(params, x, cfg)
    xbc = _causal_conv(xbc_raw, params["conv_w"], params["conv_b"])
    xs, bs, cs = _split_xbc(xbc, cfg)
    if head_sharding is not None:
        xs = jax.lax.with_sharding_constraint(xs, head_sharding)
    a_coef = -jnp.exp(params["a_log"].astype(jnp.float32))
    y, final = ssd_chunked(xs, bs, cs, dt, a_coef, chunk)
    y = y + xs.astype(jnp.float32) * params["d_skip"].astype(jnp.float32)[:, None]
    y = y.reshape(x.shape[0], x.shape[1], -1)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    y = rmsnorm_apply(params["norm"], y.astype(x.dtype), cfg.norm_eps)
    out = jnp.einsum("bsd,de->bse", y, params["out_proj"])
    cache = {"conv": xbc_raw[:, -(m.d_conv - 1):].astype(conv_cache_dtype),
             "ssm": final}
    return out, cache


# ---------------------------------------------------------------------------
# incremental decode
# ---------------------------------------------------------------------------


def mamba_cache_init(cfg: ArchConfig, batch: int, dtype=jnp.float32) -> Dict:
    m = cfg.mamba
    d = cfg.d_model
    di = m.d_inner(d)
    nh = m.num_heads(d)
    conv_ch = di + 2 * N_GROUPS * m.d_state
    return {
        "conv": jnp.zeros((batch, m.d_conv - 1, conv_ch), dtype),
        "ssm": jnp.zeros((batch, nh, m.d_state, m.head_dim), jnp.float32),
    }


def mamba_decode_step(params: Dict, x: jax.Array, cache: Dict,
                      cfg: ArchConfig) -> Tuple[jax.Array, Dict]:
    """x: (b, 1, d). O(1) per token — the reason this family runs long_500k."""
    m = cfg.mamba
    z, xbc_raw, dt = _split_proj(params, x, cfg)          # seq dim == 1
    # conv over [cache, current]
    hist = jnp.concatenate([cache["conv"],
                            xbc_raw.astype(cache["conv"].dtype)], axis=1)
    w = params["conv_w"]
    conv_out = jnp.einsum("bwc,wc->bc", hist[:, -m.d_conv:], w) + params["conv_b"]
    xbc = jax.nn.silu(conv_out)[:, None]                  # (b,1,ch)
    xs, bs, cs = _split_xbc(xbc, cfg)
    a_coef = -jnp.exp(params["a_log"].astype(jnp.float32))
    dt1 = dt[:, 0]                                        # (b,nh)
    decay = jnp.exp(dt1 * a_coef)                         # (b,nh)
    bx = jnp.einsum("bhn,bhp->bhnp",
                    jnp.broadcast_to(bs[:, 0, 0][:, None], dt1.shape + (m.d_state,)),
                    xs[:, 0].astype(jnp.float32) * dt1[..., None])
    ssm = cache["ssm"] * decay[..., None, None] + bx
    y = jnp.einsum("bhn,bhnp->bhp",
                   jnp.broadcast_to(cs[:, 0, 0][:, None], dt1.shape + (m.d_state,)
                                    ).astype(jnp.float32), ssm)
    y = y + xs[:, 0].astype(jnp.float32) * params["d_skip"].astype(jnp.float32)[:, None]
    y = y.reshape(x.shape[0], 1, -1)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    y = rmsnorm_apply(params["norm"], y.astype(x.dtype), cfg.norm_eps)
    out = jnp.einsum("bsd,de->bse", y, params["out_proj"])
    new_cache = {"conv": hist[:, 1:], "ssm": ssm}
    return out, new_cache
