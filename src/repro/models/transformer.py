"""Composable decoder / encoder-decoder transformer over the module zoo.

Layer stacking uses ``lax.scan`` over *periods* (one period = one cycle of
``cfg.layer_pattern`` × the MoE alternation), so a 72-layer hybrid compiles
the block body once.  Heterogeneous-within-period blocks (e.g. jamba's
7 Mamba + 1 attention) are unrolled *inside* the period body.

Public API
----------
    init_params(key, cfg, dtype)                  -> params pytree
    forward(params, cfg, batch)                   -> (logits, aux_loss)
    make_loss_fn(cfg)                             -> loss_fn(params, batch, rng)
    init_cache(cfg, batch, max_len, dtype)        -> cache pytree
    prefill(params, cfg, batch)                   -> (logits, cache)
    decode_step(params, cfg, token, cache, pos)   -> (logits, cache)
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import mamba as mamba_mod
from repro.models import modules as nn


# ---------------------------------------------------------------------------
# stack plan: prefix blocks + scanned periods
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ApplyOptions:
    """Knobs threaded through the apply path (no param-structure impact)."""

    attn_impl: str = "reference"     # reference | pallas
    remat: bool = True
    moe_no_drop: bool = False        # exact (capacity=t) MoE — tests/serving
    capacity_factor: float = 1.25
    # Megatron-style sequence parallelism: a NamedSharding for the logical
    # residual stream (b, s, d), applied at every layer-stack boundary so
    # the activations saved by scan-backward are sharded (e.g. seq over the
    # "model" axis).  None = let the partitioner decide.
    act_sharding: Optional[Any] = None
    # Group-limited MoE routing (expert parallelism): tokens are split into
    # ``moe_groups`` groups, each routed with its own capacity; the
    # group->expert reshard is the all-to-all of a2a expert parallelism.
    # moe_group_sharding: NamedSharding for the grouped (G, t/G, d) tokens.
    moe_groups: int = 1
    moe_group_sharding: Optional[Any] = None
    # SSD (Mamba2) scan: override the intra-chunk quadratic block length for
    # training lowerings (the L matrix is O(b * nh * s * chunk) — chunk 64
    # keeps it ~1 GB/device for jamba where the config default 256 is 4x
    # that); None keeps cfg.mamba.chunk_size.
    ssd_chunk: Optional[int] = None
    # NamedSharding for SSD per-head streams (b, s, nh, hd): shard heads
    # over "model", batch over the DP axes.
    ssd_head_sharding: Optional[Any] = None
    # NamedSharding for attention q/k/v (b, s, h, hd) after GQA expansion —
    # pins heads to "model" (critical for MLA's 128 expanded heads).
    attn_head_sharding: Optional[Any] = None

    def constrain(self, x: jax.Array) -> jax.Array:
        if self.act_sharding is None:
            return x
        return jax.lax.with_sharding_constraint(x, self.act_sharding)


DEFAULT_OPTS = ApplyOptions()


@dataclasses.dataclass(frozen=True)
class StackPlan:
    num_prefix: int          # unscanned leading layers (deepseek dense layer 0)
    period: int              # layers per scanned step
    n_periods: int

    def kinds(self, cfg: ArchConfig, base_idx: int) -> Tuple[str, ...]:
        return tuple(cfg.pattern_for_layer(base_idx + i) for i in range(self.period))


def stack_plan(cfg: ArchConfig) -> StackPlan:
    moe_period = {"all": 1, "every_2": 2, "all_but_first": 1, None: 1}[
        cfg.moe.layer_pattern if cfg.moe else None]
    num_prefix = 1 if (cfg.moe and cfg.moe.layer_pattern == "all_but_first") else 0
    period = math.lcm(len(cfg.layer_pattern), moe_period)
    rest = cfg.num_layers - num_prefix
    assert rest % period == 0, (cfg.name, rest, period)
    return StackPlan(num_prefix, period, rest // period)


def _layer_flags(cfg: ArchConfig, abs_idx: int) -> Tuple[str, bool]:
    """(kind, is_moe) for absolute layer index."""
    return cfg.pattern_for_layer(abs_idx), cfg.is_moe_layer(abs_idx)


# ---------------------------------------------------------------------------
# single block
# ---------------------------------------------------------------------------


def block_init(key, cfg: ArchConfig, kind: str, is_moe: bool,
               cross: bool = False, dtype=jnp.float32) -> Dict:
    ks = jax.random.split(key, 4)
    d = cfg.d_model
    p: Dict[str, Any] = {"ln1": nn.rmsnorm_init(d, dtype),
                         "ln2": nn.rmsnorm_init(d, dtype)}
    if kind == "mamba":
        p["mixer"] = mamba_mod.mamba_init(ks[0], cfg, dtype)
    elif cfg.mla is not None:
        p["mixer"] = nn.mla_init(ks[0], cfg, dtype)
    else:
        p["mixer"] = nn.attention_init(ks[0], cfg, dtype)
    if is_moe:
        p["ffn"] = nn.moe_init(ks[1], cfg, dtype)
    elif cfg.d_ff > 0 and kind != "mamba_only":
        p["ffn"] = nn.mlp_init(ks[1], d, cfg.d_ff, dtype)
    if cfg.final_logit_softcap is not None:  # gemma2 family: post-norms
        p["post_ln1"] = nn.rmsnorm_init(d, dtype)
        p["post_ln2"] = nn.rmsnorm_init(d, dtype)
    if cross:
        p["cross_ln"] = nn.rmsnorm_init(d, dtype)
        p["cross_attn"] = nn.attention_init(ks[2], cfg, dtype, cross=True)
    return p


def block_apply(params: Dict, x: jax.Array, cfg: ArchConfig, kind: str,
                is_moe: bool, *, memory: Optional[jax.Array] = None,
                opts: ApplyOptions = DEFAULT_OPTS,
                causal: bool = True) -> Tuple[jax.Array, jax.Array]:
    """Full-sequence block. Returns (x, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    h = nn.rmsnorm_apply(params["ln1"], x, cfg.norm_eps)
    if kind == "mamba":
        mix = mamba_mod.mamba_apply(params["mixer"], h, cfg,
                                    impl=opts.attn_impl
                                    if opts.attn_impl == "pallas" else "reference",
                                    chunk_override=opts.ssd_chunk,
                                    head_sharding=opts.ssd_head_sharding)
    elif cfg.mla is not None:
        mix = nn.mla_apply(params["mixer"], h, cfg,
                           head_sharding=opts.attn_head_sharding)
    else:
        mix = nn.attention_apply(params["mixer"], h, cfg, layer_kind=kind,
                                 causal=causal, attn_impl=opts.attn_impl,
                                 head_sharding=opts.attn_head_sharding)
    if "post_ln1" in params:
        mix = nn.rmsnorm_apply(params["post_ln1"], mix, cfg.norm_eps)
    x = x + mix
    if memory is not None and "cross_attn" in params:
        h = nn.rmsnorm_apply(params["cross_ln"], x, cfg.norm_eps)
        mem_mask = jnp.ones((x.shape[1], memory.shape[1]), bool)
        x = x + nn.attention_apply(params["cross_attn"], h, cfg,
                                   kv_override=(memory, mem_mask))
    if "ffn" in params:
        h = nn.rmsnorm_apply(params["ln2"], x, cfg.norm_eps)
        if is_moe:
            ff, aux = nn.moe_apply(params["ffn"], h, cfg,
                                   capacity_factor=opts.capacity_factor,
                                   no_drop=opts.moe_no_drop,
                                   groups=opts.moe_groups,
                                   group_sharding=opts.moe_group_sharding)
        else:
            ff = nn.mlp_apply(params["ffn"], h, cfg.act)
        if "post_ln2" in params:
            ff = nn.rmsnorm_apply(params["post_ln2"], ff, cfg.norm_eps)
        x = x + ff
    return x, aux


# ---------------------------------------------------------------------------
# parameter init
# ---------------------------------------------------------------------------


def init_params(key, cfg: ArchConfig, dtype=jnp.float32) -> Dict:
    plan = stack_plan(cfg)
    keys = jax.random.split(key, 8)
    d = cfg.d_model
    vp = cfg.padded_vocab_size
    params: Dict[str, Any] = {
        "embed": nn._dense_init(keys[0], (vp, d), dtype, scale=0.02),
        "final_norm": nn.rmsnorm_init(d, dtype),
    }
    if not cfg.tie_embeddings:
        params["head"] = nn._dense_init(keys[1], (d, vp), dtype)

    cross = cfg.encdec is not None

    def period_init(k):
        sub = jax.random.split(k, plan.period)
        blocks = []
        for i in range(plan.period):
            kind, is_moe = _layer_flags(cfg, plan.num_prefix + i)
            blocks.append(block_init(sub[i], cfg, kind, is_moe, cross=cross,
                                     dtype=dtype))
        return tuple(blocks)

    params["stack"] = jax.vmap(period_init)(
        jax.random.split(keys[2], plan.n_periods))

    if plan.num_prefix:
        # deepseek-style dense first layer(s)
        pk = jax.random.split(keys[3], plan.num_prefix)
        prefix = []
        for i in range(plan.num_prefix):
            kind, _ = cfg.pattern_for_layer(i), False
            blk = block_init(pk[i], cfg, cfg.pattern_for_layer(i), False,
                             cross=cross, dtype=dtype)
            # dense first layer uses the wide dense d_ff
            blk["ffn"] = nn.mlp_init(pk[i], cfg.d_model, cfg.d_ff or
                                     cfg.moe.d_ff_expert * 8, dtype)
            prefix.append(blk)
        params["prefix"] = tuple(prefix)

    if cfg.encdec is not None:
        ec = cfg.encdec

        def enc_period_init(k):
            return (block_init(k, cfg, "global", False, cross=False,
                               dtype=dtype),)

        params["encoder"] = {
            "stack": jax.vmap(enc_period_init)(
                jax.random.split(keys[4], ec.num_encoder_layers)),
            "final_norm": nn.rmsnorm_init(d, dtype),
        }
    return params


# ---------------------------------------------------------------------------
# full-sequence forward (training / prefill trunk)
# ---------------------------------------------------------------------------


def _embed(params, cfg: ArchConfig, tokens: jax.Array) -> jax.Array:
    x = params["embed"][tokens]
    if cfg.final_logit_softcap is not None:  # gemma family scales embeddings
        x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
    return x


def _head(params, cfg: ArchConfig, x: jax.Array) -> jax.Array:
    x = nn.rmsnorm_apply(params["final_norm"], x, cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", x, params["embed"])
    else:
        logits = jnp.einsum("bsd,dv->bsv", x, params["head"])
    logits = nn.softcap(logits, cfg.final_logit_softcap)
    if cfg.padded_vocab_size != cfg.vocab_size:   # mask vocab-padding ids
        pad_ids = jax.lax.broadcasted_iota(
            jnp.int32, logits.shape, logits.ndim - 1) >= cfg.vocab_size
        logits = jnp.where(pad_ids, jnp.asarray(-1e30, logits.dtype), logits)
    return logits


def _run_stack(params, cfg: ArchConfig, x: jax.Array, *,
               memory=None, causal=True,
               opts: ApplyOptions = DEFAULT_OPTS) -> Tuple[jax.Array, jax.Array]:
    plan = stack_plan(cfg)
    aux = jnp.zeros((), jnp.float32)
    for i, blk in enumerate(params.get("prefix", ())):
        kind, _ = _layer_flags(cfg, i)
        x, a = block_apply(blk, x, cfg, kind, False, memory=memory,
                           opts=opts, causal=causal)
        aux = aux + a

    def period_body(carry, period_params):
        x, aux = carry
        x = opts.constrain(x)        # shard the scan-carry residual stream
        for i in range(plan.period):
            kind, is_moe = _layer_flags(cfg, plan.num_prefix + i)
            x, a = block_apply(period_params[i], x, cfg, kind, is_moe,
                               memory=memory, opts=opts,
                               causal=causal)
            aux = aux + a
        return (x, aux), None

    body = jax.checkpoint(period_body) if opts.remat else period_body
    (x, aux), _ = jax.lax.scan(body, (opts.constrain(x), aux),
                               params["stack"])
    return x, aux


def encode(params, cfg: ArchConfig, frames: jax.Array,
           opts: ApplyOptions = DEFAULT_OPTS) -> jax.Array:
    """Encoder for enc-dec archs. ``frames``: precomputed frontend embeddings
    (the stub carve-out), (b, enc_len, d)."""
    enc = params["encoder"]
    plan = StackPlan(0, 1, cfg.encdec.num_encoder_layers)

    def body(carry, period_params):
        x, = carry
        x = opts.constrain(x)
        x, _ = block_apply(period_params[0], x, cfg, "global", False,
                           causal=False, opts=opts)
        return (x,), None

    (x,), _ = jax.lax.scan(jax.checkpoint(body), (opts.constrain(frames),),
                           enc["stack"])
    return nn.rmsnorm_apply(enc["final_norm"], x, cfg.norm_eps)


def forward_hidden(params, cfg: ArchConfig, batch: Dict[str, jax.Array], *,
                   opts: ApplyOptions = DEFAULT_OPTS
                   ) -> Tuple[jax.Array, jax.Array]:
    """Trunk only: final hidden states over the token positions (pre-head).

    ``batch`` keys by family:
       text:  tokens (b, s)
       vlm:   patch_embeds (b, p, d) + tokens (b, s-p)
       audio: frames (b, enc_len, d) + tokens (b, dec_len)
    """
    tokens = batch["tokens"]
    memory = None
    if cfg.encdec is not None:
        memory = encode(params, cfg, batch["frames"], opts)
    x = _embed(params, cfg, tokens)
    n_text = x.shape[1]
    if cfg.frontend is not None and cfg.frontend.kind == "vision_patches":
        x = jnp.concatenate([batch["patch_embeds"].astype(x.dtype), x], axis=1)
    x, aux = _run_stack(params, cfg, x, memory=memory, opts=opts)
    return x[:, -n_text:], aux


def forward(params, cfg: ArchConfig, batch: Dict[str, jax.Array], *,
            opts: ApplyOptions = DEFAULT_OPTS) -> Tuple[jax.Array, jax.Array]:
    """Training forward: (logits over the token part, aux loss)."""
    x, aux = forward_hidden(params, cfg, batch, opts=opts)
    return _head(params, cfg, x), aux


LOSS_CHUNK = 512     # sequence positions per head/loss chunk


def make_loss_fn(cfg: ArchConfig, opts: ApplyOptions = DEFAULT_OPTS,
                 loss_chunk: int = LOSS_CHUNK):
    """Next-token cross-entropy. Signature matches ``repro.core.dfl.LossFn``.

    Two structural choices keep the head from dominating memory at
    256k-vocab scale:

    * **Chunked head** — the unembedding matmul + logsumexp run under a
      rematted lax.scan over ``loss_chunk``-position slices, so the peak
      logits tensor is (b, chunk, v/TP) instead of (b, s, v/TP); the
      backward recomputes each chunk's logits instead of saving them.
    * **Partitioner-friendly CE** — with the unembedding sharded over the
      "model" axis the chunk logits stay *vocab-sharded*: logsumexp
      partially reduces per shard (small (b, chunk) all-reduce), and the
      target logit is a one-hot contraction instead of take_along_axis
      (whose gather would force a full-vocab all-gather).
    """

    def loss_fn(params, batch, rng):
        del rng
        x, aux = forward_hidden(params, cfg, batch, opts=opts)
        xs = x[:, :-1]                                       # predict t+1
        targets = batch["tokens"][:, 1:]
        b, sm1, d = xs.shape
        chunk = min(loss_chunk, sm1)
        pad = (-sm1) % chunk
        if pad:
            xs = jnp.pad(xs, ((0, 0), (0, pad), (0, 0)))
            targets = jnp.pad(targets, ((0, 0), (0, pad)),
                              constant_values=-1)            # masked
        nc = (sm1 + pad) // chunk
        xc = jnp.moveaxis(xs.reshape(b, nc, chunk, d), 1, 0)
        tc = jnp.moveaxis(targets.reshape(b, nc, chunk), 1, 0)

        def body(total, inp):
            x_c, t_c = inp
            logits = _head(params, cfg, x_c).astype(jnp.float32)
            lse = jax.nn.logsumexp(logits, axis=-1)          # (b, chunk)
            vocab_ids = jax.lax.broadcasted_iota(
                jnp.int32, logits.shape, logits.ndim - 1)
            tgt = jnp.sum(jnp.where(vocab_ids == t_c[..., None], logits, 0.0),
                          axis=-1)
            valid = t_c >= 0
            nll = jnp.where(valid, lse - tgt, 0.0)
            return total + nll.sum(), None

        total, _ = jax.lax.scan(jax.checkpoint(body),
                                jnp.zeros((), jnp.float32), (xc, tc))
        nll_mean = total / (b * sm1)
        loss = nll_mean + aux
        return loss, {"nll": nll_mean, "aux": aux}

    return loss_fn


# ---------------------------------------------------------------------------
# serving: cache init / prefill / decode
# ---------------------------------------------------------------------------


def _block_cache_init(cfg: ArchConfig, kind: str, batch: int, max_len: int,
                      dtype, cross: bool) -> Dict:
    c: Dict[str, Any] = {}
    if kind == "mamba":
        c["mixer"] = mamba_mod.mamba_cache_init(cfg, batch, dtype)
    elif cfg.mla is not None:
        c["mixer"] = nn.mla_cache_init(cfg, batch, max_len, dtype)
    else:
        c["mixer"] = nn.attention_cache_init(cfg, batch, max_len, kind, dtype)
    if cross:
        hd = cfg.resolved_head_dim()
        enc_len = int(max_len * cfg.encdec.encoder_len_ratio)
        c["cross_k"] = jnp.zeros((batch, enc_len, cfg.num_kv_heads, hd), dtype)
        c["cross_v"] = jnp.zeros((batch, enc_len, cfg.num_kv_heads, hd), dtype)
    return c


def init_cache(cfg: ArchConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16) -> Dict:
    plan = stack_plan(cfg)
    cross = cfg.encdec is not None
    cache: Dict[str, Any] = {"position": jnp.zeros((), jnp.int32)}
    cache["prefix"] = tuple(
        _block_cache_init(cfg, cfg.pattern_for_layer(i), batch, max_len,
                          dtype, cross)
        for i in range(plan.num_prefix))

    def one_period(_):
        return tuple(
            _block_cache_init(cfg, _layer_flags(cfg, plan.num_prefix + i)[0],
                              batch, max_len, dtype, cross)
            for i in range(plan.period))

    cache["stack"] = jax.vmap(one_period)(jnp.arange(plan.n_periods))
    return cache


def _block_decode(params, cache, x, cfg: ArchConfig, kind: str, is_moe: bool,
                  position) -> Tuple[jax.Array, Dict]:
    new_cache = dict(cache)
    h = nn.rmsnorm_apply(params["ln1"], x, cfg.norm_eps)
    if kind == "mamba":
        mix, new_cache["mixer"] = mamba_mod.mamba_decode_step(
            params["mixer"], h, cache["mixer"], cfg)
    elif cfg.mla is not None:
        # absorbed attention (W_UK/W_UV folded into q/out): attends the
        # compact latent cache directly — the naive path re-expands
        # (b, S, h, hd) K/V per layer per step (~80 GB/device at 32k).
        mix, new_cache["mixer"] = nn.mla_decode_step(
            params["mixer"], h, cache["mixer"], position, cfg, absorbed=True)
    else:
        mix, new_cache["mixer"] = nn.attention_decode_step(
            params["mixer"], h, cache["mixer"], position, cfg, layer_kind=kind)
    if "post_ln1" in params:
        mix = nn.rmsnorm_apply(params["post_ln1"], mix, cfg.norm_eps)
    x = x + mix
    if "cross_attn" in params and "cross_k" in cache:
        h = nn.rmsnorm_apply(params["cross_ln"], x, cfg.norm_eps)
        q = jnp.einsum("bsd,dhk->bshk", h, params["cross_attn"]["w_q"])
        out = nn.mha_attend(q, cache["cross_k"].astype(h.dtype),
                            cache["cross_v"].astype(h.dtype), None,
                            attn_softcap=None)
        x = x + jnp.einsum("bshk,hkd->bsd", out.astype(h.dtype),
                           params["cross_attn"]["w_o"])
    if "ffn" in params:
        h = nn.rmsnorm_apply(params["ln2"], x, cfg.norm_eps)
        if is_moe:
            ff, _ = nn.moe_apply(params["ffn"], h, cfg, no_drop=True)
        else:
            ff = nn.mlp_apply(params["ffn"], h, cfg.act)
        if "post_ln2" in params:
            ff = nn.rmsnorm_apply(params["post_ln2"], ff, cfg.norm_eps)
        x = x + ff
    return x, new_cache


def decode_step(params, cfg: ArchConfig, token: jax.Array, cache: Dict,
                ) -> Tuple[jax.Array, Dict]:
    """One synchronous decode step. token: (b, 1) int32."""
    plan = stack_plan(cfg)
    position = cache["position"]
    x = _embed(params, cfg, token)
    new_cache = dict(cache)
    new_prefix = []
    for i, blk in enumerate(params.get("prefix", ())):
        kind, _ = _layer_flags(cfg, i)
        x, c = _block_decode(blk, cache["prefix"][i], x, cfg, kind, False,
                             position)
        new_prefix.append(c)
    new_cache["prefix"] = tuple(new_prefix)

    def body(x, scanned):
        period_params, period_cache = scanned
        new_pc = []
        for i in range(plan.period):
            kind, is_moe = _layer_flags(cfg, plan.num_prefix + i)
            x, c = _block_decode(period_params[i], period_cache[i], x, cfg,
                                 kind, is_moe, position)
            new_pc.append(c)
        return x, tuple(new_pc)

    x, new_stack = jax.lax.scan(body, x, (params["stack"], cache["stack"]))
    new_cache["stack"] = new_stack
    new_cache["position"] = position + 1
    return _head(params, cfg, x), new_cache


def prefill(params, cfg: ArchConfig, batch: Dict[str, jax.Array], *,
            max_len: Optional[int] = None, cache_dtype=jnp.bfloat16,
            opts: ApplyOptions = DEFAULT_OPTS) -> Tuple[jax.Array, Dict]:
    """Run the full prompt, build a cache ready for decode.

    For simplicity and FLOPs-faithfulness the prefill trunk is the full
    forward; KV extraction re-runs projections per layer into the cache via a
    dedicated pass (kept O(prompt) — acceptable; real deployments fuse it).
    Here we take the standard approach: run per-layer apply while recording
    K/V.  For the dry-run what matters is that the compiled program has
    prefill cost + cache writes, which this does.
    """
    tokens = batch["tokens"]
    b, s = tokens.shape
    max_len = max_len or s
    memory = None
    if cfg.encdec is not None:
        memory = encode(params, cfg, batch["frames"], opts)
    x = _embed(params, cfg, tokens)
    if cfg.frontend is not None and cfg.frontend.kind == "vision_patches":
        x = jnp.concatenate([batch["patch_embeds"].astype(x.dtype), x], axis=1)

    cache = init_cache(cfg, b, max_len, cache_dtype)
    plan = stack_plan(cfg)
    seq = x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(seq), (b, seq))

    def fill_block(blk_params, blk_cache, x, kind, is_moe):
        h = nn.rmsnorm_apply(blk_params["ln1"], x, cfg.norm_eps)
        new_c = dict(blk_cache)
        if kind == "mamba":
            mix, new_c["mixer"] = mamba_mod.mamba_prefill(
                blk_params["mixer"], h, cfg,
                conv_cache_dtype=blk_cache["mixer"]["conv"].dtype,
                chunk_override=opts.ssd_chunk,
                head_sharding=opts.ssd_head_sharding)
        elif cfg.mla is not None:
            q, c_kv, k_rope = nn._mla_qkv(blk_params["mixer"], h, cfg, positions)
            mix = nn._mla_attend(blk_params["mixer"], q, c_kv, k_rope,
                                 None, cfg, causal=True,
                                 head_sharding=opts.attn_head_sharding)
            m = cfg.mla
            new_c["mixer"] = {
                "c_kv": _pad_to(c_kv, max_len).astype(cache_dtype),
                "k_rope": _pad_to(k_rope, max_len).astype(cache_dtype),
                "pos": _pad_to(positions.astype(jnp.int32), max_len, fill=-1),
            }
        else:
            window = cfg.sliding_window if kind == "local" else None
            q, k, v = nn._project_qkv(blk_params["mixer"], h, h, cfg,
                                      positions, positions, use_rope=True)
            out = nn.dispatch_attend(q, k, v, causal=True, window=window,
                                     attn_softcap=cfg.attn_logit_softcap,
                                     attn_impl=opts.attn_impl,
                                     head_sharding=opts.attn_head_sharding)
            mix = jnp.einsum("bshk,hkd->bsd", out.astype(h.dtype),
                             blk_params["mixer"]["w_o"])
            if "b_o" in blk_params["mixer"]:
                mix = mix + blk_params["mixer"]["b_o"]
            n = blk_cache["mixer"]["k"].shape[1]
            if n >= seq:
                new_c["mixer"] = {
                    "k": _pad_to(k, n).astype(cache_dtype),
                    "v": _pad_to(v, n).astype(cache_dtype),
                    "pos": _pad_to(positions.astype(jnp.int32), n, fill=-1),
                }
            else:  # sliding-window ring: keep last n, slot = pos % n
                new_c["mixer"] = _ring_pack(k, v, positions, n, cache_dtype)
        if "post_ln1" in blk_params:
            mix = nn.rmsnorm_apply(blk_params["post_ln1"], mix, cfg.norm_eps)
        x = x + mix
        if "cross_attn" in blk_params and memory is not None:
            hh = nn.rmsnorm_apply(blk_params["cross_ln"], x, cfg.norm_eps)
            mem_mask = jnp.ones((x.shape[1], memory.shape[1]), bool)
            x = x + nn.attention_apply(blk_params["cross_attn"], hh, cfg,
                                       kv_override=(memory, mem_mask))
            ck = jnp.einsum("bsd,dhk->bshk", memory,
                            blk_params["cross_attn"]["w_k"])
            cv = jnp.einsum("bsd,dhk->bshk", memory,
                            blk_params["cross_attn"]["w_v"])
            new_c["cross_k"] = ck.astype(cache_dtype)
            new_c["cross_v"] = cv.astype(cache_dtype)
        if "ffn" in blk_params:
            h = nn.rmsnorm_apply(blk_params["ln2"], x, cfg.norm_eps)
            if is_moe:
                ff, _ = nn.moe_apply(blk_params["ffn"], h, cfg,
                                     capacity_factor=opts.capacity_factor,
                                     no_drop=opts.moe_no_drop,
                                     groups=opts.moe_groups,
                                     group_sharding=opts.moe_group_sharding)
            else:
                ff = nn.mlp_apply(blk_params["ffn"], h, cfg.act)
            if "post_ln2" in blk_params:
                ff = nn.rmsnorm_apply(blk_params["post_ln2"], ff, cfg.norm_eps)
            x = x + ff
        return x, new_c

    new_prefix = []
    for i, blk in enumerate(params.get("prefix", ())):
        kind, _ = _layer_flags(cfg, i)
        x, c = fill_block(blk, cache["prefix"][i], x, kind, False)
        new_prefix.append(c)

    def body(x, scanned):
        period_params, period_cache = scanned
        new_pc = []
        for i in range(plan.period):
            kind, is_moe = _layer_flags(cfg, plan.num_prefix + i)
            x, c = fill_block(period_params[i], period_cache[i], x, kind,
                              is_moe)
            new_pc.append(c)
        return x, tuple(new_pc)

    x, new_stack = jax.lax.scan(body, x, (params["stack"], cache["stack"]))
    logits = _head(params, cfg, x[:, -1:])
    return logits, {"position": jnp.asarray(seq, jnp.int32),
                    "prefix": tuple(new_prefix), "stack": new_stack}


def _pad_to(arr: jax.Array, n: int, fill=0):
    if arr.shape[1] == n:
        return arr
    pad = [(0, 0)] * arr.ndim
    pad[1] = (0, n - arr.shape[1])
    return jnp.pad(arr, pad, constant_values=fill)


def _ring_pack(k, v, positions, n, cache_dtype):
    """Pack the last ``n`` keys of a longer prompt into ring order."""
    seq = k.shape[1]
    kk, vv, pp = k[:, -n:], v[:, -n:], positions[:, -n:]
    # slot for position p is p % n: rotate so that entry j sits at slot pp[j]%n
    slots = pp[0] % n
    order = jnp.argsort(slots)
    return {"k": kk[:, order].astype(cache_dtype),
            "v": vv[:, order].astype(cache_dtype),
            "pos": pp[:, order].astype(jnp.int32)}
