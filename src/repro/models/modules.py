"""Model building blocks (pure-JAX, functional init/apply style).

Conventions
-----------
* A module is a pair of functions ``<name>_init(key, ...) -> params`` and
  ``<name>_apply(params, x, ...) -> y``; params are plain dict pytrees.
* Every weight leaf name is stable — the sharding resolver in
  ``repro/launch/sharding.py`` maps leaf paths to PartitionSpecs.
* ``cfg`` is an ``ArchConfig``; compute happens in ``x.dtype`` (callers pick
  bf16 for deployment-shaped runs, f32 for CPU tests).
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig


def _dense_init(key, shape, dtype, scale: Optional[float] = None):
    """Truncated-normal fan-in init."""
    fan_in = shape[0] if len(shape) > 1 else 1
    std = scale if scale is not None else 1.0 / math.sqrt(fan_in)
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * std).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------


def rmsnorm_init(d: int, dtype=jnp.float32) -> Dict:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm_apply(params: Dict, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(x.dtype)


def softcap(x: jax.Array, cap: Optional[float]) -> jax.Array:
    if cap is None:
        return x
    return (cap * jnp.tanh(x.astype(jnp.float32) / cap)).astype(x.dtype)


# ---------------------------------------------------------------------------
# rotary position embedding
# ---------------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., seq, heads, head_dim); positions: broadcastable to (..., seq)."""
    freqs = rope_frequencies(x.shape[-1], theta)                 # (half,)
    angles = positions[..., None].astype(jnp.float32) * freqs    # (..., seq, half)
    cos = jnp.cos(angles)[..., None, :]                          # (..., seq, 1, half)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention (GQA family: qk-norm, softcap, sliding window)
# ---------------------------------------------------------------------------


def attention_init(key, cfg: ArchConfig, dtype=jnp.float32, cross: bool = False) -> Dict:
    d, h, kvh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads
    hd = cfg.resolved_head_dim()
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p = {
        "w_q": _dense_init(k1, (d, h, hd), dtype),
        "w_k": _dense_init(k2, (d, kvh, hd), dtype),
        "w_v": _dense_init(k3, (d, kvh, hd), dtype),
        "w_o": _dense_init(k4, (h, hd, d), dtype, scale=1.0 / math.sqrt(h * hd)),
    }
    if cfg.use_bias:
        p["b_q"] = jnp.zeros((h, hd), dtype)
        p["b_k"] = jnp.zeros((kvh, hd), dtype)
        p["b_v"] = jnp.zeros((kvh, hd), dtype)
        p["b_o"] = jnp.zeros((d,), dtype)
    if cfg.qk_norm and not cross:
        p["q_norm"] = rmsnorm_init(hd, dtype)
        p["k_norm"] = rmsnorm_init(hd, dtype)
    return p


def _project_qkv(params, xq, xkv, cfg: ArchConfig, positions_q, positions_k,
                 *, use_rope: bool):
    q = jnp.einsum("bsd,dhk->bshk", xq, params["w_q"])
    k = jnp.einsum("bsd,dhk->bshk", xkv, params["w_k"])
    v = jnp.einsum("bsd,dhk->bshk", xkv, params["w_v"])
    if "b_q" in params:
        q = q + params["b_q"]
        k = k + params["b_k"]
        v = v + params["b_v"]
    if "q_norm" in params:
        q = rmsnorm_apply(params["q_norm"], q, cfg.norm_eps)
        k = rmsnorm_apply(params["k_norm"], k, cfg.norm_eps)
    if use_rope:
        q = apply_rope(q, positions_q, cfg.rope_theta)
        k = apply_rope(k, positions_k, cfg.rope_theta)
    return q, k, v


def _expand_kv(k: jax.Array, h: int) -> jax.Array:
    """GQA: repeat kv heads up to the q-head count BEFORE the score einsum.

    Sharding rationale (DESIGN.md §5): scores carry a head axis; expanding
    first makes that axis h (divisible by the 16-wide "model" mesh axis for
    every assigned arch with h % 16 == 0), whereas the grouped (kvh, g)
    factorization would cap head-sharding at kvh (= 8 for most GQA archs)
    and replicate multi-GB score tensors per device."""
    kvh = k.shape[2]
    if kvh == h:
        return k
    return jnp.repeat(k, h // kvh, axis=2)


def mha_attend(q: jax.Array, k: jax.Array, v: jax.Array,
               mask: Optional[jax.Array], *, attn_softcap: Optional[float],
               scale: Optional[float] = None) -> jax.Array:
    """Reference attention. q: (b, sq, h, hd); k/v: (b, sk, kvh, hd).
    mask: (sq, sk), (b, sq, sk) or (b, 1, sq, sk).  Materializes the full
    (b, h, sq, sk) scores — fine for decode (sq=1) and short sequences;
    long-sequence paths use ``attend_chunked``."""
    b, sq, h, hd = q.shape
    vd = v.shape[-1]          # may differ from hd (MLA: v_head_dim != qk dim)
    k = _expand_kv(k, h)
    v = _expand_kv(v, h)
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32) * scale,
                        k.astype(jnp.float32))
    scores = softcap(scores, attn_softcap)
    if mask is not None:
        while mask.ndim < scores.ndim:
            mask = mask[:, None] if mask.ndim >= 2 and mask.shape[0] == b else mask[None]
        scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, v.astype(jnp.float32))
    return out.reshape(b, sq, h, vd)


def _chunk_mask(ic, chunk: int, sk: int, sq: int, causal: bool,
                window: Optional[int]):
    """(sq, chunk) validity of k chunk ``ic`` (queries end-aligned)."""
    q_pos = jnp.arange(sq) + (sk - sq)
    k_pos = ic * chunk + jnp.arange(chunk)
    valid = jnp.broadcast_to(k_pos[None, :] < sk, (sq, chunk))
    if causal:
        valid = valid & (k_pos[None, :] <= q_pos[:, None])
    if window is not None:
        valid = valid & (k_pos[None, :] > q_pos[:, None] - window)
    return valid


def _attend_fwd_impl(q, k, v, causal, window, cap, scale, chunk):
    """Online-softmax forward.  q: (b,sq,h,hd); k/v: (b,sk,h,{hd,vd}).
    Returns (out (b,sq,h,vd) f32, lse (b,h,sq) f32)."""
    b, sq, h, hd = q.shape
    sk = k.shape[1]
    vd = v.shape[-1]
    pad = (-sk) % chunk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nc = (sk + pad) // chunk
    kc = jnp.moveaxis(k.reshape(b, nc, chunk, h, hd), 1, 0)
    vc = jnp.moveaxis(v.reshape(b, nc, chunk, h, vd), 1, 0)
    qf = q.astype(jnp.float32) * scale

    def body(carry, inp):
        m_prev, l_prev, acc = carry
        ic, k_c, v_c = inp
        s = jnp.einsum("bqhd,bkhd->bhqk", qf, k_c.astype(jnp.float32))
        s = softcap(s, cap)
        valid = _chunk_mask(ic, chunk, sk, sq, causal, window)
        s = jnp.where(valid[None, None], s, -jnp.inf)
        m_cur = jnp.max(s, axis=-1)                    # (b, h, sq)
        m_new = jnp.maximum(m_prev, m_cur)
        safe_m = jnp.where(jnp.isinf(m_new), 0.0, m_new)
        p = jnp.where(valid[None, None], jnp.exp(s - safe_m[..., None]), 0.0)
        alpha = jnp.where(jnp.isinf(m_prev), 0.0, jnp.exp(m_prev - safe_m))
        l_new = alpha * l_prev + p.sum(-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bhqk,bkhd->bhqd", p, v_c.astype(jnp.float32))
        return (m_new, l_new, acc), None

    init = (jnp.full((b, h, sq), -jnp.inf, jnp.float32),
            jnp.zeros((b, h, sq), jnp.float32),
            jnp.zeros((b, h, sq, vd), jnp.float32))
    (m, l, acc), _ = jax.lax.scan(body, init, (jnp.arange(nc), kc, vc))
    out = acc / jnp.where(l == 0.0, 1.0, l)[..., None]
    lse = jnp.where(l > 0.0, m + jnp.log(jnp.where(l > 0.0, l, 1.0)), -jnp.inf)
    return jnp.moveaxis(out, 1, 2), lse                 # (b, sq, h, vd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def _attend_core(q, k, v, causal, window, cap, scale, chunk):
    out, _ = _attend_fwd_impl(q, k, v, causal, window, cap, scale, chunk)
    return out


def _attend_core_fwd(q, k, v, causal, window, cap, scale, chunk):
    out, lse = _attend_fwd_impl(q, k, v, causal, window, cap, scale, chunk)
    return out, (q, k, v, out, lse)


def _attend_core_bwd(causal, window, cap, scale, chunk, res, dout):
    """Flash-style backward: recompute scores chunkwise from (q, k, v, lse)
    — O(b*h*sq*chunk) transients instead of saving per-chunk probabilities
    (which is what a naively differentiated scan would do, and is the
    difference between ~0.3 GB and ~16 GB of residuals per layer at 4k)."""
    q, k, v, out, lse = res
    b, sq, h, hd = q.shape
    sk = k.shape[1]
    vd = v.shape[-1]
    pad = (-sk) % chunk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nc = (sk + pad) // chunk
    kc = jnp.moveaxis(k.reshape(b, nc, chunk, h, hd), 1, 0)
    vc = jnp.moveaxis(v.reshape(b, nc, chunk, h, vd), 1, 0)
    qf = q.astype(jnp.float32) * scale
    doutf = jnp.moveaxis(dout.astype(jnp.float32), 2, 1)   # (b, h, sq, vd)
    outf = jnp.moveaxis(out.astype(jnp.float32), 2, 1)
    delta = jnp.sum(doutf * outf, axis=-1)                 # (b, h, sq)
    lse_safe = jnp.where(jnp.isinf(lse), 0.0, lse)

    def body(dq_acc, inp):
        ic, k_c, v_c = inp
        kf = k_c.astype(jnp.float32)
        s_raw = jnp.einsum("bqhd,bkhd->bhqk", qf, kf)
        s = softcap(s_raw, cap)
        valid = _chunk_mask(ic, chunk, sk, sq, causal, window)
        p = jnp.where(valid[None, None],
                      jnp.exp(s - lse_safe[..., None]), 0.0)   # (b,h,sq,k)
        dv_c = jnp.einsum("bhqk,bhqd->bkhd", p, doutf)
        dp = jnp.einsum("bhqd,bkhd->bhqk", doutf, v_c.astype(jnp.float32))
        ds = p * (dp - delta[..., None])
        if cap is not None:
            ds = ds * (1.0 - jnp.square(s / cap))
        dq_acc = dq_acc + jnp.einsum("bhqk,bkhd->bqhd", ds, kf) * scale
        dk_c = jnp.einsum("bhqk,bqhd->bkhd", ds, qf)  # qf includes scale
        return dq_acc, (dk_c, dv_c)

    dq0 = jnp.zeros((b, sq, h, hd), jnp.float32)
    dq, (dks, dvs) = jax.lax.scan(body, dq0, (jnp.arange(nc), kc, vc))
    dk = jnp.moveaxis(dks, 0, 1).reshape(b, nc * chunk, h, hd)[:, :sk]
    dv = jnp.moveaxis(dvs, 0, 1).reshape(b, nc * chunk, h, vd)[:, :sk]
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


_attend_core.defvjp(_attend_core_fwd, _attend_core_bwd)


def attend_chunked(q: jax.Array, k: jax.Array, v: jax.Array, *,
                   causal: bool = True, window: Optional[int] = None,
                   attn_softcap: Optional[float] = None,
                   scale: Optional[float] = None,
                   chunk: int = 512) -> jax.Array:
    """Memory-efficient (online-softmax) attention: lax.scan over KV chunks
    with a flash-style custom VJP.

    The pure-JAX twin of ``kernels/flash_attention.py`` — peak activation is
    O(b*h*sq*chunk) instead of O(b*h*sq*sk) in BOTH directions, which is
    what lets 32k prefill and 4k training lower within a v5e's HBM on the
    jnp path (the Pallas kernel covers the TPU runtime; this covers
    XLA-only and the CPU dry-run).  Queries sit at the END of the key
    sequence (q_offset = sk - sq), matching the kernel and ref.py.
    """
    b, sq, h, hd = q.shape
    sk = k.shape[1]
    k = _expand_kv(k, h)
    v = _expand_kv(v, h)
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    chunk = min(chunk, sk)
    return _attend_core(q, k, v, causal, window, attn_softcap, scale, chunk)


# sk above this uses attend_chunked on the non-Pallas full-sequence path
FULL_ATTEND_MAX_KEYS = 1024


def dispatch_attend(q, k, v, *, causal: bool, window: Optional[int],
                    attn_softcap: Optional[float],
                    scale: Optional[float] = None,
                    attn_impl: str = "reference",
                    head_sharding=None) -> jax.Array:
    """Route a full-sequence attention to pallas / chunked / naive.

    ``head_sharding``: optional NamedSharding for (b, s, h, hd) — pins the
    head axis to the "model" mesh axis so the chunked-attention loop state
    shards by heads instead of replicating (MLA's 128 expanded heads are
    3.2 GB/layer at 32k otherwise)."""
    if head_sharding is not None:
        # expand GQA kv up-front so all three tensors carry the full (and
        # mesh-divisible) head count before pinning
        k = _expand_kv(k, q.shape[2])
        v = _expand_kv(v, q.shape[2])
        q = jax.lax.with_sharding_constraint(q, head_sharding)
        k = jax.lax.with_sharding_constraint(k, head_sharding)
        v = jax.lax.with_sharding_constraint(v, head_sharding)
    if attn_impl == "pallas":
        from repro.kernels import ops as kops
        return kops.flash_attention(q, k, v, causal=causal, window=window,
                                    softcap=attn_softcap, scale=scale)
    if k.shape[1] > FULL_ATTEND_MAX_KEYS:
        return attend_chunked(q, k, v, causal=causal, window=window,
                              attn_softcap=attn_softcap, scale=scale)
    sq, sk = q.shape[1], k.shape[1]
    mask = causal_mask(sq, sk, q_offset=sk - sq, window=window) if causal \
        else (jnp.ones((sq, sk), bool) if window is None else
              causal_mask(sq, sk, q_offset=sk - sq, window=window))
    return mha_attend(q, k, v, mask if (causal or window) else None,
                      attn_softcap=attn_softcap, scale=scale)


def causal_mask(sq: int, sk: int, q_offset: int = 0,
                window: Optional[int] = None) -> jax.Array:
    """(sq, sk) boolean mask; query i attends key j iff j <= i+off and within
    the sliding window (if any)."""
    qpos = jnp.arange(sq) + q_offset
    kpos = jnp.arange(sk)
    m = kpos[None, :] <= qpos[:, None]
    if window is not None:
        m = m & (kpos[None, :] > qpos[:, None] - window)
    return m


def attention_apply(params: Dict, x: jax.Array, cfg: ArchConfig, *,
                    layer_kind: str = "global",
                    positions: Optional[jax.Array] = None,
                    kv_override: Optional[Tuple[jax.Array, jax.Array]] = None,
                    causal: bool = True,
                    attn_impl: str = "reference",
                    head_sharding=None) -> jax.Array:
    """Self- (or cross-, via kv_override) attention over a full sequence."""
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    window = cfg.sliding_window if layer_kind == "local" else None
    if kv_override is None:
        q, k, v = _project_qkv(params, x, x, cfg, positions, positions,
                               use_rope=True)
    else:
        mem, _ = kv_override
        q = jnp.einsum("bsd,dhk->bshk", x, params["w_q"])
        if "b_q" in params:
            q = q + params["b_q"]
        k = jnp.einsum("bsd,dhk->bshk", mem, params["w_k"])
        v = jnp.einsum("bsd,dhk->bshk", mem, params["w_v"])
        if "b_k" in params:
            k, v = k + params["b_k"], v + params["b_v"]
        causal, window = False, None       # cross-attn sees all memory
    out = dispatch_attend(q, k, v, causal=causal, window=window,
                          attn_softcap=cfg.attn_logit_softcap,
                          attn_impl=attn_impl, head_sharding=head_sharding)
    y = jnp.einsum("bshk,hkd->bsd", out.astype(x.dtype), params["w_o"])
    if "b_o" in params:
        y = y + params["b_o"]
    return y


# -- incremental decode ------------------------------------------------------


def attention_cache_init(cfg: ArchConfig, batch: int, max_len: int,
                         layer_kind: str, dtype=jnp.bfloat16) -> Dict:
    """Ring-buffer KV cache. Local layers only keep ``sliding_window`` slots."""
    kvh, hd = cfg.num_kv_heads, cfg.resolved_head_dim()
    n = min(max_len, cfg.sliding_window) if (
        layer_kind == "local" and cfg.sliding_window) else max_len
    return {
        "k": jnp.zeros((batch, n, kvh, hd), dtype),
        "v": jnp.zeros((batch, n, kvh, hd), dtype),
        "pos": jnp.full((batch, n), -1, jnp.int32),  # true position of each slot
    }


def attention_decode_step(params: Dict, x: jax.Array, cache: Dict,
                          position: jax.Array, cfg: ArchConfig, *,
                          layer_kind: str = "global") -> Tuple[jax.Array, Dict]:
    """One-token decode. x: (b, 1, d); position: scalar int32 (same for the
    whole batch — standard synchronous decode)."""
    b = x.shape[0]
    n = cache["k"].shape[1]
    pos_b = jnp.broadcast_to(position, (b, 1))
    q, k, v = _project_qkv(params, x, x, cfg, pos_b, pos_b, use_rope=True)
    slot = position % n
    ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k.astype(cache["k"].dtype), slot, axis=1)
    cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v.astype(cache["v"].dtype), slot, axis=1)
    cpos = jax.lax.dynamic_update_slice_in_dim(
        cache["pos"], pos_b.astype(jnp.int32), slot, axis=1)
    window = cfg.sliding_window if layer_kind == "local" else None
    valid = (cpos >= 0) & (cpos <= position)
    if window is not None:
        valid = valid & (cpos > position - window)
    mask = valid[:, None, :]                                   # (b, 1, n)
    out = mha_attend(q, ck, cv, mask, attn_softcap=cfg.attn_logit_softcap)
    y = jnp.einsum("bshk,hkd->bsd", out.astype(x.dtype), params["w_o"])
    if "b_o" in params:
        y = y + params["b_o"]
    return y, {"k": ck, "v": cv, "pos": cpos}


# ---------------------------------------------------------------------------
# MLA — multi-head latent attention (DeepSeek-V2)
# ---------------------------------------------------------------------------


def mla_init(key, cfg: ArchConfig, dtype=jnp.float32) -> Dict:
    m = cfg.mla
    d, h = cfg.d_model, cfg.num_heads
    ks = jax.random.split(key, 6)
    qh = m.qk_nope_head_dim + m.qk_rope_head_dim
    return {
        "w_dq": _dense_init(ks[0], (d, m.q_lora_rank), dtype),
        "q_norm": rmsnorm_init(m.q_lora_rank, dtype),
        "w_uq": _dense_init(ks[1], (m.q_lora_rank, h, qh), dtype),
        "w_dkv": _dense_init(ks[2], (d, m.kv_lora_rank + m.qk_rope_head_dim), dtype),
        "kv_norm": rmsnorm_init(m.kv_lora_rank, dtype),
        "w_ukv": _dense_init(ks[3], (m.kv_lora_rank, h,
                                     m.qk_nope_head_dim + m.v_head_dim), dtype),
        "w_o": _dense_init(ks[4], (h, m.v_head_dim, d), dtype,
                           scale=1.0 / math.sqrt(h * m.v_head_dim)),
    }


def _mla_qkv(params, x, cfg: ArchConfig, positions):
    """Returns q (b,s,h,qh), latent c_kv (b,s,r), shared k_rope (b,s,rope)."""
    m = cfg.mla
    cq = rmsnorm_apply(params["q_norm"], jnp.einsum("bsd,dr->bsr", x, params["w_dq"]),
                       cfg.norm_eps)
    q = jnp.einsum("bsr,rhk->bshk", cq, params["w_uq"])
    q_nope, q_rope = jnp.split(q, [m.qk_nope_head_dim], axis=-1)
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    dkv = jnp.einsum("bsd,dr->bsr", x, params["w_dkv"])
    c_kv, k_rope = jnp.split(dkv, [m.kv_lora_rank], axis=-1)
    c_kv = rmsnorm_apply(params["kv_norm"], c_kv, cfg.norm_eps)
    k_rope = apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta)[:, :, 0]
    return jnp.concatenate([q_nope, q_rope], -1), c_kv, k_rope


def _mla_attend(params, q, c_kv, k_rope, mask, cfg: ArchConfig,
                causal: Optional[bool] = None, head_sharding=None):
    """Expand latent to per-head K/V and attend (naive/faithful path).

    ``mask`` is used for decode (ring-buffer validity); full-sequence
    callers pass ``causal=True`` and route through ``dispatch_attend`` so
    32k prefill never materializes (sq, sk) scores."""
    m = cfg.mla
    ukv = jnp.einsum("bsr,rhk->bshk", c_kv, params["w_ukv"])
    k_nope, v = jnp.split(ukv, [m.qk_nope_head_dim], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                  k_nope.shape[:3] + (m.qk_rope_head_dim,))], -1)
    scale = 1.0 / math.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)
    if causal is not None:
        out = dispatch_attend(q, k, v, causal=causal, window=None,
                              attn_softcap=None, scale=scale,
                              head_sharding=head_sharding)
    else:
        out = mha_attend(q, k, v, mask, attn_softcap=None, scale=scale)
    return jnp.einsum("bshk,hkd->bsd", out.astype(q.dtype), params["w_o"])


def mla_apply(params: Dict, x: jax.Array, cfg: ArchConfig,
              positions: Optional[jax.Array] = None,
              head_sharding=None) -> jax.Array:
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    q, c_kv, k_rope = _mla_qkv(params, x, cfg, positions)
    return _mla_attend(params, q, c_kv, k_rope, None, cfg, causal=True,
                       head_sharding=head_sharding)


def mla_cache_init(cfg: ArchConfig, batch: int, max_len: int,
                   dtype=jnp.bfloat16) -> Dict:
    m = cfg.mla
    return {
        "c_kv": jnp.zeros((batch, max_len, m.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((batch, max_len, m.qk_rope_head_dim), dtype),
        "pos": jnp.full((batch, max_len), -1, jnp.int32),
    }


def mla_decode_step(params: Dict, x: jax.Array, cache: Dict,
                    position: jax.Array, cfg: ArchConfig,
                    absorbed: bool = False) -> Tuple[jax.Array, Dict]:
    b = x.shape[0]
    pos_b = jnp.broadcast_to(position, (b, 1))
    q, c_kv, k_rope = _mla_qkv(params, x, cfg, pos_b)
    ck = jax.lax.dynamic_update_slice_in_dim(
        cache["c_kv"], c_kv.astype(cache["c_kv"].dtype), position, axis=1)
    cr = jax.lax.dynamic_update_slice_in_dim(
        cache["k_rope"], k_rope.astype(cache["k_rope"].dtype), position, axis=1)
    cpos = jax.lax.dynamic_update_slice_in_dim(
        cache["pos"], pos_b.astype(jnp.int32), position, axis=1)
    mask = ((cpos >= 0) & (cpos <= position))[:, None, :]
    new_cache = {"c_kv": ck, "k_rope": cr, "pos": cpos}
    if absorbed:
        y = _mla_attend_absorbed(params, q, ck, cr, mask, cfg)
    else:
        y = _mla_attend(params, q, ck.astype(x.dtype), cr.astype(x.dtype),
                        mask, cfg)
    return y, new_cache


def _mla_attend_absorbed(params, q, c_kv, k_rope, mask, cfg: ArchConfig):
    """Beyond-paper decode optimization: absorb W_UK into the query and W_UV
    into the output so the latent cache is attended *directly* — avoids
    materialising per-head K/V of size (b, S, h, hd) each step.  Math is
    identical (associativity of matmul)."""
    m = cfg.mla
    w_uk, w_uv = jnp.split(params["w_ukv"], [m.qk_nope_head_dim], axis=-1)
    q_nope, q_rope = jnp.split(q, [m.qk_nope_head_dim], axis=-1)
    # q_lat[b,t,h,r] = q_nope . W_UK^T : query expressed in latent space
    q_lat = jnp.einsum("bthk,rhk->bthr", q_nope.astype(jnp.float32),
                       w_uk.astype(jnp.float32))
    scale = 1.0 / math.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)
    scores = (jnp.einsum("bthr,bsr->bhts", q_lat, c_kv.astype(jnp.float32))
              + jnp.einsum("bthk,bsk->bhts", q_rope.astype(jnp.float32),
                           k_rope.astype(jnp.float32))) * scale
    scores = jnp.where(mask[:, None] if mask.ndim == 3 else mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("bhts,bsr->bthr", probs, c_kv.astype(jnp.float32))  # latent ctx
    out = jnp.einsum("bthr,rhv->bthv", ctx, w_uv.astype(jnp.float32))
    return jnp.einsum("bthv,hvd->btd", out.astype(q.dtype), params["w_o"])


# ---------------------------------------------------------------------------
# MLPs and MoE
# ---------------------------------------------------------------------------


def mlp_init(key, d: int, ff: int, dtype=jnp.float32) -> Dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "gate": _dense_init(k1, (d, ff), dtype),
        "up": _dense_init(k2, (d, ff), dtype),
        "down": _dense_init(k3, (ff, d), dtype),
    }


def _act(x, kind: str):
    return jax.nn.silu(x) if kind == "silu" else jax.nn.gelu(x)


def mlp_apply(params: Dict, x: jax.Array, act: str = "silu") -> jax.Array:
    h = _act(jnp.einsum("bsd,df->bsf", x, params["gate"]), act)
    h = h * jnp.einsum("bsd,df->bsf", x, params["up"])
    return jnp.einsum("bsf,fd->bsd", h, params["down"])


def moe_init(key, cfg: ArchConfig, dtype=jnp.float32) -> Dict:
    moe = cfg.moe
    d = cfg.d_model
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    e, ff = moe.num_experts, moe.d_ff_expert
    p = {
        "router": _dense_init(k1, (d, e), dtype, scale=0.02),
        "w_gate": _dense_init(k2, (e, d, ff), dtype),
        "w_up": _dense_init(k3, (e, d, ff), dtype),
        "w_down": _dense_init(k4, (e, ff, d), dtype),
    }
    if moe.num_shared_experts:
        p["shared"] = mlp_init(k5, d, moe.num_shared_experts *
                               (moe.d_ff_shared or moe.d_ff_expert), dtype)
    return p


def moe_apply(params: Dict, x: jax.Array, cfg: ArchConfig,
              capacity_factor: float = 1.25,
              no_drop: bool = False, groups: int = 1,
              group_sharding: Optional[Any] = None
              ) -> Tuple[jax.Array, jax.Array]:
    """Top-k capacity-based dispatch (einsum MoE) with group-limited routing.

    ``groups`` splits the b*s tokens into G independent routing groups, each
    with capacity ``cf * (t/G) * k / e``.  This is a2a expert parallelism in
    pjit form: the grouped buffers (G, e, cap_g, d) are token-group-sharded
    before the expert matmul and expert-sharded inside it — the reshard XLA
    inserts between the two IS the all-to-all.  Per-device dispatch memory
    drops from O(e * cap * d) (global capacity, ~40 GB for deepseek-v2 at
    524k tokens/client) to O(e * cap_g * d / TP) (~0.3 GB).

    Returns (output, aux_loss).  The load-balance aux loss stays *client
    local* under DFL — routing statistics never leave the client (privacy
    note in DESIGN.md §4).
    """
    moe = cfg.moe
    b, s, d = x.shape
    e, k = moe.num_experts, moe.top_k
    t = b * s
    g = groups if (not no_drop and t % max(groups, 1) == 0) else 1
    tg = t // g
    tokens = x.reshape(g, tg, d)
    if group_sharding is not None and g > 1:
        tokens = jax.lax.with_sharding_constraint(tokens, group_sharding)
    logits = jnp.einsum("gtd,de->gte", tokens.astype(jnp.float32),
                        params["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)               # (g, tg, k)
    gate_vals = gate_vals / (gate_vals.sum(-1, keepdims=True) + 1e-9)

    # load-balance aux loss (Switch-style): E * sum_e f_e * p_e
    me = probs.mean((0, 1))
    ce = jnp.zeros((e,), jnp.float32).at[gate_idx.reshape(-1)].add(1.0) / (t * k)
    aux = e * jnp.sum(me * ce) * moe.router_aux_weight

    # decode paths must be drop-free (capacity == tokens covers worst-case
    # routing); training uses the usual 1.25x factor per group.
    capacity = tg if no_drop else max(1, int(capacity_factor * tg * k / e))
    # position of each (token, slot) within its expert's per-group buffer
    onehot = jax.nn.one_hot(gate_idx, e, dtype=jnp.int32)       # (g, tg, k, e)
    flat = onehot.reshape(g, tg * k, e)
    pos_in_expert = (jnp.cumsum(flat, axis=1) - flat).reshape(g, tg, k, e)
    pos = (pos_in_expert * onehot).sum(-1)                      # (g, tg, k)
    keep = pos < capacity
    gate_vals = gate_vals * keep

    # Gather-based dispatch: scatter only TOKEN INDICES (tiny, s32) into the
    # (g, e, cap) slot table, then build expert inputs with a batched
    # gather.  Scatter-adding full token VECTORS into (g, e, cap, d) defeats
    # the SPMD partitioner (it replicates the buffer across all groups —
    # ~21 GB/device for deepseek-v2 at 32k prefill); the batched gather
    # partitions cleanly along the group axis.  Slot `capacity` / token id
    # `tg` are the drop sentinels.
    safe_pos = jnp.where(keep, pos, capacity)                   # (g, tg, k)
    grange = jnp.arange(g)[:, None]
    token_ids = jnp.broadcast_to(jnp.arange(tg), (g, tg))
    slot_token = jnp.full((g, e, capacity + 1), tg, jnp.int32)
    for slot in range(k):                                       # k small/static
        slot_token = slot_token.at[
            grange, gate_idx[:, :, slot], safe_pos[:, :, slot]].set(token_ids)
    slot_token = slot_token[:, :, :capacity]                    # (g, e, cap)
    tokens_pad = jnp.pad(tokens, ((0, 0), (0, 1), (0, 0)))      # sentinel -> 0
    expert_in = jnp.take_along_axis(
        tokens_pad, slot_token.reshape(g, e * capacity)[..., None],
        axis=1).reshape(g, e, capacity, d)
    h = _act(jnp.einsum("gecd,edf->gecf", expert_in, params["w_gate"]),
             cfg.act)
    h = h * jnp.einsum("gecd,edf->gecf", expert_in, params["w_up"])
    expert_out = jnp.einsum("gecf,efd->gecd", h, params["w_down"])
    expert_out = jnp.pad(expert_out,
                         ((0, 0), (0, 0), (0, 1), (0, 0)))     # sentinel -> 0
    flat_out = expert_out.reshape(g, e * (capacity + 1), d)
    y = jnp.zeros((g, tg, d), x.dtype)
    for slot in range(k):
        idx = gate_idx[:, :, slot] * (capacity + 1) + safe_pos[:, :, slot]
        picked = jnp.take_along_axis(flat_out, idx[..., None], axis=1)
        y = y + picked * gate_vals[:, :, slot, None].astype(x.dtype)
    if "shared" in params:
        y = y + mlp_apply(params["shared"], tokens, cfg.act)
    return y.reshape(b, s, d), aux
