"""Sweep tests: Pallas flash attention (interpret) vs the jnp oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops
from repro.kernels.ref import attention_ref

KEY = jax.random.key(42)


def _qkv(b, sq, sk, h, kvh, hd, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(KEY, 3)
    return (jax.random.normal(k1, (b, sq, h, hd), dtype),
            jax.random.normal(k2, (b, sk, kvh, hd), dtype),
            jax.random.normal(k3, (b, sk, kvh, hd), dtype))


def _check(q, k, v, tol=2e-5, **kw):
    out = ops.flash_attention(q, k, v, block_q=64, block_k=64, **kw)
    ref = attention_ref(q, k, v, causal=kw.get("causal", True),
                        window=kw.get("window"), softcap=kw.get("softcap"))
    np.testing.assert_allclose(np.asarray(out, jnp.float32),
                               np.asarray(ref, jnp.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("shape", [
    (1, 128, 128, 4, 4, 64),     # MHA
    (2, 128, 128, 8, 2, 64),     # GQA 4:1
    (1, 256, 256, 4, 1, 128),    # MQA, hd 128
    (2, 64, 192, 4, 2, 64),      # decode-ish: sq < sk
    (1, 100, 100, 3, 3, 32),     # ragged seq, odd heads
    (1, 128, 130, 4, 4, 64),     # ragged keys
])
def test_flash_attention_shapes(shape):
    b, sq, sk, h, kvh, hd = shape
    _check(*_qkv(b, sq, sk, h, kvh, hd))


@pytest.mark.parametrize("window", [16, 64, 4096])
def test_flash_attention_sliding_window(window):
    _check(*_qkv(1, 128, 128, 4, 2, 64), window=window)


@pytest.mark.parametrize("softcap", [20.0, 50.0])
def test_flash_attention_softcap(softcap):
    _check(*_qkv(1, 128, 128, 4, 4, 64), softcap=softcap, tol=5e-5)


def test_flash_attention_non_causal():
    _check(*_qkv(1, 128, 128, 4, 4, 64), causal=False)


def test_flash_attention_window_and_softcap():
    _check(*_qkv(1, 128, 128, 4, 2, 64), window=48, softcap=30.0, tol=5e-5)


@pytest.mark.parametrize("dtype", [jnp.bfloat16, jnp.float32])
def test_flash_attention_dtypes(dtype):
    q, k, v = _qkv(1, 128, 128, 4, 2, 64, dtype)
    out = ops.flash_attention(q, k, v, block_q=64, block_k=64)
    ref = attention_ref(q, k, v, causal=True)
    assert out.dtype == dtype
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, jnp.float32),
                               np.asarray(ref, jnp.float32),
                               rtol=tol, atol=tol)


def test_flash_attention_decode_single_query():
    """sq=1 against a long cache — the serve_step shape."""
    q, k, v = _qkv(2, 1, 512, 8, 2, 64)
    out = ops.flash_attention(q, k, v, block_q=64, block_k=128)
    ref = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_chunked_attend_matches_kernel():
    """The pure-JAX chunked path and the Pallas kernel agree."""
    from repro.models.modules import attend_chunked
    q, k, v = _qkv(2, 128, 128, 4, 2, 64)
    a = attend_chunked(q, k, v, causal=True, window=48, attn_softcap=25.0,
                       chunk=64)
    b = ops.flash_attention(q, k, v, causal=True, window=48, softcap=25.0,
                            block_q=64, block_k=64)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=3e-5, atol=3e-5)
