"""Server graphs, mixing matrices (Eq. 6), sigma_A, Theorem-1 calculators."""
import numpy as np
import pytest

from repro.core import topology as tp


@pytest.mark.parametrize("kind", ["ring", "complete", "star", "line"])
@pytest.mark.parametrize("m", [2, 3, 5, 8, 16])
def test_graphs_connected_and_symmetric(kind, m):
    adj = tp.build_graph(kind, m)
    assert adj.shape == (m, m)
    assert not adj.diagonal().any()
    assert (adj == adj.T).all()
    assert tp.is_connected(adj)


def test_erdos_renyi_connected():
    for seed in range(5):
        adj = tp.erdos_renyi_graph(10, 0.3, seed=seed)
        assert tp.is_connected(adj)


def test_torus_matches_degree():
    adj = tp.torus_2d_graph(4, 4)
    assert (adj.sum(1) == 4).all()
    assert tp.is_connected(adj)


@pytest.mark.parametrize("mixing", ["metropolis", "uniform"])
@pytest.mark.parametrize("kind", ["ring", "complete", "star", "line"])
@pytest.mark.parametrize("m", [2, 4, 7])
def test_mixing_matrix_satisfies_eq6(kind, mixing, m):
    adj = tp.build_graph(kind, m)
    a = (tp.metropolis_weights(adj) if mixing == "metropolis"
         else tp.uniform_weights(adj))
    tp.check_mixing_matrix(a, adj)       # doubly stochastic + support = G
    # positive entries on the closed neighbourhood (alpha > 0 in Eq. 6)
    for i in range(m):
        assert a[i, i] > 0
        for j in np.nonzero(adj[i])[0]:
            assert a[i, j] > 0


def test_sigma_a_contracts_with_t_s():
    adj = tp.ring_graph(6)
    a = tp.metropolis_weights(adj)
    sigmas = [tp.sigma_a(a, t) for t in (1, 5, 25, 100)]
    assert all(0 <= s < 1 for s in sigmas)
    assert sigmas == sorted(sigmas, reverse=True)
    assert sigmas[-1] < 1e-3              # long consensus ~ exact averaging


def test_sigma_complete_graph_one_round():
    # complete graph + metropolis: A = (1/M) 11' after one round -> sigma = 0
    a = tp.metropolis_weights(tp.complete_graph(5))
    assert tp.sigma_a(a, 1) < 1e-12


def test_topology_validates():
    with pytest.raises(ValueError):
        tp.FLTopology(num_servers=0, clients_per_server=1, t_client=1,
                      t_server=1)
    with pytest.raises(ValueError):
        tp.FLTopology(num_servers=2, clients_per_server=1, t_client=0,
                      t_server=1)


def test_max_step_size_and_epsilon():
    topo = tp.FLTopology(num_servers=5, clients_per_server=5, t_client=250,
                         t_server=25)
    mu, lsm, theta = 1.0, 4.0, 10.0
    gmax = topo.max_step_size(mu, lsm)
    assert gmax == pytest.approx(1.0 / (4.0 * 250))
    eps = topo.epsilon_bound(gmax / 10, mu, lsm, theta)
    assert np.isfinite(eps) and eps > 0
    # epsilon shrinks with smaller gamma (Thm 1: both terms ~ gamma)
    eps_small = topo.epsilon_bound(gmax / 100, mu, lsm, theta)
    assert eps_small < eps


def test_drop_server_graph_surgery():
    topo = tp.FLTopology(num_servers=5, clients_per_server=2, t_client=10,
                         t_server=5, graph_kind="ring")
    new, keep = topo.drop_server(2)
    assert new.num_servers == 4
    assert list(keep) == [0, 1, 3, 4]
    # the induced ring minus a node is a line — surgery must keep it connected
    assert tp.is_connected(new.adjacency())
    with pytest.raises(ValueError):
        tp.FLTopology(num_servers=1, clients_per_server=1, t_client=1,
                      t_server=0).drop_server(0)
