"""Server graphs, mixing matrices (Eq. 6), sigma_A, Theorem-1 calculators."""
import numpy as np
import pytest

from repro.core import topology as tp


@pytest.mark.parametrize("kind", ["ring", "complete", "star", "line"])
@pytest.mark.parametrize("m", [2, 3, 5, 8, 16])
def test_graphs_connected_and_symmetric(kind, m):
    adj = tp.build_graph(kind, m)
    assert adj.shape == (m, m)
    assert not adj.diagonal().any()
    assert (adj == adj.T).all()
    assert tp.is_connected(adj)


def test_erdos_renyi_connected():
    for seed in range(5):
        adj = tp.erdos_renyi_graph(10, 0.3, seed=seed)
        assert tp.is_connected(adj)


def test_torus_matches_degree():
    adj = tp.torus_2d_graph(4, 4)
    assert (adj.sum(1) == 4).all()
    assert tp.is_connected(adj)


@pytest.mark.parametrize("mixing", ["metropolis", "uniform"])
@pytest.mark.parametrize("kind", ["ring", "complete", "star", "line"])
@pytest.mark.parametrize("m", [2, 4, 7])
def test_mixing_matrix_satisfies_eq6(kind, mixing, m):
    adj = tp.build_graph(kind, m)
    a = (tp.metropolis_weights(adj) if mixing == "metropolis"
         else tp.uniform_weights(adj))
    tp.check_mixing_matrix(a, adj)       # doubly stochastic + support = G
    # positive entries on the closed neighbourhood (alpha > 0 in Eq. 6)
    for i in range(m):
        assert a[i, i] > 0
        for j in np.nonzero(adj[i])[0]:
            assert a[i, j] > 0


def test_sigma_a_contracts_with_t_s():
    adj = tp.ring_graph(6)
    a = tp.metropolis_weights(adj)
    sigmas = [tp.sigma_a(a, t) for t in (1, 5, 25, 100)]
    assert all(0 <= s < 1 for s in sigmas)
    assert sigmas == sorted(sigmas, reverse=True)
    assert sigmas[-1] < 1e-3              # long consensus ~ exact averaging


def test_sigma_complete_graph_one_round():
    # complete graph + metropolis: A = (1/M) 11' after one round -> sigma = 0
    a = tp.metropolis_weights(tp.complete_graph(5))
    assert tp.sigma_a(a, 1) < 1e-12


def test_topology_validates():
    with pytest.raises(ValueError):
        tp.FLTopology(num_servers=0, clients_per_server=1, t_client=1,
                      t_server=1)
    with pytest.raises(ValueError):
        tp.FLTopology(num_servers=2, clients_per_server=1, t_client=0,
                      t_server=1)


def test_max_step_size_and_epsilon():
    topo = tp.FLTopology(num_servers=5, clients_per_server=5, t_client=250,
                         t_server=25)
    mu, lsm, theta = 1.0, 4.0, 10.0
    gmax = topo.max_step_size(mu, lsm)
    assert gmax == pytest.approx(1.0 / (4.0 * 250))
    eps = topo.epsilon_bound(gmax / 10, mu, lsm, theta)
    assert np.isfinite(eps) and eps > 0
    # epsilon shrinks with smaller gamma (Thm 1: both terms ~ gamma)
    eps_small = topo.epsilon_bound(gmax / 100, mu, lsm, theta)
    assert eps_small < eps


def test_topology_validates_edge_cases():
    # T_S = 0 is a legal epoch split (no consensus period)
    topo = tp.FLTopology(num_servers=3, clients_per_server=2, t_client=1,
                         t_server=0)
    assert topo.epoch_len == 1
    assert topo.sigma() == tp.sigma_a(topo.mixing_matrix(), 0)
    # ... but negative T_S is not
    with pytest.raises(ValueError):
        tp.FLTopology(num_servers=3, clients_per_server=2, t_client=1,
                      t_server=-1)
    # M = 1 degenerates to single-server FL: no graph, sigma = 0
    solo = tp.FLTopology(num_servers=1, clients_per_server=4, t_client=2,
                         t_server=5)
    assert solo.sigma() == 0.0
    assert not solo.adjacency().any()


def test_star_hub_drop_falls_back_to_ring():
    """Removing the hub of a star disconnects the induced subgraph; surgery
    must fall back to a ring over the survivors (Assumption 1 restored)."""
    topo = tp.FLTopology(num_servers=5, clients_per_server=2, t_client=10,
                         t_server=5, graph_kind="star")
    new, keep = topo.drop_server(0)
    assert new.graph_kind == "ring"
    assert tp.is_connected(new.adjacency())
    assert list(keep) == [1, 2, 3, 4]
    # dropping a LEAF keeps the star intact
    new2, _ = topo.drop_server(3)
    assert new2.graph_kind == "star"
    assert tp.is_connected(new2.adjacency())


def test_torus_survives_surgery_to_any_m():
    """build_graph('torus', m) must emit exactly m nodes for EVERY m (graph
    surgery walks through arbitrary — including prime — server counts)."""
    for m in range(2, 12):
        adj = tp.build_graph("torus", m)
        assert adj.shape == (m, m), m
        assert tp.is_connected(adj), m
    with pytest.raises(ValueError):
        tp.build_graph("torus", 8, rows=3)   # 3 does not divide 8
    topo = tp.FLTopology(num_servers=8, clients_per_server=2, t_client=2,
                         t_server=1, graph_kind="torus")
    new, keep = topo.drop_server(0)          # 7 servers: prime
    assert new.adjacency().shape == (7, 7)
    assert tp.is_connected(new.adjacency())


def test_rejoin_server_inverse_surgery():
    topo = tp.FLTopology(num_servers=5, clients_per_server=2, t_client=10,
                         t_server=5, graph_kind="ring")
    dropped, _ = topo.drop_server(2)
    rejoined, idx = dropped.rejoin_server()
    assert rejoined.num_servers == 5
    assert idx == 4                        # newcomer takes the last row
    assert tp.is_connected(rejoined.adjacency())


def test_erdos_renyi_fallback_path_is_connected():
    """p=0 can never sample a connected graph: after 100 tries the fallback
    must still return a connected (ring-spanning) graph."""
    adj = tp.erdos_renyi_graph(8, 0.0, seed=0)
    assert tp.is_connected(adj)
    assert (adj == adj.T).all()
    assert not adj.diagonal().any()
    # a tiny-but-nonzero p exercises fallback-with-random-extras
    adj2 = tp.erdos_renyi_graph(12, 0.01, seed=3)
    assert tp.is_connected(adj2)
    assert (adj2 == adj2.T).all()


def test_weaken_links_stays_doubly_stochastic():
    a = tp.metropolis_weights(tp.ring_graph(6))
    weak = tp.weaken_links(a, [(0, 1), (2, 3)], factor=0.8)
    tp.check_mixing_matrix(weak)
    assert weak[0, 1] == pytest.approx(0.2 * a[0, 1])
    assert tp.sigma_a(weak, 1) < 1.0       # still a contraction
    with pytest.raises(ValueError):
        tp.weaken_links(a, [(0, 0)], 0.5)
    with pytest.raises(ValueError):
        tp.weaken_links(a, [(0, 1)], 1.5)


def test_random_edge_drop_repairs_connectivity():
    adj = tp.ring_graph(8)
    rng = np.random.default_rng(0)
    for _ in range(10):
        out = tp.random_edge_drop(adj, 0.9, rng, ensure_connected=True)
        assert tp.is_connected(out)
        assert (out == out.T).all()
    # without repair, p=1 drops everything
    bare = tp.random_edge_drop(adj, 1.0, np.random.default_rng(0),
                               ensure_connected=False)
    assert not bare.any()


def test_sigma_product_constant_matches_power():
    a = tp.metropolis_weights(tp.ring_graph(5))
    assert tp.sigma_product([a, a, a], 4) == pytest.approx(
        tp.sigma_a(a, 12), abs=1e-10)
    with pytest.raises(ValueError):
        tp.sigma_product([], 3)


def test_drop_server_graph_surgery():
    topo = tp.FLTopology(num_servers=5, clients_per_server=2, t_client=10,
                         t_server=5, graph_kind="ring")
    new, keep = topo.drop_server(2)
    assert new.num_servers == 4
    assert list(keep) == [0, 1, 3, 4]
    # the induced ring minus a node is a line — surgery must keep it connected
    assert tp.is_connected(new.adjacency())
    with pytest.raises(ValueError):
        tp.FLTopology(num_servers=1, clients_per_server=1, t_client=1,
                      t_server=0).drop_server(0)


def test_drop_server_keeps_induced_adjacency():
    """Regression: dropping a server from a ring must NOT silently
    reconnect its two neighbours with a phantom link — the survivors keep
    exactly the induced subgraph (carried as an explicit adjacency)."""
    topo = tp.FLTopology(num_servers=5, clients_per_server=2, t_client=10,
                         t_server=5, graph_kind="ring")
    adj = topo.adjacency()
    new, keep = topo.drop_server(2)
    induced = adj[np.ix_(keep, keep)]
    np.testing.assert_array_equal(new.adjacency(), induced)
    # old neighbours 1 and 3 sit at new rows 1 and 2: NOT linked
    assert not new.adjacency()[1, 2]
    assert new.graph_kind == "explicit"
    # the topology stays hashable (frozen dataclass, tuple-backed matrix)
    assert isinstance(hash(new), int)
    # mixing matrix / sigma still well-defined on the explicit graph
    tp.check_mixing_matrix(new.mixing_matrix(), new.adjacency())
    assert 0.0 < new.sigma() < 1.0


def test_drop_server_erdos_renyi_not_resampled():
    """Regression: surgery on a random family must keep the induced graph,
    not resample an unrelated erdos_renyi(seed=0, p=0.5) at M-1."""
    topo = tp.FLTopology(num_servers=8, clients_per_server=2, t_client=2,
                         t_server=1, graph_kind="erdos_renyi")
    adj = topo.adjacency()
    new, keep = topo.drop_server(3)
    if tp.is_connected(adj[np.ix_(keep, keep)]):
        np.testing.assert_array_equal(new.adjacency(),
                                      adj[np.ix_(keep, keep)])
    else:
        assert new.graph_kind == "ring"


def test_drop_server_family_kept_when_induced_matches():
    """complete minus a node IS complete(M-1): the family kind survives."""
    topo = tp.FLTopology(num_servers=5, clients_per_server=2, t_client=2,
                         t_server=1, graph_kind="complete")
    new, _ = topo.drop_server(2)
    assert new.graph_kind == "complete"
    assert new.explicit_adjacency is None


def test_explicit_rejoin_connects_newcomer_to_all():
    topo = tp.FLTopology(num_servers=5, clients_per_server=2, t_client=2,
                         t_server=1, graph_kind="ring")
    dropped, _ = topo.drop_server(2)            # explicit line
    rejoined, idx = dropped.rejoin_server()
    assert idx == 4 and rejoined.num_servers == 5
    adj = rejoined.adjacency()
    # survivors' induced subgraph untouched, newcomer linked to everyone
    np.testing.assert_array_equal(adj[:4, :4], dropped.adjacency())
    assert adj[4, :4].all() and adj[:4, 4].all() and not adj[4, 4]
    assert tp.is_connected(adj)
    # repeated surgery keeps working on the explicit carrier
    again, keep2 = rejoined.drop_server(0)
    np.testing.assert_array_equal(
        again.adjacency(), rejoined.adjacency()[np.ix_(keep2, keep2)])


def test_explicit_adjacency_validation():
    with pytest.raises(ValueError, match="explicit"):
        tp.FLTopology(num_servers=3, clients_per_server=1, t_client=1,
                      t_server=1, graph_kind="explicit")
    with pytest.raises(ValueError, match="explicit"):
        tp.FLTopology(num_servers=3, clients_per_server=1, t_client=1,
                      t_server=1, graph_kind="ring",
                      explicit_adjacency=tp.FLTopology.freeze_adjacency(
                          tp.ring_graph(3)))
    # a disconnected explicit matrix still fails Assumption 1
    with pytest.raises(ValueError, match="Assumption 1"):
        tp.FLTopology(num_servers=3, clients_per_server=1, t_client=1,
                      t_server=1, graph_kind="explicit",
                      explicit_adjacency=tp.FLTopology.freeze_adjacency(
                          np.zeros((3, 3), bool)))
