"""Sweep tests: Pallas SSD scan (interpret) vs the naive recurrence oracle,
and vs the model's chunked jnp implementation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops
from repro.kernels.ref import ssd_scan_ref
from repro.models.mamba import ssd_chunked

KEY = jax.random.key(7)


def _inputs(b, s, nh, hd, ds, dtype=jnp.float32):
    ks = jax.random.split(KEY, 4)
    xs = jax.random.normal(ks[0], (b, s, nh, hd), dtype)
    bs = jax.random.normal(ks[1], (b, s, 1, ds), dtype) * 0.5
    cs = jax.random.normal(ks[2], (b, s, 1, ds), dtype) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[3], (b, s, nh))).astype(
        jnp.float32)
    a_coef = -jnp.exp(jnp.linspace(-1.0, 1.0, nh))
    return xs, bs, cs, dt, a_coef


@pytest.mark.parametrize("shape", [
    (1, 128, 2, 32, 64, 64),
    (2, 256, 4, 64, 128, 128),
    (1, 200, 2, 32, 64, 64),      # ragged: s % chunk != 0
    (2, 64, 8, 64, 128, 64),      # single chunk
])
def test_ssd_kernel_vs_naive(shape):
    b, s, nh, hd, ds, chunk = shape
    xs, bs, cs, dt, a_coef = _inputs(b, s, nh, hd, ds)
    y, st = ops.ssd_scan(xs, bs, cs, dt, a_coef, chunk=chunk)
    y_ref, st_ref = ssd_scan_ref(xs, bs, cs, dt, a_coef)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(st), np.asarray(st_ref),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("chunk", [32, 64, 128])
def test_ssd_chunk_invariance(chunk):
    """Output must not depend on the chunk size (including vs the model's
    jnp chunked implementation at a different chunk)."""
    xs, bs, cs, dt, a_coef = _inputs(1, 192, 2, 32, 64)
    y1, st1 = ops.ssd_scan(xs, bs, cs, dt, a_coef, chunk=chunk)
    y2, st2 = ssd_chunked(xs, bs, cs, dt, a_coef, 48)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(st1), np.asarray(st2),
                               rtol=2e-4, atol=2e-4)


def test_ssd_decay_extremes():
    """Strong decay (a << 0) forgets the past; zero dt holds state."""
    b, s, nh, hd, ds = 1, 64, 1, 16, 32
    xs, bs, cs, dt, _ = _inputs(b, s, nh, hd, ds)
    # near-zero dt -> y ~ 0 and state ~ 0
    y, st = ops.ssd_scan(xs, bs, cs, jnp.zeros_like(dt),
                         -jnp.ones((nh,)), chunk=32)
    assert float(jnp.abs(y).max()) < 1e-5
    assert float(jnp.abs(st).max()) < 1e-5


def test_mamba_decode_matches_scan():
    """O(1) decode recurrence == full-sequence scan, step by step."""
    from repro.configs import get_smoke
    from repro.models import mamba as mm
    cfg = get_smoke("mamba2_780m")
    params = mm.mamba_init(KEY, cfg)
    x = jax.random.normal(KEY, (2, 16, cfg.d_model)) * 0.3
    y_full = mm.mamba_apply(params, x, cfg)
    cache = mm.mamba_cache_init(cfg, 2, jnp.float32)
    ys = []
    for t in range(16):
        y_t, cache = mm.mamba_decode_step(params, x[:, t:t + 1], cache, cfg)
        ys.append(y_t)
    y_step = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_step), np.asarray(y_full),
                               rtol=2e-4, atol=2e-4)
