"""repro.analysis: the lint engine (seeded fixtures + clean twins +
suppression grammar + CLI), the HLO auditor's edge cases, and the
compiled-program contract table — including the deliberately-dropped
donation that MUST fail and the per-leaf collective regression."""

import io
import json
from contextlib import redirect_stdout
from pathlib import Path

import numpy as np
import pytest

from repro.analysis import DEFAULT_ROOTS, RULES, hlo_audit, lint_file, lint_paths
from repro.analysis.__main__ import main as analysis_main
from repro.analysis.contracts import (CONTRACT_TABLE, audit_cell, audit_table,
                                      audit_wire_hlo, lower_cell)
from repro.core import FaultEvent, FaultSchedule
from repro.core.schedule import (diurnal_trace, load_participation_trace,
                                 save_participation_trace)

REPO = Path(__file__).resolve().parents[1]
FIXTURES = REPO / "tests" / "fixtures" / "analysis"

# ---------------------------------------------------------------------------
# seeded fixtures: each bad file must flag exactly its rule(s); each clean
# twin must be silent
# ---------------------------------------------------------------------------

BAD_FIXTURES = {
    "bad_key_reuse.py": {"key-reuse": 2},
    "bad_host_sync.py": {"host-sync-in-jit": 3},
    "bad_traced_branch.py": {"traced-branch": 2},
    "bad_donation.py": {"undonated-jit": 1},
    "bad_qmax.py": {"qmax-division": 2},
    "bad_misc.py": {"mutable-default": 1, "dead-schedule-operand": 1},
    # two bare prints flag; the reasonless suppression silences its print
    # but surfaces as bare-suppression (fixtures are in-scope by design)
    "bad_print.py": {"print-in-library": 2, "bare-suppression": 1},
}

GOOD_FIXTURES = ["good_key_reuse.py", "good_host_sync.py",
                 "good_traced_branch.py", "good_donation.py",
                 "good_qmax.py", "good_misc.py", "good_print.py"]


@pytest.mark.parametrize("name", sorted(BAD_FIXTURES))
def test_bad_fixture_flags_expected_rules(name):
    findings = lint_file(FIXTURES / name)
    got = {}
    for f in findings:
        got[f.rule] = got.get(f.rule, 0) + 1
    assert got == BAD_FIXTURES[name]


@pytest.mark.parametrize("name", GOOD_FIXTURES)
def test_clean_twin_is_silent(name):
    assert lint_file(FIXTURES / name) == []


def test_findings_are_sorted_and_carry_positions():
    findings = lint_file(FIXTURES / "bad_key_reuse.py")
    lines = [f.line for f in findings]
    assert lines == sorted(lines) and all(l > 0 for l in lines)
    d = findings[0].to_dict()
    assert set(d) >= {"rule", "path", "line", "col", "message"}
    assert findings[0].rule in RULES
    # text rendering is path:line:col: [rule] message
    assert findings[0].format().startswith(str(FIXTURES / "bad_key_reuse.py"))


def test_rules_subset_restricts_findings():
    findings = lint_file(FIXTURES / "bad_misc.py", rules=["mutable-default"])
    assert {f.rule for f in findings} == {"mutable-default"}


def test_syntax_error_becomes_single_finding(tmp_path):
    p = tmp_path / "broken.py"
    p.write_text("def f(:\n")
    findings = lint_file(p)
    assert [f.rule for f in findings] == ["syntax-error"]


# ---------------------------------------------------------------------------
# suppression grammar
# ---------------------------------------------------------------------------


def test_suppression_semantics():
    findings = lint_file(FIXTURES / "suppressed.py")
    # the reasoned suppression silences qmax-division entirely; the bare
    # one still suppresses but surfaces as bare-suppression; the
    # unknown-rule one suppresses nothing and is itself flagged.
    assert [f.rule for f in findings] == ["bare-suppression",
                                         "bare-suppression"]
    assert not any(f.rule == "qmax-division" for f in findings)
    msgs = " ".join(f.message for f in findings)
    assert "no reason" in msgs and "no-such-rule" in msgs


def test_suppression_comment_inside_string_is_inert(tmp_path):
    p = tmp_path / "s.py"
    p.write_text('def f(absmax, qmax):\n'
                 '    s = "# repro: ignore[qmax-division]: not a comment"\n'
                 '    return absmax / qmax, s\n')
    assert [f.rule for f in lint_file(p)] == ["qmax-division"]


# ---------------------------------------------------------------------------
# the repo itself is analysis-clean (tentpole acceptance) and the walker
# skips fixtures
# ---------------------------------------------------------------------------


def test_repo_is_lint_clean():
    roots = [REPO / r for r in DEFAULT_ROOTS]
    findings = lint_paths(roots)
    assert findings == [], "\n".join(f.format() for f in findings)


def test_walker_excludes_fixture_dirs():
    findings = lint_paths([REPO / "tests"])
    assert not any("fixtures" in Path(f.path).parts for f in findings)


# ---------------------------------------------------------------------------
# CLI (in-process: fast tier forbids subprocess helpers)
# ---------------------------------------------------------------------------


def test_cli_nonzero_on_seeded_fixture_and_json_parses():
    buf = io.StringIO()
    with redirect_stdout(buf):
        rc = analysis_main([str(FIXTURES / "bad_qmax.py"),
                            "--format", "json"])
    assert rc == 1
    report = json.loads(buf.getvalue())
    assert report["count"] == 2
    assert {f["rule"] for f in report["findings"]} == {"qmax-division"}


def test_cli_zero_on_clean_twin_text_format():
    buf = io.StringIO()
    with redirect_stdout(buf):
        rc = analysis_main([str(FIXTURES / "good_qmax.py")])
    assert rc == 0
    assert "0 finding(s)" in buf.getvalue()


def test_cli_output_file_and_list_rules(tmp_path):
    out = tmp_path / "report.json"
    buf = io.StringIO()
    with redirect_stdout(buf):
        rc = analysis_main([str(FIXTURES / "bad_misc.py"),
                            "--format", "json", "--output", str(out)])
    assert rc == 1 and json.loads(out.read_text())["count"] == 2
    with redirect_stdout(io.StringIO()) as buf2:
        assert analysis_main(["--list-rules"]) == 0
    listing = buf2.getvalue()
    assert all(name in listing for name in RULES)


# ---------------------------------------------------------------------------
# hlo_audit edge cases (synthetic HLO: no lowering needed)
# ---------------------------------------------------------------------------


def test_collective_sites_zero_collective_program():
    assert hlo_audit.collective_sites("ENTRY main { ROOT x = f32[2] add(a, b) }") == []


def test_collective_sites_sync_and_async_ragged():
    hlo = """
  ag = s8[5,3]{1,0} all-gather(s8[1,3]{1,0} %p), replica_groups={}
  ag2 = (f32[7]{0}, f32[7]{0}) all-gather-start(f32[1]{0} %q), dims={0}
  cp = u32[] collective-permute(u32[] %tok)
"""
    sites = hlo_audit.collective_sites(hlo)
    assert [(s["op"], s["dtype"], s["bytes"]) for s in sites] == [
        ("all-gather", "s8", 15),          # 5*3 * 1 byte
        ("all-gather", "f32", 28),         # async: largest tuple element
        ("collective-permute", "u32", 4),  # scalar shape -> one element
    ]
    assert sites[0]["shape"] == (5, 3) and sites[2]["shape"] == ()


def test_alias_pairs_and_has_donation():
    hlo = ("HloModule m, input_output_alias={ {0}: (0, {}, may-alias), "
           "{1}: (0, {1}, must-alias) }\n")
    pairs = hlo_audit.input_output_alias_pairs(hlo)
    assert len(pairs) == 2 and hlo_audit.has_donation(hlo)
    assert not hlo_audit.has_donation("HloModule m\n")


def test_host_callback_sites():
    hlo = 'x = f32[] custom-call(), custom_call_target="xla_python_cpu_callback"'
    assert hlo_audit.host_callback_sites(hlo) == ["xla_python_cpu_callback"]
    benign = 'y = f32[] custom-call(), custom_call_target="TopK"'
    assert hlo_audit.host_callback_sites(benign) == []


# ---------------------------------------------------------------------------
# wire contract on synthetic HLO: bucketed OK, per-leaf regression caught
# ---------------------------------------------------------------------------

_BUCKETED = """
  a = s8[4,100]{1,0} all-gather(s8[1,100]{1,0} %codes)
  b = f32[4,2]{1,0} all-gather(f32[1,2]{1,0} %scales)
"""

_PER_LEAF = "\n".join(
    f"  g{i} = f32[4,{n}]{{1,0}} all-gather(f32[1,{n}]{{1,0}} %p{i})"
    for i, n in enumerate([30, 10, 40, 5, 25, 60]))


def test_audit_wire_hlo_accepts_bucketed_program():
    assert audit_wire_hlo(_BUCKETED) == []


def test_audit_wire_hlo_catches_per_leaf_regression():
    violations = audit_wire_hlo(_PER_LEAF)
    assert any("per-leaf" in v for v in violations)


def test_audit_wire_hlo_catches_float_payload():
    # two sites (count OK) but the payload went out as f32 instead of s8
    hlo = """
  a = f32[4,100]{1,0} all-gather(f32[1,100]{1,0} %codes)
  b = f32[4,2]{1,0} all-gather(f32[1,2]{1,0} %scales)
"""
    violations = audit_wire_hlo(hlo, allowed_dtypes=("s8",))
    assert any("f32" in v for v in violations)


# ---------------------------------------------------------------------------
# contract table: >= 12 cells, all green; dropped donation must fail
# ---------------------------------------------------------------------------


def test_contract_table_covers_matrix_and_is_green():
    assert len(CONTRACT_TABLE) >= 12
    axes = {(c.consensus_mode, c.mixing, c.compression, c.error_feedback,
             c.wire, c.dynamic, c.superepoch, c.staleness)
            for c in CONTRACT_TABLE}
    assert len(axes) == len(CONTRACT_TABLE), "duplicate contract cells"
    results = audit_table()
    bad = [r for r in results if not r.ok]
    assert not bad, "\n".join(v for r in bad for v in r.violations)
    # every audited cell carries lowering stats for the report artifact
    assert all(r.stats.get("aliased") is not None for r in results)


def test_dropped_donation_is_caught():
    cell = CONTRACT_TABLE[0]
    assert cell.donate
    hlo = lower_cell(cell, drop_donation=True)
    result = audit_cell(cell, hlo=hlo)
    assert not result.ok
    assert any("donat" in v or "alias" in v for v in result.violations)


@pytest.mark.slow
def test_engine_retrace_contract():
    from repro.analysis.contracts import audit_engine_retrace
    report = audit_engine_retrace()
    assert report.violations == []
    assert len(report.compile_counts) >= 2
    assert all(c == 1 for c in report.compile_counts.values())


# ---------------------------------------------------------------------------
# FaultSchedule.from_trace: churn derived from the same JSONL traces the
# participation schedule replays
# ---------------------------------------------------------------------------


def test_from_trace_hand_built_outage():
    # server 1 fully dark epochs 2..3, back at 4; server 0 never down
    trace = np.ones((6, 2, 3), dtype=np.float64)
    trace[2:4, 1, :] = 0.0
    fs = FaultSchedule.from_trace(trace)
    assert fs.events == (FaultEvent(epoch=2, kind="drop", server=1),
                         FaultEvent(epoch=4, kind="rejoin", server=1))


def test_from_trace_trailing_outage_has_no_rejoin():
    trace = np.ones((5, 2, 2))
    trace[3:, 0, :] = 0.0
    fs = FaultSchedule.from_trace(trace)
    assert fs.events == (FaultEvent(epoch=3, kind="drop", server=0),)


def test_from_trace_blip_filter():
    trace = np.ones((6, 2, 2))
    trace[1, 0, :] = 0.0          # 1-epoch blip
    trace[3:5, 1, :] = 0.0        # real 2-epoch outage
    fs = FaultSchedule.from_trace(trace, min_down_epochs=2)
    assert all(e.server == 1 for e in fs.events)


def test_from_trace_all_servers_down_raises():
    trace = np.ones((4, 2, 2))
    trace[2, :, :] = 0.0
    with pytest.raises(ValueError, match="every server"):
        FaultSchedule.from_trace(trace)


def test_from_trace_rejects_bad_shapes_and_values():
    with pytest.raises(ValueError):
        FaultSchedule.from_trace(np.ones((4, 3)))
    bad = np.ones((4, 2, 2))
    bad[0, 0, 0] = 0.5
    with pytest.raises(ValueError):
        FaultSchedule.from_trace(bad)


def test_from_trace_jsonl_round_trip(tmp_path):
    trace = diurnal_trace(12, 3, 2, period=6, base=0.9, amplitude=0.9,
                          min_per_server=0, seed=7)
    path = tmp_path / "avail.jsonl"
    save_participation_trace(path, trace)
    fs_disk = FaultSchedule.from_trace(load_participation_trace(path))
    fs_mem = FaultSchedule.from_trace(trace)
    assert fs_disk.events == fs_mem.events
    # every derived event indexes a real epoch/server of the trace
    for e in fs_mem.events:
        assert 0 <= e.epoch < 12 and 0 <= e.server < 3
