"""repro.obs: the telemetry layer's contracts — JSONL schema round-trip,
span nesting/ordering invariants under an injected deterministic clock,
Chrome trace-event validity, sink fan-out, watchdog rules on seeded
pathologies, and the load-bearing guarantee: a fully-instrumented engine
run is BITWISE identical to an uninstrumented one."""

import io
import json
import math

import jax
import jax.numpy as jnp
import pytest

from repro.core import (FaultEvent, FaultSchedule, FLTopology,
                        ParticipationSchedule, TopologySchedule,
                        init_dfl_state, make_engine)
from repro.data import RegressionSpec, make_regression_task
from repro.obs import (OBS_OFF, SCHEMA_VERSION, ConsoleSink,
                       ConvergenceMonitor, JSONLSink, MemorySink,
                       MetricEvent, MetricsHub, Observability, Tracer,
                       load_jsonl, validate_chrome_trace, validate_jsonl)
from repro.optim import sgd

# ---------------------------------------------------------------------------
# tracer: spans, nesting, Chrome export
# ---------------------------------------------------------------------------


def _fake_clock():
    """Deterministic injectable clock: 0, 10, 20, ... nanoseconds."""
    t = {"now": -10}

    def clock():
        t["now"] += 10
        return t["now"]
    return clock


def test_span_nesting_and_ordering_invariants():
    tr = Tracer(clock=_fake_clock())
    with tr.span("epoch", epoch=0) as outer:
        with tr.span("local-period"):
            pass
        with tr.span("gossip-period"):
            pass
    # children appended at EXIT, before the outer span closes
    names = [s.name for s in tr.spans]
    assert names == ["local-period", "gossip-period", "epoch"]
    local, gossip, epoch = tr.spans
    assert epoch is outer
    # time containment + sibling ordering under the monotonic clock
    assert epoch.encloses(local) and epoch.encloses(gossip)
    assert local.t1_ns <= gossip.t0_ns
    assert all(s.duration_ns >= 0 for s in tr.spans)
    # nesting metadata
    assert epoch.depth == 0 and local.depth == 1 and gossip.depth == 1
    assert local.parent is epoch and gossip.parent is epoch
    assert epoch.args == {"epoch": 0}


def test_add_span_places_explicit_intervals():
    tr = Tracer(clock=_fake_clock())
    with tr.span("epoch") as ep:
        pass
    sp = tr.add_span("gossip-period", ep.t0_ns, ep.t1_ns, parent=ep,
                     method="consensus-replay")
    assert ep.encloses(sp) and sp.depth == ep.depth + 1
    with pytest.raises(ValueError):
        tr.add_span("bad", 100, 50)


def test_chrome_trace_export_is_valid_and_complete():
    tr = Tracer(clock=_fake_clock())
    with tr.span("epoch", epoch=3):
        with tr.span("fault-surgery"):
            pass
    tr.compile_event("first_trace", m=4)
    doc = tr.to_chrome()
    events = validate_chrome_trace(doc)
    assert doc["displayTimeUnit"] == "ms"
    xs = [e for e in events if e["ph"] == "X"]
    insts = [e for e in events if e["ph"] == "i"]
    assert {e["name"] for e in xs} == {"epoch", "fault-surgery"}
    assert [e["name"] for e in insts] == ["compile"]
    assert insts[0]["args"] == {"cause": "first_trace", "m": 4}
    # X events are time-sorted with microsecond ts/dur
    assert [e["ts"] for e in xs] == sorted(e["ts"] for e in xs)
    # non-JSON-serialisable args are stringified, never dropped
    with tr.span("epoch", arr=jnp.zeros(2)):
        pass
    json.dumps(tr.to_chrome())


def test_validate_chrome_trace_rejects_malformed():
    with pytest.raises(ValueError):
        validate_chrome_trace({"events": []})
    with pytest.raises(ValueError):
        validate_chrome_trace({"traceEvents": [{"name": "x", "ph": "Z",
                                                "ts": 0}]})
    with pytest.raises(ValueError):
        validate_chrome_trace({"traceEvents": [{"name": "x", "ph": "X",
                                                "ts": 0}]})  # no dur


def test_save_chrome_round_trips(tmp_path):
    tr = Tracer(clock=_fake_clock())
    with tr.span("epoch"):
        pass
    p = tmp_path / "trace.json"
    tr.save_chrome(str(p))
    validate_chrome_trace(json.loads(p.read_text()))


# ---------------------------------------------------------------------------
# hub + sinks: fan-out, JSONL schema round-trip
# ---------------------------------------------------------------------------


def test_sink_fanout_every_sink_sees_every_event(capsys):
    mem1, mem2 = MemorySink(), MemorySink()
    buf = io.StringIO()
    hub = MetricsHub([mem1, ConsoleSink()])
    hub.add_sink(mem2)
    hub.add_sink(JSONLSink(buf))
    hub.observe_epoch(0, {"loss": 1.5, "disagreement": 2e-4})
    hub.counter("wire_bytes", 100.0, epoch=0, src=1, dst=0)
    hub.warning("nan-loss", "loss is non-finite", epoch=0)
    hub.close()
    for mem in (mem1, mem2):
        assert mem.history() == {"loss": [1.5], "disagreement": [2e-4]}
        assert mem.totals() == {"wire_bytes": 100.0}
        assert [w.name for w in mem.warnings()] == ["nan-loss"]
    out = capsys.readouterr().out
    assert "epoch    0" in out and "loss=1.5000" in out
    assert "[obs:warn] nan-loss" in out
    lines = [json.loads(l) for l in buf.getvalue().splitlines()]
    assert lines[0]["kind"] == "meta"
    assert [l["kind"] for l in lines[1:]] == ["epoch", "counter", "warning"]


def test_console_sink_respects_log_every(capsys):
    hub = MetricsHub([ConsoleSink(log_every=3)])
    for e in range(7):
        hub.observe_epoch(e, {"loss": float(e)})
    out = capsys.readouterr().out
    printed = [l for l in out.splitlines() if l.startswith("epoch")]
    assert len(printed) == 3          # epochs 0, 3, 6


def test_jsonl_schema_round_trip(tmp_path):
    p = tmp_path / "telemetry.jsonl"
    hub = MetricsHub([JSONLSink(str(p), run_info={"driver": "test"})])
    hub.observe_epoch(0, {"loss": 2.0, "sigma_prod": 0.5})
    hub.gauge("tolerance_gap", 3.5, epoch=0)
    hub.histogram("screen_rejected", [0.0, 2.0, 1.0], epoch=0,
                  servers=[0, 1, 2])
    hub.counter("wire_bytes", 42.0, epoch=0, src=2, dst=1)
    hub.close()
    records = load_jsonl(str(p))
    assert records[0] == {"kind": "meta", "schema": SCHEMA_VERSION,
                          "unix_time": records[0]["unix_time"],
                          "run": {"driver": "test"}}
    events = validate_jsonl(records)
    by_kind = {e["kind"]: e for e in events}
    assert by_kind["epoch"]["value"] == {"loss": 2.0, "sigma_prod": 0.5}
    assert by_kind["gauge"] == {"kind": "gauge", "name": "tolerance_gap",
                                "value": 3.5, "epoch": 0}
    assert by_kind["histogram"]["value"] == [0.0, 2.0, 1.0]
    assert by_kind["histogram"]["labels"] == {"servers": [0, 1, 2]}
    assert by_kind["counter"]["labels"] == {"src": 2, "dst": 1}


def test_validate_jsonl_rejects_bad_streams():
    meta = {"kind": "meta", "schema": SCHEMA_VERSION}
    with pytest.raises(ValueError):
        validate_jsonl([])
    with pytest.raises(ValueError):
        validate_jsonl([{"kind": "epoch", "name": "epoch", "value": {}}])
    with pytest.raises(ValueError):
        validate_jsonl([{"kind": "meta", "schema": SCHEMA_VERSION + 1}])
    with pytest.raises(ValueError):
        validate_jsonl([meta, {"kind": "spam", "name": "x", "value": 1}])
    with pytest.raises(ValueError):
        validate_jsonl([meta, {"kind": "gauge", "name": "g", "value": [1]}])
    with pytest.raises(ValueError):
        validate_jsonl([meta, {"kind": "histogram", "name": "h",
                               "value": 1.0}])


# ---------------------------------------------------------------------------
# convergence monitor: derived gauges + watchdog rules
# ---------------------------------------------------------------------------


def test_monitor_gauges_track_paper_quantities():
    mem = MemorySink()
    hub = MetricsHub([mem])
    events = []
    hub.gauge = lambda name, value, *, epoch=None, **kw: \
        events.append((name, value, epoch))  # capture without a sink walk
    mon = ConvergenceMonitor(hub)
    mon.observe(0, {"loss": 1.0, "disagreement": 0.5, "sigma_prod": 0.8})
    mon.observe(1, {"loss": 0.9, "disagreement": 0.1, "sigma_prod": 0.4})
    gaps = [v for n, v, _ in events if n == "tolerance_gap"]
    bounds = [v for n, v, _ in events if n == "contraction_bound"]
    assert gaps == [0.5 / 1e-3, 0.1 / 1e-3]
    # d0 is the FIRST disagreement; bound contracts with sigma_prod
    assert bounds == [0.8 * 0.5, 0.4 * 0.5]


def test_watchdog_nan_loss_fires_once():
    mem = MemorySink()
    mon = ConvergenceMonitor(MetricsHub([mem]))
    mon.observe(0, {"loss": 1.0, "disagreement": 1e-4})
    assert mon.events == []
    mon.observe(1, {"loss": float("nan"), "disagreement": 1e-4})
    mon.observe(2, {"loss": float("inf"), "disagreement": 1e-4})
    assert [e.rule for e in mon.events] == ["nan-loss"]
    assert mon.events[0].epoch == 1
    assert [w.name for w in mem.warnings()] == ["nan-loss"]


def test_watchdog_disagreement_divergence():
    mon = ConvergenceMonitor(MetricsHub([MemorySink()]),
                             divergence_window=3)
    dis = [1e-4, 1e-4, 1e-4, 1e-4, 5e-2]     # 500x jump over the window
    for e, d in enumerate(dis):
        mon.observe(e, {"loss": 1.0, "disagreement": d})
    assert [e.rule for e in mon.events] == ["disagreement-divergence"]
    assert mon.events[0].value == pytest.approx(5e-2)


def test_watchdog_wire_ratio_regression():
    mon = ConvergenceMonitor(MetricsHub([MemorySink()]))
    mon.observe(0, {"loss": 1.0, "wire_ratio": 4.0})
    mon.observe(1, {"loss": 1.0, "wire_ratio": 3.5})   # mild dip: no fire
    assert mon.events == []
    mon.observe(2, {"loss": 1.0, "wire_ratio": 1.0})   # collapsed
    assert [e.rule for e in mon.events] == ["wire-ratio-regression"]


# ---------------------------------------------------------------------------
# the Observability bundle + the bitwise-inertness contract
# ---------------------------------------------------------------------------


def test_obs_off_is_a_complete_null_object():
    assert OBS_OFF.enabled is False
    with OBS_OFF.span("epoch", epoch=0) as sp:
        assert sp is None
    OBS_OFF.compile_event("first_trace")
    OBS_OFF.observe(0, {"loss": 1.0}, servers=(0,), per_link=None)
    OBS_OFF.close()


def test_observability_labels_per_link_and_screen(tmp_path):
    mem = MemorySink()
    obs = Observability(hub=MetricsHub([mem]), tracer=Tracer(),
                        monitor=True)
    per_link = [[0.0, 7.0], [3.0, 0.0]]
    obs.observe(0, {"loss": 1.0, "disagreement": 1e-4},
                servers=(0, 2),              # dense rows -> original ids
                per_link=per_link, screen_rejected=[1.0, 0.0])
    obs.close()
    assert mem.totals() == {"wire_bytes": 10.0}
    assert mem.history()["loss"] == [1.0]
    assert obs.monitor is not None and obs.monitor.events == []


def _small_engine(obs=None, faults=None):
    topo = FLTopology(num_servers=3, clients_per_server=2, t_client=2,
                      t_server=3, graph_kind="ring")
    task = make_regression_task(topo, RegressionSpec(heterogeneity=0.5),
                                seed=0)
    opt = sgd(1e-3)
    eng = make_engine(topo, task["loss_fn"], opt,
                      participation=ParticipationSchedule(
                          kind="bernoulli", rate=0.7, seed=3),
                      topology_schedule=TopologySchedule(
                          kind="edge_drop", drop_prob=0.3, seed=4),
                      faults=faults, obs=obs)
    state = init_dfl_state(eng.cfg, jnp.zeros((2,)), opt, jax.random.key(0))
    return eng, state, task["batch_fn"]


def test_engine_history_bitwise_identical_with_obs_on():
    """The load-bearing contract: attaching the FULL obs stack (hub +
    sinks + tracer with its block_until_ready sync points + monitor) must
    not change a single bit of any training metric."""
    faults = FaultSchedule((FaultEvent(2, "drop", 1),
                            FaultEvent(4, "rejoin", 1)))
    epochs = 6

    def run(obs):
        eng, state, batch_fn = _small_engine(obs=obs, faults=faults)
        hist = {}
        for e in range(epochs):
            state, rec = eng.run_epoch(state, e, batch_fn)
            for k, v in rec.items():
                hist.setdefault(k, []).append(v)
        return hist

    plain = run(None)                          # defaults to OBS_OFF
    obs = Observability(hub=MetricsHub([MemorySink()]), tracer=Tracer(),
                        monitor=True)
    traced = run(obs)
    assert set(plain) == set(traced)
    for k in plain:
        for a, b in zip(plain[k], traced[k]):
            assert a == b or (math.isnan(a) and math.isnan(b)), \
                f"obs changed {k}: {a!r} != {b!r}"


def test_engine_emits_spans_and_compile_events():
    faults = FaultSchedule((FaultEvent(2, "drop", 1),))
    tracer = Tracer()
    mem = MemorySink()
    obs = Observability(hub=MetricsHub([mem]), tracer=tracer, monitor=True)
    eng, state, batch_fn = _small_engine(obs=obs, faults=faults)
    for e in range(4):
        state, _ = eng.run_epoch(state, e, batch_fn)
    names = {s.name for s in tracer.spans}
    assert {"epoch", "local-period", "gossip-period", "fault-surgery",
            "host-aggregation"} <= names
    epochs = [s for s in tracer.spans if s.name == "epoch"]
    assert len(epochs) == 4
    for ep in epochs:
        kids = [s for s in tracer.spans if s.parent is ep]
        assert kids and all(ep.encloses(k) for k in kids)
    causes = [ev["args"]["cause"] for ev in tracer.instants
              if ev["name"] == "compile"]
    # M=3 cold trace, then the fault surgery re-jits at M=2
    assert causes == ["first_trace", "federation_size_change"]
    assert eng.compile_counts() == {3: 1, 2: 1}
    validate_chrome_trace(tracer.to_chrome())
    # the hub-side history matches what the engine returned per epoch
    assert len(mem.history()["loss"]) == 4
