"""Shared fixtures.  NOTE: no XLA_FLAGS here — smoke tests must see the
real single CPU device; only launch/dryrun.py forces 512 placeholders."""
import jax
import pytest

# Fast-tier arch subset for the per-architecture suites (test_models_smoke,
# test_decode): one representative per family — dense, dense+GQA, MoE+SWA,
# SSM, vision frontend.  The remaining archs exercise the same code paths
# with heavier smoke configs and run in the slow tier (-m slow).
FAST_ARCHS = ("smollm_360m", "qwen3_1_7b", "mixtral_8x22b", "mamba2_780m",
              "internvl2_1b")


def arch_params(arch_ids, fast=FAST_ARCHS):
    """parametrize values with non-fast archs marked slow."""
    return [pytest.param(a, marks=() if a in fast else (pytest.mark.slow,))
            for a in arch_ids]


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.key(0)
