"""Shared fixtures.  NOTE: no XLA_FLAGS here — smoke tests must see the
real single CPU device; only launch/dryrun.py forces 512 placeholders."""
import jax
import pytest


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.key(0)
