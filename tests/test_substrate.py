"""Substrate: optimizers, schedules, data pipeline, checkpointing."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import Checkpointer, restore_pytree, save_pytree
from repro.core import FLTopology
from repro.data import (DataConfig, FLDataPipeline, RegressionSpec,
                        make_regression_data, synthetic_lm_batch)
from repro.optim import adam, clip_by_global_norm, momentum, sgd, warmup_cosine


def _quad_min(opt, steps=300):
    """Minimise ||x - 3||^2 and return the final iterate."""
    params = {"x": jnp.zeros((4,))}
    state = opt.init(params)
    for _ in range(steps):
        grads = {"x": 2 * (params["x"] - 3.0)}
        params, state = opt.update(grads, state, params)
    return params["x"]


@pytest.mark.parametrize("opt", [sgd(0.1), momentum(0.05), adam(0.1),
                                 clip_by_global_norm(sgd(0.1), 1.0)])
def test_optimizers_minimize_quadratic(opt):
    x = _quad_min(opt)
    np.testing.assert_allclose(np.asarray(x), 3.0, atol=1e-2)


def test_schedule_warmup_cosine():
    sched = warmup_cosine(1.0, 10, 100)
    assert float(sched(jnp.asarray(0))) == 0.0
    assert float(sched(jnp.asarray(10))) == pytest.approx(1.0, abs=0.1)
    assert float(sched(jnp.asarray(100))) == pytest.approx(0.1, abs=0.02)


def test_regression_data_matches_spec():
    topo = FLTopology(num_servers=3, clients_per_server=2, t_client=1,
                      t_server=1)
    spec = RegressionSpec(w_star=(2.0, -1.0), points_per_client=50,
                          noise_std=0.01)
    data = make_regression_data(topo, spec, seed=1)
    assert data["x"].shape == (3, 2, 50, 2)
    assert data["y"].shape == (3, 2, 50)
    # recoverable w* from the noiseless-ish data
    w = np.linalg.lstsq(data["x"].reshape(-1, 2), data["y"].reshape(-1),
                        rcond=None)[0]
    np.testing.assert_allclose(w, [2.0, -1.0], atol=0.05)


def test_lm_pipeline_shapes_and_determinism():
    topo = FLTopology(num_servers=2, clients_per_server=3, t_client=4,
                      t_server=1)
    cfg = DataConfig(seq_len=32, per_client_batch=2, vocab_size=97, seed=5)
    p1 = FLDataPipeline(topo, cfg)
    p2 = FLDataPipeline(topo, cfg)
    b1, b2 = p1.epoch_batches(0), p2.epoch_batches(0)
    assert b1["tokens"].shape == (4, 2, 3, 2, 32)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    b3 = p1.epoch_batches(1)
    assert not np.array_equal(np.asarray(b1["tokens"]),
                              np.asarray(b3["tokens"]))
    assert int(b1["tokens"].max()) < 97


def test_lm_batch_distribution():
    toks = synthetic_lm_batch(jax.random.key(0), 1000, (4, 512))
    # zipf-ish: low ids dominate
    frac_low = float((toks < 100).mean())
    assert frac_low > 0.4


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
            "b": {"c": jnp.ones((4,), jnp.bfloat16)},
            "d": (jnp.zeros((2,)), jnp.asarray(3))}
    path = os.path.join(tmp_path, "t.npz")
    save_pytree(path, tree, meta={"epoch": 7})
    restored = restore_pytree(path, tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, jnp.float32),
                                      np.asarray(b, jnp.float32))


def test_checkpointer_gc_and_latest(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    tree = {"w": jnp.ones((3,))}
    for step in range(5):
        ck.save(step, tree)
    assert ck.latest_step() == 4
    files = [f for f in os.listdir(tmp_path) if f.endswith(".npz")]
    assert len(files) == 2
    restored, step = ck.restore(tree)
    assert step == 4


def test_checkpointer_restore_dropped(tmp_path):
    topo = FLTopology(num_servers=4, clients_per_server=1, t_client=1,
                      t_server=1)
    ck = Checkpointer(str(tmp_path))
    full = {"w": jnp.arange(4 * 3, dtype=jnp.float32).reshape(4, 3)}
    ck.save(0, full)
    new_template = {"w": jnp.zeros((3, 3))}
    restored, new_topo = ck.restore_dropped(new_template, 1, topo)
    assert new_topo.num_servers == 3
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(full["w"])[[0, 2, 3]])
