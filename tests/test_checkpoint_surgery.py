"""Checkpointer.restore_dropped round-tripped through the dynamic engine's
drop surgery: a checkpoint taken at M servers, restored onto the surviving
M-1 topology, and trained onward must agree with the uninterrupted run in
which the engine itself executed the drop — the disaster-recovery path and
the live-surgery path are the same transformation."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import Checkpointer
from repro.core import (FaultEvent, FaultSchedule, FLTopology, init_dfl_state,
                        make_engine)
from repro.core.dfl import DFLState
from repro.data import RegressionSpec, make_regression_task
from repro.optim import sgd
from repro.optim.optimizers import SGDState


def test_restore_dropped_continues_like_engine_surgery(tmp_path):
    m, n = 4, 2
    drop_epoch, dropped, total = 3, 1, 6
    topo = FLTopology(num_servers=m, clients_per_server=n, t_client=3,
                      t_server=5, graph_kind="ring")
    task = make_regression_task(topo, RegressionSpec(heterogeneity=0.5),
                                seed=0)
    opt = sgd(1e-3)

    # R1: uninterrupted — the ENGINE drops the server mid-run
    eng1 = make_engine(topo, task["loss_fn"], opt,
                       faults=FaultSchedule((FaultEvent(drop_epoch, "drop",
                                                        dropped),)))
    s1 = init_dfl_state(eng1.cfg, jnp.zeros((2,)), opt, jax.random.key(0))
    for e in range(total):
        s1, _ = eng1.run_epoch(s1, e, task["batch_fn"])
    survivors = list(eng1.alive)
    assert survivors == [0, 2, 3]

    # R2: identical run up to the drop epoch, then CHECKPOINT at M servers
    eng2 = make_engine(topo, task["loss_fn"], opt)
    s2 = init_dfl_state(eng2.cfg, jnp.zeros((2,)), opt, jax.random.key(0))
    for e in range(drop_epoch):
        s2, _ = eng2.run_epoch(s2, e, task["batch_fn"])
    ckpt = Checkpointer(str(tmp_path))
    ckpt.save(drop_epoch, {"params": s2.client_params,
                           "opt_count": s2.opt_state.count})

    # ...restore at M-1 via restore_dropped: the failed server's row goes,
    # survivors re-index densely, the topology is the induced subgraph
    keep = np.array([i for i in range(m) if i != dropped])

    def narrow(x):
        return x[keep] if hasattr(x, "ndim") and x.ndim >= 1 \
            and x.shape[0] == m else x

    template = {"params": jax.tree.map(narrow, s2.client_params),
                "opt_count": s2.opt_state.count}
    restored, new_topo = ckpt.restore_dropped(template, dropped, topo)
    assert new_topo.num_servers == m - 1
    np.testing.assert_array_equal(new_topo.adjacency(),
                                  eng1.topo.adjacency())

    # ...and continue training on a FRESH engine over the restored state.
    # Data shards follow ORIGINAL server identity, so the continuation
    # engine's dense row indices map back through the survivor list.
    eng3 = make_engine(new_topo, task["loss_fn"], opt)

    def batch_fn(epoch, alive):
        return task["batch_fn"](epoch, tuple(survivors[i] for i in alive))

    s3 = DFLState(restored["params"], SGDState(restored["opt_count"]),
                  s2.epoch, s2.rng)
    for e in range(drop_epoch, total):
        s3, _ = eng3.run_epoch(s3, e, batch_fn)

    np.testing.assert_allclose(np.asarray(s3.client_params),
                               np.asarray(s1.client_params),
                               rtol=1e-6, atol=1e-7)


def test_restore_dropped_rejects_nothing_but_drops_row(tmp_path):
    """Unit shape check: the dropped row really is the named ORIGINAL row
    (not just any row) — restored survivor rows equal the original ones."""
    m, n = 3, 2
    topo = FLTopology(num_servers=m, clients_per_server=n, t_client=2,
                      t_server=2, graph_kind="complete")
    tree = {"w": jnp.arange(m * n * 2, dtype=jnp.float32).reshape(m, n, 2)}
    ckpt = Checkpointer(str(tmp_path))
    ckpt.save(0, tree)
    template = {"w": jnp.zeros((m - 1, n, 2))}
    restored, new_topo = ckpt.restore_dropped(template, 1, topo)
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(tree["w"])[np.array([0, 2])])
    assert new_topo.num_servers == m - 1
