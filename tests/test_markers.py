"""Test-tier hygiene audit: anything that forks an interpreter or forces
a >2-device host mesh is too heavy for the fast tier and must carry the
``slow`` marker (ROADMAP test-tier contract).  The audit is an AST walk
over the test files themselves, so a new unmarked subprocess test fails
HERE with a pointed message rather than silently bloating CI."""

import ast
import pathlib

TESTS = pathlib.Path(__file__).parent
# source fragments that mean "heavier than the fast tier": interpreter
# forks and forced multi-device host platforms (the subprocess payload
# strings live at module level, but the spawning call is in the function)
HEAVY_TOKENS = ("subprocess.run", "subprocess.Popen", "subprocess.call",
                "check_output", "xla_force_host_platform_device_count")


def _has_slow_mark(fn: ast.FunctionDef, module_marked: bool) -> bool:
    if module_marked:
        return True
    for dec in fn.decorator_list:
        node = dec.func if isinstance(dec, ast.Call) else dec
        if isinstance(node, ast.Attribute) and node.attr == "slow":
            return True
    return False


def _module_has_slow_pytestmark(tree: ast.Module, src: str) -> bool:
    for node in tree.body:
        if (isinstance(node, ast.Assign)
                and any(isinstance(t, ast.Name) and t.id == "pytestmark"
                        for t in node.targets)):
            seg = ast.get_source_segment(src, node) or ""
            if "slow" in seg:
                return True
    return False


def test_subprocess_and_mesh_tests_carry_slow_marker():
    offenders = []
    for path in sorted(TESTS.glob("test_*.py")):
        if path.name == "test_markers.py":
            continue
        src = path.read_text()
        tree = ast.parse(src)
        module_marked = _module_has_slow_pytestmark(tree, src)
        for node in ast.walk(tree):
            if not (isinstance(node, ast.FunctionDef)
                    and node.name.startswith("test_")):
                continue
            seg = ast.get_source_segment(src, node) or ""
            if not any(tok in seg for tok in HEAVY_TOKENS):
                continue
            if not _has_slow_mark(node, module_marked):
                offenders.append(f"{path.name}::{node.name}")
    assert not offenders, (
        "these tests spawn a subprocess or force a multi-device host mesh "
        "but lack @pytest.mark.slow (fast tier must stay light): "
        + ", ".join(offenders))


def test_audit_actually_sees_the_known_heavy_tests():
    """Anti-rot guard: the audit's token scan must still FIND the known
    subprocess-based suites (else a refactor silently blinded it)."""
    hits = 0
    for path in sorted(TESTS.glob("test_*.py")):
        if path.name == "test_markers.py":
            continue
        src = path.read_text()
        for node in ast.walk(ast.parse(src)):
            if (isinstance(node, ast.FunctionDef)
                    and node.name.startswith("test_")
                    and any(tok in (ast.get_source_segment(src, node) or "")
                            for tok in HEAVY_TOKENS)):
                hits += 1
    assert hits >= 5, f"marker audit only found {hits} heavy tests"
