"""PR-10 overlap contracts: superepoch megastep parity, bounded-staleness
gossip, the device-sync ledger, and the software-pipelined wire kernel.

Three families of assertions:

* **degeneration** — ``staleness=0`` and ``superepoch=1`` are not "almost"
  the old paths, they ARE the old paths: history and final state bitwise
  equal under partial participation + edge drops + drop/rejoin churn.
* **parity** — the fused K-epoch megastep reproduces the barrier engine's
  per-epoch history element-for-element at K in {1, 2, 4}, through fault
  surgery (blocks split at fault epochs), and the pipelined Pallas round
  kernel is bit-identical to the stale jnp oracle.
* **overlap semantics** — ``gossip_scan_stale`` realises the exact
  operator ``A^{floor(T_S / (s+1))}``, s=1 still converges on the m=8
  regression within the fig-3 tolerance, and the superepoch engine issues
  exactly ONE host readback per dispatched block (counted through the
  injectable ``_device_get`` hook).
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (DFLConfig, FLTopology, FaultSchedule,
                        ParticipationSchedule, TopologySchedule,
                        build_dfl_superepoch_step, gossip_scan_stale,
                        init_dfl_state, make_backend, make_engine,
                        stack_epoch_schedules)
from repro.core import consensus as cns
from repro.core import topology as tp
from repro.core.schedule import EpochSchedule, SigmaTracker
from repro.comm.compressors import StochasticQuantizer, pack_int4
from repro.data import RegressionSpec, make_regression_task
from repro.kernels.consensus_mix import bucketed_gossip_round_pipelined_2d
from repro.obs import FIG3_TOLERANCE
from repro.optim import sgd

M, N, GAMMA = 4, 3, 1e-2


def _engine(superepoch=1, staleness=0, *, m=M, n=N, t_client=3, t_server=4,
            faults="drop:3:2,rejoin:5:2", seed=0, epochs_hint=None,
            **cfg_kw):
    """A churny scenario: Bernoulli participation + per-epoch edge drops +
    a drop/rejoin cycle — the harshest schedule the parity claims cover."""
    topo = FLTopology(num_servers=m, clients_per_server=n,
                      t_client=t_client, t_server=t_server,
                      graph_kind="ring")
    task = make_regression_task(topo, RegressionSpec(heterogeneity=0.3),
                                seed=seed)
    eng = make_engine(
        topo, task["loss_fn"], sgd(GAMMA),
        participation=ParticipationSchedule(kind="bernoulli", rate=0.6,
                                            seed=seed + 3),
        topology_schedule=TopologySchedule(kind="edge_drop", drop_prob=0.3,
                                           seed=seed + 5),
        faults=FaultSchedule.parse(faults),
        superepoch=superepoch, staleness=staleness, **cfg_kw)
    state = init_dfl_state(eng.cfg, jnp.zeros((2,)), sgd(GAMMA),
                           jax.random.key(seed))
    return eng, state, task["batch_fn"]


def _assert_tree_equal(a, b):
    for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


# ---------------------------------------------------------------------------
# superepoch: history + state parity with the barrier engine
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("k", [1, 2, 4])
def test_superepoch_history_parity_bitwise(k):
    """K-epoch megastep == barrier loop, element-bitwise, through
    participation + edge drops + drop/rejoin churn (blocks split at the
    fault epochs)."""
    eng1, st1, bf1 = _engine(1)
    st1, h1 = eng1.run(st1, 7, bf1)
    engk, stk, bfk = _engine(k)
    stk, hk = engk.run(stk, 7, bfk)
    assert set(h1) == set(hk)
    for key in h1:
        assert h1[key] == hk[key], key
    _assert_tree_equal(st1.client_params, stk.client_params)


def test_superepoch_parity_push_sum_and_byzantine():
    """The stacked optional operands (byz codes, per-epoch psum weights)
    ride the scan too: parity holds under push_sum + a byzantine schedule
    + a robust screen, including the per-epoch psum_min_weight and
    screen_rejected columns."""
    from repro.core import ByzantineSchedule
    scenarios = (
        dict(faults="", mixing="push_sum"),
        dict(faults="", consensus_mode="trimmed_mean:1",
             byzantine=ByzantineSchedule.parse("sign_flip:0.3", seed=7)),
    )
    want_cols = ({"psum_min_weight"}, {"byzantine", "screen_rejected"})
    for kw, cols in zip(scenarios, want_cols):
        eng1, st1, bf1 = _engine(1, **kw)
        st1, h1 = eng1.run(st1, 6, bf1)
        eng3, st3, bf3 = _engine(3, **kw)
        st3, h3 = eng3.run(st3, 6, bf3)
        assert set(h1) == set(h3) and cols <= set(h1)
        for key in h1:
            assert h1[key] == h3[key], key
        _assert_tree_equal(st1.client_params, st3.client_params)


def test_superepoch_parity_compressed_wire():
    """wire_mb / wire_ratio history columns match per-epoch: the block
    ledger (``BytesTracker.update_many``) snapshots the cumulative ratio
    after each epoch, not after the block."""
    kw = dict(compression="int8:8", error_feedback=True, wire="physical")
    eng1, st1, bf1 = _engine(1, **kw)
    st1, h1 = eng1.run(st1, 6, bf1)
    eng2, st2, bf2 = _engine(2, **kw)
    st2, h2 = eng2.run(st2, 6, bf2)
    assert "wire_mb" in h1 and "wire_ratio" in h1
    for key in h1:
        assert h1[key] == h2[key], key
    _assert_tree_equal(st1.client_params, st2.client_params)


def test_superepoch_compile_once_per_m_k():
    """The stacked EpochScheduleBatch is a traced operand: one program per
    (M, K), however the masks/matrices/codes vary across blocks."""
    eng, st, bf = _engine(4)
    eng.run(st, 12, bf)
    counts = eng.superepoch_compile_counts()
    assert counts and all(c == 1 for c in counts.values()), counts
    # blocks split at fault epochs 3 and 5 -> K in {4, 3, 2, 1} appear
    assert {k for (_, k) in counts} >= {2, 3}


def test_plan_blocks_cuts_at_faults():
    eng, _, _ = _engine(4)
    blocks = eng._plan_blocks(10)
    # faults at 3 and 5: [0,3) [3,5) [5,10) chunked by <= 4
    assert blocks == [(0, 3), (3, 2), (5, 4), (9, 1)]
    assert sum(k for _, k in blocks) == 10
    starts = [e for e, _ in blocks]
    assert 3 in starts and 5 in starts


def test_stack_epoch_schedules_validation():
    a = np.eye(2, dtype=np.float32)
    mask = np.ones((2, 3), np.float32)
    with pytest.raises(ValueError, match="empty"):
        stack_epoch_schedules([])
    mixed = [EpochSchedule(mask, a, None, np.zeros(2, np.int32)),
             EpochSchedule(mask, a, None, None)]
    with pytest.raises(ValueError, match="uniform operand structure"):
        stack_epoch_schedules(mixed)
    sb = stack_epoch_schedules([EpochSchedule(mask, a)] * 3)
    assert sb.k == 3 and sb.mask.shape == (3, 2, 3)
    assert sb.lam2 is None and sb.byz is None


def test_superepoch_step_refuses_static_and_k0():
    topo = FLTopology(num_servers=2, clients_per_server=2, t_client=1,
                      t_server=1, graph_kind="complete")
    task = make_regression_task(topo, seed=0)
    with pytest.raises(ValueError, match="dynamic"):
        build_dfl_superepoch_step(DFLConfig(topology=topo),
                                  task["loss_fn"], sgd(GAMMA), 2)
    with pytest.raises(ValueError, match=">= 1"):
        build_dfl_superepoch_step(DFLConfig(topology=topo, dynamic=True),
                                  task["loss_fn"], sgd(GAMMA), 0)


# ---------------------------------------------------------------------------
# the device-sync ledger (satellite 1)
# ---------------------------------------------------------------------------


def test_one_device_get_per_dispatch():
    """EVERY host metric readback flows through the injectable
    ``_device_get`` hook: the barrier engine syncs exactly once per epoch
    (not once per metric — the old scattered float()/np.asarray reads),
    and the superepoch engine exactly once per K-epoch block."""
    for superepoch, epochs, dispatches in ((1, 6, 6), (3, 6, 2), (6, 6, 1)):
        eng, st, bf = _engine(superepoch, faults="", mixing="push_sum")
        calls = []
        real = eng._device_get
        eng._device_get = lambda x: (calls.append(1), real(x))[1]
        eng.run(st, epochs, bf)
        assert len(calls) == dispatches, (superepoch, len(calls))


# ---------------------------------------------------------------------------
# bounded staleness: semantics, degeneration, convergence
# ---------------------------------------------------------------------------


def test_gossip_scan_stale_zero_is_gossip_scan():
    a = jnp.asarray(tp.metropolis_weights(tp.ring_graph(5)), jnp.float32)
    tree = {"w": jax.random.normal(jax.random.key(0), (5, 7)),
            "b": jax.random.normal(jax.random.key(1), (5, 2, 3))}
    out0 = jax.jit(lambda t: gossip_scan_stale(a, t, 6, 0))(tree)
    ref = jax.jit(lambda t: cns.gossip_scan(a, t, 6))(tree)
    _assert_tree_equal(out0, ref)


@pytest.mark.parametrize("s,t_server", [(1, 2), (1, 5), (1, 8), (2, 7)])
def test_gossip_scan_stale_exact_operator(s, t_server):
    """Exact arithmetic: T_S stale rounds apply A^{floor(T_S/(s+1))} — the
    contraction SigmaTracker budgets for."""
    a = tp.metropolis_weights(tp.ring_graph(5)).astype(np.float32)
    w = np.random.default_rng(0).normal(size=(5, 4)).astype(np.float32)
    out = jax.jit(lambda t: gossip_scan_stale(
        jnp.asarray(a), t, t_server, s))({"w": jnp.asarray(w)})
    want = np.linalg.matrix_power(a, t_server // (s + 1)) @ w
    np.testing.assert_allclose(np.asarray(out["w"]), want, atol=1e-5)


def test_sigma_tracker_staleness_contraction():
    a = tp.metropolis_weights(tp.ring_graph(5))
    sync = SigmaTracker(5).update(a, 6)
    stale = SigmaTracker(5, staleness=1).update(a, 6)
    ref = SigmaTracker(5).update(a, 3)          # A^3 == 6 rounds at s=1
    assert stale == pytest.approx(ref)
    assert stale > sync                         # weaker contraction


def test_staleness0_engine_bitwise_degeneration():
    """DFLConfig(staleness=0) IS the synchronous path — bitwise, through
    participation + edge drops + churn, on both the einsum and the blocked
    backend."""
    for mode in ("gossip", "gossip_blocked"):
        eng0, st0, bf0 = _engine(1, consensus_mode=mode)
        st0, h0 = eng0.run(st0, 7, bf0)
        engz, stz, bfz = _engine(1, 0, consensus_mode=mode)
        stz, hz = engz.run(stz, 7, bfz)
        for key in h0:
            assert h0[key] == hz[key], (mode, key)
        _assert_tree_equal(st0.client_params, stz.client_params)


def test_staleness1_converges_fig3_m8():
    """s=1 on the m=8 regression: consensus still contracts (operator
    A^{floor(T_S/2)} per epoch) and the run lands within the fig-3
    disagreement tolerance of obs.monitor."""
    eng, st, bf = _engine(2, 1, m=8, n=2, t_client=10, t_server=10,
                          faults="")
    st, hist = eng.run(st, 40, bf)
    assert hist["disagreement"][-1] < FIG3_TOLERANCE
    # and the s=0 twin agrees on the final loss to fig-3 precision
    eng0, st0, bf0 = _engine(2, 0, m=8, n=2, t_client=10, t_server=10,
                             faults="")
    st0, hist0 = eng0.run(st0, 40, bf0)
    assert abs(hist["loss"][-1] - hist0["loss"][-1]) < FIG3_TOLERANCE


def test_staleness_refusal_matrix():
    topo = FLTopology(num_servers=3, clients_per_server=2, t_client=1,
                      t_server=2, graph_kind="complete")
    from repro.core import PushSumState, init_push_sum
    with pytest.raises(ValueError, match="staleness"):
        make_backend("gossip", topo.mixing_matrix(), 2,
                     staleness=1).mix_push_sum(
            init_push_sum({"w": jnp.zeros((3, 2))}))
    with pytest.raises(ValueError, match="staleness"):
        make_backend("collapsed", topo.mixing_matrix(), 2, staleness=1)
    with pytest.raises(ValueError, match="negative|>= 0"):
        make_backend("gossip", topo.mixing_matrix(), 2, staleness=-1)
    task = make_regression_task(topo, seed=0)
    from repro.core import build_dfl_epoch_step
    with pytest.raises(ValueError, match="push_sum"):
        build_dfl_epoch_step(
            DFLConfig(topology=topo, mixing="push_sum", staleness=1),
            task["loss_fn"], sgd(GAMMA))
    with pytest.raises(ValueError, match="none"):
        build_dfl_epoch_step(
            DFLConfig(topology=topo, consensus_mode="none", staleness=1),
            task["loss_fn"], sgd(GAMMA))
    # simulated-wire compression + staleness is incoherent: there is no
    # physical collective to overlap
    inner = cns.GossipBackend(topo.mixing_matrix(), 2, staleness=1)
    with pytest.raises(ValueError, match="physical"):
        cns.CompressedBackend(inner, StochasticQuantizer(bits=8, chunk=4),
                              wire="simulated")


@pytest.mark.slow
def test_stale_bucketed_wire_matches_shard_map():
    """The simulated stale wire (``gossip_scan_wire_bucketed`` with
    staleness) is bitwise the multi-device pipelined shard_map program —
    the double-buffered collective really computes the same recursion."""
    r = subprocess.run([sys.executable, "-c", _STALE_WIRE],
                       capture_output=True, text=True, timeout=600,
                       env={**os.environ, "PYTHONPATH": "src"})
    assert "OK" in r.stdout, r.stderr[-3000:]


_STALE_WIRE = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.core import consensus as cns
from repro.core import topology as tp
from repro.comm import compressors as cp
from repro.comm import accounting as acc

m, blk, chunk = 4, 32, 16
mesh = jax.sharding.Mesh(np.array(jax.devices()).reshape(m), ("server",))
tree = {"w": jax.random.normal(jax.random.key(0), (m, 4, 33)) * 2,
        "b": jax.random.normal(jax.random.key(1), (m, 7))}
specs = {"w": P("server", None, None), "b": P("server", None)}
key = jax.random.key(9)
a = jnp.asarray(tp.metropolis_weights(tp.ring_graph(m)), jnp.float32)

for bits in (8, 4):
    codec = cp.StochasticQuantizer(bits=bits, chunk=chunk)
    for s, t_s in ((1, 5), (2, 7)):
        run = cns.make_gossip_shard_map(mesh, t_s, specs, block=blk,
                                        codec=codec, staleness=s)
        ref = jax.jit(lambda t: cns.gossip_scan_wire_bucketed(
            a, t, t_s, codec, key, block=blk, staleness=s))(tree)
        out = run(a, tree, key)
        for k in tree:
            np.testing.assert_array_equal(
                np.asarray(out[k]), np.asarray(ref[k]), err_msg=f"{bits}:{s}:{k}")
    # the pipelined program keeps the 2-gather-per-round structure
    run1 = cns.make_gossip_shard_map(mesh, 5, specs, block=blk,
                                     codec=codec, staleness=1)
    hlo = jax.jit(run1).lower(a, tree, key).compile().as_text()
    gathers = [c for c in acc.hlo_collective_bytes(hlo)
               if c["op"] == "all-gather"]
    assert len(gathers) == 2, gathers
    assert sorted(c["dtype"] for c in gathers) == ["f32", "s8"], gathers

# staleness without the delta-coded wire must refuse at build time
try:
    cns.make_gossip_shard_map(mesh, 5, specs, block=blk, staleness=1)
except ValueError as e:
    assert "codec" in str(e)
else:
    raise AssertionError("plain shard_map accepted staleness")
print("OK")
"""


# ---------------------------------------------------------------------------
# the software-pipelined Pallas round kernel
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("bits", [8, 4])
def test_pipelined_kernel_matches_stale_oracle(bits):
    """encode -> own-decode -> delayed left-to-right consume, bit-identical
    to the stale wire body's jnp form for both code widths."""
    m, d, chunk = 4, 1024, 128
    codec = StochasticQuantizer(bits=bits, chunk=chunk)
    rng = np.random.default_rng(0)
    a = tp.metropolis_weights(tp.ring_graph(m)).astype(np.float32)
    w = rng.normal(size=(m, d)).astype(np.float32)
    ref = rng.normal(size=(m, d)).astype(np.float32) * 0.1
    acc = rng.normal(size=(m, d)).astype(np.float32) * 0.1
    qmax = 2 ** (bits - 1) - 1
    old_c = rng.integers(-qmax, qmax, size=(m, d)).astype(np.int8)
    old_s = (np.abs(rng.normal(size=(m, d // chunk))) + 0.1
             ).astype(np.float32)
    dither = np.full((m, d), 0.5, np.float32)
    # the oracle consumes codes in the codec's STORAGE layout (packed for
    # int4), the kernel in the UNPACKED all-gather layout
    old_c_oracle = (np.asarray(pack_int4(old_c)) if bits == 4 else old_c)

    def oracle(a, old_c, old_s, w, ref, acc, dither):
        a32 = a.astype(jnp.float32)
        delta = w.astype(jnp.float32) - ref
        codes, scales = codec.encode_block(delta, dither)
        own3 = codec.code_chunks(codes, d)
        ref2 = ref + (own3 * scales[..., None]).reshape(m, d)
        c3 = codec.code_chunks(old_c, d).astype(jnp.float32)
        ws = a32[:, :, None] * old_s
        acc3 = acc.reshape(m, -1, chunk)
        for j in range(m):
            acc3 = acc3 + ws[:, j, :, None] * c3[j]
        return acc3.reshape(m, d), ref2, codes, scales

    oa, orf, oq, osc = jax.jit(oracle)(a, old_c_oracle, old_s, w, ref,
                                       acc, dither)
    ka, kr, kq, ks = jax.jit(
        lambda *xs: bucketed_gossip_round_pipelined_2d(
            *xs, bits=bits, chunk=chunk, block_d=512))(
        a, old_c, old_s, w, ref, acc, dither)
    # the kernel ships UNPACKED codes; unpack the oracle's for bits=4
    oq_flat = np.asarray(codec.code_chunks(oq, d)).reshape(m, d)
    np.testing.assert_array_equal(oq_flat, np.asarray(kq))
    np.testing.assert_array_equal(np.asarray(oa), np.asarray(ka))
    np.testing.assert_array_equal(np.asarray(orf), np.asarray(kr))
    np.testing.assert_array_equal(np.asarray(osc), np.asarray(ks))


def test_pipelined_kernel_validation():
    z = jnp.zeros((2, 128), jnp.float32)
    c = jnp.zeros((2, 128), jnp.int8)
    s = jnp.ones((2, 1), jnp.float32)
    with pytest.raises(ValueError, match="bits"):
        bucketed_gossip_round_pipelined_2d(jnp.eye(2), c, s, z, z, z, z,
                                           bits=3, chunk=128)
    with pytest.raises(ValueError, match="divide D"):
        bucketed_gossip_round_pipelined_2d(jnp.eye(2), c[:, :100], s,
                                           z[:, :100], z[:, :100],
                                           z[:, :100], z[:, :100],
                                           chunk=32)
