"""Consensus strategies (Eq. 5/7): faithful vs collapsed vs Chebyshev."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import consensus as cns
from repro.core import topology as tp


def _tree(m, key):
    k1, k2 = jax.random.split(key)
    return {"w": jax.random.normal(k1, (m, 4, 3)),
            "b": jax.random.normal(k2, (m, 7))}


@pytest.mark.parametrize("kind", ["ring", "line", "complete"])
@pytest.mark.parametrize("t_s", [1, 5, 25])
def test_collapsed_equals_faithful(kind, t_s, rng_key):
    m = 5
    a_np = tp.metropolis_weights(tp.build_graph(kind, m))
    a = jnp.asarray(a_np, jnp.float32)
    a_eff = jnp.asarray(cns.collapse_mixing(a_np, t_s), jnp.float32)
    tree = _tree(m, rng_key)
    out_scan = cns.gossip_scan(a, tree, t_s)
    out_coll = cns.gossip_collapsed(a_eff, tree)
    for l1, l2 in zip(jax.tree.leaves(out_scan), jax.tree.leaves(out_coll)):
        np.testing.assert_allclose(l1, l2, rtol=2e-5, atol=2e-5)


def test_gossip_preserves_mean(rng_key):
    m = 6
    a = jnp.asarray(tp.metropolis_weights(tp.ring_graph(m)), jnp.float32)
    tree = _tree(m, rng_key)
    out = cns.gossip_scan(a, tree, 13)
    for before, after in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_allclose(before.mean(0), after.mean(0),
                                   rtol=1e-5, atol=1e-5)


def test_gossip_contracts_disagreement(rng_key):
    m = 6
    a_np = tp.metropolis_weights(tp.ring_graph(m))
    a = jnp.asarray(a_np, jnp.float32)
    tree = _tree(m, rng_key)

    def dis(t):
        leaves = jnp.concatenate([l.reshape(m, -1)
                                  for l in jax.tree.leaves(t)], 1)
        return float(jnp.linalg.norm(leaves - leaves.mean(0)))

    d0 = dis(tree)
    d1 = dis(cns.gossip_scan(a, tree, 5))
    d2 = dis(cns.gossip_scan(a, tree, 25))
    assert d1 < d0 and d2 < d1
    # Lemma-1 style bound: ||W_ts - 1 wbar|| <= sigma_A ||W_0 - 1 wbar||
    assert d1 <= tp.sigma_a(a_np, 5) * d0 * (1 + 1e-5)
    assert d2 <= tp.sigma_a(a_np, 25) * d0 * (1 + 1e-5)


def test_chebyshev_preserves_mean_and_accelerates(rng_key):
    m = 8
    a_np = tp.metropolis_weights(tp.ring_graph(m))
    a = jnp.asarray(a_np, jnp.float32)
    ev = np.sort(np.abs(np.linalg.eigvalsh(a_np)))[::-1]
    lam2 = float(ev[1])
    tree = _tree(m, rng_key)

    def dis(t):
        leaves = jnp.concatenate([l.reshape(m, -1)
                                  for l in jax.tree.leaves(t)], 1)
        return float(jnp.linalg.norm(leaves - leaves.mean(0)))

    rounds = 6
    cheb = cns.gossip_chebyshev(a, tree, rounds, lam2)
    plain = cns.gossip_scan(a, tree, rounds)
    for before, after in zip(jax.tree.leaves(tree), jax.tree.leaves(cheb)):
        np.testing.assert_allclose(before.mean(0), after.mean(0),
                                   rtol=2e-4, atol=2e-4)
    # same round budget: Chebyshev contracts strictly more on a ring
    assert dis(cheb) < dis(plain)


@pytest.mark.parametrize("block", [3, 5, 64])
def test_gossip_scan_blocked_pad_unpad_roundtrip(block, rng_key):
    """Non-divisible leaf sizes force padding: blocked gossip must still
    equal the reference leaf-wise scan after unpadding (total flattened
    D = 4*3 + 7 = 19 is divisible by none of the blocks)."""
    m, t_s = 5, 6
    a = jnp.asarray(tp.metropolis_weights(tp.ring_graph(m)), jnp.float32)
    tree = _tree(m, rng_key)
    out_blocked = cns.gossip_scan_blocked(a, tree, t_s, block=block)
    out_ref = cns.gossip_scan(a, tree, t_s)
    for l1, l2 in zip(jax.tree.leaves(out_blocked),
                      jax.tree.leaves(out_ref)):
        assert l1.shape == l2.shape
        np.testing.assert_allclose(l1, l2, rtol=2e-5, atol=2e-5)


def test_gossip_scan_blocked_t0_and_shapes(rng_key):
    m = 4
    a = jnp.asarray(tp.metropolis_weights(tp.ring_graph(m)), jnp.float32)
    tree = _tree(m, rng_key)
    out = cns.gossip_scan_blocked(a, tree, 0)
    for before, after in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(before, after)   # T_S=0 is identity


@pytest.mark.slow
def test_ring_gossip_shard_map_multidevice():
    """ppermute ring gossip == dense A gossip, on an 8-device subprocess."""
    import subprocess
    import sys
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.core import consensus as cns
from repro.core import topology as tp
m = 8
mesh = jax.make_mesh((m,), ("server",))
a = jnp.asarray(tp.uniform_weights(tp.ring_graph(m)), jnp.float32)
w_self = float(a[0, 0]); w_nb = float(a[0, 1])
tree = {"w": jax.random.normal(jax.random.key(0), (m, 16))}
run = cns.make_ring_gossip(mesh, "server", 7, w_self, w_nb)
out_ring = run(tree)
out_dense = cns.gossip_scan(a, tree, 7)
np.testing.assert_allclose(out_ring["w"], out_dense["w"], rtol=2e-5, atol=2e-5)
print("OK")
"""
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=300,
                       env={**os.environ, "PYTHONPATH": "src"})
    assert "OK" in r.stdout, r.stderr[-2000:]


def test_consensus_mix_kernel_pytree(rng_key):
    """Fused Pallas consensus kernel == dense reference on a pytree."""
    from repro.kernels import consensus_mix_pytree
    m = 5
    a_np = tp.metropolis_weights(tp.ring_graph(m))
    a_eff = jnp.asarray(cns.collapse_mixing(a_np, 10), jnp.float32)
    tree = _tree(m, rng_key)
    out_k = consensus_mix_pytree(a_eff, tree, block_d=8)
    out_d = cns.gossip_collapsed(a_eff, tree)
    for l1, l2 in zip(jax.tree.leaves(out_k), jax.tree.leaves(out_d)):
        np.testing.assert_allclose(l1, l2, rtol=2e-5, atol=2e-5)


@pytest.mark.slow
def test_gossip_shard_map_matches_dense():
    """The production u16-wire blocked shard_map gossip == dense gossip_scan
    numerically, on an 8-device (2 servers x 2 replica x 2 model) mesh."""
    import subprocess
    import sys
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.core import consensus as cns
from repro.core import topology as tp
m, t_s = 2, 7
mesh = jax.make_mesh((m, 2, 2), ("server", "replica", "model"))
a_np = tp.metropolis_weights(tp.ring_graph(m))
tree = {"w": jax.random.normal(jax.random.key(0), (m, 8, 64), jnp.bfloat16),
        "b": jax.random.normal(jax.random.key(1), (m, 32), jnp.bfloat16)}
specs = {"w": P("server", "replica", "model"), "b": P("server", "model")}
tree = {k: jax.device_put(v, NamedSharding(mesh, specs[k]))
        for k, v in tree.items()}
run = cns.make_gossip_shard_map(mesh, t_s, specs, block=128)
out_sm = jax.jit(run)(jnp.asarray(a_np, jnp.float32), tree)
out_ref = cns.gossip_scan(jnp.asarray(a_np, jnp.float32),
                          {k: v.astype(jnp.float32) for k, v in tree.items()},
                          t_s)
for k in tree:
    np.testing.assert_allclose(
        np.asarray(out_sm[k], jnp.float32), np.asarray(out_ref[k]),
        rtol=0.05, atol=0.05)   # bf16 wire vs f32 reference
print("OK")
"""
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=300,
                       env={**os.environ, "PYTHONPATH": "src"})
    assert "OK" in r.stdout, r.stderr[-2000:]
