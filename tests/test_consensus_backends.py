"""The unified consensus-execution backend: every ConsensusBackend must be
allclose-identical to the reference ``gossip_scan`` / ``gossip_push_sum``
under the same EpochSchedule — static, edge_drop, and asymmetric (push-sum)
alike — and the dynamic engine must run the production blocked / shard_map
paths it was previously locked out of.  Also covers the engine donation fix
(single buffered copy) and the psum-weight invariants across drop/rejoin
surgery."""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (DFLConfig, EpochSchedule, FaultEvent, FaultSchedule,
                        FLTopology, ParticipationSchedule, TopologySchedule,
                        build_dfl_epoch_step, init_dfl_state, make_engine)
from repro.core import consensus as cns
from repro.core import topology as tp
from repro.data import RegressionSpec, make_regression_task
from repro.optim import sgd

M, T_S = 5, 7


def _tree(m, key):
    k1, k2 = jax.random.split(key)
    return {"w": jax.random.normal(k1, (m, 4, 3)),
            "b": jax.random.normal(k2, (m, 7))}


def _schedule_mats(kind, epochs=3, m=M, **kw):
    """Per-epoch mixing matrices from a TopologySchedule (host side)."""
    topo = FLTopology(num_servers=m, clients_per_server=2, t_client=2,
                      t_server=T_S, graph_kind="ring",
                      mixing="out_degree" if kind == "asymmetric"
                      else "metropolis")
    sched = TopologySchedule(kind=kind, **kw)
    return [jnp.asarray(sched.mixing(topo, e), jnp.float32)
            for e in range(epochs)]


def _backends():
    a_np = tp.metropolis_weights(tp.ring_graph(M))
    return a_np, {
        "gossip": cns.make_backend("gossip", a_np, T_S),
        "gossip_blocked": cns.make_backend("gossip_blocked", a_np, T_S,
                                           block=5),
        "collapsed": cns.make_backend("collapsed", a_np, T_S),
        # identity compression: the comm wrapper must be invisible in math
        "compressed_identity": cns.make_backend(
            "gossip", a_np, T_S, compression="identity",
            error_feedback=True),
    }


# ---------------------------------------------------------------------------
# backend equivalence vs the reference schedule
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kind,kw", [("static", {}),
                                     ("edge_drop", {"drop_prob": 0.4,
                                                    "seed": 3})])
def test_backends_match_reference_gossip_traced(kind, kw, rng_key):
    """mix(tree, A_p) with a traced per-epoch matrix == gossip_scan(A_p)."""
    _, backends = _backends()
    tree = _tree(M, rng_key)
    for a_p in _schedule_mats(kind, **kw):
        ref = cns.gossip_scan(a_p, tree, T_S)
        for name, backend in backends.items():
            out = jax.jit(backend.mix)(tree, a_p)
            for l1, l2 in zip(jax.tree.leaves(out), jax.tree.leaves(ref)):
                np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                                           rtol=2e-5, atol=2e-5, err_msg=name)


def test_backends_static_matches_reference(rng_key):
    """mix(tree, None) uses the static topology matrix the backend holds."""
    a_np, backends = _backends()
    a = jnp.asarray(a_np, jnp.float32)
    tree = _tree(M, rng_key)
    ref = cns.gossip_scan(a, tree, T_S)
    for name, backend in backends.items():
        out = backend.mix(tree)
        for l1, l2 in zip(jax.tree.leaves(out), jax.tree.leaves(ref)):
            np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                                       rtol=2e-5, atol=2e-5, err_msg=name)


def test_backends_push_sum_match_reference_asymmetric(rng_key):
    """mix_push_sum under row-stochastic per-epoch A_p (the asymmetric
    schedule) == reference gossip_push_sum: values, weights, and the
    unbiased ratio read-out."""
    _, backends = _backends()
    tree = _tree(M, rng_key)
    for a_p in _schedule_mats("asymmetric", drop_prob=0.4, seed=5):
        tp.check_row_stochastic(np.asarray(a_p, np.float64), atol=1e-6)
        ref = cns.gossip_push_sum(a_p, cns.init_push_sum(tree), T_S)
        for name, backend in backends.items():
            out = jax.jit(backend.mix_push_sum)(cns.init_push_sum(tree), a_p)
            np.testing.assert_allclose(np.asarray(out.weight),
                                       np.asarray(ref.weight),
                                       rtol=2e-5, atol=2e-6, err_msg=name)
            for l1, l2 in zip(jax.tree.leaves(out.ratio()),
                              jax.tree.leaves(ref.ratio())):
                np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                                           rtol=2e-5, atol=2e-5, err_msg=name)
            # invariants: weights positive, summing to M
            w = np.asarray(out.weight)
            assert (w > 0).all(), (name, w)
            np.testing.assert_allclose(w.sum(), M, rtol=1e-5)


def test_gossip_push_sum_blocked_function(rng_key):
    """The module-level blocked push-sum variant (padding path included)."""
    a = jnp.asarray(tp.out_degree_weights(tp.directed_ring(M)), jnp.float32)
    tree = _tree(M, rng_key)
    out = cns.gossip_push_sum_blocked(a, cns.init_push_sum(tree), T_S,
                                      block=3)
    ref = cns.gossip_push_sum(a, cns.init_push_sum(tree), T_S)
    np.testing.assert_allclose(np.asarray(out.weight), np.asarray(ref.weight),
                               rtol=2e-5)
    for l1, l2 in zip(jax.tree.leaves(out.values),
                      jax.tree.leaves(ref.values)):
        np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                                   rtol=2e-5, atol=2e-5)
    # t_server=0 is the identity
    out0 = cns.gossip_push_sum_blocked(a, cns.init_push_sum(tree), 0)
    np.testing.assert_array_equal(np.asarray(out0.values["w"]),
                                  np.asarray(tree["w"]))


def test_make_backend_registry():
    a_np = tp.metropolis_weights(tp.ring_graph(M))
    for mode in cns.BACKEND_MODES:
        backend = cns.make_backend(mode, a_np, T_S)
        assert backend.name == mode
        assert not backend.compressed
    # chebyshev now consumes a traced A_p (+ per-epoch lam2 estimate)
    cheb = cns.make_backend("chebyshev", a_np, T_S)
    assert cheb.supports_traced and cheb.needs_spectral
    assert not cns.make_backend("exact_mean", a_np, T_S).supports_directed
    with pytest.raises(ValueError, match="unknown consensus mode"):
        cns.make_backend("bogus", a_np, T_S)
    with pytest.raises(ValueError, match="static mixing matrix"):
        cns.make_backend("gossip", None, T_S).mix({"w": jnp.ones((M, 2))})
    # direct-API guard rails: no silent garbage from undefined combinations
    with pytest.raises(ValueError, match="ratio-consensus"):
        cns.make_backend("exact_mean", a_np, T_S).mix_push_sum(
            cns.init_push_sum({"w": jnp.ones((M, 2))}))
    # a matrix-less chebyshev is traced-only: static mix has no operator
    with pytest.raises(ValueError, match="static mixing matrix"):
        cns.make_backend("chebyshev", None, T_S).mix({"w": jnp.ones((M, 2))})
    # compression wrapping through the registry
    wrapped = cns.make_backend("gossip", a_np, T_S, compression="int8",
                               error_feedback=True)
    assert wrapped.compressed and wrapped.error_feedback
    assert wrapped.name == "compressed[gossip+int8]"
    assert wrapped.supports_traced and wrapped.supports_directed
    with pytest.raises(ValueError, match="already-compressed"):
        cns.CompressedBackend(wrapped, wrapped.compressor)


# ---------------------------------------------------------------------------
# the lifted prohibitions: dynamic epoch steps on the production paths
# ---------------------------------------------------------------------------


def _dyn_setup(m=4, n=3, t_c=5, t_s=6):
    topo = FLTopology(num_servers=m, clients_per_server=n, t_client=t_c,
                      t_server=t_s, graph_kind="ring")
    task = make_regression_task(topo, RegressionSpec(heterogeneity=0.5),
                                seed=0)
    return topo, task


@pytest.mark.parametrize("mixing", ["symmetric", "push_sum"])
def test_dynamic_blocked_epoch_step_matches_gossip(mixing):
    """The previously-prohibited combinations — dynamic + gossip_blocked,
    and push_sum + gossip_blocked — agree with the reference gossip path
    under a per-epoch traced A_p."""
    topo, task = _dyn_setup()
    opt = sgd(1e-3)
    states, steps = {}, {}
    for mode in ("gossip", "gossip_blocked"):
        cfg = DFLConfig(topology=topo, consensus_mode=mode, dynamic=True,
                        mixing=mixing)
        steps[mode] = jax.jit(build_dfl_epoch_step(cfg, task["loss_fn"], opt))
        states[mode] = init_dfl_state(cfg, jnp.zeros((2,)), opt,
                                      jax.random.key(0))
    mask = jnp.ones((topo.num_servers, topo.clients_per_server), jnp.float32)
    kind = "asymmetric" if mixing == "push_sum" else "edge_drop"
    mats = _schedule_mats(kind, epochs=3, m=topo.num_servers, drop_prob=0.4,
                          seed=2)
    for a_p in mats:
        for mode in steps:
            states[mode], _ = steps[mode](states[mode], task["batches"],
                                          EpochSchedule(mask, a_p))
    np.testing.assert_allclose(
        np.asarray(states["gossip_blocked"].client_params),
        np.asarray(states["gossip"].client_params), rtol=2e-5, atol=2e-6)
    if mixing == "push_sum":
        np.testing.assert_allclose(
            np.asarray(states["gossip_blocked"].psum_weight),
            np.asarray(states["gossip"].psum_weight), rtol=2e-5)


def test_engine_gossip_blocked_full_scenario_matches_gossip():
    """End to end through the engine — participation sampling, edge drops,
    AND drop/rejoin surgery (per-M re-jit) — the blocked path tracks the
    einsum path allclose."""
    topo = FLTopology(num_servers=4, clients_per_server=3, t_client=5,
                      t_server=6, graph_kind="ring")
    task = make_regression_task(topo, RegressionSpec(heterogeneity=0.5),
                                seed=1)
    gamma = 1e-3
    finals = {}
    for mode in ("gossip", "gossip_blocked"):
        engine = make_engine(
            topo, task["loss_fn"], sgd(gamma), consensus_mode=mode,
            participation=ParticipationSchedule(kind="bernoulli", rate=0.6,
                                                seed=2),
            topology_schedule=TopologySchedule(kind="edge_drop",
                                               drop_prob=0.3, seed=4),
            faults=FaultSchedule((FaultEvent(2, "drop", 1),
                                  FaultEvent(5, "rejoin", 1))))
        state = init_dfl_state(engine.cfg, jnp.zeros((2,)), sgd(gamma),
                               jax.random.key(0))
        state, hist = engine.run(state, 7, task["batch_fn"])
        finals[mode] = np.asarray(state.client_params)
        assert engine.alive == [0, 2, 3, 1]
    np.testing.assert_allclose(finals["gossip_blocked"], finals["gossip"],
                               rtol=2e-5, atol=2e-6)


def test_engine_push_sum_blocked_weight_invariants_across_surgery():
    """psum_weight invariants on the blocked path through drop/rejoin
    surgery: reset to ones at each new federation size, positive, summing
    to the live M after every epoch."""
    topo = FLTopology(num_servers=4, clients_per_server=2, t_client=3,
                      t_server=6, graph_kind="ring")
    task = make_regression_task(topo, seed=0)
    engine = make_engine(
        topo, task["loss_fn"], sgd(1e-3), consensus_mode="gossip_blocked",
        mixing="push_sum",
        topology_schedule=TopologySchedule(kind="asymmetric", drop_prob=0.5,
                                           seed=3),
        faults=FaultSchedule((FaultEvent(1, "drop", 2),
                              FaultEvent(3, "rejoin", 2))))
    state = init_dfl_state(engine.cfg, jnp.zeros((2,)), sgd(1e-3),
                           jax.random.key(0))
    for epoch in range(5):
        state, rec = engine.run_epoch(state, epoch, task["batch_fn"])
        m_live = engine.topo.num_servers
        w = np.asarray(state.psum_weight)
        assert w.shape == (m_live,)
        assert (w > 0).all(), (epoch, w)
        np.testing.assert_allclose(w.sum(), m_live, rtol=1e-5)
        assert rec["psum_min_weight"] > 0
    # surgery reset: drop mid-state and check the fresh unit weights
    fresh = engine.apply_faults(
        state._replace(psum_weight=state.psum_weight * 2.0), 1)
    np.testing.assert_array_equal(np.asarray(fresh.psum_weight), 1.0)


# ---------------------------------------------------------------------------
# the donation fix
# ---------------------------------------------------------------------------


def test_engine_step_donates_carried_state():
    """The dynamic engine's compiled step donates the carried DFLState, so
    a run holds ONE buffered copy of client params + optimizer state (the
    input buffers are consumed) instead of two."""
    topo = FLTopology(num_servers=3, clients_per_server=2, t_client=3,
                      t_server=4, graph_kind="ring")
    task = make_regression_task(topo, seed=0)
    engine = make_engine(topo, task["loss_fn"], sgd(1e-3))
    state = init_dfl_state(engine.cfg, jnp.zeros((2,)), sgd(1e-3),
                           jax.random.key(0))
    params_in = state.client_params
    opt_in = jax.tree.leaves(state.opt_state)
    new_state, _ = engine.run_epoch(state, 0, task["batch_fn"])
    assert params_in.is_deleted()
    assert all(l.is_deleted() for l in opt_in if hasattr(l, "is_deleted"))
    assert not new_state.client_params.is_deleted()
    # the step signature no longer carries the dead `donate` flag
    import inspect
    assert "donate" not in inspect.signature(build_dfl_epoch_step).parameters


# ---------------------------------------------------------------------------
# mesh-bound backends and fault surgery
# ---------------------------------------------------------------------------


def test_engine_rejects_mesh_bound_backend_with_faults():
    topo = FLTopology(num_servers=2, clients_per_server=2, t_client=2,
                      t_server=2, graph_kind="ring")

    class FakeShardMap(cns.ConsensusBackend):
        name = "shard_map"
        mesh_bound = True

        def _mix(self, tree, a):
            return tree

    backend = FakeShardMap(topo.mixing_matrix(), topo.t_server)
    with pytest.raises(ValueError, match="mesh-bound"):
        make_engine(topo, lambda w, b, r: (jnp.zeros(()), {}), sgd(1e-3),
                    consensus_backend=backend,
                    faults=FaultSchedule((FaultEvent(1, "drop", 1),)))


# ---------------------------------------------------------------------------
# CLI plumbing (launch/train.py)
# ---------------------------------------------------------------------------


def test_trainer_cli_exposes_blocked_and_backend_flags():
    from repro.launch.train import (CONSENSUS_BACKENDS, build_parser,
                                    resolve_consensus_backend)
    args = build_parser().parse_args(
        ["--consensus-mode", "gossip_blocked", "--consensus-backend",
         "blocked"])
    assert args.consensus_mode == "gossip_blocked"
    assert args.consensus_backend == "blocked"
    assert set(CONSENSUS_BACKENDS) == {"auto", "einsum", "blocked",
                                       "shard_map"}
    topo = FLTopology(num_servers=2, clients_per_server=2, t_client=2,
                      t_server=2)
    params = {"w": jnp.zeros((3,))}
    # flag -> config plumbing
    assert resolve_consensus_backend("auto", "gossip_blocked", topo,
                                     params) == ("gossip_blocked", None)
    assert resolve_consensus_backend("blocked", "gossip", topo,
                                     params) == ("gossip_blocked", None)
    assert resolve_consensus_backend("einsum", "gossip_blocked", topo,
                                     params) == ("gossip", None)
    with pytest.raises(ValueError, match="undefined"):
        resolve_consensus_backend("blocked", "exact_mean", topo, params)
    if jax.device_count() < topo.num_servers:
        with pytest.raises(ValueError, match="device"):
            resolve_consensus_backend("shard_map", "gossip", topo, params)


def test_trainer_runs_gossip_blocked_end_to_end():
    """--consensus-mode gossip_blocked drives a (tiny) LM epoch."""
    from repro.launch.train import train
    res = train("smollm-360m", servers=2, clients=1, t_client=1, t_server=3,
                epochs=2, seq_len=16, per_client_batch=1, gamma=0.05,
                consensus_mode="gossip_blocked", log_every=100)
    assert len(res["history"]["loss"]) == 2
    assert np.isfinite(res["history"]["loss"]).all()
    assert res["history"]["disagreement"][-1] < 1e-2


# ---------------------------------------------------------------------------
# shard_map backend (multi-device): subprocess, slow tier
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_shard_map_backend_dynamic_engine_matches_gossip():
    """The ShardMapBackend consumes a traced per-epoch A_p (including the
    push-sum variant) inside the dynamic engine, matching the reference
    gossip engine allclose — on a 4-device forced-host mesh."""
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from repro.core import (FLTopology, ParticipationSchedule, TopologySchedule,
                        init_dfl_state, make_engine)
from repro.core import consensus as cns
from repro.data import RegressionSpec, make_regression_task
from repro.launch import sharding as shd
from repro.optim import sgd

m = 4
topo = FLTopology(num_servers=m, clients_per_server=2, t_client=4,
                  t_server=5, graph_kind="ring")
task = make_regression_task(topo, RegressionSpec(heterogeneity=0.5), seed=0)
mesh = jax.sharding.Mesh(np.array(jax.devices()).reshape(m), ("server",))
server_abs = jax.eval_shape(lambda: jnp.zeros((m, 2), jnp.float32))
backend = shd.fl_consensus_backend(topo, mesh, server_abs, tp_axis=None,
                                   block=8)
assert backend.name == "shard_map" and backend.mesh_bound

for mixing, kind in (("symmetric", "edge_drop"), ("push_sum", "asymmetric")):
    base = FLTopology(num_servers=m, clients_per_server=2, t_client=4,
                      t_server=5, graph_kind="ring",
                      mixing="out_degree" if mixing == "push_sum"
                      else "metropolis")
    finals = {}
    for name, kw in (("gossip", {}), ("shard_map",
                                      {"consensus_backend": backend})):
        engine = make_engine(
            base, task["loss_fn"], sgd(1e-3), mixing=mixing,
            participation=ParticipationSchedule(kind="bernoulli", rate=0.7,
                                                seed=1),
            topology_schedule=TopologySchedule(kind=kind, drop_prob=0.4,
                                               seed=3), **kw)
        state = init_dfl_state(engine.cfg, jnp.zeros((2,)), sgd(1e-3),
                               jax.random.key(0))
        state, hist = engine.run(state, 3, task["batch_fn"])
        finals[name] = np.asarray(state.client_params)
        if mixing == "push_sum":
            w = np.asarray(state.psum_weight)
            assert (w > 0).all() and abs(w.sum() - m) < 1e-3, w
    np.testing.assert_allclose(finals["shard_map"], finals["gossip"],
                               rtol=2e-4, atol=2e-5)
print("OK")
"""
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=480,
                       env={**os.environ, "PYTHONPATH": "src"})
    assert "OK" in r.stdout, r.stderr[-3000:]


@pytest.mark.slow
def test_shard_map_traced_operator_matches_dense():
    """make_gossip_shard_map with a TRACED operator: one compiled program
    serves distinct per-epoch matrices, plain and transposed (push-sum)."""
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.core import consensus as cns
from repro.core import topology as tp
m, t_s = 4, 6
mesh = jax.sharding.Mesh(np.array(jax.devices()).reshape(m), ("server",))
specs = {"w": P("server", None)}
run = jax.jit(cns.make_gossip_shard_map(mesh, t_s, specs, block=16))
tree = {"w": jax.random.normal(jax.random.key(0), (m, 40))}
mats = [tp.metropolis_weights(tp.ring_graph(m)),
        tp.metropolis_weights(tp.complete_graph(m)),
        tp.out_degree_weights(tp.directed_ring(m))]
for a_np in mats:
    a = jnp.asarray(a_np, jnp.float32)
    np.testing.assert_allclose(
        np.asarray(run(a, tree)["w"]),
        np.asarray(cns.gossip_scan(a, tree, t_s)["w"]),
        rtol=2e-5, atol=2e-5)
    # transposed operator == push-sum numerator mixing
    np.testing.assert_allclose(
        np.asarray(run(a.T, tree)["w"]),
        np.asarray(cns.gossip_push_sum(
            a, cns.init_push_sum(tree), t_s).values["w"]),
        rtol=2e-5, atol=2e-5)
print("OK")
"""
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=300,
                       env={**os.environ, "PYTHONPATH": "src"})
    assert "OK" in r.stdout, r.stderr[-3000:]
